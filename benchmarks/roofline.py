"""Roofline analysis from dry-run artifacts (EXPERIMENTS.md §Roofline).

Terms per (arch, shape, mesh), all derived from the compiled dry-run:

  t_compute    = HLO_FLOPs/device / peak_FLOPs        (197 TF bf16, v5e)
  t_memory     = HLO_bytes/device / HBM_bw            (819 GB/s)
  t_collective = collective_bytes/device / link_bw    (~50 GB/s ICI)

plus MODEL_FLOPS = 6·N·D (train) or 2·N_active·D (inference) and the
useful-compute ratio MODEL_FLOPS / HLO_FLOPs_global (catches remat and
reconstruction overhead).
"""

from __future__ import annotations

import glob
import json
import os
from typing import Dict, List

PEAK_FLOPS = 197e12  # bf16 / chip
HBM_BW = 819e9  # bytes/s
LINK_BW = 50e9  # bytes/s ICI per chip (v5e, 1 usable link assumption)


def _model_flops(arch: str, shape: str, chips: int) -> float:
    import sys

    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
    from repro.configs.base import active_param_count_estimate
    from repro.configs.registry import get_arch, get_shape

    cfg = get_arch(arch)
    s = get_shape(shape)
    n_active = active_param_count_estimate(cfg)
    if s.kind == "train":
        tokens = s.global_batch * s.seq_len
        return 6.0 * n_active * tokens
    if s.kind == "prefill":
        tokens = s.global_batch * s.seq_len
        return 2.0 * n_active * tokens
    tokens = s.global_batch  # one new token per sequence
    return 2.0 * n_active * tokens


def summarize_file(path: str) -> Dict:
    with open(path) as f:
        rec = json.load(f)
    if rec.get("skipped") or "error" in rec:
        return rec
    chips = 512 if rec["mesh"] == "2x16x16" else 256
    flops_dev = float(rec["flops_per_device"])
    bytes_dev = float(rec["bytes_accessed_per_device"])
    coll_dev = float(sum(rec["collective_bytes_per_device"].values()))
    t_comp = flops_dev / PEAK_FLOPS
    t_mem = bytes_dev / HBM_BW
    t_coll = coll_dev / LINK_BW
    terms = {"compute": t_comp, "memory": t_mem, "collective": t_coll}
    bound = max(terms, key=terms.get)
    mf = _model_flops(rec["arch"], rec["shape"], chips)
    useful = mf / max(flops_dev * chips, 1.0)
    suggestions = {
        "compute": "raise arithmetic intensity: larger per-chip batch or "
                   "fewer recomputed FLOPs (remat policy)",
        "memory": "cut bytes/step: fuse reconstruction into consumers, "
                  "bf16 residuals, smaller CE/f32 footprint",
        "collective": "shrink traffic on the dominant collective: bit-pack "
                      "masks, reshard weights sharding-major, overlap "
                      "reduce with compute",
    }
    return {
        "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
        "mode": rec.get("mode", ""),
        "t_compute_ms": t_comp * 1e3,
        "t_memory_ms": t_mem * 1e3,
        "t_collective_ms": t_coll * 1e3,
        "bound": bound,
        "model_flops": mf,
        "hlo_flops_global": flops_dev * chips,
        "useful_ratio": useful,
        "hbm_temp_gb": rec["memory"]["temp_bytes"] / 1e9,
        "collectives": rec["collective_bytes_per_device"],
        "move_next": suggestions[bound],
    }


def summarize_dir(d: str, mesh: str = "16x16", mode: str = "zampling"
                  ) -> List[Dict]:
    rows = []
    for path in sorted(glob.glob(os.path.join(d, "*.json"))):
        base = os.path.basename(path)
        if not base.endswith(f"_{mesh}_{mode}.json"):
            continue
        r = summarize_file(path)
        if r.get("skipped") or "error" in r:
            continue
        rows.append(r)
    return rows


def markdown_table(rows: List[Dict]) -> str:
    hdr = ("| arch | shape | t_comp (ms) | t_mem (ms) | t_coll (ms) | bound "
           "| useful | temp GB |\n|---|---|---|---|---|---|---|---|")
    lines = [hdr]
    for r in rows:
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['t_compute_ms']:.2f} "
            f"| {r['t_memory_ms']:.2f} | {r['t_collective_ms']:.2f} "
            f"| **{r['bound']}** | {r['useful_ratio']:.2f} "
            f"| {r['hbm_temp_gb']:.1f} |"
        )
    return "\n".join(lines)


if __name__ == "__main__":
    import sys

    rows = summarize_dir(sys.argv[1] if len(sys.argv) > 1 else
                         "experiments/dryrun")
    print(markdown_table(rows))
