"""Benchmark harness — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows; full row dumps land in
experiments/results/<bench>.json.  ``--full`` switches to the paper's
full grids (hours on CPU); default is the quick CI-scale pass that
still exercises every claim.

  PYTHONPATH=src python -m benchmarks.run [--full] [--only table2,...]
"""

import argparse
import json
import os
import sys
import time


def _emit(name, us, derived):
    print(f"{name},{us:.1f},{derived}")
    sys.stdout.flush()


def _dump(name, rows):
    os.makedirs("experiments/results", exist_ok=True)
    with open(f"experiments/results/{name}.json", "w") as f:
        json.dump(rows, f, indent=2, default=str)


def bench_kernel_reconstruct():
    """Microbenchmark of the hot op (ref vs pallas-interpret on CPU)."""
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.core.qspec import make_qspec
    from repro.kernels import ops

    spec = make_qspec(0, (1024, 1024), 1024, compression=32, d=8, window=512)
    z = jnp.asarray(
        (np.random.RandomState(0).rand(spec.n) < 0.5), jnp.float32
    )
    out = {}
    for impl in ("ref", "pallas"):
        f = jax.jit(lambda z_, impl=impl: ops.reconstruct(spec, z_, impl=impl))
        f(z).block_until_ready()
        t0 = time.perf_counter()
        iters = 20 if impl == "ref" else 3
        for _ in range(iters):
            f(z).block_until_ready()
        us = (time.perf_counter() - t0) / iters * 1e6
        out[impl] = us
        _emit(f"kernel_qz_reconstruct_{impl}", us,
              f"m={spec.m};n={spec.n};d={spec.d}")
    return [out]


def bench_table1(full=False):
    from repro.experiments import comm_savings_table

    t0 = time.perf_counter()
    rows = comm_savings_table()
    us = (time.perf_counter() - t0) * 1e6
    for r in rows:
        _emit("table1_comm_savings", us / len(rows),
              f"{r['method']}:client={r['client_savings']:.0f}x"
              f";server={r['server_savings']:.2f}x")
    return rows


def bench_table2(full=False):
    from repro.experiments import run_local_compression

    t0 = time.perf_counter()
    rows = run_local_compression(quick=not full)
    us = (time.perf_counter() - t0) * 1e6 / max(len(rows), 1)
    for r in rows:
        _emit("table2_compression", us,
              f"d={r['d']};m/n={r['compression']}"
              f";sampled={r['sampled_acc']:.3f}")
    return rows


def bench_fig4(full=False):
    from repro.experiments import run_federated

    t0 = time.perf_counter()
    rows = run_federated(quick=not full)
    us = (time.perf_counter() - t0) * 1e6 / max(len(rows), 1)
    for r in rows:
        _emit("fig4_federated", us,
              f"m/n={r['compression']};acc={r['final_sampled_acc']:.3f}"
              f";client_savings={r['client_savings']:.0f}x")
    return rows


def bench_table4(full=False):
    from repro.experiments import run_sensitivity

    t0 = time.perf_counter()
    rows = run_sensitivity(quick=not full)
    us = (time.perf_counter() - t0) * 1e6 / max(len(rows), 1)
    for r in rows:
        _emit("table4_sensitivity", us,
              f"{r['training']};tau={r['tau']}"
              f";sens={r['avg_sensitivity']:.4f}")
    return rows


def bench_fig5(full=False):
    from repro.experiments import run_integrality

    t0 = time.perf_counter()
    rows = run_integrality(quick=not full)
    us = (time.perf_counter() - t0) * 1e6 / max(len(rows), 1)
    for r in rows:
        _emit("fig5_integrality", us,
              f"beta={r['beta']};gap={r['integrality_gap']:.3f}")
    return rows


def bench_fig6(full=False):
    from repro.experiments import run_zhou_comparison

    t0 = time.perf_counter()
    rows = run_zhou_comparison(quick=not full)
    us = (time.perf_counter() - t0) * 1e6 / max(len(rows), 1)
    for r in rows:
        _emit("fig6_zhou", us,
              f"{r['method']};mean={r['mean_sampled_acc']:.3f}"
              f";best={r['best_mask_acc']:.3f}")
    return rows


def bench_roofline(full=False):
    """Roofline terms per (arch x shape) from the dry-run artifacts."""
    from benchmarks.roofline import summarize_dir

    rows = summarize_dir("experiments/dryrun")
    for r in rows:
        _emit("roofline", 0.0,
              f"{r['arch']}/{r['shape']}:bound={r['bound']}"
              f";t_comp={r['t_compute_ms']:.2f}ms"
              f";t_mem={r['t_memory_ms']:.2f}ms"
              f";t_coll={r['t_collective_ms']:.2f}ms")
    return rows


BENCHES = {
    "kernel": lambda full: bench_kernel_reconstruct(),
    "table1": bench_table1,
    "table2": bench_table2,
    "fig4": bench_fig4,
    "table4": bench_table4,
    "fig5": bench_fig5,
    "fig6": bench_fig6,
    "roofline": bench_roofline,
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--only", default=None)
    args = ap.parse_args()
    only = args.only.split(",") if args.only else list(BENCHES)
    print("name,us_per_call,derived")
    for name in only:
        try:
            rows = BENCHES[name](args.full)
            _dump(name, rows)
        except Exception as e:  # noqa: BLE001
            _emit(name, 0.0, f"ERROR:{e}")


if __name__ == "__main__":
    main()
