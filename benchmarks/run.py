"""Benchmark harness — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows; full row dumps land in
experiments/results/<bench>.json.  ``--full`` switches to the paper's
full grids (hours on CPU); default is the quick CI-scale pass that
still exercises every claim.

  PYTHONPATH=src python -m benchmarks.run [--full] [--only table2,...]
"""

import argparse
import functools
import json
import os
import sys
import time


def _emit(name, us, derived):
    print(f"{name},{us:.1f},{derived}")
    sys.stdout.flush()


def _dump(name, rows):
    os.makedirs("experiments/results", exist_ok=True)
    with open(f"experiments/results/{name}.json", "w") as f:
        json.dump(rows, f, indent=2, default=str)


def bench_kernel_reconstruct():
    """Microbenchmark of the hot op, one row per impl.

    On CPU the 'pallas' impl runs in INTERPRET mode: its timing is a
    correctness-path artifact (the interpreter evaluates the one-hot
    contraction element by element), NOT kernel performance — so that
    row is keyed ``{"impl": "pallas_interpret"}`` with
    ``regression_comparable: False`` and must be EXCLUDED from any
    perf-regression comparison.  Hardware Pallas numbers (a TPU run)
    replace it under ``{"impl": "pallas"}`` when available.
    """
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.core.qspec import make_qspec
    from repro.kernels import ops

    spec = make_qspec(0, (1024, 1024), 1024, compression=32, d=8, window=512)
    z = jnp.asarray(
        (np.random.RandomState(0).rand(spec.n) < 0.5), jnp.float32
    )
    rows = []
    for impl, key in (("ref", "ref"), ("pallas", "pallas_interpret")):
        f = jax.jit(lambda z_, impl=impl: ops.reconstruct(spec, z_, impl=impl))
        f(z).block_until_ready()
        t0 = time.perf_counter()
        iters = 20 if impl == "ref" else 3
        for _ in range(iters):
            f(z).block_until_ready()
        us = (time.perf_counter() - t0) / iters * 1e6
        rows.append({
            "bench": "kernel_qz_reconstruct", "impl": key, "us": us,
            "m": spec.m, "n": spec.n, "d": spec.d,
            "regression_comparable": impl == "ref",
        })
        _emit(f"kernel_qz_reconstruct_{key}", us,
              f"m={spec.m};n={spec.n};d={spec.d}")
    return rows


def bench_federated_round(full=False):
    """The batched multi-client reconstruction win (this PR's tentpole):
    vmap-of-single-client w = Qz vs the natively-batched kernel at
    K clients per host, forward and vmap(grad) chain, ref path on CPU.

    Rows land in experiments/results/fedround.json AND are merged into
    BENCH_reconstruct.json at the repo root (the cross-PR perf
    baseline; see scripts/ci.sh).

    NOTE (transpose-plan PR): the row plan is now a per-spec cached
    CONSTANT (core.transpose_plan), so the vmap-of-single-client
    baseline no longer pays K-times hash+Box–Muller regeneration —
    both sides start from the same baked plan and ``speedup`` measures
    only the contraction-strategy difference (the batched entry stays
    the memory-bounded choice: O(m_pad·d) temporaries vs the vmap
    mega-gather's O(K·m_pad·d)).  The headline backward comparison
    lives in the ``bwd_transpose_plan`` rows (bench_bwd).
    """
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.core.qspec import make_qspec
    from repro.kernels import ops

    spec = make_qspec(0, (1024, 1024), 1024, compression=32, d=8, window=512)
    rows = []
    for K in (4, 10, 32):
        Z = jnp.asarray(
            (np.random.RandomState(0).rand(K, spec.n) < 0.5), jnp.float32
        )
        V = jnp.asarray(
            np.random.RandomState(1).randn(K, *spec.shape), jnp.float32
        )
        f_vmap = jax.jit(jax.vmap(
            lambda z: ops.reconstruct(spec, z, auto_batch=False)
        ))
        f_bat = jax.jit(lambda Z_: ops.reconstruct_batched(spec, Z_))
        g_vmap = jax.jit(jax.vmap(jax.grad(
            lambda z, v: jnp.vdot(
                ops.reconstruct(spec, z, auto_batch=False), v
            )
        )))
        g_bat = jax.jit(jax.grad(
            lambda Z_, v: jnp.vdot(ops.reconstruct_batched(spec, Z_), v)
        ))
        g_bat = functools.partial(g_bat, v=V)
        np.testing.assert_allclose(
            np.asarray(f_vmap(Z)), np.asarray(f_bat(Z)), rtol=1e-4, atol=1e-4
        )
        jax.block_until_ready(g_bat(Z))  # compile before timing
        np.testing.assert_allclose(
            np.asarray(g_vmap(Z, V)), np.asarray(g_bat(Z)),
            rtol=1e-4, atol=1e-4,
        )
        iters = 5 if not full else 20
        out = {"bench": "federated_round_reconstruct", "K": K,
               "m": spec.m, "n": spec.n, "d": spec.d}
        for name, f in (("vmap", lambda: f_vmap(Z)),
                        ("batched", lambda: f_bat(Z)),
                        ("vmap_bwd", lambda: g_vmap(Z, V)),
                        ("batched_bwd", lambda: g_bat(Z))):
            f().block_until_ready()
            t0 = time.perf_counter()
            for _ in range(iters):
                f().block_until_ready()
            out[f"{name}_us"] = (time.perf_counter() - t0) / iters * 1e6
        out["speedup"] = out["vmap_us"] / out["batched_us"]
        out["bwd_speedup"] = out["vmap_bwd_us"] / out["batched_bwd_us"]
        _emit(f"fedround_reconstruct_K{K}", out["batched_us"],
              f"vmap={out['vmap_us']:.0f}us"
              f";speedup={out['speedup']:.2f}x"
              f";bwd_speedup={out['bwd_speedup']:.2f}x")
        rows.append(out)
    return rows


def _merge_bench_root(rows):
    """Merge benchmark rows into BENCH_reconstruct.json at the repo
    root, keyed by (bench, K, strategy, impl, m_pad_d) — the perf
    trajectory across PRs (unused key fields are None per bench).
    Legacy pre-impl-keyed ``kernel_qz_reconstruct`` rows (one dict
    holding both a ref and an interpret-mode Pallas timing as if they
    were comparable) are dropped on sight."""
    path = os.path.join(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))), "BENCH_reconstruct.json")

    def _key(r):
        return (r.get("bench"), r.get("K"), r.get("strategy"),
                r.get("impl"), r.get("m_pad_d"))

    def _legacy(r):
        return (r.get("bench") == "kernel_qz_reconstruct"
                and "impl" not in r)

    try:
        with open(path) as f:
            kept = {_key(r): r for r in json.load(f) if not _legacy(r)}
    except FileNotFoundError:
        kept = {}
    except (OSError, ValueError, AttributeError, TypeError) as e:
        # unparseable/wrong-shape baseline: restart it, but say so —
        # the accumulated cross-PR history is being dropped
        print(f"WARNING: resetting corrupt {path}: {e}", file=sys.stderr)
        kept = {}
    for r in rows:
        if isinstance(r, dict) and "bench" in r:
            kept[_key(r)] = r
    with open(path, "w") as f:
        json.dump(list(kept.values()), f, indent=2, default=str)
    return path


def bench_wire(full=False):
    """Wire-format transports on a stacked client mask slab: time the
    three aggregation strategies, check bit-exactness, and report the
    exact wire bytes each puts on the network (comm.metering).

    Rows land in experiments/results/wire.json AND are merged into
    BENCH_reconstruct.json at the repo root keyed by
    (bench, K, strategy) — the CI staleness gate (scripts/ci.sh)
    asserts the committed JSON carries all three strategies.
    """
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.comm.metering import mask_uplink_bytes
    from repro.comm.protocol import get_transport, transport_names

    # n is FIXED across quick/--full runs: the rows are keyed by
    # (bench, K, strategy) in BENCH_reconstruct.json, so a different n
    # would silently overwrite the cross-PR baseline with an
    # incomparable problem size (--full only raises iteration counts)
    n = 1 << 20
    rows = []
    for K in (10, 32):
        Z = jnp.asarray(
            (np.random.RandomState(0).rand(K, n) < 0.5), jnp.float32
        )
        names = transport_names(include_aliases=False)
        outs = {
            name: np.asarray(
                jax.jit(get_transport(name).aggregate_stacked)(Z)
            )
            for name in names
        }
        for name in names:
            np.testing.assert_array_equal(
                outs[name], outs["mean_f32"],
                err_msg=f"{name} not bit-exact vs mean_f32",
            )
        for name in names:
            t = get_transport(name)
            f = jax.jit(t.aggregate_stacked)
            f(Z).block_until_ready()
            iters = 20 if full else 5
            t0 = time.perf_counter()
            for _ in range(iters):
                f(Z).block_until_ready()
            us = (time.perf_counter() - t0) / iters * 1e6
            up = mask_uplink_bytes(t, n)
            f32_up = mask_uplink_bytes(get_transport("mean_f32"), n)
            rows.append({
                "bench": "wire_aggregate", "strategy": name, "K": K,
                "n": n, "us": us,
                "uplink_bytes_per_client": up,
                "uplink_vs_f32": up / f32_up,
            })
            _emit(f"wire_aggregate_{name}_K{K}", us,
                  f"up={up}B;vs_f32={up / f32_up:.4f}")
    return rows


def bench_fused(full=False):
    """Fused mask lifecycle vs the composed oracle (this PR's
    tentpole): ``w = Q·Bern(f(s))`` as one op vs sample -> reconstruct
    with the (K, n) f32 mask slab materialized between dispatches, and
    ``sample_pack`` (scores -> uint32 wire lanes) vs draw -> pack.

    Spec point: m = n = 2^20, compression 1, d = 1 — the paper's
    Zhou-et-al. retrieval configuration (Q diagonal), where the mask
    lifecycle IS the round and fusion matters most on CPU.  At the
    compression-32 / d-8 end the Q-gather dominates the ref path
    ~256:1, so the CPU-visible fused win shrinks to dispatch noise —
    there the win is architectural (the (K, n) f32 slab never crossing
    HBM; see kernels/qz_reconstruct.py).  n is FIXED across
    quick/--full runs: rows are keyed (bench, K) in
    BENCH_reconstruct.json and --full only raises iteration counts.

    Composed timings are the honest pre-fusion pipeline: separate
    dispatches with the straight-through ``p + sg(z - p)`` slab
    crossing memory between them — exactly what ``mask_path='composed'``
    (the bit-exact oracle) pays per round.  Fused and composed are
    timed INTERLEAVED (median of alternating runs) so load drift
    cancels; bit-exactness of fused vs composed is asserted before
    timing.
    """
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.comm.bitpack import pack_mask
    from repro.core.qspec import make_qspec
    from repro.core.sampling import sample_mask_hash, sample_mask_st_hash
    from repro.kernels import ops

    spec = make_qspec(0, (1024, 1024), 1024, compression=1, d=1, window=512)
    iters = 30 if full else 12
    rows = []

    def ab(f_composed, f_fused):
        """Median us of each side, alternating composed/fused runs."""
        jax.block_until_ready(f_composed())  # compile + warm
        jax.block_until_ready(f_fused())
        ta, tb = [], []
        for _ in range(iters):
            t0 = time.perf_counter()
            jax.block_until_ready(f_composed())
            ta.append(time.perf_counter() - t0)
            t0 = time.perf_counter()
            jax.block_until_ready(f_fused())
            tb.append(time.perf_counter() - t0)
        return float(np.median(ta) * 1e6), float(np.median(tb) * 1e6)

    for K in (10, 32):
        P = jnp.asarray(
            np.random.RandomState(0).rand(K, spec.n), jnp.float32
        )
        steps = jnp.arange(K, dtype=jnp.uint32)
        f_st = jax.jit(lambda P_, s_: sample_mask_st_hash(
            P_, spec.seed, spec.tensor_id, s_))
        f_draw = jax.jit(lambda P_, s_: sample_mask_hash(
            P_, spec.seed, spec.tensor_id, s_))
        f_rec = jax.jit(lambda Z_: ops.reconstruct_batched(spec, Z_))
        f_pack = jax.jit(pack_mask)
        f_fused = jax.jit(lambda P_, s_: ops.sample_reconstruct_batched(
            spec, P_, s_))
        f_spack = jax.jit(lambda P_, s_: ops.sample_pack_batched(
            spec, P_, s_))
        # bit-exactness gate before timing (fused == composed, exact)
        np.testing.assert_array_equal(
            np.asarray(f_fused(P, steps)),
            np.asarray(f_rec(f_draw(P, steps))),
            err_msg="fused forward not bit-exact vs composed",
        )
        np.testing.assert_array_equal(
            np.asarray(f_spack(P, steps)),
            np.asarray(f_pack(f_draw(P, steps))),
            err_msg="fused pack not bit-exact vs composed",
        )
        out = {"bench": "fused_mask_lifecycle", "K": K, "m": spec.m,
               "n": spec.n, "d": spec.d}
        out["fwd_composed_us"], out["fwd_fused_us"] = ab(
            lambda: f_rec(f_st(P, steps)), lambda: f_fused(P, steps))
        out["pack_composed_us"], out["pack_fused_us"] = ab(
            lambda: f_pack(f_draw(P, steps)), lambda: f_spack(P, steps))
        out["fwd_speedup"] = out["fwd_composed_us"] / out["fwd_fused_us"]
        out["pack_speedup"] = out["pack_composed_us"] / out["pack_fused_us"]
        out["lifecycle_speedup"] = (
            out["fwd_composed_us"] + out["pack_composed_us"]
        ) / (out["fwd_fused_us"] + out["pack_fused_us"])
        _emit(f"fused_lifecycle_K{K}", out["fwd_fused_us"],
              f"composed={out['fwd_composed_us']:.0f}us"
              f";fwd_speedup={out['fwd_speedup']:.3f}x"
              f";pack_speedup={out['pack_speedup']:.2f}x"
              f";lifecycle={out['lifecycle_speedup']:.3f}x")
        rows.append(out)
    return rows


def bench_downlink(full=False):
    """Downlink codec subsystem (this PR's tentpole): a real federated
    round per registered codec with the ENCODED scores as the carried
    state, reporting metered downlink bytes and round wall-clock.

    Bit-exactness asserted pre-timing: (a) the ``f32`` codec is the
    identity oracle — its encode returns the input arrays unchanged,
    so those rounds are bit-identical to the pre-codec protocol; (b)
    for the quantized codecs the widened-threshold integer draw equals
    the f32 draw on the decoded probabilities EXACTLY
    (``sample_mask_qhash`` vs ``sample_mask_hash``), and a round fed
    the u8 carry runs the vmap path to finite loss.

    Byte columns are MASK-ONLY (``score_downlink_bytes``, symmetric
    with bench_wire's ``mask_uplink_bytes``): u8 is exactly 1/4 of
    f32 per coordinate — the ci.sh gate requires <= 1/4.  Rows land in
    BENCH_reconstruct.json keyed (bench, K, strategy=codec).
    """
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.comm.downlink import codec_names, get_codec
    from repro.comm.metering import score_downlink_bytes
    from repro.core import (
        FederatedConfig, ZamplingConfig, build_specs, encode_state,
        init_state,
    )
    from repro.core.federated import federated_round
    from repro.core.qspec import make_qspec
    from repro.core.sampling import sample_mask_hash, sample_mask_qhash
    from repro.data import client_batch_stream, iid_client_split, make_teacher_dataset
    from repro.models.mlp import SMALL_DIMS, init_mlp_params, mlp_loss

    # draw-word exactness gate (quantized codecs), before any timing
    spec = make_qspec(0, (256, 256), 256, compression=8, d=8, window=128)
    rng = np.random.RandomState(0)
    for name in codec_names(include_aliases=False):
        codec = get_codec(name)
        if not codec.quantized:
            p = jnp.asarray(rng.rand(spec.n), jnp.float32)
            out = codec.encode(spec, p, jnp.uint32(3))
            np.testing.assert_array_equal(np.asarray(out), np.asarray(p))
            continue
        q = jnp.asarray(rng.randint(0, 1 << codec.bits, spec.n), jnp.uint32)
        if codec.packed:
            # packed codecs carry uint32 LANES: decode from the lanes,
            # draw from the per-coordinate words they unpack to
            from repro.comm.bitpack import pack_words

            wire = pack_words(q, codec.bits)
        else:
            wire = q.astype(codec.wire_dtype)
            q = wire.astype(jnp.uint32)
        a = np.asarray(sample_mask_qhash(q, codec.bits, spec.seed,
                                         spec.tensor_id, jnp.uint32(9)))
        b = np.asarray(sample_mask_hash(codec.decode(spec, wire), spec.seed,
                                        spec.tensor_id, jnp.uint32(9)))
        np.testing.assert_array_equal(
            a, b, err_msg=f"{name} integer draw not bit-exact vs decoded f32"
        )

    ds = make_teacher_dataset(n_train=2000, n_test=200, seed=0)
    template = init_mlp_params(jax.random.PRNGKey(0), SMALL_DIMS)
    zspecs = build_specs(template, ZamplingConfig(
        compression=8.0, d=10, window=128, min_size=128))
    state0 = init_state(jax.random.PRNGKey(1), zspecs, dense_init=template)
    n = zspecs.n_total
    f32_down = score_downlink_bytes(get_codec("f32"), n)
    rows = []
    for K in (10, 32):
        clients = iid_client_split(ds, K)
        xs, ys = next(client_batch_stream(clients, 64, 2, seed=0))
        batch = {"x": jnp.asarray(xs), "y": jnp.asarray(ys)}
        for name in codec_names(include_aliases=False):
            codec = get_codec(name)
            cfg = FederatedConfig(num_clients=K, local_steps=2,
                                  local_lr=0.5, aggregate="psum_u32",
                                  downlink=name)
            st = encode_state(zspecs, cfg, state0)
            f = jax.jit(lambda s, b, k, cfg=cfg: federated_round(
                zspecs, s, mlp_loss, b, k, cfg))
            st1, met = f(st, batch, jax.random.PRNGKey(0))
            jax.block_until_ready(st1)
            assert np.isfinite(float(met["loss"])), name
            iters = 10 if full else 3
            t0 = time.perf_counter()
            for _ in range(iters):
                jax.block_until_ready(f(st, batch, jax.random.PRNGKey(0)))
            us = (time.perf_counter() - t0) / iters * 1e6
            down = score_downlink_bytes(codec, n)
            rows.append({
                "bench": "downlink_codec", "codec": name,
                "strategy": name, "K": K, "n": n, "us": us,
                "downlink_bytes_per_client": down,
                "downlink_vs_f32": down / f32_down,
            })
            _emit(f"downlink_codec_{name}_K{K}", us,
                  f"down={down}B;vs_f32={down / f32_down:.4f}")

    # adaptive rate schedules: a scanned R-round fit per schedule with
    # the REALIZED metered bytes (scheduled width + lane padding), one
    # compile each — ci.sh gates on these rows being present
    from repro.train import federated_fit

    K, R = 10, 4 if not full else 8
    clients = iid_client_split(ds, K)
    stream = client_batch_stream(clients, 64, 2, seed=0)
    per_round = [next(stream) for _ in range(R)]
    rb = {"x": jnp.asarray(np.stack([x for x, _ in per_round])),
          "y": jnp.asarray(np.stack([y for _, y in per_round]))}
    for sched, name in (("constant", "u8"), ("cosine", "packed4"),
                        ("frontier", "u8"), ("frontier", "packed4")):
        extra = {"downlink_schedule": sched, "schedule_b_min": 2}
        if sched == "cosine":
            extra["schedule_rounds"] = R
        cfg = FederatedConfig(num_clients=K, local_steps=2, local_lr=0.5,
                              aggregate="psum_u32", downlink=name, **extra)
        st = encode_state(zspecs, cfg, state0)
        f = jax.jit(lambda s, b, k, cfg=cfg: federated_fit(
            zspecs, s, mlp_loss, b, k, cfg))
        st1, met = f(st, rb, jax.random.PRNGKey(0))
        jax.block_until_ready(st1)
        assert np.isfinite(np.asarray(met["loss"])).all(), (sched, name)
        iters = 5 if full else 2
        t0 = time.perf_counter()
        for _ in range(iters):
            jax.block_until_ready(f(st, rb, jax.random.PRNGKey(0)))
        us = (time.perf_counter() - t0) / iters / R * 1e6
        down = np.asarray(met["downlink_bytes_per_client"], np.float64)
        rows.append({
            "bench": "downlink_schedule", "codec": name,
            "strategy": f"{sched}_{name}", "K": K, "n": n,
            "rounds": R, "us": us,
            "downlink_bytes_per_client": float(down[-1]),
            "downlink_bytes_cumulative": float(down.sum()),
            "downlink_vs_f32": float(down[-1]) / f32_down,
        })
        _emit(f"downlink_schedule_{sched}_{name}", us,
              f"cum={down.sum():.0f}B;last={down[-1]:.0f}B")
    return rows


def bench_faults(full=False):
    """Fault-tolerant partial-participation round engine (this PR's
    tentpole): full federated rounds through the weighted-aggregation
    path at dropout rates {0, 0.2, 0.5} vs the plain PR-5 protocol.

    Bit-exactness asserted PRE-TIMING: the zero-fault participation
    round (every client at weight 1, an all-zero FaultPlan) must
    reproduce the plain round's aggregated scores and loss bit for
    bit at each K.  ``fault_overhead`` is the zero-fault round's
    wall-clock over the plain round's (alternating-run medians) — the
    price of carrying fault draws, upload checksums, and weighted
    psums through a round nothing goes wrong in; scripts/ci.sh fails
    if the committed baseline shows > 1.05x.  Rows land in
    BENCH_reconstruct.json keyed (bench, K, strategy=dropout level).
    """
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.core import (
        FederatedConfig, ZamplingConfig, build_specs, init_state,
    )
    from repro.core.federated import federated_round
    from repro.data import client_batch_stream, iid_client_split, make_teacher_dataset
    from repro.fault import FaultPlan
    from repro.models.mlp import SMALL_DIMS, init_mlp_params, mlp_loss

    ds = make_teacher_dataset(n_train=2000, n_test=200, seed=0)
    template = init_mlp_params(jax.random.PRNGKey(0), SMALL_DIMS)
    zspecs = build_specs(template, ZamplingConfig(
        compression=8.0, d=10, window=128, min_size=128))
    state0 = init_state(jax.random.PRNGKey(1), zspecs, dense_init=template)
    rows = []
    for K in (10, 32):
        clients = iid_client_split(ds, K)
        xs, ys = next(client_batch_stream(clients, 64, 2, seed=0))
        batch = {"x": jnp.asarray(xs), "y": jnp.asarray(ys)}
        cfg = FederatedConfig(num_clients=K, local_steps=2, local_lr=0.5,
                              aggregate="psum_u32")
        key = jax.random.PRNGKey(0)
        ids = jnp.arange(K, dtype=jnp.uint32)
        ones = jnp.ones(K, jnp.uint32)
        f_plain = jax.jit(lambda s, b, k, cfg=cfg: federated_round(
            zspecs, s, mlp_loss, b, k, cfg))
        for p in (0.0, 0.2, 0.5):
            plan = FaultPlan(dropout=p)
            f_fault = jax.jit(
                lambda s, b, k, cfg=cfg, plan=plan: federated_round(
                    zspecs, s, mlp_loss, b, k, cfg, client_ids=ids,
                    weights=ones, faults=plan))
            st_f, met = f_fault(state0, batch, key)
            jax.block_until_ready(st_f)
            assert np.isfinite(float(met["loss"]))
            if p == 0.0:
                # the acceptance gate, before any timing: zero faults
                # == the plain protocol, bit for bit
                st_p, met_p = f_plain(state0, batch, key)
                for path in st_p["scores"]:
                    np.testing.assert_array_equal(
                        np.asarray(st_p["scores"][path]),
                        np.asarray(st_f["scores"][path]),
                        err_msg=f"zero-fault scores diverge at {path}",
                    )
                assert (np.float32(met_p["loss"]).view(np.uint32)
                        == np.float32(met["loss"]).view(np.uint32)), \
                    "zero-fault loss not bit-identical to the plain round"
            iters = 20 if full else 8
            us_fault, us_plain = _ab_median(
                lambda: f_fault(state0, batch, key),
                lambda: f_plain(state0, batch, key), iters)
            rows.append({
                "bench": "fault_round", "strategy": f"dropout{p:g}",
                "K": K, "n": zspecs.n_total, "dropout": p,
                "us": us_fault, "plain_us": us_plain,
                "fault_overhead": us_fault / us_plain,
                "num_participating": float(met["num_participating"]),
            })
            _emit(f"fault_round_dropout{p:g}_K{K}", us_fault,
                  f"plain={us_plain:.0f}us"
                  f";overhead={us_fault / us_plain:.3f}x"
                  f";part={float(met['num_participating']):.0f}/{K}")
    return rows


def _device_peak_bytes():
    """Peak device memory if the backend reports it (GPU/TPU
    ``memory_stats``); ``None`` on CPU, whose allocations go through
    the host allocator and are invisible to XLA's stats."""
    import jax

    try:
        stats = jax.local_devices()[0].memory_stats()
    except Exception:  # noqa: BLE001 — backend without stats support
        return None
    if not stats:
        return None
    return stats.get("peak_bytes_in_use")


def bench_streaming(full=False):
    """Streaming cohort accumulator vs the one-shot slab round (this
    PR's tentpole): identical federated rounds with
    ``stream_chunk=c`` folding uploads c clients at a time vs the
    (K, lanes) slab aggregation, K swept to 256.

    Bit-exactness asserted PRE-TIMING at every (K, chunk): the
    streaming round's aggregated scores must equal the slab round's
    bit for bit (uint32 vote counts are associative, so chunked
    folding changes nothing).  ``stream_overhead`` is the streaming
    round's wall-clock over the slab round's (alternating-run
    medians); scripts/ci.sh fails if the committed baseline shows
    > 1.05x at small K.  The memory columns are the analytic model
    (comm.metering): ``peak_upload_bytes`` — one chunk's lanes plus
    the (n,) vote accumulator — is a function of the CHUNK only and
    stays flat as K grows, while ``slab_upload_bytes`` grows linearly;
    at K=256/chunk=8 the slab holds 32x the lanes.  ``device_peak
    _bytes`` records the backend's measured peak where the platform
    reports one (GPU/TPU; None on CPU).  Rows land in
    BENCH_reconstruct.json keyed (bench, K, strategy=chunk level).
    """
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.comm.metering import streaming_peak_bytes, upload_slab_bytes
    from repro.core import (
        FederatedConfig, ZamplingConfig, build_specs, init_state,
    )
    from repro.core.federated import federated_round
    from repro.data import make_teacher_dataset
    from repro.models.mlp import SMALL_DIMS, init_mlp_params, mlp_loss

    ds = make_teacher_dataset(n_train=2000, n_test=200, seed=0)
    template = init_mlp_params(jax.random.PRNGKey(0), SMALL_DIMS)
    zspecs = build_specs(template, ZamplingConfig(
        compression=8.0, d=10, window=128, min_size=128))
    state0 = init_state(jax.random.PRNGKey(1), zspecs, dense_init=template)
    E, B = 2, 16
    rng = np.random.RandomState(0)
    rows = []
    # chunk divides K in every timed row: padding the last chunk would
    # bill the streaming side for wasted local updates and muddy the
    # pure folding-overhead number the CI gate pins
    for K, chunk in ((10, 5), (32, 8), (128, 8), (128, 32),
                     (256, 8), (256, 32)):
        idx = rng.randint(0, len(ds.x_train), (K, E, B))
        batch = {"x": jnp.asarray(ds.x_train[idx]),
                 "y": jnp.asarray(ds.y_train[idx])}
        key = jax.random.PRNGKey(0)
        cfg_slab = FederatedConfig(num_clients=K, local_steps=E,
                                   local_lr=0.5, aggregate="psum_u32")
        cfg_strm = FederatedConfig(num_clients=K, local_steps=E,
                                   local_lr=0.5, aggregate="psum_u32",
                                   stream_chunk=chunk)
        f_slab = jax.jit(lambda s, b, k, cfg=cfg_slab: federated_round(
            zspecs, s, mlp_loss, b, k, cfg))
        f_strm = jax.jit(lambda s, b, k, cfg=cfg_strm: federated_round(
            zspecs, s, mlp_loss, b, k, cfg))
        st_a, met_a = f_slab(state0, batch, key)
        st_b, met_b = f_strm(state0, batch, key)
        jax.block_until_ready((st_a, st_b))
        # the acceptance gate, before any timing: chunked folding ==
        # the slab aggregation, bit for bit
        for path in st_a["scores"]:
            np.testing.assert_array_equal(
                np.asarray(st_a["scores"][path]),
                np.asarray(st_b["scores"][path]),
                err_msg=f"streaming scores diverge at {path} "
                        f"(K={K}, chunk={chunk})",
            )
        assert np.isfinite(float(met_b["loss"]))
        iters = (20 if full else 8) if K <= 32 else (10 if full else 4)
        us_strm, us_slab = _ab_median(
            lambda: f_strm(state0, batch, key),
            lambda: f_slab(state0, batch, key), iters)
        peak = streaming_peak_bytes(zspecs, "psum_u32", chunk)
        slab = upload_slab_bytes(zspecs, "psum_u32", K)
        rows.append({
            "bench": "streaming_round", "strategy": f"chunk{chunk}",
            "K": K, "n": zspecs.n_total, "chunk": chunk,
            "us": us_strm, "slab_us": us_slab,
            "stream_overhead": us_strm / us_slab,
            "peak_upload_bytes": peak,
            "slab_upload_bytes": slab,
            "slab_vs_peak": slab / peak,
            "lane_ratio": slab / upload_slab_bytes(zspecs, "psum_u32",
                                                   chunk),
            "device_peak_bytes": _device_peak_bytes(),
        })
        _emit(f"streaming_round_K{K}_chunk{chunk}", us_strm,
              f"slab={us_slab:.0f}us"
              f";overhead={us_strm / us_slab:.3f}x"
              f";peak={peak / 1024:.0f}KiB"
              f";slab_mem={slab / 1024:.0f}KiB"
              f";slab_vs_peak={slab / peak:.1f}x")
    return rows


def _ab_median(f_a, f_b, iters):
    """Median us of each side, alternating runs (load drift cancels)."""
    import jax
    import numpy as np

    jax.block_until_ready(f_a())  # compile + warm
    jax.block_until_ready(f_b())
    ta, tb = [], []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(f_a())
        ta.append(time.perf_counter() - t0)
        t0 = time.perf_counter()
        jax.block_until_ready(f_b())
        tb.append(time.perf_counter() - t0)
    return float(np.median(ta) * 1e6), float(np.median(tb) * 1e6)


class _env:
    """Temporarily set/unset an env var (trace-time knobs)."""

    def __init__(self, name, value):
        self.name, self.value = name, value

    def __enter__(self):
        self.prev = os.environ.get(self.name)
        if self.value is None:
            os.environ.pop(self.name, None)
        else:
            os.environ[self.name] = str(self.value)

    def __exit__(self, *exc):
        if self.prev is None:
            os.environ.pop(self.name, None)
        else:
            os.environ[self.name] = self.prev


def bench_bwd(full=False):
    """Transpose-plan backward vs the scatter oracle (this PR's
    tentpole): ``grad_Z = Q^T grad_W`` through the full custom_vjp
    chain at the bench spec (m=2^20, d=8), K clients, CPU ref path.

    The two paths are traced under their ``REPRO_BWD_PLAN`` gate (read
    at trace time; fresh closures -> fresh traces) and timed
    INTERLEAVED; allclose plan-vs-scatter is asserted before timing.

    ``scatter_bwd_us`` / ``plan_bwd_us`` time the PURE backward (the
    ``_bwd_many`` dispatch the custom_vjp invokes) so ``bwd_speedup``
    is not diluted by the shared forward that ``jax.grad`` would also
    evaluate; ``grad_scatter_us`` / ``grad_plan_us`` keep the full
    fwd+bwd grad-chain numbers for continuity with the PR-1
    ``federated_round_reconstruct`` *_bwd_us baseline rows.  Rows land
    in BENCH_reconstruct.json as ``bwd_transpose_plan`` keyed
    (bench, K); scripts/ci.sh requires them and fails if the plan
    path's ``bwd_speedup`` regresses below 1.0.
    """
    import functools as _ft

    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.core.qspec import make_qspec
    from repro.kernels import ops

    spec = make_qspec(0, (1024, 1024), 1024, compression=32, d=8, window=512)
    rows = []
    for K in (4, 10, 32):
        Z = jnp.asarray(
            (np.random.RandomState(0).rand(K, spec.n) < 0.5), jnp.float32
        )
        V = jnp.asarray(
            np.random.RandomState(1).randn(K, *spec.shape), jnp.float32
        )

        def make_bwd():
            # the exact transpose dispatch the custom_vjp bwd invokes;
            # a fresh closure per gate: the trace re-reads REPRO_BWD_PLAN
            return jax.jit(
                lambda G_: ops._bwd_many(spec, G_, "ref", 1, None)
            )

        def make_grad():
            g = jax.jit(jax.grad(
                lambda Z_, v: jnp.vdot(ops.reconstruct_batched(spec, Z_),
                                       v)
            ))
            return _ft.partial(g, v=V)

        with _env("REPRO_BWD_PLAN", "scatter"):
            b_scatter, g_scatter = make_bwd(), make_grad()
            # compile INSIDE the gate block: jit traces (and reads the
            # env) at first call, not at wrapper creation
            out_scatter = np.asarray(b_scatter(V))
            jax.block_until_ready(g_scatter(Z))
        with _env("REPRO_BWD_PLAN", "plan"):
            b_plan, g_plan = make_bwd(), make_grad()
            out_plan = np.asarray(b_plan(V))
            jax.block_until_ready(g_plan(Z))
            f_fwd = jax.jit(lambda Z_: ops.reconstruct_batched(spec, Z_))
            jax.block_until_ready(f_fwd(Z))
        np.testing.assert_allclose(out_plan, out_scatter, rtol=1e-4,
                                   atol=1e-4)
        iters = 10 if full else 3
        scatter_us, plan_us = _ab_median(
            lambda: b_scatter(V), lambda: b_plan(V), iters)
        grad_scatter_us, grad_plan_us = _ab_median(
            lambda: g_scatter(Z), lambda: g_plan(Z), iters)
        f_fwd(Z).block_until_ready()
        t0 = time.perf_counter()
        for _ in range(iters):
            f_fwd(Z).block_until_ready()
        fwd_us = (time.perf_counter() - t0) / iters * 1e6
        out = {
            "bench": "bwd_transpose_plan", "K": K, "m": spec.m,
            "n": spec.n, "d": spec.d,
            "scatter_bwd_us": scatter_us, "plan_bwd_us": plan_us,
            "bwd_speedup": scatter_us / plan_us,
            "grad_scatter_us": grad_scatter_us,
            "grad_plan_us": grad_plan_us,
            "grad_speedup": grad_scatter_us / grad_plan_us,
            "fwd_us": fwd_us, "bwd_fwd_ratio_plan": plan_us / fwd_us,
        }
        _emit(f"bwd_transpose_plan_K{K}", plan_us,
              f"scatter={scatter_us:.0f}us"
              f";bwd_speedup={out['bwd_speedup']:.2f}x"
              f";grad_speedup={out['grad_speedup']:.2f}x"
              f";bwd:fwd={out['bwd_fwd_ratio_plan']:.2f}")
        rows.append(out)
    return rows


def bench_threshold(full=False):
    """Re-measure the ``REPRO_BATCH_MAP_THRESHOLD`` crossover (ROADMAP
    open item) now that the backward no longer dominates: force each
    batched contraction strategy via the env var across spec sizes
    spanning the default threshold (m_pad·d = 2e6) and time fwd and
    the (plan) bwd.  The threshold also gates the plan backward's
    lax.map-vs-broadcast choice, so both directions are reported.
    Rows keyed (bench, K, strategy, m_pad_d) in BENCH_reconstruct.json.
    """
    import functools as _ft

    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.core.qspec import make_qspec
    from repro.kernels import ops

    K = 10
    rows = []
    for shape in ((256, 256), (512, 512), (1024, 1024)):
        spec = make_qspec(0, shape, shape[0], compression=32, d=8,
                          window=512)
        Z = jnp.asarray(
            (np.random.RandomState(0).rand(K, spec.n) < 0.5), jnp.float32
        )
        V = jnp.asarray(
            np.random.RandomState(1).randn(K, *spec.shape), jnp.float32
        )
        for strategy, thresh in (("fused", 1 << 62), ("lax_map", 1)):
            with _env("REPRO_BATCH_MAP_THRESHOLD", thresh):
                f = jax.jit(lambda Z_: ops.reconstruct_batched(spec, Z_))
                g = _ft.partial(jax.jit(jax.grad(
                    lambda Z_, v: jnp.vdot(
                        ops.reconstruct_batched(spec, Z_), v)
                )), v=V)
                jax.block_until_ready(f(Z))
                jax.block_until_ready(g(Z))
                iters = 10 if full else 3
                t0 = time.perf_counter()
                for _ in range(iters):
                    f(Z).block_until_ready()
                fwd_us = (time.perf_counter() - t0) / iters * 1e6
                t0 = time.perf_counter()
                for _ in range(iters):
                    g(Z).block_until_ready()
                bwd_us = (time.perf_counter() - t0) / iters * 1e6
            rows.append({
                "bench": "batch_map_threshold", "K": K,
                "strategy": strategy, "m_pad_d": spec.m_pad * spec.d,
                "m": spec.m, "n": spec.n, "d": spec.d,
                "fwd_us": fwd_us, "bwd_us": bwd_us,
            })
            _emit(f"batch_map_threshold_{strategy}_mpd{spec.m_pad * spec.d}",
                  fwd_us, f"bwd={bwd_us:.0f}us;K={K}")
    return rows


def bench_table1(full=False):
    from repro.experiments import comm_savings_table

    t0 = time.perf_counter()
    rows = comm_savings_table()
    us = (time.perf_counter() - t0) * 1e6
    for r in rows:
        _emit("table1_comm_savings", us / len(rows),
              f"{r['method']}:client={r['client_savings']:.0f}x"
              f";server={r['server_savings']:.2f}x")
    return rows


def bench_table2(full=False):
    from repro.experiments import run_local_compression

    t0 = time.perf_counter()
    rows = run_local_compression(quick=not full)
    us = (time.perf_counter() - t0) * 1e6 / max(len(rows), 1)
    for r in rows:
        _emit("table2_compression", us,
              f"d={r['d']};m/n={r['compression']}"
              f";sampled={r['sampled_acc']:.3f}")
    return rows


def bench_fig4(full=False):
    from repro.experiments import run_federated

    t0 = time.perf_counter()
    rows = run_federated(quick=not full)
    us = (time.perf_counter() - t0) * 1e6 / max(len(rows), 1)
    for r in rows:
        _emit("fig4_federated", us,
              f"m/n={r['compression']};acc={r['final_sampled_acc']:.3f}"
              f";client_savings={r['client_savings']:.0f}x")
    return rows


def bench_table4(full=False):
    from repro.experiments import run_sensitivity

    t0 = time.perf_counter()
    rows = run_sensitivity(quick=not full)
    us = (time.perf_counter() - t0) * 1e6 / max(len(rows), 1)
    for r in rows:
        _emit("table4_sensitivity", us,
              f"{r['training']};tau={r['tau']}"
              f";sens={r['avg_sensitivity']:.4f}")
    return rows


def bench_fig5(full=False):
    from repro.experiments import run_integrality

    t0 = time.perf_counter()
    rows = run_integrality(quick=not full)
    us = (time.perf_counter() - t0) * 1e6 / max(len(rows), 1)
    for r in rows:
        _emit("fig5_integrality", us,
              f"beta={r['beta']};gap={r['integrality_gap']:.3f}")
    return rows


def bench_fig6(full=False):
    from repro.experiments import run_zhou_comparison

    t0 = time.perf_counter()
    rows = run_zhou_comparison(quick=not full)
    us = (time.perf_counter() - t0) * 1e6 / max(len(rows), 1)
    for r in rows:
        _emit("fig6_zhou", us,
              f"{r['method']};mean={r['mean_sampled_acc']:.3f}"
              f";best={r['best_mask_acc']:.3f}")
    return rows


def bench_roofline(full=False):
    """Roofline terms per (arch x shape) from the dry-run artifacts."""
    from benchmarks.roofline import summarize_dir

    rows = summarize_dir("experiments/dryrun")
    for r in rows:
        _emit("roofline", 0.0,
              f"{r['arch']}/{r['shape']}:bound={r['bound']}"
              f";t_comp={r['t_compute_ms']:.2f}ms"
              f";t_mem={r['t_memory_ms']:.2f}ms"
              f";t_coll={r['t_collective_ms']:.2f}ms")
    return rows


def bench_wire_formats(full=False):
    """The end-to-end wire-format table (experiments.run_wire_formats):
    a real federated round per transport, bit-exactness asserted."""
    from repro.experiments import run_wire_formats

    t0 = time.perf_counter()
    rows = run_wire_formats(quick=not full)
    us = (time.perf_counter() - t0) * 1e6 / max(len(rows), 1)
    for r in rows:
        _emit("wire_formats", us,
              f"{r['strategy']};up={r['uplink_bytes_per_client']:.0f}B"
              f";vs_f32={r['uplink_vs_f32']:.4f}")
    return rows


def bench_downlink_tradeoff(full=False):
    """Accuracy vs downlink bytes per codec — the paper's trade-off
    knob as a table (experiments.run_downlink_tradeoff)."""
    from repro.experiments import run_downlink_tradeoff

    t0 = time.perf_counter()
    rows = run_downlink_tradeoff(quick=not full)
    us = (time.perf_counter() - t0) * 1e6 / max(len(rows), 1)
    for r in rows:
        _emit("downlink_tradeoff", us,
              f"{r['codec']};acc={r['final_sampled_acc']:.3f}"
              f";down={r['downlink_bytes_per_client']:.0f}B"
              f";vs_f32={r['downlink_vs_f32']:.4f}")
    return rows


def bench_serve(full=False):
    """Zampling-native serving: dense vs reconstruct-on-load vs
    streaming (this PR's tentpole), plus the delta broadcast.

    ``serve_decode`` rows: tokens/sec and resident zampled-state bytes
    per serving mode at two model sizes.  Bit-exactness is asserted
    PRE-TIMING: streaming and load generations must agree bit for bit
    at every size (and per-step across all three downlink codecs at
    the small size) — the modes share the canonical serve contraction
    (kernels/ops.py), so the resident-bytes win carries zero output
    risk.  All timings are CPU; the streaming impl timed is 'chunked'
    (the jnp fallback) and the one interpret-mode Pallas row is keyed
    ``impl='u8_pallas_interpret'`` with ``regression_comparable:
    False`` (interpreter artifact, not kernel perf — same convention
    as kernel_qz_reconstruct).  The dense row serves the SAME sampled
    weights through model.decode_step — the no-zampling baseline.

    ``serve_delta`` rows: exact delta-vs-full broadcast bytes on a
    converged-round scenario (1% of scores move, re-encoded under the
    SAME dither word per the comm/downlink.py reuse rule), one row per
    codec; asserts delta_bytes <= full_bytes / 8 AND that apply_delta
    on a live state reproduces the fresh round t+1 state bitwise.
    Rows land in BENCH_reconstruct.json keyed (bench, K=d_model,
    strategy=mode, impl=codec); scripts/ci.sh gates on the byte
    columns.
    """
    import dataclasses

    import jax
    import jax.numpy as jnp

    from repro.configs.registry import get_arch
    from repro.core import ZamplingConfig, build_specs, init_state
    from repro.core.zampling import sample_weights
    from repro.models import build_model
    from repro.serve import (apply_delta, build_serve_engine, delta_report,
                             generate, make_delta, make_generator,
                             make_serve_state)

    small = get_arch("qwen2-0.5b").reduced()
    large = dataclasses.replace(small, name="qwen2-0.5b-r512",
                                d_model=512, d_ff=1024)
    prompt = jnp.asarray([[1, 2, 3]], jnp.int32)
    new_tokens = 6 if full else 4
    B, Sp = prompt.shape
    seq_len = Sp + new_tokens
    rows = []

    for cfg in (small, large):
        model = build_model(cfg)
        params = model.init_params(jax.random.PRNGKey(0))
        zspecs = build_specs(params, ZamplingConfig(compression=8, d=8,
                                                    min_size=2048))
        state = init_state(jax.random.PRNGKey(1), zspecs,
                           dense_init=params)
        sstate = make_serve_state(zspecs, state, jax.random.PRNGKey(2),
                                  downlink="u8")

        # bit-exactness oracle before any timing: streaming == load,
        # full generation; per-step across all codecs at small size
        outs = {}
        for mode in ("load", "streaming"):
            engine = build_serve_engine(model, sstate, mode=mode)
            run = make_generator(engine.step, new_tokens)
            toks, _ = run(engine.arrays_of(sstate),
                          engine.init_cache(B, seq_len), prompt,
                          jax.random.PRNGKey(0))
            outs[mode] = toks
        assert (outs["load"] == outs["streaming"]).all(), \
            f"serve modes diverge at d_model={cfg.d_model}"
        if cfg is small:
            for codec in ("f32", "u16", "u8"):
                ss = make_serve_state(zspecs, state,
                                      jax.random.PRNGKey(2),
                                      downlink=codec)
                es = build_serve_engine(model, ss, mode="streaming")
                el = build_serve_engine(model, ss, mode="load")
                c0 = es.init_cache(B, seq_len)
                ls, _ = jax.jit(es.step)(es.arrays_of(ss), c0,
                                         prompt[:, :1])
                ll, _ = jax.jit(el.step)(el.arrays_of(ss), c0,
                                         prompt[:, :1])
                assert (ls == ll).all(), f"codec {codec} diverges"

        # sampled dense weights = the same model a no-zampling fleet
        # would hold; serves through model.decode_step
        dense_params = sample_weights(zspecs, state, jax.random.PRNGKey(2))

        def _time(fn):
            fn()  # compile
            t0 = time.perf_counter()
            fn()
            return (time.perf_counter() - t0)

        zamp_bytes = {
            "dense": 4 * zspecs.m_total,
            "load": sstate.loaded_zampled_bytes(),
            "streaming": sstate.resident_zampled_bytes(),
        }
        for mode in ("dense", "load", "streaming"):
            if mode == "dense":
                dt = _time(lambda: generate(
                    model, dense_params, prompt, new_tokens,
                    seq_len=seq_len).block_until_ready())
            else:
                engine = build_serve_engine(model, sstate, mode=mode)
                arrays = engine.arrays_of(sstate)
                run = make_generator(engine.step, new_tokens)
                cache = engine.init_cache(B, seq_len)
                dt = _time(lambda: run(arrays, cache, prompt,
                                       jax.random.PRNGKey(0)
                                       )[0].block_until_ready())
            tok_s = B * new_tokens / dt
            rows.append({
                "bench": "serve_decode", "K": cfg.d_model,
                "strategy": mode,
                "impl": "dense" if mode == "dense" else "u8",
                "tok_s": tok_s, "us": dt / (B * new_tokens) * 1e6,
                "resident_zampled_bytes": zamp_bytes[mode],
                "dense_bytes": sstate.dense_bytes(),
                "m_total": zspecs.m_total, "n_total": zspecs.n_total,
                "bit_exact_vs_load": mode != "dense",
                "regression_comparable": True,
            })
            _emit(f"serve_decode_{mode}_d{cfg.d_model}",
                  dt / (B * new_tokens) * 1e6,
                  f"tok_s={tok_s:.2f}"
                  f";zampled_bytes={zamp_bytes[mode]}")

        if cfg is small:
            # one interpret-mode Pallas step: correctness-path timing
            # only (the interpreter walks the one-hot contraction), so
            # the row is excluded from perf regression comparisons
            engine = build_serve_engine(model, sstate, mode="streaming",
                                        impl="pallas")
            arrays = engine.arrays_of(sstate)
            cache = engine.init_cache(B, seq_len)
            stepf = jax.jit(engine.step)
            dt = _time(lambda: stepf(arrays, cache, prompt[:, :1]
                                     )[0].block_until_ready())
            rows.append({
                "bench": "serve_decode", "K": cfg.d_model,
                "strategy": "streaming",
                "impl": "u8_pallas_interpret",
                "tok_s": B / dt, "us": dt / B * 1e6,
                "resident_zampled_bytes": zamp_bytes["streaming"],
                "dense_bytes": sstate.dense_bytes(),
                "m_total": zspecs.m_total, "n_total": zspecs.n_total,
                "bit_exact_vs_load": True,
                "regression_comparable": False,
            })
            _emit(f"serve_decode_streaming_pallas_d{cfg.d_model}",
                  dt / B * 1e6, "interpret-mode;not-comparable")

    # --- delta broadcast on a converged round ----------------------------
    model = build_model(small)
    params = model.init_params(jax.random.PRNGKey(0))
    zspecs = build_specs(params, ZamplingConfig(compression=8, d=8,
                                                min_size=2048))
    state = init_state(jax.random.PRNGKey(1), zspecs, dense_init=params)
    key = jax.random.PRNGKey(7)
    scores2 = {}
    for p, s in state["scores"].items():
        k1, k2, key = jax.random.split(key, 3)
        touch = jax.random.bernoulli(k1, 0.01, s.shape)
        scores2[p] = jnp.where(
            touch, s + 0.05 * jax.random.normal(k2, s.shape), s)
    state2 = {"scores": scores2, "dense": state["dense"]}
    for codec in ("f32", "u16", "u8"):
        s1 = make_serve_state(zspecs, state, jax.random.PRNGKey(2),
                              downlink=codec, dither_word=0)
        s2 = make_serve_state(zspecs, state2, jax.random.PRNGKey(2),
                              downlink=codec, dither_word=0)
        swapped = apply_delta(s1, make_delta(s1, s2))
        assert all(bool((swapped.words[p] == s2.words[p]).all())
                   for p in s2.words), f"hot-swap != fresh load ({codec})"
        rep = delta_report(s1, s2)
        assert rep["delta_bytes"] < rep["full_bytes"], codec
        assert rep["delta_vs_full"] <= 0.125, \
            f"delta {rep['delta_vs_full']:.4f} > 1/8 ({codec})"
        rows.append({
            "bench": "serve_delta", "strategy": codec,
            "words_total": rep["words_total"],
            "words_changed": rep["words_changed"],
            "delta_bytes": rep["delta_bytes"],
            "full_bytes": rep["full_bytes"],
            "delta_vs_full": rep["delta_vs_full"],
            "changed_frac": 0.01,
            "regression_comparable": True,
        })
        _emit(f"serve_delta_{codec}", 0.0,
              f"delta={rep['delta_bytes']}B;full={rep['full_bytes']}B"
              f";ratio={rep['delta_vs_full']:.4f}")
    return rows


def bench_serve_throughput(full=False):
    """Continuous batching + the hot-block cache: tok/s vs batch width.

    ``serve_batch`` rows: tokens/sec at batch B in {1, 4, 16} x serving
    mode in {load, streaming, cached} on the reduced model (window=128
    specs so the retention scenario below is fine-grained).  The cached
    mode runs at FULL budget — the pool caps at one row per canonical
    tile, so this is the upper end of the dial; its budget and the
    exact resident bytes (comm.metering.serve_resident_bytes: words +
    pool + lane KV + dense) land in every row, along with the device
    peak probe (None on CPU).  Bit-exactness is asserted PRE-TIMING at
    every batch width: the three modes' generations must agree bit for
    bit, so the throughput column carries zero output risk.

    One ``strategy="scheduler"`` row drives the real continuous-batching
    scheduler (ragged prompts, admission/retirement, host-side greedy
    sampling) at the largest width — ``regression_comparable: False``,
    since its pacing includes the host control plane.

    One ``strategy="retention"`` row replays the converged-round
    scenario (1% of scores move, amp 0.02, pinned dither + draw words)
    against a fully warm cache: drawn-bit invalidation must retain
    >= 90% of the pool, asserted here and gated in scripts/ci.sh along
    with cached >= 2x streaming tok/s at the largest batch.
    """
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.comm.metering import serve_resident_bytes
    from repro.configs.registry import get_arch
    from repro.core import ZamplingConfig, build_specs, init_state
    from repro.models import build_model
    from repro.serve import (HotBlockCache, ServeConfig, ServeScheduler,
                             apply_delta, build_serve_engine, make_delta,
                             make_generator, make_serve_state)

    cfg = get_arch("qwen2-0.5b").reduced()
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    # d=12: the per-block regeneration the cache elides walks 12 edges
    # per row — the production-density regime, where streaming pays for
    # every decode step and the pool's gather does not
    zspecs = build_specs(params, ZamplingConfig(compression=4, d=12,
                                                window=128))
    state = init_state(jax.random.PRNGKey(1), zspecs, dense_init=params)
    sstate = make_serve_state(zspecs, state, jax.random.PRNGKey(2),
                              downlink="u8", dither_word=0)
    budget = 1 << 30  # >= model: pool caps at one row per tile
    cache = HotBlockCache(sstate, budget)
    cache.fill(sstate)
    assert cache.capacity_bytes <= budget

    Sp = 4
    new_tokens = 6 if full else 4
    seq_len = Sp + new_tokens
    batches = (1, 4, 16)
    allp = jnp.asarray(
        np.random.RandomState(0).randint(1, cfg.vocab, (max(batches), Sp)),
        jnp.int32)

    def _time(fn):
        fn()  # compile
        best = float("inf")
        for _ in range(3):  # min-of-3: the 2x CI gate needs low noise
            t0 = time.perf_counter()
            fn()
            best = min(best, time.perf_counter() - t0)
        return best

    rows = []
    for B in batches:
        prompt = allp[:B]
        outs, runs = {}, {}
        for mode in ("load", "streaming", "cached"):
            engine = build_serve_engine(model, sstate, mode=mode)
            arrays = engine.arrays_of(
                sstate, cache=cache if mode == "cached" else None)
            run = make_generator(engine.step, new_tokens)
            kv = engine.init_cache(B, seq_len)
            toks, _ = run(arrays, kv, prompt, jax.random.PRNGKey(0))
            outs[mode] = np.asarray(toks)
            runs[mode] = (run, arrays, kv)
        assert (outs["load"] == outs["streaming"]).all(), B
        assert (outs["load"] == outs["cached"]).all(), B
        for mode in ("load", "streaming", "cached"):
            run, arrays, kv = runs[mode]
            dt = _time(lambda: run(arrays, kv, prompt,
                                   jax.random.PRNGKey(0)
                                   )[0].block_until_ready())
            tok_s = B * new_tokens / dt
            res = serve_resident_bytes(
                sstate, budget if mode == "cached" else 0, mode=mode,
                kv_cache=kv)
            assert res["cache_bytes"] <= budget
            rows.append({
                "bench": "serve_batch", "K": B, "strategy": mode,
                "impl": "u8", "tok_s": tok_s,
                "us": dt / (B * new_tokens) * 1e6,
                "cache_budget_bytes": budget if mode == "cached" else 0,
                "resident_bytes": res["total_bytes"],
                "cache_bytes": res["cache_bytes"],
                "device_peak_bytes": _device_peak_bytes(),
                "bit_exact_across_modes": True,
                "regression_comparable": True,
            })
            _emit(f"serve_batch_{mode}_B{B}",
                  dt / (B * new_tokens) * 1e6,
                  f"tok_s={tok_s:.2f}"
                  f";resident={res['total_bytes']:.0f}B")

    # the real scheduler at the largest width: ragged prompts, lane
    # admission/retirement, host greedy sampling (not gate-comparable)
    lanes = max(batches)
    sched = ServeScheduler(model, sstate, ServeConfig(
        lanes=lanes, seq_len=seq_len, cache_budget_bytes=budget,
        mode="cached", max_new_tokens=new_tokens), cache=cache)
    ragged = [list(range(1, 2 + (i % Sp))) for i in range(2 * lanes)]
    for p in ragged:
        sched.submit(p)
    t0 = time.perf_counter()
    results = sched.run()
    dt = time.perf_counter() - t0
    ntok = sum(len(v) for v in results.values())
    rows.append({
        "bench": "serve_batch", "K": lanes, "strategy": "scheduler",
        "impl": "u8", "tok_s": ntok / dt, "us": dt / ntok * 1e6,
        "requests": len(ragged), "engine_steps": sched.metrics()["steps"],
        "cache_budget_bytes": budget,
        "device_peak_bytes": _device_peak_bytes(),
        "regression_comparable": False,  # includes compile + host pacing
    })
    _emit(f"serve_batch_scheduler_B{lanes}", dt / ntok * 1e6,
          f"tok_s={ntok / dt:.2f};requests={len(ragged)};incl-compile")

    # cache retention across a converged round's delta hot-swap
    key = jax.random.PRNGKey(7)
    scores2 = {}
    for p, s in state["scores"].items():
        k1, k2, key = jax.random.split(key, 3)
        touch = jax.random.bernoulli(k1, 0.01, s.shape)
        scores2[p] = jnp.where(
            touch, s + 0.02 * jax.random.normal(k2, s.shape), s)
    s2 = make_serve_state(zspecs, {"scores": scores2,
                                   "dense": state["dense"]},
                          jax.random.PRNGKey(2), downlink="u8",
                          dither_word=0)
    cache.fill(sstate)  # re-warm after the scheduler run
    total = cache.resident_tiles
    assert total == cache.total_tiles
    apply_delta(sstate, make_delta(sstate, s2), cache=cache)
    retained = cache.resident_tiles / total
    assert retained >= 0.9, f"cache retention {retained:.3f} < 0.9"
    rows.append({
        "bench": "serve_batch", "strategy": "retention", "impl": "u8",
        "total_tiles": total, "retained_tiles": cache.resident_tiles,
        "retained_fraction": retained, "changed_frac": 0.01,
        "amp": 0.02, "window": 128,
        "regression_comparable": True,
    })
    _emit("serve_batch_retention", 0.0,
          f"retained={cache.resident_tiles}/{total}"
          f";fraction={retained:.4f}")
    return rows


BENCHES = {
    "kernel": lambda full: bench_kernel_reconstruct(),
    "fedround": bench_federated_round,
    "fused": bench_fused,
    "bwd": bench_bwd,
    "threshold": bench_threshold,
    "wire": bench_wire,
    "downlink": bench_downlink,
    "faults": bench_faults,
    "streaming": bench_streaming,
    "serve": bench_serve,
    "serve_batch": bench_serve_throughput,
    "wire_formats": bench_wire_formats,
    "downlink_tradeoff": bench_downlink_tradeoff,
    "table1": bench_table1,
    "table2": bench_table2,
    "fig4": bench_fig4,
    "table4": bench_table4,
    "fig5": bench_fig5,
    "fig6": bench_fig6,
    "roofline": bench_roofline,
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--only", default=None)
    args = ap.parse_args()
    only = args.only.split(",") if args.only else list(BENCHES)
    print("name,us_per_call,derived")
    failed = []
    for name in only:
        try:
            rows = BENCHES[name](args.full)
            _dump(name, rows)
            if name in ("kernel", "fedround", "fused", "bwd", "threshold",
                        "wire", "downlink", "faults", "streaming",
                        "serve", "serve_batch"):
                _merge_bench_root(rows)
        except Exception as e:  # noqa: BLE001
            _emit(name, 0.0, f"ERROR:{e}")
            failed.append(name)
    if failed:  # make scripts/ci.sh a real gate (exit non-zero)
        sys.exit(f"benchmarks failed: {','.join(failed)}")


if __name__ == "__main__":
    main()
