"""Generate the data-driven sections of EXPERIMENTS.md from artifacts.

  PYTHONPATH=src python -m benchmarks.report
writes markdown fragments to experiments/md/*.md:
  dryrun.md    — §Dry-run per-combo table (memory, collectives, compile)
  roofline.md  — §Roofline three-term table + bottleneck + useful ratio
  repro_*.md   — paper-experiment tables from experiments/results/*.json
"""

from __future__ import annotations

import glob
import json
import os

from benchmarks.roofline import summarize_dir, summarize_file


def _fmt_bytes(b):
    if b >= 1e9:
        return f"{b/1e9:.2f} GB"
    if b >= 1e6:
        return f"{b/1e6:.1f} MB"
    return f"{b/1e3:.0f} KB"


def dryrun_table(d="experiments/dryrun", mesh="16x16", mode="zampling"):
    lines = [
        "| arch | shape | status | compile (s) | HBM temp+args | "
        "AR | AG | A2A / CP | note |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for path in sorted(glob.glob(os.path.join(d, "*.json"))):
        base = os.path.basename(path)
        if f"_{mesh}_{mode}.json" not in base:
            continue
        r = json.load(open(path))
        if r.get("skipped"):
            lines.append(f"| {r['arch']} | {r['shape']} | SKIP | | | | | "
                         f"| {r['reason']} |")
            continue
        if "error" in r:
            lines.append(f"| {r['arch']} | {r['shape']} | FAIL | | | | | "
                         f"| {r['error'][:60]} |")
            continue
        c = r["collective_bytes_per_device"]
        hbm = r["memory"]["temp_bytes"] + r["memory"]["argument_bytes"]
        lines.append(
            f"| {r['arch']} | {r['shape']} | ok | {r['compile_s']} "
            f"| {_fmt_bytes(hbm)} "
            f"| {_fmt_bytes(c['all-reduce'])} | {_fmt_bytes(c['all-gather'])} "
            f"| {_fmt_bytes(c['all-to-all'] + c['collective-permute'])} "
            f"| {r.get('note','')} |"
        )
    return "\n".join(lines)


def roofline_table(d="experiments/dryrun", mesh="16x16", mode="zampling"):
    rows = summarize_dir(d, mesh=mesh, mode=mode)
    lines = [
        "| arch | shape | t_comp (ms) | t_mem (ms) | t_coll (ms) | bound | "
        "MODEL/HLO flops | next move |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for r in rows:
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['t_compute_ms']:.2f} "
            f"| {r['t_memory_ms']:.2f} | {r['t_collective_ms']:.2f} "
            f"| **{r['bound']}** | {r['useful_ratio']:.2f} "
            f"| {r['move_next']} |"
        )
    return "\n".join(lines)


def repro_tables():
    out = {}
    for path in sorted(glob.glob("experiments/results/*.json")):
        name = os.path.splitext(os.path.basename(path))[0]
        rows = json.load(open(path))
        if not rows or not isinstance(rows, list) or not isinstance(rows[0],
                                                                    dict):
            continue
        cols = [c for c in rows[0] if c != "bench"]
        lines = ["| " + " | ".join(cols) + " |",
                 "|" + "---|" * len(cols)]
        for r in rows:
            lines.append(
                "| " + " | ".join(
                    f"{r.get(c):.4f}" if isinstance(r.get(c), float)
                    else str(r.get(c)) for c in cols
                ) + " |"
            )
        out[name] = "\n".join(lines)
    return out


def baseline_table(d="experiments/dryrun"):
    """Zampling vs dense-DP train_4k comparison (where both exist)."""
    lines = [
        "| arch | mode | HBM temp | AR | AG | flops/dev |",
        "|---|---|---|---|---|---|",
    ]
    for path in sorted(glob.glob(os.path.join(d, "*train_4k_16x16_*.json"))):
        r = json.load(open(path))
        if r.get("skipped") or "error" in r:
            continue
        base = path.replace("_zampling.json", "_baseline.json")
        if r["mode"] == "zampling" and not os.path.exists(base):
            continue
        c = r["collective_bytes_per_device"]
        lines.append(
            f"| {r['arch']} | {r['mode']} "
            f"| {_fmt_bytes(r['memory']['temp_bytes'])} "
            f"| {_fmt_bytes(c['all-reduce'])} | {_fmt_bytes(c['all-gather'])} "
            f"| {r['flops_per_device']:.3g} |"
        )
    return "\n".join(lines)


def splice_experiments_md():
    """Replace the <!-- *_TABLE --> markers in EXPERIMENTS.md."""
    with open("EXPERIMENTS.md") as f:
        text = f.read()
    single = "### Single pod (16x16)\n\n" + dryrun_table()
    multi = ""
    if glob.glob("experiments/dryrun/*_2x16x16_*.json"):
        multi = "### Multi-pod (2x16x16)\n\n" + dryrun_table(mesh="2x16x16")
    reps = {
        "<!-- DRYRUN_TABLE -->": single,
        "<!-- ROOFLINE_TABLE -->": roofline_table(),
        "<!-- BASELINE_TABLE -->": baseline_table(),
        "<!-- MULTIPOD_NOTE -->": multi,
    }
    for marker, table in reps.items():
        if marker in text:
            text = text.replace(marker, marker + "\n\n" + table)
    with open("EXPERIMENTS.md", "w") as f:
        f.write(text)


def main():
    os.makedirs("experiments/md", exist_ok=True)
    with open("experiments/md/dryrun.md", "w") as f:
        f.write("### Single pod (16x16)\n\n")
        f.write(dryrun_table() + "\n\n")
        if glob.glob("experiments/dryrun/*_2x16x16_*.json"):
            f.write("### Multi-pod (2x16x16)\n\n")
            f.write(dryrun_table(mesh="2x16x16") + "\n")
    with open("experiments/md/roofline.md", "w") as f:
        f.write(roofline_table() + "\n")
    for name, table in repro_tables().items():
        with open(f"experiments/md/repro_{name}.md", "w") as f:
            f.write(table + "\n")
    splice_experiments_md()
    print("wrote experiments/md/*.md and spliced EXPERIMENTS.md")


if __name__ == "__main__":
    main()
