"""Zampling-native serving: fused decode kernels, the two-mode engine,
and the XOR delta hot-swap.

The load-bearing claims, each pinned bitwise (no tolerances):

 - every serve impl (ref = reconstruct-then-matmul oracle, chunked,
   interpret-mode Pallas) and the resident (load-mode) contraction
   produce IDENTICAL bits for all three downlink codecs — the
   canonical contraction tree contract of kernels/ops.py;
 - the streaming engine's decode jaxpr contains no f32 value the size
   of a weight tensor — serving really does run without weights;
 - applying a round's XOR delta to a live server is indistinguishable,
   bit for bit, from freshly loading the next round's broadcast —
   including mid-generation, against a KV cache built under the old
   round.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.comm.downlink import get_codec
from repro.comm.metering import delta_wire_bytes, score_downlink_bytes
from repro.core import ZamplingConfig, build_specs, init_state
from repro.core.qspec import make_qspec
from repro.core.sampling import as_word, clip_probs
from repro.kernels import ops
from repro.serve import (
    apply_delta,
    apply_word_delta,
    build_serve_engine,
    delta_report,
    generate,
    make_delta,
    make_generator,
    make_serve_state,
    serve_generate,
    word_delta,
)

CODECS = ("f32", "u16", "u8")


def _scores(n, seed=0):
    return jnp.asarray(np.random.RandomState(seed).rand(n).astype(np.float32))


def _words(codec_name, spec, scores):
    """(operand, qbits) the serve ops take for this codec."""
    c = get_codec(codec_name)
    if c.quantized:
        return c.encode(spec, scores, as_word(3)), c.bits
    return scores, None


def _reconstruct(spec, codec_name, scores, step):
    words, qbits = _words(codec_name, spec, scores)
    operand = words if qbits is not None else clip_probs(scores)
    return ops.sample_reconstruct(spec, operand, step, qbits=qbits)


class TestFusedServeKernels:
    """serve_matvec/matmul: ref == chunked == pallas(interpret) ==
    resident, bit for bit, every codec."""

    @pytest.mark.parametrize("codec", CODECS)
    def test_matvec_exact_across_impls(self, codec):
        spec = make_qspec(11, (24, 40), 24, compression=4.0, d=4, window=64)
        scores = _scores(spec.n)
        words, qbits = _words(codec, spec, scores)
        step = as_word(5)
        x = jnp.asarray(np.random.RandomState(1).randn(24).astype(np.float32))
        ref = ops.serve_matvec(spec, words, step, x, qbits=qbits,
                               impl="ref")
        for impl in ("chunked", "pallas"):
            out = ops.serve_matvec(spec, words, step, x, qbits=qbits,
                                   impl=impl)
            assert (np.asarray(out) == np.asarray(ref)).all(), impl
        W = _reconstruct(spec, codec, scores, step)
        res = ops.serve_resident_matvec(spec, W, x)
        assert (np.asarray(res) == np.asarray(ref)).all()
        # the oracle really is x @ W (same values, retiled summation)
        np.testing.assert_allclose(np.asarray(ref), np.asarray(x @ W),
                                   rtol=2e-5, atol=2e-5)

    @pytest.mark.parametrize("codec", CODECS)
    def test_matmul_batched_exact_across_impls(self, codec):
        spec = make_qspec(12, (24, 40), 24, compression=4.0, d=4, window=64)
        scores = _scores(spec.n, seed=2)
        words, qbits = _words(codec, spec, scores)
        step = as_word(9)
        X = jnp.asarray(
            np.random.RandomState(3).randn(3, 24).astype(np.float32))
        ref = ops.serve_matmul(spec, words, step, X, qbits=qbits,
                               impl="ref")
        for impl in ("chunked", "pallas"):
            out = ops.serve_matmul(spec, words, step, X, qbits=qbits,
                                   impl=impl)
            assert (np.asarray(out) == np.asarray(ref)).all(), impl
        W = _reconstruct(spec, codec, scores, step)
        res = ops.serve_resident_matmul(spec, W, X)
        assert (np.asarray(res) == np.asarray(ref)).all()

    def test_stacked_groups_exact(self):
        spec = make_qspec(13, (2, 16, 24), 16, compression=4.0, d=4,
                          window=64)
        scores = _scores(spec.n, seed=4)
        step = as_word(1)
        X = jnp.asarray(
            np.random.RandomState(5).randn(2, 16).astype(np.float32))
        W = _reconstruct(spec, "u8", scores, step)
        words, qbits = _words("u8", spec, scores)
        for g in (0, 1):
            ref = ops.serve_matmul(spec, words, step, X, group=g,
                                   qbits=qbits, impl="ref")
            for impl in ("chunked", "pallas"):
                out = ops.serve_matmul(spec, words, step, X, group=g,
                                       qbits=qbits, impl=impl)
                assert (np.asarray(out) == np.asarray(ref)).all(), (g, impl)
            res = ops.serve_resident_matmul(spec, W, X, group=g)
            assert (np.asarray(res) == np.asarray(ref)).all(), g

    def test_embed_rows_match_take(self):
        spec = make_qspec(14, (40, 24), 40, compression=4.0, d=4, window=64)
        scores = _scores(spec.n, seed=6)
        step = as_word(2)
        tokens = jnp.asarray([[3, 0], [39, 7]], jnp.int32)
        for codec in CODECS:
            words, qbits = _words(codec, spec, scores)
            rows = ops.serve_embed_rows(spec, words, step, tokens,
                                        qbits=qbits)
            W = _reconstruct(spec, codec, scores, step)
            ref = jnp.take(W, tokens, axis=0)
            assert (np.asarray(rows) == np.asarray(ref)).all(), codec


@pytest.fixture(scope="module")
def served():
    from repro.configs.registry import get_arch
    from repro.models import build_model

    cfg = get_arch("qwen2-0.5b").reduced()
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    zspecs = build_specs(params, ZamplingConfig(compression=4, d=4))
    state = init_state(jax.random.PRNGKey(1), zspecs, dense_init=params)
    return model, zspecs, state


def _perturbed(state, frac=0.01, amp=0.05, seed=7):
    """Round t+1: a converged-round score update touching ``frac``."""
    key = jax.random.PRNGKey(seed)
    scores2 = {}
    for p, s in state["scores"].items():
        k1, k2, key = jax.random.split(key, 3)
        touch = jax.random.bernoulli(k1, frac, s.shape)
        scores2[p] = jnp.where(touch,
                               s + amp * jax.random.normal(k2, s.shape), s)
    return {"scores": scores2, "dense": state["dense"]}


class TestServeEngine:
    @pytest.mark.parametrize("codec", CODECS)
    def test_modes_bit_identical(self, served, codec):
        model, zspecs, state = served
        ss = make_serve_state(zspecs, state, jax.random.PRNGKey(2),
                              downlink=codec)
        prompt = jnp.asarray([[1, 2, 3, 4]], jnp.int32)
        o_s = serve_generate(model, ss, prompt, 3, mode="streaming",
                             seq_len=16)
        o_l = serve_generate(model, ss, prompt, 3, mode="load", seq_len=16)
        assert o_s.shape == (1, 7)
        assert (o_s[:, :4] == prompt).all()
        assert (np.asarray(o_s) == np.asarray(o_l)).all()

    def test_streaming_jaxpr_materializes_no_weight(self, served):
        model, zspecs, state = served
        ss = make_serve_state(zspecs, state, jax.random.PRNGKey(2),
                              downlink="u8")
        engine = build_serve_engine(model, ss, mode="streaming")
        arrays = engine.arrays_of(ss)
        cache = engine.init_cache(1, 8)
        tok = jnp.zeros((1, 1), jnp.int32)
        jaxpr = jax.make_jaxpr(engine.step)(arrays, cache, tok)
        thresh = min(s.m for s in zspecs.specs.values())

        def subjaxprs(eqn):
            for v in eqn.params.values():
                for item in (v if isinstance(v, (tuple, list)) else (v,)):
                    inner = getattr(item, "jaxpr", item)
                    if hasattr(inner, "eqns"):
                        yield inner

        def walk(jx):
            for eqn in jx.eqns:
                for var in eqn.outvars:
                    av = var.aval
                    if (getattr(av, "dtype", None) == jnp.float32
                            and av.size >= thresh):
                        raise AssertionError(
                            f"weight-sized f32 {av.shape} materialized by "
                            f"{eqn.primitive} in the streaming decode jaxpr"
                        )
                for sub in subjaxprs(eqn):
                    walk(sub)

        walk(jaxpr.jaxpr)
        # the threshold bites: load mode's resident arrays ARE that big
        loaded = build_serve_engine(model, ss, mode="load").arrays_of(ss)
        assert any(int(jnp.size(w)) >= thresh
                   for w in loaded["weights"].values())

    def test_delta_apply_equals_fresh_load(self, served):
        model, zspecs, state = served
        state2 = _perturbed(state)
        prompt = jnp.asarray([[1, 2, 3, 4]], jnp.int32)
        for codec in CODECS:
            s1 = make_serve_state(zspecs, state, jax.random.PRNGKey(2),
                                  downlink=codec, dither_word=0)
            s2 = make_serve_state(zspecs, state2, jax.random.PRNGKey(2),
                                  downlink=codec, dither_word=0)
            swapped = apply_delta(s1, make_delta(s1, s2))
            for p in s2.words:
                assert (np.asarray(swapped.words[p])
                        == np.asarray(s2.words[p])).all(), (codec, p)
            assert swapped.step == s2.step
            # words bit-equal => identical generations; run the
            # generation-level check once (u8) to pin the wiring
            if codec == "u8":
                o_fresh = serve_generate(model, s2, prompt, 2, seq_len=8)
                o_swap = serve_generate(model, swapped, prompt, 2,
                                        seq_len=8)
                assert (np.asarray(o_fresh)
                        == np.asarray(o_swap)).all(), codec

    def test_hot_swap_mid_generation_deterministic(self, served):
        model, zspecs, state = served
        state2 = _perturbed(state)
        s1 = make_serve_state(zspecs, state, jax.random.PRNGKey(2),
                              downlink="u8", dither_word=0)
        s2 = make_serve_state(zspecs, state2, jax.random.PRNGKey(2),
                              downlink="u8", dither_word=0)
        engine = build_serve_engine(model, s1, mode="streaming")
        step = jax.jit(engine.step)
        a1 = engine.arrays_of(s1)
        prompt = jnp.asarray([[1, 2, 3]], jnp.int32)

        def run(mid_arrays):
            cache = engine.init_cache(1, 8)
            logits = None
            for t in range(prompt.shape[1]):
                logits, cache = step(a1, cache, prompt[:, t:t + 1])
            toks = []
            arrays = a1
            for i in range(4):
                nxt = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
                toks.append(nxt)
                if i == 1:  # round t+1 broadcast lands mid-generation
                    arrays = mid_arrays
                logits, cache = step(arrays, cache, nxt)
            return jnp.concatenate(toks, axis=1)

        via_delta = run(engine.arrays_of(apply_delta(s1, make_delta(s1, s2))))
        via_fresh = run(engine.arrays_of(s2))
        again = run(engine.arrays_of(apply_delta(s1, make_delta(s1, s2))))
        assert (np.asarray(via_delta) == np.asarray(via_fresh)).all()
        assert (np.asarray(via_delta) == np.asarray(again)).all()

    def test_delta_guards(self, served):
        _, zspecs, state = served
        s_u8 = make_serve_state(zspecs, state, jax.random.PRNGKey(2),
                                downlink="u8")
        s_u16 = make_serve_state(zspecs, state, jax.random.PRNGKey(2),
                                 downlink="u16")
        with pytest.raises(ValueError):
            make_delta(s_u8, s_u16)
        d = make_delta(s_u8, s_u8)
        with pytest.raises(ValueError):
            apply_delta(s_u16, d)

    def test_generate_temperature_path(self, served):
        model, _, _ = served
        params = model.init_params(jax.random.PRNGKey(0))
        prompt = jnp.asarray([[1, 2]], jnp.int32)
        out = generate(model, params, prompt, 3, seq_len=8,
                       temperature=0.8, key=jax.random.PRNGKey(4))
        assert out.shape == (1, 5)
        with pytest.raises(ValueError):
            generate(model, params, prompt, 3, seq_len=8, temperature=0.8)

    def test_generator_reuse_without_retrace(self, served):
        model, zspecs, state = served
        ss = make_serve_state(zspecs, state, jax.random.PRNGKey(2),
                              downlink="u8")
        engine = build_serve_engine(model, ss, mode="streaming")
        run = make_generator(engine.step, 2)
        cache = engine.init_cache(1, 8)
        prompt = jnp.asarray([[1, 2, 3]], jnp.int32)
        a = engine.arrays_of(ss)
        t1, _ = run(a, cache, prompt, jax.random.PRNGKey(0))
        t2, _ = run(a, cache, prompt, jax.random.PRNGKey(0))
        assert (np.asarray(t1) == np.asarray(t2)).all()
        assert t1.shape == (1, 2)


class TestDeltaWire:
    def test_word_delta_roundtrip_bit_patterns(self):
        for arr in (
            jnp.asarray([0.0, -0.0, 1.5, -2.25, np.inf], jnp.float32),
            jnp.asarray([0, 1, 255, 128], jnp.uint8),
            jnp.asarray([0, 65535, 4097], jnp.uint16),
        ):
            new = arr[::-1]
            patch = word_delta(arr, new)
            back = apply_word_delta(arr, patch)
            assert back.dtype == arr.dtype
            assert (np.asarray(back).view(np.uint8)
                    == np.asarray(new).view(np.uint8)).all()

    def test_delta_wire_bytes_exact(self):
        # coordinate list wins when sparse, bitmap when dense
        assert delta_wire_bytes(1000, 0, 1) == 4
        assert delta_wire_bytes(1000, 10, 1) == 4 + 10 * 5
        assert delta_wire_bytes(1000, 500, 1) == 125 + 500
        # never beats neither encoding's formula
        for changed in (0, 1, 999, 1000):
            b = delta_wire_bytes(1000, changed, 2)
            assert b == min(125 + 2 * changed, 4 + 6 * changed)
        with pytest.raises(ValueError):
            delta_wire_bytes(10, 11, 1)

    def test_report_vs_full_broadcast(self, served):
        _, zspecs, state = served
        state2 = _perturbed(state)
        for codec in CODECS:
            s1 = make_serve_state(zspecs, state, jax.random.PRNGKey(2),
                                  downlink=codec, dither_word=0)
            s2 = make_serve_state(zspecs, state2, jax.random.PRNGKey(2),
                                  downlink=codec, dither_word=0)
            rep = delta_report(s1, s2)
            c = get_codec(codec)
            full = sum(score_downlink_bytes(c, s.n)
                       for s in zspecs.specs.values())
            assert rep["full_bytes"] == full
            assert rep["delta_bytes"] < rep["full_bytes"] / 8, codec
            # identical rounds cost only the draw word + per-leaf counts
            rep0 = delta_report(s1, s1)
            assert rep0["words_changed"] == 0
            assert rep0["delta_bytes"] == 4 + 4 * len(zspecs.specs)


class TestCheckpointEncodedCarry:
    def test_u8_carry_roundtrips_without_widening(self, tmp_path, served):
        from repro.checkpoint import load_checkpoint, save_checkpoint

        _, zspecs, state = served
        codec = get_codec("u8")
        words = {p: codec.encode(spec, state["scores"][p], as_word(0))
                 for p, spec in zspecs.specs.items()}
        carry = {"scores": words, "dense": state["dense"]}
        path = str(tmp_path / "ckpt")
        save_checkpoint(path, carry, meta={"downlink": "u8", "round": 9})

        # the widening template: f32 zeros in the saved structure —
        # the old loader cast the u8 words to it (4x blow-up AND wire
        # words reinterpreted as probabilities)
        template = jax.tree.map(
            lambda a: jnp.zeros(jnp.shape(a), jnp.float32), carry)
        restored, meta = load_checkpoint(path, template)
        assert meta["downlink"] == "u8"
        assert meta["round"] == 9
        assert "__leaf_dtypes__" not in meta
        for p in words:
            got = restored["scores"][p]
            assert got.dtype == np.uint8, p
            assert (np.asarray(got) == np.asarray(words[p])).all(), p
        for p in state["dense"]:
            assert restored["dense"][p].dtype == np.float32
