"""Deeper model-layer tests: flash-vs-direct attention, grouped scan,
MoE dispatch semantics, CE vocab padding, bitpacking properties."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:  # vendored fallback: fixed-seed examples, no shrinking
    from _hypothesis_fallback import given, settings
    from _hypothesis_fallback import strategies as st

from repro.configs.base import MoEConfig
from repro.core.bitpack import pack_mask, packed_len, unpack_mask
from repro.models.attention import AttnDims, _sdpa, decode_self_attention, init_attn_params, init_cache, self_attention
from repro.models.common import cross_entropy, grouped_scan
from repro.models.flash import blockwise_attention
from repro.models.moe import init_moe_params, moe_block


class TestFlashAttention:
    def _qkv(self, B=2, S=256, H=4, KV=2, hd=16, seed=0):
        rs = np.random.RandomState(seed)
        q = jnp.asarray(rs.randn(B, S, H, hd), jnp.float32)
        k = jnp.asarray(rs.randn(B, S, KV, hd), jnp.float32)
        v = jnp.asarray(rs.randn(B, S, KV, hd), jnp.float32)
        return q, k, v

    def _direct(self, q, k, v, causal=True, window=None):
        B, S, H, hd = q.shape
        idx = jnp.arange(S)
        mask = jnp.zeros((B, 1, S, S), jnp.float32)
        if causal:
            mask = jnp.where(idx[None, :] > idx[:, None], -1e30, mask)
        if window is not None:
            mask = jnp.where(idx[None, :] <= idx[:, None] - window, -1e30,
                             mask)
        return _sdpa(q, k, v, mask, H // k.shape[2])

    @pytest.mark.parametrize("window", [None, 64])
    def test_matches_direct(self, window):
        q, k, v = self._qkv()
        want = self._direct(q, k, v, window=window)
        got = blockwise_attention(q, k, v, causal=True, window=window,
                                  q_chunk=64, k_chunk=64)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-4, atol=2e-4)

    def test_grad_matches_direct(self):
        q, k, v = self._qkv(S=128)

        def f_flash(q):
            return jnp.sum(blockwise_attention(q, k, v, q_chunk=64,
                                               k_chunk=64) ** 2)

        def f_direct(q):
            return jnp.sum(self._direct(q, k, v) ** 2)

        g1 = jax.grad(f_flash)(q)
        g2 = jax.grad(f_direct)(q)
        np.testing.assert_allclose(np.asarray(g1), np.asarray(g2),
                                   rtol=1e-3, atol=1e-3)

    def test_noncausal(self):
        q, k, v = self._qkv(S=128)
        want = _sdpa(q, k, v, None, q.shape[2] // k.shape[2])
        got = blockwise_attention(q, k, v, causal=False, q_chunk=64,
                                  k_chunk=64)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-4, atol=2e-4)

    def test_cross_lengths(self):
        """Sq != Sk (cross-attention path, seamless 32k prefill)."""
        rs = np.random.RandomState(3)
        q = jnp.asarray(rs.randn(1, 256, 4, 16), jnp.float32)
        k = jnp.asarray(rs.randn(1, 128, 2, 16), jnp.float32)
        v = jnp.asarray(rs.randn(1, 128, 2, 16), jnp.float32)
        want = _sdpa(q, k, v, None, 2)
        got = blockwise_attention(q, k, v, causal=False, q_chunk=128,
                                  k_chunk=64)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=3e-4, atol=3e-4)


class TestSWADecode:
    def test_ring_buffer_equals_full_forward(self):
        """Decode with ring-buffer SWA cache == forward with window mask."""
        dims = AttnDims(n_heads=4, n_kv=2, head_dim=16, window=8)
        params = init_attn_params(jax.random.PRNGKey(0), 32, dims,
                                  jnp.float32)
        S = 24
        x = jax.random.normal(jax.random.PRNGKey(1), (1, S, 32), jnp.float32)
        positions = jnp.arange(S)[None]
        full = self_attention(params, x, dims, positions)
        cache = init_cache(1, S, dims, jnp.float32)
        outs = []
        for t in range(S):
            y, cache = decode_self_attention(params, x[:, t:t+1], cache, dims)
            outs.append(y[:, 0])
        dec = jnp.stack(outs, 1)
        np.testing.assert_allclose(np.asarray(dec), np.asarray(full),
                                   rtol=2e-3, atol=2e-3)
        # ring buffer must be no larger than the window
        assert cache.k.shape[1] == 8


class TestGroupedScan:
    def test_matches_plain_scan_and_grad(self):
        L, D = 16, 8
        ws = jax.random.normal(jax.random.PRNGKey(0), (L, D, D)) * 0.1
        x0 = jax.random.normal(jax.random.PRNGKey(1), (D,))

        def body(x, w):
            return jnp.tanh(w @ x), None

        def f_plain(x0):
            x, _ = jax.lax.scan(body, x0, ws)
            return jnp.sum(x ** 2)

        def f_grouped(x0):
            return jnp.sum(grouped_scan(body, x0, ws, group=4) ** 2)

        np.testing.assert_allclose(f_plain(x0), f_grouped(x0), rtol=1e-6)
        np.testing.assert_allclose(
            np.asarray(jax.grad(f_plain)(x0)),
            np.asarray(jax.grad(f_grouped)(x0)), rtol=1e-5, atol=1e-6,
        )

    def test_awkward_group_falls_back(self):
        L, D = 7, 4
        ws = jax.random.normal(jax.random.PRNGKey(0), (L, D, D)) * 0.1
        x0 = jnp.ones((D,))

        def body(x, w):
            return jnp.tanh(w @ x), None

        out = grouped_scan(body, x0, ws, group=4)  # 7 % 4 != 0
        plain, _ = jax.lax.scan(body, x0, ws)
        np.testing.assert_allclose(np.asarray(out), np.asarray(plain))


class TestMoE:
    def test_group_locality_preserves_routing(self):
        """With ample capacity, grouped == ungrouped output."""
        cfg = MoEConfig(num_experts=4, top_k=2, d_ff_expert=16,
                        capacity_factor=8.0)
        params = init_moe_params(jax.random.PRNGKey(0), 8, cfg, jnp.float32)
        x = jax.random.normal(jax.random.PRNGKey(1), (2, 64, 8), jnp.float32)
        out_one, _ = moe_block(params, x, cfg, group_size=128)  # 1 group
        out_four, _ = moe_block(params, x, cfg, group_size=32)  # 4 groups
        np.testing.assert_allclose(np.asarray(out_one), np.asarray(out_four),
                                   rtol=2e-4, atol=2e-4)

    def test_matches_per_token_reference(self):
        cfg = MoEConfig(num_experts=4, top_k=2, d_ff_expert=16,
                        capacity_factor=8.0)
        D = 8
        params = init_moe_params(jax.random.PRNGKey(0), D, cfg, jnp.float32)
        x = jax.random.normal(jax.random.PRNGKey(1), (1, 16, D), jnp.float32)
        out, _ = moe_block(params, x, cfg)

        # reference: loop over tokens, run top-k experts densely
        logits = x[0] @ params["router"]
        probs = jax.nn.softmax(logits, -1)
        ref = []
        for t in range(16):
            gv, gi = jax.lax.top_k(probs[t], 2)
            gv = gv / gv.sum()
            acc = jnp.zeros((D,))
            for w, e in zip(np.asarray(gv), np.asarray(gi)):
                h = jax.nn.silu(x[0, t] @ params["gate"][e]) * (
                    x[0, t] @ params["up"][e]
                )
                acc = acc + w * (h @ params["down"][e])
            ref.append(acc)
        np.testing.assert_allclose(np.asarray(out[0]), np.asarray(ref),
                                   rtol=2e-3, atol=2e-3)

    def test_capacity_drops_tokens(self):
        cfg = MoEConfig(num_experts=2, top_k=1, d_ff_expert=8,
                        capacity_factor=0.25)
        params = init_moe_params(jax.random.PRNGKey(0), 4, cfg, jnp.float32)
        x = jax.random.normal(jax.random.PRNGKey(1), (1, 32, 4))
        out, aux = moe_block(params, x, cfg)
        assert bool(jnp.isfinite(out).all()) and bool(jnp.isfinite(aux))


class TestCrossEntropy:
    def test_vocab_padding_equivalence(self):
        rs = np.random.RandomState(0)
        logits = jnp.asarray(rs.randn(4, 8, 10), jnp.float32)
        labels = jnp.asarray(rs.randint(0, 10, (4, 8)), jnp.int32)
        base = cross_entropy(logits, labels)
        padded = jnp.pad(logits, ((0, 0), (0, 0), (0, 6)),
                         constant_values=5.0)  # junk in pad columns
        got = cross_entropy(padded, labels, num_classes=10)
        np.testing.assert_allclose(float(got), float(base), rtol=1e-6)

    def test_matches_naive_softmax_ce(self):
        rs = np.random.RandomState(1)
        logits = jnp.asarray(rs.randn(3, 5, 7), jnp.float32)
        labels = jnp.asarray(rs.randint(0, 7, (3, 5)), jnp.int32)
        want = -jnp.mean(
            jnp.take_along_axis(jax.nn.log_softmax(logits), labels[..., None],
                                -1)
        )
        got = cross_entropy(logits, labels)
        np.testing.assert_allclose(float(got), float(want), rtol=1e-6)


class TestBitpack:
    @settings(max_examples=30, deadline=None)
    @given(n=st.integers(1, 500), seed=st.integers(0, 1000))
    def test_roundtrip(self, n, seed):
        z = (np.random.RandomState(seed).rand(n) < 0.5).astype(np.float32)
        packed = pack_mask(jnp.asarray(z))
        assert packed.shape == (packed_len(n),)
        back = unpack_mask(packed, n)
        np.testing.assert_array_equal(np.asarray(back), z)

    def test_wire_size_is_n_bits(self):
        n = 1024
        z = jnp.ones((n,))
        assert pack_mask(z).size * 32 == n
