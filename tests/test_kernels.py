"""Pallas kernel validation: sweep shapes/dtypes, allclose vs ref.py oracle."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.qspec import make_qspec
from repro.kernels import ops
from repro.kernels.qz_reconstruct import qz_reconstruct_bwd, qz_reconstruct_fwd
from repro.kernels.ref import grad_z_ref, reconstruct_ref

# Interpret-mode sweeps are expensive (each case compiles a fresh
# Pallas interpreter program).  A 2-case FAST subset runs by default;
# the full grid is @slow (run with `-m ""` or `-m slow`).
SWEEP_FAST = [
    # (shape, compression, d, window)
    ((512,), 2.0, 4, 64),
    ((64, 96), 8.0, 8, 256),
]
SWEEP_SLOW = [
    ((1000,), 4.0, 1, 128),
    ((3, 40, 50), 3.0, 5, 32),
    ((1024, 17), 32.0, 8, 512),
    ((2048,), 1.0, 2, 512),
]


def _mk(shape, c, d, window, seed=11):
    fan = shape[0] if len(shape) == 1 else int(np.prod(shape[:-1]))
    return make_qspec(1, shape, fan, compression=c, d=d, window=window,
                      seed=seed)


def _sweep_params():
    return [pytest.param(*case) for case in SWEEP_FAST] + [
        pytest.param(*case, marks=pytest.mark.slow) for case in SWEEP_SLOW
    ]


@pytest.mark.parametrize("shape,c,d,window", _sweep_params())
def test_pallas_fwd_matches_ref(shape, c, d, window):
    spec = _mk(shape, c, d, window)
    z = (np.random.RandomState(0).rand(spec.n) < 0.5).astype(np.float32)
    want = np.asarray(reconstruct_ref(spec, jnp.asarray(z))).reshape(-1)
    got = np.asarray(qz_reconstruct_fwd(spec, jnp.asarray(z), interpret=True))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("shape,c,d,window", _sweep_params())
def test_pallas_bwd_matches_ref(shape, c, d, window):
    spec = _mk(shape, c, d, window)
    g = np.random.RandomState(1).randn(spec.m).astype(np.float32)
    want = np.asarray(grad_z_ref(spec, jnp.asarray(g)))
    got = np.asarray(qz_reconstruct_bwd(spec, jnp.asarray(g), interpret=True))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize(
    "bm", [64, pytest.param(256, marks=pytest.mark.slow),
           pytest.param(1024, marks=pytest.mark.slow)]
)
def test_pallas_block_size_invariance(bm):
    spec = _mk((900, 30), 16.0, 8, 128)
    z = (np.random.RandomState(2).rand(spec.n) < 0.4).astype(np.float32)
    want = np.asarray(reconstruct_ref(spec, jnp.asarray(z))).reshape(-1)
    got = np.asarray(
        qz_reconstruct_fwd(spec, jnp.asarray(z), bm=bm, interpret=True)
    )
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize(
    "dtype", [jnp.float32, pytest.param(jnp.bfloat16,
                                        marks=pytest.mark.slow)]
)
def test_ops_dispatch_dtypes(dtype):
    spec = _mk((64, 80), 4.0, 6, 128)
    z = jnp.asarray((np.random.RandomState(3).rand(spec.n) < 0.5), jnp.float32)
    ref = reconstruct_ref(spec, z, dtype=dtype)
    for impl in ("ref", "pallas"):
        got = ops.reconstruct(spec, z, dtype=dtype, impl=impl)
        assert got.dtype == dtype and got.shape == spec.shape
        np.testing.assert_allclose(
            np.asarray(got, np.float32), np.asarray(ref, np.float32),
            rtol=2e-2 if dtype == jnp.bfloat16 else 1e-5, atol=1e-5,
        )


@pytest.mark.parametrize("chunks", [1, 3, 8])
def test_ops_chunked_matches(chunks):
    spec = _mk((777,), 2.0, 4, 64)
    z = jnp.asarray((np.random.RandomState(4).rand(spec.n) < 0.5), jnp.float32)
    want = ops.reconstruct(spec, z, chunks=1)
    got = ops.reconstruct(spec, z, chunks=chunks)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5,
                               atol=1e-6)


def test_ops_custom_vjp_pallas_end_to_end():
    spec = _mk((300, 20), 8.0, 5, 64)
    z = jnp.asarray(np.random.RandomState(5).rand(spec.n), jnp.float32)
    v = jnp.asarray(np.random.RandomState(6).randn(*spec.shape), jnp.float32)

    def loss(z_, impl):
        return jnp.vdot(ops.reconstruct(spec, z_, impl=impl), v)

    g_ref = jax.grad(lambda z_: loss(z_, "ref"))(z)
    g_pl = jax.grad(lambda z_: loss(z_, "pallas"))(z)
    np.testing.assert_allclose(np.asarray(g_pl), np.asarray(g_ref),
                               rtol=1e-4, atol=1e-4)


def test_ops_under_jit():
    spec = _mk((128, 64), 8.0, 8, 128)
    z = jnp.asarray(np.random.RandomState(7).rand(spec.n) < 0.5, jnp.float32)
    f = jax.jit(lambda z_: ops.reconstruct(spec, z_))
    np.testing.assert_allclose(
        np.asarray(f(z)), np.asarray(ops.reconstruct(spec, z)),
        rtol=1e-4, atol=1e-6,
    )
