"""Streaming cohort accumulator (``FederatedConfig.stream_chunk``).

The central contract: the chunk-scan round is BIT-IDENTICAL on scores
to the one-shot slab round — for every transport, every chunk size
(dividing K or not), weight-1 and faulted, on the vmap and the
4-device shard_map driver.  The uplink vote counts are uint32 (packed
transports) or f32 sums of binary·small-integer products (mean_f32),
both exact under re-association, so chunked folding changes nothing.
Dense f32 leaves and the loss are sums of real numbers — those agree
up to reduction order only (same tolerance as the cross-driver
contract in tests/test_faults.py).

Also pinned here: the architectural claim that the streaming jaxpr
never materializes the (K, lanes) upload slab, the transport fold
hooks against the integer oracle, the streamed-fit host-staging driver
against ``federated_fit``, and the analytic peak-memory model.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _helpers import data_mesh_or_skip, round_metric_specs

from repro.comm import get_transport, streaming_peak_bytes, upload_slab_bytes
from repro.comm.bitpack import pack_mask, packed_len
from repro.core import FederatedConfig, ZamplingConfig, build_specs, init_state
from repro.core.federated import (
    PARTICIPATION_METRIC_KEYS,
    ROUND_METRIC_KEYS,
    federated_round,
)
from repro.data import (
    cohort_batch_stream,
    iid_client_split,
    make_teacher_dataset,
)
from repro.fault import ClientPopulation, FaultPlan
from repro.models.mlp import SMALL_DIMS, init_mlp_params, mlp_loss
from repro.train import federated_fit, streamed_federated_fit

K, E, B = 6, 2, 16
TRANSPORTS = ["mean_f32", "psum_u32", "allgather_packed"]
CHUNKS = [2, 3, 4, 5]  # 4 and 5 do not divide K=6 -> padded last chunk
PLAN = FaultPlan(dropout=0.3, straggler=0.1, corrupt=0.2, duplicate=0.1,
                 seed=5)
WEIGHTS = np.array([5, 2, 9, 1, 4, 7], np.uint32)


@pytest.fixture(scope="module")
def setup():
    ds = make_teacher_dataset(n_train=600, n_test=50, seed=0)
    template = init_mlp_params(jax.random.PRNGKey(0), SMALL_DIMS)
    zspecs = build_specs(template, ZamplingConfig(
        compression=2.0, d=5, window=128, min_size=256))
    state = init_state(jax.random.PRNGKey(1), zspecs, dense_init=template)
    clients = iid_client_split(ds, K)
    xs, ys = [], []
    rng = np.random.RandomState(3)
    for c in clients:
        idx = rng.randint(0, len(c.x_train), (E, B))
        xs.append(c.x_train[idx])
        ys.append(c.y_train[idx])
    batch = {"x": jnp.asarray(np.stack(xs)), "y": jnp.asarray(np.stack(ys))}
    return ds, zspecs, state, batch


def _cfg(aggregate, **kw):
    return FederatedConfig(num_clients=K, local_steps=E, local_lr=0.1,
                           aggregate=aggregate, **kw)


def _round(zspecs, state, batch, key, cfg, **kw):
    return jax.jit(lambda s, b, k: federated_round(
        zspecs, s, mlp_loss, b, k, cfg, **kw))(state, batch, key)


def _assert_scores_exact_dense_close(a, b):
    for p in a["scores"]:
        np.testing.assert_array_equal(
            np.asarray(a["scores"][p]), np.asarray(b["scores"][p]))
    for p in a["dense"]:
        np.testing.assert_allclose(
            np.asarray(a["dense"][p]).astype(np.float32),
            np.asarray(b["dense"][p]).astype(np.float32),
            rtol=1e-6, atol=1e-7)


# ---------------------------------------------------------------------------
# Config validation + slab fall-through
# ---------------------------------------------------------------------------

def test_stream_chunk_must_be_nonnegative():
    with pytest.raises(ValueError):
        FederatedConfig(num_clients=K, stream_chunk=-1)


def test_chunk_at_least_k_falls_through_to_slab(setup):
    _, zspecs, state, batch = setup
    key = jax.random.PRNGKey(7)
    st0, m0 = _round(zspecs, state, batch, key, _cfg("psum_u32"))
    st1, m1 = _round(zspecs, state, batch, key,
                     _cfg("psum_u32", stream_chunk=K))
    for p in st0["scores"]:
        np.testing.assert_array_equal(np.asarray(st0["scores"][p]),
                                      np.asarray(st1["scores"][p]))
    assert np.asarray(m0["loss"]).view(np.uint32) == \
        np.asarray(m1["loss"]).view(np.uint32)


# ---------------------------------------------------------------------------
# Streaming == slab: every transport, every chunking, plain and faulted
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", TRANSPORTS)
@pytest.mark.parametrize("chunk", CHUNKS)
def test_streaming_matches_slab_weight_one(setup, name, chunk):
    _, zspecs, state, batch = setup
    key = jax.random.PRNGKey(7)
    slab, m0 = _round(zspecs, state, batch, key, _cfg(name))
    stream, m1 = _round(zspecs, state, batch, key,
                        _cfg(name, stream_chunk=chunk))
    _assert_scores_exact_dense_close(slab, stream)
    np.testing.assert_allclose(float(m0["loss"]), float(m1["loss"]),
                               rtol=1e-6)
    assert set(m1) == set(ROUND_METRIC_KEYS)
    assert float(m1["num_participating"]) == K
    assert float(m1["weight_sum"]) == K
    assert float(m1["round_skipped"]) == 0.0
    assert float(m1["uplink_bytes_round"]) == float(m0["uplink_bytes_round"])


@pytest.mark.parametrize("name", TRANSPORTS)
@pytest.mark.parametrize("chunk", CHUNKS)
def test_streaming_matches_slab_faulted(setup, name, chunk):
    """Padded chunk lanes replay real clients' fault draws at live=0:
    they must influence nothing — votes, weight sum, counters, loss,
    realized bytes all equal the slab round's."""
    _, zspecs, state, batch = setup
    key = jax.random.PRNGKey(7)
    kw = dict(client_ids=jnp.arange(K, dtype=jnp.uint32),
              weights=jnp.asarray(WEIGHTS), faults=PLAN)
    slab, m0 = _round(zspecs, state, batch, key, _cfg(name), **kw)
    stream, m1 = _round(zspecs, state, batch, key,
                        _cfg(name, stream_chunk=chunk), **kw)
    _assert_scores_exact_dense_close(slab, stream)
    np.testing.assert_allclose(float(m0["loss"]), float(m1["loss"]),
                               rtol=1e-6)
    for mk in PARTICIPATION_METRIC_KEYS + ("weight_sum", "round_skipped"):
        assert float(m0[mk]) == float(m1[mk]), mk
    assert float(m0["uplink_bytes_round"]) == float(m1["uplink_bytes_round"])
    assert 0 < float(m1["num_participating"]) < K, \
        "plan injected no faults at this seed; pick another seed"


def test_streaming_skips_below_min_clients(setup):
    _, zspecs, state, batch = setup
    plan = FaultPlan(dropout=0.99, seed=2)
    cfg = _cfg("psum_u32", min_clients=K, stream_chunk=2)
    st, m = _round(zspecs, state, batch, jax.random.PRNGKey(7), cfg,
                   client_ids=jnp.arange(K, dtype=jnp.uint32),
                   weights=jnp.asarray(WEIGHTS), faults=plan)
    assert float(m["round_skipped"]) == 1.0
    for p in st["scores"]:
        np.testing.assert_array_equal(np.asarray(st["scores"][p]),
                                      np.asarray(state["scores"][p]))


# ---------------------------------------------------------------------------
# Cross-driver: streaming vmap == 4-device shard_map slab
# ---------------------------------------------------------------------------

def test_streaming_vmap_matches_shard_map_slab(setup):
    from repro.comm import shard_map_compat
    from repro.core.federated import sharded_client_update
    from jax.sharding import PartitionSpec as P

    _, zspecs, state, batch = setup
    mesh = data_mesh_or_skip()
    k4 = 4
    b4 = jax.tree.map(lambda x: x[:k4], batch)
    w4 = jnp.asarray(WEIGHTS[:k4])
    cfg = _cfg("psum_u32", stream_chunk=2)
    key = jax.random.PRNGKey(7)
    stv, mv = _round(zspecs, state, b4, key, cfg,
                     client_ids=jnp.arange(k4, dtype=jnp.uint32),
                     weights=w4, faults=PLAN)
    state_specs = jax.tree.map(lambda _: P(), state)

    def body(s, b, kk, i, ww):
        b = jax.tree.map(lambda x: x[0], b)
        return sharded_client_update(zspecs, s, mlp_loss, b, kk,
                                     cfg, faults=PLAN, client_id=i[0],
                                     weight=ww[0])

    with mesh:
        f = shard_map_compat(
            body, ("data",),
            (state_specs, P("data"), P(), P("data"), P("data")),
            (state_specs, round_metric_specs()))
        sts, ms = jax.jit(f)(state, b4, key,
                             jnp.arange(k4, dtype=jnp.uint32), w4)
    _assert_scores_exact_dense_close(stv, sts)
    for mk in PARTICIPATION_METRIC_KEYS:
        assert float(mv[mk]) == float(ms[mk]), mk


# ---------------------------------------------------------------------------
# Transport fold hooks == whole-stack aggregation (integer oracle)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", TRANSPORTS)
def test_fold_hooks_match_stacked_aggregation(name):
    rng = np.random.RandomState(0)
    n, k, chunk = 203, 6, 2
    Z = rng.randint(0, 2, (k, n)).astype(np.float32)
    w = np.array([3, 1, 0, 7, 2, 5], np.uint32)
    t = get_transport(name)
    acc = t.stream_init(n)
    if t.packed_wire:
        lanes = pack_mask(jnp.asarray(Z))
        for c in range(0, k, chunk):
            acc = t.fold_stacked_packed_weighted(
                acc, lanes[c:c + chunk], n, jnp.asarray(w[c:c + chunk]))
        want = t.aggregate_stacked_packed_weighted(lanes, n, jnp.asarray(w))
    else:
        for c in range(0, k, chunk):
            acc = t.fold_stacked_weighted(
                acc, jnp.asarray(Z[c:c + chunk]), jnp.asarray(w[c:c + chunk]))
        want = t.aggregate_stacked_weighted(jnp.asarray(Z), jnp.asarray(w))
    np.testing.assert_array_equal(np.asarray(acc), np.asarray(want))
    oracle = np.sum(Z.astype(np.int64) * w[:, None].astype(np.int64), axis=0)
    np.testing.assert_array_equal(np.asarray(acc).astype(np.int64), oracle)


# ---------------------------------------------------------------------------
# The architectural claim: no (K, lanes) upload slab in the streaming jaxpr
# ---------------------------------------------------------------------------

def _eqn_out_shapes(jaxpr, acc):
    for eqn in jaxpr.eqns:
        for v in eqn.outvars:
            aval = getattr(v, "aval", None)
            if aval is not None and getattr(aval, "dtype", None) is not None:
                acc.append((tuple(aval.shape), str(aval.dtype)))
        for param in eqn.params.values():
            inner = getattr(param, "jaxpr", None)
            if inner is not None:
                _eqn_out_shapes(inner, acc)
            elif hasattr(param, "eqns"):
                _eqn_out_shapes(param, acc)
    return acc


@pytest.mark.parametrize("name", ["mean_f32", "psum_u32"])
def test_no_upload_slab_in_streaming_jaxpr(setup, name):
    """With stream_chunk < K no equation anywhere in the round jaxpr may
    output a full-cohort upload (K, n) f32 mask or (K, lanes) uint32
    slab — only (chunk, ·) uploads exist.  The slab round DOES emit
    them (detector sanity)."""
    _, zspecs, state, batch = setup
    key = jax.random.PRNGKey(7)
    t = get_transport(name)
    if t.packed_wire:
        slabs = {((K, packed_len(s.n)), "uint32")
                 for s in zspecs.specs.values()}
    else:
        slabs = {((K, s.n), "float32") for s in zspecs.specs.values()}

    def jaxpr_shapes(cfg):
        closed = jax.make_jaxpr(lambda s, b, k: federated_round(
            zspecs, s, mlp_loss, b, k, cfg))(state, batch, key)
        return set(_eqn_out_shapes(closed.jaxpr, []))

    stream_shapes = jaxpr_shapes(_cfg(name, stream_chunk=2))
    assert not (slabs & stream_shapes), (
        f"streaming round materializes upload slab(s): "
        f"{slabs & stream_shapes}")
    slab_shapes = jaxpr_shapes(_cfg(name))
    assert slabs & slab_shapes, (
        "detector failed: slab round should materialize the upload slab")


# ---------------------------------------------------------------------------
# Fit drivers: scan-of-rounds and the host-staging streamed fit
# ---------------------------------------------------------------------------

def test_fit_with_stream_chunk_matches_slab_fit(setup):
    _, zspecs, state, batch = setup
    R = 2
    batches = jax.tree.map(
        lambda x: jnp.broadcast_to(x, (R,) + x.shape), batch)
    ids = jnp.broadcast_to(jnp.arange(K, dtype=jnp.uint32), (R, K))
    w = jnp.broadcast_to(jnp.asarray(WEIGHTS), (R, K))
    key = jax.random.PRNGKey(9)
    st0, m0 = jax.jit(lambda s, b, k: federated_fit(
        zspecs, s, mlp_loss, b, k, _cfg("psum_u32"),
        client_ids=ids, weights=w, faults=PLAN))(state, batches, key)
    st1, m1 = jax.jit(lambda s, b, k: federated_fit(
        zspecs, s, mlp_loss, b, k, _cfg("psum_u32", stream_chunk=4),
        client_ids=ids, weights=w, faults=PLAN))(state, batches, key)
    _assert_scores_exact_dense_close(st0, st1)
    np.testing.assert_array_equal(
        np.asarray(m0["num_participating"]),
        np.asarray(m1["num_participating"]))


def test_streamed_fit_matches_federated_fit(setup):
    """The double-buffered host-staging driver replays the identical
    cohorts/batches, so its state must match the all-device slab fit
    bitwise on scores."""
    ds, zspecs, state, _ = setup
    clients = iid_client_split(ds, 10)
    pop = ClientPopulation(
        10, sample_counts=tuple(len(c.x_train) for c in clients), seed=4)
    R, csize = 3, 4
    cfg = FederatedConfig(num_clients=csize, local_steps=E, local_lr=0.1,
                          aggregate="psum_u32", stream_chunk=3)
    plan = FaultPlan(dropout=0.2, seed=11)
    key = jax.random.PRNGKey(2)
    stream = cohort_batch_stream(clients, pop, csize, B, E, seed=0)
    st0, m0 = streamed_federated_fit(zspecs, state, mlp_loss, stream, key,
                                     cfg, R, faults=plan)
    gen = cohort_batch_stream(clients, pop, csize, B, E, seed=0)
    rows = [next(gen) for _ in range(R)]
    batches = {"x": jnp.asarray(np.stack([r[2] for r in rows])),
               "y": jnp.asarray(np.stack([r[3] for r in rows]))}
    st1, m1 = jax.jit(lambda s, b, k: federated_fit(
        zspecs, s, mlp_loss, b, k, cfg,
        client_ids=jnp.asarray(np.stack([r[0] for r in rows])),
        weights=jnp.asarray(np.stack([r[1] for r in rows])),
        faults=plan))(state, batches, key)
    _assert_scores_exact_dense_close(st0, st1)
    np.testing.assert_array_equal(np.asarray(m0["num_participating"]),
                                  np.asarray(m1["num_participating"]))
    assert m0["loss"].shape == (R,)


# ---------------------------------------------------------------------------
# Peak-memory model: streaming bound is flat in K
# ---------------------------------------------------------------------------

def test_streaming_peak_bytes_flat_in_k(setup):
    _, zspecs, _, _ = setup
    chunk = 8
    peak = streaming_peak_bytes(zspecs, "psum_u32", chunk)
    # the peak is a function of the chunk only — flat as K sweeps
    assert streaming_peak_bytes(zspecs, "psum_u32", chunk) == peak
    # the slab grows linearly in K ...
    slab8 = upload_slab_bytes(zspecs, "psum_u32", chunk)
    assert upload_slab_bytes(zspecs, "psum_u32", 256) == 32 * slab8
    # ... so at K=256 it holds 32x the lanes the streaming round ever
    # keeps resident, and still dwarfs the peak with the (n,) vote
    # accumulator charged against streaming
    assert upload_slab_bytes(zspecs, "psum_u32", 256) / slab8 >= 25.0
    assert upload_slab_bytes(zspecs, "psum_u32", 256) > 6.0 * peak
