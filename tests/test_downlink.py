"""Downlink codec subsystem (comm.downlink + the quantized draw path).

The codec contract, pinned here:

 - ``f32`` is the IDENTITY oracle: encode/decode pass arrays through
   untouched, so ``downlink='f32'`` rounds are bit-identical to the
   pre-codec protocol (fwd + grad, vmap and 4-device shard_map);
 - ``u8``/``u16`` are EXACT at the draw-word level: the widened
   threshold ``T(q) = floor(q * 2^24 / (2^b - 1))`` is computed
   exactly in uint32, the integer-compare draw
   ``(hash >> 8) < T(q)`` fires with probability exactly
   ``T(q) * 2^-24`` (the decoded probability, exactly representable in
   f32), and it is bit-identical to ``bernoulli_u32`` on that decoded
   value — for every draw word;
 - encode -> decode round-trips within ``2^-b`` (dithered rounding at
   half amplitude + the threshold floor);
 - the encoded scores ARE the round carry: quantized rounds thread
   uint8/uint16 score pytrees through ``federated_round`` /
   ``federated_fit`` / ``sharded_client_update``, with the vmap and
   shard_map paths producing bit-identical encoded states;
 - metering: ``downlink_bytes_*`` / ``downlink_vs_f32`` keys, and the
   analytic ``comm_bits_per_round``'s ``server_down_wire`` == 8x the
   metered ``downlink_bytes_per_client`` per codec;
 - an MNIST-FC smoke run: u16's final loss lands within tolerance of
   the f32 oracle's.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from _helpers import data_mesh_or_skip, round_metric_specs

from repro.comm.downlink import (
    codec_for_dtype,
    codec_names,
    get_codec,
)
from repro.comm.metering import (
    downlink_table,
    round_wire_report,
    score_downlink_bytes,
    wire_table,
)
from repro.comm.shardmap import shard_map_compat
from repro.core import (
    FederatedConfig,
    ZamplingConfig,
    build_specs,
    encode_state,
    decode_state,
    init_state,
)
from repro.core.federated import (
    WIRE_METRIC_KEYS,
    federated_round,
    sharded_client_update,
)
from repro.core.hashrng import bernoulli_u32
from repro.core.qspec import make_qspec
from repro.core.sampling import (
    quant_threshold_u24,
    sample_mask_hash,
    sample_mask_qhash,
)
from repro.core.zampling import MaskProgram, infer_downlink, sample_weights
from repro.kernels import ops

CODECS = ("f32", "u16", "u8", "packed4", "packed2")
# per-coordinate-word quantized codecs; the packed sub-byte codecs
# (uint32 lane carrier) have their own suite in test_packed_downlink.py
QUANTIZED = ("u16", "u8")


def _mk(shape=(300, 20), c=8.0, d=5, window=64, seed=7, **kw):
    fan = shape[0] if len(shape) == 1 else int(np.prod(shape[:-1]))
    return make_qspec(1, shape, fan, compression=c, d=d, window=window,
                      seed=seed, **kw)


def _qwords(codec, n, seed=0):
    rng = np.random.RandomState(seed)
    return jnp.asarray(rng.randint(0, 1 << codec.bits, n),
                       codec.wire_dtype)


# ---------------------------------------------------------------------------
# registry + config validation (satellite)
# ---------------------------------------------------------------------------

class TestRegistry:
    def test_registered_codecs(self):
        assert codec_names(include_aliases=False) == sorted(CODECS)
        assert get_codec("f32").bits == 32
        assert get_codec("u16").bits == 16
        assert get_codec("u8").bits == 8

    def test_unknown_codec_raises(self):
        with pytest.raises(ValueError, match="registered"):
            get_codec("u7")

    def test_config_validates_at_construction(self):
        with pytest.raises(ValueError) as ei:
            FederatedConfig(downlink="u7")
        for name in CODECS:
            assert name in str(ei.value)

    @pytest.mark.parametrize("name", CODECS)
    def test_registered_codecs_accepted(self, name):
        assert FederatedConfig(downlink=name).downlink == name

    def test_codec_for_dtype(self):
        assert codec_for_dtype(jnp.float32).name == "f32"
        assert codec_for_dtype(jnp.uint8).name == "u8"
        assert codec_for_dtype(jnp.uint16).name == "u16"
        with pytest.raises(ValueError, match="registered"):
            codec_for_dtype(jnp.int64)


# ---------------------------------------------------------------------------
# the widened threshold: exact integer math
# ---------------------------------------------------------------------------

class TestThreshold:
    @pytest.mark.parametrize("bits", [8, 16])
    def test_exact_floor(self, bits):
        """T(q) == floor(q * 2^24 / (2^b - 1)) for every (u8) / a dense
        sample + boundaries (u16) of the wire alphabet — exact python
        bigint arithmetic as the oracle."""
        S = (1 << bits) - 1
        if bits == 8:
            qs = np.arange(S + 1)
        else:
            rng = np.random.RandomState(0)
            qs = np.unique(np.concatenate([
                np.arange(0, 300), np.array([S - 2, S - 1, S]),
                rng.randint(0, S + 1, 4000),
            ]))
        T = np.asarray(quant_threshold_u24(jnp.asarray(qs, jnp.uint32),
                                           bits))
        want = np.array([(int(q) * (1 << 24)) // S for q in qs],
                        np.uint32)
        np.testing.assert_array_equal(T, want)
        assert T[0] == 0
        assert int(quant_threshold_u24(jnp.uint32(S), bits)) == 1 << 24

    def test_invalid_bits_raises(self):
        with pytest.raises(ValueError, match="bits"):
            quant_threshold_u24(jnp.uint32(1), 32)

    @pytest.mark.parametrize("name", QUANTIZED)
    def test_decode_is_threshold_over_2_24(self, name):
        """decode(q) == T(q) * 2^-24 exactly in f32, within 2^-24 of
        the ideal q / (2^b - 1)."""
        codec = get_codec(name)
        S = (1 << codec.bits) - 1
        q = _qwords(codec, 4096, seed=1)
        spec = _mk()
        phat = np.asarray(codec.decode(spec, q))
        T = np.asarray(quant_threshold_u24(q, codec.bits))
        np.testing.assert_array_equal(phat,
                                      T.astype(np.float64) * 2.0 ** -24)
        ideal = np.asarray(q).astype(np.float64) / S
        assert np.abs(phat - ideal).max() <= 2.0 ** -24
        assert phat.min() >= 0.0 and phat.max() <= 1.0


# ---------------------------------------------------------------------------
# the quantized draw: exactly unbiased at the draw-word level
# ---------------------------------------------------------------------------

class TestQuantizedDraw:
    @pytest.mark.parametrize("name", QUANTIZED)
    def test_bit_identical_to_f32_draw_on_decoded(self, name):
        """The integer compare == bernoulli_u32 on the decoded
        probability, bit for bit, across steps and coordinates."""
        codec = get_codec(name)
        spec = _mk()
        q = _qwords(codec, spec.n, seed=2)
        phat = codec.decode(spec, q)
        for step in (0, 7, 123456789):
            a = np.asarray(sample_mask_qhash(q, codec.bits, spec.seed,
                                             spec.tensor_id,
                                             jnp.uint32(step)))
            b = np.asarray(sample_mask_hash(phat, spec.seed,
                                            spec.tensor_id,
                                            jnp.uint32(step)))
            np.testing.assert_array_equal(a, b)

    @pytest.mark.parametrize("bits", [8, 16])
    def test_every_draw_word_at_the_boundary(self, bits):
        """Exactness for EVERY draw word, not just hash samples: sweep
        v over the threshold boundary — the compare must flip exactly
        at v == T, matching the f32 path's float compare (so the count
        of firing words is exactly T, i.e. P(z=1) == T * 2^-24)."""
        S = (1 << bits) - 1
        for q in (0, 1, S // 3, S // 2, S - 1, S):
            T = int(quant_threshold_u24(jnp.uint32(q), bits))
            phat = np.float32(T * 2.0 ** -24)
            vs = np.unique(np.clip(
                np.array([0, T - 2, T - 1, T, T + 1, (1 << 24) - 1]),
                0, (1 << 24) - 1,
            ))
            u = jnp.asarray((vs.astype(np.uint64) << 8) | 0xAB, jnp.uint32)
            int_draw = (vs < T)
            f32_draw = np.asarray(bernoulli_u32(u, phat)).astype(bool)
            np.testing.assert_array_equal(int_draw, f32_draw, err_msg=str(q))

    @pytest.mark.parametrize("name", QUANTIZED)
    def test_endpoints_exact(self, name):
        codec = get_codec(name)
        S = (1 << codec.bits) - 1
        zeros = jnp.zeros((512,), codec.wire_dtype)
        ones = jnp.full((512,), S, codec.wire_dtype)
        assert np.asarray(sample_mask_qhash(zeros, codec.bits, 3, 1,
                                            jnp.uint32(5))).sum() == 0
        assert np.asarray(sample_mask_qhash(ones, codec.bits, 3, 1,
                                            jnp.uint32(5))).sum() == 512

    def test_empirical_mean_matches_analytic(self):
        """Frequency over many draw words ~ T * 2^-24 (CLT bound)."""
        codec = get_codec("u8")
        q = jnp.full((200_000,), 85, codec.wire_dtype)  # ~ 1/3
        p = int(quant_threshold_u24(jnp.uint32(85), 8)) * 2.0 ** -24
        z = np.asarray(sample_mask_qhash(q, 8, 3, 1, jnp.uint32(11)))
        sigma = (p * (1 - p) / z.size) ** 0.5
        assert abs(z.mean() - p) < 5 * sigma


# ---------------------------------------------------------------------------
# encode: shared-stream dither, round-trip error
# ---------------------------------------------------------------------------

class TestEncode:
    @pytest.mark.parametrize("name", QUANTIZED)
    def test_roundtrip_error_within_2_pow_b(self, name):
        codec = get_codec(name)
        spec = _mk()
        rng = np.random.RandomState(3)
        p = jnp.asarray(rng.rand(20_000), jnp.float32)
        q = codec.encode(spec, p, jnp.uint32(5))
        assert q.dtype == jnp.dtype(codec.wire_dtype)
        err = np.abs(np.asarray(codec.decode(spec, q), np.float64)
                     - np.asarray(p, np.float64))
        assert err.max() <= 2.0 ** -codec.bits, err.max()

    @pytest.mark.parametrize("name", QUANTIZED)
    def test_deterministic_per_word(self, name):
        """Same (spec, word) -> identical encoding (the shard_map
        shards' agreement); different words dither differently."""
        codec = get_codec(name)
        spec = _mk()
        p = jnp.asarray(np.random.RandomState(4).rand(spec.n), jnp.float32)
        a = np.asarray(codec.encode(spec, p, jnp.uint32(9)))
        b = np.asarray(codec.encode(spec, p, jnp.uint32(9)))
        np.testing.assert_array_equal(a, b)
        c = np.asarray(codec.encode(spec, p, jnp.uint32(10)))
        assert (a != c).any()

    @pytest.mark.parametrize("name", QUANTIZED)
    def test_clips_and_keeps_endpoints(self, name):
        codec = get_codec(name)
        spec = _mk()
        S = (1 << codec.bits) - 1
        p = jnp.asarray([-2.0, 0.0, 1.0, 3.0], jnp.float32)
        q = np.asarray(codec.encode(spec, p, jnp.uint32(0)))
        np.testing.assert_array_equal(q, [0, 0, S, S])
        dec = np.asarray(codec.decode(spec, jnp.asarray(q,
                                                        codec.wire_dtype)))
        np.testing.assert_array_equal(dec, [0.0, 0.0, 1.0, 1.0])

    def test_f32_codec_is_identity(self):
        codec = get_codec("f32")
        spec = _mk()
        p = jnp.asarray(np.random.RandomState(5).rand(spec.n), jnp.float32)
        assert codec.encode(spec, p, jnp.uint32(3)) is p
        assert codec.decode(spec, p) is p


# ---------------------------------------------------------------------------
# fused kernels accept the quantized operand (tentpole)
# ---------------------------------------------------------------------------

class TestFusedQuantized:
    @pytest.mark.parametrize("impl", ["ref", "pallas"])
    @pytest.mark.parametrize("name", QUANTIZED)
    def test_single_matches_composed(self, impl, name):
        codec = get_codec(name)
        spec = _mk()
        q = _qwords(codec, spec.n, seed=6)
        step = jnp.uint32(42)
        z = sample_mask_hash(codec.decode(spec, q), spec.seed,
                             spec.tensor_id, step)
        want = np.asarray(ops.reconstruct(spec, z, impl=impl,
                                          auto_batch=False))
        got = np.asarray(ops.sample_reconstruct(spec, q, step,
                                                qbits=codec.bits,
                                                impl=impl))
        np.testing.assert_array_equal(got, want)

    @pytest.mark.parametrize("impl", ["ref", "pallas"])
    def test_batched_and_vmap_match_composed(self, impl):
        codec = get_codec("u8")
        spec = _mk()
        rng = np.random.RandomState(7)
        Q = jnp.asarray(rng.randint(0, 256, (5, spec.n)), jnp.uint8)
        steps = jnp.arange(5, dtype=jnp.uint32) + 3
        Z = sample_mask_hash(codec.decode(spec, Q), spec.seed,
                             spec.tensor_id, steps)
        want = np.asarray(ops.reconstruct_batched(spec, Z, impl=impl))
        got = np.asarray(ops.sample_reconstruct_batched(
            spec, Q, steps, qbits=8, impl=impl))
        np.testing.assert_array_equal(got, want)
        got_v = np.asarray(jax.vmap(
            lambda q_, s_: ops.sample_reconstruct(spec, q_, s_, qbits=8,
                                                  impl=impl)
        )(Q, steps))
        np.testing.assert_array_equal(got_v, want)

    def test_chunked_matches(self):
        codec = get_codec("u16")
        spec = _mk((777,), 2.0, 4, 64, seed=4)
        q = _qwords(codec, spec.n, seed=8)
        step = jnp.uint32(9)
        want = np.asarray(ops.sample_reconstruct(spec, q, step, qbits=16))
        got = np.asarray(ops.sample_reconstruct(spec, q, step, qbits=16,
                                                chunks=4))
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)

    def test_no_f32_score_slab_in_quantized_pallas_jaxpr(self):
        """The quantized fused path must not materialize an (K, n) f32
        probability slab — the operand stays integer until the
        in-block draw."""
        from test_fused import _eqn_out_shapes

        spec = _mk()
        k = 6
        Q = jnp.asarray(np.random.RandomState(9).randint(
            0, 256, (k, spec.n)), jnp.uint8)
        steps = jnp.arange(k, dtype=jnp.uint32)
        jaxpr = jax.make_jaxpr(
            lambda Q_: ops.sample_reconstruct_batched(spec, Q_, steps,
                                                      qbits=8,
                                                      impl="pallas")
        )(Q)
        shapes = _eqn_out_shapes(jaxpr.jaxpr, [])
        assert ((k, spec.n), "float32") not in shapes


# ---------------------------------------------------------------------------
# MaskProgram: drawing straight from the encoded broadcast
# ---------------------------------------------------------------------------

class TestMaskProgramWire:
    def _zsetup(self):
        template = {
            "l0": {"kernel": jnp.zeros((64, 128))},
            "l1": {"kernel": jnp.zeros((128, 32))},
        }
        zspecs = build_specs(template, ZamplingConfig(
            compression=4, d=4, window=128, min_size=256))
        state = init_state(jax.random.PRNGKey(0), zspecs)
        return zspecs, state

    @pytest.mark.parametrize("name", QUANTIZED)
    def test_weights_from_wire_fused_equals_composed(self, name):
        zspecs, state = self._zsetup()
        cfg = FederatedConfig(downlink=name)
        wire = encode_state(zspecs, cfg, state)["scores"]
        step = jnp.uint32(17)
        w_f = MaskProgram(zspecs, fused=True, downlink=name)\
            .weights_from_wire(wire, state["dense"], step)
        w_c = MaskProgram(zspecs, fused=False, downlink=name)\
            .weights_from_wire(wire, state["dense"], step)
        for a, b in zip(jax.tree.leaves(w_f), jax.tree.leaves(w_c)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    @pytest.mark.parametrize("name", QUANTIZED)
    def test_wire_draw_equals_decoded_draw(self, name):
        """masks_from_wire == masks on the decoded f32 state (exact)."""
        zspecs, state = self._zsetup()
        cfg = FederatedConfig(downlink=name)
        encoded = encode_state(zspecs, cfg, state)
        decoded = decode_state(zspecs, cfg, encoded)
        step = jnp.uint32(3)
        prog = MaskProgram(zspecs, downlink=name)
        m_wire = prog.masks_from_wire(encoded["scores"], step)
        m_f32 = MaskProgram(zspecs).masks(decoded["scores"], step)
        for p in m_wire:
            np.testing.assert_array_equal(np.asarray(m_wire[p]),
                                          np.asarray(m_f32[p]))

    def test_discretize_from_wire_is_threshold_compare(self):
        zspecs, state = self._zsetup()
        cfg = FederatedConfig(downlink="u8", mode="discretize")
        encoded = encode_state(zspecs, cfg, state)
        decoded = decode_state(zspecs, cfg, encoded)
        prog = MaskProgram(zspecs, mode="discretize", downlink="u8")
        m_wire = prog.masks_from_wire(encoded["scores"], jnp.uint32(0))
        m_ref = MaskProgram(zspecs, mode="discretize").masks(
            decoded["scores"], jnp.uint32(0))
        for p in m_wire:
            np.testing.assert_array_equal(np.asarray(m_wire[p]),
                                          np.asarray(m_ref[p]))

    def test_sample_weights_infers_codec_from_dtype(self):
        from repro.core.sampling import as_word

        zspecs, state = self._zsetup()
        cfg = FederatedConfig(downlink="u16")
        encoded = encode_state(zspecs, cfg, state)
        assert infer_downlink(encoded["scores"]) == "u16"
        assert infer_downlink(state["scores"]) == "f32"
        key = jax.random.PRNGKey(2)
        w_auto = sample_weights(zspecs, encoded, key)
        w_wire = MaskProgram(zspecs, downlink="u16").weights_from_wire(
            encoded["scores"], encoded["dense"], as_word(key))
        for a, b in zip(jax.tree.leaves(w_auto), jax.tree.leaves(w_wire)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_wrong_dtype_raises(self):
        zspecs, state = self._zsetup()
        prog = MaskProgram(zspecs, downlink="u8")
        with pytest.raises(ValueError, match="encode the state"):
            prog.decode_scores(state["scores"])  # f32 leaves into u8

    def test_sample_weights_rejects_mismatched_override(self):
        """An explicit downlink that contradicts the state's leaf
        dtypes must raise — treating u8 wire words as f32 scores would
        silently clip them all to p=1."""
        zspecs, state = self._zsetup()
        encoded = encode_state(zspecs, FederatedConfig(downlink="u8"),
                               state)
        key = jax.random.PRNGKey(4)
        with pytest.raises(ValueError, match="does not match"):
            sample_weights(zspecs, encoded, key, downlink="f32")
        with pytest.raises(ValueError, match="does not match"):
            sample_weights(zspecs, state, key, downlink="u8")
        # the agreeing override still works and equals the inferred path
        w_a = sample_weights(zspecs, encoded, key, downlink="u8")
        w_b = sample_weights(zspecs, encoded, key)
        for a, b in zip(jax.tree.leaves(w_a), jax.tree.leaves(w_b)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# federated rounds: the encoded scores ARE the carry
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def fed_setup():
    from repro.data import client_batch_stream, iid_client_split, make_teacher_dataset
    from repro.models.mlp import SMALL_DIMS, init_mlp_params

    ds = make_teacher_dataset(n_train=600, n_test=100, seed=0)
    template = init_mlp_params(jax.random.PRNGKey(0), SMALL_DIMS)
    zspecs = build_specs(template, ZamplingConfig(
        compression=2.0, d=5, window=128, min_size=256))
    state = init_state(jax.random.PRNGKey(1), zspecs, dense_init=template)
    K, E = 4, 2
    clients = iid_client_split(ds, K)
    stream = client_batch_stream(clients, 32, E, seed=0)
    xs, ys = next(stream)
    batch = {"x": jnp.asarray(xs), "y": jnp.asarray(ys)}
    return zspecs, state, batch, stream, K, E


def _round(zspecs, state, batch, cfg, key=0, rid=0):
    from repro.models.mlp import mlp_loss

    return jax.jit(
        lambda s, b, k: federated_round(zspecs, s, mlp_loss, b, k, cfg,
                                        round_index=rid)
    )(state, batch, jax.random.PRNGKey(key))


class TestFederatedRounds:
    def test_f32_codec_bit_identical_to_default(self, fed_setup):
        """downlink='f32' is the identity oracle: same scores (exact),
        same dense grads, as the default config — on every uplink."""
        zspecs, state, batch, _, K, E = fed_setup
        for agg in ("mean_f32", "psum_u32"):
            base, _ = _round(zspecs, state, batch, FederatedConfig(
                num_clients=K, local_steps=E, local_lr=0.1, aggregate=agg))
            got, _ = _round(zspecs, state, batch, FederatedConfig(
                num_clients=K, local_steps=E, local_lr=0.1, aggregate=agg,
                downlink="f32"))
            for p in base["scores"]:
                np.testing.assert_array_equal(
                    np.asarray(base["scores"][p]),
                    np.asarray(got["scores"][p]))
            for p in base["dense"]:
                np.testing.assert_array_equal(
                    np.asarray(base["dense"][p]),
                    np.asarray(got["dense"][p]))

    @pytest.mark.parametrize("name", QUANTIZED)
    def test_quantized_round_carries_wire_dtype(self, fed_setup, name):
        zspecs, state, batch, _, K, E = fed_setup
        codec = get_codec(name)
        cfg = FederatedConfig(num_clients=K, local_steps=E, local_lr=0.1,
                              aggregate="psum_u32", downlink=name)
        st = encode_state(zspecs, cfg, state)
        st1, met = _round(zspecs, st, batch, cfg)
        for p, spec in zspecs.specs.items():
            assert st1["scores"][p].dtype == jnp.dtype(codec.wire_dtype)
            assert st1["scores"][p].shape == (spec.n,)
        # round metrics meter the configured codec exactly (f32 cast)
        rep = round_wire_report(zspecs, "psum_u32", K, downlink=name)
        assert np.isclose(float(met["downlink_bytes_per_client"]),
                          rep["downlink_bytes_per_client"], rtol=1e-6)
        assert np.isclose(float(met["downlink_bytes_round"]),
                          rep["downlink_bytes_round"], rtol=1e-6)

    def test_quantized_agnostic_to_uplink_transport(self, fed_setup):
        """With a fixed codec the uplink strategies stay bit-exact
        against each other (the encode sees identical aggregates)."""
        zspecs, state, batch, _, K, E = fed_setup
        outs = {}
        for agg in ("mean_f32", "psum_u32", "allgather_packed"):
            cfg = FederatedConfig(num_clients=K, local_steps=E,
                                  local_lr=0.1, aggregate=agg,
                                  downlink="u8")
            st = encode_state(zspecs, cfg, state)
            st1, _ = _round(zspecs, st, batch, cfg)
            outs[agg] = jax.tree.map(np.asarray, st1["scores"])
        for agg in ("psum_u32", "allgather_packed"):
            for p in outs["mean_f32"]:
                np.testing.assert_array_equal(outs["mean_f32"][p],
                                              outs[agg][p])

    def test_encode_state_idempotent_and_guards_cross_codec(self, fed_setup):
        """Re-encoding an already-encoded carry must be a no-op (a
        second pass would reinterpret wire words as f32 scores and
        saturate them to the top code); encoding into a DIFFERENT
        codec raises instead of silently corrupting."""
        zspecs, state, _, _, K, E = fed_setup
        cfg8 = FederatedConfig(num_clients=K, local_steps=E,
                               downlink="u8")
        st8 = encode_state(zspecs, cfg8, state)
        again = encode_state(zspecs, cfg8, st8)
        for p in st8["scores"]:
            np.testing.assert_array_equal(np.asarray(st8["scores"][p]),
                                          np.asarray(again["scores"][p]))
        cfg16 = FederatedConfig(num_clients=K, local_steps=E,
                                downlink="u16")
        with pytest.raises(ValueError, match="already encoded"):
            encode_state(zspecs, cfg16, st8)
        with pytest.raises(ValueError, match="already encoded"):
            encode_state(zspecs, FederatedConfig(num_clients=K,
                                                 local_steps=E), st8)

    def test_float_state_into_quantized_round_raises(self, fed_setup):
        zspecs, state, batch, _, K, E = fed_setup
        cfg = FederatedConfig(num_clients=K, local_steps=E, local_lr=0.1,
                              downlink="u8")
        with pytest.raises(ValueError, match="encode the state"):
            _round(zspecs, state, batch, cfg)

    def test_fit_matches_sequential_rounds_u8(self, fed_setup):
        """The scan driver threads the encoded carry: fit over R rounds
        == R sequential rounds, bit for bit, on the u8 codec."""
        from repro.models.mlp import mlp_loss
        from repro.train import federated_fit

        zspecs, state, _, stream, K, E = fed_setup
        cfg = FederatedConfig(num_clients=K, local_steps=E, local_lr=0.1,
                              aggregate="psum_u32", downlink="u8")
        st0 = encode_state(zspecs, cfg, state)
        R = 3
        xs, ys = zip(*(next(stream) for _ in range(R)))
        batches = {"x": jnp.asarray(np.stack(xs)),
                   "y": jnp.asarray(np.stack(ys))}
        key = jax.random.PRNGKey(7)
        st_fit, mets = jax.jit(
            lambda s, b, k: federated_fit(zspecs, s, mlp_loss, b, k, cfg)
        )(st0, batches, key)
        assert mets["loss"].shape == (R,)
        st_seq = st0
        for r, sub in enumerate(jax.random.split(key, R)):
            b = jax.tree.map(lambda x, r=r: x[r], batches)
            st_seq, _ = jax.jit(
                lambda s, b_, k, r_=jnp.uint32(r): federated_round(
                    zspecs, s, mlp_loss, b_, k, cfg, round_index=r_)
            )(st_seq, b, sub)
        for p in st_fit["scores"]:
            np.testing.assert_array_equal(
                np.asarray(st_fit["scores"][p]),
                np.asarray(st_seq["scores"][p]))

    def test_sharded_round_bit_identical_to_vmap_u8(self, fed_setup):
        """The shard_map path re-encodes the replicated aggregate with
        the shared dither word: encoded carry == the vmap path's,
        bit for bit."""
        from repro.models.mlp import mlp_loss

        mesh = data_mesh_or_skip(4)
        zspecs, state, batch, _, K, E = fed_setup
        cfg = FederatedConfig(num_clients=K, local_steps=E, local_lr=0.1,
                              aggregate="psum_u32", downlink="u8")
        st = encode_state(zspecs, cfg, state)
        want, _ = _round(zspecs, st, batch, cfg)
        state_specs = jax.tree.map(lambda _: P(), st)
        met_specs = round_metric_specs()

        def body(s, b, k):
            b = jax.tree.map(lambda x: x[0], b)
            return sharded_client_update(zspecs, s, mlp_loss, b, k, cfg)

        with mesh:
            f = shard_map_compat(body, ("data",),
                                 (state_specs, P("data"), P()),
                                 (state_specs, met_specs))
            got, _ = jax.jit(f)(st, batch, jax.random.PRNGKey(0))
        for p in want["scores"]:
            assert got["scores"][p].dtype == jnp.uint8
            np.testing.assert_array_equal(np.asarray(want["scores"][p]),
                                          np.asarray(got["scores"][p]))

    def test_evaluate_on_encoded_carry(self, fed_setup):
        """train.local.evaluate consumes the quantized carry directly
        (sample_weights infers the codec from the leaf dtype)."""
        from repro.train import evaluate

        zspecs, state, batch, _, K, E = fed_setup
        cfg = FederatedConfig(num_clients=K, local_steps=E, local_lr=0.1,
                              downlink="u16")
        st = encode_state(zspecs, cfg, state)
        st1, _ = _round(zspecs, st, batch, cfg)
        metric = jax.jit(
            lambda params: sum(jnp.sum(l * l) for l in
                               jax.tree.leaves(params)))
        m, s = evaluate(zspecs, st1, metric, jax.random.PRNGKey(3),
                        n_samples=3)
        assert np.isfinite(m)


# ---------------------------------------------------------------------------
# metering: bidirectional wire accounting
# ---------------------------------------------------------------------------

class TestDownlinkMetering:
    def _zspecs(self):
        # all leaves reparametrized (no dense): the downlink ratio is
        # exactly bits/32
        template = {
            "l0": {"kernel": jnp.zeros((64, 128))},
            "l1": {"kernel": jnp.zeros((128, 32))},
        }
        return build_specs(template, ZamplingConfig(
            compression=4, d=4, window=128, min_size=256))

    def test_downlink_keys_and_exact_ratio(self):
        zspecs = self._zspecs()
        K = 10
        f32 = round_wire_report(zspecs, "psum_u32", K, downlink="f32")
        u8 = round_wire_report(zspecs, "psum_u32", K, downlink="u8")
        u16 = round_wire_report(zspecs, "psum_u32", K, downlink="u16")
        n = zspecs.n_total
        assert f32["downlink_bytes_per_client"] == 4 * n
        assert u16["downlink_bytes_per_client"] == 2 * n
        assert u8["downlink_bytes_per_client"] == 1 * n
        assert u8["downlink_vs_f32"] == 0.25
        assert u16["downlink_vs_f32"] == 0.5
        for rep in (f32, u8):
            assert rep["downlink_bytes_round"] == (
                K * rep["downlink_bytes_per_client"])
        # the acceptance claim: u8 drops the metered downlink >= 4x
        assert (f32["downlink_bytes_per_client"]
                / u8["downlink_bytes_per_client"]) >= 4.0

    def test_wire_metric_keys_cover_downlink(self):
        assert "downlink_bytes_per_client" in WIRE_METRIC_KEYS
        assert "downlink_bytes_round" in WIRE_METRIC_KEYS
        zspecs = self._zspecs()
        rep = round_wire_report(zspecs, "mean", 4, downlink="u8")
        for k in WIRE_METRIC_KEYS:
            assert k in rep

    def test_comm_bits_cross_check_per_codec(self):
        """server_down_wire == 8 x metered downlink bytes, per codec
        (the analytic/exact cross-check, downlink leg)."""
        zspecs = self._zspecs()
        for name in CODECS:
            bits = zspecs.comm_bits_per_round(packed=True, downlink=name)
            rep = round_wire_report(zspecs, "psum_u32", 10, downlink=name)
            assert bits["server_down_wire"] == 8 * rep[
                "downlink_bytes_per_client"], name
            assert bits["server_down"] == get_codec(name).bits * (
                zspecs.n_total)

    def test_tables_carry_downlink_columns(self):
        zspecs = self._zspecs()
        rows = wire_table(zspecs, 4, downlink="u8")
        for r in rows:
            assert r["downlink"] == "u8"
            assert r["downlink_bytes_per_client"] == zspecs.n_total
        down = downlink_table(zspecs, 4)
        assert {r["codec"] for r in down} == set(CODECS)
        by = {r["codec"]: r for r in down}
        assert by["f32"]["downlink_vs_f32"] == 1.0
        assert by["u8"]["downlink_bytes_per_client"] < by["u16"][
            "downlink_bytes_per_client"]

    def test_score_downlink_bytes(self):
        assert score_downlink_bytes(get_codec("f32"), 1000) == 4000
        assert score_downlink_bytes(get_codec("u16"), 1000) == 2000
        assert score_downlink_bytes(get_codec("u8"), 1000) == 1000
        # odd bit totals round up to whole bytes
        assert score_downlink_bytes(get_codec("u8"), 3) == 3


# ---------------------------------------------------------------------------
# MNIST-FC smoke: u16 within tolerance of the f32 oracle
# ---------------------------------------------------------------------------

def test_mnistfc_u16_loss_close_to_f32(fed_setup):
    """A short federated fit per codec on the MNIST-FC stand-in: the
    u16 broadcast's rounding noise must not derail training — final
    loss within tolerance of the f32 oracle, and both decrease."""
    from repro.models.mlp import mlp_loss
    from repro.train import federated_fit

    zspecs, state, _, stream, K, E = fed_setup
    R = 5
    xs, ys = zip(*(next(stream) for _ in range(R)))
    batches = {"x": jnp.asarray(np.stack(xs)),
               "y": jnp.asarray(np.stack(ys))}
    losses = {}
    for name in ("f32", "u16"):
        cfg = FederatedConfig(num_clients=K, local_steps=E, local_lr=0.5,
                              aggregate="psum_u32", downlink=name)
        st = encode_state(zspecs, cfg, state)
        _, mets = jax.jit(
            lambda s, b, k, cfg=cfg: federated_fit(zspecs, s, mlp_loss,
                                                   b, k, cfg)
        )(st, batches, jax.random.PRNGKey(0))
        losses[name] = np.asarray(mets["loss"])
    for name, curve in losses.items():
        assert np.isfinite(curve).all(), name
        assert curve[-1] < curve[0], (name, curve)
    assert abs(losses["u16"][-1] - losses["f32"][-1]) < 0.1, losses
