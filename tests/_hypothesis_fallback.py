"""Tiny deterministic stand-in for `hypothesis` when it isn't installed.

The test modules do ``try: from hypothesis import ... except
ImportError: from _hypothesis_fallback import ...`` so property tests
still run (with fixed-seed random examples and no shrinking) on a bare
interpreter.  Install the real thing via ``pip install -r
requirements-dev.txt`` to get shrinking, the example database, and the
full strategy library.

Only the surface these tests use is implemented: ``given`` (kwargs
form), ``settings(max_examples=, deadline=)``, and the strategies
``integers``, ``sampled_from``, ``floats``, ``booleans``.
"""

from __future__ import annotations

import functools
import inspect
import random
import types


class _Strategy:
    def __init__(self, draw):
        self.draw = draw


def integers(min_value, max_value):
    return _Strategy(lambda rng: rng.randint(min_value, max_value))


def sampled_from(elements):
    elements = list(elements)
    return _Strategy(lambda rng: elements[rng.randrange(len(elements))])


def floats(min_value=0.0, max_value=1.0, **_):
    return _Strategy(lambda rng: rng.uniform(min_value, max_value))


def booleans():
    return _Strategy(lambda rng: rng.random() < 0.5)


strategies = types.SimpleNamespace(
    integers=integers, sampled_from=sampled_from, floats=floats,
    booleans=booleans,
)

_DEFAULT_MAX_EXAMPLES = 20


def settings(max_examples=_DEFAULT_MAX_EXAMPLES, deadline=None, **_):
    del deadline

    def deco(fn):
        fn._fallback_max_examples = max_examples
        return fn

    return deco


def given(**strategy_kwargs):
    def deco(fn):
        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            n = getattr(wrapper, "_fallback_max_examples",
                        getattr(fn, "_fallback_max_examples",
                                _DEFAULT_MAX_EXAMPLES))
            rng = random.Random(0xC0FFEE)
            for _ in range(n):
                drawn = {k: s.draw(rng) for k, s in strategy_kwargs.items()}
                fn(*args, **kwargs, **drawn)

        # pytest must not see the drawn parameters as fixtures: present
        # the signature with them stripped, and drop __wrapped__ so
        # introspection doesn't recover the original one.
        sig = inspect.signature(fn)
        wrapper.__signature__ = sig.replace(parameters=[
            p for name, p in sig.parameters.items()
            if name not in strategy_kwargs
        ])
        del wrapper.__wrapped__
        return wrapper

    return deco
