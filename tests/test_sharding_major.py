"""Sharding-major layout (QSpec.major_axis / shard_count) consistency:
the distributed reconstruction must be a pure re-layout of the same Q —
validated globally on CPU against materialize_q."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.qspec import make_qspec
from repro.core.reconstruct import grad_z_ref, materialize_q, reconstruct_ref

CASES = [
    # (shape, major_axis, shard_count, compression, d, window)
    ((8, 6, 16), 2, 4, 2.0, 4, 32),
    ((12, 10), 0, 4, 4.0, 5, 32),
    ((4, 32, 5), 1, 8, 2.0, 3, 16),
    ((64, 48), 1, 16, 8.0, 8, 64),
]


@pytest.mark.parametrize("shape,a,sc,c,d,window", CASES)
def test_reconstruct_matches_dense_q(shape, a, sc, c, d, window):
    spec = make_qspec(0, shape, 16, compression=c, d=d, window=window,
                      seed=3, major_axis=a, shard_count=sc)
    assert spec.shard_count == sc  # no silent fallback
    z = (np.random.RandomState(0).rand(spec.n) < 0.5).astype(np.float32)
    q = np.asarray(materialize_q(spec))  # natural-order rows
    want = (q @ z).reshape(shape)
    got = np.asarray(reconstruct_ref(spec, jnp.asarray(z)))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("shape,a,sc,c,d,window", CASES)
def test_grad_matches_dense_q_transpose(shape, a, sc, c, d, window):
    spec = make_qspec(0, shape, 16, compression=c, d=d, window=window,
                      seed=3, major_axis=a, shard_count=sc)
    g = np.random.RandomState(1).randn(*shape).astype(np.float32)
    q = np.asarray(materialize_q(spec))
    want = q.T @ g.reshape(-1)
    got = np.asarray(grad_z_ref(spec, jnp.asarray(g)))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_fallback_when_axis_not_divisible():
    spec = make_qspec(0, (7, 10), 7, compression=2, d=3, window=16,
                      major_axis=0, shard_count=4)  # 7 % 4 != 0
    assert spec.shard_count == 1 and spec.major_axis == 0


def test_block_window_locality():
    """Rows of block k must only index block k's windows."""
    from repro.core.qspec import padded_row_window

    spec = make_qspec(0, (64, 48), 16, compression=8.0, d=8, window=64,
                      seed=3, major_axis=1, shard_count=16)
    rp = jnp.arange(spec.m_pad, dtype=jnp.int32)
    win = np.asarray(padded_row_window(spec, rp))
    blk = np.asarray(rp) // spec.m_pad_loc
    assert (win // spec.nw_loc == blk).all()


def _model_mesh(size=4):
    if len(jax.devices()) < size:
        pytest.skip(f"needs {size} devices (conftest forces 4 on CPU)")
    return jax.make_mesh((size,), ("model",))


class TestShardMapPath:
    """The real distributed op (kernels/qz_sharded.py) on a forced
    4-device CPU mesh — single-client and K-stacked, vs dense Q."""

    def _spec(self):
        return make_qspec(0, (8, 6, 16), 16, compression=2.0, d=4,
                          window=32, seed=3, major_axis=2, shard_count=4)

    def test_sharded_reconstruct_matches_dense(self):
        from repro.kernels.qz_sharded import sharded_reconstruct

        spec = self._spec()
        z = jnp.asarray(np.random.RandomState(0).rand(spec.n), jnp.float32)
        q = np.asarray(materialize_q(spec))
        with _model_mesh():
            got = np.asarray(sharded_reconstruct(spec, z, 4))
        np.testing.assert_allclose(
            got, (q @ np.asarray(z)).reshape(spec.shape), rtol=1e-5,
            atol=1e-5,
        )

    def test_sharded_batched_matches_dense(self):
        from repro.kernels.qz_sharded import (
            sharded_grad_z_batched,
            sharded_reconstruct_batched,
        )

        spec = self._spec()
        k = 3
        Z = jnp.asarray(np.random.RandomState(1).rand(k, spec.n),
                        jnp.float32)
        q = np.asarray(materialize_q(spec))
        with _model_mesh():
            got = np.asarray(sharded_reconstruct_batched(spec, Z, 4))
        want = np.einsum("mn,kn->km", q, np.asarray(Z)).reshape(
            k, *spec.shape
        )
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)
        G = jnp.asarray(np.random.RandomState(2).randn(k, *spec.shape),
                        jnp.float32)
        with _model_mesh():
            got_g = np.asarray(sharded_grad_z_batched(spec, G, 4))
        want_g = np.einsum("mn,km->kn", q, np.asarray(G).reshape(k, -1))
        np.testing.assert_allclose(got_g, want_g, rtol=1e-4, atol=1e-4)

    def test_ops_dispatch_through_mesh(self):
        spec = self._spec()
        from repro.kernels import ops

        Z = jnp.asarray(np.random.RandomState(3).rand(2, spec.n),
                        jnp.float32)
        want = np.asarray(reconstruct_ref(spec, Z[0]))
        with _model_mesh():
            got = np.asarray(ops.reconstruct(spec, Z[0], model_size=4))
            got_b = np.asarray(
                ops.reconstruct_batched(spec, Z, model_size=4)
            )
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)
        np.testing.assert_allclose(got_b[0], want, rtol=1e-5, atol=1e-5)


class TestShardedLocalDraw:
    """The fused sharded forward draws only the shard's own windows
    (coords offset by ``sid * n_loc``) — bit-identical to drawing the
    replicated (n,) mask and re-slicing, for the f32 and quantized
    downlink paths, single and K-stacked, and through the public op."""

    def _spec(self):
        return make_qspec(0, (8, 6, 16), 16, compression=2.0, d=4,
                          window=32, seed=3, major_axis=2, shard_count=4)

    def test_local_draw_matches_replicated_draw(self):
        from repro.core.sampling import sample_mask_hash
        from repro.kernels.qz_sharded import (
            sharded_reconstruct,
            sharded_sample_reconstruct,
        )

        spec = self._spec()
        p = jnp.asarray(np.random.RandomState(0).rand(spec.n), jnp.float32)
        step = jnp.uint32(77)
        with _model_mesh():
            z = sample_mask_hash(p, spec.seed, spec.tensor_id, step)
            want = np.asarray(sharded_reconstruct(spec, z, 4))
            got = np.asarray(sharded_sample_reconstruct(spec, p, step, 4))
        np.testing.assert_array_equal(got, want)

    def test_local_draw_batched_and_quantized(self):
        from repro.core.sampling import sample_mask_hash, sample_mask_qhash
        from repro.kernels.qz_sharded import (
            sharded_reconstruct,
            sharded_reconstruct_batched,
            sharded_sample_reconstruct,
            sharded_sample_reconstruct_batched,
        )

        spec = self._spec()
        k = 5
        Pr = jnp.asarray(np.random.RandomState(1).rand(k, spec.n),
                         jnp.float32)
        steps = jnp.arange(10, 10 + k, dtype=jnp.uint32)
        q = jnp.asarray((np.random.RandomState(2).rand(spec.n) * 255)
                        .astype(np.uint8))
        with _model_mesh():
            Z = sample_mask_hash(Pr, spec.seed, spec.tensor_id, steps)
            want_b = np.asarray(sharded_reconstruct_batched(spec, Z, 4))
            got_b = np.asarray(
                sharded_sample_reconstruct_batched(spec, Pr, steps, 4))
            zq = sample_mask_qhash(q, 8, spec.seed, spec.tensor_id,
                                   jnp.uint32(77))
            want_q = np.asarray(sharded_reconstruct(spec, zq, 4))
            got_q = np.asarray(sharded_sample_reconstruct(
                spec, q.astype(jnp.uint32), jnp.uint32(77), 4, qbits=8))
        np.testing.assert_array_equal(got_b, want_b)
        np.testing.assert_array_equal(got_q, want_q)

    def test_public_fused_op_uses_local_draw(self):
        from repro.core.sampling import sample_mask_hash
        from repro.kernels import ops
        from repro.kernels.qz_sharded import (
            sharded_reconstruct,
            sharded_reconstruct_batched,
        )

        spec = self._spec()
        Pr = jnp.asarray(np.random.RandomState(3).rand(2, spec.n),
                         jnp.float32)
        steps = jnp.asarray([4, 9], jnp.uint32)
        with _model_mesh():
            Z = sample_mask_hash(Pr, spec.seed, spec.tensor_id, steps)
            want = np.asarray(sharded_reconstruct(spec, Z[0], 4))
            want_b = np.asarray(sharded_reconstruct_batched(spec, Z, 4))
            got = np.asarray(ops.sample_reconstruct(
                spec, Pr[0], steps[0], model_size=4))
            got_b = np.asarray(ops.sample_reconstruct_batched(
                spec, Pr, steps, model_size=4))
        np.testing.assert_array_equal(got, want)
        np.testing.assert_array_equal(got_b, want_b)


def test_autodiff_through_reconstruct_sc():
    spec = make_qspec(0, (8, 6, 16), 16, compression=2.0, d=4, window=32,
                      seed=5, major_axis=2, shard_count=4)
    z = jnp.asarray(np.random.RandomState(2).rand(spec.n), jnp.float32)
    v = jnp.asarray(np.random.RandomState(3).randn(8, 6, 16), jnp.float32)
    g = jax.grad(lambda z_: jnp.vdot(reconstruct_ref(spec, z_), v))(z)
    q = np.asarray(materialize_q(spec))
    np.testing.assert_allclose(np.asarray(g), q.T @ np.asarray(v).reshape(-1),
                               rtol=1e-4, atol=1e-4)
