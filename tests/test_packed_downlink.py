"""Packed sub-byte downlink codecs + adaptive rate schedules.

The contracts pinned here:

 - ``pack_words``/``unpack_words`` are exact inverses for any b in
   [1, 16] at any length (lane padding reads back as zeros);
 - ``packed{b}`` keeps the PR-5 draw contract EXACTLY: the b-bit
   probability words quantize, threshold, and draw bit-identically to
   a word-per-coordinate codec of the same width — the bigint
   ``floor(q * 2^24 / (2^b - 1))`` is the oracle across the full
   alphabet (boundary words and endpoints included);
 - ``quant_threshold_u24_dyn`` (traced width) == the static
   ``quant_threshold_u24`` for every width;
 - ``encode_at`` at the codec's own width is BITWISE ``encode``, and
   the divisor embedding of b into B is the exact threshold embedding
   whenever b | B;
 - the fused kernels (ref, pallas, batched, the serve contractions)
   consume the uint32 lanes directly and match the composed
   unpack -> qhash -> reconstruct oracle bit for bit, without
   materializing an unpacked per-coordinate word slab in the pallas
   jaxpr;
 - scheduled rounds: ``downlink_schedule='constant'`` is bit-identical
   to the equivalent fixed codec on the vmap AND 4-device shard_map
   drivers; ``frontier`` reaches the u8 loss neighborhood at strictly
   fewer cumulative downlink bytes; the frontier width vector and the
   packed uint32 carry round-trip a checkpoint bitwise;
 - routing: the packed codecs share the uint32 carrier, so dtype
   sniffing raises on ambiguity and the explicit ``carried=`` tag is
   the only way in.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from _helpers import data_mesh_or_skip, round_metric_specs

from repro.comm.bitpack import (
    pack_words,
    packed_word_len,
    unpack_words,
    words_per_lane,
)
from repro.comm.downlink import codec_for_dtype, get_codec
from repro.comm.metering import scheduled_downlink_bits
from repro.comm.shardmap import shard_map_compat
from repro.core import (
    FederatedConfig,
    ZamplingConfig,
    build_specs,
    decode_state,
    encode_state,
    init_state,
)
from repro.core.federated import federated_round, sharded_client_update
from repro.core.qspec import make_qspec
from repro.core.sampling import (
    quant_threshold_u24,
    quant_threshold_u24_dyn,
    sample_mask_qhash,
)
from repro.core.zampling import infer_downlink, sample_weights
from repro.kernels import ops

PACKED = ("packed4", "packed2")
SWEEP_BITS = (1, 2, 4, 6, 8, 12, 16)


def _mk(shape=(300, 20), c=8.0, d=5, window=64, seed=7, **kw):
    fan = shape[0] if len(shape) == 1 else int(np.prod(shape[:-1]))
    return make_qspec(1, shape, fan, compression=c, d=d, window=window,
                      seed=seed, **kw)


def _lanes(bits, n, seed=0):
    """Random packed lanes whose every word is a valid b-bit value."""
    rng = np.random.RandomState(seed)
    q = jnp.asarray(rng.randint(0, 1 << bits, n), jnp.uint32)
    return pack_words(q, bits), q


# ---------------------------------------------------------------------------
# lane layout: pack/unpack round-trip (satellite 3, property tests)
# ---------------------------------------------------------------------------

class TestBitpack:
    @pytest.mark.parametrize("bits", SWEEP_BITS)
    @pytest.mark.parametrize("n", [1, 7, 31, 32, 33, 257])
    def test_round_trip(self, bits, n):
        rng = np.random.RandomState(bits * 1000 + n)
        q = jnp.asarray(rng.randint(0, 1 << bits, n), jnp.uint32)
        lanes = pack_words(q, bits)
        assert lanes.dtype == jnp.uint32
        assert lanes.shape == (packed_word_len(n, bits),)
        np.testing.assert_array_equal(np.asarray(unpack_words(lanes, n, bits)),
                                      np.asarray(q))

    @pytest.mark.parametrize("bits", SWEEP_BITS)
    def test_layout_word_j_at_offset_bj(self, bits):
        """Word j of lane i is coordinate i*wpl + j at bit offset b*j —
        the layout the in-kernel unpack and the serve gather assume."""
        wpl = words_per_lane(bits)
        n = 3 * wpl + max(wpl - 1, 1)
        rng = np.random.RandomState(1)
        q = rng.randint(0, 1 << bits, n)
        lanes = np.asarray(pack_words(jnp.asarray(q, jnp.uint32), bits))
        mask = (1 << bits) - 1
        for i in range(n):
            got = (int(lanes[i // wpl]) >> (bits * (i % wpl))) & mask
            assert got == q[i], (bits, i)
        # lane padding holds zero words
        tail = n % wpl
        if tail:
            for j in range(tail, wpl):
                assert (int(lanes[-1]) >> (bits * j)) & mask == 0

    def test_batched_leading_axes(self):
        rng = np.random.RandomState(2)
        q = jnp.asarray(rng.randint(0, 16, (3, 45)), jnp.uint32)
        lanes = pack_words(q, 4)
        assert lanes.shape == (3, packed_word_len(45, 4))
        np.testing.assert_array_equal(np.asarray(unpack_words(lanes, 45, 4)),
                                      np.asarray(q))

    def test_invalid_bits_raise(self):
        for bad in (0, 17, 32):
            with pytest.raises(ValueError, match="bits"):
                words_per_lane(bad)


# ---------------------------------------------------------------------------
# the widened threshold vs exact bigint, across the b sweep (satellite 3)
# ---------------------------------------------------------------------------

class TestThresholdSweep:
    @pytest.mark.parametrize("bits", SWEEP_BITS)
    def test_static_matches_bigint_oracle(self, bits):
        """T(q) == floor(q * 2^24 / (2^b - 1)) — exact python bigint
        oracle over the full alphabet (b <= 8) or a boundary-heavy
        sample, endpoints pinned: T(0) == 0, T(S) == 2^24."""
        S = (1 << bits) - 1
        if S <= 4096:
            qs = np.arange(S + 1)
        else:
            rng = np.random.RandomState(bits)
            qs = np.unique(np.concatenate([
                np.arange(0, 300),
                np.array([S // 2 - 1, S // 2, S // 2 + 1,
                          S - 2, S - 1, S]),
                rng.randint(0, S + 1, 4000),
            ]))
        T = np.asarray(quant_threshold_u24(jnp.asarray(qs, jnp.uint32),
                                           bits))
        want = np.array([(int(q) << 24) // S for q in qs], np.uint32)
        np.testing.assert_array_equal(T, want)
        assert int(quant_threshold_u24(jnp.uint32(0), bits)) == 0
        assert int(quant_threshold_u24(jnp.uint32(S), bits)) == 1 << 24

    @pytest.mark.parametrize("bits", SWEEP_BITS)
    def test_dyn_matches_static(self, bits):
        """The traced-width threshold (what the scheduled encode runs
        under scan) == the static one, for every word of the alphabet
        (b <= 12) / a dense sample."""
        S = (1 << bits) - 1
        qs = (np.arange(S + 1) if S <= 4096
              else np.random.RandomState(9).randint(0, S + 1, 8192))
        q = jnp.asarray(qs, jnp.uint32)
        stat = quant_threshold_u24(q, bits)
        dyn = jax.jit(quant_threshold_u24_dyn)(q, jnp.uint32(bits))
        np.testing.assert_array_equal(np.asarray(stat), np.asarray(dyn))

    @pytest.mark.parametrize("bits", [1, 2, 4])
    def test_divisor_embedding_preserves_threshold(self, bits):
        """Widening q_b into the B=8 alphabet by the exact divisor
        embedding q = (q_b*S_B + S_b//2) // S_b preserves the draw
        threshold exactly when b | B — the carry can hold the
        scheduled word at full codec width with zero draw drift."""
        B = 8
        S_b, S_B = (1 << bits) - 1, (1 << B) - 1
        for qb in range(S_b + 1):
            q = (qb * S_B + S_b // 2) // S_b
            t_b = (qb << 24) // S_b
            t_B = (q << 24) // S_B
            assert t_b == t_B, (bits, qb)


# ---------------------------------------------------------------------------
# the packed codecs: encode/decode/draw == word-level contract
# ---------------------------------------------------------------------------

class TestPackedCodec:
    @pytest.mark.parametrize("name", PACKED)
    def test_registry_and_shapes(self, name):
        codec = get_codec(name)
        assert codec.packed and codec.quantized
        assert codec.wire_dtype == jnp.uint32
        spec = _mk()
        assert codec.wire_len(spec.n) == packed_word_len(spec.n, codec.bits)
        assert codec.downlink_bits_per_client(spec.n) == \
            32 * packed_word_len(spec.n, codec.bits)

    def test_aliases(self):
        assert get_codec("u4").name == "packed4"
        assert get_codec("u2").name == "packed2"

    @pytest.mark.parametrize("name", PACKED)
    def test_encode_produces_lanes_decode_unpacks(self, name):
        codec = get_codec(name)
        spec = _mk()
        rng = np.random.RandomState(3)
        scores = jnp.asarray(rng.uniform(-0.2, 1.2, spec.n), jnp.float32)
        wire = codec.encode(spec, scores, jnp.uint32(5))
        assert wire.dtype == jnp.uint32
        assert wire.shape == (packed_word_len(spec.n, codec.bits),)
        words = codec.wire_words(spec, wire)
        assert words.shape == (spec.n,)
        assert int(jnp.max(words)) <= (1 << codec.bits) - 1
        # decode == T(word) * 2^-24, the same expression as u8/u16
        T = np.asarray(quant_threshold_u24(words, codec.bits))
        np.testing.assert_array_equal(
            np.asarray(codec.decode(spec, wire)),
            T.astype(np.float64) * 2.0 ** -24)

    @pytest.mark.parametrize("name", PACKED)
    def test_draw_bit_identical_to_word_level(self, name):
        """The client draw from packed lanes == sample_mask_qhash on
        the unpacked words — Bern(p-hat) at the draw-word level."""
        codec = get_codec(name)
        spec = _mk()
        lanes, q = _lanes(codec.bits, spec.n, seed=4)
        for step in (0, 1, 77):
            z_oracle = sample_mask_qhash(q, codec.bits, spec.seed,
                                         spec.tensor_id, jnp.uint32(step))
            z_packed = sample_mask_qhash(
                codec.wire_words(spec, lanes), codec.bits, spec.seed,
                spec.tensor_id, jnp.uint32(step))
            np.testing.assert_array_equal(np.asarray(z_oracle),
                                          np.asarray(z_packed))

    @pytest.mark.parametrize("name", PACKED)
    def test_encode_at_full_width_is_encode(self, name):
        codec = get_codec(name)
        spec = _mk()
        rng = np.random.RandomState(5)
        scores = jnp.asarray(rng.uniform(-0.1, 1.1, spec.n), jnp.float32)
        w = jnp.uint32(9)
        a = codec.encode(spec, scores, w)
        b = jax.jit(lambda s: codec.encode_at(spec, s, w,
                                              jnp.uint32(codec.bits)))(scores)
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_encode_at_scheduled_width_embeds(self):
        """u8's encode_at(b=2) lands every word on the widened 2-bit
        sublattice of the u8 alphabet (divisor embedding), with the
        2-bit threshold."""
        codec = get_codec("u8")
        spec = _mk()
        rng = np.random.RandomState(6)
        scores = jnp.asarray(rng.uniform(0, 1, spec.n), jnp.float32)
        q8 = np.asarray(codec.encode_at(spec, scores, jnp.uint32(3),
                                        jnp.uint32(2)))
        lattice = {(qb * 255 + 1) // 3 for qb in range(4)}
        assert set(np.unique(q8)).issubset(lattice)

    def test_dtype_sniffing_raises_on_uint32_carrier(self):
        with pytest.raises(ValueError, match="packed|ambig|uint32"):
            codec_for_dtype(jnp.uint32)


# ---------------------------------------------------------------------------
# fused kernels on packed lanes == composed oracle, no word slab
# ---------------------------------------------------------------------------

class TestPackedKernels:
    @pytest.mark.parametrize("name", PACKED)
    @pytest.mark.parametrize("impl", ["ref", "pallas"])
    def test_sample_reconstruct_matches_oracle(self, name, impl):
        codec = get_codec(name)
        spec = _mk()
        lanes, q = _lanes(codec.bits, spec.n, seed=10)
        step = jnp.uint32(3)
        got = ops.sample_reconstruct(spec, lanes, step, qbits=codec.bits,
                                     qpacked=True, impl=impl)
        z = sample_mask_qhash(q, codec.bits, spec.seed, spec.tensor_id,
                              step)
        want = ops.reconstruct(spec, z, impl="ref")
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    @pytest.mark.parametrize("name", PACKED)
    def test_batched_matches_oracle(self, name):
        codec = get_codec(name)
        spec = _mk()
        K = 5
        L = packed_word_len(spec.n, codec.bits)
        rng = np.random.RandomState(11)
        qs = jnp.asarray(rng.randint(0, 1 << codec.bits, (K, spec.n)),
                         jnp.uint32)
        lanes = pack_words(qs, codec.bits)
        assert lanes.shape == (K, L)
        steps = jnp.arange(K, dtype=jnp.uint32)
        got = ops.sample_reconstruct_batched(spec, lanes, steps,
                                             qbits=codec.bits,
                                             qpacked=True, impl="pallas")
        for k in range(K):
            z = sample_mask_qhash(qs[k], codec.bits, spec.seed,
                                  spec.tensor_id, steps[k])
            np.testing.assert_array_equal(
                np.asarray(got[k]),
                np.asarray(ops.reconstruct(spec, z, impl="ref")))

    def test_no_word_slab_in_packed_pallas_jaxpr(self):
        """The packed fused pallas path must unpack lanes IN-BLOCK:
        no (n,) per-coordinate uint32 word slab in its jaxpr.  The ref
        fallback DOES materialize it (detector sanity check)."""
        codec = get_codec("packed4")
        spec = _mk()
        lanes, _ = _lanes(codec.bits, spec.n)
        step = jnp.uint32(0)
        slab = ((spec.n,), "uint32")

        def shapes(jx, acc):
            for eqn in jx.eqns:
                for v in eqn.outvars:
                    aval = getattr(v, "aval", None)
                    if aval is not None and getattr(aval, "dtype", None) \
                            is not None:
                        acc.append((tuple(aval.shape), str(aval.dtype)))
                for param in eqn.params.values():
                    inner = getattr(param, "jaxpr", None)
                    if inner is not None:
                        shapes(inner, acc)
                    elif hasattr(param, "eqns"):
                        shapes(param, acc)
            return acc

        fused = jax.make_jaxpr(
            lambda w: ops.sample_reconstruct(spec, w, step,
                                             qbits=codec.bits,
                                             qpacked=True, impl="pallas")
        )(lanes)
        assert slab not in shapes(fused.jaxpr, []), (
            "packed pallas path materializes the (n,) word slab")

        ref = jax.make_jaxpr(
            lambda w: ops.sample_reconstruct(spec, w, step,
                                             qbits=codec.bits,
                                             qpacked=True, impl="ref")
        )(lanes)
        assert slab in shapes(ref.jaxpr, []), (
            "detector failed: ref oracle should materialize the words")

    @pytest.mark.parametrize("impl", ["ref", "chunked", "pallas"])
    def test_serve_matvec_matches_oracle(self, impl):
        codec = get_codec("packed4")
        spec = _mk()
        lanes, q = _lanes(codec.bits, spec.n, seed=12)
        step = jnp.uint32(7)
        rng = np.random.RandomState(13)
        x = jnp.asarray(rng.randn(spec.shape[0]), jnp.float32)
        got = ops.serve_matvec(spec, lanes, step, x, qbits=codec.bits,
                               qpacked=True, impl=impl)
        z = sample_mask_qhash(q, codec.bits, spec.seed, spec.tensor_id,
                              step)
        W = ops.reconstruct(spec, z, impl="ref").reshape(spec.shape)
        np.testing.assert_allclose(np.asarray(got),
                                   np.asarray(x @ W), rtol=1e-5,
                                   atol=1e-5)

    def test_non_fusable_window_falls_back_to_ref(self):
        """window not divisible by words-per-lane: the fused q-kernels
        must still be exact via the ref fallback (which pays the word
        slab — the documented trade)."""
        codec = get_codec("packed4")
        spec = _mk(window=4)  # 4 % 8 != 0: a lane straddles windows
        lanes, q = _lanes(codec.bits, spec.n, seed=14)
        step = jnp.uint32(2)
        got = ops.sample_reconstruct(spec, lanes, step, qbits=codec.bits,
                                     qpacked=True, impl="pallas")
        z = sample_mask_qhash(q, codec.bits, spec.seed, spec.tensor_id,
                              step)
        np.testing.assert_array_equal(
            np.asarray(got),
            np.asarray(ops.reconstruct(spec, z, impl="ref")))


# ---------------------------------------------------------------------------
# federated rounds on the packed carry + schedules
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def fed_setup():
    from repro.data import (client_batch_stream, iid_client_split,
                            make_teacher_dataset)
    from repro.models.mlp import SMALL_DIMS, init_mlp_params

    ds = make_teacher_dataset(n_train=600, n_test=100, seed=0)
    template = init_mlp_params(jax.random.PRNGKey(0), SMALL_DIMS)
    zspecs = build_specs(template, ZamplingConfig(
        compression=2.0, d=5, window=128, min_size=256))
    state = init_state(jax.random.PRNGKey(1), zspecs, dense_init=template)
    K, E, R = 4, 2, 6
    clients = iid_client_split(ds, K)
    stream = client_batch_stream(clients, 32, E, seed=0)
    rounds = [next(stream) for _ in range(R)]
    batches = {"x": jnp.asarray(np.stack([x for x, _ in rounds])),
               "y": jnp.asarray(np.stack([y for _, y in rounds])),}
    return zspecs, state, batches, K, E, R


def _fit(zspecs, state, batches, cfg, key=0):
    from repro.models.mlp import mlp_loss
    from repro.train import federated_fit

    return jax.jit(
        lambda s, b, k: federated_fit(zspecs, s, mlp_loss, b, k, cfg)
    )(state, batches, jax.random.PRNGKey(key))


class TestPackedRounds:
    @pytest.mark.parametrize("name", PACKED)
    def test_round_carries_lanes(self, fed_setup, name):
        """The packed wire lanes ARE the round carry: uint32, lane
        count per tensor, metered at 32 bits/lane."""
        zspecs, state, batches, K, E, R = fed_setup
        codec = get_codec(name)
        cfg = FederatedConfig(num_clients=K, local_steps=E, local_lr=0.1,
                              aggregate="psum_u32", downlink=name)
        st = encode_state(zspecs, cfg, state)
        st1, mets = _fit(zspecs, st, batches, cfg)
        bits = 0
        for p, spec in zspecs.specs.items():
            L = packed_word_len(spec.n, codec.bits)
            assert st1["scores"][p].dtype == jnp.uint32
            assert st1["scores"][p].shape == (L,)
            bits += 32 * L
        dense = sum(4 * int(np.prod(np.shape(v)))
                    for v in st1["dense"].values())
        want = -(-bits // 8) + dense
        np.testing.assert_allclose(
            np.asarray(mets["downlink_bytes_per_client"]),
            float(want), rtol=1e-6)

    def test_packed4_downlink_an_eighth_of_f32(self, fed_setup):
        """The acceptance gate: packed4 score downlink bytes <= 1/8 of
        the f32 score broadcast + lane slack."""
        zspecs, *_ = fed_setup
        codec = get_codec("packed4")
        score_bytes = sum(
            4 * packed_word_len(s.n, codec.bits)
            for s in zspecs.specs.values())
        f32_bytes = sum(4 * s.n for s in zspecs.specs.values())
        slack = 4 * len(zspecs.specs)  # <= one lane per tensor
        assert score_bytes <= f32_bytes / 8 + slack

    def test_constant_schedule_bitwise_equals_fixed_vmap(self, fed_setup):
        zspecs, state, batches, K, E, R = fed_setup
        base = FederatedConfig(num_clients=K, local_steps=E, local_lr=0.1,
                               aggregate="psum_u32", downlink="u8")
        sched = FederatedConfig(num_clients=K, local_steps=E,
                                local_lr=0.1, aggregate="psum_u32",
                                downlink="u8",
                                downlink_schedule="constant")
        st = encode_state(zspecs, base, state)
        a, ma = _fit(zspecs, st, batches, base)
        b, mb = _fit(zspecs, st, batches, sched)
        for p in a["scores"]:
            np.testing.assert_array_equal(np.asarray(a["scores"][p]),
                                          np.asarray(b["scores"][p]))
        assert set(ma) == set(mb)
        np.testing.assert_array_equal(
            np.asarray(ma["downlink_bytes_per_client"]),
            np.asarray(mb["downlink_bytes_per_client"]))

    def test_constant_schedule_bitwise_equals_fixed_shardmap(self,
                                                            fed_setup):
        """Same claim on the 4-device shard_map driver (+ the sharded
        scheduled state matches the vmap one bitwise)."""
        from repro.models.mlp import mlp_loss

        zspecs, state, batches, K, E, R = fed_setup
        mesh = data_mesh_or_skip(4)
        batch0 = jax.tree.map(lambda x: x[0], batches)
        cfgs = {
            "fixed": FederatedConfig(num_clients=K, local_steps=E,
                                     local_lr=0.1, aggregate="psum_u32",
                                     downlink="u8"),
            "sched": FederatedConfig(num_clients=K, local_steps=E,
                                     local_lr=0.1, aggregate="psum_u32",
                                     downlink="u8",
                                     downlink_schedule="constant"),
        }
        outs = {}
        for tag, cfg in cfgs.items():
            st = encode_state(zspecs, cfg, state)
            state_specs = jax.tree.map(lambda _: P(), st)

            def body(s, b, k, cfg=cfg):
                b = jax.tree.map(lambda x: x[0], b)
                return sharded_client_update(zspecs, s, mlp_loss, b, k,
                                             cfg)

            with mesh:
                f = shard_map_compat(body, ("data",),
                                     (state_specs, P("data"), P()),
                                     (state_specs, round_metric_specs()))
                outs[tag], _ = jax.jit(f)(st, batch0,
                                          jax.random.PRNGKey(0))
        vm, _ = jax.jit(
            lambda s, b, k: federated_round(
                zspecs, s, mlp_loss, b, k, cfgs["fixed"], round_index=0)
        )(encode_state(zspecs, cfgs["fixed"], state), batch0,
          jax.random.PRNGKey(0))
        for p in vm["scores"]:
            a = np.asarray(outs["fixed"]["scores"][p])
            b = np.asarray(outs["sched"]["scores"][p])
            np.testing.assert_array_equal(a, b)
            np.testing.assert_array_equal(a, np.asarray(vm["scores"][p]))

    def test_cosine_anneals_width_up(self, fed_setup):
        zspecs, state, batches, K, E, R = fed_setup
        cfg = FederatedConfig(num_clients=K, local_steps=E, local_lr=0.1,
                              aggregate="psum_u32", downlink="packed4",
                              downlink_schedule="cosine",
                              schedule_b_min=1, schedule_rounds=R)
        st = encode_state(zspecs, cfg, state)
        st1, mets = _fit(zspecs, st, batches, cfg)
        down = np.asarray(mets["downlink_bytes_per_client"], np.float64)
        assert down[0] < down[-1]
        assert (np.diff(down) >= 0).all(), down
        # carry stays at the codec's fixed lane layout throughout
        for p, spec in zspecs.specs.items():
            assert st1["scores"][p].dtype == jnp.uint32
            assert st1["scores"][p].shape == (packed_word_len(spec.n, 4),)

    def test_frontier_beats_constant_u8_on_bytes(self, fed_setup):
        """The acceptance gate: the frontier schedule reaches the u8
        loss neighborhood (within 0.1) at strictly fewer cumulative
        downlink bytes than constant u8."""
        zspecs, state, batches, K, E, R = fed_setup
        base = FederatedConfig(num_clients=K, local_steps=E, local_lr=0.5,
                               aggregate="psum_u32", downlink="u8")
        fr = FederatedConfig(num_clients=K, local_steps=E, local_lr=0.5,
                             aggregate="psum_u32", downlink="u8",
                             downlink_schedule="frontier",
                             schedule_b_min=2)
        _, mu8 = _fit(zspecs, encode_state(zspecs, base, state), batches,
                      base)
        st_fr, mfr = _fit(zspecs, encode_state(zspecs, fr, state),
                          batches, fr)
        cum_u8 = float(np.sum(mu8["downlink_bytes_per_client"]))
        cum_fr = float(np.sum(mfr["downlink_bytes_per_client"]))
        assert cum_fr < cum_u8, (cum_fr, cum_u8)
        lu8 = float(np.asarray(mu8["loss"])[-1])
        lfr = float(np.asarray(mfr["loss"])[-1])
        assert abs(lfr - lu8) < 0.1, (lfr, lu8)
        assert "downlink_b" in st_fr
        b = np.asarray(st_fr["downlink_b"])
        assert b.dtype == np.uint32 and b.shape == (len(zspecs.specs),)
        assert (b >= 2).all() and (b <= 8).all()

    def test_scheduled_bits_meter_matches_lane_padding(self):
        assert scheduled_downlink_bits(65, 4) == 32 * 9
        assert scheduled_downlink_bits(64, 4) == 32 * 8
        traced = jax.jit(
            lambda b: scheduled_downlink_bits(65, b))(jnp.uint32(4))
        assert int(traced) == 32 * 9


# ---------------------------------------------------------------------------
# routing + checkpoint round-trip (satellites 1 & 2)
# ---------------------------------------------------------------------------

class TestRoutingAndCheckpoint:
    def test_infer_raises_on_packed_carry(self, fed_setup):
        zspecs, state, *_ = fed_setup
        cfg = FederatedConfig(downlink="packed4")
        st = encode_state(zspecs, cfg, state)
        with pytest.raises(ValueError):
            infer_downlink(st["scores"])

    def test_sample_weights_needs_tag_for_packed(self, fed_setup):
        zspecs, state, *_ = fed_setup
        cfg = FederatedConfig(downlink="packed4")
        st = encode_state(zspecs, cfg, state)
        key = jax.random.PRNGKey(2)
        with pytest.raises(ValueError):
            sample_weights(zspecs, st, key)  # sniffing is ambiguous
        w = sample_weights(zspecs, st, key, carried="packed4")
        for leaf in jax.tree.leaves(w):
            assert jnp.asarray(leaf).dtype == jnp.float32
        # the WRONG packed tag is rejected by the lane-count check
        # (packed2 lanes are longer), not silently misdecoded
        with pytest.raises(ValueError):
            sample_weights(zspecs, st, key, carried="packed2")

    def test_evaluate_with_carried_tag(self, fed_setup):
        from repro.train import evaluate

        zspecs, state, *_ = fed_setup
        cfg = FederatedConfig(downlink="packed4")
        st = encode_state(zspecs, cfg, state)
        ms, _ = evaluate(zspecs, st, lambda p: 1.0, jax.random.PRNGKey(0),
                         n_samples=2, carried="packed4")
        assert ms == 1.0

    def test_checkpoint_roundtrip_packed_carry_bitwise(self, fed_setup,
                                                       tmp_path):
        from repro.checkpoint import (checkpoint_downlink,
                                      load_checkpoint, save_checkpoint)

        zspecs, state, batches, K, E, R = fed_setup
        cfg = FederatedConfig(num_clients=K, local_steps=E, local_lr=0.1,
                              aggregate="psum_u32", downlink="packed4",
                              downlink_schedule="frontier",
                              schedule_b_min=2)
        st = encode_state(zspecs, cfg, state)
        st1, _ = _fit(zspecs, st, batches, cfg)
        path = str(tmp_path / "packed_carry.npz")
        save_checkpoint(path, st1, downlink="packed4")
        loaded, meta = load_checkpoint(path, st1)
        assert checkpoint_downlink(meta) == "packed4"
        flat1 = jax.tree_util.tree_leaves_with_path(st1)
        flat2 = dict(jax.tree_util.tree_leaves_with_path(loaded))
        for p, leaf in flat1:
            got = flat2[p]
            assert np.asarray(got).dtype == np.asarray(leaf).dtype, p
            np.testing.assert_array_equal(np.asarray(got),
                                          np.asarray(leaf), err_msg=str(p))
        # the restored carry + width vector drive another round as-is
        st2 = encode_state(zspecs, cfg, loaded)  # idempotent pass-through
        assert st2["scores"] is loaded["scores"] or all(
            np.array_equal(np.asarray(st2["scores"][k]),
                           np.asarray(loaded["scores"][k]))
            for k in st2["scores"])
        _fit(zspecs, st2, batches, cfg)

    def test_serve_from_packed_carry(self, fed_setup):
        from repro.serve import make_serve_state, reconstruct_resident

        zspecs, state, *_ = fed_setup
        cfg = FederatedConfig(downlink="packed4")
        st = encode_state(zspecs, cfg, state)
        sstate = make_serve_state(zspecs, st, jax.random.PRNGKey(0),
                                  carried="packed4")
        assert sstate.qbits == 4 and sstate.qpacked
        resident = reconstruct_resident(sstate)
        codec = get_codec("packed4")
        for p, spec in zspecs.specs.items():
            q = codec.wire_words(spec, sstate.words[p])
            z = sample_mask_qhash(q, 4, spec.seed, spec.tensor_id,
                                  sstate.step)
            want = ops.reconstruct(spec, z, impl="ref").reshape(spec.shape)
            np.testing.assert_array_equal(np.asarray(resident[p]),
                                          np.asarray(want))
        # wrong tag rejected
        with pytest.raises(ValueError):
            make_serve_state(zspecs, st, jax.random.PRNGKey(0),
                             carried="packed2")
