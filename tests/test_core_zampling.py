"""Unit + property tests for the Zampling core (Q generation, w = Qz)."""

import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:  # vendored fallback: fixed-seed examples, no shrinking
    from _hypothesis_fallback import given, settings
    from _hypothesis_fallback import strategies as st

from repro.core import zonotope
from repro.core.qspec import make_qspec, row_indices, row_values
from repro.core.reconstruct import materialize_q, reconstruct_ref
from repro.core.sampling import clip_probs, sample_mask, sample_mask_st
from repro.core.zampling import ZamplingConfig, build_specs, init_state, sample_weights


def spec_small(m=600, c=4.0, d=5, window=64, seed=3, fan_in=20):
    return make_qspec(0, (m,), fan_in, compression=c, d=d, window=window, seed=seed)


class TestQSpec:
    def test_rows_have_exactly_d_distinct_indices(self):
        spec = spec_small()
        idx = np.asarray(row_indices(spec, jnp.arange(spec.m_pad)))
        assert idx.shape == (spec.m_pad, spec.d)
        assert (idx >= 0).all() and (idx < spec.window).all()
        for r in range(0, spec.m_pad, 37):
            assert len(set(idx[r].tolist())) == spec.d  # without replacement

    def test_value_distribution_matches_lemma_2_1(self):
        # q_ij ~ N(0, 6/(d fan_in)): check mean/var over many rows
        spec = make_qspec(0, (4096, 64), 64, compression=8, d=8, seed=1)
        vals = np.asarray(row_values(spec, jnp.arange(20000)))
        sigma2 = 6.0 / (spec.d * spec.fan_in)
        assert abs(vals.mean()) < 3 * math.sqrt(sigma2 / vals.size) * 2 + 1e-3
        np.testing.assert_allclose(vals.var(), sigma2, rtol=0.05)

    def test_determinism_across_calls(self):
        spec = spec_small()
        a = row_values(spec, jnp.arange(100))
        b = row_values(spec, jnp.arange(100))
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_different_seeds_decorrelate(self):
        s1, s2 = spec_small(seed=1), spec_small(seed=2)
        v1 = np.asarray(row_values(s1, jnp.arange(5000))).ravel()
        v2 = np.asarray(row_values(s2, jnp.arange(5000))).ravel()
        assert abs(np.corrcoef(v1, v2)[0, 1]) < 0.05

    def test_padding_and_window_accounting(self):
        spec = make_qspec(0, (1000,), 10, compression=3, d=4, window=64)
        assert spec.n == spec.num_windows * spec.window
        assert spec.m_pad >= spec.m
        assert spec.n >= spec.n_raw


class TestReconstruct:
    def test_matches_dense_matmul(self):
        spec = spec_small()
        z = (np.random.RandomState(0).rand(spec.n) < 0.5).astype(np.float32)
        q = np.asarray(materialize_q(spec))
        want = q @ z
        got = np.asarray(reconstruct_ref(spec, jnp.asarray(z))).reshape(-1)
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)

    @pytest.mark.slow
    def test_kaiming_he_variance_of_w(self):
        # Lemma 2.1: w_i -> N(0, E[p^2] * 6 / fan_in); E[p^2]=1/3 for U(0,1)
        fan_in = 128
        spec = make_qspec(0, (512, fan_in, 128), fan_in, compression=16,
                          d=16, seed=7)
        p = jax.random.uniform(jax.random.PRNGKey(0), (spec.n,))
        w = np.asarray(reconstruct_ref(spec, p)).ravel()
        np.testing.assert_allclose(w.var(), 2.0 / fan_in, rtol=0.1)
        assert abs(w.mean()) < 0.01

    def test_grad_is_q_transpose(self):
        spec = spec_small(m=300, window=32, d=3)
        z = jnp.asarray(np.random.RandomState(1).rand(spec.n), jnp.float32)
        v = jnp.asarray(np.random.RandomState(2).randn(spec.m), jnp.float32)
        f = lambda z_: jnp.vdot(reconstruct_ref(spec, z_).reshape(-1), v)
        g = jax.grad(f)(z)
        q = np.asarray(materialize_q(spec))
        np.testing.assert_allclose(np.asarray(g), q.T @ np.asarray(v),
                                   rtol=1e-4, atol=1e-4)

    @pytest.mark.slow
    @settings(max_examples=15, deadline=None)
    @given(
        m=st.integers(40, 2000),
        c=st.sampled_from([1.0, 2.0, 8.0, 32.0]),
        d=st.integers(1, 16),
        window=st.sampled_from([32, 128, 512]),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_property_reconstruct_equals_dense(self, m, c, d, window, seed):
        spec = make_qspec(0, (m,), 16, compression=c, d=d, window=window,
                          seed=seed)
        z = (np.random.RandomState(seed % 1000).rand(spec.n) < 0.5).astype(
            np.float32
        )
        q = np.asarray(materialize_q(spec))
        got = np.asarray(reconstruct_ref(spec, jnp.asarray(z))).reshape(-1)
        np.testing.assert_allclose(got, q @ z, rtol=1e-4, atol=1e-4)


class TestSampling:
    def test_clip_is_paper_f(self):
        s = jnp.asarray([-1.0, 0.0, 0.3, 1.0, 2.0])
        np.testing.assert_allclose(
            np.asarray(clip_probs(s)), [0.0, 0.0, 0.3, 1.0, 1.0]
        )

    def test_mask_is_binary_and_unbiased(self):
        p = jnp.full((20000,), 0.3)
        z = np.asarray(sample_mask(p, jax.random.PRNGKey(0)))
        assert set(np.unique(z)) <= {0.0, 1.0}
        assert abs(z.mean() - 0.3) < 0.02

    def test_straight_through_gradient(self):
        p = jnp.asarray([0.2, 0.8, 0.5])
        g = jax.grad(lambda p_: sample_mask_st(p_, jax.random.PRNGKey(1)).sum())(p)
        np.testing.assert_allclose(np.asarray(g), 1.0)


class TestZamplingTree:
    def _template(self):
        return {
            "layer0": {"kernel": jnp.zeros((64, 128)), "bias": jnp.zeros((128,))},
            "layer1": {"kernel": jnp.zeros((128, 32))},
            "norm": {"scale": jnp.ones((128,))},
        }

    def test_build_specs_partition(self):
        zs = build_specs(self._template(), ZamplingConfig(compression=8, d=4))
        assert set(zs.specs) == {"layer0/kernel", "layer1/kernel"}
        assert set(zs.dense_paths) == {"layer0/bias", "norm/scale"}
        assert zs.m_total == 64 * 128 + 128 * 32
        assert 4 <= zs.compression <= 8.01

    def test_sample_weights_shapes_and_finite(self):
        tmpl = self._template()
        zs = build_specs(tmpl, ZamplingConfig(compression=4, d=4, window=128))
        state = init_state(jax.random.PRNGKey(0), zs)
        w = sample_weights(zs, state, jax.random.PRNGKey(1))
        assert jax.tree.structure(w) == jax.tree.structure(tmpl)
        for a, b in zip(jax.tree.leaves(w), jax.tree.leaves(tmpl)):
            assert a.shape == b.shape
            assert bool(jnp.isfinite(a).all())

    def test_comm_accounting(self):
        zs = build_specs(self._template(), ZamplingConfig(compression=8))
        bits = zs.comm_bits_per_round(packed=True)
        assert bits["client_up"] == zs.n_total
        assert bits["naive_client_up"] == 32 * zs.m_total
        # the headline: >= ~32x compression on top of the 32x binarization
        assert bits["naive_client_up"] / bits["client_up"] > 100


class TestZonotopeTheory:
    def test_lemma_2_2_nonzero_weights(self):
        # empirical E[nnz(w)] vs m(1 - 2^-d), averaging over p~U and z~Bern(p)
        spec = spec_small(m=2000, c=1.0, d=3, window=2048)
        rng, nnz = np.random.RandomState(0), []
        for t in range(30):
            p = rng.rand(spec.n).astype(np.float32)
            z = (rng.rand(spec.n) < p).astype(np.float32)
            w = np.asarray(reconstruct_ref(spec, jnp.asarray(z)))
            nnz.append((np.abs(w) > 1e-12).sum())
        want = zonotope.expected_nonzero_weights(spec.m, spec.d)
        np.testing.assert_allclose(np.mean(nnz), want, rtol=0.05)

    def test_lemma_2_3_empty_columns(self):
        # fraction of z entries with no influence ~ e^-d for m = n
        spec = make_qspec(0, (4096,), 16, compression=1.0, d=2, window=256,
                          seed=5)
        q = np.asarray(materialize_q(spec))
        frac = (np.abs(q).sum(0) == 0).mean()
        np.testing.assert_allclose(frac, math.exp(-spec.d), atol=0.04)

    def test_prop_2_6_jensen_dimension(self):
        # dim(C_tau) of the average >= average of dims
        rng = np.random.RandomState(0)
        ps = [np.clip(rng.rand(500) + rng.randn(500) * 0.3, 0, 1)
              for _ in range(8)]
        tau = 0.05
        dims = [zonotope.tau_hypercube_dim(p, tau) for p in ps]
        dim_avg = zonotope.tau_hypercube_dim(np.mean(ps, 0), tau)
        assert dim_avg >= np.mean(dims) - 1e-9

    def test_log_volume_finite(self):
        v = zonotope.log_expected_zonotope_volume([64] * 100, d=8)
        assert math.isfinite(v)
