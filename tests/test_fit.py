"""Scan-over-rounds drivers (train.fit).

``federated_fit`` over R rounds must (a) be numerically identical to R
sequential ``federated_round`` calls with the same per-round keys and
round indices (the scan threads the round counter into the mask-draw
words), and (b) trace the round body exactly once regardless of R —
one compile per (R, K, E, batch) shape, with re-dispatch free of
retracing.  ``sharded_client_fit`` is the same contract inside
``shard_map`` on the forced 4-device CPU mesh.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from _helpers import data_mesh_or_skip, round_metric_specs

from repro.comm import shard_map_compat
from repro.core import FederatedConfig, ZamplingConfig, build_specs, init_state
from repro.core.federated import (
    WIRE_METRIC_KEYS,
    federated_round,
    sharded_client_update,
)
from repro.data import client_batch_stream, iid_client_split, make_teacher_dataset
from repro.models.mlp import SMALL_DIMS, init_mlp_params, mlp_loss
from repro.train import federated_fit, sharded_client_fit

K, E, B = 4, 2, 32


@pytest.fixture(scope="module")
def setup():
    ds = make_teacher_dataset(n_train=600, n_test=100, seed=0)
    template = init_mlp_params(jax.random.PRNGKey(0), SMALL_DIMS)
    zspecs = build_specs(template, ZamplingConfig(
        compression=2.0, d=5, window=128, min_size=256))
    state = init_state(jax.random.PRNGKey(1), zspecs, dense_init=template)
    clients = iid_client_split(ds, K)
    stream = client_batch_stream(clients, B, E, seed=0)
    return zspecs, state, stream


def _round_stack(stream, r):
    xs, ys = zip(*(next(stream) for _ in range(r)))
    return {"x": jnp.asarray(np.stack(xs)), "y": jnp.asarray(np.stack(ys))}


def test_fit_matches_sequential_rounds(setup):
    zspecs, state, stream = setup
    cfg = FederatedConfig(num_clients=K, local_steps=E, local_lr=0.1,
                          aggregate="psum_u32")
    R = 5
    batches = _round_stack(stream, R)
    key = jax.random.PRNGKey(7)
    st_fit, mets = jax.jit(
        lambda s, b, k: federated_fit(zspecs, s, mlp_loss, b, k, cfg)
    )(state, batches, key)
    assert mets["loss"].shape == (R,)
    for mk in WIRE_METRIC_KEYS:
        assert mets[mk].shape == (R,)

    round_fn = jax.jit(
        lambda s, b, k, r: federated_round(zspecs, s, mlp_loss, b, k, cfg,
                                           round_index=r)
    )
    st_seq = state
    seq_losses = []
    for r, sub in enumerate(jax.random.split(key, R)):
        b = jax.tree.map(lambda x, r=r: x[r], batches)
        st_seq, m = round_fn(st_seq, b, sub, jnp.uint32(r))
        seq_losses.append(float(m["loss"]))
    for p in st_fit["scores"]:
        np.testing.assert_array_equal(
            np.asarray(st_fit["scores"][p]), np.asarray(st_seq["scores"][p])
        )
    for p in st_fit["dense"]:
        np.testing.assert_allclose(
            np.asarray(st_fit["dense"][p]), np.asarray(st_seq["dense"][p]),
            rtol=1e-6, atol=1e-7,
        )
    np.testing.assert_allclose(np.asarray(mets["loss"]), seq_losses,
                               rtol=1e-6, atol=1e-7)


def test_fit_compiles_once(setup):
    """The loss is Python-traced a fixed number of times per COMPILE,
    never per round: R=5 and R=2 fits trace identically, and a second
    same-shape call adds zero traces."""
    zspecs, state, stream = setup
    cfg = FederatedConfig(num_clients=K, local_steps=E, local_lr=0.1)
    traces = []

    def counting_loss(params, batch):
        traces.append(1)
        return mlp_loss(params, batch)

    def fit(r):
        f = jax.jit(lambda s, b, k: federated_fit(
            zspecs, s, counting_loss, b, k, cfg))
        b = _round_stack(stream, r)
        out = f(state, b, jax.random.PRNGKey(0))
        jax.block_until_ready(out)
        return f, b

    f5, b5 = fit(5)
    n5 = len(traces)
    assert n5 > 0
    f5(state, b5, jax.random.PRNGKey(1))  # same shapes: cached
    assert len(traces) == n5, "same-shape refit retraced"
    traces.clear()
    fit(2)
    n2 = len(traces)
    assert n2 == n5, (
        f"trace count scales with R ({n2} at R=2 vs {n5} at R=5): "
        "the scan driver is not compiling once"
    )


def test_fit_respects_rounds_arg(setup):
    zspecs, state, stream = setup
    cfg = FederatedConfig(num_clients=K, local_steps=E, local_lr=0.1)
    batches = _round_stack(stream, 3)
    _, mets = jax.jit(lambda s, b, k: federated_fit(
        zspecs, s, mlp_loss, b, k, cfg, rounds=3))(
        state, batches, jax.random.PRNGKey(0))
    assert mets["loss"].shape == (3,)


def _data_mesh(size=4):
    return data_mesh_or_skip(size)


def test_sharded_fit_matches_sequential(setup):
    """R rounds scanned INSIDE shard_map == R sequential shard_map
    dispatches of sharded_client_update (exact), packed transport."""
    mesh = _data_mesh()
    zspecs, state, stream = setup
    cfg = FederatedConfig(num_clients=K, local_steps=E, local_lr=0.1,
                          aggregate="allgather_packed")
    R = 3
    per_round = [next(stream) for _ in range(R)]
    # per-shard slab: (K, R, E, B, ...) — K is the sharded mesh axis
    rb = {"x": jnp.asarray(np.stack([x for x, _ in per_round], 1)),
          "y": jnp.asarray(np.stack([y for _, y in per_round], 1))}
    key = jax.random.PRNGKey(3)
    state_specs = jax.tree.map(lambda _: P(), state)
    met_specs = round_metric_specs()

    def fit_body(s, b, k):
        b = jax.tree.map(lambda x: x[0], b)  # (R, E, B, ...)
        return sharded_client_fit(zspecs, s, mlp_loss, b, k, cfg)

    with mesh:
        f = shard_map_compat(fit_body, ("data",),
                             (state_specs, P("data"), P()),
                             (state_specs, met_specs))
        st_fit, mets = jax.jit(f)(state, rb, key)
    assert mets["loss"].shape == (R,)

    def round_body(s, b, k, r):
        b = jax.tree.map(lambda x: x[0], b)
        return sharded_client_update(zspecs, s, mlp_loss, b, k, cfg,
                                     round_index=r)

    st_seq = state
    for r, sub in enumerate(jax.random.split(key, R)):
        with mesh:
            f2 = shard_map_compat(round_body, ("data",),
                                  (state_specs, P("data"), P(), P()),
                                  (state_specs, met_specs))
            b = jax.tree.map(lambda x, r=r: x[:, r], rb)
            st_seq, _ = jax.jit(f2)(st_seq, b, sub, jnp.uint32(r))
    for p in st_fit["scores"]:
        np.testing.assert_array_equal(
            np.asarray(st_fit["scores"][p]), np.asarray(st_seq["scores"][p])
        )
