"""Transpose-plan backward: ``grad_z = Q^T grad_w`` as a gather.

Contract (core/transpose_plan.py): EXACT equality per ordering mode
(the same plan always sums each coordinate's incoming edges in the
same order), ``allclose`` across ordering modes and against the
scatter oracle.  Sweeps d / window / shard_count / non-divisible
``rows_per_window % bm``, zero-in-degree columns, chunked and sharded
paths, and ``vmap(grad(local_update))`` through the federated round
on the forced 4-device mesh.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.core.qspec import make_qspec
from repro.core.reconstruct import (
    grad_z_batched_ref,
    grad_z_plan_batched_ref,
    grad_z_plan_ref,
    grad_z_ref,
    grad_z_scatter_batched_ref,
    grad_z_scatter_ref,
    materialize_q,
)
from repro.core.transpose_plan import (
    build_block_plan,
    build_transpose_plan,
    resolve_bwd_path,
    set_default_bwd_path,
)
from repro.kernels import ops
from repro.kernels.qz_reconstruct import (
    qz_reconstruct_batched_bwd_plan,
    qz_reconstruct_bwd_plan,
)

# (shape, compression, d, window, make_qspec kwargs) — sweeps d and
# window, shard-major layouts, and a d=1 diagonal-ish spec
SWEEP = [
    ((64, 96), 8.0, 8, 256, {}),
    ((512,), 2.0, 4, 64, {}),
    ((1000,), 4.0, 1, 128, {}),
    ((8, 6, 16), 2.0, 4, 32, dict(major_axis=2, shard_count=4)),
    ((64, 48), 2.0, 4, 32, dict(major_axis=1, shard_count=16)),
]


def _mk(shape, c, d, window, kw=None, seed=11):
    fan = shape[0] if len(shape) == 1 else int(np.prod(shape[:-1]))
    return make_qspec(1, shape, fan, compression=c, d=d, window=window,
                      seed=seed, **(kw or {}))


def _g(spec, seed=1, k=None):
    r = np.random.RandomState(seed)
    shape = spec.shape if k is None else (k, *spec.shape)
    return jnp.asarray(r.randn(*shape), jnp.float32)


@pytest.mark.parametrize("shape,c,d,window,kw", SWEEP)
def test_plan_allclose_scatter_and_dense(shape, c, d, window, kw):
    spec = _mk(shape, c, d, window, kw)
    g = _g(spec)
    plan = np.asarray(grad_z_plan_ref(spec, g))
    scatter = np.asarray(grad_z_scatter_ref(spec, g))
    np.testing.assert_allclose(plan, scatter, rtol=1e-4, atol=1e-5)
    q = np.asarray(materialize_q(spec))
    dense = np.einsum("mn,m->n", q, np.asarray(g).reshape(-1))
    np.testing.assert_allclose(plan, dense, rtol=1e-4, atol=1e-4)
    # batched: one plan constant, K clients
    G = _g(spec, seed=2, k=3)
    np.testing.assert_allclose(
        np.asarray(grad_z_plan_batched_ref(spec, G)),
        np.asarray(grad_z_scatter_batched_ref(spec, G)),
        rtol=1e-4, atol=1e-5,
    )


@pytest.mark.parametrize("order", ["canonical", "slot"])
def test_plan_exact_per_ordering_mode(order):
    """Same ordering mode -> bit-identical results, jit or not."""
    spec = _mk((64, 96), 8.0, 8, 256, {})
    g = _g(spec)
    a = np.asarray(grad_z_plan_ref(spec, g, order=order))
    b = np.asarray(jax.jit(
        lambda g_: grad_z_plan_ref(spec, g_, order=order))(g))
    c = np.asarray(jax.jit(  # a distinct jit cache entry
        lambda g_, o=order: grad_z_plan_ref(spec, g_, o))(g))
    np.testing.assert_array_equal(a, b)
    np.testing.assert_array_equal(a, c)


def test_plan_orders_allclose_cross_mode():
    spec = _mk((64, 96), 8.0, 8, 256, {})
    g = _g(spec)
    a = np.asarray(grad_z_plan_ref(spec, g, order="canonical"))
    b = np.asarray(grad_z_plan_ref(spec, g, order="slot"))
    np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-5)
    # the two plans really do order edges differently where deg > 1
    pa = build_transpose_plan(spec, "canonical")
    pb = build_transpose_plan(spec, "slot")
    np.testing.assert_array_equal(pa.counts, pb.counts)
    assert (pa.rows != pb.rows).any()


def test_zero_in_degree_columns():
    """Coordinates no row ever touches must get exactly zero grad."""
    spec = _mk((1000,), 4.0, 1, 128, {})
    plan = build_transpose_plan(spec)
    dead = np.flatnonzero(plan.counts == 0)
    assert dead.size > 0, "sweep spec no longer has zero-degree columns"
    g = _g(spec)
    out = np.asarray(grad_z_plan_ref(spec, g))
    np.testing.assert_array_equal(out[dead], 0.0)
    np.testing.assert_allclose(out, np.asarray(grad_z_scatter_ref(spec, g)),
                               rtol=1e-4, atol=1e-5)


def test_plan_counts_match_valid_edges():
    for shape, c, d, window, kw in SWEEP:
        spec = _mk(shape, c, d, window, kw)
        plan = build_transpose_plan(spec)
        assert plan.n_edges == spec.m * spec.d  # padding rows excluded
        assert plan.deg == int(plan.counts.max())
        assert (np.asarray(plan.vals)[..., :] != 0).sum() <= plan.n_edges


@pytest.mark.parametrize("bm", [64, 256])
def test_pallas_plan_bwd_matches(bm):
    """Block plan kernel, incl. rows_per_window % bm != 0 re-binning."""
    spec = _mk((900, 30), 16.0, 8, 128, {})
    assert spec.rows_per_window % bm != 0
    g = _g(spec).reshape(-1)
    want = np.asarray(grad_z_scatter_ref(spec, g.reshape(spec.shape)))
    got = np.asarray(qz_reconstruct_bwd_plan(spec, g, bm=bm,
                                             interpret=True))
    got2 = np.asarray(qz_reconstruct_bwd_plan(spec, g, bm=bm,
                                              interpret=True))
    np.testing.assert_array_equal(got, got2)  # its own ordering mode
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)
    G = _g(spec, seed=3, k=3).reshape(3, -1)
    wantb = np.asarray(
        grad_z_scatter_batched_ref(spec, G.reshape(3, *spec.shape)))
    gotb = np.asarray(qz_reconstruct_batched_bwd_plan(spec, G, bm=bm,
                                                      interpret=True))
    np.testing.assert_allclose(gotb, wantb, rtol=1e-4, atol=1e-4)


def test_block_plan_geometry():
    spec = _mk((900, 30), 16.0, 8, 128, {})
    bp = build_block_plan(spec, 64)
    assert bp.bpw == -(-spec.rows_per_window // 64)
    assert bp.rows.shape == (spec.num_windows, bp.bpw, spec.window, bp.deg)
    assert bp.rows.max() < 64  # block-relative
    flat = build_transpose_plan(spec)
    # re-binning preserves the edge multiset per coordinate
    assert (bp.vals != 0).sum() == flat.n_edges


def test_chunked_plan_matches_unchunked():
    spec = _mk((777,), 2.0, 4, 64, {})
    z = jnp.asarray(np.random.RandomState(4).rand(spec.n), jnp.float32)
    v = _g(spec, seed=5)

    def grad_with(chunks):
        return jax.grad(lambda z_: jnp.vdot(
            ops.reconstruct(spec, z_, chunks=chunks, auto_batch=False),
            v))(z)

    a, b = np.asarray(grad_with(1)), np.asarray(grad_with(5))
    np.testing.assert_allclose(b, a, rtol=1e-4, atol=1e-5)
    G = _g(spec, seed=6, k=3)
    Z = jnp.asarray(np.random.RandomState(7).rand(3, spec.n), jnp.float32)

    def bgrad_with(chunks):
        return jax.grad(lambda Z_: jnp.vdot(
            ops.reconstruct_batched(spec, Z_, chunks=chunks), G))(Z)

    np.testing.assert_allclose(np.asarray(bgrad_with(5)),
                               np.asarray(bgrad_with(1)),
                               rtol=1e-4, atol=1e-5)


def test_env_gate_routes_paths(monkeypatch):
    """REPRO_BWD_PLAN picks the trace-time path: each gated trace must
    reproduce its oracle BIT-exactly."""
    spec = _mk((64, 96), 8.0, 8, 256, {}, seed=21)
    z = jnp.asarray(np.random.RandomState(8).rand(spec.n), jnp.float32)
    v = _g(spec, seed=9)

    def traced_grad():
        # a fresh closure per call: a fresh trace reads the gate
        return np.asarray(jax.grad(lambda z_: jnp.vdot(
            ops.reconstruct(spec, z_, auto_batch=False), v))(z))

    monkeypatch.setenv("REPRO_BWD_PLAN", "scatter")
    np.testing.assert_array_equal(
        traced_grad(), np.asarray(grad_z_scatter_ref(spec, v)))
    monkeypatch.setenv("REPRO_BWD_PLAN", "plan")
    np.testing.assert_array_equal(
        traced_grad(), np.asarray(grad_z_plan_ref(spec, v)))
    monkeypatch.setenv("REPRO_BWD_PLAN", "plan:slot")
    np.testing.assert_array_equal(
        traced_grad(), np.asarray(grad_z_plan_ref(spec, v, order="slot")))
    monkeypatch.setenv("REPRO_BWD_PLAN", "bogus")
    with pytest.raises(ValueError, match="REPRO_BWD_PLAN"):
        resolve_bwd_path()


def test_set_default_bwd_path_validates():
    with pytest.raises(ValueError, match="valid paths"):
        set_default_bwd_path("bogus")
    assert resolve_bwd_path("plan") == ("plan", "canonical")
    assert resolve_bwd_path("plan:slot") == ("plan", "slot")
    assert resolve_bwd_path("scatter") == ("scatter", None)


def test_grad_z_ref_dispatches_to_plan_by_default():
    spec = _mk((64, 96), 8.0, 8, 256, {}, seed=23)
    g = _g(spec, seed=10)
    np.testing.assert_array_equal(np.asarray(grad_z_ref(spec, g)),
                                  np.asarray(grad_z_plan_ref(spec, g)))
    G = _g(spec, seed=11, k=3)
    np.testing.assert_array_equal(
        np.asarray(grad_z_batched_ref(spec, G)),
        np.asarray(grad_z_plan_batched_ref(spec, G)))


def test_sharded_plan_matches_scatter_and_global(monkeypatch):
    from tests._helpers import data_mesh_or_skip
    from repro.kernels.qz_sharded import sharded_grad_z, sharded_grad_z_batched

    mesh = data_mesh_or_skip(4, "model")
    spec = make_qspec(0, (8, 6, 16), 16, compression=2.0, d=4, window=32,
                      seed=3, major_axis=2, shard_count=4)
    g, G = _g(spec, seed=12), _g(spec, seed=13, k=3)
    with mesh:
        got = np.asarray(sharded_grad_z(spec, g, 4))
        gotb = np.asarray(sharded_grad_z_batched(spec, G, 4))
        monkeypatch.setenv("REPRO_BWD_PLAN", "scatter")
        sc = np.asarray(sharded_grad_z(spec, g, 4))
        scb = np.asarray(sharded_grad_z_batched(spec, G, 4))
        monkeypatch.delenv("REPRO_BWD_PLAN")
    # the shard-local plan is a window-slice of the global plan: the
    # per-coordinate edge order coincides, so single-client sharded is
    # bit-identical to the global plan path
    np.testing.assert_array_equal(got, np.asarray(grad_z_plan_ref(spec, g)))
    np.testing.assert_allclose(got, sc, rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(gotb, scb, rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(
        gotb, np.asarray(grad_z_plan_batched_ref(spec, G)),
        rtol=1e-4, atol=1e-5)


def test_federated_round_plan_vs_scatter(monkeypatch):
    """vmap(grad(local_update)) through a full round on the 4-device
    mesh topology: the plan backward must be deterministic (exact
    across reruns) and allclose to a scatter-gated round."""
    from repro.core.federated import FederatedConfig, federated_round
    from repro.core.zampling import ZamplingConfig, build_specs, init_state
    from repro.data import client_batch_stream, iid_client_split, make_teacher_dataset
    from repro.models.mlp import SMALL_DIMS, init_mlp_params, mlp_loss

    ds = make_teacher_dataset(n_train=300, n_test=50, seed=0)
    template = init_mlp_params(jax.random.PRNGKey(0), SMALL_DIMS)
    zspecs = build_specs(template, ZamplingConfig(
        compression=2.0, d=5, window=128, min_size=256))
    state = init_state(jax.random.PRNGKey(1), zspecs, dense_init=template)
    K, E = 4, 2
    xs, ys = next(client_batch_stream(iid_client_split(ds, K), 16, E,
                                      seed=0))
    batch = {"x": jnp.asarray(xs), "y": jnp.asarray(ys)}
    cfg = FederatedConfig(num_clients=K, local_steps=E, local_lr=0.1)

    def run():
        st, met = jax.jit(lambda s, b, k: federated_round(
            zspecs, s, mlp_loss, b, k, cfg))(state, batch,
                                             jax.random.PRNGKey(0))
        assert np.isfinite(float(met["loss"]))
        return jax.tree.map(np.asarray, st["scores"])

    plan_scores = run()
    plan_again = run()
    monkeypatch.setenv("REPRO_BWD_PLAN", "scatter")
    scatter_scores = run()
    for p in plan_scores:
        np.testing.assert_array_equal(plan_scores[p], plan_again[p])
        np.testing.assert_allclose(plan_scores[p], scatter_scores[p],
                                   rtol=1e-4, atol=1e-5)
