"""Substrate behaviour tests: training loops, federated rounds,
checkpointing, data pipeline, serving."""

import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import load_checkpoint, save_checkpoint
from repro.core import (
    FederatedConfig,
    ZamplingConfig,
    build_specs,
    federated_round,
    init_state,
    sample_weights,
)
from repro.data import iid_client_split, make_teacher_dataset, client_batch_stream
from repro.models.mlp import (
    SMALL_DIMS,
    init_mlp_params,
    mlp_accuracy,
    mlp_loss,
)
from repro.optim import adam, sgd
from repro.train import LocalTrainConfig, evaluate, train_local_zampling


@pytest.fixture(scope="module")
def dataset():
    return make_teacher_dataset(n_train=3000, n_test=600, seed=0)


def _zsetup(compression=2.0, d=5, seed=0):
    template = init_mlp_params(jax.random.PRNGKey(0), SMALL_DIMS)
    zspecs = build_specs(
        template,
        ZamplingConfig(compression=compression, d=d, window=128, seed=seed,
                       min_size=256),
    )
    state = init_state(jax.random.PRNGKey(1), zspecs, dense_init=template)
    return zspecs, state


class TestLocalZampling:
    @pytest.mark.slow
    def test_learns_synthetic_task(self, dataset):
        zspecs, state = _zsetup()
        batches = (
            {"x": jnp.asarray(x), "y": jnp.asarray(y)}
            for x, y in dataset.batches(128, seed=0)
        )
        test_batch = {
            "x": jnp.asarray(dataset.x_test), "y": jnp.asarray(dataset.y_test)
        }
        eval_fn = jax.jit(lambda p: mlp_accuracy(p, test_batch))
        state, hist = train_local_zampling(
            zspecs, state, mlp_loss, batches,
            LocalTrainConfig(steps=600, lr=1e-2, eval_every=200),
            eval_fn=eval_fn,
        )
        mean_acc, std = evaluate(
            zspecs, state, eval_fn, jax.random.PRNGKey(7), n_samples=10
        )
        assert mean_acc > 0.55, f"sampled accuracy too low: {mean_acc}"
        exp_acc, _ = evaluate(zspecs, state, eval_fn, jax.random.PRNGKey(7),
                              mode="continuous")
        # paper: expected ~ sampled accuracy after training-by-sampling
        assert abs(exp_acc - mean_acc) < 0.15

    def test_loss_decreases(self, dataset):
        zspecs, state = _zsetup()
        batches = (
            {"x": jnp.asarray(x), "y": jnp.asarray(y)}
            for x, y in dataset.batches(128, seed=1)
        )
        _, hist = train_local_zampling(
            zspecs, state, mlp_loss, batches,
            LocalTrainConfig(steps=200, lr=1e-2, eval_every=10**9),
        )
        first = np.mean(hist["loss"][:20])
        last = np.mean(hist["loss"][-20:])
        assert last < first * 0.8


class TestFederated:
    def test_round_aggregates_masks(self, dataset):
        zspecs, state = _zsetup()
        K, E, B = 4, 3, 64
        clients = iid_client_split(dataset, K)
        stream = client_batch_stream(clients, B, E, seed=0)
        xs, ys = next(stream)
        batch = {"x": jnp.asarray(xs), "y": jnp.asarray(ys)}
        cfg = FederatedConfig(num_clients=K, local_steps=E, local_lr=0.1)
        new_state, metrics = federated_round(
            zspecs, state, mlp_loss, batch, jax.random.PRNGKey(0), cfg
        )
        assert jnp.isfinite(metrics["loss"])
        for path, s in new_state["scores"].items():
            v = np.asarray(s)
            # mean of K binary masks: multiples of 1/K in [0,1]
            assert v.min() >= 0 and v.max() <= 1
            np.testing.assert_allclose(v * K, np.round(v * K), atol=1e-5)

    @pytest.mark.slow
    def test_federated_training_improves(self, dataset):
        zspecs, state = _zsetup(compression=2.0)
        K, E, B = 10, 40, 64
        clients = iid_client_split(dataset, K)
        stream = client_batch_stream(clients, B, E, seed=0)
        cfg = FederatedConfig(num_clients=K, local_steps=E, local_lr=0.5)
        test_batch = {
            "x": jnp.asarray(dataset.x_test), "y": jnp.asarray(dataset.y_test)
        }
        eval_fn = jax.jit(lambda p: mlp_accuracy(p, test_batch))

        @jax.jit
        def round_fn(state, batch, key):
            return federated_round(zspecs, state, mlp_loss, batch, key, cfg)

        acc0, _ = evaluate(zspecs, state, eval_fn, jax.random.PRNGKey(3),
                           mode="continuous")
        key = jax.random.PRNGKey(0)
        losses = []
        for r in range(15):
            xs, ys = next(stream)
            key, sub = jax.random.split(key)
            state, m = round_fn(
                state, {"x": jnp.asarray(xs), "y": jnp.asarray(ys)}, sub
            )
            losses.append(float(m["loss"]))
        acc1, _ = evaluate(zspecs, state, eval_fn, jax.random.PRNGKey(3),
                           mode="continuous")
        assert acc1 > acc0 + 0.05, (acc0, acc1)
        assert losses[-1] < losses[0] - 0.1, losses

    def test_continuous_mode_runs(self, dataset):
        zspecs, state = _zsetup()
        clients = iid_client_split(dataset, 2)
        xs, ys = next(client_batch_stream(clients, 32, 2, seed=0))
        cfg = FederatedConfig(num_clients=2, local_steps=2, mode="continuous")
        new_state, metrics = federated_round(
            zspecs, state, mlp_loss, {"x": jnp.asarray(xs), "y": jnp.asarray(ys)},
            jax.random.PRNGKey(0), cfg,
        )
        assert jnp.isfinite(metrics["loss"])


class TestCheckpoint:
    def test_roundtrip(self):
        zspecs, state = _zsetup()
        with tempfile.TemporaryDirectory() as td:
            path = os.path.join(td, "ckpt")
            save_checkpoint(path, state, meta={"q_seed": 0, "round": 3})
            restored, meta = load_checkpoint(path, state)
            assert meta["round"] == 3
            for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
                np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_checkpoint_is_compressed_size(self):
        """Zampling ckpt stores n floats, not m: check the artifact size."""
        zspecs, state = _zsetup(compression=8.0)
        with tempfile.TemporaryDirectory() as td:
            path = os.path.join(td, "ckpt")
            save_checkpoint(path, state, meta={})
            sz = os.path.getsize(path + ".npz")
            dense_bytes = 4 * zspecs.m_total
            assert sz < dense_bytes, (sz, dense_bytes)


class TestServing:
    def test_generate_and_compressed_serving(self):
        from repro.configs.registry import get_arch
        from repro.core import sample_masks
        from repro.models import build_model
        from repro.serve import generate, serve_from_compressed

        cfg = get_arch("qwen2-0.5b").reduced()
        model = build_model(cfg)
        params = model.init_params(jax.random.PRNGKey(0))
        prompt = jnp.asarray([[1, 2, 3, 4]], jnp.int32)
        out = generate(model, params, prompt, 5, seq_len=16)
        assert out.shape == (1, 9)
        assert (out[:, :4] == prompt).all()

        zspecs = build_specs(params, ZamplingConfig(compression=4, d=4))
        state = init_state(jax.random.PRNGKey(1), zspecs, dense_init=params)
        masks = sample_masks(zspecs, state, jax.random.PRNGKey(2))
        out2 = serve_from_compressed(
            model, zspecs, masks, state["dense"], prompt, 3, seq_len=16
        )
        assert out2.shape == (1, 7)


class TestData:
    def test_teacher_dataset_learnable_structure(self, dataset):
        # nearest-prototype on raw inputs should beat chance materially
        from numpy.linalg import norm

        x, y = dataset.x_test, dataset.y_test
        protos = np.stack([
            dataset.x_train[dataset.y_train == c].mean(0) for c in range(10)
        ])
        pred = np.argmax(x @ protos.T, axis=1)
        assert (pred == y).mean() > 0.5

    def test_iid_split_partitions(self, dataset):
        clients = iid_client_split(dataset, 5)
        total = sum(len(c.x_train) for c in clients)
        assert total == len(dataset.x_train)

    def test_lm_stream_shapes(self):
        from repro.data import lm_token_batches

        it = lm_token_batches(vocab=100, batch=4, seq=16)
        b = next(it)
        assert b.shape == (4, 16) and b.dtype == np.int32
        assert b.min() >= 0 and b.max() < 100
