"""Fault-tolerant partial-participation round engine (fault package +
the weighted aggregation paths of core.federated).

Contracts under test:

- Cohort sampling is a deterministic K-of-N draw from the counter-hash
  stream — replayable on host and device, keyed on (seed, round) only.
- The weighted aggregation path with every client participating at
  weight 1 is BIT-IDENTICAL to the PR-5 unweighted path, on the vmap
  and the 4-device shard_map driver, for packed and f32 transports.
- Fault draws are deterministic in (plan.seed, round, client_id):
  the same seed produces the same faulted rounds on both drivers.
- A faulted round computes the exact weighted mean over survivors
  (transport-level integer oracle + survivor-subset replay).
- Rounds below ``min_clients`` degrade gracefully: state carried
  forward unchanged, ``round_skipped`` raised in the metrics.
- Server-side validation detects injected lane corruption.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from _helpers import data_mesh_or_skip, round_metric_specs

from repro.comm import get_transport, shard_map_compat
from repro.comm.bitpack import pack_mask, packed_weighted_sum
from repro.core import FederatedConfig, ZamplingConfig, build_specs, init_state
from repro.core.federated import (
    PARTICIPATION_METRIC_KEYS,
    ROUND_METRIC_KEYS,
    federated_round,
    sharded_client_update,
)
from repro.data import (
    cohort_batch_stream,
    dirichlet_client_split,
    iid_client_split,
    make_teacher_dataset,
)
from repro.fault import (
    CORRUPT,
    DROP,
    OK,
    ClientPopulation,
    FaultPlan,
    corrupt_uploads,
    draw_faults,
    upload_counts,
    validate_uploads,
)
from repro.models.mlp import SMALL_DIMS, init_mlp_params, mlp_loss
from repro.train import federated_fit, sharded_client_fit

K, E, B = 4, 2, 16


@pytest.fixture(scope="module")
def setup():
    ds = make_teacher_dataset(n_train=400, n_test=50, seed=0)
    template = init_mlp_params(jax.random.PRNGKey(0), SMALL_DIMS)
    zspecs = build_specs(template, ZamplingConfig(
        compression=2.0, d=5, window=128, min_size=256))
    state = init_state(jax.random.PRNGKey(1), zspecs, dense_init=template)
    clients = iid_client_split(ds, K)
    xs, ys = [], []
    rng = np.random.RandomState(3)
    for c in clients:
        idx = rng.randint(0, len(c.x_train), (E, B))
        xs.append(c.x_train[idx])
        ys.append(c.y_train[idx])
    batch = {"x": jnp.asarray(np.stack(xs)), "y": jnp.asarray(np.stack(ys))}
    return ds, zspecs, state, batch


def _cfg(aggregate, **kw):
    return FederatedConfig(num_clients=K, local_steps=E, local_lr=0.1,
                           aggregate=aggregate, **kw)


def _assert_state_bits(a, b):
    for p in a["scores"]:
        np.testing.assert_array_equal(
            np.asarray(a["scores"][p]), np.asarray(b["scores"][p]))
    for p in a["dense"]:
        x, y = np.asarray(a["dense"][p]), np.asarray(b["dense"][p])
        if x.dtype == np.float32:
            np.testing.assert_array_equal(x.view(np.uint32),
                                          y.view(np.uint32))
        else:
            np.testing.assert_array_equal(x, y)


def _assert_cross_driver(a, b):
    """Cross-driver contract (the seed's, extended): scores are
    bit-identical; dense f32 leaves agree up to reduction order (XLA
    fuses the vmap stacked sum and the psum differently)."""
    for p in a["scores"]:
        np.testing.assert_array_equal(
            np.asarray(a["scores"][p]), np.asarray(b["scores"][p]))
    for p in a["dense"]:
        np.testing.assert_allclose(
            np.asarray(a["dense"][p]).astype(np.float32),
            np.asarray(b["dense"][p]).astype(np.float32),
            rtol=1e-6, atol=1e-7)


def _sharded_round(mesh, zspecs, state, batch, key, cfg, *, ids=None,
                   weights=None, faults=None):
    state_specs = jax.tree.map(lambda _: P(), state)
    in_specs = [state_specs, P("data"), P()]
    args = [state, batch, key]

    def body(s, b, k, *rest):
        b = jax.tree.map(lambda x: x[0], b)
        kw = {}
        if ids is not None:
            kw["client_id"] = rest[0][0]
        if weights is not None:
            kw["weight"] = rest[-1][0]
        return sharded_client_update(zspecs, s, mlp_loss, b, k, cfg,
                                     faults=faults, **kw)

    if ids is not None:
        in_specs.append(P("data"))
        args.append(jnp.asarray(ids, jnp.uint32))
    if weights is not None:
        in_specs.append(P("data"))
        args.append(jnp.asarray(weights, jnp.uint32))
    with mesh:
        f = shard_map_compat(body, ("data",), tuple(in_specs),
                             (jax.tree.map(lambda _: P(), state),
                              round_metric_specs()))
        return jax.jit(f)(*args)


# ---------------------------------------------------------------------------
# Cohort sampling + data staging
# ---------------------------------------------------------------------------

def test_cohort_sampler_properties():
    pop = ClientPopulation(23, seed=9)
    seen = set()
    for r in range(6):
        ids, w = pop.cohort_np(r, 7)
        assert ids.shape == (7,) and w.shape == (7,)
        assert len(np.unique(ids)) == 7
        assert (np.sort(ids) == ids).all()
        assert (ids < 23).all()
        assert (w == 1).all()  # no sample counts -> unit weights
        seen.add(tuple(ids.tolist()))
    assert len(seen) > 1, "cohort never varies across rounds"
    # replay: same (seed, round) -> same cohort, on host and on device
    ids0, _ = pop.cohort_np(2, 7)
    ids1, _ = pop.cohort_np(2, 7)
    np.testing.assert_array_equal(ids0, ids1)
    dev_ids, dev_w = jax.jit(lambda: pop.sample_cohort(2, 7))()
    np.testing.assert_array_equal(np.asarray(dev_ids), ids0)


def test_cohort_weights_are_sample_counts():
    counts = tuple(range(1, 11))
    pop = ClientPopulation(10, sample_counts=counts, seed=3)
    ids, w = pop.cohort_np(5, 4)
    np.testing.assert_array_equal(w, np.asarray(counts)[ids])


def test_dirichlet_split_partitions_and_weights():
    ds = make_teacher_dataset(n_train=500, n_test=20, seed=1)
    clients, hist = dirichlet_client_split(ds, 8, beta=0.3, seed=2)
    assert len(clients) == 8
    sizes = np.array([len(c.x_train) for c in clients])
    assert sizes.sum() == len(ds.x_train), "split is not a partition"
    assert (sizes >= 1).all(), "empty client escaped the rebalance"
    np.testing.assert_array_equal(hist.sum(axis=1), sizes)
    assert hist.sum() == len(ds.x_train)
    # non-IID: at least one client's label mix differs from uniform
    frac = hist / np.maximum(hist.sum(axis=1, keepdims=True), 1)
    assert np.abs(frac - frac.mean(axis=0)).max() > 0.05
    with pytest.raises(ValueError):
        dirichlet_client_split(ds, 4, beta=0.0)


def test_cohort_batch_stream_replays_sampler():
    ds = make_teacher_dataset(n_train=300, n_test=20, seed=0)
    clients, hist = dirichlet_client_split(ds, 10, beta=0.5, seed=0)
    pop = ClientPopulation(10, sample_counts=tuple(hist.sum(axis=1)), seed=4)
    stream = cohort_batch_stream(clients, pop, 3, B, E, seed=0)
    for r in range(3):
        ids, w, x, y = next(stream)
        want_ids, want_w = pop.cohort_np(r, 3)
        np.testing.assert_array_equal(ids, want_ids)
        np.testing.assert_array_equal(w, want_w)
        assert x.shape[:3] == (3, E, B)
        assert y.shape[:2] == (3, E)
    with pytest.raises(ValueError):
        next(cohort_batch_stream(clients[:5], pop, 3, B, E))


# ---------------------------------------------------------------------------
# Fault draws: determinism and rates
# ---------------------------------------------------------------------------

def test_fault_draw_determinism_and_codes():
    plan = FaultPlan(dropout=0.2, straggler=0.1, corrupt=0.1,
                     duplicate=0.1, seed=11)
    ids = jnp.arange(64, dtype=jnp.uint32)
    a = np.asarray(draw_faults(plan, 0, ids))
    b = np.asarray(jax.jit(lambda: draw_faults(plan, 0, ids))())
    np.testing.assert_array_equal(a, b)
    assert set(np.unique(a)).issubset({0, 1, 2, 3, 4})
    # a different round or seed reshuffles the outcome
    c = np.asarray(draw_faults(plan, 1, ids))
    d = np.asarray(draw_faults(
        FaultPlan(dropout=0.2, straggler=0.1, corrupt=0.1, duplicate=0.1,
                  seed=12), 0, ids))
    assert (a != c).any() and (a != d).any()
    # zero-rate plan never faults
    clean = np.asarray(draw_faults(FaultPlan(), 0, ids))
    assert (clean == OK).all()
    # empirical rate sanity on a large draw
    big = np.asarray(draw_faults(plan, 7, jnp.arange(20000, dtype=jnp.uint32)))
    assert abs(float(np.mean(big == DROP)) - 0.2) < 0.02


def test_fault_plan_validates_rates():
    with pytest.raises(ValueError):
        FaultPlan(dropout=0.7, straggler=0.4)
    with pytest.raises(ValueError):
        FaultPlan(dropout=-0.1)


# ---------------------------------------------------------------------------
# Weighted aggregation: integer oracle
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", ["mean_f32", "psum_u32", "allgather_packed"])
def test_weighted_sum_matches_integer_oracle(name):
    rng = np.random.RandomState(0)
    n = 203
    Z = rng.randint(0, 2, (K, n)).astype(np.float32)
    w = np.array([3, 1, 0, 7], np.uint32)
    want = np.sum(Z.astype(np.int64) * w[:, None].astype(np.int64), axis=0)
    t = get_transport(name)
    if t.packed_wire:
        # the native operand of the packed transports IS the lanes
        lanes = pack_mask(jnp.asarray(Z))
        counts = np.asarray(t.aggregate_stacked_packed_weighted(
            lanes, n, jnp.asarray(w)))
        np.testing.assert_array_equal(counts, want.astype(np.uint32))
    else:
        got = np.asarray(t.aggregate_stacked_weighted(
            jnp.asarray(Z), jnp.asarray(w)))
        np.testing.assert_array_equal(got, want.astype(np.float32))


def test_packed_weighted_sum_kernel():
    rng = np.random.RandomState(1)
    n = 97
    Z = rng.randint(0, 2, (5, n)).astype(np.float32)
    w = np.array([2, 5, 1, 0, 9], np.uint32)
    counts = np.asarray(packed_weighted_sum(
        pack_mask(jnp.asarray(Z)), n, jnp.asarray(w)))
    np.testing.assert_array_equal(
        counts, np.sum(Z.astype(np.int64) * w[:, None], axis=0))


# ---------------------------------------------------------------------------
# Round-level: weight-1 full participation == legacy path (bitwise)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", ["mean_f32", "psum_u32", "allgather_packed"])
def test_weight_one_full_participation_matches_legacy(setup, name):
    _, zspecs, state, batch = setup
    cfg = _cfg(name)
    key = jax.random.PRNGKey(7)
    st0, m0 = jax.jit(lambda s, b, k: federated_round(
        zspecs, s, mlp_loss, b, k, cfg))(state, batch, key)
    st1, m1 = jax.jit(lambda s, b, k: federated_round(
        zspecs, s, mlp_loss, b, k, cfg,
        client_ids=jnp.arange(K, dtype=jnp.uint32),
        weights=jnp.ones(K, jnp.uint32),
        faults=FaultPlan()))(state, batch, key)
    _assert_state_bits(st0, st1)
    assert np.asarray(m0["loss"]).view(np.uint32) == \
        np.asarray(m1["loss"]).view(np.uint32)
    assert set(m1) == set(ROUND_METRIC_KEYS)
    assert float(m1["num_participating"]) == K
    assert float(m1["round_skipped"]) == 0.0
    assert float(m1["uplink_bytes_round"]) == float(m0["uplink_bytes_round"])


@pytest.mark.parametrize("name", ["mean_f32", "psum_u32", "allgather_packed"])
def test_weight_one_full_participation_matches_legacy_sharded(setup, name):
    _, zspecs, state, batch = setup
    mesh = data_mesh_or_skip()
    cfg = _cfg(name)
    key = jax.random.PRNGKey(7)
    st0, m0 = _sharded_round(mesh, zspecs, state, batch, key, cfg)
    st1, m1 = _sharded_round(
        mesh, zspecs, state, batch, key, cfg,
        ids=np.arange(K), weights=np.ones(K, np.uint32), faults=FaultPlan())
    _assert_state_bits(st0, st1)
    assert np.asarray(m0["loss"]).view(np.uint32) == \
        np.asarray(m1["loss"]).view(np.uint32)
    assert float(m1["weight_sum"]) == K


# ---------------------------------------------------------------------------
# Faulted rounds: vmap/shard_map parity, survivor replay, skip, bytes
# ---------------------------------------------------------------------------

PLAN = FaultPlan(dropout=0.3, straggler=0.1, corrupt=0.2, duplicate=0.1,
                 seed=5)


@pytest.mark.parametrize("name", ["psum_u32", "mean_f32"])
def test_faulted_round_vmap_sharded_bit_identical(setup, name):
    _, zspecs, state, batch = setup
    mesh = data_mesh_or_skip()
    cfg = _cfg(name)
    key = jax.random.PRNGKey(7)
    w = np.array([5, 2, 9, 1], np.uint32)
    stv, mv = jax.jit(lambda s, b, k: federated_round(
        zspecs, s, mlp_loss, b, k, cfg,
        client_ids=jnp.arange(K, dtype=jnp.uint32),
        weights=jnp.asarray(w), faults=PLAN))(state, batch, key)
    sts, ms = _sharded_round(mesh, zspecs, state, batch, key, cfg,
                             ids=np.arange(K), weights=w, faults=PLAN)
    _assert_cross_driver(stv, sts)
    assert np.asarray(mv["loss"]).view(np.uint32) == \
        np.asarray(ms["loss"]).view(np.uint32)
    for mk in PARTICIPATION_METRIC_KEYS:
        assert float(mv[mk]) == float(ms[mk]), mk
    assert float(mv["num_participating"]) < K, \
        "plan injected no faults at this seed; pick another seed"


def test_faulted_round_equals_survivor_subset_round(setup):
    """Dropping clients is the SAME as never sampling them: a faulted
    full-cohort round reproduces the participation round run on just
    the survivors (draw words key on global client ids)."""
    _, zspecs, state, batch = setup
    plan = FaultPlan(dropout=0.5, seed=21)
    codes = np.asarray(draw_faults(plan, 0, jnp.arange(K, dtype=jnp.uint32)))
    surv = np.flatnonzero(codes == OK)
    assert 1 <= len(surv) < K, "seed 21 must drop some but not all of K=4"
    w = np.array([5, 2, 9, 1], np.uint32)
    cfg = _cfg("psum_u32")
    key = jax.random.PRNGKey(7)
    st_fault, m_fault = jax.jit(lambda s, b, k: federated_round(
        zspecs, s, mlp_loss, b, k, cfg,
        client_ids=jnp.arange(K, dtype=jnp.uint32),
        weights=jnp.asarray(w), faults=plan))(state, batch, key)
    sub = jax.tree.map(lambda x: x[surv], batch)
    st_surv, m_surv = jax.jit(lambda s, b, k: federated_round(
        zspecs, s, mlp_loss, b, k, cfg,
        client_ids=jnp.asarray(surv, jnp.uint32),
        weights=jnp.asarray(w[surv])))(state, sub, key)
    for p in st_fault["scores"]:
        np.testing.assert_array_equal(np.asarray(st_fault["scores"][p]),
                                      np.asarray(st_surv["scores"][p]))
    for p in st_fault["dense"]:
        np.testing.assert_allclose(np.asarray(st_fault["dense"][p]),
                                   np.asarray(st_surv["dense"][p]),
                                   rtol=1e-6, atol=1e-7)
    np.testing.assert_allclose(float(m_fault["loss"]), float(m_surv["loss"]),
                               rtol=1e-6)
    assert float(m_fault["weight_sum"]) == float(w[surv].sum())


def test_skip_round_below_min_clients(setup):
    _, zspecs, state, batch = setup
    plan = FaultPlan(dropout=0.99, seed=2)
    cfg = _cfg("psum_u32", min_clients=3)
    codes = np.asarray(draw_faults(plan, 0, jnp.arange(K, dtype=jnp.uint32)))
    assert int(np.sum(codes == OK)) < 3
    st, m = jax.jit(lambda s, b, k: federated_round(
        zspecs, s, mlp_loss, b, k, cfg, faults=plan))(state, batch,
                                                      jax.random.PRNGKey(7))
    assert float(m["round_skipped"]) == 1.0
    _assert_state_bits(state, st)


def test_duplicate_uploads_dedup_but_double_bytes(setup):
    _, zspecs, state, batch = setup
    plan = FaultPlan(duplicate=1.0, seed=0)
    cfg = _cfg("psum_u32")
    key = jax.random.PRNGKey(7)
    st0, m0 = jax.jit(lambda s, b, k: federated_round(
        zspecs, s, mlp_loss, b, k, cfg))(state, batch, key)
    st1, m1 = jax.jit(lambda s, b, k: federated_round(
        zspecs, s, mlp_loss, b, k, cfg, faults=plan))(state, batch, key)
    # dedup: the aggregate counts every client once -> bit-identical
    _assert_state_bits(st0, st1)
    assert float(m1["num_duplicates"]) == K
    assert float(m1["num_participating"]) == K
    # ... but the duplicated uploads were still paid for on the wire
    assert float(m1["uplink_bytes_round"]) == \
        2.0 * float(m0["uplink_bytes_round"])


def test_all_corrupt_round_is_excluded_and_skipped(setup):
    _, zspecs, state, batch = setup
    plan = FaultPlan(corrupt=1.0, seed=0)
    cfg = _cfg("psum_u32")
    st, m = jax.jit(lambda s, b, k: federated_round(
        zspecs, s, mlp_loss, b, k, cfg, faults=plan))(state, batch,
                                                      jax.random.PRNGKey(7))
    assert float(m["num_corrupt"]) == K
    assert float(m["num_participating"]) == 0.0
    assert float(m["round_skipped"]) == 1.0
    _assert_state_bits(state, st)
    # corrupt bytes still crossed the wire before validation rejected them
    assert float(m["uplink_bytes_round"]) > 0.0


# ---------------------------------------------------------------------------
# Upload validation primitives
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("packed", [True, False])
def test_validation_detects_injected_corruption(setup, packed):
    _, zspecs, _, _ = setup
    rng = np.random.RandomState(0)
    plan = FaultPlan(corrupt=0.5, seed=13)
    z_all = {}
    for path, spec in zspecs.specs.items():
        z = rng.randint(0, 2, (K, spec.n)).astype(np.float32)
        z_all[path] = pack_mask(jnp.asarray(z)) if packed else jnp.asarray(z)
    declared = upload_counts(z_all, zspecs, packed=packed)
    clean_ok = np.asarray(validate_uploads(z_all, declared, zspecs,
                                           packed=packed))
    assert clean_ok.all(), "clean uploads must validate"
    mask = jnp.asarray(np.array([1, 0, 1, 0], bool))
    bad = corrupt_uploads(plan, z_all, declared, mask, 0,
                          jnp.arange(K, dtype=jnp.uint32), zspecs,
                          packed=packed)
    ok = np.asarray(validate_uploads(bad, declared, zspecs, packed=packed))
    np.testing.assert_array_equal(ok, ~np.asarray(mask))


# ---------------------------------------------------------------------------
# Scan drivers thread participation end-to-end
# ---------------------------------------------------------------------------

def test_fit_threads_participation(setup):
    """federated_fit with (R, K) id/weight slabs == R sequential
    participation rounds, faults and all."""
    _, zspecs, state, batch = setup
    R = 3
    pop = ClientPopulation(12, sample_counts=tuple(range(1, 13)), seed=6)
    ids = np.stack([pop.cohort_np(r, K)[0] for r in range(R)])
    w = np.stack([pop.cohort_np(r, K)[1] for r in range(R)])
    batches = jax.tree.map(
        lambda x: jnp.broadcast_to(x, (R,) + x.shape), batch)
    cfg = _cfg("psum_u32")
    key = jax.random.PRNGKey(9)
    st_fit, mets = jax.jit(lambda s, b, k: federated_fit(
        zspecs, s, mlp_loss, b, k, cfg,
        client_ids=jnp.asarray(ids), weights=jnp.asarray(w),
        faults=PLAN))(state, batches, key)
    assert mets["round_skipped"].shape == (R,)
    st_seq = state
    for r, sub in enumerate(jax.random.split(key, R)):
        st_seq, m = jax.jit(lambda s, b, k, r=r: federated_round(
            zspecs, s, mlp_loss, b, k, cfg, round_index=jnp.uint32(r),
            client_ids=jnp.asarray(ids[r]), weights=jnp.asarray(w[r]),
            faults=PLAN))(st_seq, batch, sub)
        assert float(m["num_participating"]) == float(
            mets["num_participating"][r])
    _assert_state_bits(st_fit, st_seq)


def test_sharded_fit_threads_participation(setup):
    _, zspecs, state, batch = setup
    mesh = data_mesh_or_skip()
    R = 2
    ids = np.broadcast_to(np.arange(K, dtype=np.uint32), (R, K)).copy()
    w = np.broadcast_to(np.array([5, 2, 9, 1], np.uint32), (R, K)).copy()
    batches = jax.tree.map(
        lambda x: jnp.broadcast_to(x, (R,) + x.shape), batch)
    cfg = _cfg("psum_u32")
    key = jax.random.PRNGKey(9)
    st_v, m_v = jax.jit(lambda s, b, k: federated_fit(
        zspecs, s, mlp_loss, b, k, cfg, client_ids=jnp.asarray(ids),
        weights=jnp.asarray(w), faults=PLAN))(state, batches, key)
    state_specs = jax.tree.map(lambda _: P(), state)
    met_specs = {mk: P() for mk in m_v}

    def body(s, b, k, i, ww):
        b = jax.tree.map(lambda x: x[:, 0], b)
        return sharded_client_fit(zspecs, s, mlp_loss, b, k, cfg,
                                  client_ids=i[:, 0], weights=ww[:, 0],
                                  faults=PLAN)

    with mesh:
        f = shard_map_compat(
            body, ("data",),
            (state_specs, P(None, "data"), P(), P(None, "data"),
             P(None, "data")),
            (state_specs, met_specs))
        st_s, m_s = jax.jit(f)(state, batches, key, jnp.asarray(ids),
                               jnp.asarray(w))
    _assert_cross_driver(st_v, st_s)
    np.testing.assert_array_equal(np.asarray(m_v["num_participating"]),
                                  np.asarray(m_s["num_participating"]))
