"""Hot-block cache + continuous batching: the PR-9 serving surface.

The load-bearing claims, each pinned bitwise (no tolerances):

 - ``serve_cached_matmul`` equals ``serve_matmul`` at EVERY cache
   occupancy (empty, partial, full, garbage-poisoned free pool rows)
   for all three downlink codecs — a hit only changes WHERE a block's
   values come from;
 - ``serve_fill_tiles`` writes exactly the values the streaming miss
   branch regenerates (cross-checked against the reconstructed leaf);
 - the batched lane path equals the single-request PR-8 path at
   matched KV capacity, per lane, including lane recycling and a
   round delta landing MID-GENERATION on a live scheduler;
 - a delta invalidates exactly the flipped-drawn-bit tiles: every
   retained pool row is bit-identical to a fresh round-t+1 rebuild,
   and a 1%-moved converged round retains >= 90% of the cache.
"""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import (
    DOWNLINK_KEY,
    checkpoint_downlink,
    load_checkpoint,
    save_checkpoint,
)
from repro.comm.downlink import get_codec
from repro.comm.metering import serve_resident_bytes, serve_tile_pool_bytes
from repro.core import ZamplingConfig, build_specs, init_state
from repro.core.qspec import make_qspec
from repro.core.sampling import as_word
from repro.kernels import ops
from repro.serve import (
    HotBlockCache,
    ServeConfig,
    ServeScheduler,
    apply_delta,
    build_cache,
    build_serve_engine,
    delta_flipped_windows,
    make_delta,
    make_serve_state,
    serve_generate,
)

CODECS = ("f32", "u16", "u8")


def _scores(n, seed=0):
    return jnp.asarray(np.random.RandomState(seed).rand(n).astype(np.float32))


def _words(codec_name, spec, scores):
    """(operand, qbits) the serve ops take for this codec."""
    c = get_codec(codec_name)
    if c.quantized:
        return c.encode(spec, scores, as_word(3)), c.bits
    return scores, None


def _full_slots(spec, bm=ops.SERVE_BM):
    """Slot maps covering every canonical block: {g: (nblk,) i32}
    plus the total tile count, slots assigned in canonical order."""
    groups, d_in, d_out = ops.serve_group_dims(spec)
    sub = d_in * d_out
    slot_rows, k = [], 0
    for g in range(groups):
        _, nblk, _ = ops.serve_block_grid(spec, bm, g * sub, sub)
        slot_rows.append(np.arange(k, k + nblk, dtype=np.int32))
        k += nblk
    return slot_rows, k


class TestCachedKernels:
    """ops-level: the cached contraction against the streaming oracle."""

    @pytest.mark.parametrize("codec", CODECS)
    def test_cached_matmul_every_occupancy(self, codec):
        spec = make_qspec(11, (24, 40), 24, compression=4.0, d=4, window=64)
        scores = _scores(spec.n, seed=3)
        words, qbits = _words(codec, spec, scores)
        step = as_word(2)
        groups, d_in, _ = ops.serve_group_dims(spec)
        X = jnp.asarray(
            np.random.RandomState(1).randn(3, d_in).astype(np.float32))
        slot_rows, total = _full_slots(spec)
        for g in range(groups):
            gs = jnp.full((len(slot_rows[g]),), g, jnp.int32)
            ts = jnp.arange(len(slot_rows[g]), dtype=jnp.int32)
            tiles = ops.serve_fill_tiles(spec, words, step, gs, ts,
                                         qbits=qbits)
            ref = ops.serve_matmul(spec, words, step, X, group=g,
                                   qbits=qbits)
            # empty: all-miss, pool rows are GARBAGE and must not leak
            poison = jnp.full((total, ops.SERVE_BM), jnp.nan, jnp.float32)
            empty = jnp.full((len(slot_rows[g]),), -1, jnp.int32)
            out = ops.serve_cached_matmul(spec, words, step, X, poison,
                                          empty, group=g, qbits=qbits)
            assert (np.asarray(out) == np.asarray(ref)).all(), (codec, g)
            # full: all-hit from the filled pool
            pool = poison.at[jnp.asarray(slot_rows[g])].set(tiles)
            full = jnp.asarray(slot_rows[g])
            out = ops.serve_cached_matmul(spec, words, step, X, pool,
                                          full, group=g, qbits=qbits)
            assert (np.asarray(out) == np.asarray(ref)).all(), (codec, g)
            # partial: every other block hits, the rest stream
            half = np.asarray(slot_rows[g]).copy()
            half[::2] = -1
            out = ops.serve_cached_matmul(spec, words, step, X, pool,
                                          jnp.asarray(half), group=g,
                                          qbits=qbits)
            assert (np.asarray(out) == np.asarray(ref)).all(), (codec, g)

    @pytest.mark.parametrize("codec", CODECS)
    def test_fill_tiles_match_reconstructed_leaf(self, codec):
        """Pool rows scattered back along the canonical grid reproduce
        the reconstructed leaf exactly (dead lanes exact +0.0)."""
        spec = make_qspec(12, (40, 24), 40, compression=4.0, d=4, window=64)
        scores = _scores(spec.n, seed=4)
        words, qbits = _words(codec, spec, scores)
        step = as_word(2)
        W = np.asarray(ops.sample_reconstruct(
            spec, words if qbits is not None else scores, step,
            qbits=qbits)).reshape(-1)
        groups, d_in, d_out = ops.serve_group_dims(spec)
        sub = d_in * d_out
        rpw = spec.rows_per_window
        bm = ops.SERVE_BM
        bpw = max(1, -(-rpw // bm))
        for g in range(groups):
            w0, nblk, _ = ops.serve_block_grid(spec, bm, g * sub, sub)
            ts = np.arange(nblk)
            tiles = np.asarray(ops.serve_fill_tiles(
                spec, words, step,
                jnp.full((nblk,), g, jnp.int32),
                jnp.asarray(ts, jnp.int32), qbits=qbits))
            for t in ts:
                bstart = (w0 + t // bpw) * rpw + (t % bpw) * bm
                rows = bstart + np.arange(bm)
                live = ((rows >= g * sub) & (rows < (g + 1) * sub)
                        & ((t % bpw) * bm + np.arange(bm) < rpw)
                        & (rows < spec.m))
                want = np.where(live, W[np.minimum(rows, spec.m - 1)], 0.0)
                got = tiles[t]
                assert (got == want.astype(np.float32)).all(), (codec, g, t)
                assert not got[~live].any(), "dead lanes must be +0.0"

    def test_cached_matmul_validates(self):
        spec = make_qspec(11, (24, 40), 24, compression=4.0, d=4, window=64)
        words, qbits = _words("u8", spec, _scores(spec.n))
        pool = jnp.zeros((1, ops.SERVE_BM), jnp.float32)
        _, nblk, _ = ops.serve_block_grid(spec, ops.SERVE_BM, 0, spec.m)
        slots = jnp.full((nblk,), -1, jnp.int32)
        with pytest.raises(ValueError):
            ops.serve_cached_matmul(spec, words, as_word(2),
                                    jnp.zeros((24,)), pool, slots,
                                    qbits=qbits)
        with pytest.raises(ValueError):
            ops.serve_cached_matmul(spec, words, as_word(2),
                                    jnp.zeros((1, 24)), pool, slots,
                                    group=7, qbits=qbits)
        with pytest.raises(ValueError):
            ops.serve_fill_tiles(spec, words, as_word(2),
                                 jnp.zeros((2,), jnp.int32),
                                 jnp.zeros((3,), jnp.int32), qbits=qbits)


@pytest.fixture(scope="module")
def served():
    from repro.configs.registry import get_arch
    from repro.models import build_model

    cfg = get_arch("qwen2-0.5b").reduced()
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    # window=128: fine-grained tiles so drawn-bit invalidation has
    # headroom (the retention gate below) while staying CPU-fast
    zspecs = build_specs(params, ZamplingConfig(compression=4, d=4,
                                                window=128))
    state = init_state(jax.random.PRNGKey(1), zspecs, dense_init=params)
    return model, zspecs, state


def _perturbed(state, frac=0.01, amp=0.02, seed=7):
    """Round t+1: a converged-round score update touching ``frac``."""
    key = jax.random.PRNGKey(seed)
    scores2 = {}
    for p, s in state["scores"].items():
        k1, k2, key = jax.random.split(key, 3)
        touch = jax.random.bernoulli(k1, frac, s.shape)
        scores2[p] = jnp.where(touch,
                               s + amp * jax.random.normal(k2, s.shape), s)
    return {"scores": scores2, "dense": state["dense"]}


class TestHotBlockCache:
    def test_budget_dial_endpoints(self, served):
        _, zspecs, state = served
        ss = make_serve_state(zspecs, state, jax.random.PRNGKey(2),
                              downlink="u8")
        # budget 0: pure streaming — nothing resident, all misses
        c0 = build_cache(ss, ServeConfig(cache_budget_bytes=0))
        assert c0.capacity == 0 and c0.resident_tiles == 0
        assert c0.fill(ss) == 0
        c0.record_step(3)
        assert c0.counters["hits"] == 0
        assert c0.counters["misses"] == 3 * c0.total_tiles
        # budget >= model: capacity caps at one row per canonical tile
        cf = build_cache(ss, ServeConfig(cache_budget_bytes=1 << 30))
        assert cf.capacity == cf.total_tiles
        assert cf.resident_tiles == cf.total_tiles
        assert cf.used_bytes == cf.capacity_bytes
        cf.record_step()
        assert cf.counters["hits"] == cf.total_tiles
        assert cf.counters["misses"] == 0
        # partial budget buys exactly budget // tile_bytes rows
        budget = 17 * cf.tile_bytes + 5
        cp = build_cache(ss, ServeConfig(cache_budget_bytes=budget))
        assert cp.capacity == 17 and cp.resident_tiles == 17

    def test_pool_bytes_meter_matches_cache(self, served):
        _, zspecs, state = served
        ss = make_serve_state(zspecs, state, jax.random.PRNGKey(2),
                              downlink="u8")
        for budget in (0, 12345, 1 << 20, 1 << 30):
            cache = HotBlockCache(ss, budget)
            assert (serve_tile_pool_bytes(zspecs, budget)
                    == cache.capacity_bytes), budget

    def test_clock_eviction_second_chance(self, served):
        _, zspecs, state = served
        ss = make_serve_state(zspecs, state, jax.random.PRNGKey(2),
                              downlink="u8")
        cache = HotBlockCache(ss, 8 * 4 * ops.SERVE_BM)
        assert cache.fill(ss) == 8
        # default fill never evicts: the pool is full, nothing happens
        assert cache.fill(ss) == 0
        assert cache.counters["evictions"] == 0
        # evict=True admits new tiles through the clock (ref bits are
        # set from the fill, so the hand sweeps once to clear them)
        n = cache.fill(ss, limit=3, evict=True)
        assert n == 3
        assert cache.counters["evictions"] == 3
        assert cache.resident_tiles == 8  # still at capacity

    def test_serve_config_validates(self):
        with pytest.raises(ValueError):
            ServeConfig(lanes=0)
        with pytest.raises(ValueError):
            ServeConfig(cache_budget_bytes=-1)
        with pytest.raises(ValueError):
            ServeConfig(mode="turbo")
        with pytest.raises(ValueError):
            ServeConfig(max_new_tokens=0)


class TestCachedEngine:
    @pytest.mark.parametrize("codec", CODECS)
    def test_three_modes_bit_identical_across_budgets(self, served, codec):
        model, zspecs, state = served
        ss = make_serve_state(zspecs, state, jax.random.PRNGKey(2),
                              downlink=codec)
        prompt = jnp.asarray([[1, 2, 3, 4]], jnp.int32)
        o_s = serve_generate(model, ss, prompt, 3, mode="streaming",
                             seq_len=16)
        o_l = serve_generate(model, ss, prompt, 3, mode="load", seq_len=16)
        assert (np.asarray(o_s) == np.asarray(o_l)).all()
        full = HotBlockCache(ss, 1 << 30)
        part = HotBlockCache(ss, full.capacity_bytes // 3)
        for cache in (HotBlockCache(ss, 0), part, full):
            cache.fill(ss)
            o_c = serve_generate(model, ss, prompt, 3, mode="cached",
                                 seq_len=16, cache=cache)
            assert (np.asarray(o_c) == np.asarray(o_s)).all(), (
                codec, cache.resident_tiles)

    def test_cached_engine_requires_cache(self, served):
        model, zspecs, state = served
        ss = make_serve_state(zspecs, state, jax.random.PRNGKey(2),
                              downlink="u8")
        engine = build_serve_engine(model, ss, mode="cached")
        with pytest.raises(ValueError):
            engine.arrays_of(ss)


class TestScheduler:
    RAGGED = ([5, 17, 42, 7], [1, 2, 3], [9, 9, 1, 0, 3], [4, 4])

    def _single(self, model, ss, prompt, new, seq_len, mode="streaming",
                cache=None):
        out = serve_generate(model, ss, jnp.asarray([prompt], jnp.int32),
                             new, mode=mode, seq_len=seq_len, cache=cache)
        return np.asarray(out)[0, len(prompt):]

    @pytest.mark.parametrize("mode", ["streaming", "cached"])
    def test_batched_equals_single_request(self, served, mode):
        model, zspecs, state = served
        ss = make_serve_state(zspecs, state, jax.random.PRNGKey(2),
                              downlink="u8")
        new = 4
        seq_len = max(len(p) for p in self.RAGGED) + new
        cfg = ServeConfig(lanes=4, seq_len=seq_len, mode=mode,
                          cache_budget_bytes=1 << 30, max_new_tokens=new)
        sched = ServeScheduler(model, ss, cfg)
        rids = {sched.submit(p): p for p in self.RAGGED}
        results = sched.run()
        for rid, p in rids.items():
            # bit-equality holds at MATCHED KV capacity: softmax reduces
            # over seq_len slots, so the lane and the single request
            # must share it
            want = self._single(model, ss, p, new, seq_len, mode=mode,
                                cache=sched.cache)
            assert (results[rid] == want).all(), p

    def test_lane_recycling_bitwise(self, served):
        """More requests than lanes: retired lanes re-admit from the
        queue; recycled-lane outputs still equal single-request."""
        model, zspecs, state = served
        ss = make_serve_state(zspecs, state, jax.random.PRNGKey(2),
                              downlink="u8")
        prompts = list(self.RAGGED) + [[8, 3, 1], [2, 7]]
        new, seq_len = 3, 8
        cfg = ServeConfig(lanes=2, seq_len=seq_len, mode="streaming",
                          max_new_tokens=new)
        sched = ServeScheduler(model, ss, cfg)
        rids = {sched.submit(p): p for p in prompts}
        results = sched.run()
        assert len(results) == len(prompts)
        for rid, p in rids.items():
            want = self._single(model, ss, p, new, seq_len)
            assert (results[rid] == want).all(), p

    @pytest.mark.parametrize("mode", ["streaming", "cached"])
    def test_hot_swap_mid_generation_per_lane(self, served, mode):
        """Satellite (c): a round delta lands mid-flight on a batched
        scheduler; every lane matches the single-request PR-8 swap at
        the same per-request step boundary, twice (determinism)."""
        model, zspecs, state = served
        ss1 = make_serve_state(zspecs, state, jax.random.PRNGKey(2),
                               downlink="u8", dither_word=0)
        ss2 = make_serve_state(zspecs, _perturbed(state),
                               jax.random.PRNGKey(2),
                               downlink="u8", dither_word=0)
        delta = make_delta(ss1, ss2)
        new = 4
        seq_len = max(len(p) for p in self.RAGGED) + new
        swap_at = 3  # engine steps under round t before the broadcast

        def batched():
            cfg = ServeConfig(lanes=4, seq_len=seq_len, mode=mode,
                              cache_budget_bytes=1 << 30,
                              max_new_tokens=new)
            sched = ServeScheduler(model, ss1, cfg)
            rids = {sched.submit(p): p for p in self.RAGGED}
            for _ in range(swap_at):
                sched.step_once()
            sched.apply_round_delta(delta)
            return {tuple(rids[r]): v for r, v in sched.run().items()}

        def single(prompt):
            # the PR-8 scalar path, swapping arrays after swap_at steps
            engine = build_serve_engine(model, ss1, mode="streaming")
            step = jax.jit(engine.step)
            arrays = [engine.arrays_of(ss1),
                      engine.arrays_of(apply_delta(ss1, delta))]
            kv = engine.init_cache(1, seq_len)
            toks, logits, n = [], None, 0
            while len(toks) < new:
                if n < len(prompt):
                    tok = jnp.asarray([[prompt[n]]], jnp.int32)
                else:
                    tok = jnp.asarray([[toks[-1]]], jnp.int32)
                logits, kv = step(arrays[n >= swap_at], kv, tok)
                n += 1
                if n >= len(prompt):
                    toks.append(int(np.argmax(np.asarray(logits)[0, 0])))
            return np.asarray(toks, np.int32)

        got = batched()
        again = batched()
        for p in self.RAGGED:
            want = single(list(p))
            assert (got[tuple(p)] == want).all(), p
            assert (again[tuple(p)] == got[tuple(p)]).all(), p

    def test_submit_overflow_rejected(self, served):
        model, zspecs, state = served
        ss = make_serve_state(zspecs, state, jax.random.PRNGKey(2),
                              downlink="u8")
        sched = ServeScheduler(model, ss, ServeConfig(
            lanes=1, seq_len=6, mode="streaming", max_new_tokens=4))
        with pytest.raises(ValueError):
            sched.submit([1, 2, 3])  # 3 + 4 > 6


class TestDeltaInvalidation:
    def test_flip_map_requires_pinned_draw(self, served):
        _, zspecs, state = served
        s1 = make_serve_state(zspecs, state, jax.random.PRNGKey(2),
                              downlink="u8", dither_word=0)
        s2 = make_serve_state(zspecs, _perturbed(state),
                              jax.random.PRNGKey(3),
                              downlink="u8", dither_word=0)
        delta = make_delta(s1, s2)
        with pytest.raises(ValueError):
            delta_flipped_windows(s1, delta)
        # apply_delta with a changed draw word drops the whole cache
        cache = build_cache(s1, ServeConfig(cache_budget_bytes=1 << 30))
        assert cache.resident_tiles == cache.total_tiles
        apply_delta(s1, delta, cache=cache)
        assert cache.resident_tiles == 0

    @pytest.mark.parametrize("codec", CODECS)
    def test_retained_tiles_equal_fresh_rebuild(self, served, codec):
        """The invalidation-exactness pin: after the swap, every tile
        still resident is bit-identical to filling it fresh from the
        NEW words — the cache needs no rebuild."""
        _, zspecs, state = served
        s1 = make_serve_state(zspecs, state, jax.random.PRNGKey(2),
                              downlink=codec, dither_word=0)
        s2 = make_serve_state(zspecs, _perturbed(state),
                              jax.random.PRNGKey(2),
                              downlink=codec, dither_word=0)
        cache = build_cache(s1, ServeConfig(cache_budget_bytes=1 << 30))
        new_state = apply_delta(s1, make_delta(s1, s2), cache=cache)
        assert 0 < cache.resident_tiles < cache.total_tiles
        pool = np.asarray(cache.arrays()["pool"])
        for path, slots in cache.slots.items():
            grid = cache.grids[path]
            g_idx, t_idx = np.nonzero(slots >= 0)
            if not g_idx.size:
                continue
            fresh = np.asarray(ops.serve_fill_tiles(
                grid.spec, new_state.words[path], new_state.step,
                jnp.asarray(g_idx, jnp.int32),
                jnp.asarray(t_idx, jnp.int32), qbits=cache.qbits))
            got = pool[slots[g_idx, t_idx]]
            assert (got == fresh).all(), (codec, path)

    def test_post_swap_cached_equals_streaming(self, served):
        model, zspecs, state = served
        s1 = make_serve_state(zspecs, state, jax.random.PRNGKey(2),
                              downlink="u8", dither_word=0)
        s2 = make_serve_state(zspecs, _perturbed(state),
                              jax.random.PRNGKey(2),
                              downlink="u8", dither_word=0)
        cache = build_cache(s1, ServeConfig(cache_budget_bytes=1 << 30))
        swapped = apply_delta(s1, make_delta(s1, s2), cache=cache)
        cache.fill(swapped)  # re-materialize the freed slots
        assert cache.resident_tiles == cache.total_tiles
        prompt = jnp.asarray([[1, 2, 3]], jnp.int32)
        o_c = serve_generate(model, swapped, prompt, 3, mode="cached",
                             seq_len=8, cache=cache)
        o_s = serve_generate(model, swapped, prompt, 3, mode="streaming",
                             seq_len=8)
        assert (np.asarray(o_c) == np.asarray(o_s)).all()

    def test_converged_round_retention(self, served):
        """The CI gate's claim at test scale: a 1%-moved round under the
        drawn-bit flip map retains >= 90% of the hot-block cache."""
        _, zspecs, state = served
        s1 = make_serve_state(zspecs, state, jax.random.PRNGKey(2),
                              downlink="u8", dither_word=0)
        s2 = make_serve_state(zspecs, _perturbed(state),
                              jax.random.PRNGKey(2),
                              downlink="u8", dither_word=0)
        cache = build_cache(s1, ServeConfig(cache_budget_bytes=1 << 30))
        total = cache.resident_tiles
        apply_delta(s1, make_delta(s1, s2), cache=cache)
        retained = cache.resident_tiles / total
        assert retained >= 0.9, f"retention {retained:.3f} < 0.9"


class TestCodecTagCheckpoint:
    def test_tag_roundtrip_and_routing(self, served, tmp_path):
        _, zspecs, state = served
        ss = make_serve_state(zspecs, state, jax.random.PRNGKey(2),
                              downlink="u8", dither_word=0)
        carry = {"scores": dict(ss.words), "dense": dict(ss.dense)}
        path = os.path.join(tmp_path, "round.npz")
        save_checkpoint(path, carry, {"round": 7}, downlink="u8")
        loaded, meta = load_checkpoint(path, carry)
        tag = checkpoint_downlink(meta)
        assert tag == "u8" and meta[DOWNLINK_KEY] == "u8"
        back = make_serve_state(zspecs, loaded, jax.random.PRNGKey(2),
                                carried=tag)
        assert back.codec == "u8"
        for p in ss.words:
            assert (np.asarray(back.words[p])
                    == np.asarray(ss.words[p])).all(), p

    def test_tag_validation(self, served, tmp_path):
        _, zspecs, state = served
        path = os.path.join(tmp_path, "bad.npz")
        with pytest.raises(ValueError):
            save_checkpoint(path, state, downlink="zstd-9000")
        with pytest.raises(ValueError):
            save_checkpoint(path, state, {DOWNLINK_KEY: "u16"},
                            downlink="u8")
        save_checkpoint(path, state, {DOWNLINK_KEY: "f32"})
        _, meta = load_checkpoint(path, state)
        assert checkpoint_downlink(meta) == "f32"
        assert checkpoint_downlink({}) is None
        with pytest.raises(ValueError):
            checkpoint_downlink({DOWNLINK_KEY: "nope"})
        # the tag refuses leaves that cannot carry it: f32 scores are
        # not u8 wire words, dtype sniffing be damned
        with pytest.raises(ValueError):
            make_serve_state(zspecs, state, jax.random.PRNGKey(2),
                             carried="u8")


class TestResidentAccounting:
    def test_serve_resident_bytes_modes(self, served):
        model, zspecs, state = served
        ss = make_serve_state(zspecs, state, jax.random.PRNGKey(2),
                              downlink="u8")
        budget = 1 << 20
        kv = build_serve_engine(model, ss,
                                mode="streaming").init_cache(1, 16)
        kv_bytes = sum(int(jnp.asarray(x).nbytes)
                       for x in jax.tree_util.tree_leaves(kv))
        r_s = serve_resident_bytes(ss, mode="streaming", kv_cache=kv)
        r_l = serve_resident_bytes(ss, mode="load")
        r_c = serve_resident_bytes(ss, budget, mode="cached")
        assert r_s["zampled_bytes"] == ss.resident_zampled_bytes()
        assert r_s["kv_bytes"] == kv_bytes
        assert r_l["zampled_bytes"] == ss.loaded_zampled_bytes()
        assert r_l["cache_bytes"] == 0 and r_l["kv_bytes"] == 0
        assert r_c["cache_bytes"] == serve_tile_pool_bytes(zspecs, budget)
        for r in (r_s, r_l, r_c):
            assert r["total_bytes"] == (r["zampled_bytes"]
                                        + r["cache_bytes"] + r["kv_bytes"]
                                        + r["dense_bytes"])
        # the dial's endpoints: cached at full budget holds words+pool,
        # strictly between streaming and load+words
        r_f = serve_resident_bytes(ss, 1 << 30, mode="cached")
        assert r_s["total_bytes"] < r_f["total_bytes"]
        with pytest.raises(ValueError):
            serve_resident_bytes(ss, mode="resident")
