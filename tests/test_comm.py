"""Wire-format transport layer (repro.comm).

 - bitpack edge cases (n % 32 != 0, n < 32, all-ones/all-zeros) and
   batched (K, n) pack/unpack;
 - the three transports bit-IDENTICAL (exact equality, not allclose)
   on the stacked vmap path, on a full ``federated_round``, and on the
   collective ``shard_map`` path over the forced 4-device CPU mesh;
 - the psum(pack) ≡ pack-side popcount sum ≡ f32 psum property on the
   mesh;
 - exact wire accounting: packed uplink ≤ 1/32 of f32 + lane padding;
 - ``FederatedConfig.aggregate`` validation at construction;
 - the ``REPRO_BATCH_MAP_THRESHOLD`` env override (satellite).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:  # vendored fallback: fixed-seed examples, no shrinking
    from _hypothesis_fallback import given, settings
    from _hypothesis_fallback import strategies as st

from jax.sharding import PartitionSpec as P

from _helpers import data_mesh_or_skip, round_metric_specs

from repro.comm.bitpack import (
    pack_mask,
    packed_len,
    packed_popcount_sum,
    unpack_mask,
)
from repro.comm.metering import mask_uplink_bytes, round_wire_report, wire_table
from repro.comm.protocol import get_transport, resolve_transport, transport_names
from repro.comm.shardmap import shard_map_compat
from repro.core import FederatedConfig, ZamplingConfig, build_specs, init_state
from repro.core.federated import WIRE_METRIC_KEYS, federated_round, sharded_client_update
from repro.data import client_batch_stream, iid_client_split, make_teacher_dataset
from repro.models.mlp import SMALL_DIMS, init_mlp_params, mlp_loss

STRATEGIES = ("mean_f32", "psum_u32", "allgather_packed")


def _binary(shape, seed=0, p=0.5):
    return (np.random.RandomState(seed).rand(*shape) < p).astype(np.float32)


# ---------------------------------------------------------------------------
# bitpack
# ---------------------------------------------------------------------------

class TestBitpackEdges:
    @pytest.mark.parametrize("n", [1, 5, 31, 32, 33, 63, 700])
    def test_roundtrip_odd_sizes(self, n):
        z = _binary((n,), seed=n)
        packed = pack_mask(jnp.asarray(z))
        assert packed.shape == (packed_len(n),)
        assert packed.dtype == jnp.uint32
        np.testing.assert_array_equal(np.asarray(unpack_mask(packed, n)), z)

    @pytest.mark.parametrize("n", [5, 32, 70])
    @pytest.mark.parametrize("fill", [0.0, 1.0])
    def test_all_ones_all_zeros(self, n, fill):
        z = np.full((n,), fill, np.float32)
        packed = pack_mask(jnp.asarray(z))
        np.testing.assert_array_equal(np.asarray(unpack_mask(packed, n)), z)
        counts = packed_popcount_sum(packed[None], n)
        np.testing.assert_array_equal(np.asarray(counts),
                                      z.astype(np.uint32))

    @pytest.mark.parametrize("k", [1, 3])
    @pytest.mark.parametrize("n", [5, 33, 256])
    def test_batched_pack_unpack(self, k, n):
        Z = _binary((k, n), seed=n + k)
        packed = pack_mask(jnp.asarray(Z))
        assert packed.shape == (k, packed_len(n))
        np.testing.assert_array_equal(np.asarray(unpack_mask(packed, n)), Z)
        np.testing.assert_array_equal(
            np.asarray(packed_popcount_sum(packed, n)),
            Z.sum(0).astype(np.uint32),
        )

    def test_pack_composes_with_vmap(self):
        Z = _binary((4, 70), seed=2)
        a = np.asarray(jax.vmap(pack_mask)(jnp.asarray(Z)))
        b = np.asarray(pack_mask(jnp.asarray(Z)))
        np.testing.assert_array_equal(a, b)

    @settings(max_examples=12, deadline=None)
    @given(n=st.integers(1, 700), seed=st.integers(0, 10_000))
    def test_popcount_equals_f32_sum(self, n, seed):
        Z = _binary((5, n), seed=seed)
        counts = packed_popcount_sum(pack_mask(jnp.asarray(Z)), n)
        np.testing.assert_array_equal(
            np.asarray(counts).astype(np.float32), Z.sum(0)
        )


# ---------------------------------------------------------------------------
# transports: stacked path
# ---------------------------------------------------------------------------

class TestTransportsStacked:
    @pytest.mark.parametrize("n", [31, 32, 777])
    def test_bit_identical_across_strategies(self, n):
        Z = jnp.asarray(_binary((10, n), seed=n, p=0.3))
        outs = {s: np.asarray(get_transport(s).aggregate_stacked(Z))
                for s in STRATEGIES}
        for s in STRATEGIES[1:]:
            np.testing.assert_array_equal(outs["mean_f32"], outs[s])
        np.testing.assert_array_equal(outs["mean_f32"],
                                      np.asarray(Z).sum(0) / 10)

    def test_mean_alias(self):
        assert get_transport("mean") is get_transport("mean_f32")
        assert resolve_transport("psum_u32", "continuous").name == "mean_f32"

    def test_unknown_transport_raises(self):
        with pytest.raises(ValueError, match="registered"):
            get_transport("nope")


# ---------------------------------------------------------------------------
# transports: full federated_round (exact equality of new_scores)
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def fed_setup():
    ds = make_teacher_dataset(n_train=600, n_test=100, seed=0)
    template = init_mlp_params(jax.random.PRNGKey(0), SMALL_DIMS)
    zspecs = build_specs(template, ZamplingConfig(
        compression=2.0, d=5, window=128, min_size=256))
    state = init_state(jax.random.PRNGKey(1), zspecs, dense_init=template)
    K, E = 4, 2
    clients = iid_client_split(ds, K)
    xs, ys = next(client_batch_stream(clients, 32, E, seed=0))
    batch = {"x": jnp.asarray(xs), "y": jnp.asarray(ys)}
    return zspecs, state, batch, K, E


def _round_outputs(fed_setup, aggregate):
    zspecs, state, batch, K, E = fed_setup
    cfg = FederatedConfig(num_clients=K, local_steps=E, local_lr=0.1,
                          aggregate=aggregate)
    return jax.jit(
        lambda s, b, k: federated_round(zspecs, s, mlp_loss, b, k, cfg)
    )(state, batch, jax.random.PRNGKey(0))


def test_round_scores_bit_identical(fed_setup):
    base, base_met = _round_outputs(fed_setup, "mean_f32")
    for s in ("psum_u32", "allgather_packed", "mean"):
        got, _ = _round_outputs(fed_setup, s)
        for p in base["scores"]:
            np.testing.assert_array_equal(
                np.asarray(base["scores"][p]), np.asarray(got["scores"][p]),
                err_msg=f"{s} differs from mean_f32 at {p}",
            )
    assert np.isfinite(float(base_met["loss"]))


def test_round_metrics_report_wire_bytes(fed_setup):
    zspecs, state, batch, K, E = fed_setup
    _, met_f32 = _round_outputs(fed_setup, "mean_f32")
    _, met_packed = _round_outputs(fed_setup, "psum_u32")
    for k in WIRE_METRIC_KEYS:
        assert k in met_f32 and k in met_packed
    # the packed mask traffic is 1/32 of f32 + at most one lane/tensor
    mask_f32 = sum(4 * s.n for s in zspecs.specs.values())
    mask_packed = sum(4 * packed_len(s.n) for s in zspecs.specs.values())
    dense = float(met_f32["uplink_bytes_per_client"]) - mask_f32
    assert float(met_packed["uplink_bytes_per_client"]) == mask_packed + dense
    assert mask_packed <= mask_f32 / 32 + 4 * len(zspecs.specs)
    assert float(met_packed["uplink_bytes_round"]) == K * (
        mask_packed + dense
    )


def test_wire_accounting_ratio():
    """uplink(packed) ≤ 1/32 of f32 + lane padding, exactly metered."""
    for n in (31, 32, 1000, 12345):
        f32_b = mask_uplink_bytes(get_transport("mean_f32"), n)
        for s in ("psum_u32", "allgather_packed"):
            b = mask_uplink_bytes(get_transport(s), n)
            assert b == 4 * packed_len(n)
            assert b <= f32_b / 32 + 4


def test_wire_table_rows(fed_setup):
    zspecs, _, _, K, _ = fed_setup
    rows = wire_table(zspecs, K)
    assert {r["strategy"] for r in rows} == set(STRATEGIES)
    by = {r["strategy"]: r for r in rows}
    assert by["mean_f32"]["uplink_vs_f32"] == 1.0
    assert by["psum_u32"]["uplink_bytes_per_client"] < by["mean_f32"][
        "uplink_bytes_per_client"
    ]
    rep = round_wire_report(zspecs, "mean", K)
    assert rep["transport"] == "mean_f32"  # alias resolves in metering


# ---------------------------------------------------------------------------
# FederatedConfig validation (satellite)
# ---------------------------------------------------------------------------

class TestConfigValidation:
    def test_unknown_strategy_raises_at_construction(self):
        with pytest.raises(ValueError) as ei:
            FederatedConfig(aggregate="allgather_paked")  # typo
        for name in STRATEGIES:
            assert name in str(ei.value)

    @pytest.mark.parametrize("name", STRATEGIES + ("mean",))
    def test_registered_strategies_accepted(self, name):
        assert FederatedConfig(aggregate=name).aggregate == name


# ---------------------------------------------------------------------------
# collective path: forced 4-device CPU mesh
# ---------------------------------------------------------------------------

def _data_mesh(size=4):
    return data_mesh_or_skip(size)


class TestCollectivePath:
    def test_psum_pack_popcount_f32_all_agree(self):
        """psum of unpacked u32 bits ≡ pack-side popcount of the
        gathered lanes ≡ f32 psum — the property behind psum_u32 and
        allgather_packed being interchangeable."""
        mesh = _data_mesh()
        n = 100  # not a multiple of 32
        Z = jnp.asarray(_binary((4, n), seed=3, p=0.4))

        def body(zl):
            z = zl[0]
            packed = pack_mask(z)
            s_f32 = jax.lax.psum(z.astype(jnp.float32), ("data",))
            s_u32 = jax.lax.psum(
                unpack_mask(packed, n, dtype=jnp.uint32), ("data",)
            )
            lanes = jax.lax.all_gather(packed, ("data",), axis=0)
            s_pop = packed_popcount_sum(lanes, n)
            return s_f32[None], s_u32[None], s_pop[None]

        with mesh:
            f = shard_map_compat(body, ("data",), P("data", None),
                                 (P(None, None),) * 3)
            s_f32, s_u32, s_pop = jax.jit(f)(Z)
        want = np.asarray(Z).sum(0)
        np.testing.assert_array_equal(np.asarray(s_f32)[0], want)
        np.testing.assert_array_equal(
            np.asarray(s_u32)[0].astype(np.float32), want
        )
        np.testing.assert_array_equal(
            np.asarray(s_pop)[0].astype(np.float32), want
        )

    def test_collective_aggregate_bit_identical(self):
        mesh = _data_mesh()
        n = 777
        Z = jnp.asarray(_binary((4, n), seed=4, p=0.6))
        outs = {}
        for s in STRATEGIES:
            t = get_transport(s)

            def body(zl, t=t):
                return t.aggregate_collective(zl[0], ("data",))[None]

            with mesh:
                f = shard_map_compat(body, ("data",), P("data", None),
                                     P(None, None))
                outs[s] = np.asarray(jax.jit(f)(Z))[0]
        for s in STRATEGIES[1:]:
            np.testing.assert_array_equal(outs["mean_f32"], outs[s])
        np.testing.assert_array_equal(outs["mean_f32"],
                                      np.asarray(Z).sum(0) / 4)

    def test_sharded_client_update_bit_identical(self, fed_setup):
        """The full production body under shard_map: every transport
        yields the same aggregated scores, bit for bit."""
        mesh = _data_mesh()
        zspecs, state, batch, K, E = fed_setup
        state_specs = jax.tree.map(lambda _: P(), state)
        met_specs = round_metric_specs()
        outs = {}
        for s in STRATEGIES:
            cfg = FederatedConfig(num_clients=K, local_steps=E,
                                  local_lr=0.1, aggregate=s)

            def body(st, b, k, cfg=cfg):
                b = jax.tree.map(lambda x: x[0], b)
                return sharded_client_update(zspecs, st, mlp_loss, b, k,
                                             cfg)

            with mesh:
                f = shard_map_compat(body, ("data",),
                                     (state_specs, P("data"), P()),
                                     (state_specs, met_specs))
                ns, met = jax.jit(f)(state, batch, jax.random.PRNGKey(0))
            outs[s] = jax.tree.map(np.asarray, ns["scores"])
            assert np.isfinite(float(met["loss"]))
        for s in STRATEGIES[1:]:
            for p in outs["mean_f32"]:
                np.testing.assert_array_equal(outs["mean_f32"][p],
                                              outs[s][p])

    def test_sharded_metrics_use_mesh_size(self, fed_setup):
        """Wire metrics on the sharded path count the mesh axis size,
        not cfg.num_clients (which is unused there and may differ)."""
        mesh = _data_mesh()
        zspecs, state, batch, K, E = fed_setup
        cfg = FederatedConfig(num_clients=10, local_steps=E,
                              local_lr=0.1, aggregate="psum_u32")
        state_specs = jax.tree.map(lambda _: P(), state)
        met_specs = round_metric_specs()

        def body(st, b, k):
            b = jax.tree.map(lambda x: x[0], b)
            return sharded_client_update(zspecs, st, mlp_loss, b, k, cfg)

        with mesh:
            f = shard_map_compat(body, ("data",),
                                 (state_specs, P("data"), P()),
                                 (state_specs, met_specs))
            _, met = jax.jit(f)(state, batch, jax.random.PRNGKey(0))
        assert float(met["uplink_bytes_round"]) == 4 * float(
            met["uplink_bytes_per_client"]
        )


# ---------------------------------------------------------------------------
# REPRO_BATCH_MAP_THRESHOLD env override (satellite)
# ---------------------------------------------------------------------------

class TestBatchMapThresholdEnv:
    def test_env_overrides_default(self, monkeypatch):
        from repro.core.reconstruct import (
            _BATCH_MAP_THRESHOLD,
            _batch_map_threshold,
        )

        assert _batch_map_threshold() == _BATCH_MAP_THRESHOLD
        monkeypatch.setenv("REPRO_BATCH_MAP_THRESHOLD", "123")
        assert _batch_map_threshold() == 123

    def test_both_strategies_agree(self, monkeypatch):
        """Forcing the crossover either way must not change results."""
        from repro.core.qspec import make_qspec
        from repro.core.reconstruct import reconstruct_batched_ref

        spec = make_qspec(1, (64, 96), 64 * 1, compression=8.0, d=8,
                          window=256, seed=11)
        Z = jnp.asarray(_binary((3, spec.n), seed=5))
        monkeypatch.setenv("REPRO_BATCH_MAP_THRESHOLD", "1")  # force map
        w_map = np.asarray(reconstruct_batched_ref(spec, Z))
        monkeypatch.setenv("REPRO_BATCH_MAP_THRESHOLD", str(1 << 62))
        w_fused = np.asarray(reconstruct_batched_ref(spec, Z))
        np.testing.assert_allclose(w_map, w_fused, rtol=1e-5, atol=1e-6)


def test_transport_names_stable():
    names = transport_names(include_aliases=False)
    assert names == sorted(STRATEGIES)
    assert "mean" in transport_names()
