"""Force a 4-device CPU topology before jax initializes.

The sharding-major reconstruction (kernels/qz_sharded.py) is a
shard_map over a 'model' mesh axis; with a single CPU device it is
untestable.  Setting the flag here (conftest is imported before any
test module, hence before jax backend init) lets the suite exercise
the real distributed path — tests that need it build a mesh via
``jax.make_mesh((4,), ("model",))`` and skip if fewer devices exist.
"""

import os

# respect an explicit device count the developer already set
if "--xla_force_host_platform_device_count" not in os.environ.get(
    "XLA_FLAGS", ""
):
    os.environ["XLA_FLAGS"] = (
        os.environ.get("XLA_FLAGS", "")
        + " --xla_force_host_platform_device_count=4"
    ).strip()
