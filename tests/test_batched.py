"""Batched multi-client reconstruction: ``reconstruct_batched(spec, Z)``
must be exactly ``jax.vmap(reconstruct)(Z)`` — forward and gradient —
across impls (ref / chunked / pallas / sharded), client counts, and
layouts (chunks>1, shard_count>1).  Plus the bitpack round-trip
property test for the masks the batched round puts on the wire."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:  # vendored fallback: fixed-seed examples, no shrinking
    from _hypothesis_fallback import given, settings
    from _hypothesis_fallback import strategies as st

from repro.core.bitpack import pack_mask, packed_len, unpack_mask
from repro.core.qspec import make_qspec
from repro.core.reconstruct import (
    grad_z_batched_ref,
    grad_z_ref,
    materialize_q,
    reconstruct_batched_ref,
)
from repro.kernels import ops
from repro.kernels.qz_reconstruct import (
    qz_reconstruct_batched_bwd,
    qz_reconstruct_batched_fwd,
)

# K=8 rides in the @slow set; {1, 3} cover the degenerate and the
# general case fast.
KS = [1, 3, pytest.param(8, marks=pytest.mark.slow)]


def _mk(shape=(64, 96), c=8.0, d=8, window=256, seed=11, **kw):
    fan = shape[0] if len(shape) == 1 else int(np.prod(shape[:-1]))
    return make_qspec(1, shape, fan, compression=c, d=d, window=window,
                      seed=seed, **kw)


def _z(spec, k, seed=0):
    return jnp.asarray(np.random.RandomState(seed).rand(k, spec.n),
                       jnp.float32)


def _vmap_naive(spec, Z, **kw):
    return jax.vmap(
        lambda z: ops.reconstruct(spec, z, auto_batch=False, **kw)
    )(Z)


@pytest.mark.parametrize("k", KS)
def test_batched_ref_equals_vmap_fwd(k):
    spec = _mk()
    Z = _z(spec, k)
    want = _vmap_naive(spec, Z)
    got = ops.reconstruct_batched(spec, Z)
    assert got.shape == (k, *spec.shape)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("k", KS)
def test_batched_ref_equals_vmap_grad(k):
    spec = _mk()
    Z = _z(spec, k)
    V = jnp.asarray(np.random.RandomState(1).randn(k, *spec.shape),
                    jnp.float32)

    def loss_b(Z_):
        return jnp.vdot(ops.reconstruct_batched(spec, Z_), V)

    def loss_v(Z_):
        return jnp.vdot(_vmap_naive(spec, Z_), V)

    np.testing.assert_allclose(
        np.asarray(jax.grad(loss_b)(Z)), np.asarray(jax.grad(loss_v)(Z)),
        rtol=1e-4, atol=1e-4,
    )


def test_large_spec_takes_map_strategy():
    # crosses _BATCH_MAP_THRESHOLD: exercises the lax.map contraction
    from repro.core.reconstruct import _BATCH_MAP_THRESHOLD

    spec = _mk((1200, 300), 16.0, 8, 512, seed=2)
    assert spec.m_pad * spec.d >= _BATCH_MAP_THRESHOLD
    Z = _z(spec, 2)
    want = _vmap_naive(spec, Z)
    np.testing.assert_allclose(
        np.asarray(ops.reconstruct_batched(spec, Z)), np.asarray(want),
        rtol=1e-5, atol=1e-5,
    )
    G = jnp.asarray(np.random.RandomState(3).randn(2, *spec.shape),
                    jnp.float32)
    want_g = jax.vmap(lambda g: grad_z_ref(spec, g))(G)
    np.testing.assert_allclose(
        np.asarray(grad_z_batched_ref(spec, G)), np.asarray(want_g),
        rtol=1e-4, atol=1e-4,
    )


@pytest.mark.parametrize("chunks", [3, 8])
@pytest.mark.parametrize("k", [1, 3])
def test_batched_chunked_matches(chunks, k):
    spec = _mk((777,), 2.0, 4, 64, seed=4)
    Z = _z(spec, k, seed=4)
    want = ops.reconstruct_batched(spec, Z, chunks=1)
    got = ops.reconstruct_batched(spec, Z, chunks=chunks)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-6)
    # the backward is chunked too (bounded O(rpc·d + K·rpc) temps)
    V = jnp.asarray(np.random.RandomState(5).randn(k, *spec.shape),
                    jnp.float32)

    def g(c):
        return jax.grad(lambda Z_: jnp.vdot(
            ops.reconstruct_batched(spec, Z_, chunks=c), V))(Z)

    np.testing.assert_allclose(np.asarray(g(chunks)), np.asarray(g(1)),
                               rtol=1e-4, atol=1e-5)


@pytest.mark.parametrize("chunks", [3, 8])
def test_single_chunked_grad_matches(chunks):
    spec = _mk((777,), 2.0, 4, 64, seed=4)
    z = _z(spec, 1, seed=6)[0]
    v = jnp.asarray(np.random.RandomState(7).randn(*spec.shape),
                    jnp.float32)

    def g(c):
        return jax.grad(lambda z_: jnp.vdot(
            ops.reconstruct(spec, z_, chunks=c, auto_batch=False), v))(z)

    np.testing.assert_allclose(np.asarray(g(chunks)), np.asarray(g(1)),
                               rtol=1e-4, atol=1e-5)


@pytest.mark.parametrize(
    "shape,a,sc", [((8, 6, 16), 2, 4), ((12, 10), 0, 4), ((64, 48), 1, 16)]
)
@pytest.mark.parametrize("k", [1, 3])
def test_batched_sharding_major_layout(shape, a, sc, k):
    """shard_count>1 specs through the (global) ref path: batched must
    equal the dense Q contraction in natural-row order."""
    spec = make_qspec(0, shape, 16, compression=2.0, d=4, window=32,
                      seed=3, major_axis=a, shard_count=sc)
    assert spec.shard_count == sc
    Z = _z(spec, k, seed=5)
    q = np.asarray(materialize_q(spec))
    want = np.einsum("mn,kn->km", q, np.asarray(Z)).reshape(k, *shape)
    got = np.asarray(reconstruct_batched_ref(spec, Z))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)
    G = jnp.asarray(np.random.RandomState(6).randn(k, *shape), jnp.float32)
    want_g = np.einsum("mn,km->kn", q, np.asarray(G).reshape(k, -1))
    np.testing.assert_allclose(np.asarray(grad_z_batched_ref(spec, G)),
                               want_g, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("k", KS)
def test_batched_pallas_matches_ref(k):
    spec = _mk((300, 20), 8.0, 5, 64, seed=7)
    Z = _z(spec, k, seed=7)
    want = np.asarray(reconstruct_batched_ref(spec, Z)).reshape(k, -1)
    got = np.asarray(qz_reconstruct_batched_fwd(spec, Z, interpret=True))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)
    G = jnp.asarray(np.random.RandomState(8).randn(k, spec.m), jnp.float32)
    want_g = np.asarray(
        grad_z_batched_ref(spec, G.reshape(k, *spec.shape))
    )
    got_g = np.asarray(qz_reconstruct_batched_bwd(spec, G, interpret=True))
    np.testing.assert_allclose(got_g, want_g, rtol=1e-4, atol=1e-4)


def test_pallas_impl_dispatch_batched():
    spec = _mk((300, 20), 8.0, 5, 64, seed=7)
    Z = _z(spec, 3, seed=9)
    ref = ops.reconstruct_batched(spec, Z, impl="ref")
    got = ops.reconstruct_batched(spec, Z, impl="pallas")
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("batched", [False, True])
def test_pallas_dispatch_major_axis_moved(batched):
    """major_axis != 0 with shard_count == 1: the pallas kernel emits
    moved-order rows — dispatch must un-move them (fwd) and move the
    cotangent (bwd) exactly like the ref path."""
    spec = make_qspec(1, (12, 10), 16, compression=2.0, d=4, window=32,
                      seed=13, major_axis=1, shard_count=1)
    Z = _z(spec, 2, seed=13)
    V = jnp.asarray(np.random.RandomState(14).randn(2, *spec.shape),
                    jnp.float32)
    if batched:
        fwd = lambda impl: ops.reconstruct_batched(spec, Z, impl=impl)
        grad = lambda impl: jax.grad(lambda Z_: jnp.vdot(
            ops.reconstruct_batched(spec, Z_, impl=impl), V))(Z)
    else:
        fwd = lambda impl: ops.reconstruct(spec, Z[0], impl=impl,
                                           auto_batch=False)
        grad = lambda impl: jax.grad(lambda z_: jnp.vdot(
            ops.reconstruct(spec, z_, impl=impl, auto_batch=False),
            V[0]))(Z[0])
    np.testing.assert_allclose(np.asarray(fwd("pallas")),
                               np.asarray(fwd("ref")),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(grad("pallas")),
                               np.asarray(grad("ref")),
                               rtol=1e-4, atol=1e-4)


def test_vmap_auto_lowers_to_batched(monkeypatch):
    """jax.vmap(reconstruct) must dispatch onto the batched impl (the
    custom_vmap rule), not K replicated single-client reconstructions."""
    spec = _mk(seed=12)  # fresh seed: avoid any cached trace of _mk()
    Z = _z(spec, 4)
    calls = []
    real = ops._fwd_many
    monkeypatch.setattr(
        ops, "_fwd_many",
        lambda *a, **k: (calls.append(1), real(*a, **k))[1],
    )
    want = _vmap_naive(spec, Z)
    got = jax.vmap(lambda z: ops.reconstruct(spec, z))(Z)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)
    assert calls, "batched rule never fired under jax.vmap"


def test_vmap_grad_auto_lowers_to_batched(monkeypatch):
    spec = _mk(seed=15)  # fresh seed: avoid any cached trace
    Z = _z(spec, 4)
    V = jnp.asarray(np.random.RandomState(2).randn(4, *spec.shape),
                    jnp.float32)
    calls = []
    real = ops._bwd_many
    monkeypatch.setattr(
        ops, "_bwd_many",
        lambda *a, **k: (calls.append(1), real(*a, **k))[1],
    )

    def gfun(auto):
        def loss(z, v):
            return jnp.vdot(ops.reconstruct(spec, z, auto_batch=auto), v)

        return jax.vmap(jax.grad(loss))(Z, V)

    np.testing.assert_allclose(np.asarray(gfun(True)),
                               np.asarray(gfun(False)),
                               rtol=1e-4, atol=1e-4)
    assert calls, "batched bwd rule never fired under vmap(grad)"


class TestBitpackRoundTrip:
    @settings(max_examples=12, deadline=None)
    @given(n=st.integers(1, 700), seed=st.integers(0, 10_000))
    def test_pack_unpack_roundtrip(self, n, seed):
        z = (np.random.RandomState(seed).rand(n) < 0.5).astype(np.float32)
        packed = pack_mask(jnp.asarray(z))
        assert packed.shape == (packed_len(n),)
        assert packed.dtype == jnp.uint32
        out = np.asarray(unpack_mask(packed, n))
        np.testing.assert_array_equal(out, z)

    def test_pack_is_32x(self):
        n = 4096
        z = jnp.ones((n,), jnp.float32)
        assert pack_mask(z).size * 32 == n
