"""Fused mask lifecycle: ``w = Q·Bern(f(s))`` as one op, masks as
uint32 lanes end-to-end.

The bit-exactness contract: fused ≡ composed (sample -> reconstruct ->
pack) to EXACT equality — forward and gradient — on ref and
interpret-mode Pallas, single-client, vmap-batched (K ∈ {1, 10, 32}),
and the forced 4-device shard_map mesh; plus the architectural claim
that no (K, n) f32 mask array appears in the fused Pallas path's jaxpr.

Satellites covered here: ``set_default_impl`` validation and the
``REPRO_RECONSTRUCT_IMPL`` env override; the analytic-vs-exact wire
accounting cross-check (``ZamplingSpecs.comm_bits_per_round`` vs
``comm.metering.round_wire_report``).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from _helpers import data_mesh_or_skip, round_metric_specs

from repro.comm.bitpack import pack_mask, packed_len
from repro.comm.metering import round_wire_report
from repro.comm.shardmap import shard_map_compat
from repro.core import FederatedConfig, ZamplingConfig, build_specs, init_state
from repro.core.federated import federated_round, local_update, sharded_client_update
from repro.core.qspec import make_qspec
from repro.core.sampling import clip_probs, fold_word, mask_u32, sample_mask_hash
from repro.core.zampling import MaskProgram, sample_weights
from repro.kernels import ops

STRATEGIES = ("mean_f32", "psum_u32", "allgather_packed")
KS = [1, 10, 32]


def _mk(shape=(300, 20), c=8.0, d=5, window=64, seed=7, **kw):
    fan = shape[0] if len(shape) == 1 else int(np.prod(shape[:-1]))
    return make_qspec(1, shape, fan, compression=c, d=d, window=window,
                      seed=seed, **kw)


def _probs(spec, k=None, seed=0):
    rng = np.random.RandomState(seed)
    shape = (spec.n,) if k is None else (k, spec.n)
    return jnp.asarray(rng.rand(*shape), jnp.float32)


def _composed_fwd(spec, p, step, impl):
    z = sample_mask_hash(p, spec.seed, spec.tensor_id, step)
    if p.ndim == 2:
        return ops.reconstruct_batched(spec, z, impl=impl)
    return ops.reconstruct(spec, z, impl=impl, auto_batch=False)


# ---------------------------------------------------------------------------
# fused == composed: forward
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("impl", ["ref", "pallas"])
def test_fused_equals_composed_single(impl):
    spec = _mk()
    p = _probs(spec)
    step = jnp.uint32(42)
    want = np.asarray(_composed_fwd(spec, p, step, impl))
    got = np.asarray(ops.sample_reconstruct(spec, p, step, impl=impl))
    np.testing.assert_array_equal(got, want)


@pytest.mark.parametrize("impl", ["ref", "pallas"])
@pytest.mark.parametrize("k", KS)
def test_fused_equals_composed_batched(impl, k):
    spec = _mk()
    P_ = _probs(spec, k)
    steps = jnp.arange(k, dtype=jnp.uint32) + 7
    want = np.asarray(_composed_fwd(spec, P_, steps, impl))
    got = np.asarray(ops.sample_reconstruct_batched(spec, P_, steps,
                                                    impl=impl))
    np.testing.assert_array_equal(got, want)
    # jax.vmap over (p, step) must hit the same batched fused impl
    got_v = np.asarray(jax.vmap(
        lambda p_, s_: ops.sample_reconstruct(spec, p_, s_, impl=impl)
    )(P_, steps))
    np.testing.assert_array_equal(got_v, got)


@pytest.mark.parametrize("chunks", [3, 8])
def test_fused_chunked_matches(chunks):
    spec = _mk((777,), 2.0, 4, 64, seed=4)
    p = _probs(spec, seed=4)
    step = jnp.uint32(9)
    want = np.asarray(ops.sample_reconstruct(spec, p, step, chunks=1))
    got = np.asarray(ops.sample_reconstruct(spec, p, step, chunks=chunks))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)


# ---------------------------------------------------------------------------
# fused == composed: gradient (straight-through through the clip gate)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("impl", ["ref", "pallas"])
def test_fused_grad_equals_composed_single(impl):
    spec = _mk()
    rng = np.random.RandomState(3)
    s = jnp.asarray(rng.randn(spec.n) * 0.7 + 0.3, jnp.float32)
    v = jnp.asarray(rng.randn(*spec.shape), jnp.float32)
    step = jnp.uint32(11)

    def loss_fused(s_):
        return jnp.vdot(
            ops.sample_reconstruct(spec, clip_probs(s_), step, impl=impl), v
        )

    def loss_comp(s_):
        p = clip_probs(s_)
        z = sample_mask_hash(p, spec.seed, spec.tensor_id, step)
        z_st = p + jax.lax.stop_gradient(z - p)
        return jnp.vdot(
            ops.reconstruct(spec, z_st, impl=impl, auto_batch=False), v
        )

    np.testing.assert_array_equal(np.asarray(jax.grad(loss_fused)(s)),
                                  np.asarray(jax.grad(loss_comp)(s)))
    # the clip gate: coordinates outside (0, 1) get zero gradient
    g = np.asarray(jax.grad(loss_fused)(s))
    outside = (np.asarray(s) < 0.0) | (np.asarray(s) > 1.0)
    np.testing.assert_array_equal(g[outside], 0.0)


@pytest.mark.parametrize("impl", ["ref", "pallas"])
@pytest.mark.parametrize("k", [1, 10])
def test_fused_vmap_grad_equals_composed(impl, k):
    spec = _mk()
    rng = np.random.RandomState(5)
    S = jnp.asarray(rng.randn(k, spec.n) * 0.7 + 0.3, jnp.float32)
    V = jnp.asarray(rng.randn(k, *spec.shape), jnp.float32)
    steps = jnp.arange(k, dtype=jnp.uint32) + 3

    def g_fused():
        def loss(s_, st, v_):
            return jnp.vdot(
                ops.sample_reconstruct(spec, clip_probs(s_), st, impl=impl),
                v_)

        return jax.vmap(jax.grad(loss))(S, steps, V)

    def g_comp():
        # auto_batch default: vmap lowers the composed custom_vjp onto
        # the SAME batched backward as the fused op — exactness needs
        # like-for-like lowering, not per-client replication
        def loss(s_, st, v_):
            p = clip_probs(s_)
            z = sample_mask_hash(p, spec.seed, spec.tensor_id, st)
            z_st = p + jax.lax.stop_gradient(z - p)
            return jnp.vdot(ops.reconstruct(spec, z_st, impl=impl), v_)

        return jax.vmap(jax.grad(loss))(S, steps, V)

    np.testing.assert_array_equal(np.asarray(g_fused()),
                                  np.asarray(g_comp()))


def test_fused_vmap_lowers_onto_batched(monkeypatch):
    """vmap(sample_reconstruct) must hit the natively-batched fused
    forward, and vmap(grad(...)) the batched backward rule."""
    spec = _mk(seed=21)
    P_ = _probs(spec, 4, seed=21)
    steps = jnp.arange(4, dtype=jnp.uint32)
    fwd_calls, bwd_calls = [], []
    real_f, real_b = ops._fwd_many_fused, ops._bwd_many
    monkeypatch.setattr(ops, "_fwd_many_fused",
                        lambda *a, **k: (fwd_calls.append(1),
                                         real_f(*a, **k))[1])
    monkeypatch.setattr(ops, "_bwd_many",
                        lambda *a, **k: (bwd_calls.append(1),
                                         real_b(*a, **k))[1])
    jax.vmap(lambda p_, s_: ops.sample_reconstruct(spec, p_, s_))(P_, steps)
    assert fwd_calls, "batched fused fwd rule never fired under vmap"
    V = jnp.asarray(np.random.RandomState(1).randn(4, *spec.shape),
                    jnp.float32)
    jax.vmap(jax.grad(
        lambda p_, s_, v_: jnp.vdot(ops.sample_reconstruct(spec, p_, s_),
                                    v_)
    ))(P_, steps, V)
    assert bwd_calls, "batched bwd rule never fired under vmap(grad)"


# ---------------------------------------------------------------------------
# fused sample_pack == composed sample -> pack
# ---------------------------------------------------------------------------

class TestSamplePack:
    @pytest.mark.parametrize("impl", ["ref", "pallas"])
    def test_single_matches_composed(self, impl):
        spec = _mk()
        p = _probs(spec)
        step = jnp.uint32(5)
        want = np.asarray(pack_mask(
            sample_mask_hash(p, spec.seed, spec.tensor_id, step)))
        got = np.asarray(ops.sample_pack(spec, p, step, impl=impl))
        assert got.dtype == np.uint32
        np.testing.assert_array_equal(got, want)

    @pytest.mark.parametrize("impl", ["ref", "pallas"])
    @pytest.mark.parametrize("k", KS)
    def test_batched_matches_composed(self, impl, k):
        spec = _mk()
        P_ = _probs(spec, k)
        steps = jnp.arange(k, dtype=jnp.uint32) + 1
        want = np.asarray(pack_mask(
            sample_mask_hash(P_, spec.seed, spec.tensor_id, steps)))
        got = np.asarray(ops.sample_pack_batched(spec, P_, steps, impl=impl))
        np.testing.assert_array_equal(got, want)
        got_v = np.asarray(jax.vmap(
            lambda p_, s_: ops.sample_pack(spec, p_, s_, impl=impl)
        )(P_, steps))
        np.testing.assert_array_equal(got_v, want)

    def test_small_window_falls_back(self):
        # window 16 < 32: the pallas impl must fall back to the jnp
        # oracle (partial lanes cannot be emitted blockwise)
        spec = _mk((40,), 2.0, 3, 16, seed=2)
        assert spec.window % 32 != 0
        p = _probs(spec)
        step = jnp.uint32(3)
        want = np.asarray(pack_mask(
            sample_mask_hash(p, spec.seed, spec.tensor_id, step)))
        got = np.asarray(ops.sample_pack(spec, p, step, impl="pallas"))
        assert got.shape == (packed_len(spec.n),)
        np.testing.assert_array_equal(got, want)


# ---------------------------------------------------------------------------
# the architectural claim: no (K, n) f32 mask in the fused pallas jaxpr
# ---------------------------------------------------------------------------

def _eqn_out_shapes(jaxpr, acc):
    for eqn in jaxpr.eqns:
        for v in eqn.outvars:
            aval = getattr(v, "aval", None)
            if aval is not None and getattr(aval, "dtype", None) is not None:
                acc.append((tuple(aval.shape), str(aval.dtype)))
        for param in eqn.params.values():
            inner = getattr(param, "jaxpr", None)
            if inner is not None:
                _eqn_out_shapes(inner, acc)
            elif hasattr(param, "eqns"):
                _eqn_out_shapes(param, acc)
    return acc


def test_no_mask_slab_in_fused_pallas_jaxpr():
    """The fused Pallas path must not materialize the (K, n) f32 mask
    anywhere in its jaxpr — the draw lives in-block at (window, K).
    The composed path DOES materialize it (detector sanity check)."""
    spec = _mk()
    k = 10
    P_ = _probs(spec, k)
    steps = jnp.arange(k, dtype=jnp.uint32)
    slab = ((k, spec.n), "float32")

    fused = jax.make_jaxpr(
        lambda P: ops.sample_reconstruct_batched(spec, P, steps,
                                                 impl="pallas")
    )(P_)
    fused_shapes = _eqn_out_shapes(fused.jaxpr, [])
    assert slab not in fused_shapes, (
        "fused pallas path materializes the (K, n) f32 mask slab"
    )

    composed = jax.make_jaxpr(
        lambda P: ops.reconstruct_batched(
            spec, sample_mask_hash(P, spec.seed, spec.tensor_id, steps),
            impl="pallas")
    )(P_)
    assert slab in _eqn_out_shapes(composed.jaxpr, []), (
        "detector failed: composed path should materialize the mask"
    )

    # same claim for the fused upload: lanes come out, no f32 mask
    pack = jax.make_jaxpr(
        lambda P: ops.sample_pack_batched(spec, P, steps, impl="pallas")
    )(P_)
    assert slab not in _eqn_out_shapes(pack.jaxpr, [])


# ---------------------------------------------------------------------------
# federated: fused == composed across transports, vmap and shard_map
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def fed_setup():
    from repro.data import client_batch_stream, iid_client_split, make_teacher_dataset
    from repro.models.mlp import SMALL_DIMS, init_mlp_params

    ds = make_teacher_dataset(n_train=600, n_test=100, seed=0)
    template = init_mlp_params(jax.random.PRNGKey(0), SMALL_DIMS)
    zspecs = build_specs(template, ZamplingConfig(
        compression=2.0, d=5, window=128, min_size=256))
    state = init_state(jax.random.PRNGKey(1), zspecs, dense_init=template)
    K, E = 4, 2
    clients = iid_client_split(ds, K)
    xs, ys = next(client_batch_stream(clients, 32, E, seed=0))
    batch = {"x": jnp.asarray(xs), "y": jnp.asarray(ys)}
    return zspecs, state, batch, K, E


def _round_scores(fed_setup, aggregate, mask_path):
    from repro.models.mlp import mlp_loss

    zspecs, state, batch, K, E = fed_setup
    cfg = FederatedConfig(num_clients=K, local_steps=E, local_lr=0.1,
                          aggregate=aggregate, mask_path=mask_path)
    st, met = jax.jit(
        lambda s, b, k: federated_round(zspecs, s, mlp_loss, b, k, cfg)
    )(state, batch, jax.random.PRNGKey(0))
    assert np.isfinite(float(met["loss"]))
    return jax.tree.map(np.asarray, st["scores"])


def test_round_fused_equals_composed_all_transports(fed_setup):
    base = _round_scores(fed_setup, "mean_f32", "composed")
    for agg in STRATEGIES:
        for mask_path in ("fused", "composed"):
            got = _round_scores(fed_setup, agg, mask_path)
            for p in base:
                np.testing.assert_array_equal(
                    base[p], got[p],
                    err_msg=f"{agg}/{mask_path} differs at {p}",
                )


def test_local_update_emits_native_lanes(fed_setup):
    """Packed transports receive uint32 wire lanes from local_update —
    no post-hoc pack of an f32 mask slab."""
    from repro.models.mlp import mlp_loss

    zspecs, state, batch, K, E = fed_setup
    b0 = jax.tree.map(lambda x: x[0], batch)
    for mask_path in ("fused", "composed"):
        cfg = FederatedConfig(num_clients=K, local_steps=E, local_lr=0.1,
                              aggregate="psum_u32", mask_path=mask_path)
        z_new, _, _ = jax.jit(
            lambda s, b, k, cfg=cfg: local_update(zspecs, s, mlp_loss, b,
                                                  k, cfg)
        )(state, b0, jax.random.PRNGKey(0))
        for p, spec in zspecs.specs.items():
            assert z_new[p].dtype == jnp.uint32, (mask_path, p)
            assert z_new[p].shape == (packed_len(spec.n),), (mask_path, p)
    # the f32 strategy still gets f32 masks
    cfg = FederatedConfig(num_clients=K, local_steps=E, local_lr=0.1,
                          aggregate="mean_f32")
    z_new, _, _ = jax.jit(
        lambda s, b, k: local_update(zspecs, s, mlp_loss, b, k, cfg)
    )(state, b0, jax.random.PRNGKey(0))
    for p, spec in zspecs.specs.items():
        assert z_new[p].dtype == jnp.float32
        assert z_new[p].shape == (spec.n,)


def test_discretize_keeps_packed_wire(fed_setup):
    """Discretized uploads are binary, so packed transports keep their
    wire (no silent mean_f32 downgrade): lanes on the wire, scores
    bit-identical to the f32 strategy, packed bytes in the metrics."""
    from repro.models.mlp import mlp_loss

    zspecs, state, batch, K, E = fed_setup
    b0 = jax.tree.map(lambda x: x[0], batch)
    cfg_p = FederatedConfig(num_clients=K, local_steps=E, local_lr=0.1,
                            mode="discretize", aggregate="psum_u32")
    z_new, _, _ = jax.jit(
        lambda s, b, k: local_update(zspecs, s, mlp_loss, b, k, cfg_p)
    )(state, b0, jax.random.PRNGKey(0))
    for p, spec in zspecs.specs.items():
        assert z_new[p].dtype == jnp.uint32
        assert z_new[p].shape == (packed_len(spec.n),)
    outs, mets = {}, {}
    for agg in ("mean_f32", "psum_u32"):
        cfg = FederatedConfig(num_clients=K, local_steps=E, local_lr=0.1,
                              mode="discretize", aggregate=agg)
        st, met = jax.jit(
            lambda s, b, k, cfg=cfg: federated_round(zspecs, s, mlp_loss,
                                                     b, k, cfg)
        )(state, batch, jax.random.PRNGKey(0))
        outs[agg] = jax.tree.map(np.asarray, st["scores"])
        mets[agg] = met
    for p in outs["mean_f32"]:
        np.testing.assert_array_equal(outs["mean_f32"][p],
                                      outs["psum_u32"][p])
    assert float(mets["psum_u32"]["uplink_bytes_per_client"]) < float(
        mets["mean_f32"]["uplink_bytes_per_client"])


def test_sharded_fused_equals_vmap_and_composed(fed_setup):
    """shard_map path == vmap path == composed, bit for bit, per
    transport (the draw words coincide across execution paths)."""
    from repro.models.mlp import mlp_loss

    mesh = data_mesh_or_skip(4)
    zspecs, state, batch, K, E = fed_setup
    state_specs = jax.tree.map(lambda _: P(), state)
    met_specs = round_metric_specs()
    base = _round_scores(fed_setup, "mean_f32", "composed")
    for agg in STRATEGIES:
        for mask_path in ("fused", "composed"):
            cfg = FederatedConfig(num_clients=K, local_steps=E,
                                  local_lr=0.1, aggregate=agg,
                                  mask_path=mask_path)

            def body(st, b, k, cfg=cfg):
                b = jax.tree.map(lambda x: x[0], b)
                return sharded_client_update(zspecs, st, mlp_loss, b, k,
                                             cfg)

            with mesh:
                f = shard_map_compat(body, ("data",),
                                     (state_specs, P("data"), P()),
                                     (state_specs, met_specs))
                ns, _ = jax.jit(f)(state, batch, jax.random.PRNGKey(0))
            for p in base:
                np.testing.assert_array_equal(
                    base[p], np.asarray(ns["scores"][p]),
                    err_msg=f"shard_map {agg}/{mask_path} differs at {p}",
                )


def test_fused_model_sharded_dispatch():
    """The 'model'-mesh branch: a shard_count>1 spec with model_size
    routes the fused op through the sharded reconstruction — exact vs
    the composed sharded path (same draw, same local chunks)."""
    if len(jax.devices()) < 4:
        pytest.skip("needs 4 devices (conftest forces 4 on CPU)")
    spec = make_qspec(0, (8, 6, 16), 16, compression=2.0, d=4,
                      window=32, seed=3, major_axis=2, shard_count=4)
    p = _probs(spec, seed=13)
    step = jnp.uint32(2)
    mesh = jax.make_mesh((4,), ("model",))
    with mesh:
        got = np.asarray(
            ops.sample_reconstruct(spec, p, step, model_size=4))
        z = sample_mask_hash(p, spec.seed, spec.tensor_id, step)
        want = np.asarray(ops.reconstruct(spec, z, model_size=4,
                                          auto_batch=False))
    np.testing.assert_array_equal(got, want)


# ---------------------------------------------------------------------------
# MaskProgram / sample_weights
# ---------------------------------------------------------------------------

class TestMaskProgram:
    def _zsetup(self):
        template = {
            "l0": {"kernel": jnp.zeros((64, 128)), "bias": jnp.zeros((128,))},
            "l1": {"kernel": jnp.zeros((128, 32))},
        }
        zspecs = build_specs(template, ZamplingConfig(
            compression=4, d=4, window=128, min_size=256))
        state = init_state(jax.random.PRNGKey(0), zspecs)
        return zspecs, state

    def test_invalid_mode_raises(self):
        zspecs, _ = self._zsetup()
        with pytest.raises(ValueError, match="valid modes"):
            MaskProgram(zspecs, mode="bogus")
        with pytest.raises(ValueError, match="valid modes"):
            FederatedConfig(mode="bogus")
        with pytest.raises(ValueError, match="valid paths"):
            FederatedConfig(mask_path="bogus")

    def test_sample_weights_fused_equals_composed(self):
        zspecs, state = self._zsetup()
        key = jax.random.PRNGKey(2)
        w_f = sample_weights(zspecs, state, key, fused=True)
        w_c = sample_weights(zspecs, state, key, fused=False)
        for a, b in zip(jax.tree.leaves(w_f), jax.tree.leaves(w_c)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_modes_route_through_program(self):
        zspecs, state = self._zsetup()
        key = jax.random.PRNGKey(3)
        w_cont = sample_weights(zspecs, state, key, mode="continuous")
        w_disc = sample_weights(zspecs, state, key, mode="discretize")
        for a, b in zip(jax.tree.leaves(w_cont), jax.tree.leaves(w_disc)):
            assert a.shape == b.shape

    def test_upload_fused_equals_composed(self):
        zspecs, state = self._zsetup()
        step = jnp.uint32(17)
        for packed in (False, True):
            up_f = MaskProgram(zspecs, fused=True, packed=packed).upload(
                state["scores"], step)
            up_c = MaskProgram(zspecs, fused=False, packed=packed).upload(
                state["scores"], step)
            for p in up_f:
                np.testing.assert_array_equal(np.asarray(up_f[p]),
                                              np.asarray(up_c[p]))
                if packed:
                    assert up_f[p].dtype == jnp.uint32


# ---------------------------------------------------------------------------
# the hash mask stream itself
# ---------------------------------------------------------------------------

class TestMaskStream:
    def test_deterministic_and_binary(self):
        p = jnp.full((4096,), 0.3, jnp.float32)
        a = np.asarray(sample_mask_hash(p, 3, 1, jnp.uint32(5)))
        b = np.asarray(sample_mask_hash(p, 3, 1, jnp.uint32(5)))
        np.testing.assert_array_equal(a, b)
        assert set(np.unique(a)) <= {0.0, 1.0}
        assert abs(a.mean() - 0.3) < 0.05

    def test_steps_and_tensors_decorrelate(self):
        p = jnp.full((20000,), 0.5, jnp.float32)
        a = np.asarray(sample_mask_hash(p, 3, 1, jnp.uint32(5)))
        for args in ((3, 1, jnp.uint32(6)), (3, 2, jnp.uint32(5)),
                     (4, 1, jnp.uint32(5))):
            b = np.asarray(sample_mask_hash(p, *args))
            agree = (a == b).mean()
            assert 0.45 < agree < 0.55, (args, agree)

    def test_stream_disjoint_from_q_generation(self):
        # the 5-word mask stream must not alias the 4-word Q streams
        from repro.core.qspec import row_indices

        spec = _mk()
        u_mask = np.asarray(mask_u32(
            spec.seed, spec.tensor_id, jnp.uint32(0),
            jnp.arange(256, dtype=jnp.uint32)))
        idx = np.asarray(row_indices(spec, jnp.arange(256))).ravel()
        # crude: the mask words are full-range u32, not window indices
        assert u_mask.max() > spec.window * 1000

    def test_fold_word_counters_distinct(self):
        w = jnp.uint32(123)
        words = {int(fold_word(w, e)) for e in range(64)}
        assert len(words) == 64


# ---------------------------------------------------------------------------
# satellite: impl default validation + env override
# ---------------------------------------------------------------------------

class TestImplDefault:
    def test_set_default_impl_rejects_unknown(self):
        with pytest.raises(ValueError, match="valid impls"):
            ops.set_default_impl("bogus")
        assert ops._default_impl() == "ref"  # unchanged after the raise

    def test_env_override(self, monkeypatch):
        monkeypatch.setenv("REPRO_RECONSTRUCT_IMPL", "pallas")
        assert ops._default_impl() == "pallas"
        monkeypatch.setenv("REPRO_RECONSTRUCT_IMPL", "bogus")
        with pytest.raises(ValueError, match="valid impls"):
            ops._default_impl()
        monkeypatch.delenv("REPRO_RECONSTRUCT_IMPL")
        assert ops._default_impl() == "ref"

    def test_env_override_routes_dispatch(self, monkeypatch):
        spec = _mk(seed=31)
        p = _probs(spec, seed=31)
        step = jnp.uint32(1)
        want = np.asarray(ops.sample_reconstruct(spec, p, step,
                                                 impl="pallas"))
        monkeypatch.setenv("REPRO_RECONSTRUCT_IMPL", "pallas")
        got = np.asarray(ops.sample_reconstruct(spec, p, step))
        np.testing.assert_array_equal(got, want)


# ---------------------------------------------------------------------------
# satellite: analytic vs exact wire accounting
# ---------------------------------------------------------------------------

class TestWireAccountingCrossCheck:
    def _zspecs(self, window):
        # window=16 + the (40, 40) leaf: n = 25 windows x 16 = 400,
        # NOT a multiple of 32 -> real uint32 lane padding on the wire
        template = {
            "l0": {"kernel": jnp.zeros((40, 40)), "bias": jnp.zeros((128,))},
            "l1": {"kernel": jnp.zeros((128, 32))},
        }
        return build_specs(template, ZamplingConfig(
            compression=4, d=4, window=window, min_size=256))

    @pytest.mark.parametrize("window", [16, 128])
    def test_wire_keys_match_metering_exactly(self, window):
        zspecs = self._zspecs(window)
        bits = zspecs.comm_bits_per_round(packed=True)
        rep = round_wire_report(zspecs, "psum_u32", 10)
        assert bits["client_up_wire"] == 8 * rep["uplink_bytes_per_client"]
        assert bits["server_down_wire"] == 8 * rep[
            "downlink_bytes_per_client"]
        rep_f32 = round_wire_report(zspecs, "mean_f32", 10)
        bits_u = zspecs.comm_bits_per_round(packed=False)
        assert bits_u["client_up_wire"] == 8 * rep_f32[
            "uplink_bytes_per_client"]

    @pytest.mark.parametrize("window", [16, 128])
    def test_analytic_delta_is_padding_plus_dense(self, window):
        """The idealized ``client_up = n`` undercounts by exactly the
        uint32 lane padding + the dense f32 leaves — pinned here."""
        zspecs = self._zspecs(window)
        bits = zspecs.comm_bits_per_round(packed=True)
        pad = sum(32 * packed_len(s.n) - s.n for s in zspecs.specs.values())
        dense = 32 * zspecs.dense_total
        assert bits["client_up_wire"] - bits["client_up"] == pad + dense
        if window == 16:
            assert pad > 0  # small windows really do pad lanes
        else:
            assert pad == 0  # window % 32 == 0: lanes tile exactly
