"""Per-arch smoke tests: REDUCED variant (2 layers, d_model<=512,
<=4 experts), one forward + one train step + one decode step on CPU,
asserting output shapes and no NaNs."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, InputShape
from repro.configs.registry import get_arch
from repro.launch.input_specs import make_batch
from repro.models import build_model, loss_fn
from repro.optim import sgd
from repro.optim.optimizers import apply_updates

SMOKE_SHAPE = InputShape("smoke", seq_len=32, global_batch=2, kind="train")
DECODE_SHAPE = InputShape("smoke_dec", seq_len=32, global_batch=2,
                          kind="decode")

ARCH_IDS = sorted(ARCHS)


@pytest.fixture(scope="module")
def built():
    cache = {}

    def get(name):
        if name not in cache:
            cfg = get_arch(name).reduced()
            model = build_model(cfg)
            params = model.init_params(jax.random.PRNGKey(0))
            cache[name] = (cfg, model, params)
        return cache[name]

    return get


def _finite(tree):
    return all(bool(jnp.isfinite(l).all()) for l in jax.tree.leaves(tree)
               if jnp.issubdtype(l.dtype, jnp.floating))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_shapes_and_finite(arch, built):
    cfg, model, params = built(arch)
    batch = make_batch(cfg, SMOKE_SHAPE)
    logits, aux = model.forward(params, batch)
    B, S = SMOKE_SHAPE.global_batch, SMOKE_SHAPE.seq_len
    assert logits.shape == (B, S, cfg.vocab)
    assert _finite({"logits": logits})
    assert jnp.isfinite(aux["aux_loss"])


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_one_train_step(arch, built):
    cfg, model, params = built(arch)
    batch = make_batch(cfg, SMOKE_SHAPE)
    if "labels" not in batch:
        batch["labels"] = batch.get("tokens")
    opt = sgd(1e-2)

    def loss(p):
        return loss_fn(model, p, batch)

    l0, grads = jax.value_and_grad(loss)(params)
    assert bool(jnp.isfinite(l0)) and l0 > 0
    assert _finite(grads)
    updates, _ = opt.update(grads, opt.init(params), params)
    new_params = apply_updates(params, updates)
    l1 = loss(new_params)
    assert bool(jnp.isfinite(l1))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_decode_step(arch, built):
    cfg, model, params = built(arch)
    B = DECODE_SHAPE.global_batch
    cache = model.init_cache(params, B, DECODE_SHAPE.seq_len)
    batch = {"tokens": jnp.zeros((B, 1), jnp.int32)}
    logits, cache = model.decode_step(params, cache, batch)
    assert logits.shape == (B, 1, cfg.vocab)
    assert _finite({"logits": logits})
    logits2, cache = model.decode_step(params, cache, batch)
    assert _finite({"logits2": logits2})


def test_decode_matches_forward_dense(built):
    """Greedy consistency: step-by-step decode logits == full forward."""
    cfg, model, params = built("qwen2-0.5b")
    S = 8
    toks = jnp.asarray(np.random.RandomState(0).randint(0, cfg.vocab, (1, S)),
                       jnp.int32)
    full_logits, _ = model.forward(params, {"tokens": toks})
    cache = model.init_cache(params, 1, S)
    outs = []
    for t in range(S):
        lg, cache = model.decode_step(params, cache, {"tokens": toks[:, t:t+1]})
        outs.append(lg[:, 0])
    dec_logits = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(
        np.asarray(dec_logits, np.float32), np.asarray(full_logits, np.float32),
        rtol=2e-2, atol=2e-2,
    )


def test_decode_matches_forward_ssm(built):
    cfg, model, params = built("mamba2-1.3b")
    S = 16  # must tile the reduced chunk (16)
    toks = jnp.asarray(np.random.RandomState(1).randint(0, cfg.vocab, (1, S)),
                       jnp.int32)
    full_logits, _ = model.forward(params, {"tokens": toks})
    cache = model.init_cache(params, 1, S)
    outs = []
    for t in range(S):
        lg, cache = model.decode_step(params, cache, {"tokens": toks[:, t:t+1]})
        outs.append(lg[:, 0])
    dec_logits = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(
        np.asarray(dec_logits, np.float32), np.asarray(full_logits, np.float32),
        rtol=5e-2, atol=5e-2,
    )


def test_sliding_window_masks_old_tokens(built):
    """Mixtral SWA: token beyond the window must not affect logits."""
    cfg, model, params = built("mixtral-8x7b")
    assert cfg.window is not None
    W = cfg.window
    S = W + 8
    rs = np.random.RandomState(2)
    t1 = rs.randint(0, cfg.vocab, (1, S))
    t2 = t1.copy()
    t2[0, 0] = (t2[0, 0] + 1) % cfg.vocab  # perturb a token outside window
    l1, _ = model.forward(params, {"tokens": jnp.asarray(t1, jnp.int32)})
    l2, _ = model.forward(params, {"tokens": jnp.asarray(t2, jnp.int32)})
    # last position attends to (S-W, S]; with 2 layers receptive field is
    # 2W; position 0 is outside for the FIRST layer only — so compare a
    # 1-layer property instead: positions >= W+1 in layer-1 outputs can
    # still differ through layer stacking. Check instead that logits at
    # the perturbed position itself DO differ (sanity).
    assert not np.allclose(np.asarray(l1[0, 0]), np.asarray(l2[0, 0]))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_full_config_instantiates_metadata(arch):
    """FULL configs: metadata sanity (no allocation here)."""
    cfg = get_arch(arch)
    assert cfg.n_layers >= 12 and cfg.vocab > 1000
    if cfg.n_heads:
        assert cfg.n_heads % max(cfg.n_kv, 1) == 0
    if cfg.moe:
        assert cfg.moe.top_k <= cfg.moe.num_experts
    if cfg.ssm:
        assert (cfg.ssm.expand * cfg.d_model) % cfg.ssm.headdim == 0
