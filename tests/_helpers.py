"""Shared test utilities (not a test module)."""

import jax
import pytest
from jax.sharding import PartitionSpec as P

from repro.core.federated import ROUND_METRIC_KEYS


def data_mesh_or_skip(size=4, axis="data"):
    """A (size,) mesh over ``axis``, or skip when the forced CPU
    topology (tests/conftest.py) has fewer devices."""
    if len(jax.devices()) < size:
        pytest.skip(f"needs {size} devices (conftest forces 4 on CPU)")
    return jax.make_mesh((size,), (axis,))


def round_metric_specs():
    """shard_map out_specs for the metrics dict every federated round
    returns (loss + wire bytes + realized-cohort counters) —
    replicated scalars, keyed off the ONE list in core.federated."""
    return {k: P() for k in ROUND_METRIC_KEYS}
