"""Quickstart: Zampling in 60 lines.

Reparametrize a small MLP with w = Q z (m/n = 4, d = 5), train the
probability vector by sampling (LOCAL ZAMPLING, paper §1.3), and show
that sampled networks match the expected network's accuracy.

  PYTHONPATH=src python examples/quickstart.py
"""

import jax
import jax.numpy as jnp

from repro.core import ZamplingConfig, build_specs, init_state
from repro.data import make_teacher_dataset
from repro.models.mlp import SMALL_DIMS, init_mlp_params, mlp_accuracy, mlp_loss
from repro.train import LocalTrainConfig, evaluate, train_local_zampling

ds = make_teacher_dataset(n_train=6000, n_test=1200, seed=0)
test_batch = {"x": jnp.asarray(ds.x_test), "y": jnp.asarray(ds.y_test)}

# 1. template network -> QSpecs (the influence matrix, never materialized)
template = init_mlp_params(jax.random.PRNGKey(0), SMALL_DIMS)
zspecs = build_specs(
    template, ZamplingConfig(compression=4.0, d=5, window=128, min_size=128)
)
print(f"weights m={zspecs.m_total}, trainable n={zspecs.n_total} "
      f"({zspecs.compression:.1f}x compression)")
bits = zspecs.comm_bits_per_round()
print(f"federated client upload: {bits['client_up']} bits vs naive "
      f"{bits['naive_client_up']} ({bits['naive_client_up']/bits['client_up']:.0f}x)")

# 2. train-by-sampling: fresh Bernoulli mask every forward pass
state = init_state(jax.random.PRNGKey(1), zspecs, dense_init=template)
batches = ({"x": jnp.asarray(x), "y": jnp.asarray(y)}
           for x, y in ds.batches(128, seed=0))
state, hist = train_local_zampling(
    zspecs, state, mlp_loss, batches,
    LocalTrainConfig(steps=800, lr=1e-2),
)
print(f"loss: {hist['loss'][0]:.3f} -> {hist['loss'][-1]:.3f}")

# 3. evaluate sampled vs expected networks
acc = jax.jit(lambda p: mlp_accuracy(p, test_batch))
mean_s, std_s = evaluate(zspecs, state, acc, jax.random.PRNGKey(2),
                         n_samples=20)
mean_e, _ = evaluate(zspecs, state, acc, jax.random.PRNGKey(2),
                     mode="continuous")
print(f"sampled accuracy  {mean_s:.3f} +- {std_s:.3f}")
print(f"expected accuracy {mean_e:.3f}  (paper: the two should be close "
      f"after training-by-sampling)")
