"""FEDERATED ZAMPLING end-to-end (paper §3.2 setup, CPU scale).

10 clients, MNISTFC-family network, m/n = 8: each round the clients
upload n BITS (the sampled masks) instead of 32m float bits — a 256x
reduction — and the server averages masks into the new probability
vector.  ``--aggregate`` picks the wire transport (mean_f32 baseline,
psum_u32 popcount psum, allgather_packed raw lanes; all bit-exact
against each other — only the measured bytes differ).  ``--downlink``
picks the server broadcast codec (f32 oracle, u16/u8 quantized
probability words — 2x/4x less downlink; the carried state between
rounds IS the encoded wire representation, and eval samples networks
straight from it).

Rounds run through the ``federated_fit`` scan driver: the loop below
compiles ONE (block, K, E)-shaped program and re-dispatches it per
eval block, instead of one dispatch (and, across (K, E) changes, one
compile) per round.

  PYTHONPATH=src python examples/federated_mnistfc.py [--rounds 25]
"""

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.comm.metering import downlink_table, round_wire_report, wire_table
from repro.core import (
    FederatedConfig, ZamplingConfig, build_specs, encode_state, init_state,
)
from repro.data import client_batch_stream, iid_client_split, make_teacher_dataset
from repro.models.mlp import SMALL_DIMS, init_mlp_params, mlp_accuracy, mlp_loss
from repro.train import evaluate, federated_fit

ap = argparse.ArgumentParser()
ap.add_argument("--rounds", type=int, default=25)
ap.add_argument("--clients", type=int, default=10)
ap.add_argument("--local-steps", type=int, default=30)
ap.add_argument("--compression", type=float, default=8.0)
ap.add_argument("--aggregate", default="psum_u32",
                help="wire transport: mean_f32 | psum_u32 | allgather_packed")
ap.add_argument("--downlink", default="u8",
                help="server broadcast codec: f32 | u16 | u8")
ap.add_argument("--block", type=int, default=5,
                help="rounds per compiled scan block (and eval period)")
args = ap.parse_args()

ds = make_teacher_dataset(n_train=8000, n_test=1500, seed=0)
template = init_mlp_params(jax.random.PRNGKey(0), SMALL_DIMS)
zspecs = build_specs(template, ZamplingConfig(
    compression=args.compression, d=10, window=128, min_size=128))
state = init_state(jax.random.PRNGKey(1), zspecs, dense_init=template)

rep = round_wire_report(zspecs, args.aggregate, args.clients,
                        downlink=args.downlink)
print(f"m={zspecs.m_total} n={zspecs.n_total}; transport={rep['transport']}: "
      f"client upload {rep['uplink_bytes_per_client']/1024:.1f} KiB/round vs "
      f"naive f32 {rep['naive_uplink_bytes_per_client']/1024:.1f} KiB "
      f"({rep['naive_uplink_bytes_per_client']/rep['uplink_bytes_per_client']:.0f}x less)")
for row in wire_table(zspecs, args.clients, downlink=args.downlink):
    print(f"  {row['strategy']:>17}: {row['uplink_bytes_per_client']/1024:8.1f}"
          f" KiB/client/round ({row['uplink_vs_f32']:.4f}x of f32)")
print(f"downlink codec={rep['downlink']}: server broadcast "
      f"{rep['downlink_bytes_per_client']/1024:.1f} KiB/client/round "
      f"({rep['downlink_vs_f32']:.4f}x of f32)")
for row in downlink_table(zspecs, args.clients, aggregate=args.aggregate):
    print(f"  {row['codec']:>17}: {row['downlink_bytes_per_client']/1024:8.1f}"
          f" KiB/client/round ({row['downlink_vs_f32']:.4f}x of f32)")

clients = iid_client_split(ds, args.clients)
stream = client_batch_stream(clients, 64, args.local_steps, seed=0)
fcfg = FederatedConfig(num_clients=args.clients,
                       local_steps=args.local_steps, local_lr=0.5,
                       aggregate=args.aggregate, downlink=args.downlink)
# the round carry is the ENCODED broadcast: quantized codecs carry
# uint8/uint16 wire words between rounds, never an f32 score slab
state = encode_state(zspecs, fcfg, state)
acc = jax.jit(lambda p: mlp_accuracy(
    p, {"x": jnp.asarray(ds.x_test), "y": jnp.asarray(ds.y_test)}))


# ONE compile for the whole run: every block has the same
# (block, K, E, batch) shape, so this traces exactly once.
@jax.jit
def fit_block(state, batches, key):
    return federated_fit(zspecs, state, mlp_loss, batches, key, fcfg)


key = jax.random.PRNGKey(0)
done = 0
while done < args.rounds:
    # a tail block smaller than --block recompiles once for its shape
    r = min(args.block, args.rounds - done)
    xs, ys = zip(*(next(stream) for _ in range(r)))
    key, sub = jax.random.split(key)
    state, mets = fit_block(
        state,
        {"x": jnp.asarray(np.stack(xs)), "y": jnp.asarray(np.stack(ys))},
        sub,
    )
    done += r
    ms, std = evaluate(zspecs, state, acc, jax.random.PRNGKey(3),
                       n_samples=10)
    losses = np.asarray(mets["loss"])
    print(f"round {done:3d}: loss={losses[-1]:.3f} "
          f"(block mean {losses.mean():.3f}) "
          f"sampled-acc={ms:.3f}+-{std:.3f}")
print("done — every upload was a binary mask and every broadcast was "
      f"{args.downlink} wire words, never a naive float tensor.")
