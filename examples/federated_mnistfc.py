"""FEDERATED ZAMPLING end-to-end (paper §3.2 setup, CPU scale).

10 clients, MNISTFC-family network, m/n = 8: each round the clients
upload n BITS (the sampled masks) instead of 32m float bits — a 256x
reduction — and the server averages masks into the new probability
vector.  ``--aggregate`` picks the wire transport (mean_f32 baseline,
psum_u32 popcount psum, allgather_packed raw lanes; all bit-exact
against each other — only the measured bytes differ).  ``--downlink``
picks the server broadcast codec (f32 oracle, u16/u8 quantized
probability words — 2x/4x less downlink; the carried state between
rounds IS the encoded wire representation, and eval samples networks
straight from it).

Rounds run through the ``federated_fit`` scan driver: the loop below
compiles ONE (block, K, E)-shaped program and re-dispatches it per
eval block, instead of one dispatch (and, across (K, E) changes, one
compile) per round.

Partial participation (``repro.fault``): ``--population N`` switches
to a Dirichlet-split population of N virtual clients of UNEQUAL size,
of which ``--cohort K`` are sampled each round by the deterministic
counter-hash cohort draw; ``--dropout-rate p`` makes each sampled
client drop the round with probability p (drawn reproducibly per
(round, client)).  The server then computes the sample-count-weighted
mean over the realized survivors and the run prints a per-round
participation/fault table with the REALIZED wire bytes.

  PYTHONPATH=src python examples/federated_mnistfc.py [--rounds 25]
  PYTHONPATH=src python examples/federated_mnistfc.py \
      --population 100 --cohort 10 --dropout-rate 0.2
"""

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.comm.metering import downlink_table, round_wire_report, wire_table
from repro.core import (
    FederatedConfig, ZamplingConfig, build_specs, encode_state, init_state,
)
from repro.data import (
    client_batch_stream,
    cohort_batch_stream,
    dirichlet_client_split,
    iid_client_split,
    make_teacher_dataset,
)
from repro.fault import ClientPopulation, FaultPlan
from repro.models.mlp import SMALL_DIMS, init_mlp_params, mlp_accuracy, mlp_loss
from repro.train import evaluate, federated_fit

ap = argparse.ArgumentParser()
ap.add_argument("--rounds", type=int, default=25)
ap.add_argument("--clients", type=int, default=10)
ap.add_argument("--local-steps", type=int, default=30)
ap.add_argument("--compression", type=float, default=8.0)
ap.add_argument("--aggregate", default="psum_u32",
                help="wire transport: mean_f32 | psum_u32 | allgather_packed")
ap.add_argument("--downlink", default="u8",
                help="server broadcast codec: f32 | u16 | u8 | "
                     "packed4 | packed2 (sub-byte words in uint32 lanes)")
ap.add_argument("--downlink-schedule", default="constant",
                help="downlink rate schedule: constant | cosine (anneal "
                     "width up over --rounds) | frontier (per-tensor "
                     "width from the measured draw-word flip fraction); "
                     "the realized per-round bytes are metered in the "
                     "'down' column")
ap.add_argument("--schedule-b-min", type=int, default=2,
                help="minimum scheduled width in bits (cosine start / "
                     "frontier floor)")
ap.add_argument("--block", type=int, default=5,
                help="rounds per compiled scan block (and eval period)")
ap.add_argument("--population", type=int, default=0,
                help="total virtual clients N (0 = every client "
                     "participates every round, the paper's setup)")
ap.add_argument("--cohort", type=int, default=0,
                help="clients sampled per round (default: --clients)")
ap.add_argument("--dropout-rate", type=float, default=0.0,
                help="per-round probability a sampled client drops")
ap.add_argument("--beta", type=float, default=0.5,
                help="Dirichlet concentration of the non-IID split")
ap.add_argument("--min-clients", type=int, default=1,
                help="skip rounds with fewer survivors than this")
ap.add_argument("--stream-chunk", type=int, default=0,
                help="fold uploads this many clients at a time (streaming "
                     "cohort accumulator; 0 = one-shot slab aggregation; "
                     "scores are bit-identical either way)")
ap.add_argument("--het-table", action="store_true",
                help="print the heterogeneity table (accuracy vs Dirichlet "
                     "beta per downlink codec) and exit")
args = ap.parse_args()

if args.het_table:
    from repro.experiments import run_heterogeneity

    print("accuracy vs Dirichlet beta x downlink codec (quick grid)")
    print(f"{'beta':>6} {'codec':>6} {'acc':>7} {'std':>6} "
          f"{'down KiB':>9} {'vs f32':>7}")
    for row in run_heterogeneity(quick=True):
        print(f"{row['beta']:>6.2f} {row['codec']:>6} "
              f"{row['final_sampled_acc']:>7.3f} {row['sampled_std']:>6.3f} "
              f"{row['downlink_bytes_per_client'] / 1024:>9.1f} "
              f"{row['downlink_vs_f32']:>7.4f}")
    raise SystemExit(0)

use_cohort = args.population > 0
cohort = args.cohort or args.clients
if use_cohort and cohort > args.population:
    ap.error(f"--cohort {cohort} exceeds --population {args.population}")

ds = make_teacher_dataset(n_train=8000, n_test=1500, seed=0)
template = init_mlp_params(jax.random.PRNGKey(0), SMALL_DIMS)
zspecs = build_specs(template, ZamplingConfig(
    compression=args.compression, d=10, window=128, min_size=128))
state = init_state(jax.random.PRNGKey(1), zspecs, dense_init=template)

rep = round_wire_report(zspecs, args.aggregate,
                        cohort if use_cohort else args.clients,
                        downlink=args.downlink)
print(f"m={zspecs.m_total} n={zspecs.n_total}; transport={rep['transport']}: "
      f"client upload {rep['uplink_bytes_per_client']/1024:.1f} KiB/round vs "
      f"naive f32 {rep['naive_uplink_bytes_per_client']/1024:.1f} KiB "
      f"({rep['naive_uplink_bytes_per_client']/rep['uplink_bytes_per_client']:.0f}x less)")
for row in wire_table(zspecs, args.clients, downlink=args.downlink):
    print(f"  {row['strategy']:>17}: {row['uplink_bytes_per_client']/1024:8.1f}"
          f" KiB/client/round ({row['uplink_vs_f32']:.4f}x of f32)")
print(f"downlink codec={rep['downlink']}: server broadcast "
      f"{rep['downlink_bytes_per_client']/1024:.1f} KiB/client/round "
      f"({rep['downlink_vs_f32']:.4f}x of f32)")
for row in downlink_table(zspecs, args.clients, aggregate=args.aggregate):
    print(f"  {row['codec']:>17}: {row['downlink_bytes_per_client']/1024:8.1f}"
          f" KiB/client/round ({row['downlink_vs_f32']:.4f}x of f32)")

if use_cohort:
    clients, hist = dirichlet_client_split(ds, args.population,
                                           beta=args.beta, seed=0)
    sizes = hist.sum(axis=1)
    pop = ClientPopulation(args.population,
                           sample_counts=tuple(int(s) for s in sizes),
                           seed=0)
    plan = FaultPlan(dropout=args.dropout_rate)
    stream = cohort_batch_stream(clients, pop, cohort, 64,
                                 args.local_steps, seed=0)
    print(f"population N={args.population} (Dirichlet beta={args.beta}, "
          f"client sizes {sizes.min()}..{sizes.max()}), cohort K={cohort}, "
          f"dropout p={args.dropout_rate}")
else:
    plan = None
    clients = iid_client_split(ds, args.clients)
    stream = client_batch_stream(clients, 64, args.local_steps, seed=0)
sched_kw = {}
if args.downlink_schedule != "constant":
    sched_kw = {"downlink_schedule": args.downlink_schedule,
                "schedule_b_min": args.schedule_b_min}
    if args.downlink_schedule == "cosine":
        sched_kw["schedule_rounds"] = args.rounds
fcfg = FederatedConfig(num_clients=cohort if use_cohort else args.clients,
                       local_steps=args.local_steps, local_lr=0.5,
                       aggregate=args.aggregate, downlink=args.downlink,
                       min_clients=args.min_clients,
                       stream_chunk=args.stream_chunk, **sched_kw)
# the round carry is the ENCODED broadcast: quantized codecs carry
# uint8/uint16 wire words between rounds, never an f32 score slab
state = encode_state(zspecs, fcfg, state)
acc = jax.jit(lambda p: mlp_accuracy(
    p, {"x": jnp.asarray(ds.x_test), "y": jnp.asarray(ds.y_test)}))


# ONE compile for the whole run: every block has the same
# (block, K, E, batch) shape, so this traces exactly once.
if use_cohort:
    @jax.jit
    def fit_block(state, batches, key, ids, weights):
        return federated_fit(zspecs, state, mlp_loss, batches, key, fcfg,
                             client_ids=ids, weights=weights, faults=plan)
else:
    @jax.jit
    def fit_block(state, batches, key):
        return federated_fit(zspecs, state, mlp_loss, batches, key, fcfg)


FAULT_COLS = ("num_participating", "num_dropped", "num_stragglers",
              "num_corrupt", "num_duplicates", "round_skipped")

key = jax.random.PRNGKey(0)
done = 0
total_down = 0.0
if use_cohort:
    print(f"{'round':>5} {'part':>4} {'drop':>4} {'strag':>5} {'corr':>4} "
          f"{'dup':>3} {'skip':>4} {'w_sum':>7} {'uplink KiB':>10} "
          f"{'down KiB':>8}")
while done < args.rounds:
    # a tail block smaller than --block recompiles once for its shape
    r = min(args.block, args.rounds - done)
    key, sub = jax.random.split(key)
    if use_cohort:
        ids, ws, xs, ys = zip(*(next(stream) for _ in range(r)))
        state, mets = fit_block(
            state,
            {"x": jnp.asarray(np.stack(xs)), "y": jnp.asarray(np.stack(ys))},
            sub, jnp.asarray(np.stack(ids)), jnp.asarray(np.stack(ws)),
        )
        cols = {c: np.asarray(mets[c]) for c in FAULT_COLS}
        up = np.asarray(mets["uplink_bytes_round"])
        down = np.asarray(mets["downlink_bytes_per_client"])
        wsum = np.asarray(mets["weight_sum"])
        for j in range(r):
            print(f"{done + j:>5} {cols['num_participating'][j]:>4.0f} "
                  f"{cols['num_dropped'][j]:>4.0f} "
                  f"{cols['num_stragglers'][j]:>5.0f} "
                  f"{cols['num_corrupt'][j]:>4.0f} "
                  f"{cols['num_duplicates'][j]:>3.0f} "
                  f"{cols['round_skipped'][j]:>4.0f} "
                  f"{wsum[j]:>7.0f} {up[j] / 1024:>10.1f} "
                  f"{down[j] / 1024:>8.1f}")
    else:
        xs, ys = zip(*(next(stream) for _ in range(r)))
        state, mets = fit_block(
            state,
            {"x": jnp.asarray(np.stack(xs)), "y": jnp.asarray(np.stack(ys))},
            sub,
        )
    done += r
    ms, std = evaluate(zspecs, state, acc, jax.random.PRNGKey(3),
                       n_samples=10, carried=args.downlink)
    losses = np.asarray(mets["loss"])
    # realized (metered) downlink bytes per client, per round — a
    # scheduled run charges only the scheduled width + lane padding
    down = np.asarray(mets["downlink_bytes_per_client"], np.float64)
    total_down += float(down.sum())
    down_col = " ".join(f"{b / 1024:.1f}" for b in down)
    print(f"round {done:3d}: loss={losses[-1]:.3f} "
          f"(block mean {losses.mean():.3f}) "
          f"sampled-acc={ms:.3f}+-{std:.3f} down/client KiB: {down_col}")
print(f"cumulative downlink: {total_down / 1024:.1f} KiB/client over "
      f"{args.rounds} rounds ({args.downlink}, "
      f"schedule={args.downlink_schedule})")
print("done — every upload was a binary mask and every broadcast was "
      f"{args.downlink} wire words, never a naive float tensor.")
