"""FEDERATED ZAMPLING end-to-end (paper §3.2 setup, CPU scale).

10 clients, MNISTFC-family network, m/n = 8: each round the clients
upload n BITS (the sampled masks) instead of 32m float bits — a 256x
reduction — and the server averages masks into the new probability
vector.

  PYTHONPATH=src python examples/federated_mnistfc.py [--rounds 25]
"""

import argparse

import jax
import jax.numpy as jnp

from repro.core import (
    FederatedConfig, ZamplingConfig, build_specs, federated_round, init_state,
)
from repro.data import client_batch_stream, iid_client_split, make_teacher_dataset
from repro.models.mlp import SMALL_DIMS, init_mlp_params, mlp_accuracy, mlp_loss
from repro.train import evaluate

ap = argparse.ArgumentParser()
ap.add_argument("--rounds", type=int, default=25)
ap.add_argument("--clients", type=int, default=10)
ap.add_argument("--local-steps", type=int, default=30)
ap.add_argument("--compression", type=float, default=8.0)
args = ap.parse_args()

ds = make_teacher_dataset(n_train=8000, n_test=1500, seed=0)
template = init_mlp_params(jax.random.PRNGKey(0), SMALL_DIMS)
zspecs = build_specs(template, ZamplingConfig(
    compression=args.compression, d=10, window=128, min_size=128))
state = init_state(jax.random.PRNGKey(1), zspecs, dense_init=template)

bits = zspecs.comm_bits_per_round()
print(f"m={zspecs.m_total} n={zspecs.n_total}; client upload "
      f"{bits['client_up']/8/1024:.1f} KiB/round vs naive "
      f"{bits['naive_client_up']/8/1024:.1f} KiB "
      f"({bits['naive_client_up']/bits['client_up']:.0f}x less)")

clients = iid_client_split(ds, args.clients)
stream = client_batch_stream(clients, 64, args.local_steps, seed=0)
fcfg = FederatedConfig(num_clients=args.clients,
                       local_steps=args.local_steps, local_lr=0.5)
acc = jax.jit(lambda p: mlp_accuracy(
    p, {"x": jnp.asarray(ds.x_test), "y": jnp.asarray(ds.y_test)}))


@jax.jit
def round_fn(state, batch, key):
    return federated_round(zspecs, state, mlp_loss, batch, key, fcfg)


key = jax.random.PRNGKey(0)
for r in range(args.rounds):
    xs, ys = next(stream)
    key, sub = jax.random.split(key)
    state, met = round_fn(state, {"x": jnp.asarray(xs), "y": jnp.asarray(ys)},
                          sub)
    if (r + 1) % 5 == 0:
        ms, std = evaluate(zspecs, state, acc, jax.random.PRNGKey(3),
                           n_samples=10)
        print(f"round {r+1:3d}: loss={met['loss']:.3f} "
              f"sampled-acc={ms:.3f}+-{std:.3f}")
print("done — every upload in that run was a binary mask, never a float.")
