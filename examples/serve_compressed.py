"""Serve a generative LM from the COMPRESSED Zampling artifact.

The deployment object is the encoded score broadcast (u8/u16 wire
words or f32 scores) + dense leaves + one uint32 draw word.  Two ways
to decode against it:

  --mode load       reconstruct w = Q Bern(f(s)) once, serve resident
                    f32 tensors (the PR-5-era trade);
  --mode streaming  never materialize a weight: every decode linear
                    regenerates its (window, bm) block inside the
                    contraction (kernels.ops serve section).  Bit-
                    identical logits, ~codec.bits/32 of the resident
                    zampled bytes.

With --delta, a synthetic converged round (1% of scores move) is
re-encoded under the SAME dither word and shipped as an XOR word
delta, hot-swapping the live server; the table shows delta-vs-full
broadcast bytes per codec.

  PYTHONPATH=src python examples/serve_compressed.py \
      --mode streaming --delta
"""

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs.registry import get_arch
from repro.core import ZamplingConfig, build_specs, init_state, sample_masks
from repro.serve import (
    apply_delta,
    build_serve_engine,
    delta_report,
    make_delta,
    make_generator,
    make_serve_state,
    serve_from_compressed,
)
from repro.models import build_model

parser = argparse.ArgumentParser()
parser.add_argument("--mode", choices=["load", "streaming"],
                    default="streaming",
                    help="serving mode for the timed generation")
parser.add_argument("--delta", action="store_true",
                    help="also demo the XOR delta hot-swap round update")
parser.add_argument("--codec", choices=["f32", "u16", "u8"], default="u8",
                    help="downlink codec carried by the serving state")
parser.add_argument("--new-tokens", type=int, default=8)
args = parser.parse_args()

cfg = get_arch("qwen2-0.5b").reduced()
model = build_model(cfg)
params_t = jax.eval_shape(model.init_params, jax.random.PRNGKey(0))
zspecs = build_specs(params_t, ZamplingConfig(compression=8, d=8,
                                              min_size=1024))
state = init_state(jax.random.PRNGKey(1), zspecs,
                   dense_init=model.init_params(jax.random.PRNGKey(0)))

masks = sample_masks(zspecs, state, jax.random.PRNGKey(2))
mask_bits = sum(int(m.shape[0]) for m in masks.values())
print(f"compressed artifact: {mask_bits/8/1024:.1f} KiB of masks for "
      f"{zspecs.m_total/1e6:.1f}M weights "
      f"(+{sum(int(jnp.size(v)) for v in state['dense'].values())/1e3:.0f}K "
      f"dense params)")

prompt = jnp.asarray([[5, 17, 42, 7], [1, 2, 3, 4]], jnp.int32)
out = serve_from_compressed(model, zspecs, masks, state["dense"], prompt,
                            max_new_tokens=8, seq_len=32)
print("batched generation (legacy mask artifact, reconstruct-on-load):")
for row in out.tolist():
    print("  ", row)

# --- the Zampling-native serving state -----------------------------------
sstate = make_serve_state(zspecs, state, jax.random.PRNGKey(2),
                          downlink=args.codec, dither_word=0)
B, Sp = prompt.shape
seq_len = Sp + args.new_tokens

print(f"\nresident zampled state ({args.codec} codec) and decode "
      f"throughput, mode={args.mode} timed:")
print(f"  {'mode':<11} {'resident KiB':>12} {'tok/s':>10}")
rows = {}
for mode in ("load", "streaming"):
    engine = build_serve_engine(model, sstate, mode=mode)
    arrays = engine.arrays_of(sstate)
    run = make_generator(engine.step, args.new_tokens)
    cache = engine.init_cache(B, seq_len)
    toks, _ = run(arrays, cache, prompt, jax.random.PRNGKey(0))
    toks.block_until_ready()  # compile + correctness reference
    rows[mode] = toks
    resident = (sstate.loaded_zampled_bytes() if mode == "load"
                else sstate.resident_zampled_bytes())
    if mode == args.mode:
        t0 = time.perf_counter()
        out2, _ = run(arrays, cache, prompt, jax.random.PRNGKey(0))
        out2.block_until_ready()
        dt = time.perf_counter() - t0
        tps = f"{B * args.new_tokens / dt:10.1f}"
    else:
        tps = f"{'-':>10}"
    print(f"  {mode:<11} {resident/1024:12.1f} {tps}")
assert (rows["load"] == rows["streaming"]).all(), "modes must agree bitwise"
print("  (modes verified bit-identical; dense leaves "
      f"{sstate.dense_bytes()/1024:.1f} KiB in all modes)")

if args.delta:
    print("\ndelta hot-swap (synthetic converged round: 1% of scores move):")
    key = jax.random.PRNGKey(7)
    scores2 = {}
    for p, s in state["scores"].items():
        k1, k2, key = jax.random.split(key, 3)
        touch = jax.random.bernoulli(k1, 0.01, s.shape)
        scores2[p] = jnp.where(
            touch, s + 0.05 * jax.random.normal(k2, s.shape), s)
    state2 = {"scores": scores2, "dense": state["dense"]}
    print(f"  {'codec':<6} {'changed':>8} {'delta KiB':>10} "
          f"{'full KiB':>9} {'ratio':>7}")
    for codec in ("f32", "u16", "u8"):
        s1 = make_serve_state(zspecs, state, jax.random.PRNGKey(2),
                              downlink=codec, dither_word=0)
        s2 = make_serve_state(zspecs, state2, jax.random.PRNGKey(2),
                              downlink=codec, dither_word=0)
        rep = delta_report(s1, s2)
        print(f"  {codec:<6} {rep['words_changed']:>8} "
              f"{rep['delta_bytes']/1024:10.1f} "
              f"{rep['full_bytes']/1024:9.1f} "
              f"{rep['delta_vs_full']:7.4f}")
    swapped = apply_delta(sstate, make_delta(
        sstate, make_serve_state(zspecs, state2, jax.random.PRNGKey(2),
                                 downlink=args.codec, dither_word=0)))
    engine = build_serve_engine(model, sstate, mode=args.mode)
    run = make_generator(engine.step, args.new_tokens)
    cache = engine.init_cache(B, seq_len)
    t1, _ = run(engine.arrays_of(swapped), cache, prompt,
                jax.random.PRNGKey(0))
    print("  post-swap generation (same compiled step, new words):")
    for row in jnp.concatenate([prompt, t1], axis=1).tolist():
        print("  ", row)
