"""Serve a generative LM from the COMPRESSED Zampling artifact.

The deployment object is (Q seed, z bits, dense leaves) — ~m/32 bits of
model state. Weights are reconstructed once on load (w = Q z) and the
model serves batched greedy generation through the KV-cache decode path
(the same serve_step the 32k/500k dry-runs lower at production scale).

  PYTHONPATH=src python examples/serve_compressed.py
"""

import jax
import jax.numpy as jnp

from repro.configs.registry import get_arch
from repro.core import ZamplingConfig, build_specs, init_state, sample_masks
from repro.models import build_model
from repro.serve import generate, serve_from_compressed

cfg = get_arch("qwen2-0.5b").reduced()
model = build_model(cfg)
params_t = jax.eval_shape(model.init_params, jax.random.PRNGKey(0))
zspecs = build_specs(params_t, ZamplingConfig(compression=8, d=8,
                                              min_size=1024))
state = init_state(jax.random.PRNGKey(1), zspecs,
                   dense_init=model.init_params(jax.random.PRNGKey(0)))

masks = sample_masks(zspecs, state, jax.random.PRNGKey(2))
mask_bits = sum(int(m.shape[0]) for m in masks.values())
print(f"compressed artifact: {mask_bits/8/1024:.1f} KiB of masks for "
      f"{zspecs.m_total/1e6:.1f}M weights "
      f"(+{sum(int(jnp.size(v)) for v in state['dense'].values())/1e3:.0f}K "
      f"dense params)")

prompt = jnp.asarray([[5, 17, 42, 7], [1, 2, 3, 4]], jnp.int32)
out = serve_from_compressed(model, zspecs, masks, state["dense"], prompt,
                            max_new_tokens=8, seq_len=32)
print("batched generation:")
for row in out.tolist():
    print("  ", row)
print("(weights never left the (seed, z) representation until load)")
