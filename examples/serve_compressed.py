"""Serve a generative LM from the COMPRESSED Zampling artifact.

The deployment object is the encoded score broadcast (u8/u16 wire
words or f32 scores) + dense leaves + one uint32 draw word.  Three
ways to decode against it:

  --mode load       reconstruct w = Q Bern(f(s)) once, serve resident
                    f32 tensors (the PR-5-era trade);
  --mode streaming  never materialize a weight: every decode linear
                    regenerates its (window, bm) block inside the
                    contraction (kernels.ops serve section);
  --mode cached     streaming plus the hot-block tile pool: the first
                    --cache-budget-kib of canonical tiles serve
                    resident, the rest stream — the dialable midpoint.

Bit-identical logits in all three; the resident table below meters
the FULL node (words + tile pool + lane KV + dense), not words only
(comm.metering.serve_resident_bytes).

The batched section drives the continuous-batching scheduler: ragged
prompts admitted/retired per step over fixed lanes, bitwise equal to
the single-request path.  With --delta, a synthetic converged round
(1% of scores move) ships as an XOR word delta and hot-swaps the live
scheduler MID-FLIGHT — the hot-block cache survives, dropping only
the tiles whose drawn mask bits actually flipped.

  PYTHONPATH=src python examples/serve_compressed.py \
      --mode cached --delta
"""

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs.registry import get_arch
from repro.comm.metering import serve_resident_bytes
from repro.core import ZamplingConfig, build_specs, init_state, sample_masks
from repro.serve import (
    ServeConfig,
    ServeScheduler,
    apply_delta,
    build_cache,
    build_serve_engine,
    delta_report,
    make_delta,
    make_generator,
    make_serve_state,
    serve_from_compressed,
)
from repro.models import build_model

parser = argparse.ArgumentParser()
parser.add_argument("--mode", choices=["load", "streaming", "cached"],
                    default="cached",
                    help="serving mode for the timed generation")
parser.add_argument("--delta", action="store_true",
                    help="also demo the XOR delta hot-swap round update")
parser.add_argument("--codec", choices=["f32", "u16", "u8"], default="u8",
                    help="downlink codec carried by the serving state")
parser.add_argument("--cache-budget-kib", type=int, default=2048,
                    help="hot-block tile pool budget (mode=cached)")
parser.add_argument("--lanes", type=int, default=4,
                    help="scheduler batch lanes")
parser.add_argument("--new-tokens", type=int, default=8)
args = parser.parse_args()

cfg = get_arch("qwen2-0.5b").reduced()
model = build_model(cfg)
params_t = jax.eval_shape(model.init_params, jax.random.PRNGKey(0))
zspecs = build_specs(params_t, ZamplingConfig(compression=8, d=8,
                                              min_size=1024))
state = init_state(jax.random.PRNGKey(1), zspecs,
                   dense_init=model.init_params(jax.random.PRNGKey(0)))

masks = sample_masks(zspecs, state, jax.random.PRNGKey(2))
mask_bits = sum(int(m.shape[0]) for m in masks.values())
print(f"compressed artifact: {mask_bits/8/1024:.1f} KiB of masks for "
      f"{zspecs.m_total/1e6:.1f}M weights "
      f"(+{sum(int(jnp.size(v)) for v in state['dense'].values())/1e3:.0f}K "
      f"dense params)")

prompt = jnp.asarray([[5, 17, 42, 7], [1, 2, 3, 4]], jnp.int32)
out = serve_from_compressed(model, zspecs, masks, state["dense"], prompt,
                            max_new_tokens=8, seq_len=32)
print("batched generation (legacy mask artifact, reconstruct-on-load):")
for row in out.tolist():
    print("  ", row)

# --- the Zampling-native serving state -----------------------------------
sstate = make_serve_state(zspecs, state, jax.random.PRNGKey(2),
                          downlink=args.codec, dither_word=0)
B, Sp = prompt.shape
seq_len = Sp + args.new_tokens
budget = args.cache_budget_kib * 1024

print(f"\nresident node state ({args.codec} codec; words + cache pool + "
      f"KV + dense) and decode throughput, mode={args.mode} timed:")
print(f"  {'mode':<11} {'zampled KiB':>12} {'cache KiB':>10} "
      f"{'KV KiB':>7} {'total KiB':>10} {'tok/s':>10}")
rows = {}
for mode in ("load", "streaming", "cached"):
    engine = build_serve_engine(model, sstate, mode=mode)
    hbc = None
    if mode == "cached":
        hbc = build_cache(sstate, ServeConfig(
            lanes=args.lanes, seq_len=seq_len,
            cache_budget_bytes=budget, mode="cached"))
    arrays = engine.arrays_of(sstate, cache=hbc)
    run = make_generator(engine.step, args.new_tokens)
    cache = engine.init_cache(B, seq_len)
    toks, _ = run(arrays, cache, prompt, jax.random.PRNGKey(0))
    toks.block_until_ready()  # compile + correctness reference
    rows[mode] = toks
    res = serve_resident_bytes(sstate, budget if mode == "cached" else 0,
                               mode=mode, kv_cache=cache)
    if mode == args.mode:
        t0 = time.perf_counter()
        out2, _ = run(arrays, cache, prompt, jax.random.PRNGKey(0))
        out2.block_until_ready()
        dt = time.perf_counter() - t0
        tps = f"{B * args.new_tokens / dt:10.1f}"
    else:
        tps = f"{'-':>10}"
    print(f"  {mode:<11} {res['zampled_bytes']/1024:12.1f} "
          f"{res['cache_bytes']/1024:10.1f} {res['kv_bytes']/1024:7.1f} "
          f"{res['total_bytes']/1024:10.1f} {tps}")
assert (rows["load"] == rows["streaming"]).all(), "modes must agree bitwise"
assert (rows["load"] == rows["cached"]).all(), "cached mode must agree too"
print("  (modes verified bit-identical; dense leaves "
      f"{sstate.dense_bytes()/1024:.1f} KiB in all modes)")

# --- continuous batching --------------------------------------------------
print(f"\ncontinuous batching: {args.lanes} lanes, ragged prompts, "
      f"mode={args.mode}:")
ragged = [[5, 17, 42, 7], [1, 2, 3], [9, 9, 1, 0, 3], [4, 4]]
scfg = ServeConfig(lanes=args.lanes,
                   seq_len=max(len(p) for p in ragged) + args.new_tokens,
                   cache_budget_bytes=budget, mode=args.mode,
                   max_new_tokens=args.new_tokens)
sched = ServeScheduler(model, sstate, scfg)
rids = {sched.submit(p): p for p in ragged}
t0 = time.perf_counter()
results = sched.run()
dt = time.perf_counter() - t0
for rid, p in rids.items():
    print("  ", p, "->", results[rid].tolist())
m = sched.metrics()
print(f"  {m['completed']} requests in {m['steps']} engine steps "
      f"({sum(len(v) for v in results.values())/dt:.1f} tok/s incl. "
      "compile)")
if "cache" in m:
    c = m["cache"]
    print(f"  cache: {c['resident_tiles']}/{c['total_tiles']} tiles "
          f"resident, {c['hits']} hits / {c['misses']} misses")

if args.delta:
    print("\ndelta hot-swap (synthetic converged round: 1% of scores move):")
    key = jax.random.PRNGKey(7)
    scores2 = {}
    for p, s in state["scores"].items():
        k1, k2, key = jax.random.split(key, 3)
        touch = jax.random.bernoulli(k1, 0.01, s.shape)
        scores2[p] = jnp.where(
            touch, s + 0.05 * jax.random.normal(k2, s.shape), s)
    state2 = {"scores": scores2, "dense": state["dense"]}
    print(f"  {'codec':<6} {'changed':>8} {'flipped':>8} {'delta KiB':>10} "
          f"{'full KiB':>9} {'ratio':>7}")
    for codec in ("f32", "u16", "u8"):
        s1 = make_serve_state(zspecs, state, jax.random.PRNGKey(2),
                              downlink=codec, dither_word=0)
        s2 = make_serve_state(zspecs, state2, jax.random.PRNGKey(2),
                              downlink=codec, dither_word=0)
        rep = delta_report(s1, s2)
        print(f"  {codec:<6} {rep['words_changed']:>8} "
              f"{rep['words_flipped']:>8} "
              f"{rep['delta_bytes']/1024:10.1f} "
              f"{rep['full_bytes']/1024:9.1f} "
              f"{rep['delta_vs_full']:7.4f}")
    delta = make_delta(sstate, make_serve_state(
        zspecs, state2, jax.random.PRNGKey(2), downlink=args.codec,
        dither_word=0))
    # swap the LIVE scheduler mid-queue: in-flight KV survives, and in
    # cached mode only flipped-bit tiles drop from the pool
    for p in ragged:
        sched.submit(p)
    sched.step_once()
    before = (sched.cache.resident_tiles if sched.cache else None)
    sched.apply_round_delta(delta)
    results2 = sched.run()
    if sched.cache is not None:
        c = sched.cache.stats()
        print(f"  cache survived swap: {c['invalidations']} tiles "
              f"invalidated of {before}, refilled to "
              f"{c['resident_tiles']}/{c['total_tiles']}")
    print("  post-swap generations (same compiled step, new words):")
    for rid in sorted(results2)[len(rids):]:
        print("  ", results2[rid].tolist())
