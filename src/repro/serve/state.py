"""Serving state: the encoded score broadcast as the ONLY zampled state.

A Zampling serving node does not hold weights.  Its entire zampled
model state is the downlink codec's encoded score words — u8/u16 wire
words (or raw f32 scores under the ``f32`` oracle codec) per zampled
leaf — plus the uint32 draw word that pins the mask draw and the small
dense leaves (norm scales, biases).  Weights exist only transiently:

 - ``mode="streaming"`` (serve.decode) contracts activations against
   the encoded words directly via ``kernels.ops.serve_matmul`` /
   ``serve_embed_rows`` — weight values live for one (window, bm)
   block and are consumed in place;
 - ``mode="load"`` calls ``reconstruct_resident`` once and serves from
   the materialized f32 tensors — the PR-5-era trade this subsystem
   exists to beat on resident bytes.

Round-to-round updates arrive as XOR deltas of the words
(serve.delta); ``ServeState.replace_arrays`` swaps the new words into
a live server without touching the compiled engine (the arrays are
jit arguments, not closure constants).
"""

from __future__ import annotations

from dataclasses import dataclass, replace
from typing import Any, Dict, Mapping, Optional

import jax.numpy as jnp

from ..comm.downlink import get_codec
from ..core.sampling import as_word, clip_probs
from ..core.zampling import ZamplingSpecs, infer_downlink, validate_carried


@dataclass(frozen=True)
class ServeState:
    """One serving node's model state.

    NOT a jax pytree: the static half (``zspecs``, ``codec``) stays in
    engine closures; the array half travels through jitted functions
    via ``arrays()`` / ``replace_arrays`` so a delta hot-swap never
    recompiles.
    """

    zspecs: ZamplingSpecs
    codec: str  # downlink codec name ('f32' | 'u16' | 'u8')
    words: Mapping[str, Any]  # path -> (n,) encoded score words
    dense: Mapping[str, Any]  # path -> dense leaf
    step: Any  # () uint32 mask draw word

    @property
    def qbits(self) -> Optional[int]:
        codec = get_codec(self.codec)
        return codec.bits if codec.quantized else None

    @property
    def qpacked(self) -> bool:
        """True when the words are uint32 lanes of a packed sub-byte
        codec (the contraction kernels unpack in-block)."""
        return bool(get_codec(self.codec).packed)

    def arrays(self) -> Dict[str, Any]:
        """The jit-visible half, as a plain dict pytree."""
        return {"words": dict(self.words), "dense": dict(self.dense),
                "step": self.step}

    def replace_arrays(self, arrays: Dict[str, Any]) -> "ServeState":
        """New state with swapped arrays (hot-swap entry point)."""
        return replace(self, words=dict(arrays["words"]),
                       dense=dict(arrays["dense"]), step=arrays["step"])

    def resident_zampled_bytes(self) -> int:
        """Bytes of resident zampled state in streaming mode: the
        encoded words alone (+4 for the draw word)."""
        return sum(int(jnp.asarray(w).nbytes) for w in self.words.values()) + 4

    def loaded_zampled_bytes(self) -> int:
        """Bytes of resident zampled state in reconstruct-on-load mode:
        the materialized f32 tensors."""
        return sum(4 * s.m for s in self.zspecs.specs.values())

    def dense_bytes(self) -> int:
        return sum(int(jnp.asarray(v).nbytes) for v in self.dense.values())


def make_serve_state(zspecs: ZamplingSpecs, state, key, *,
                     downlink: Optional[str] = None,
                     dither_word=0,
                     carried: Optional[str] = None) -> ServeState:
    """Build a ServeState from a training-side ``state`` dict.

    ``state``: {"scores": {path: scores-or-wire-words}, "dense": ...}.
    ``key``: PRNG key or uint32 word pinning the serving mask draw
    (``core.sampling.as_word`` — same derivation as ``sample_weights``).
    ``downlink``: target codec; default keeps the state's own
    representation.  An f32 state is encoded here with ``dither_word``
    keying the dither stream — servers that broadcast deltas MUST
    reuse one dither word across rounds (see serve.delta) so unchanged
    scores keep unchanged words.

    ``carried``: the codec the score leaves ALREADY carry — pass the
    checkpoint's tag (``checkpoint.checkpoint_downlink``) when serving
    from a saved carry, instead of letting ``infer_downlink`` sniff
    dtypes (a uint8 leaf is ambiguous: wire words and token ids look
    alike, and the packed sub-byte codecs ALL share the uint32 lane
    carrier — only the tag can tell ``packed4`` from ``packed2``).
    Validated against the leaves' full wire signature (dtype + lane
    count, ``core.zampling.validate_carried``); default falls back to
    sniffing for in-process states, whose provenance is known —
    sniffing raises on the ambiguous uint32 carrier.
    """
    if carried is not None:
        carried = validate_carried(zspecs, state["scores"], carried)
    else:
        carried = infer_downlink(state["scores"])
    target = downlink or carried
    if carried == target:
        words = dict(state["scores"])
    elif carried != "f32":
        raise ValueError(
            f"state already carries codec {carried!r}; decode before "
            f"re-encoding as {target!r}"
        )
    else:
        codec = get_codec(target)
        w = as_word(dither_word)
        words = {path: codec.encode(spec, state["scores"][path], w)
                 for path, spec in zspecs.specs.items()}
    return ServeState(zspecs=zspecs, codec=target, words=words,
                      dense=dict(state["dense"]),
                      step=jnp.asarray(as_word(key), jnp.uint32))


def reconstruct_resident(sstate: ServeState,
                         impl: Optional[str] = None) -> Dict[str, Any]:
    """Reconstruct-on-load: materialize every zampled leaf once.

    Returns {path: W (spec.shape) f32} — the resident state of
    ``mode="load"``.  Values are bit-identical to the weights the
    streaming path regenerates per block (same draw word, same edge
    streams), which is what makes the two modes comparable
    bit-for-bit.
    """
    from ..kernels import ops  # kernels sit above comm/core

    qbits = sstate.qbits
    qpacked = sstate.qpacked
    out = {}
    for path, spec in sstate.zspecs.specs.items():
        w = sstate.words[path]
        operand = w if qbits is not None else clip_probs(
            jnp.asarray(w).astype(jnp.float32))
        out[path] = ops.sample_reconstruct(spec, operand, sstate.step,
                                           qbits=qbits, qpacked=qpacked,
                                           impl=impl)
    return out
