"""Hot-block tile cache: the budgeted midpoint of the load/stream trade.

PR 8 left serving with a binary choice: ``mode="load"`` holds 32 bits
per weight and decodes fast, ``mode="streaming"`` holds only the
encoded score words and regenerates every (window, bm) weight block
inside the contraction, ~3-7x slower on CPU.  This module makes the
trade a DIAL: a byte-budgeted pool of materialized f32 tiles — one
pool row per canonical contraction block (``kernels/ops.py`` serve
section; key (path, group, block)) — sitting between the two extremes.

 - ``cache_budget_bytes = 0``  → pure streaming (no tile resident);
 - ``cache_budget_bytes >= 4·m`` of the zampled leaves → fully loaded
   (every block hits the pool at resident-matmul speed);
 - anything between → the first ``budget // (4·bm)`` canonical tiles
   serve resident, the rest stream.

Bit-exactness is free by construction: a pool row is written by
``ops.serve_fill_tiles``, which computes the exact expression the
streaming miss branch regenerates, and ``ops.serve_cached_matmul``
replays the canonical contraction tree choosing per block only WHERE
its (bm,) values come from.  Every occupancy — empty, partial, full,
post-invalidation — therefore produces logits bit-identical to
streaming and to reconstruct-on-load (asserted in
tests/test_serve_batch.py and pre-timing in every ``serve_batch``
bench row).

Jit discipline: the pool (S, bm) and the per-leaf slot maps
(groups, nblk) int32 are fixed-shape JIT ARGUMENTS of the engine step
(like the score words themselves), so fills, clock evictions, and
delta invalidations never recompile.  The manager below is host-side
numpy; the decode step only ever sees the current (pool, slots)
snapshot via ``arrays()``.

Counters: the decode access pattern is dense — every engine step
contracts every block of every zampled linear exactly once — so
hit/miss counts are analytic (``record_step``), not instrumented
inside jit; fills/evictions/invalidations are counted where they
happen on the host.  ``serve.delta.apply_delta(..., cache=...)`` is
the invalidation entry point: only tiles whose DRAWN MASK BITS
actually flip (changed word AND flipped Bernoulli bit — see
serve/delta.py) are dropped, so a converged round's delta leaves the
cache ~intact instead of cold.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Tuple

import jax.numpy as jnp
import numpy as np

from ..kernels import ops
from .state import ServeState


@dataclass(frozen=True)
class ServeConfig:
    """Operator-facing serving knobs (``core.federated.FederatedConfig``
    style: a validated frozen dataclass the whole serve stack reads).

    ``lanes``: fixed batch width of the continuous-batching scheduler;
    ``seq_len``: per-lane KV capacity (admission resets a lane's
    position, stale KV is masked — no reallocation, no recompile);
    ``cache_budget_bytes``: hot-block pool budget, the load/stream
    dial; ``mode``: engine weight-sourcing mode ('cached' engages the
    pool); ``impl``: streaming kernel impl override (ref/chunked/
    pallas; None = ``REPRO_SERVE_IMPL`` or 'chunked');
    ``max_new_tokens``: per-request generation cap default.
    """

    lanes: int = 4
    seq_len: int = 128
    cache_budget_bytes: int = 0
    mode: str = "cached"
    impl: Optional[str] = None
    max_new_tokens: int = 32

    def __post_init__(self):
        if self.lanes < 1:
            raise ValueError(f"lanes must be >= 1, got {self.lanes}")
        if self.seq_len < 1:
            raise ValueError(f"seq_len must be >= 1, got {self.seq_len}")
        if self.cache_budget_bytes < 0:
            raise ValueError(
                f"cache_budget_bytes must be >= 0, got "
                f"{self.cache_budget_bytes}"
            )
        if self.mode not in ("load", "streaming", "cached"):
            raise ValueError(f"unknown serve mode {self.mode!r}")
        if self.max_new_tokens < 1:
            raise ValueError(
                f"max_new_tokens must be >= 1, got {self.max_new_tokens}"
            )


@dataclass
class _LeafGrid:
    """Static canonical-block geometry of one cacheable leaf."""

    spec: Any
    groups: int
    nblk: int  # canonical blocks per group
    bpw: int  # blocks per window
    w0: np.ndarray  # (groups,) first window of each group


class HotBlockCache:
    """Host-side manager of the (pool, slot-map) tile cache.

    Mutable by design — fills, evictions, and invalidations rewrite the
    numpy slot maps and the device pool rows in place; the engine step
    consumes snapshots via ``arrays()``.  Not thread-safe (one serving
    scheduler owns one cache).
    """

    def __init__(self, sstate: ServeState, budget_bytes: int, *,
                 bm: int = ops.SERVE_BM):
        self.bm = int(bm)
        self.tile_bytes = 4 * self.bm
        self.budget_bytes = int(budget_bytes)
        self.qbits = sstate.qbits
        self.qpacked = sstate.qpacked
        # cacheable leaves: every zampled matmul leaf.  'embed' streams
        # through the row-gather path (serve_embed_rows), which never
        # runs the blocked contraction — nothing to cache there.
        self.grids: Dict[str, _LeafGrid] = {}
        for path in sorted(sstate.zspecs.specs):
            if path == "embed":
                continue
            spec = sstate.zspecs.specs[path]
            groups, d_in, d_out = ops.serve_group_dims(spec)
            sub = d_in * d_out
            w0s, nblk0, bpw = ops.serve_block_grid(spec, self.bm, 0, sub)
            w0 = np.empty(groups, np.int64)
            for g in range(groups):
                wg, nblk, bpw_g = ops.serve_block_grid(
                    spec, self.bm, g * sub, sub)
                assert nblk == nblk0 and bpw_g == bpw
                w0[g] = wg
            self.grids[path] = _LeafGrid(spec=spec, groups=groups,
                                         nblk=nblk0, bpw=bpw, w0=w0)
        self.total_tiles = sum(g.groups * g.nblk
                               for g in self.grids.values())
        # never allocate past the model: budget >= 4·m caps at exactly
        # one pool row per canonical tile (fully loaded)
        self.capacity = min(self.budget_bytes // self.tile_bytes,
                            self.total_tiles)
        # pool keeps >= 1 row so the hit branch of the cached
        # contraction traces at budget 0 too (it just never executes)
        self._pool = jnp.zeros((max(self.capacity, 1), self.bm),
                               jnp.float32)
        self.slots: Dict[str, np.ndarray] = {
            p: np.full((g.groups, g.nblk), -1, np.int32)
            for p, g in self.grids.items()
        }
        # slot k's owner as (path index, group, block); -1 = free
        self._paths: List[str] = list(self.grids)
        self._owner = np.full((max(self.capacity, 1), 3), -1, np.int64)
        self._ref = np.zeros(max(self.capacity, 1), bool)
        self._hand = 0
        self.counters = {"hits": 0, "misses": 0, "fills": 0,
                         "evictions": 0, "invalidations": 0}
        self._device_slots: Optional[Dict[str, Any]] = None

    # --- accounting -----------------------------------------------------
    @property
    def resident_tiles(self) -> int:
        return int((self._owner[:self.capacity, 0] >= 0).sum())

    @property
    def capacity_bytes(self) -> int:
        """Allocated pool bytes (what the budget actually buys)."""
        return self.capacity * self.tile_bytes

    @property
    def used_bytes(self) -> int:
        return self.resident_tiles * self.tile_bytes

    def stats(self) -> Dict[str, Any]:
        return {
            **self.counters,
            "resident_tiles": self.resident_tiles,
            "total_tiles": self.total_tiles,
            "capacity_tiles": self.capacity,
            "capacity_bytes": self.capacity_bytes,
            "used_bytes": self.used_bytes,
        }

    def record_step(self, n_steps: int = 1) -> None:
        """Analytic hit/miss accounting for ``n_steps`` engine steps.

        Each decode step contracts every canonical block of every
        cacheable leaf exactly once (the dense decode access pattern),
        so per step: hits = resident tiles, misses = the rest.  Also
        the clock 'touch': every resident tile's reference bit is set.
        """
        r = self.resident_tiles
        self.counters["hits"] += r * n_steps
        self.counters["misses"] += (self.total_tiles - r) * n_steps
        self._ref[self._owner[:, 0] >= 0] = True

    # --- slot allocation (clock) ----------------------------------------
    def _free_slot(self) -> Optional[int]:
        free = np.nonzero(self._owner[:self.capacity, 0] < 0)[0]
        return int(free[0]) if free.size else None

    def _evict_clock(self) -> int:
        """Second-chance clock: clear ref bits until an unreferenced
        resident slot comes under the hand; evict it."""
        assert self.capacity > 0
        for _ in range(2 * self.capacity + 1):
            k = self._hand
            self._hand = (self._hand + 1) % self.capacity
            if self._owner[k, 0] < 0:
                continue
            if self._ref[k]:
                self._ref[k] = False
                continue
            pi, g, t = self._owner[k]
            self.slots[self._paths[pi]][g, t] = -1
            self._owner[k] = -1
            self.counters["evictions"] += 1
            self._device_slots = None
            return k
        raise RuntimeError("clock found no evictable slot")

    # --- fill -----------------------------------------------------------
    def _uncached_blocks(self) -> List[Tuple[int, int, int]]:
        out = []
        for pi, path in enumerate(self._paths):
            g, t = np.nonzero(self.slots[path] < 0)
            out.extend((pi, int(gg), int(tt)) for gg, tt in zip(g, t))
        return out

    def fill(self, sstate: ServeState, *, limit: Optional[int] = None,
             evict: bool = False) -> int:
        """Materialize uncached tiles into the pool, canonical order.

        By default fills only FREE slots (the steady decode pattern
        touches every resident tile every step, so clock eviction to
        admit a new tile would thrash); ``evict=True`` lets the clock
        make room — the shifting-workload policy.  Returns tiles
        filled.  One ``serve_fill_tiles`` batch per leaf — no full-leaf
        materialization.
        """
        if self.capacity == 0:
            return 0
        want = self._uncached_blocks()
        if limit is not None:
            want = want[:limit]
        per_path: Dict[int, List[Tuple[int, int, int]]] = {}
        filled = 0
        for pi, g, t in want:
            slot = self._free_slot()
            if slot is None:
                if not evict:
                    break
                slot = self._evict_clock()
            self._owner[slot] = (pi, g, t)
            self.slots[self._paths[pi]][g, t] = slot
            self._ref[slot] = True
            per_path.setdefault(pi, []).append((slot, g, t))
            filled += 1
        for pi, entries in per_path.items():
            path = self._paths[pi]
            grid = self.grids[path]
            ks = jnp.asarray([e[0] for e in entries], jnp.int32)
            gs = jnp.asarray([e[1] for e in entries], jnp.int32)
            ts = jnp.asarray([e[2] for e in entries], jnp.int32)
            tiles = ops.serve_fill_tiles(grid.spec, sstate.words[path],
                                         sstate.step, gs, ts,
                                         qbits=self.qbits,
                                         qpacked=self.qpacked, bm=self.bm)
            self._pool = self._pool.at[ks].set(tiles)
        if filled:
            self.counters["fills"] += filled
            self._device_slots = None
        return filled

    # --- invalidation ---------------------------------------------------
    def invalidate_windows(self, path: str, flipped: np.ndarray) -> int:
        """Drop every tile of ``path`` whose source window's drawn bits
        flipped.  ``flipped``: (num_windows,) bool.  A canonical block
        reads z coordinates of exactly ONE window (w0[g] + t // bpw),
        so window granularity is exact tile granularity.  Returns
        tiles invalidated."""
        grid = self.grids.get(path)
        if grid is None:
            return 0
        flipped = np.asarray(flipped, bool)
        t = np.arange(grid.nblk)
        win = grid.w0[:, None] + t[None, :] // grid.bpw  # (groups, nblk)
        kill = flipped[win] & (self.slots[path] >= 0)
        n = int(kill.sum())
        if n:
            dead = self.slots[path][kill]
            self._owner[dead] = -1
            self._ref[dead] = False
            self.slots[path][kill] = -1
            self.counters["invalidations"] += n
            self._device_slots = None
        return n

    def invalidate_all(self) -> int:
        """Full drop (codec change, draw-word change, leaf-set change)."""
        n = self.resident_tiles
        for path in self._paths:
            self.slots[path][:] = -1
        self._owner[:] = -1
        self._ref[:] = False
        if n:
            self.counters["invalidations"] += n
            self._device_slots = None
        return n

    # --- the jit-visible snapshot ---------------------------------------
    def arrays(self) -> Dict[str, Any]:
        """{"pool": (S, bm) f32, "slots": {path: (groups, nblk) i32}} —
        the fixed-shape jit arguments the cached engine step takes."""
        if self._device_slots is None:
            self._device_slots = {p: jnp.asarray(s)
                                  for p, s in self.slots.items()}
        return {"pool": self._pool, "slots": self._device_slots}


def build_cache(sstate: ServeState, config: ServeConfig, *,
                warm: bool = True) -> HotBlockCache:
    """Construct (and by default warm) the hot-block cache for a
    serving node: fills the first ``budget // (4·bm)`` canonical tiles
    — 'first touch' under the dense decode pattern is simply canonical
    order."""
    cache = HotBlockCache(sstate, config.cache_budget_bytes)
    if warm:
        cache.fill(sstate)
    return cache
