"""Round-to-round serving updates as XOR deltas of the score broadcast.

Between federated rounds t and t+1 most encoded score words do not
change — late in training the server's score vector moves slowly, and
the quantized codecs (u8/u16) snap small moves to the SAME wire word
(provided the server reuses one dither word across rounds: the dither
stream is a pure function of (tensor_id, dither word, coordinate), so
an unchanged quantized probability re-encodes to an unchanged word —
see ``comm/downlink.py``).  Broadcasting the full word vector every
round then pays for information the serving fleet already has.

The delta wire is the XOR of the two rounds' word BIT PATTERNS (f32
scores are bitcast to uint32 first): zero where unchanged, and
trivially invertible — ``apply_delta`` XORs the patch back into a live
server's words, which is bit-identical to a fresh load of round t+1
(pinned in tests/test_serve.py), because the serving engine's output
is a pure function of (words, step) and the patched words ARE round
t+1's words.  No re-encode, no drift, no restart.

Byte accounting is exact (``comm.metering.delta_wire_bytes``): the
broadcaster ships the cheaper of a presence bitmap or a coordinate
list, plus the 4-byte draw word.  The same XOR trick meters packed
mask-lane updates (``lanes_delta``) for deployments that ship drawn
masks rather than scores.
"""

from __future__ import annotations

from typing import Any, Dict, NamedTuple

import jax
import jax.numpy as jnp

from ..comm.downlink import get_codec
from ..comm.metering import delta_wire_bytes, score_downlink_bytes
from .state import ServeState


def _bits(a):
    """Bit pattern of a word array as a same-width unsigned int."""
    a = jnp.asarray(a)
    if jnp.issubdtype(a.dtype, jnp.floating):
        return jax.lax.bitcast_convert_type(a, jnp.uint32)
    return a


def _unbits(u, dtype):
    """Inverse of ``_bits``: reinterpret back to the word dtype."""
    dtype = jnp.dtype(dtype)
    if jnp.issubdtype(dtype, jnp.floating):
        return jax.lax.bitcast_convert_type(u.astype(jnp.uint32), dtype)
    return u.astype(dtype)


def word_delta(old, new):
    """XOR patch old -> new of one word array (uint, zero = unchanged)."""
    o, n = _bits(old), _bits(new)
    if o.shape != n.shape:
        raise ValueError(f"word shapes differ: {o.shape} vs {n.shape}")
    return o ^ n


def apply_word_delta(base, patch):
    """XOR a patch into a word array, preserving the word dtype."""
    return _unbits(_bits(base) ^ jnp.asarray(patch), jnp.asarray(base).dtype)


class ServeDelta(NamedTuple):
    """One round's serving update: per-path XOR word patches + the new
    draw word.  ``codec`` guards against cross-codec application."""

    codec: str
    words: Dict[str, Any]  # path -> XOR patch (unsigned, zero=same)
    step: Any  # () uint32 — round t+1's draw word


def make_delta(old: ServeState, new: ServeState) -> ServeDelta:
    """The broadcastable update taking a round-t server to round t+1."""
    if old.codec != new.codec:
        raise ValueError(
            f"delta across codecs ({old.codec!r} -> {new.codec!r}); "
            "re-broadcast in full instead"
        )
    if set(old.words) != set(new.words):
        raise ValueError("delta requires identical zampled leaf sets")
    return ServeDelta(
        codec=new.codec,
        words={p: word_delta(old.words[p], new.words[p])
               for p in old.words},
        step=jnp.asarray(new.step, jnp.uint32),
    )


def apply_delta(sstate: ServeState, delta: ServeDelta) -> ServeState:
    """Hot-swap: patch a live server's words to the next round.

    Returns a ServeState bit-identical to ``make_serve_state`` on round
    t+1's broadcast; feed ``engine.arrays_of`` on the result to the
    already-compiled decode step (arrays are jit arguments, so no
    recompile).
    """
    if delta.codec != sstate.codec:
        raise ValueError(
            f"delta is for codec {delta.codec!r}, state carries "
            f"{sstate.codec!r}"
        )
    words = {p: apply_word_delta(sstate.words[p], delta.words[p])
             for p in sstate.words}
    return sstate.replace_arrays(
        {"words": words, "dense": dict(sstate.dense), "step": delta.step}
    )


def lanes_delta(old_lanes: Dict[str, Any], new_lanes: Dict[str, Any]):
    """XOR patches for packed uint32 mask lanes (the drawn-mask wire of
    ``comm.protocol``'s packed transports): {path: patch}."""
    if set(old_lanes) != set(new_lanes):
        raise ValueError("lane delta requires identical leaf sets")
    return {p: word_delta(old_lanes[p], new_lanes[p]) for p in old_lanes}


def delta_report(old: ServeState, new: ServeState) -> Dict[str, Any]:
    """Exact byte accounting of delta-vs-full for one round step.

    ``delta_bytes`` is what ``make_delta`` costs on the wire (cheaper
    of bitmap / coordinate-list per leaf, + 4 bytes draw word);
    ``full_bytes`` is the codec's full score broadcast for the same
    leaf set.  Word-change counts are computed host-side, so call this
    outside jit.
    """
    delta = make_delta(old, new)
    codec = get_codec(new.codec)
    wb = codec.bits // 8
    per_path = {}
    delta_bytes = 4  # the draw word rides along
    full_bytes = 0
    changed_total = 0
    total = 0
    for path, patch in delta.words.items():
        n = int(patch.size)
        changed = int(jnp.count_nonzero(patch))
        d = delta_wire_bytes(n, changed, wb)
        f = score_downlink_bytes(codec, n)
        per_path[path] = {"words": n, "changed": changed,
                          "delta_bytes": d, "full_bytes": f}
        delta_bytes += d
        full_bytes += f
        changed_total += changed
        total += n
    return {
        "codec": new.codec,
        "words_total": total,
        "words_changed": changed_total,
        "delta_bytes": delta_bytes,
        "full_bytes": full_bytes,
        "delta_vs_full": delta_bytes / full_bytes if full_bytes else 0.0,
        "per_path": per_path,
    }
