"""Round-to-round serving updates as XOR deltas of the score broadcast.

Between federated rounds t and t+1 most encoded score words do not
change — late in training the server's score vector moves slowly, and
the quantized codecs (u8/u16) snap small moves to the SAME wire word
(provided the server reuses one dither word across rounds: the dither
stream is a pure function of (tensor_id, dither word, coordinate), so
an unchanged quantized probability re-encodes to an unchanged word —
see ``comm/downlink.py``).  Broadcasting the full word vector every
round then pays for information the serving fleet already has.

The delta wire is the XOR of the two rounds' word BIT PATTERNS (f32
scores are bitcast to uint32 first): zero where unchanged, and
trivially invertible — ``apply_delta`` XORs the patch back into a live
server's words, which is bit-identical to a fresh load of round t+1
(pinned in tests/test_serve.py), because the serving engine's output
is a pure function of (words, step) and the patched words ARE round
t+1's words.  No re-encode, no drift, no restart.

Cache survival — the changed-word → touched-tile map.  A hot-block
cache (``serve.cache``) holds materialized weight tiles keyed by
canonical contraction block; each block reads z coordinates of
exactly ONE window, and its weight values depend on the score words
ONLY through the drawn mask bits (w_row = Σ_k val_k · bit_k with
static val_k).  So the exact invalidation set of a delta is: tiles
whose window contains a coordinate where the DRAWN BIT flips —
``(word changed) AND (Bern(decode(old)) != Bern(decode(new)))`` under
the pinned draw word.  That is far smaller than "window contains a
changed word": a word move that does not cross its coordinate's draw
threshold changes nothing the cache holds.  ``delta_flipped_windows``
computes the per-window flip map (same integer-threshold /
``bernoulli_u32`` draw expressions as the serve kernels, so the map
is exact, not heuristic), ``apply_delta(..., cache=...)`` drops
exactly those tiles — the cache SURVIVES the hot-swap, retaining
~(1-λ)^window of its tiles at per-coordinate flip rate λ (the
``serve_batch`` bench gates >= 90% on a 1%-moved converged round).
If the delta also changes the draw word (``delta.step != state.step``)
every drawn bit re-rolls and the whole cache drops — serving
deployments pin ONE draw word per deployment for exactly this reason.
Invalidation is pinned bitwise against a fresh rebuild: a retained
tile's pool row equals the tile a cold cache fills from round t+1's
words (tests/test_serve_batch.py).

Byte accounting is exact (``comm.metering.delta_wire_bytes``): the
broadcaster ships the cheaper of a presence bitmap or a coordinate
list, plus the 4-byte draw word.  The same XOR trick meters packed
mask-lane updates (``lanes_delta``) for deployments that ship drawn
masks rather than scores.
"""

from __future__ import annotations

from typing import Any, Dict, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from ..comm.downlink import get_codec
from ..comm.metering import delta_wire_bytes, score_downlink_bytes
from ..core.hashrng import bernoulli_u32
from ..core.sampling import mask_u32, quant_threshold_u24
from .state import ServeState


def _bits(a):
    """Bit pattern of a word array as a same-width unsigned int."""
    a = jnp.asarray(a)
    if jnp.issubdtype(a.dtype, jnp.floating):
        return jax.lax.bitcast_convert_type(a, jnp.uint32)
    return a


def _unbits(u, dtype):
    """Inverse of ``_bits``: reinterpret back to the word dtype."""
    dtype = jnp.dtype(dtype)
    if jnp.issubdtype(dtype, jnp.floating):
        return jax.lax.bitcast_convert_type(u.astype(jnp.uint32), dtype)
    return u.astype(dtype)


def word_delta(old, new):
    """XOR patch old -> new of one word array (uint, zero = unchanged)."""
    o, n = _bits(old), _bits(new)
    if o.shape != n.shape:
        raise ValueError(f"word shapes differ: {o.shape} vs {n.shape}")
    return o ^ n


def apply_word_delta(base, patch):
    """XOR a patch into a word array, preserving the word dtype."""
    return _unbits(_bits(base) ^ jnp.asarray(patch), jnp.asarray(base).dtype)


class ServeDelta(NamedTuple):
    """One round's serving update: per-path XOR word patches + the new
    draw word.  ``codec`` guards against cross-codec application."""

    codec: str
    words: Dict[str, Any]  # path -> XOR patch (unsigned, zero=same)
    step: Any  # () uint32 — round t+1's draw word


def make_delta(old: ServeState, new: ServeState) -> ServeDelta:
    """The broadcastable update taking a round-t server to round t+1."""
    if old.codec != new.codec:
        raise ValueError(
            f"delta across codecs ({old.codec!r} -> {new.codec!r}); "
            "re-broadcast in full instead"
        )
    if set(old.words) != set(new.words):
        raise ValueError("delta requires identical zampled leaf sets")
    return ServeDelta(
        codec=new.codec,
        words={p: word_delta(old.words[p], new.words[p])
               for p in old.words},
        step=jnp.asarray(new.step, jnp.uint32),
    )


def _drawn_bits(spec, words, step, qbits, qpacked=False):
    """The (n,) drawn mask bits of one leaf under the pinned draw word
    — the exact draw expressions of ``kernels.ops._serve_edge_weights``
    evaluated per z coordinate (packed lanes unpack to per-coordinate
    words first)."""
    coords = jnp.arange(spec.n, dtype=jnp.uint32)
    u = mask_u32(spec.seed, spec.tensor_id, jnp.asarray(step, jnp.uint32),
                 coords)
    if qbits is None:
        p = jnp.clip(jnp.asarray(words).astype(jnp.float32), 0.0, 1.0)
        return bernoulli_u32(u, p).astype(bool)
    if qpacked:
        from ..comm.bitpack import unpack_words

        words = unpack_words(jnp.asarray(words), spec.n, qbits)
    thr = quant_threshold_u24(jnp.asarray(words).astype(jnp.uint32), qbits)
    return (u >> np.uint32(8)) < thr


def delta_flipped_windows(sstate: ServeState,
                          delta: ServeDelta) -> Dict[str, Any]:
    """{path: (num_windows,) bool} — windows where a drawn bit flips.

    The EXACT invalidation map of ``delta`` for any tile cache keyed
    by window (serve.cache): a cached tile is stale iff its window is
    flagged here.  Requires the pinned draw word (``delta.step ==
    sstate.step``) — with a changed draw word every bit re-rolls and
    the caller must drop everything instead.
    """
    if int(jnp.asarray(delta.step)) != int(jnp.asarray(sstate.step)):
        raise ValueError(
            "delta changes the draw word; the flip map is the full set "
            "— invalidate the whole cache"
        )
    qbits = sstate.qbits
    qpacked = sstate.qpacked
    out = {}
    for path, patch in delta.words.items():
        spec = sstate.zspecs.specs[path]
        old_w = sstate.words[path]
        new_w = apply_word_delta(old_w, patch)
        flipped = (_drawn_bits(spec, old_w, sstate.step, qbits, qpacked)
                   != _drawn_bits(spec, new_w, sstate.step, qbits,
                                  qpacked))
        out[path] = flipped.reshape(spec.num_windows, spec.window).any(1)
    return out


def apply_delta(sstate: ServeState, delta: ServeDelta,
                cache=None) -> ServeState:
    """Hot-swap: patch a live server's words to the next round.

    Returns a ServeState bit-identical to ``make_serve_state`` on round
    t+1's broadcast; feed ``engine.arrays_of`` on the result to the
    already-compiled decode step (arrays are jit arguments, so no
    recompile).

    ``cache``: a live ``serve.cache.HotBlockCache`` to carry across
    the swap — exactly the tiles whose drawn bits flip are dropped
    (``delta_flipped_windows``; everything, if the draw word changed).
    Retained tiles are bit-identical to a fresh round-t+1 fill, so the
    cache needs no rebuild; call ``cache.fill(new_state)`` afterwards
    to re-materialize the freed slots from the NEW words at leisure.
    """
    if delta.codec != sstate.codec:
        raise ValueError(
            f"delta is for codec {delta.codec!r}, state carries "
            f"{sstate.codec!r}"
        )
    if cache is not None:
        if int(jnp.asarray(delta.step)) != int(jnp.asarray(sstate.step)):
            cache.invalidate_all()
        else:
            for path, flipped in delta_flipped_windows(sstate,
                                                       delta).items():
                cache.invalidate_windows(path, np.asarray(flipped))
    words = {p: apply_word_delta(sstate.words[p], delta.words[p])
             for p in sstate.words}
    return sstate.replace_arrays(
        {"words": words, "dense": dict(sstate.dense), "step": delta.step}
    )


def lanes_delta(old_lanes: Dict[str, Any], new_lanes: Dict[str, Any]):
    """XOR patches for packed uint32 mask lanes (the drawn-mask wire of
    ``comm.protocol``'s packed transports): {path: patch}."""
    if set(old_lanes) != set(new_lanes):
        raise ValueError("lane delta requires identical leaf sets")
    return {p: word_delta(old_lanes[p], new_lanes[p]) for p in old_lanes}


def delta_report(old: ServeState, new: ServeState) -> Dict[str, Any]:
    """Exact byte accounting of delta-vs-full for one round step.

    ``delta_bytes`` is what ``make_delta`` costs on the wire (cheaper
    of bitmap / coordinate-list per leaf, + 4 bytes draw word);
    ``full_bytes`` is the codec's full score broadcast for the same
    leaf set.  ``words_flipped`` counts changed words whose DRAWN BIT
    also flips — the part of the delta a tile cache actually feels
    (see module docstring).  Word-change counts are computed
    host-side, so call this outside jit.
    """
    delta = make_delta(old, new)
    codec = get_codec(new.codec)
    qbits = old.qbits
    wb = codec.bits // 8
    same_step = int(jnp.asarray(delta.step)) == int(jnp.asarray(old.step))
    per_path = {}
    delta_bytes = 4  # the draw word rides along
    full_bytes = 0
    changed_total = 0
    flipped_total = 0
    total = 0
    for path, patch in delta.words.items():
        n = int(patch.size)
        changed = int(jnp.count_nonzero(patch))
        if same_step:
            spec = old.zspecs.specs[path]
            flips = int(jnp.count_nonzero(
                _drawn_bits(spec, old.words[path], old.step, qbits)
                != _drawn_bits(spec, new.words[path], old.step, qbits)))
        else:
            flips = n
        d = delta_wire_bytes(n, changed, wb)
        f = score_downlink_bytes(codec, n)
        per_path[path] = {"words": n, "changed": changed,
                          "flipped": flips, "delta_bytes": d,
                          "full_bytes": f}
        delta_bytes += d
        full_bytes += f
        changed_total += changed
        flipped_total += flips
        total += n
    return {
        "codec": new.codec,
        "words_total": total,
        "words_changed": changed_total,
        "words_flipped": flipped_total,
        "delta_bytes": delta_bytes,
        "full_bytes": full_bytes,
        "delta_vs_full": delta_bytes / full_bytes if full_bytes else 0.0,
        "per_path": per_path,
    }
