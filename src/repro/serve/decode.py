"""Serving: batched autoregressive generation over the decode_step path.

At production scale the decode_step is pjit-lowered per the dry-run;
this module drives it for the runnable examples/tests (CPU scale).
``serve_from_compressed`` is the Zampling-native deployment: the node
stores only (seed, z) — m/32 bits of model state — and reconstructs
weights on load (or per-step under the 'streaming' memory trade
analyzed in EXPERIMENTS.md §Perf).
"""

from __future__ import annotations

from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from ..core.zampling import ZamplingSpecs, weights_from_masks
from ..models.model import Model


def generate(
    model: Model,
    params,
    prompt: jnp.ndarray,  # (B, Sp) int32
    max_new_tokens: int,
    *,
    seq_len: Optional[int] = None,
    temperature: float = 0.0,
    key=None,
):
    """Greedy (or temperature) generation. Returns (B, Sp+new) tokens."""
    B, Sp = prompt.shape
    seq_len = seq_len or (Sp + max_new_tokens)
    cache = model.init_cache(params, B, seq_len)

    @jax.jit
    def step(cache, tok):
        return model.decode_step(params, cache, {"tokens": tok})

    # feed the prompt token-by-token (CPU-scale prefill)
    logits = None
    for t in range(Sp):
        logits, cache = step(cache, prompt[:, t : t + 1])

    toks = [prompt]
    cur = None
    for i in range(max_new_tokens):
        if temperature > 0.0:
            key, sub = jax.random.split(key)
            cur = jax.random.categorical(
                sub, logits[:, -1].astype(jnp.float32) / temperature
            )[:, None]
        else:
            cur = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
        toks.append(cur)
        if i + 1 < max_new_tokens:
            logits, cache = step(cache, cur)
    return jnp.concatenate(toks, axis=1)


def serve_from_compressed(
    model: Model,
    zspecs: ZamplingSpecs,
    masks: Dict[str, Any],
    dense: Dict[str, Any],
    prompt,
    max_new_tokens: int,
    **kw,
):
    """Deployment from the compressed (z, dense) artifact: reconstruct
    once, then serve. Storage = n bits + dense leaves (vs 32m naive)."""
    params = weights_from_masks(zspecs, masks, {"dense": dense})
    return generate(model, params, prompt, max_new_tokens, **kw)
