"""Serving: scan-driven generation, with a Zampling-native engine.

Three ways to source a zampled linear's weights at decode time — one
engine, one canonical contraction, three residency points:

 - ``mode="load"`` — reconstruct every zampled leaf once at startup
   (``serve.state.reconstruct_resident``) and decode against the
   materialized f32 tensors.  Fast steps, but the node holds 32 bits
   per weight again — the memory the (seed, z) story promised back.
 - ``mode="streaming"`` — the node's only zampled state is the encoded
   score broadcast (``ServeState``); every decode-step linear calls
   ``kernels.ops.serve_matmul`` / ``serve_embed_rows``, which
   regenerate Q edges and draw mask bits inside the contraction.  No
   weight tensor ever exists (jaxpr-asserted in tests/test_serve.py);
   resident zampled bytes drop from 32m to the wire size of the codec
   words (n·codec.bits bits).
 - ``mode="cached"`` — streaming plus the hot-block tile pool
   (``serve.cache``): each canonical block either gathers its
   materialized (bm,) tile from the pool (resident-matmul speed) or
   falls back to the streaming regeneration, per a slot map filled
   under ``ServeConfig.cache_budget_bytes``.  Budget 0 IS streaming;
   budget >= 4·m IS load; anything between is a dialable point on the
   resident-bytes/latency frontier.

All modes are BIT-IDENTICAL at every cache occupancy: they run the
same engine code (layers unrolled in Python — a lax.scan over layers
lets XLA fuse the norm reductions differently and breaks bitwise
equality) and contract every zampled linear through the canonical
blocked tree (``kernels/ops.py`` serve section); they differ only in
where each block's weight values come from.  That makes the budget
knob a pure memory/latency trade with zero output risk, and makes a
delta hot-swap (``serve.delta.apply_delta``) equivalent to restarting
the server on the new round's broadcast — with the cache SURVIVING
the swap minus only the tiles whose drawn bits actually flipped.

Batching: the engine step serves either a single request (scalar
``cache.pos`` — the PR-8 path, bit-for-bit unchanged) or a fixed-lane
batch (``init_lane_cache``: per-lane (B,) positions plus a (B,) live
mask threaded to ``models.attention.decode_attend_lanes``).  Lane
admission just resets that lane's position — stale KV from the
previous occupant sits beyond the validity mask and contributes exact
zeros, so the continuous-batching scheduler (``serve.scheduler``)
admits/retires requests per step without reallocation or recompile.
Per-lane bits equal the single-request decode at the same position
and KV capacity, which is what lets the scheduler's throughput wins
come with a bitwise-equality guarantee.

Generation is a jitted ``lax.scan`` pair — a cache-building prefill
scan over the prompt (the decoder's ``model.prefill`` is logits-only
and returns no cache, so scanning the decode step IS the cache-honest
prefill at serving time) and a greedy/temperature generation scan —
so serving benches measure decode, not Python-loop dispatch.  Engine
arrays travel as jit ARGUMENTS (never closure constants): swapping in
a delta-patched ``ServeState`` — or a refilled/invalidated cache
snapshot — reuses the compiled step.
"""

from __future__ import annotations

from typing import Any, Callable, Dict, NamedTuple, Optional

import jax
import jax.numpy as jnp

from ..core.zampling import ZamplingSpecs, weights_from_masks
from ..kernels import ops
from ..models import attention as attn
from ..models.attention import KVCache
from ..models.common import rms_norm
from ..models.model import Model, _attn_dims
from .state import ServeState, reconstruct_resident


def make_generator(step_fn, max_new_tokens: int, temperature: float = 0.0):
    """Jit-once generation driver over ``step_fn(arrays, cache, tok)``.

    Returns ``run(arrays, cache, prompt, key) -> (new_tokens (B, N),
    cache)``: a prefill scan feeding the prompt token-by-token through
    the step (building the KV cache), then a generation scan sampling
    ``max_new_tokens`` greedily (``temperature == 0``) or from the
    tempered logits with ``fold_in(key, i)`` per position.  Works with
    both cache layouts the engine step accepts — a scalar-position
    cache (single request) or a lane cache from ``init_lane_cache``
    (equal-length prompts decode in lockstep; for ragged admission use
    ``serve.scheduler``).  Reuse the returned callable across calls —
    each ``make_generator`` call traces fresh.
    """

    def select(logits, key, i):
        if temperature > 0.0:
            sub = jax.random.fold_in(key, i)
            return jax.random.categorical(
                sub, logits.astype(jnp.float32) / temperature
            ).astype(jnp.int32)
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)

    @jax.jit
    def run(arrays, cache, prompt, key):
        def prefill_body(c, t):
            logits, c = step_fn(arrays, c, t[:, None])
            return c, logits[:, -1]

        cache, last = jax.lax.scan(prefill_body, cache,
                                   jnp.swapaxes(prompt, 0, 1))
        first = select(last[-1], key, 0)

        def gen_body(carry, i):
            c, prev = carry
            logits, c = step_fn(arrays, c, prev[:, None])
            nxt = select(logits[:, -1], key, i)
            return (c, nxt), nxt

        if max_new_tokens > 1:
            (cache, _), rest = jax.lax.scan(
                gen_body, (cache, first),
                jnp.arange(1, max_new_tokens, dtype=jnp.int32))
            toks = jnp.concatenate([first[None], rest], axis=0)
        else:
            toks = first[None]
        return jnp.swapaxes(toks, 0, 1), cache

    return run


def _check_key(temperature: float, key):
    if temperature > 0.0 and key is None:
        raise ValueError("temperature sampling needs a PRNG key")
    return key if key is not None else jax.random.PRNGKey(0)


def generate(
    model: Model,
    params,
    prompt: jnp.ndarray,  # (B, Sp) int32
    max_new_tokens: int,
    *,
    seq_len: Optional[int] = None,
    temperature: float = 0.0,
    key=None,
):
    """Greedy (or temperature) generation. Returns (B, Sp+new) tokens."""
    B, Sp = prompt.shape
    seq_len = seq_len or (Sp + max_new_tokens)
    cache = model.init_cache(params, B, seq_len)

    def step_fn(arrays, c, tok):
        return model.decode_step(arrays, c, {"tokens": tok})

    run = make_generator(step_fn, max_new_tokens, temperature)
    new, _ = run(params, cache, prompt, _check_key(temperature, key))
    return jnp.concatenate([prompt, new.astype(prompt.dtype)], axis=1)


# ---------------------------------------------------------------------------
# the Zampling-native serving engine
# ---------------------------------------------------------------------------

class ServeEngine(NamedTuple):
    """A compiled-shape serving plan for one (model, ServeState) pair.

    ``step(arrays, cache, tok (B, 1), live=None) -> (logits (B, 1, V),
    cache)`` — ``cache.pos`` scalar selects the single-request path
    (PR-8 bit-compat), (B,) the per-lane batched path with optional
    (B,) ``live`` admission mask; ``arrays_of(sstate, cache=None)``
    builds the jit-visible arrays for any state sharing this engine's
    zspecs/codec, merging the hot-block pool snapshot in
    ``mode="cached"`` (THE hot-swap path: feed a delta-patched state's
    arrays — and the delta-invalidated cache's snapshot — to the same
    compiled step); ``init_cache(B, seq_len)`` the single-request KV
    cache, ``init_lane_cache(lanes, seq_len)`` the per-lane one.
    """

    step: Callable[..., Any]
    arrays_of: Callable[..., Dict[str, Any]]
    init_cache: Callable[[int, int], Any]
    init_lane_cache: Callable[[int, int], Any]
    mode: str


def build_serve_engine(model: Model, sstate: ServeState, *,
                       mode: str = "streaming",
                       impl: Optional[str] = None) -> ServeEngine:
    """Build the serving decode step for a dense-family decoder.

    Layers are unrolled in Python and every zampled linear goes
    through the canonical serve contraction, so ``mode="load"``,
    ``mode="streaming"`` and ``mode="cached"`` produce bit-identical
    logits at any cache occupancy (the residency choice is
    memory-only).  ``impl`` picks the streaming kernel impl
    (ref/chunked/pallas; default ``REPRO_SERVE_IMPL`` or 'chunked');
    the cached mode's hit branch is pure jnp whatever the impl.
    """
    if mode not in ("load", "streaming", "cached"):
        raise ValueError(f"unknown serve mode {mode!r}")
    cfg = model.cfg
    if cfg.family not in ("dense", "vlm") or cfg.moe is not None:
        raise NotImplementedError(
            "the serving engine covers the dense decoder family; got "
            f"family={cfg.family!r}"
        )
    dims = _attn_dims(cfg)
    L = cfg.n_layers
    specs = sstate.zspecs.specs
    qbits = sstate.qbits
    qpacked = sstate.qpacked

    for path in specs:
        leaf = path.rsplit("/", 1)[-1]
        if leaf in ("ln1", "ln2", "bq", "bk", "bv", "q_norm", "k_norm",
                    "final_norm"):
            raise NotImplementedError(
                f"engine expects bias/norm leaves dense, got zampled "
                f"{path!r}"
            )

    def arrays_of(s: ServeState, cache=None) -> Dict[str, Any]:
        if mode == "load":
            return {"weights": reconstruct_resident(s),
                    "dense": dict(s.dense)}
        out = s.arrays()
        if mode == "cached":
            if cache is None:
                raise ValueError(
                    "mode='cached' needs the HotBlockCache snapshot: "
                    "arrays_of(sstate, cache=hot_block_cache)"
                )
            out.update(cache.arrays())
        return out

    def linear(arrays, path, layer, x2d):
        """x2d (B, d_in) @ leaf[layer] -> (B, d_out)."""
        spec = specs.get(path)
        if spec is None:
            w = arrays["dense"][path]
            if w.ndim == 3:
                w = w[layer]
            return jnp.dot(x2d, w)
        if mode == "load":
            return ops.serve_resident_matmul(spec, arrays["weights"][path],
                                             x2d, group=layer)
        if mode == "cached":
            return ops.serve_cached_matmul(spec, arrays["words"][path],
                                           arrays["step"], x2d,
                                           arrays["pool"],
                                           arrays["slots"][path][layer],
                                           group=layer, qbits=qbits,
                                           qpacked=qpacked)
        return ops.serve_matmul(spec, arrays["words"][path],
                                arrays["step"], x2d, group=layer,
                                qbits=qbits, qpacked=qpacked, impl=impl)

    def embed_rows(arrays, tokens):
        spec = specs.get("embed")
        if spec is None:
            return jnp.take(arrays["dense"]["embed"], tokens, axis=0)
        if mode == "load":
            return jnp.take(arrays["weights"]["embed"], tokens, axis=0)
        return ops.serve_embed_rows(spec, arrays["words"]["embed"],
                                    arrays["step"], tokens, qbits=qbits,
                                    qpacked=qpacked)

    def dlayer(arrays, path, layer):
        return arrays["dense"][path][layer]

    attn_extras = []
    if dims.qkv_bias:
        attn_extras += ["bq", "bk", "bv"]
    if dims.qk_norm:
        attn_extras += ["q_norm", "k_norm"]

    def step(arrays, cache, tokens, live=None):
        x = embed_rows(arrays, tokens)  # (B, 1, D)
        B = x.shape[0]
        lanes = cache.pos.ndim == 1
        if lanes:
            lv = (jnp.ones((B,), bool) if live is None
                  else jnp.asarray(live, bool))
            positions = cache.pos[:, None]
        else:
            positions = jnp.broadcast_to(cache.pos[None, None], (B, 1))
        nk, nv = [], []
        new_pos = cache.pos
        for l in range(L):
            h = rms_norm(x, dlayer(arrays, "blocks/ln1", l)).reshape(B, -1)
            q = linear(arrays, "blocks/attn/wq", l, h)[:, None, :]
            k = linear(arrays, "blocks/attn/wk", l, h)[:, None, :]
            v = linear(arrays, "blocks/attn/wv", l, h)[:, None, :]
            ap = {e: dlayer(arrays, f"blocks/attn/{e}", l)
                  for e in attn_extras}
            q, k, v = attn.finish_qkv(ap, q, k, v, dims, positions)
            lc = KVCache(k=cache.k[l], v=cache.v[l], pos=cache.pos)
            if lanes:
                out, nc = attn.decode_attend_lanes(q, k, v, lc, dims, lv)
            else:
                out, nc = attn.decode_attend(q, k, v, lc, dims)
            new_pos = nc.pos
            x = x + linear(arrays, "blocks/attn/wo", l,
                           out.reshape(B, -1))[:, None, :]
            hm = rms_norm(x, dlayer(arrays, "blocks/ln2", l)).reshape(B, -1)
            g = linear(arrays, "blocks/mlp/gate", l, hm)
            u = linear(arrays, "blocks/mlp/up", l, hm)
            hsw = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
            x = x + linear(arrays, "blocks/mlp/down", l, hsw)[:, None, :]
            nk.append(nc.k)
            nv.append(nc.v)
        x = rms_norm(x, arrays["dense"]["final_norm"])
        logits = linear(arrays, "lm_head", 0, x.reshape(B, -1))[:, None, :]
        return logits, KVCache(k=jnp.stack(nk), v=jnp.stack(nv),
                               pos=new_pos)

    def init_cache(batch_size: int, seq_len: int):
        return model.init_cache(None, batch_size, seq_len)

    def init_lane_cache(lanes: int, seq_len: int):
        c = model.init_cache(None, lanes, seq_len)
        return c._replace(pos=jnp.zeros((lanes,), jnp.int32))

    return ServeEngine(step=step, arrays_of=arrays_of,
                       init_cache=init_cache,
                       init_lane_cache=init_lane_cache, mode=mode)


def serve_generate(
    model: Model,
    sstate: ServeState,
    prompt,
    max_new_tokens: int,
    *,
    mode: str = "streaming",
    impl: Optional[str] = None,
    seq_len: Optional[int] = None,
    temperature: float = 0.0,
    key=None,
    cache=None,
):
    """Generate from a ServeState. Returns (B, Sp+new) tokens.

    ``mode="streaming"`` never materializes a weight tensor;
    ``mode="load"`` reconstructs once and serves resident;
    ``mode="cached"`` serves through the hot-block pool (pass the
    warmed ``serve.cache.HotBlockCache`` as ``cache``).  Outputs are
    bit-identical across modes and cache occupancies.
    """
    engine = build_serve_engine(model, sstate, mode=mode, impl=impl)
    B, Sp = prompt.shape
    seq_len = seq_len or (Sp + max_new_tokens)
    kv = engine.init_cache(B, seq_len)
    run = make_generator(engine.step, max_new_tokens, temperature)
    new, _ = run(engine.arrays_of(sstate, cache=cache), kv, prompt,
                 _check_key(temperature, key))
    return jnp.concatenate([prompt, new.astype(prompt.dtype)], axis=1)


def serve_from_compressed(
    model: Model,
    zspecs: ZamplingSpecs,
    masks: Dict[str, Any],
    dense: Dict[str, Any],
    prompt,
    max_new_tokens: int,
    **kw,
):
    """Deployment from the compressed (z, dense) artifact: reconstruct
    once, then serve. Storage = n bits + dense leaves (vs 32m naive)."""
    params = weights_from_masks(zspecs, masks, {"dense": dense})
    return generate(model, params, prompt, max_new_tokens, **kw)
