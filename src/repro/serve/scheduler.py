"""Continuous batching: a request scheduler over the serve engine step.

``make_generator`` drives one batch of equal-length prompts in
lockstep — fine for benches, wrong for a serving node where requests
arrive ragged and finish ragged.  ``ServeScheduler`` runs the engine's
batched step as a fixed set of LANES instead:

 - every engine step advances all lanes one token, live-masked;
 - a lane is ADMITTED by popping the request queue and resetting that
   lane's position to 0 — no KV reallocation, no recompile (positions
   are a (B,) jit argument, and the previous occupant's stale KV sits
   beyond the validity mask contributing exact zeros);
 - a lane PREFILLS in place, decode-style: prompt tokens feed one per
   step (the cache-honest prefill of serve.decode), and the step that
   consumes the last prompt token yields the first sampled token;
 - a lane RETIRES the moment its request hits ``max_new_tokens`` (or
   the optional eos), freeing the slot for the next admission at the
   very next step.

Per-lane bits equal the single-request path at the same KV capacity
(``decode_attend_lanes``; pinned in tests/test_serve_batch.py), so
batching is a pure throughput knob: B lanes amortize the per-step
weight sourcing — the streamed regeneration or the hot-block cache
gather runs ONCE per step whatever B is — without touching outputs.

Sampling is greedy (host argmax over the step's logits — one device
sync per step, which also paces the async dispatch queue).  Round
updates hot-swap mid-flight: ``apply_round_delta`` patches the words,
drops exactly the flipped-bit tiles from the hot-block cache, refills
the freed slots from the new words, and swaps the arrays under the
same compiled step — in-flight requests keep the KV they built under
round t and continue under t+1, deterministically (the PR-8 semantics,
now per lane).
"""

from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..models.model import Model
from .cache import HotBlockCache, ServeConfig, build_cache
from .decode import ServeEngine, build_serve_engine
from .delta import ServeDelta, apply_delta
from .state import ServeState


@dataclass
class Request:
    """One queued/in-flight generation request."""

    rid: int
    prompt: np.ndarray  # (P,) int32
    max_new_tokens: int
    eos: Optional[int] = None
    tokens: List[int] = field(default_factory=list)
    fed: int = 0  # engine steps this request has taken


class ServeScheduler:
    """Fixed-lane continuous-batching driver for one serving node.

    Owns the compiled step, the lane KV cache, the current
    ``ServeState`` arrays, and (in cached mode) the hot-block cache.
    Host-side control plane: admission, per-lane token assembly,
    greedy sampling, retirement — everything device-side is the one
    jitted engine step at fixed (lanes, 1) shapes.
    """

    def __init__(self, model: Model, sstate: ServeState,
                 config: ServeConfig, *,
                 cache: Optional[HotBlockCache] = None,
                 engine: Optional[ServeEngine] = None):
        self.config = config
        self.sstate = sstate
        self.engine = engine or build_serve_engine(
            model, sstate, mode=config.mode, impl=config.impl)
        self.cache = cache
        if self.engine.mode == "cached" and self.cache is None:
            self.cache = build_cache(sstate, config)
        self.arrays = self.engine.arrays_of(sstate, cache=self.cache)
        self.kv = self.engine.init_lane_cache(config.lanes, config.seq_len)
        self._step = jax.jit(self.engine.step)
        self._lane: List[Optional[Request]] = [None] * config.lanes
        self._queue: deque = deque()
        self._next_rid = 0
        self.results: Dict[int, np.ndarray] = {}
        self.steps = 0

    # --- request lifecycle ----------------------------------------------
    def submit(self, prompt, max_new_tokens: Optional[int] = None,
               eos: Optional[int] = None) -> int:
        """Queue a request; returns its id (key into ``results``)."""
        prompt = np.asarray(prompt, np.int32).reshape(-1)
        new = max_new_tokens or self.config.max_new_tokens
        if prompt.size + new > self.config.seq_len:
            raise ValueError(
                f"prompt ({prompt.size}) + max_new_tokens ({new}) "
                f"exceeds lane seq_len {self.config.seq_len}"
            )
        rid = self._next_rid
        self._next_rid += 1
        self._queue.append(Request(rid=rid, prompt=prompt,
                                   max_new_tokens=new, eos=eos))
        return rid

    @property
    def active(self) -> int:
        return sum(r is not None for r in self._lane)

    @property
    def pending(self) -> int:
        return len(self._queue) + self.active

    def _admit(self) -> None:
        for l in range(self.config.lanes):
            if self._lane[l] is None and self._queue:
                self._lane[l] = self._queue.popleft()
                # lane recycling IS position reset — stale KV beyond
                # the validity mask never reaches the softmax
                self.kv = self.kv._replace(
                    pos=self.kv.pos.at[l].set(0))

    def _retire(self, l: int) -> None:
        req = self._lane[l]
        self.results[req.rid] = np.asarray(req.tokens, np.int32)
        self._lane[l] = None

    # --- the step -------------------------------------------------------
    def step_once(self) -> None:
        """Admit, advance every live lane one token, sample, retire."""
        self._admit()
        B = self.config.lanes
        tok = np.zeros((B, 1), np.int32)
        live = np.zeros((B,), bool)
        for l, req in enumerate(self._lane):
            if req is None:
                continue
            live[l] = True
            tok[l, 0] = (req.prompt[req.fed] if req.fed < req.prompt.size
                         else req.tokens[-1])
        logits, self.kv = self._step(self.arrays, self.kv,
                                     jnp.asarray(tok), jnp.asarray(live))
        self.steps += 1
        if self.cache is not None:
            self.cache.record_step()
        row = np.asarray(logits[:, 0])  # the per-step device sync
        for l, req in enumerate(self._lane):
            if req is None:
                continue
            req.fed += 1
            if req.fed >= req.prompt.size:
                nxt = int(np.argmax(row[l]))
                req.tokens.append(nxt)
                if (len(req.tokens) >= req.max_new_tokens
                        or nxt == req.eos):
                    self._retire(l)

    def run(self) -> Dict[int, np.ndarray]:
        """Drain the queue: step until every request retired.  Returns
        {rid: (new_tokens,) int32} for everything completed so far."""
        while self.pending:
            self.step_once()
        return self.results

    # --- round updates --------------------------------------------------
    def swap_state(self, sstate: ServeState) -> None:
        """Replace the serving state wholesale (full re-broadcast).
        Drops the whole hot-block cache; in-flight lanes keep their KV
        and continue under the new words."""
        if self.cache is not None:
            self.cache.invalidate_all()
            self.cache.fill(sstate)
        self.sstate = sstate
        self.arrays = self.engine.arrays_of(sstate, cache=self.cache)

    def apply_round_delta(self, delta: ServeDelta) -> ServeState:
        """Hot-swap mid-flight: patch words, invalidate exactly the
        flipped-bit tiles, refill the freed slots from the new words,
        swap arrays under the same compiled step."""
        new_state = apply_delta(self.sstate, delta, cache=self.cache)
        if self.cache is not None:
            self.cache.fill(new_state)
        self.sstate = new_state
        self.arrays = self.engine.arrays_of(new_state, cache=self.cache)
        return new_state

    # --- metrics --------------------------------------------------------
    def metrics(self) -> Dict[str, Any]:
        out = {
            "steps": self.steps,
            "lanes": self.config.lanes,
            "active": self.active,
            "queued": len(self._queue),
            "completed": len(self.results),
        }
        if self.cache is not None:
            out["cache"] = self.cache.stats()
        return out
