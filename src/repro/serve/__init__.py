from .decode import generate, serve_from_compressed

__all__ = ["generate", "serve_from_compressed"]
