from .cache import HotBlockCache, ServeConfig, build_cache
from .decode import (
    ServeEngine,
    build_serve_engine,
    generate,
    make_generator,
    serve_from_compressed,
    serve_generate,
)
from .delta import (
    ServeDelta,
    apply_delta,
    apply_word_delta,
    delta_flipped_windows,
    delta_report,
    lanes_delta,
    make_delta,
    word_delta,
)
from .scheduler import Request, ServeScheduler
from .state import ServeState, make_serve_state, reconstruct_resident

__all__ = [
    "ServeEngine", "ServeState", "ServeDelta",
    "ServeConfig", "HotBlockCache", "build_cache",
    "ServeScheduler", "Request",
    "build_serve_engine", "make_generator", "generate",
    "serve_generate", "serve_from_compressed",
    "make_serve_state", "reconstruct_resident",
    "make_delta", "apply_delta", "delta_report",
    "delta_flipped_windows",
    "word_delta", "apply_word_delta", "lanes_delta",
]
