"""Sharding planner: path/shape -> PartitionSpec over the production mesh.

Rules (DESIGN.md §5):
 - batch dims  -> ("pod","data") (replicated when not divisible, e.g.
   long_500k's batch=1);
 - vocab/embedding rows, MoE expert axis, d_ff/heads (last or
   second-to-last dim) -> "model", first divisible dim wins;
 - score vectors / masks (1-D, window-aligned) -> "model";
 - KV caches: batch -> data axes, kv-heads -> "model" when divisible
   (GQA kv<16 falls back to the sequence dim);
 - everything small/non-divisible -> replicated.

The planner only proposes; every spec is checked for divisibility
against the actual mesh before use, so one code path serves the 16x16
single-pod and 2x16x16 multi-pod meshes.
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P


def _axis_size(mesh, name: str) -> int:
    return dict(zip(mesh.axis_names, mesh.devices.shape)).get(name, 1)


def _data_axes(mesh) -> Tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def _data_size(mesh) -> int:
    n = 1
    for a in _data_axes(mesh):
        n *= _axis_size(mesh, a)
    return n


def _path_str(path) -> str:
    return "/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in path)


def param_spec(path: str, shape, mesh) -> P:
    ms = _axis_size(mesh, "model")
    ndim = len(shape)
    dims = [None] * ndim
    if ndim == 0:
        return P()
    pl = path.lower()
    if ndim == 1:
        # score/mask vectors are window-aligned; shard when divisible
        if ("scores" in pl or "mask" in pl) and shape[0] % ms == 0:
            return P("model")
        return P()
    # embedding tables: shard vocab rows
    if "embed" in pl and shape[-2] % ms == 0:
        dims[-2] = "model"
        return P(*dims)
    # MoE expert stacks (L, E, a, b): prefer expert parallelism
    if ndim >= 3 and any(t in pl for t in ("gate", "up", "down")) and (
        "moe" in pl or ndim == 4
    ):
        e_dim = ndim - 3
        if shape[e_dim] % ms == 0:
            dims[e_dim] = "model"
            return P(*dims)
    # Megatron pairing: down-proj and attention-out are ROW-parallel
    # (shard the contracting/input dim so the column-parallel producer's
    # sharded activations feed them without an all-gather; the output
    # psum is the cheap direction).
    order = ((ndim - 2, ndim - 1)
             if any(t in pl for t in ("down", "wo")) else
             (ndim - 1, ndim - 2))
    for d in order:
        if shape[d] % ms == 0 and shape[d] >= ms:
            dims[d] = "model"
            return P(*dims)
    return P()


def batch_spec(path: str, shape, mesh) -> P:
    """Model inputs: shard the leading (batch) dim over data axes."""
    if not shape:
        return P()
    dn = _data_size(mesh)
    dims: list = [None] * len(shape)
    if shape[0] % dn == 0 and shape[0] >= dn:
        dims[0] = _data_axes(mesh)
    return P(*dims)


def cache_spec(path: str, shape, mesh) -> P:
    """KV/SSM caches, stacked (L, B, ...): B -> data, heads/seq -> model."""
    ndim = len(shape)
    if ndim < 3:
        return P()
    ms = _axis_size(mesh, "model")
    dn = _data_size(mesh)
    dims: list = [None] * ndim
    if shape[1] % dn == 0 and shape[1] >= dn:
        dims[1] = _data_axes(mesh)
    # prefer a head-like dim (dim 3 of (L,B,C,KV,hd) / (L,B,H,P,N)),
    # then head_dim; the seq dim (2) LAST — the decode ring-buffer write
    # (dynamic-update-slice at a traced slot) forces copies across a
    # seq-sharded cache.
    for d in (3, ndim - 1, 2):
        if 1 < d < ndim and shape[d] % ms == 0 and shape[d] >= ms:
            dims[d] = "model"
            break
    return P(*dims)


def plan_tree(tree, mesh, kind: str) -> Any:
    """Pytree of NamedSharding matching ``tree`` (arrays or SDS)."""
    rule = {"param": param_spec, "input": batch_spec, "cache": cache_spec}[kind]

    def one(path, leaf):
        shape = tuple(getattr(leaf, "shape", ()))
        return NamedSharding(mesh, rule(_path_str(path), shape, mesh))

    return jax.tree_util.tree_map_with_path(one, tree)


def replicated(mesh) -> NamedSharding:
    return NamedSharding(mesh, P())


def shard_map_specs(tree, manual_axes: Tuple[str, ...], batch_dim0: bool):
    """shard_map in_specs: only the manual axes may appear."""

    def one(path, leaf):
        shape = tuple(getattr(leaf, "shape", ()))
        if batch_dim0 and shape:
            dn = 1
            for a in manual_axes:
                dn *= 1  # divisibility checked by caller
            if shape[0] >= len(manual_axes):
                dims = [manual_axes] + [None] * (len(shape) - 1)
                return P(*dims)
        return P()

    return jax.tree_util.tree_map_with_path(one, tree)
