"""Model inputs per (arch, input-shape): ShapeDtypeStruct stand-ins for
the dry-run (no allocation) and real random batches for smoke tests.

Modality stubs (DESIGN.md §4): VLM archs get precomputed patch/text
embeddings (B, S, d_model); audio/enc-dec archs get encoder frame
embeddings (B, S/4, d_model) — a 4x conv-codec downsampling stand-in —
plus decoder token ids.
"""

from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ArchConfig
from ..configs.registry import InputShape

ENC_DOWNSAMPLE = 4


def _embed_dtype(cfg: ArchConfig):
    return jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32


def input_specs(cfg: ArchConfig, shape: InputShape) -> Dict[str, Any]:
    """ShapeDtypeStructs for every model input of this (arch, shape)."""
    B, S = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    dt = _embed_dtype(cfg)
    if shape.kind == "decode":
        batch = {"tokens": jax.ShapeDtypeStruct((B, 1), i32)}
        return batch
    specs: Dict[str, Any] = {}
    if cfg.family in ("encdec", "audio"):
        specs["enc_embeds"] = jax.ShapeDtypeStruct(
            (B, S // ENC_DOWNSAMPLE, cfg.d_model), dt
        )
        specs["tokens"] = jax.ShapeDtypeStruct((B, S), i32)
    elif cfg.embed_stub:  # vlm
        specs["embeds"] = jax.ShapeDtypeStruct((B, S, cfg.d_model), dt)
    else:
        specs["tokens"] = jax.ShapeDtypeStruct((B, S), i32)
    if shape.kind == "train":
        specs["labels"] = jax.ShapeDtypeStruct((B, S), i32)
    return specs


def make_batch(cfg: ArchConfig, shape: InputShape, seed: int = 0
               ) -> Dict[str, Any]:
    """Concrete random batch with the same structure as input_specs."""
    rng = np.random.RandomState(seed)
    out = {}
    for name, sds in input_specs(cfg, shape).items():
        if jnp.issubdtype(sds.dtype, jnp.integer):
            out[name] = jnp.asarray(
                rng.randint(0, cfg.vocab, sds.shape), sds.dtype
            )
        else:
            out[name] = jnp.asarray(rng.randn(*sds.shape), sds.dtype)
    return out
