"""Production mesh builders.

Functions, not module constants — importing this module must never
touch jax device state (smoke tests see 1 CPU device; only dryrun.py
sets XLA_FLAGS for 512 placeholder devices).
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single pod (256 chips) or 2x16x16 multi-pod (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """Whatever this host has (1 CPU in CI) as a (data, model) mesh."""
    n = len(jax.devices())
    return jax.make_mesh((n, 1), ("data", "model"))


def data_axes(mesh) -> tuple:
    """The client/batch axes: ('pod','data') when multi-pod."""
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))
