import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x input-shape) on the
production mesh, record memory/cost/collective analyses.

This is the proof that the distribution config is coherent without real
hardware (the two lines above MUST precede any other import — jax locks
the device count on first init).

Per (arch, shape, mesh, mode):
 - train_4k    -> one FEDERATED ZAMPLING round (the paper's system):
                  shard_map manual over the client axes ('pod','data'),
                  GSPMD over 'model'; E local score-steps; mask psum.
                  mode='baseline' lowers standard dense-DP training
                  (fp32 grad all-reduce) for the communication
                  comparison in EXPERIMENTS.md.
 - prefill_32k -> forward logits over the full prompt.
 - decode_32k / long_500k -> serve_step: ONE token against a KV/SSM
                  cache of seq_len (ring-buffer under SWA).

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch yi-9b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod] [--mode baseline]
"""

import argparse
import dataclasses
import json
import re
import time
import traceback
from typing import Any, Dict

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from ..configs.base import ArchConfig
from ..configs.registry import ARCHS, SHAPES, InputShape, get_arch, get_shape
from ..core.federated import FederatedConfig, sharded_client_update
from ..core.zampling import ZamplingConfig, build_specs, state_spec
from ..launch import sharding as shp
from ..launch.input_specs import input_specs
from ..launch.mesh import data_axes, make_production_mesh
from ..models.model import build_model, loss_fn
from ..optim import sgd

DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s32": 4, "u32": 4,
               "s64": 8, "u64": 8, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
               "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1}

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Sum per-device result bytes of every collective op in the
    post-SPMD HLO. (cost_analysis does not report collectives.)"""
    out = {k: 0 for k in COLLECTIVES}
    array_re = re.compile(r"(\w+)\[([0-9,]*)\]")
    for line in hlo_text.splitlines():
        m = re.search(r"=\s*(.+?)\s+(all-reduce|all-gather|reduce-scatter|"
                      r"all-to-all|collective-permute)(-start|-done)?\(", line)
        if not m or m.group(3) == "-done":
            continue
        kind = m.group(2)
        lhs_types = m.group(1)
        nbytes = 0
        for dt, dims in array_re.findall(lhs_types):
            if dt not in DTYPE_BYTES:
                continue
            n = 1
            for d in dims.split(","):
                if d:
                    n *= int(d)
            nbytes += n * DTYPE_BYTES[dt]
        out[kind] += nbytes
    return out


def _sds(x):
    return jax.ShapeDtypeStruct(x.shape, x.dtype)


def zampling_config(cfg: ArchConfig) -> ZamplingConfig:
    """Paper-default reparametrization for the big archs: m/n=32, d=8."""
    return ZamplingConfig(compression=32.0, d=8, window=512, seed=0,
                          min_size=1_000_000, shard_align=16)


# ---------------------------------------------------------------------------
# step builders: return (jitted_fn, example_args_as_SDS)
# ---------------------------------------------------------------------------

def build_train_zampling(cfg: ArchConfig, shape: InputShape, mesh,
                         local_steps: int = 1):
    """One federated round: shard_map over client axes, mask psum."""
    model = build_model(cfg)
    params_sds = jax.eval_shape(model.init_params, jax.random.PRNGKey(0))

    def shard_plan(path, shape):
        spec = shp.param_spec(path, shape, mesh)
        for i, axis in enumerate(spec):
            if axis == "model" or (isinstance(axis, tuple)
                                   and "model" in axis):
                return i
        return None

    zspecs = build_specs(params_sds, zampling_config(cfg),
                         shard_plan_fn=shard_plan)
    tstate = state_spec(zspecs)
    daxes = data_axes(mesh)
    dsize = 1
    for a in daxes:
        dsize *= dict(zip(mesh.axis_names, mesh.devices.shape))[a]
    # plain PartitionSpecs: resolved against the context (abstract) mesh
    # inside shard_map — a concrete-mesh NamedSharding trips the
    # Manual/Auto axis-type check when closed over into scanned bodies
    constraints = {
        p: shp.param_spec(p, s.shape, mesh) for p, s in zspecs.specs.items()
    }
    fcfg = FederatedConfig(num_clients=dsize, local_steps=local_steps,
                           local_lr=0.1)

    def mloss(params, batch):
        return loss_fn(model, params, batch)

    def round_fn(state, batch, key):
        batches = jax.tree.map(lambda x: x[None], batch)  # E=1 local step
        return sharded_client_update(
            zspecs, state, mloss, batches, key, fcfg,
            axis_names=daxes, constraints=constraints,
            row_sharding=NamedSharding(mesh, P("model", None)),
        )

    # ---- shapes & shardings
    ins = input_specs(cfg, shape)
    state_shard = jax.tree.map(
        lambda l: NamedSharding(
            mesh, shp.param_spec("scores", l.shape, mesh)
            if l.ndim == 1 else shp.param_spec("dense", l.shape, mesh)
        ),
        tstate,
    )
    batch_shard = shp.plan_tree(ins, mesh, "input")
    key_sds = jax.ShapeDtypeStruct((2,), jnp.uint32)

    sm_in_specs = (
        jax.tree.map(lambda _: P(), tstate),
        jax.tree.map(
            lambda l: P(daxes) if (l.shape and l.shape[0] % dsize == 0
                                   and l.shape[0] >= dsize) else P(),
            ins,
        ),
        P(),
    )
    from ..core.federated import ROUND_METRIC_KEYS

    sm_out_specs = (
        jax.tree.map(lambda _: P(), tstate),
        {k: P() for k in ROUND_METRIC_KEYS},
    )

    smapped = jax.shard_map(
        round_fn, mesh=mesh, in_specs=sm_in_specs, out_specs=sm_out_specs,
        axis_names=set(daxes), check_vma=False,
    )
    jf = jax.jit(
        smapped,
        in_shardings=(state_shard, batch_shard, NamedSharding(mesh, P())),
        out_shardings=(
            state_shard,
            {k: NamedSharding(mesh, P()) for k in ROUND_METRIC_KEYS},
        ),
        donate_argnums=(0,),
    )
    meta = {
        "zampling": {
            "m_total": zspecs.m_total, "n_total": zspecs.n_total,
            "compression": zspecs.compression,
            "comm_bits": zspecs.comm_bits_per_round(),
        }
    }
    return jf, (tstate, ins, key_sds), meta


def build_train_baseline(cfg: ArchConfig, shape: InputShape, mesh):
    """Standard dense-DP training step (fp32 grad all-reduce baseline)."""
    model = build_model(cfg)
    optimizer = sgd(1e-2)
    params_sds = jax.eval_shape(model.init_params, jax.random.PRNGKey(0))
    opt_sds = jax.eval_shape(optimizer.init, params_sds)
    param_shard = shp.plan_tree(params_sds, mesh, "param")
    opt_shard = shp.plan_tree(opt_sds, mesh, "param")
    ins = input_specs(cfg, shape)
    batch_shard = shp.plan_tree(ins, mesh, "input")

    def step(params, opt_state, batch):
        def loss(p):
            return loss_fn(model, p, batch)

        l, grads = jax.value_and_grad(loss)(params)
        updates, opt_state = optimizer.update(grads, opt_state, params)
        params = jax.tree.map(lambda p, u: (p + u).astype(p.dtype), params,
                              updates)
        return params, opt_state, l

    jf = jax.jit(
        step,
        in_shardings=(param_shard, opt_shard, batch_shard),
        out_shardings=(param_shard, opt_shard, NamedSharding(mesh, P())),
        donate_argnums=(0, 1),
    )
    return jf, (params_sds, opt_sds, ins), {}


def build_prefill(cfg: ArchConfig, shape: InputShape, mesh):
    model = build_model(cfg)
    params_sds = jax.eval_shape(model.init_params, jax.random.PRNGKey(0))
    param_shard = shp.plan_tree(params_sds, mesh, "param")
    ins = input_specs(cfg, shape)
    batch_shard = shp.plan_tree(ins, mesh, "input")
    logits_shard = NamedSharding(
        mesh, P(data_axes(mesh) if shape.global_batch >= 16 else None, None,
                "model" if cfg.vocab % 16 == 0 else None)
    )

    def prefill(params, batch):
        # realistic prefill product: next-token logits for the LAST
        # position (returning all-position logits is a 33 GB/device
        # output at 32k x 256k vocab)
        logits, _ = model.forward(params, batch)
        return logits[:, -1:]

    jf = jax.jit(prefill, in_shardings=(param_shard, batch_shard),
                 out_shardings=logits_shard)
    return jf, (params_sds, ins), {}


def build_decode(cfg: ArchConfig, shape: InputShape, mesh,
                 window_override=None):
    model = build_model(cfg, window_override=window_override)
    params_sds = jax.eval_shape(model.init_params, jax.random.PRNGKey(0))
    param_shard = shp.plan_tree(params_sds, mesh, "param")
    cache_sds = jax.eval_shape(
        lambda: model.init_cache(None, shape.global_batch, shape.seq_len)
    )
    cache_shard = jax.tree.map(
        lambda l: NamedSharding(
            mesh, shp.cache_spec("cache", l.shape, mesh)
        ),
        cache_sds,
    )
    ins = input_specs(cfg, shape)
    batch_shard = shp.plan_tree(ins, mesh, "input")
    logits_shard = NamedSharding(
        mesh,
        P(data_axes(mesh) if shape.global_batch >= 16 else None, None,
          "model" if cfg.vocab % 16 == 0 else None),
    )

    def serve_step(params, cache, batch):
        return model.decode_step(params, cache, batch)

    jf = jax.jit(
        serve_step,
        in_shardings=(param_shard, cache_shard, batch_shard),
        out_shardings=(logits_shard, cache_shard),
        donate_argnums=(1,),  # alias the KV/SSM cache in place
    )
    return jf, (params_sds, cache_sds, ins), {}


# ---------------------------------------------------------------------------
# runner
# ---------------------------------------------------------------------------

def dryrun_one(arch: str, shape_name: str, *, multi_pod: bool = False,
               mode: str = "zampling", window_override=None,
               local_steps: int = 1) -> Dict[str, Any]:
    cfg = get_arch(arch)
    shape = get_shape(shape_name)
    ok, note = cfg.supports_shape(shape_name)
    if not ok:
        return {"arch": arch, "shape": shape_name, "skipped": True,
                "reason": note}
    if (shape_name == "long_500k" and cfg.family in ("dense", "moe", "hybrid")
            and cfg.window is None and window_override is None):
        # documented SWA long-context variant; for the hybrid the SSM
        # backbone carries the long-range state (Jamba-style)
        window_override = 4096
        note = "SWA variant W=4096"
    mesh = make_production_mesh(multi_pod=multi_pod)
    t0 = time.time()
    if shape.kind == "train":
        if mode == "zampling":
            jf, args, meta = build_train_zampling(cfg, shape, mesh,
                                                  local_steps=local_steps)
        else:
            jf, args, meta = build_train_baseline(cfg, shape, mesh)
    elif shape.kind == "prefill":
        jf, args, meta = build_prefill(cfg, shape, mesh)
    else:
        jf, args, meta = build_decode(cfg, shape, mesh,
                                      window_override=window_override)
    with jax.set_mesh(mesh):
        lowered = jf.lower(*args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
        ma = compiled.memory_analysis()
        ca = compiled.cost_analysis()
        colls = collective_bytes(compiled.as_text())
    res = {
        "arch": arch,
        "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "mode": mode,
        "note": note,
        "skipped": False,
        "lower_s": round(t_lower, 1),
        "compile_s": round(t_compile, 1),
        "flops_per_device": ca.get("flops", 0.0),
        "bytes_accessed_per_device": ca.get("bytes accessed", 0.0),
        "collective_bytes_per_device": colls,
        "memory": {
            "argument_bytes": ma.argument_size_in_bytes,
            "output_bytes": ma.output_size_in_bytes,
            "temp_bytes": ma.temp_size_in_bytes,
            "alias_bytes": ma.alias_size_in_bytes,
        },
        **meta,
    }
    return res


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--mode", default="zampling",
                    choices=["zampling", "baseline"])
    ap.add_argument("--local-steps", type=int, default=1)
    ap.add_argument("--out", default="experiments/dryrun")
    args = ap.parse_args()

    jobs = []
    if args.all:
        for a in sorted(ARCHS):
            for s in SHAPES:
                jobs.append((a, s))
    else:
        jobs.append((args.arch, args.shape))

    os.makedirs(args.out, exist_ok=True)
    failures = 0
    for arch, shape in jobs:
        tag = f"{arch}_{shape}_{'2x16x16' if args.multi_pod else '16x16'}_{args.mode}"
        path = os.path.join(args.out, tag + ".json")
        try:
            res = dryrun_one(arch, shape, multi_pod=args.multi_pod,
                             mode=args.mode, local_steps=args.local_steps)
        except Exception as e:  # noqa: BLE001 — record and continue
            failures += 1
            res = {"arch": arch, "shape": shape, "error": str(e),
                   "traceback": traceback.format_exc()}
        with open(path, "w") as f:
            json.dump(res, f, indent=2, default=float)
        status = ("SKIP " + res.get("reason", "") if res.get("skipped")
                  else "FAIL " + res.get("error", "")[:80]
                  if "error" in res else
                  f"ok compile={res['compile_s']}s "
                  f"flops/dev={res['flops_per_device']:.3g}")
        print(f"[dryrun] {tag}: {status}", flush=True)
    raise SystemExit(1 if failures else 0)


if __name__ == "__main__":
    main()
