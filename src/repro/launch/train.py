"""End-to-end LM training driver.

Trains an assigned architecture (optionally size-scaled) with FEDERATED
ZAMPLING on the synthetic Markov LM stream, on whatever devices exist
(1 CPU in this container; the production mesh via --mesh pod on real
hardware).  Demonstrates the full system: config -> model -> zampling
reparam -> federated rounds -> checkpoint.

  PYTHONPATH=src python -m repro.launch.train --arch qwen2-0.5b \
      --scale 0.25 --rounds 30 --local-steps 2 --clients 4 \
      --compression 8 --out runs/demo
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..checkpoint import save_checkpoint
from ..configs.registry import get_arch
from ..core import (
    FederatedConfig,
    ZamplingConfig,
    build_specs,
    federated_round,
    init_state,
)
from ..data import lm_token_batches
from ..models.model import build_model, loss_fn


def scaled(cfg, scale: float):
    """Shrink width/depth by ~scale (keeps the family & flavour)."""
    if scale >= 1.0:
        return cfg
    d = int(cfg.d_model * scale**0.5) // 64 * 64 or 64
    L = max(2, int(cfg.n_layers * scale**0.5))
    heads = max(1, int(cfg.n_heads * scale**0.5)) if cfg.n_heads else 0
    kv = max(1, min(cfg.n_kv, heads)) if cfg.n_kv else 0
    if heads:
        while heads % kv:
            kv -= 1
    return dataclasses.replace(
        cfg, d_model=d, n_layers=L, n_heads=heads, n_kv=kv,
        head_dim=64 if heads else 0,
        d_ff=int(cfg.d_ff * scale**0.5) // 64 * 64 if cfg.d_ff else 0,
        vocab=min(cfg.vocab, 8192), dtype="float32",
    )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--scale", type=float, default=0.25)
    ap.add_argument("--rounds", type=int, default=30)
    ap.add_argument("--clients", type=int, default=4)
    ap.add_argument("--local-steps", type=int, default=2)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--compression", type=float, default=8.0)
    ap.add_argument("--d", type=int, default=8)
    ap.add_argument("--lr", type=float, default=0.05)
    ap.add_argument("--out", default="runs/demo")
    args = ap.parse_args()

    cfg = scaled(get_arch(args.arch), args.scale)
    model = build_model(cfg)
    params_t = jax.eval_shape(model.init_params, jax.random.PRNGKey(0))
    n_params = sum(int(np.prod(l.shape)) for l in jax.tree.leaves(params_t))
    zspecs = build_specs(
        params_t,
        ZamplingConfig(compression=args.compression, d=args.d,
                       min_size=4096),
    )
    print(f"[train] arch={cfg.name} scaled: {n_params/1e6:.1f}M params, "
          f"reparam {zspecs.m_total/1e6:.1f}M -> {zspecs.n_total/1e6:.2f}M "
          f"trainable ({zspecs.compression:.1f}x), client upload/round = "
          f"{zspecs.n_total/8/1e3:.0f} KB vs naive "
          f"{zspecs.m_total*4/1e6:.0f} MB")

    # dense leaves initialised from a real model init
    real = model.init_params(jax.random.PRNGKey(0))
    state = init_state(jax.random.PRNGKey(1), zspecs, dense_init=real)
    del real

    fcfg = FederatedConfig(num_clients=args.clients,
                           local_steps=args.local_steps, local_lr=args.lr)

    def mloss(params, batch):
        return loss_fn(model, params, batch)

    @jax.jit
    def round_fn(state, batch, key):
        return federated_round(zspecs, state, mloss, batch, key, fcfg)

    stream = lm_token_batches(cfg.vocab, args.clients * args.local_steps
                              * args.batch, args.seq + 1, seed=0)
    key = jax.random.PRNGKey(0)
    os.makedirs(args.out, exist_ok=True)
    history = []
    for r in range(args.rounds):
        toks = next(stream).reshape(args.clients, args.local_steps,
                                    args.batch, args.seq + 1)
        batch = {"tokens": jnp.asarray(toks[..., :-1]),
                 "labels": jnp.asarray(toks[..., :-1])}
        key, sub = jax.random.split(key)
        t0 = time.time()
        state, met = round_fn(state, batch, sub)
        dt = time.time() - t0
        history.append(float(met["loss"]))
        print(f"[round {r:3d}] loss={met['loss']:.4f}  ({dt:.1f}s)",
              flush=True)

    save_checkpoint(os.path.join(args.out, "ckpt"), state,
                    meta={"arch": cfg.name, "q_seed": 0,
                          "rounds": args.rounds,
                          "compression": zspecs.compression})
    with open(os.path.join(args.out, "history.json"), "w") as f:
        json.dump(history, f)
    print(f"[train] done. loss {history[0]:.3f} -> {history[-1]:.3f}; "
          f"checkpoint at {args.out}/ckpt.npz")


if __name__ == "__main__":
    main()
