from .ckpt import (
    DOWNLINK_KEY,
    checkpoint_downlink,
    load_checkpoint,
    save_checkpoint,
)

__all__ = ["save_checkpoint", "load_checkpoint", "checkpoint_downlink",
           "DOWNLINK_KEY"]
