"""Checkpointing: pytree <-> npz with path-flattened keys + JSON meta.

A Zampling checkpoint is tiny by construction: the Q matrix is never
stored (it regenerates from ``meta['q_seed']``), so the artifact is the
score vectors (n floats ~ m/32), dense leaves, and optimizer state.

A state that carries an ENCODED score vector (the u8/u16 downlink
codec words, or the packed sub-byte codecs' uint32 lanes — see
``comm/downlink.py``) round-trips at its wire dtype:
``save_checkpoint`` records every leaf's dtype in the meta sidecar and
``load_checkpoint`` restores the SAVED dtype, never the template's.
The frontier schedule's per-tensor width vector
(``state['downlink_b']``, uint32) is an ordinary leaf and rides along
bitwise — include it in the load template when restoring a scheduled
carry.
Casting to the template (the old behavior) silently widened a u8
carry to the caller's f32 template — a 4x artifact blow-up AND a
corruption: wire words reinterpreted as probabilities.  The template
fixes only the tree STRUCTURE.

The codec tag is FIRST-CLASS: ``save_checkpoint(...,
downlink=codec.name)`` validates the name against the codec registry
and writes it under ``meta['downlink']``; ``checkpoint_downlink``
reads it back.  Route loaded score words by this tag
(``serve.state.make_serve_state(..., carried=tag)``), never by dtype
sniffing — a uint8 array is ambiguous on its own (u8 wire words? u8
token ids? somebody's quantized activations?), and the dtype-based
``infer_downlink`` can only say "it LOOKS like u8".  The tag says
what it IS.
"""

from __future__ import annotations

import json
import os
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np

_DTYPES_KEY = "__leaf_dtypes__"
DOWNLINK_KEY = "downlink"


def _flatten(tree) -> Dict[str, np.ndarray]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                       for k in path)
        out[key] = np.asarray(leaf)
    return out


def save_checkpoint(path: str, tree: Any, meta: Optional[Dict] = None,
                    *, downlink: Optional[str] = None) -> None:
    """Write ``tree`` as npz + JSON meta sidecar.

    ``downlink``: the codec name of an encoded score carry in ``tree``
    — validated against the codec registry and recorded as
    ``meta['downlink']`` so loaders route the words by tag instead of
    sniffing dtypes.  A ``downlink`` already present in ``meta`` is
    validated too (and must agree if both are given).
    """
    meta = dict(meta or {})
    if downlink is not None:
        if DOWNLINK_KEY in meta and meta[DOWNLINK_KEY] != downlink:
            raise ValueError(
                f"conflicting codec tags: downlink={downlink!r} vs "
                f"meta['downlink']={meta[DOWNLINK_KEY]!r}"
            )
        meta[DOWNLINK_KEY] = downlink
    if DOWNLINK_KEY in meta:
        from ..comm.downlink import get_codec  # comm sits above ckpt

        get_codec(meta[DOWNLINK_KEY])  # unknown name raises here
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    arrays = _flatten(tree)
    np.savez_compressed(path, **arrays)
    meta[_DTYPES_KEY] = {k: str(v.dtype) for k, v in arrays.items()}
    # sidecar name mirrors load_checkpoint whether or not the caller
    # spelled out the .npz suffix np.savez appends
    stem = path[:-4] if path.endswith(".npz") else path
    with open(stem + ".meta.json", "w") as f:
        json.dump(meta, f, indent=2, default=str)


def load_checkpoint(path: str, template: Any) -> Tuple[Any, Dict]:
    """Restore into the STRUCTURE of ``template`` at the SAVED dtypes.

    The saved dtype comes from the meta sidecar (old sidecars without
    the dtype record fall back to the npz arrays' own dtypes, which
    ``np.savez`` preserves anyway) — an encoded u8/u16 score carry
    comes back as wire words even when the template holds f32 scores.
    """
    data = np.load(path if path.endswith(".npz") else path + ".npz")
    meta = {}
    meta_path = (path[:-4] if path.endswith(".npz") else path) + ".meta.json"
    if os.path.exists(meta_path):
        with open(meta_path) as f:
            meta = json.load(f)
    dtypes = meta.pop(_DTYPES_KEY, {})
    flat, treedef = jax.tree_util.tree_flatten_with_path(template)
    leaves = []
    for p, leaf in flat:
        key = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                       for k in p)
        arr = data[key]
        if key in dtypes:
            arr = arr.astype(np.dtype(dtypes[key]))
        leaves.append(arr)
    return jax.tree_util.tree_unflatten(treedef, leaves), meta


def checkpoint_downlink(meta: Dict) -> Optional[str]:
    """The codec tag of a loaded checkpoint's score carry, validated
    against the registry; None when the checkpoint predates the tag
    (fall back to ``core.zampling.infer_downlink`` dtype sniffing at
    your own risk — u8 words and u8 token ids look alike)."""
    name = meta.get(DOWNLINK_KEY)
    if name is None:
        return None
    from ..comm.downlink import get_codec

    get_codec(name)
    return name
