"""Checkpointing: pytree <-> npz with path-flattened keys + JSON meta.

A Zampling checkpoint is tiny by construction: the Q matrix is never
stored (it regenerates from ``meta['q_seed']``), so the artifact is the
score vectors (n floats ~ m/32), dense leaves, and optimizer state.

A state that carries an ENCODED score vector (the u8/u16 downlink
codec words — see ``comm/downlink.py``) round-trips at its wire dtype:
``save_checkpoint`` records every leaf's dtype in the meta sidecar and
``load_checkpoint`` restores the SAVED dtype, never the template's.
Casting to the template (the old behavior) silently widened a u8
carry to the caller's f32 template — a 4x artifact blow-up AND a
corruption: wire words reinterpreted as probabilities.  The template
fixes only the tree STRUCTURE.  Tag the codec via
``meta={'downlink': codec.name}`` so a loader can route the words
without sniffing dtypes.
"""

from __future__ import annotations

import json
import os
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np

_DTYPES_KEY = "__leaf_dtypes__"


def _flatten(tree) -> Dict[str, np.ndarray]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                       for k in path)
        out[key] = np.asarray(leaf)
    return out


def save_checkpoint(path: str, tree: Any, meta: Optional[Dict] = None
                    ) -> None:
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    arrays = _flatten(tree)
    np.savez_compressed(path, **arrays)
    meta = dict(meta or {})
    meta[_DTYPES_KEY] = {k: str(v.dtype) for k, v in arrays.items()}
    with open(path + ".meta.json", "w") as f:
        json.dump(meta, f, indent=2, default=str)


def load_checkpoint(path: str, template: Any) -> Tuple[Any, Dict]:
    """Restore into the STRUCTURE of ``template`` at the SAVED dtypes.

    The saved dtype comes from the meta sidecar (old sidecars without
    the dtype record fall back to the npz arrays' own dtypes, which
    ``np.savez`` preserves anyway) — an encoded u8/u16 score carry
    comes back as wire words even when the template holds f32 scores.
    """
    data = np.load(path if path.endswith(".npz") else path + ".npz")
    meta = {}
    meta_path = (path[:-4] if path.endswith(".npz") else path) + ".meta.json"
    if os.path.exists(meta_path):
        with open(meta_path) as f:
            meta = json.load(f)
    dtypes = meta.pop(_DTYPES_KEY, {})
    flat, treedef = jax.tree_util.tree_flatten_with_path(template)
    leaves = []
    for p, leaf in flat:
        key = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                       for k in p)
        arr = data[key]
        if key in dtypes:
            arr = arr.astype(np.dtype(dtypes[key]))
        leaves.append(arr)
    return jax.tree_util.tree_unflatten(treedef, leaves), meta
