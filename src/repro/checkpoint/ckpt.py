"""Checkpointing: pytree <-> npz with path-flattened keys + JSON meta.

A Zampling checkpoint is tiny by construction: the Q matrix is never
stored (it regenerates from ``meta['q_seed']``), so the artifact is the
score vectors (n floats ~ m/32), dense leaves, and optimizer state.
"""

from __future__ import annotations

import json
import os
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np


def _flatten(tree) -> Dict[str, np.ndarray]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                       for k in path)
        out[key] = np.asarray(leaf)
    return out


def save_checkpoint(path: str, tree: Any, meta: Optional[Dict] = None
                    ) -> None:
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    arrays = _flatten(tree)
    np.savez_compressed(path, **arrays)
    with open(path + ".meta.json", "w") as f:
        json.dump(meta or {}, f, indent=2, default=str)


def load_checkpoint(path: str, template: Any) -> Tuple[Any, Dict]:
    """Restore into the structure of ``template``."""
    data = np.load(path if path.endswith(".npz") else path + ".npz")
    flat, treedef = jax.tree_util.tree_flatten_with_path(template)
    leaves = []
    for p, leaf in flat:
        key = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                       for k in p)
        arr = data[key]
        if hasattr(leaf, "dtype"):
            arr = arr.astype(leaf.dtype)
        leaves.append(arr)
    meta = {}
    meta_path = (path[:-4] if path.endswith(".npz") else path) + ".meta.json"
    if os.path.exists(meta_path):
        with open(meta_path) as f:
            meta = json.load(f)
    return jax.tree_util.tree_unflatten(treedef, leaves), meta
