"""Native optimizers (no optax in the container) — optax-style triples.

An Optimizer is (init, update):
    state = opt.init(params)
    updates, state = opt.update(grads, state, params)
    params = apply_updates(params, updates)

Adam matches the paper's experimental setup (Adam, beta1=0.9); SGD is
used for the federated local steps where the paper quotes a plain
learning rate.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, NamedTuple, Sequence

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class Optimizer:
    init: Callable[[Any], Any]
    update: Callable[[Any, Any, Any], Any]


def apply_updates(params, updates):
    return jax.tree.map(lambda p, u: (p + u).astype(p.dtype), params, updates)


def sgd(lr: float, momentum: float = 0.0) -> Optimizer:
    def init(params):
        if momentum == 0.0:
            return ()
        return jax.tree.map(jnp.zeros_like, params)

    def update(grads, state, params=None):
        del params
        if momentum == 0.0:
            return jax.tree.map(lambda g: -lr * g, grads), state
        new_v = jax.tree.map(lambda v, g: momentum * v + g, state, grads)
        return jax.tree.map(lambda v: -lr * v, new_v), new_v

    return Optimizer(init, update)


class AdamState(NamedTuple):
    step: jnp.ndarray
    mu: Any
    nu: Any


def adam(lr: float, b1: float = 0.9, b2: float = 0.999,
         eps: float = 1e-8) -> Optimizer:
    def init(params):
        z = lambda: jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        return AdamState(jnp.zeros((), jnp.int32), z(), z())

    def update(grads, state, params=None):
        del params
        step = state.step + 1
        mu = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g, state.mu, grads)
        nu = jax.tree.map(lambda v, g: b2 * v + (1 - b2) * g * g, state.nu, grads)
        t = step.astype(jnp.float32)
        bc1 = 1.0 - b1 ** t
        bc2 = 1.0 - b2 ** t
        upd = jax.tree.map(
            lambda m, v: -lr * (m / bc1) / (jnp.sqrt(v / bc2) + eps), mu, nu
        )
        return upd, AdamState(step, mu, nu)

    return Optimizer(init, update)


def clip_by_global_norm(max_norm: float) -> Optimizer:
    def init(params):
        return ()

    def update(grads, state, params=None):
        del params
        norm = jnp.sqrt(
            sum(jnp.sum(jnp.square(g)) for g in jax.tree.leaves(grads))
        )
        scale = jnp.minimum(1.0, max_norm / (norm + 1e-12))
        return jax.tree.map(lambda g: g * scale, grads), state

    return Optimizer(init, update)


def chain(*opts: Optimizer) -> Optimizer:
    """Left-to-right composition; each stage transforms the updates."""

    def init(params):
        return tuple(o.init(params) for o in opts)

    def update(grads, state, params=None):
        new_states = []
        upd = grads
        for o, s in zip(opts, state):
            upd, ns = o.update(upd, s, params)
            new_states.append(ns)
        return upd, tuple(new_states)

    return Optimizer(init, update)
