from .optimizers import Optimizer, adam, sgd, clip_by_global_norm, chain

__all__ = ["Optimizer", "adam", "sgd", "clip_by_global_norm", "chain"]
