"""Architecture + input-shape registry (``--arch`` / ``--shape`` lookup)."""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict

from .base import ArchConfig
from .mamba2_1_3b import CONFIG as _mamba2
from .mixtral_8x7b import CONFIG as _mixtral
from .olmoe_1b_7b import CONFIG as _olmoe
from .pixtral_12b import CONFIG as _pixtral
from .qwen1_5_4b import CONFIG as _qwen15
from .qwen2_0_5b import CONFIG as _qwen2
from .qwen3_14b import CONFIG as _qwen3
from .seamless_m4t_medium import CONFIG as _seamless
from .yi_9b import CONFIG as _yi
from .zamba2_7b import CONFIG as _zamba2

ARCHS: Dict[str, ArchConfig] = {
    c.name: c
    for c in [
        _mamba2, _pixtral, _seamless, _olmoe, _yi, _qwen15, _zamba2,
        _mixtral, _qwen2, _qwen3,
    ]
}


@dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES: Dict[str, InputShape] = {
    s.name: s
    for s in [
        InputShape("train_4k", 4_096, 256, "train"),
        InputShape("prefill_32k", 32_768, 32, "prefill"),
        InputShape("decode_32k", 32_768, 128, "decode"),
        InputShape("long_500k", 524_288, 1, "decode"),
    ]
}


def get_arch(name: str) -> ArchConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; have {sorted(ARCHS)}")
    return ARCHS[name]


def get_shape(name: str) -> InputShape:
    if name not in SHAPES:
        raise KeyError(f"unknown shape {name!r}; have {sorted(SHAPES)}")
    return SHAPES[name]
