from .base import ArchConfig, EncoderConfig, MoEConfig, SSMConfig
from .registry import ARCHS, SHAPES, InputShape, get_arch, get_shape

__all__ = [
    "ArchConfig", "EncoderConfig", "MoEConfig", "SSMConfig",
    "ARCHS", "SHAPES", "InputShape", "get_arch", "get_shape",
]
