"""mamba2-1.3b — SSD (state-space duality) [arXiv:2405.21060].

48L d_model=2048, attention-free (d_ff=0: the Mamba2 block subsumes the
MLP), vocab=50280, ssm_state=128.
"""

from .base import ArchConfig, SSMConfig

CONFIG = ArchConfig(
    name="mamba2-1.3b",
    family="ssm",
    n_layers=48,
    d_model=2048,
    vocab=50280,
    d_ff=0,
    ssm=SSMConfig(d_state=128, headdim=64, expand=2, conv_width=4, chunk=128),
    source="arXiv:2405.21060",
)
