"""qwen2-0.5b — GQA, QKV bias [arXiv:2407.10671].

24L d_model=896, 14H GQA kv=2, d_ff=4864, vocab=151936.
"""

from .base import ArchConfig

CONFIG = ArchConfig(
    name="qwen2-0.5b",
    family="dense",
    n_layers=24,
    d_model=896,
    vocab=151936,
    n_heads=14,
    n_kv=2,
    d_ff=4864,
    qkv_bias=True,
    tie_embeddings=True,
    source="arXiv:2407.10671",
)
