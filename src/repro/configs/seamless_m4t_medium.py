"""seamless-m4t-medium — enc-dec, multimodal [arXiv:2308.11596].

12L decoder, d_model=1024, 16H kv=16, d_ff=4096, vocab=256206; 12-layer
encoder consuming STUBBED mel/conv frame embeddings (B, S/4, d_model)
via ``input_specs`` (the conv-codec front-end is the documented stub).
"""

from .base import ArchConfig, EncoderConfig

CONFIG = ArchConfig(
    name="seamless-m4t-medium",
    family="audio",
    n_layers=12,
    d_model=1024,
    vocab=256206,
    n_heads=16,
    n_kv=16,
    d_ff=4096,
    encoder=EncoderConfig(n_layers=12, n_heads=16, n_kv=16, d_ff=4096),
    embed_stub=True,
    source="arXiv:2308.11596",
)
