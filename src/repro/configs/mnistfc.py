"""The paper's own architectures (MNIST feedforward, §3).

Not part of the assigned-arch pool; used by the faithful-reproduction
experiments and benchmarks.  SMALL: 784-20-20-10 (§3.1, §3.3);
MNISTFC: 784-300-100-10 = 266,610 params (§3.2, App. B.1).
"""

from ..models.mlp import MNISTFC_DIMS, SMALL_DIMS, param_count

SMALL = SMALL_DIMS
MNISTFC = MNISTFC_DIMS

assert param_count(MNISTFC) == 266_610  # paper's figure, §3.2
