"""pixtral-12b — pixtral-ViT + mistral-nemo backbone
[hf:mistralai/Pixtral-12B-2409].

40L d_model=5120, 32H GQA kv=8, d_ff=14336, vocab=131072.  The vision
encoder + projector are STUBBED per the brief: ``input_specs`` feeds
precomputed patch/text embeddings of shape (B, S, d_model) to the
decoder (``embed_stub=True``).
"""

from .base import ArchConfig

CONFIG = ArchConfig(
    name="pixtral-12b",
    family="vlm",
    n_layers=40,
    d_model=5120,
    vocab=131072,
    n_heads=32,
    n_kv=8,
    head_dim=160,
    d_ff=14336,
    rope_theta=1_000_000.0,
    embed_stub=True,
    source="hf:mistralai/Pixtral-12B-2409",
)
