"""qwen3-14b — qk-norm, GQA [hf:Qwen/Qwen3-8B family].

40L d_model=5120, 40H GQA kv=8 (head_dim=128), d_ff=17408, vocab=151936.
"""

from .base import ArchConfig

CONFIG = ArchConfig(
    name="qwen3-14b",
    family="dense",
    n_layers=40,
    d_model=5120,
    vocab=151936,
    n_heads=40,
    n_kv=8,
    head_dim=128,
    d_ff=17408,
    qk_norm=True,
    rope_theta=1_000_000.0,
    source="hf:Qwen/Qwen3-8B",
)
