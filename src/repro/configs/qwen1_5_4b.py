"""qwen1.5-4b — QKV bias [hf:Qwen/Qwen1.5-0.5B family].

40L d_model=2560, 20H kv=20 (MHA), d_ff=6912, vocab=151936.
"""

from .base import ArchConfig

CONFIG = ArchConfig(
    name="qwen1.5-4b",
    family="dense",
    n_layers=40,
    d_model=2560,
    vocab=151936,
    n_heads=20,
    n_kv=20,
    d_ff=6912,
    qkv_bias=True,
    source="hf:Qwen/Qwen1.5-0.5B",
)
