"""mixtral-8x7b — 8 experts top-2, sliding-window attention
[arXiv:2401.04088].

32L d_model=4096, 32H GQA kv=8, expert d_ff=14336, vocab=32000, SWA 4096
(native — so long_500k runs with a ring-buffer KV cache).
"""

from .base import ArchConfig, MoEConfig

CONFIG = ArchConfig(
    name="mixtral-8x7b",
    family="moe",
    n_layers=32,
    d_model=4096,
    vocab=32000,
    n_heads=32,
    n_kv=8,
    d_ff=0,
    window=4096,
    moe=MoEConfig(num_experts=8, top_k=2, d_ff_expert=14336),
    source="arXiv:2401.04088",
)
