"""Architecture config schema.

One ``ArchConfig`` fully determines a model: the decoder/encoder stack,
attention flavour (GQA, qkv-bias, qk-norm, sliding window), MoE and SSM
blocks, and modality front-end stubs.  ``reduced()`` returns the
CI-scale variant used by the per-arch smoke tests (2 layers,
d_model <= 512, <= 4 experts) — same family, same code paths.
"""

from __future__ import annotations

from dataclasses import dataclass, field, replace
from typing import Optional, Tuple


@dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    d_ff_expert: int
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.01


@dataclass(frozen=True)
class SSMConfig:
    d_state: int = 128
    headdim: int = 64
    expand: int = 2
    conv_width: int = 4
    chunk: int = 128
    n_groups: int = 1


@dataclass(frozen=True)
class EncoderConfig:
    """Encoder stack for enc-dec archs (Seamless)."""

    n_layers: int = 12
    n_heads: int = 16
    n_kv: int = 16
    d_ff: int = 4096


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | encdec | vlm | audio
    n_layers: int
    d_model: int
    vocab: int
    n_heads: int = 0  # 0 for attention-free
    n_kv: int = 0
    head_dim: int = 0  # 0 -> d_model // n_heads
    d_ff: int = 0
    qkv_bias: bool = False
    qk_norm: bool = False
    window: Optional[int] = None  # sliding-window size (Mixtral 4096)
    rope_theta: float = 10_000.0
    tie_embeddings: bool = False
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    encoder: Optional[EncoderConfig] = None
    # hybrid (Zamba2): one SHARED attention block applied every k layers
    attn_every: int = 0
    # modality stub: model consumes precomputed embeddings, not token ids
    embed_stub: bool = False
    dtype: str = "bfloat16"
    source: str = ""  # citation

    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // max(self.n_heads, 1)

    @property
    def padded_vocab(self) -> int:
        """Vocab rounded to 256 so embed/lm_head shard over 'model'
        (unpadded 50280-style vocabs force full-logit replication —
        measured 13 GB/device f32 at 4k seq). CE masks the pad columns."""
        return ((self.vocab + 255) // 256) * 256

    def reduced(self) -> "ArchConfig":
        """Smoke-test variant: 2 layers, d_model<=512, <=4 experts."""
        d_model = min(self.d_model, 256)
        n_heads = min(self.n_heads, 4) if self.n_heads else 0
        n_kv = min(self.n_kv, max(1, n_heads // 2)) if self.n_kv else 0
        moe = None
        if self.moe is not None:
            moe = replace(
                self.moe,
                num_experts=min(self.moe.num_experts, 4),
                top_k=min(self.moe.top_k, 2),
                d_ff_expert=min(self.moe.d_ff_expert, 128),
            )
        ssm = None
        if self.ssm is not None:
            ssm = replace(self.ssm, d_state=min(self.ssm.d_state, 16),
                          headdim=32, chunk=16)
        enc = None
        if self.encoder is not None:
            enc = replace(self.encoder, n_layers=2, n_heads=4, n_kv=4,
                          d_ff=128)
        return replace(
            self,
            n_layers=2,
            d_model=d_model,
            n_heads=n_heads,
            n_kv=n_kv,
            head_dim=64 if self.n_heads else 0,
            d_ff=min(self.d_ff, 512) if self.d_ff else 0,
            vocab=min(self.vocab, 512),
            window=min(self.window, 64) if self.window else None,
            moe=moe,
            ssm=ssm,
            encoder=enc,
            attn_every=2 if self.attn_every else 0,
            dtype="float32",
        )

    def supports_shape(self, shape_name: str) -> Tuple[bool, str]:
        """Which input shapes this arch runs (DESIGN.md §4 skips)."""
        if shape_name == "long_500k":
            if self.family in ("ssm", "hybrid"):
                return True, "sub-quadratic (SSM/hybrid)"
            if self.window is not None:
                return True, f"sliding-window attention (W={self.window})"
            if self.family in ("dense", "moe"):
                return True, "SWA long-context variant (DESIGN.md §4)"
            return False, ("full-attention VLM/enc-dec arch: quadratic "
                           "attention at 500k; no SWA variant published")
        return True, ""


def param_count_estimate(cfg: ArchConfig) -> int:
    """Rough N for MODEL_FLOPS=6ND accounting (embeddings excluded)."""
    d = cfg.d_model
    hd = cfg.resolved_head_dim
    per_layer = 0
    if cfg.n_heads:
        per_layer += d * hd * (cfg.n_heads + 2 * cfg.n_kv) + cfg.n_heads * hd * d
    if cfg.moe is not None:
        per_layer += 3 * d * cfg.moe.d_ff_expert * cfg.moe.num_experts
        per_layer += d * cfg.moe.num_experts
    elif cfg.d_ff:
        per_layer += 3 * d * cfg.d_ff
    if cfg.ssm is not None:
        di = cfg.ssm.expand * d
        nh = di // cfg.ssm.headdim
        conv_dim = di + 2 * cfg.ssm.n_groups * cfg.ssm.d_state
        per_layer += d * (2 * di + 2 * cfg.ssm.n_groups * cfg.ssm.d_state + nh)
        per_layer += conv_dim * cfg.ssm.conv_width + di * d + nh * 2 + di
    total = cfg.n_layers * per_layer
    total += 2 * cfg.vocab * d  # embed + head
    if cfg.encoder is not None:
        e = cfg.encoder
        total += e.n_layers * (4 * d * d + 3 * d * e.d_ff)
    return int(total)


def active_param_count_estimate(cfg: ArchConfig) -> int:
    """N_active for MoE (6·N_active·D accounting)."""
    if cfg.moe is None:
        return param_count_estimate(cfg)
    d = cfg.d_model
    dense_moe = 3 * d * cfg.moe.d_ff_expert * cfg.moe.num_experts
    active_moe = 3 * d * cfg.moe.d_ff_expert * cfg.moe.top_k
    return param_count_estimate(cfg) - cfg.n_layers * (dense_moe - active_moe)
