"""olmoe-1b-7b — 64 experts top-8 [arXiv:2409.02060].

16L d_model=2048, 16H kv=16, expert d_ff=1024, vocab=50304.
"""

from .base import ArchConfig, MoEConfig

CONFIG = ArchConfig(
    name="olmoe-1b-7b",
    family="moe",
    n_layers=16,
    d_model=2048,
    vocab=50304,
    n_heads=16,
    n_kv=16,
    d_ff=0,
    qk_norm=True,
    moe=MoEConfig(num_experts=64, top_k=8, d_ff_expert=1024),
    source="arXiv:2409.02060",
)
