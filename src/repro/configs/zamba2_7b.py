"""zamba2-7b — Mamba2 backbone + shared attention blocks [arXiv:2411.15242].

81 Mamba2 layers, d_model=3584, ssm_state=64; ONE shared attn+MLP block
(32H kv=32, d_ff=14336) applied every 6 mamba layers with reused
weights, vocab=32000.
"""

from .base import ArchConfig, SSMConfig

CONFIG = ArchConfig(
    name="zamba2-7b",
    family="hybrid",
    n_layers=81,
    d_model=3584,
    vocab=32000,
    n_heads=32,
    n_kv=32,
    d_ff=14336,
    attn_every=6,
    ssm=SSMConfig(d_state=64, headdim=64, expand=2, conv_width=4, chunk=128),
    source="arXiv:2411.15242",
)
