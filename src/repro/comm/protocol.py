"""Transport/Aggregator strategies for the federated mask upload.

The server update is always ``p(t+1) = (1/K) sum_k z^(k)`` — what
differs between strategies is the WIRE FORMAT of the K client
contributions and where the unpack happens:

 - ``mean_f32``         the baseline: clients ship the {0,1} mask as
   f32 (4 bytes/coordinate) and the server psums floats — today's
   data-parallel-shaped traffic;
 - ``psum_u32``         clients bitpack ``z`` into uint32 lanes
   (n bits + padding on the wire) and the reduction is an integer
   psum of the per-coordinate bit counts — a lane-wise popcount
   accumulated across the client axis;
 - ``allgather_packed`` clients bitpack and the server all-gathers the
   raw lanes (K·n bits total), then unpacks and averages — the
   paper's literal upload-n-bits protocol, and the strategy string
   that ``FederatedConfig.aggregate`` always promised.

All three are bit-exact against each other: the vote counts are exact
small integers in every representation, and every strategy performs
the same final ``counts / K`` f32 division.  Strategies assume BINARY
masks; ``resolve_transport`` falls back to ``mean_f32`` for continuous
(probability-valued) uploads, which cannot be bitpacked.

Partial participation (the fault-tolerant round, ``repro.fault``):
every strategy also exposes WEIGHTED variants that return the
UNNORMALIZED weighted sum ``sum_k w_k z^(k)`` — participation bits
{0,1} and per-client sample counts enter the reduction as exact uint32
multiplies on the packed strategies (exact while ``sum(w) < 2^32``)
and as exact f32 multiplies on ``mean_f32`` (binary z times an integer
weight below 2^24).  The caller normalizes by the REALIZED weight sum
(``core.federated``), so a dropped / corrupt client (weight 0)
contributes nothing and the mean stays exact over the survivors.  With
all weights 1 the multiplies are identities: the weighted reduction is
bit-identical to the unweighted one.

Each strategy exposes both execution paths of the federated round:
``aggregate_stacked`` for the vmap simulation (a stacked (K, n) slab on
one host) and ``aggregate_collective`` for the ``shard_map`` production
path where the client axis is a mesh axis and the collective IS the
network.

STREAMING aggregation (the unbounded-K mode, ``core.federated``
``stream_chunk``): every strategy additionally exposes ``stream_init``
/ ``fold_stacked_weighted`` / ``fold_stacked_packed_weighted`` — the
server holds one (n,) accumulator of unnormalized weighted vote counts
and FOLDS each chunk of C uploads into it as they "arrive", so the
(K, n) slab never materializes and peak upload memory is O(C·n)
whatever K is.  The fold is bit-exact against the slab reduction by
construction: the packed carry is uint32 (integer addition is
associative) and the ``mean_f32`` carry is an f32 sum of exact
integer-valued terms (binary z × integer weight, exact while
``sum(w) < 2^24``) — the same exact integer counts in a different
association.  A straggler past the round cutoff is simply an upload
never folded in.
"""

from __future__ import annotations

from typing import Dict, List, Sequence, Tuple

import jax
import jax.numpy as jnp

from .bitpack import (
    pack_mask,
    packed_len,
    packed_popcount_sum,
    packed_weighted_fold,
    packed_weighted_sum,
    unpack_mask,
)
from .shardmap import axis_size


class Transport:
    """One wire-format strategy. Subclasses define the three hooks.

    Packed-native entry points: strategies with ``packed_wire`` take
    the clients' uint32 lanes DIRECTLY (``aggregate_stacked_packed`` /
    ``aggregate_collective_packed``) — the fused mask lifecycle
    (``kernels.ops.sample_pack``) emits lanes from the score vector, so
    the f32 mask slab never exists between the client update and the
    wire.  The f32-mask entry points remain for the composed oracle and
    for ``mean_f32``.
    """

    name: str = "?"
    packed_wire: bool = False  # True: native operand is uint32 lanes

    def uplink_bits_per_client(self, n: int) -> int:
        """Exact bits one client puts on the wire for an n-coord mask."""
        raise NotImplementedError

    def aggregate_stacked(self, Z):
        """(K, n) stacked client masks -> (n,) f32 mean."""
        raise NotImplementedError

    def aggregate_collective(self, z, axis_names: Sequence[str]):
        """Per-client (n,) mask -> replicated (n,) f32 mean, via
        collectives over ``axis_names`` (call inside shard_map)."""
        raise NotImplementedError

    def aggregate_stacked_packed(self, lanes, n: int):
        """(K, L) stacked uint32 lanes -> (n,) f32 mean."""
        raise NotImplementedError(
            f"transport {self.name!r} does not take packed lanes"
        )

    def aggregate_collective_packed(self, lanes, n: int,
                                    axis_names: Sequence[str]):
        """Per-client (L,) uint32 lanes -> replicated (n,) f32 mean."""
        raise NotImplementedError(
            f"transport {self.name!r} does not take packed lanes"
        )

    # ---- weighted (partial-participation) variants: UNNORMALIZED
    # sums; the round driver divides by the realized weight sum

    def aggregate_stacked_weighted(self, Z, weights):
        """(K, n) masks x (K,) uint32 weights -> (n,) f32 weighted sum."""
        raise NotImplementedError

    def aggregate_collective_weighted(self, z, weight,
                                      axis_names: Sequence[str]):
        """Per-client (n,) mask x scalar uint32 weight -> replicated
        (n,) f32 weighted sum over ``axis_names``."""
        raise NotImplementedError

    def aggregate_stacked_packed_weighted(self, lanes, n: int, weights):
        """(K, L) lanes x (K,) uint32 weights -> (n,) uint32 weighted
        vote counts (exact while sum(weights) < 2^32)."""
        raise NotImplementedError(
            f"transport {self.name!r} does not take packed lanes"
        )

    def aggregate_collective_packed_weighted(self, lanes, n: int, weight,
                                             axis_names: Sequence[str]):
        """Per-client (L,) lanes x scalar uint32 weight -> replicated
        (n,) uint32 weighted vote counts."""
        raise NotImplementedError(
            f"transport {self.name!r} does not take packed lanes"
        )

    # ---- streaming folds (unbounded K): the server's accumulator is
    # ONE (n,) vote-count vector; chunks of C uploads fold into it so
    # the (K, ·) slab never materializes.  Defined once here in terms
    # of the unnormalized weighted sums, so every strategy streams with
    # the identical integer arithmetic it uses on the slab path.

    def stream_init(self, n: int):
        """Zero vote-count accumulator for an n-coordinate mask:
        uint32 on the packed-wire strategies, f32 (exact integer
        values) on ``mean_f32``."""
        dtype = jnp.uint32 if self.packed_wire else jnp.float32
        return jnp.zeros((n,), dtype)

    def fold_stacked_weighted(self, acc, Z, weights):
        """Fold a (C, n) mask chunk × (C,) uint32 weights into the
        (n,) f32 accumulator.  Each chunk sum is an exact integer in
        f32, so any chunking reproduces the slab sum bit for bit."""
        return acc + self.aggregate_stacked_weighted(Z, weights)

    def fold_stacked_packed_weighted(self, acc, lanes, n: int, weights):
        """Fold a (C, L) uint32 lane chunk × (C,) uint32 weights into
        the (n,) uint32 accumulator (associative integer addition —
        bit-identical to the one-shot slab reduction)."""
        return acc + self.aggregate_stacked_packed_weighted(lanes, n,
                                                            weights)


class MeanF32(Transport):
    """Baseline: f32 masks, float psum — 32 bits/coordinate uplink."""

    name = "mean_f32"

    def uplink_bits_per_client(self, n: int) -> int:
        return 32 * n

    def aggregate_stacked(self, Z):
        return jnp.sum(Z.astype(jnp.float32), axis=0) / Z.shape[0]

    def aggregate_collective(self, z, axis_names):
        names = tuple(axis_names)
        return jax.lax.psum(z.astype(jnp.float32), names) / axis_size(names)

    def aggregate_stacked_weighted(self, Z, weights):
        # z * w is exact (binary z, integer w < 2^24 in f32), and at
        # w == 1 the multiply is the identity: bit-identical sum
        w = weights.astype(jnp.float32)[:, None]
        return jnp.sum(Z.astype(jnp.float32) * w, axis=0)

    def aggregate_collective_weighted(self, z, weight, axis_names):
        names = tuple(axis_names)
        return jax.lax.psum(
            z.astype(jnp.float32) * weight.astype(jnp.float32), names
        )


def _popcount_mean(Z):
    """Stacked (K, n) masks -> (n,) f32 mean via the packed wire: both
    bitpacked strategies share this exact reduction, so a change to
    one cannot silently break bit-exactness of the other."""
    packed = pack_mask(Z)  # (K, L) — the wire representation
    counts = packed_popcount_sum(packed, Z.shape[-1])
    return counts.astype(jnp.float32) / Z.shape[0]


def _packed_mean(lanes, n: int):
    """(K, L) uint32 lanes -> (n,) f32 mean — the native-lane version
    of ``_popcount_mean`` (identical reduction on identical bits)."""
    counts = packed_popcount_sum(lanes, n)
    return counts.astype(jnp.float32) / lanes.shape[0]


class PsumU32(Transport):
    """Bitpacked wire + integer psum of per-coordinate bit counts."""

    name = "psum_u32"
    packed_wire = True

    def uplink_bits_per_client(self, n: int) -> int:
        return 32 * packed_len(n)

    def aggregate_stacked(self, Z):
        return _popcount_mean(Z)

    def aggregate_collective(self, z, axis_names):
        return self.aggregate_collective_packed(pack_mask(z), z.shape[-1],
                                                axis_names)

    def aggregate_stacked_packed(self, lanes, n):
        return _packed_mean(lanes, n)

    def aggregate_collective_packed(self, lanes, n, axis_names):
        # XLA has no sub-word all-reduce, so the SIMULATED collective
        # operand is the unpacked uint32 vector; the metered uplink is
        # the protocol's packed client upload (each contribution is
        # losslessly n bits), not this operand's width — see
        # comm.metering.  allgather_packed keeps raw lanes on the wire
        # end to end.
        names = tuple(axis_names)
        bits = unpack_mask(lanes, n, dtype=jnp.uint32)
        counts = jax.lax.psum(bits, names)
        return counts.astype(jnp.float32) / axis_size(names)

    def aggregate_stacked_packed_weighted(self, lanes, n, weights):
        return packed_weighted_sum(lanes, n, weights)

    def fold_stacked_packed_weighted(self, acc, lanes, n, weights):
        return packed_weighted_fold(acc, lanes, n, weights)

    def aggregate_collective_packed_weighted(self, lanes, n, weight,
                                             axis_names):
        names = tuple(axis_names)
        bits = unpack_mask(lanes, n, dtype=jnp.uint32)
        return jax.lax.psum(bits * weight.astype(jnp.uint32), names)


class AllgatherPacked(Transport):
    """Bitpacked wire, raw lanes all-gathered; server-side unpack."""

    name = "allgather_packed"
    packed_wire = True

    def uplink_bits_per_client(self, n: int) -> int:
        return 32 * packed_len(n)

    def aggregate_stacked(self, Z):
        # the server's view after the gather: K packed lanes to reduce
        return _popcount_mean(Z)

    def aggregate_collective(self, z, axis_names):
        return self.aggregate_collective_packed(pack_mask(z), z.shape[-1],
                                                axis_names)

    def aggregate_stacked_packed(self, lanes, n):
        return _packed_mean(lanes, n)

    def aggregate_collective_packed(self, lanes, n, axis_names):
        names = tuple(axis_names)
        k = axis_size(names)
        gathered = jax.lax.all_gather(lanes, names, axis=0)  # (K, L)
        counts = packed_popcount_sum(gathered.reshape(k, -1), n)
        return counts.astype(jnp.float32) / k

    def aggregate_stacked_packed_weighted(self, lanes, n, weights):
        return packed_weighted_sum(lanes, n, weights)

    def fold_stacked_packed_weighted(self, acc, lanes, n, weights):
        return packed_weighted_fold(acc, lanes, n, weights)

    def aggregate_collective_packed_weighted(self, lanes, n, weight,
                                             axis_names):
        # gather raw lanes AND weights: the server sees every upload
        # with its weight and reduces exactly as the stacked path does
        names = tuple(axis_names)
        k = axis_size(names)
        gathered = jax.lax.all_gather(lanes, names, axis=0)  # (K, L)
        w = jax.lax.all_gather(weight.astype(jnp.uint32), names, axis=0)
        return packed_weighted_sum(gathered.reshape(k, -1), n,
                                   w.reshape(k))


_REGISTRY: Dict[str, Transport] = {}
_ALIASES: Dict[str, str] = {}


def register_transport(transport: Transport,
                       aliases: Tuple[str, ...] = ()) -> Transport:
    """Add a strategy (and optional alias names) to the registry."""
    _REGISTRY[transport.name] = transport
    for a in aliases:
        _ALIASES[a] = transport.name
    return transport


def transport_names(include_aliases: bool = True) -> List[str]:
    names = sorted(_REGISTRY)
    if include_aliases:
        names += sorted(_ALIASES)
    return names


def get_transport(name: str) -> Transport:
    canonical = _ALIASES.get(name, name)
    if canonical not in _REGISTRY:
        raise ValueError(
            f"unknown transport {name!r}; registered: "
            f"{', '.join(transport_names())}"
        )
    return _REGISTRY[canonical]


def resolve_transport(aggregate: str, mode: str = "sample") -> Transport:
    """Strategy for a round: bit transports need binary masks, so
    continuous (probability-valued) uploads fall back to ``mean_f32``.
    Sampled AND discretized uploads are binary — both keep the
    configured transport (and its wire accounting)."""
    if mode == "continuous":
        return get_transport("mean_f32")
    return get_transport(aggregate)


register_transport(MeanF32(), aliases=("mean",))
register_transport(PsumU32())
register_transport(AllgatherPacked())
