"""jax-version compat for entering ``shard_map`` and sizing mesh axes.

The transport collectives (``protocol``), the sharded reconstruction
(``kernels.qz_sharded``) and the scan-over-rounds sharded driver
(``train.fit``) all run bodies under ``shard_map``.  On jax versions
without the top-level ``jax.shard_map`` entry point the mesh is taken
from the ambient ``with mesh:`` context instead, so every path is
exercisable on a forced-multi-device CPU too.
"""

from __future__ import annotations

from typing import Sequence

import jax


def axis_size(axis_names: Sequence[str]) -> int:
    """Total device count across the named mesh axes, inside shard_map.

    ``psum`` of a python scalar constant-folds to a concrete int at
    trace time on every jax version (``jax.lax.axis_size`` does not
    exist on 0.4.x).
    """
    return jax.lax.psum(1, tuple(axis_names))


def shard_map_compat(f, axis_names: Sequence[str], in_specs, out_specs):
    """``jax.shard_map`` when available; else the experimental API bound
    to the ambient ``with mesh:`` context (jax<=0.4.x spelling)."""
    names = tuple(axis_names)
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, in_specs=in_specs, out_specs=out_specs,
                             axis_names=set(names), check_vma=False)
    from jax._src import mesh as mesh_lib
    from jax.experimental.shard_map import shard_map as _sm

    mesh = mesh_lib.thread_resources.env.physical_mesh
    missing = [a for a in names if mesh.empty or a not in mesh.axis_names]
    if missing:
        raise RuntimeError(
            f"shard_map needs an active mesh with axes {names} "
            f"(enter `with mesh:`) on this jax version; missing {missing}"
        )
    return _sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
               check_rep=False)
