"""Wire-format layer — how bits cross the network, BOTH directions.

The paper's entire communication story is the wire: a client uploads
``z ∈ {0,1}^n`` as *n bits*, and the server broadcasts the score
vector back.  This package makes both directions first-class, measured
subsystems:

 - ``bitpack``   — batched (K, n) <-> (K, ceil(n/32)) uint32 lane
   packing plus the packed-popcount reduction, composable with ``vmap``
   and with ``psum``/``all_gather`` inside ``shard_map``;
 - ``protocol``  — the ``Transport`` abstraction and the three
   interchangeable UPLINK aggregation strategies (``mean_f32``,
   ``psum_u32``, ``allgather_packed``), all bit-exact against each
   other;
 - ``downlink``  — the ``DownlinkCodec`` registry for the server's
   score broadcast (``f32`` identity oracle, ``u16``/``u8``
   probability-space quantizers whose widened-threshold draw is exact
   at the draw-word level);
 - ``metering``  — exact uplink AND downlink byte accounting per round
   per (transport, codec) (surfaced in round metrics, paper tables,
   benchmarks);
 - ``shardmap``  — the jax-version compat shim for entering
   ``shard_map`` from an ambient mesh (shared with ``kernels``).
"""

from .bitpack import pack_mask, packed_len, packed_popcount_sum, unpack_mask
from .downlink import (
    DownlinkCodec,
    codec_for_dtype,
    codec_names,
    get_codec,
    register_codec,
)
from .metering import (
    downlink_table,
    mask_uplink_bytes,
    round_wire_report,
    score_downlink_bytes,
    streaming_peak_bytes,
    upload_slab_bytes,
    wire_table,
)
from .protocol import (
    Transport,
    get_transport,
    register_transport,
    resolve_transport,
    transport_names,
)
from .shardmap import axis_size, shard_map_compat

__all__ = [
    "pack_mask", "packed_len", "packed_popcount_sum", "unpack_mask",
    "DownlinkCodec", "codec_for_dtype", "codec_names", "get_codec",
    "register_codec",
    "mask_uplink_bytes", "score_downlink_bytes", "round_wire_report",
    "upload_slab_bytes", "streaming_peak_bytes",
    "wire_table", "downlink_table",
    "Transport", "get_transport", "register_transport",
    "resolve_transport", "transport_names",
    "axis_size", "shard_map_compat",
]
