"""Wire-format transport layer — how client masks cross the network.

The paper's entire communication story is that a client uploads
``z ∈ {0,1}^n`` as *n bits*.  This package makes that wire format a
first-class, measured subsystem:

 - ``bitpack``   — batched (K, n) <-> (K, ceil(n/32)) uint32 lane
   packing plus the packed-popcount reduction, composable with ``vmap``
   and with ``psum``/``all_gather`` inside ``shard_map``;
 - ``protocol``  — the ``Transport`` abstraction and the three
   interchangeable aggregation strategies (``mean_f32``, ``psum_u32``,
   ``allgather_packed``), all bit-exact against each other;
 - ``metering``  — exact uplink/downlink byte accounting per round per
   strategy (surfaced in round metrics, paper tables, benchmarks);
 - ``shardmap``  — the jax-version compat shim for entering
   ``shard_map`` from an ambient mesh (shared with ``kernels``).
"""

from .bitpack import pack_mask, packed_len, packed_popcount_sum, unpack_mask
from .metering import mask_uplink_bytes, round_wire_report, wire_table
from .protocol import (
    Transport,
    get_transport,
    register_transport,
    resolve_transport,
    transport_names,
)
from .shardmap import axis_size, shard_map_compat

__all__ = [
    "pack_mask", "packed_len", "packed_popcount_sum", "unpack_mask",
    "mask_uplink_bytes", "round_wire_report", "wire_table",
    "Transport", "get_transport", "register_transport",
    "resolve_transport", "transport_names",
    "axis_size", "shard_map_compat",
]
