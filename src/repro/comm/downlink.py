"""Downlink codec — the wire format of the server's p(t) broadcast.

The uplink has been bits-on-the-wire since the transport layer
(``comm.protocol``), but the server's score broadcast was still a full
f32 vector: 32n bits, the dominant term of the round's traffic.  This
module makes the downlink representation a first-class, registered
strategy — the protocol-level counterpart of ``Transport`` — and the
ENCODED scores ARE the federated round's carried state
(``core.federated`` threads them through the round/scan drivers).

Why quantizing in probability space is nearly free here: a client never
uses the broadcast score s except through the Bernoulli compare
``z = 1[uniform(hash) <= f(s)]`` (and as the init of its local SGD), so
it only needs the probability at the precision of that compare.  The
codec therefore transmits ``q = dithered_round(f(s) * (2^b - 1))`` in b
bits per coordinate and DEFINES the decoded probability as the exactly
achievable threshold value:

    T(q)   = floor(q * 2^24 / (2^b - 1))     (``quant_threshold_u24``)
    p_hat  = T(q) * 2^-24                     (exact in f32)

so the client-side draw is a pure integer compare of the 24-bit draw
word against the widened threshold — ``(hash >> 8) < T(q)`` — with
P(z=1 | q) EXACTLY p_hat at the draw-word level (no double rounding
through a float compare), and bit-identical to ``bernoulli_u32`` on
p_hat.  No dequantized f32 score slab exists on the draw path
(``core.sampling.sample_mask_qhash``; in-kernel:
``kernels.ops.sample_reconstruct(..., qbits=b)``).

Encode dither: ``q = floor(p*S + 1/4 + dither/2)`` with ``dither in
[0, 1)`` from the counter-hash stream (``core.sampling
.QUANT_DITHER_CTR``, words ``(spec.seed, spec.tensor_id, CTR, word,
coord)``).  Deterministic-but-pseudorandom: every shard re-encoding the
replicated aggregate regenerates the identical dither from the shared
round word, so server and clients agree WITHOUT extra bits, while the
rounding error decorrelates across coordinates and rounds.  The
half-amplitude dither keeps the worst-case step error at 3/4 of a
quantization step, so the encode→decode round trip is within
``2^-b`` of the input (pinned in tests/test_downlink.py).

Registered codecs: ``f32`` (identity — the bit-exact oracle; a
``downlink='f32'`` round is bit-identical to the pre-codec protocol),
``u16`` and ``u8`` (16/8 bits per coordinate, 2x/4x downlink
reduction).  ``comm.metering`` meters whichever codec the round
configures, exactly.

DELTA WIRE FORMAT (serve.delta — the serving fleet's round update).
A serving node already holds round t's word vector, so round t+1
broadcasts only the XOR of the two rounds' word bit patterns (f32
words bitcast to uint32 first): zero where unchanged, involutive to
apply.  On the wire each leaf ships the cheaper of

    bitmap:     ceil(n/8) presence bits  + changed · (bits/8)
    coord list: 4-byte count             + changed · (4 + bits/8)

plus one 4-byte draw word for the update (``comm.metering
.delta_wire_bytes`` is the exact accounting; a full broadcast is
``downlink_bits_per_client(n)/8``).  The format leans on a DITHER
REUSE rule: the encode dither is keyed by ``word`` (above), so a
server that re-encodes each round under a FRESH word re-dithers every
coordinate and flips ~half the quantized words even when no score
moved — deltas degenerate to full broadcasts.  Serving encoders must
pin one dither word across rounds (``serve.state.make_serve_state``'s
``dither_word``); then an unchanged probability re-encodes to an
unchanged word and the delta is supported exactly on the coordinates
the aggregate actually moved.  Training rounds keep the per-round
word — the reuse rule is a serving-wire convention, not a change to
the federated protocol.
"""

from __future__ import annotations

from typing import Dict, List, Tuple

import jax.numpy as jnp
import numpy as np

# NOTE: no top-level ``repro.core`` import — ``core.federated`` imports
# this package eagerly (registry validation at config construction), so
# the draw/dither primitives are imported lazily inside the methods.

_INV_2_24 = np.float32(1.0 / (1 << 24))


class DownlinkCodec:
    """One downlink wire format. Subclasses define the four hooks.

    ``encode`` runs wherever the aggregate lives (the vmap server, or
    every shard of the shard_map path on the replicated aggregate);
    ``decode`` runs on the client to seed its trainable score copy.
    The mask-draw path does NOT decode: quantized codecs draw through
    the widened-threshold integer compare (``threshold_u24``).
    """

    name: str = "?"
    bits: int = 32  # wire bits per coordinate
    wire_dtype = jnp.float32
    quantized: bool = False  # True: wire words are b-bit uints

    def downlink_bits_per_client(self, n: int) -> int:
        """Exact bits the server puts on the wire per client for an
        n-coordinate score broadcast."""
        return self.bits * n

    def encode(self, spec, scores, word):
        """f32 scores -> wire representation (``word``: the shared
        round word keying the dither stream; unused by ``f32``)."""
        raise NotImplementedError

    def decode(self, spec, wire):
        """Wire representation -> f32 probabilities."""
        raise NotImplementedError

    def threshold_u24(self, wire):
        """Wire words -> widened uint32 draw thresholds in [0, 2^24]."""
        raise NotImplementedError(
            f"codec {self.name!r} has no quantized threshold"
        )


class F32Down(DownlinkCodec):
    """Identity: the full f32 score vector, today's broadcast.  The
    bit-exact oracle — encode and decode pass arrays through untouched,
    so a ``downlink='f32'`` round is bit-identical to the pre-codec
    protocol on every execution path."""

    name = "f32"
    bits = 32
    quantized = False

    def encode(self, spec, scores, word):
        del spec, word
        return scores

    def decode(self, spec, wire):
        del spec
        return wire


class QuantizedDown(DownlinkCodec):
    """b-bit probability words with shared-stream dithered rounding."""

    quantized = True

    def __init__(self, name: str, bits: int, wire_dtype):
        self.name = name
        self.bits = bits
        self.wire_dtype = wire_dtype
        self._scale = np.float32((1 << bits) - 1)

    def _dither(self, spec, word, n: int):
        """Shared dither in [0, 1): regenerated identically by every
        party from (spec.seed, spec.tensor_id, word, coord)."""
        from ..core.hashrng import hash_u32
        from ..core.sampling import QUANT_DITHER_CTR

        coords = jnp.arange(n, dtype=jnp.uint32)
        u = hash_u32(spec.seed, spec.tensor_id, QUANT_DITHER_CTR,
                     jnp.asarray(word, jnp.uint32), coords)
        return (u >> np.uint32(8)).astype(jnp.float32) * _INV_2_24

    def encode(self, spec, scores, word):
        from ..core.sampling import clip_probs

        p = clip_probs(jnp.asarray(scores, jnp.float32))
        d = self._dither(spec, word, p.shape[-1])
        q = jnp.floor(p * self._scale + np.float32(0.25)
                      + np.float32(0.5) * d)
        return jnp.clip(q, 0.0, self._scale).astype(self.wire_dtype)

    def decode(self, spec, wire):
        del spec
        return self.threshold_u24(wire).astype(jnp.float32) * _INV_2_24

    def threshold_u24(self, wire):
        from ..core.sampling import quant_threshold_u24

        return quant_threshold_u24(wire, self.bits)


_REGISTRY: Dict[str, DownlinkCodec] = {}
_ALIASES: Dict[str, str] = {}


def register_codec(codec: DownlinkCodec,
                   aliases: Tuple[str, ...] = ()) -> DownlinkCodec:
    """Add a downlink codec (and optional aliases) to the registry."""
    _REGISTRY[codec.name] = codec
    for a in aliases:
        _ALIASES[a] = codec.name
    return codec


def codec_names(include_aliases: bool = True) -> List[str]:
    names = sorted(_REGISTRY)
    if include_aliases:
        names += sorted(_ALIASES)
    return names


def get_codec(name: str) -> DownlinkCodec:
    canonical = _ALIASES.get(name, name)
    if canonical not in _REGISTRY:
        raise ValueError(
            f"unknown downlink codec {name!r}; registered: "
            f"{', '.join(codec_names())}"
        )
    return _REGISTRY[canonical]


def codec_for_dtype(dtype) -> DownlinkCodec:
    """The quantized codec whose wire dtype matches, or ``f32`` for
    floating score leaves — how ``core.zampling.sample_weights`` infers
    the broadcast representation from an encoded state."""
    dtype = jnp.dtype(dtype)
    if jnp.issubdtype(dtype, jnp.floating):
        return get_codec("f32")
    for codec in _REGISTRY.values():
        if codec.quantized and jnp.dtype(codec.wire_dtype) == dtype:
            return codec
    raise ValueError(
        f"no downlink codec carries dtype {dtype}; registered: "
        f"{', '.join(codec_names())}"
    )


register_codec(F32Down())
register_codec(QuantizedDown("u16", 16, jnp.uint16))
register_codec(QuantizedDown("u8", 8, jnp.uint8))
