"""Downlink codec — the wire format of the server's p(t) broadcast.

The uplink has been bits-on-the-wire since the transport layer
(``comm.protocol``), but the server's score broadcast was still a full
f32 vector: 32n bits, the dominant term of the round's traffic.  This
module makes the downlink representation a first-class, registered
strategy — the protocol-level counterpart of ``Transport`` — and the
ENCODED scores ARE the federated round's carried state
(``core.federated`` threads them through the round/scan drivers).

Why quantizing in probability space is nearly free here: a client never
uses the broadcast score s except through the Bernoulli compare
``z = 1[uniform(hash) <= f(s)]`` (and as the init of its local SGD), so
it only needs the probability at the precision of that compare.  The
codec therefore transmits ``q = dithered_round(f(s) * (2^b - 1))`` in b
bits per coordinate and DEFINES the decoded probability as the exactly
achievable threshold value:

    T(q)   = floor(q * 2^24 / (2^b - 1))     (``quant_threshold_u24``)
    p_hat  = T(q) * 2^-24                     (exact in f32)

so the client-side draw is a pure integer compare of the 24-bit draw
word against the widened threshold — ``(hash >> 8) < T(q)`` — with
P(z=1 | q) EXACTLY p_hat at the draw-word level (no double rounding
through a float compare), and bit-identical to ``bernoulli_u32`` on
p_hat.  No dequantized f32 score slab exists on the draw path
(``core.sampling.sample_mask_qhash``; in-kernel:
``kernels.ops.sample_reconstruct(..., qbits=b)``).

Encode dither: ``q = floor(p*S + 1/4 + dither/2)`` with ``dither in
[0, 1)`` from the counter-hash stream (``core.sampling
.QUANT_DITHER_CTR``, words ``(spec.seed, spec.tensor_id, CTR, word,
coord)``).  Deterministic-but-pseudorandom: every shard re-encoding the
replicated aggregate regenerates the identical dither from the shared
round word, so server and clients agree WITHOUT extra bits, while the
rounding error decorrelates across coordinates and rounds.  The
half-amplitude dither keeps the worst-case step error at 3/4 of a
quantization step, so the encode→decode round trip is within
``2^-b`` of the input (pinned in tests/test_downlink.py).

PACKED SUB-BYTE LANES (the ``packed{b}`` family).  Below 8 bits there
is no native dtype to carry a word per coordinate, so the sub-byte
codecs pack ``wpl = floor(32/b)`` b-bit words into each uint32 lane —
the SAME uint32-lane carrier as the uplink mask packing
(``comm.bitpack``; word j of lane i is coordinate ``i*wpl + j`` at bit
offset ``b*j``, ``pack_words``/``unpack_words``).  The lanes are the
round's NATIVE carried state through both scan fits: encode quantizes
exactly as above and packs; the fused draw kernels
(``kernels.qz_reconstruct``/``qz_decode``) take the lanes as their
operand and unpack IN-BLOCK, per window tile, before the widened
threshold compare — no per-coordinate word slab (let alone an f32
score slab) ever materializes on the draw path (jaxpr-asserted in
tests).  ``packed4``/``packed2`` (aliases ``u4``/``u2``) are
registered by default; ``packed_codec(b)`` builds any width b in
[1, 16].  Metering counts REALIZED lane bytes — ``32·ceil(n/wpl)``
bits — so non-multiple-of-8 widths and the wasted top ``32 mod b``
bits of a non-divisor width (e.g. b=6, wpl=5) are spent, not hidden.
NOTE the routing consequence: every packed codec's wire dtype is
uint32, so dtype sniffing (``codec_for_dtype``,
``core.zampling.infer_downlink``) is AMBIGUOUS on packed carries and
raises — route packed states by explicit tag (``carried=``/
``downlink=`` arguments; ``meta['downlink']`` of a checkpoint).

SCHEDULED RATE CONTROL (``FederatedConfig.downlink_schedule``).  The
codec's width ``b_max = codec.bits`` is a CEILING, not the spent rate:
``encode_at(spec, p, word, b)`` quantizes at any (possibly traced)
width ``b <= b_max`` and EMBEDS the b-bit word into the codec's
b_max-bit alphabet via

    q_bmax = round(q_b * S_bmax / S_b)    (exact uint32 arithmetic),

which is the IDENTITY at ``b = b_max`` (bit-for-bit the plain
``encode``) and exact threshold equality ``T_bmax(q_bmax) = T_b(q_b)``
whenever ``b | b_max`` (then ``S_b | S_bmax``); other widths round to
the nearest representable threshold.  Only b bits per word cross the
wire — the widening multiplier is a shared constant — so
``comm.metering.scheduled_downlink_*`` meters the round at the
scheduled width while the carry keeps ONE fixed lane layout and every
consumer of the carry (fused kernels, serving, checkpoints) stays at
the static ``b_max`` fast path.  ``core.federated`` turns this into
the per-round, per-tensor controller (constant / cosine / frontier);
the dither word is the round word either way, shared exactly as above.

Registered codecs: ``f32`` (identity — the bit-exact oracle; a
``downlink='f32'`` round is bit-identical to the pre-codec protocol),
``u16`` and ``u8`` (16/8 bits per coordinate, 2x/4x downlink
reduction), ``packed4`` and ``packed2`` (4/2 bits per coordinate in
uint32 lanes, 8x/16x).  ``comm.metering`` meters whichever codec the
round configures, exactly.

DELTA WIRE FORMAT (serve.delta — the serving fleet's round update).
A serving node already holds round t's word vector, so round t+1
broadcasts only the XOR of the two rounds' word bit patterns (f32
words bitcast to uint32 first): zero where unchanged, involutive to
apply.  On the wire each leaf ships the cheaper of

    bitmap:     ceil(n/8) presence bits  + changed · (bits/8)
    coord list: 4-byte count             + changed · (4 + bits/8)

plus one 4-byte draw word for the update (``comm.metering
.delta_wire_bytes`` is the exact accounting; a full broadcast is
``downlink_bits_per_client(n)/8``; packed codecs delta whole uint32
LANES — a lane is the atomic wire unit).  The format leans on a DITHER
REUSE rule: the encode dither is keyed by ``word`` (above), so a
server that re-encodes each round under a FRESH word re-dithers every
coordinate and flips ~half the quantized words even when no score
moved — deltas degenerate to full broadcasts.  Serving encoders must
pin one dither word across rounds (``serve.state.make_serve_state``'s
``dither_word``); then an unchanged probability re-encodes to an
unchanged word and the delta is supported exactly on the coordinates
the aggregate actually moved.  Training rounds keep the per-round
word — the reuse rule is a serving-wire convention, not a change to
the federated protocol.
"""

from __future__ import annotations

from typing import Dict, List, Tuple

import jax.numpy as jnp
import numpy as np

from .bitpack import pack_words, packed_word_len, unpack_words, words_per_lane

# NOTE: no top-level ``repro.core`` import — ``core.federated`` imports
# this package eagerly (registry validation at config construction), so
# the draw/dither primitives are imported lazily inside the methods.

_INV_2_24 = np.float32(1.0 / (1 << 24))


class DownlinkCodec:
    """One downlink wire format. Subclasses define the four hooks.

    ``encode`` runs wherever the aggregate lives (the vmap server, or
    every shard of the shard_map path on the replicated aggregate);
    ``decode`` runs on the client to seed its trainable score copy.
    The mask-draw path does NOT decode: quantized codecs draw through
    the widened-threshold integer compare (``threshold_u24``).
    """

    name: str = "?"
    bits: int = 32  # wire bits per coordinate
    wire_dtype = jnp.float32
    quantized: bool = False  # True: wire words are b-bit uints
    packed: bool = False  # True: wire is b-bit words in uint32 lanes

    def downlink_bits_per_client(self, n: int) -> int:
        """Exact bits the server puts on the wire per client for an
        n-coordinate score broadcast."""
        return self.bits * n

    def wire_len(self, n: int) -> int:
        """Wire-leaf length for an n-coordinate score vector (n for
        the word-per-coordinate codecs; lane count for packed)."""
        return n

    def encode(self, spec, scores, word):
        """f32 scores -> wire representation (``word``: the shared
        round word keying the dither stream; unused by ``f32``)."""
        raise NotImplementedError

    def decode(self, spec, wire):
        """Wire representation -> f32 probabilities."""
        raise NotImplementedError

    def wire_words(self, spec, wire):
        """Encoded leaf -> per-coordinate b-bit words (identity for the
        word-per-coordinate codecs; lane unpack for packed)."""
        del spec
        return wire

    def threshold_u24(self, wire):
        """Per-coordinate wire WORDS -> widened uint32 draw thresholds
        in [0, 2^24] (packed codecs: ``wire_words`` first)."""
        raise NotImplementedError(
            f"codec {self.name!r} has no quantized threshold"
        )


class F32Down(DownlinkCodec):
    """Identity: the full f32 score vector, today's broadcast.  The
    bit-exact oracle — encode and decode pass arrays through untouched,
    so a ``downlink='f32'`` round is bit-identical to the pre-codec
    protocol on every execution path."""

    name = "f32"
    bits = 32
    quantized = False

    def encode(self, spec, scores, word):
        del spec, word
        return scores

    def decode(self, spec, wire):
        del spec
        return wire


class QuantizedDown(DownlinkCodec):
    """b-bit probability words with shared-stream dithered rounding."""

    quantized = True

    def __init__(self, name: str, bits: int, wire_dtype):
        self.name = name
        self.bits = bits
        self.wire_dtype = wire_dtype
        self._scale = np.float32((1 << bits) - 1)

    def _dither(self, spec, word, n: int):
        """Shared dither in [0, 1): regenerated identically by every
        party from (spec.seed, spec.tensor_id, word, coord)."""
        from ..core.hashrng import hash_u32
        from ..core.sampling import QUANT_DITHER_CTR

        coords = jnp.arange(n, dtype=jnp.uint32)
        u = hash_u32(spec.seed, spec.tensor_id, QUANT_DITHER_CTR,
                     jnp.asarray(word, jnp.uint32), coords)
        return (u >> np.uint32(8)).astype(jnp.float32) * _INV_2_24

    def _wire_of_words(self, q):
        """Per-coordinate uint words -> this codec's wire leaf."""
        return q.astype(self.wire_dtype)

    def encode(self, spec, scores, word):
        from ..core.sampling import clip_probs

        p = clip_probs(jnp.asarray(scores, jnp.float32))
        d = self._dither(spec, word, p.shape[-1])
        q = jnp.floor(p * self._scale + np.float32(0.25)
                      + np.float32(0.5) * d)
        return self._wire_of_words(jnp.clip(q, 0.0, self._scale))

    def encode_at(self, spec, scores, word, bits):
        """Scheduled encode: quantize at (possibly TRACED) width
        ``bits <= self.bits``, then embed in this codec's alphabet.

        The b-bit word ``q_b = floor(p·S_b + 1/4 + dither/2)`` (the
        same dither stream as ``encode``, so server and clients agree
        with zero extra bits) is widened to ``q = round(q_b·S/S_b)``
        with ``S = 2^self.bits - 1`` — exact uint32 arithmetic
        ``(q_b·S + S_b//2) // S_b``, which is the bitwise identity at
        ``bits == self.bits`` and the exact threshold embedding
        ``T(q) == T_b(q_b)`` whenever ``bits | self.bits``.  Only
        ``bits`` bits per word cross the wire (the widening is a shared
        deterministic map); the carry keeps this codec's fixed wire
        layout, so every downstream consumer stays on the static fast
        path.  ``bits`` may be a traced uint32 scalar — the downlink
        schedules re-quantize per round inside one compiled scan.
        """
        from ..core.sampling import clip_probs

        p = clip_probs(jnp.asarray(scores, jnp.float32))
        d = self._dither(spec, word, p.shape[-1])
        b = jnp.asarray(bits).astype(jnp.uint32)
        s_b = (jnp.uint32(1) << b) - jnp.uint32(1)
        s_bf = s_b.astype(jnp.float32)
        q_b = jnp.floor(p * s_bf + np.float32(0.25)
                        + np.float32(0.5) * d)
        q_b = jnp.clip(q_b, 0.0, s_bf).astype(jnp.uint32)
        s_max = np.uint32((1 << self.bits) - 1)
        q = (q_b * s_max + s_b // jnp.uint32(2)) // s_b
        return self._wire_of_words(q)

    def decode(self, spec, wire):
        words = self.wire_words(spec, wire)
        return self.threshold_u24(words).astype(jnp.float32) * _INV_2_24

    def threshold_u24(self, wire):
        from ..core.sampling import quant_threshold_u24

        return quant_threshold_u24(wire, self.bits)


class PackedDown(QuantizedDown):
    """Sub-byte b-bit words packed into uint32 lanes (b in [1, 16]).

    Quantization/threshold contract is EXACTLY ``QuantizedDown``'s —
    same dither stream, same ``q = floor(p·S + 1/4 + dither/2)``, same
    widened-threshold draw — only the carrier differs: ``floor(32/b)``
    words per uint32 lane (``comm.bitpack.pack_words`` layout).  The
    lanes are the carried state; the fused kernels unpack them
    in-block (``kernels.qz_reconstruct``/``qz_decode``), and
    ``downlink_bits_per_client`` meters the realized ``32·ceil(n/wpl)``
    lane bits including padding.
    """

    packed = True

    def __init__(self, name: str, bits: int):
        super().__init__(name, bits, jnp.uint32)

    @property
    def words_per_lane(self) -> int:
        return words_per_lane(self.bits)

    def downlink_bits_per_client(self, n: int) -> int:
        # realized lane bits: padding (the tail lane AND the wasted top
        # 32 mod b bits of a non-divisor width) is spent, not hidden
        return 32 * packed_word_len(n, self.bits)

    def wire_len(self, n: int) -> int:
        return packed_word_len(n, self.bits)

    def _wire_of_words(self, q):
        return pack_words(q.astype(jnp.uint32), self.bits)

    def wire_words(self, spec, wire):
        return unpack_words(wire, spec.n, self.bits)


_REGISTRY: Dict[str, DownlinkCodec] = {}
_ALIASES: Dict[str, str] = {}


def register_codec(codec: DownlinkCodec,
                   aliases: Tuple[str, ...] = ()) -> DownlinkCodec:
    """Add a downlink codec (and optional aliases) to the registry."""
    _REGISTRY[codec.name] = codec
    for a in aliases:
        _ALIASES[a] = codec.name
    return codec


def codec_names(include_aliases: bool = True) -> List[str]:
    names = sorted(_REGISTRY)
    if include_aliases:
        names += sorted(_ALIASES)
    return names


def get_codec(name: str) -> DownlinkCodec:
    canonical = _ALIASES.get(name, name)
    if canonical not in _REGISTRY:
        raise ValueError(
            f"unknown downlink codec {name!r}; registered: "
            f"{', '.join(codec_names())}"
        )
    return _REGISTRY[canonical]


def packed_codec(bits: int) -> PackedDown:
    """The ``packed{b}`` codec for any width b in [1, 16] — registered
    on first use (``packed4``/``packed2`` are pre-registered)."""
    words_per_lane(bits)  # range check
    name = f"packed{bits}"
    if name not in _REGISTRY:
        register_codec(PackedDown(name, bits))
    return _REGISTRY[name]


def codec_for_dtype(dtype) -> DownlinkCodec:
    """The quantized codec whose wire dtype matches, or ``f32`` for
    floating score leaves — how ``core.zampling.sample_weights`` infers
    the broadcast representation from an encoded state.

    VALIDATED FALLBACK only: every packed codec's wire dtype is uint32,
    so a packed carry is ambiguous by dtype and this raises, listing
    the candidates — route packed states by explicit tag
    (``carried=``, ``meta['downlink']``) instead of sniffing.
    """
    dtype = jnp.dtype(dtype)
    if jnp.issubdtype(dtype, jnp.floating):
        return get_codec("f32")
    matches = [c for c in _REGISTRY.values()
               if c.quantized and jnp.dtype(c.wire_dtype) == dtype]
    if len(matches) > 1:
        raise ValueError(
            f"dtype {dtype} is ambiguous between downlink codecs "
            f"{', '.join(sorted(c.name for c in matches))}; route by "
            f"explicit tag (carried=/downlink= argument, or the "
            f"checkpoint's meta['downlink'])"
        )
    if matches:
        return matches[0]
    raise ValueError(
        f"no downlink codec carries dtype {dtype}; registered: "
        f"{', '.join(codec_names())}"
    )


register_codec(F32Down())
register_codec(QuantizedDown("u16", 16, jnp.uint16))
register_codec(QuantizedDown("u8", 8, jnp.uint8))
register_codec(PackedDown("packed4", 4), aliases=("u4",))
register_codec(PackedDown("packed2", 2), aliases=("u2",))
