"""Bit-packing of binary masks for communication (batched).

The federated protocol uploads ``z ∈ {0,1}^n`` — n *bits* on the wire.
JAX has no 1-bit dtype, so we pack 32 mask bits per ``uint32`` lane;
the packed representation is what crosses the network, giving the
paper's full 32x-over-f32 saving (up to one padded lane per tensor).

All functions accept arbitrary leading batch axes — ``pack_mask`` on a
stacked ``(K, n)`` client slab returns ``(K, ceil(n/32))`` lanes, so
packing composes with ``vmap`` in ``federated_round`` and with
``psum``/``all_gather`` inside ``sharded_client_update``.

``packed_popcount_sum`` is the server-side reduction: given the K
clients' packed lanes it produces the per-coordinate vote counts
``sum_k z^(k)`` without ever materializing a (K, n) float slab — the
uint32 equivalent of a lane-wise popcount accumulated over clients.

On the fused mask lifecycle (``FederatedConfig.mask_path='fused'``)
the lanes are not packed here at all: ``kernels.ops.sample_pack``
draws the upload mask in-kernel and emits lanes in THIS module's
layout (bit j of lane i = coordinate 32i+j, bit-identical to
``pack_mask``), and the packed transports consume them natively
(``Transport.aggregate_*_packed``).  ``pack_mask``/``unpack_mask``
remain the composed oracle and the server-side unpack.
"""

from __future__ import annotations

import jax.numpy as jnp

def _shifts():
    # fresh per call: a module-level cache created under a trace would
    # leak the tracer into later calls
    return jnp.arange(32, dtype=jnp.uint32)


def packed_len(n: int) -> int:
    """uint32 lanes needed for an n-bit mask."""
    return (n + 31) // 32


def pack_mask(z):
    """{0,1} mask ``(..., n)`` (float/bool/int) -> ``(..., ceil(n/32))``
    uint32 lanes; bit j of lane i is coordinate ``32*i + j``."""
    n = z.shape[-1]
    pad = packed_len(n) * 32 - n
    widths = [(0, 0)] * (z.ndim - 1) + [(0, pad)]
    bits = jnp.pad(z.astype(jnp.uint32), widths).reshape(*z.shape[:-1], -1, 32)
    return jnp.sum(bits << _shifts(), axis=-1, dtype=jnp.uint32)


def unpack_mask(packed, n: int, dtype=jnp.float32):
    """uint32 lanes ``(..., ceil(n/32))`` -> ``(..., n)`` mask in
    ``dtype`` (f32 by default; pass uint32 for an integer psum)."""
    bits = (packed[..., :, None] >> _shifts()) & jnp.uint32(1)
    return bits.reshape(*packed.shape[:-1], -1)[..., :n].astype(dtype)


def packed_popcount_sum(packed, n: int):
    """Per-coordinate vote counts from K clients' packed lanes.

    ``packed``: (K, ceil(n/32)) uint32 -> (n,) uint32 with entry j equal
    to ``sum_k z_j^(k)`` — exact for any K < 2^32.
    """
    bits = (packed[:, :, None] >> _shifts()) & jnp.uint32(1)  # (K, L, 32)
    counts = jnp.sum(bits, axis=0, dtype=jnp.uint32)  # (L, 32)
    return counts.reshape(-1)[:n]


def packed_weighted_sum(packed, n: int, weights):
    """Weighted per-coordinate vote counts — the partial-participation
    generalization of ``packed_popcount_sum``.

    ``packed``: (K, ceil(n/32)) uint32; ``weights``: (K,) uint32 —
    participation bits and sample counts enter the sum as exact integer
    multiplies, so the result is exact whenever ``sum(weights) < 2^32``
    (a weight-0 client contributes nothing).  With ``weights`` all ones
    the multiply is the u32 identity: bit-identical to
    ``packed_popcount_sum``.
    """
    bits = (packed[:, :, None] >> _shifts()) & jnp.uint32(1)  # (K, L, 32)
    w = weights.astype(jnp.uint32)[:, None, None]
    counts = jnp.sum(bits * w, axis=0, dtype=jnp.uint32)  # (L, 32)
    return counts.reshape(-1)[:n]


def packed_weighted_fold(acc, packed, n: int, weights):
    """Fold one CHUNK of packed uploads into a running vote-count
    accumulator — the streaming form of ``packed_weighted_sum``.

    ``acc``: (n,) uint32 counts so far; ``packed``: (C, ceil(n/32))
    uint32 lanes of this chunk's C uploads; ``weights``: (C,) uint32.
    uint32 addition is associative, so folding chunk-by-chunk yields
    the IDENTICAL integer counts as one ``packed_weighted_sum`` over
    the full (K, L) slab, for any chunking — the peak operand is
    O(C·L) instead of O(K·L).
    """
    return acc + packed_weighted_sum(packed, n, weights)


def packed_total_popcount(packed):
    """Total set bits over the trailing lane axis (leading batch axes
    kept) -> uint32.  The per-tensor upload checksum of the fault
    layer's server-side validation (``fault.validate``)."""
    bits = (packed[..., :, None] >> _shifts()) & jnp.uint32(1)
    return jnp.sum(bits, axis=(-1, -2), dtype=jnp.uint32)


# ---------------------------------------------------------------------------
# b-bit WORD lanes (downlink): pack b-bit probability words, b in [1,16],
# into uint32 lanes — the sub-byte codecs' wire format (comm.downlink
# ``packed{b}``).  Same uint32-lane carrier as the mask packing above,
# but each lane holds floor(32/b) words instead of 32 bits: word j of
# lane i is coordinate ``i*wpl + j`` at bit offset ``b*j``.  A
# non-divisor width (e.g. b=6, wpl=5) wastes the top ``32 mod b`` bits
# of every lane; ``packed_word_len`` (and the codec's metering) counts
# those padding bits as spent, so the metered bytes are the realized
# wire bytes, not the information content.
# ---------------------------------------------------------------------------

def words_per_lane(bits: int) -> int:
    """b-bit words per uint32 lane: floor(32 / b)."""
    if not 1 <= bits <= 16:
        raise ValueError(f"packed word width must be 1..16 bits, got {bits}")
    return 32 // bits


def packed_word_len(n: int, bits: int) -> int:
    """uint32 lanes needed for n b-bit words: ceil(n / floor(32/b))."""
    wpl = words_per_lane(bits)
    return (n + wpl - 1) // wpl


def _word_shifts(bits: int):
    # fresh per call, like _shifts(): no tracer-leaking module cache
    wpl = words_per_lane(bits)
    return jnp.uint32(bits) * jnp.arange(wpl, dtype=jnp.uint32)


def pack_words(q, bits: int):
    """b-bit words ``(..., n)`` (any uint dtype, values < 2^b) ->
    ``(..., packed_word_len(n, b))`` uint32 lanes; word j of lane i is
    coordinate ``i*wpl + j`` at bit offset ``b*j``."""
    wpl = words_per_lane(bits)
    q = jnp.asarray(q)
    n = q.shape[-1]
    pad = packed_word_len(n, bits) * wpl - n
    widths = [(0, 0)] * (q.ndim - 1) + [(0, pad)]
    words = jnp.pad(q.astype(jnp.uint32), widths).reshape(
        *q.shape[:-1], -1, wpl)
    return jnp.sum(words << _word_shifts(bits), axis=-1, dtype=jnp.uint32)


def unpack_words(lanes, n: int, bits: int):
    """uint32 lanes ``(..., packed_word_len(n, b))`` -> ``(..., n)``
    uint32 b-bit words — the exact inverse of ``pack_words`` (trailing
    lane padding dropped)."""
    mask = jnp.uint32((1 << bits) - 1)
    words = (lanes[..., :, None] >> _word_shifts(bits)) & mask
    return words.reshape(*lanes.shape[:-1], -1)[..., :n]
