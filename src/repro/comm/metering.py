"""Exact wire accounting: bytes on the network per federated round.

Analytic, not sampled — the byte counts are a pure function of the
spec set, the uplink transport, and the downlink codec, and they meter
the PROTOCOL in BOTH directions: what one client uploads to the
aggregator (uint32 lane padding included, unlike the idealized
``n bits`` of the paper's Table 1) AND what the server broadcasts back
(the configured ``comm.downlink`` codec's b bits per coordinate — no
longer a hardcoded ``4 * n_total`` f32 assumption).  One caveat for
``psum_u32``: XLA has no sub-word all-reduce, so in the shard_map
SIMULATION its psum operand is the unpacked uint32 vector — the
metered packed bytes describe the client upload a bandwidth-optimal
reduction would move, not that simulated operand's width.
``allgather_packed`` moves exactly the metered lanes end to end, in
simulation too.  Symmetrically, the quantized downlink codecs carry
their wire words as uint8/uint16 arrays in simulation, so there the
carried state IS the metered wire.

Per round, per client:

  uplink    = sum over reparametrized tensors of the transport's mask
              wire bytes  +  f32 bytes for the dense leaves (norms /
              biases are trained locally and averaged too);
  downlink  = sum over reparametrized tensors of the codec's score
              wire bytes (b bits/coordinate)  +  the same dense leaves.

``round_wire_report`` feeds the round metrics in ``core.federated``;
``wire_table`` / ``downlink_table`` feed the experiment tables and
``benchmarks/run.py``.  The analytic cross-check lives in
``ZamplingSpecs.comm_bits_per_round``: its ``client_up_wire`` /
``server_down_wire`` keys equal 8x this module's metered bytes
(pinned in tests/test_fused.py and tests/test_downlink.py).
"""

from __future__ import annotations

from typing import Dict, List, Optional

from .downlink import DownlinkCodec, codec_names, get_codec
from .protocol import Transport, get_transport, resolve_transport, transport_names

_F32_BYTES = 4


def mask_uplink_bytes(transport: Transport, n: int) -> int:
    """Exact wire bytes for one client's n-coordinate mask upload."""
    return -(-transport.uplink_bits_per_client(n) // 8)


def score_downlink_bytes(codec: DownlinkCodec, n: int) -> int:
    """Exact wire bytes of the server's n-coordinate score broadcast
    to one client under ``codec`` (the downlink mirror of
    ``mask_uplink_bytes``)."""
    return -(-codec.downlink_bits_per_client(n) // 8)


def scheduled_downlink_bits(n: int, bits):
    """REALIZED downlink bits of one n-coordinate tensor broadcast at a
    (possibly TRACED) scheduled width ``bits``: b-bit words packed into
    uint32 lanes, ``32 · ceil(n / floor(32/b))`` — lane padding and the
    wasted top ``32 mod b`` bits of a non-divisor width included, so a
    scheduled round meters what actually crosses the wire, never the
    idealized ``b·n``.  Returns a traced uint32 scalar when ``bits`` is
    traced (the schedule metrics inside the compiled round), a python
    int otherwise."""
    if isinstance(bits, (int,)):
        wpl = 32 // bits
        return 32 * ((n + wpl - 1) // wpl)
    import jax.numpy as jnp

    b = jnp.asarray(bits).astype(jnp.uint32)
    wpl = jnp.uint32(32) // b
    lanes = (jnp.uint32(n) + wpl - jnp.uint32(1)) // wpl
    return jnp.uint32(32) * lanes


def scheduled_wire_metrics(report, zspecs, b_vec, num_clients,
                           dense_bytes=None):
    """Override a round report's CONFIGURED downlink byte counts with
    the REALIZED counts of a scheduled round (``FederatedConfig
    .downlink_schedule``): per-tensor bits from
    ``scheduled_downlink_bits`` at the round's traced per-tensor width
    vector ``b_vec`` (ordered as ``zspecs.specs``), dense leaves still
    f32.  The overridden values are traced f32 scalars; the key set is
    unchanged, so round-metrics consumers (shard_map out_specs,
    ``ROUND_METRIC_KEYS``) never see a schedule-dependent tree."""
    import jax.numpy as jnp

    b_vec = jnp.asarray(b_vec).astype(jnp.uint32)
    bits = jnp.uint32(0)
    for i, spec in enumerate(zspecs.specs.values()):
        bits = bits + scheduled_downlink_bits(spec.n, b_vec[i])
    if dense_bytes is None:
        dense_bytes = _F32_BYTES * zspecs.dense_total
    down = jnp.ceil(bits.astype(jnp.float32) / 8.0) + jnp.float32(
        dense_bytes)
    down_f32 = float(_F32_BYTES * zspecs.n_total + dense_bytes)
    return {
        **report,
        "downlink_bytes_per_client": down,
        "downlink_bytes_round": down * jnp.float32(num_clients),
        "downlink_vs_f32": down / jnp.float32(down_f32),
    }


def delta_wire_bytes(total_words: int, changed_words: int,
                     word_bytes: int) -> int:
    """Exact wire bytes of a sparse word delta (serve.delta).

    The broadcaster picks the cheaper of the two standard encodings of
    "these positions changed, here are their new words":

      bitmap:     ceil(total/8) presence bits + changed · word_bytes
      coord list: 4-byte count  + changed · (4 + word_bytes)

    Both are exact byte counts of a canonical serialization, mirroring
    ``mask_uplink_bytes`` / ``score_downlink_bytes`` — no entropy-coding
    optimism.  A full broadcast is ``total · word_bytes``
    (``score_downlink_bytes`` of the codec); the delta wins whenever
    few words changed, which is the converged-round regime.
    """
    if changed_words < 0 or changed_words > total_words:
        raise ValueError(
            f"changed_words={changed_words} outside [0, {total_words}]"
        )
    bitmap = -(-total_words // 8) + changed_words * word_bytes
    coords = 4 + changed_words * (4 + word_bytes)
    return min(bitmap, coords)


def round_wire_report(zspecs, aggregate: str, num_clients: int,
                      mode: str = "sample",
                      downlink: str = "f32") -> Dict[str, float]:
    """Exact per-round byte counts for one (transport, codec) pair.

    ``zspecs``: anything with ``.specs`` ({path: spec with .n}),
    ``.n_total``, ``.m_total`` and ``.dense_total`` (ZamplingSpecs).
    Values are python floats (exact for any realistic byte count) —
    int32 would overflow past 2 GiB.  Note that a JITTED function
    returning them (round metrics) casts to f32: exact below 16 MiB,
    ≤ 2^-24 relative rounding above; compare against this function's
    output with a tolerance at that scale.
    """
    t = resolve_transport(aggregate, mode)
    codec = get_codec(downlink)
    mask_up = sum(mask_uplink_bytes(t, s.n) for s in zspecs.specs.values())
    dense = _F32_BYTES * zspecs.dense_total
    up_client = mask_up + dense
    down_mask = sum(score_downlink_bytes(codec, s.n)
                    for s in zspecs.specs.values())
    down_client = down_mask + dense
    down_f32 = _F32_BYTES * zspecs.n_total + dense
    return {
        "transport": t.name,
        "downlink": codec.name,
        "uplink_bytes_per_client": float(up_client),
        "uplink_bytes_round": float(up_client * num_clients),
        "downlink_bytes_per_client": float(down_client),
        "downlink_bytes_round": float(down_client * num_clients),
        "downlink_vs_f32": float(down_client) / float(down_f32),
        "naive_uplink_bytes_per_client": float(
            _F32_BYTES * zspecs.m_total + dense
        ),
    }


def upload_slab_bytes(zspecs, aggregate: str, num_clients: int,
                      mode: str = "sample") -> float:
    """Device bytes of the stacked (K, lanes) upload slab the one-shot
    aggregation materializes before reducing — the quantity the
    streaming accumulator (``FederatedConfig.stream_chunk``) bounds.

    Per client this equals the wire bytes of its mask upload (uint32
    lanes on the packed transports, 4·n f32 on ``mean_f32``); the slab
    is K of them resident at once.
    """
    t = resolve_transport(aggregate, mode)
    per = sum(mask_uplink_bytes(t, s.n) for s in zspecs.specs.values())
    return float(per * num_clients)


def streaming_peak_bytes(zspecs, aggregate: str, chunk: int,
                         mode: str = "sample") -> float:
    """Peak upload-side device bytes of the STREAMING round: one
    chunk's lanes plus the (n,) vote-count accumulator per tensor —
    independent of K.  ``upload_slab_bytes(zspecs, agg, K) /
    streaming_peak_bytes(zspecs, agg, chunk)`` is the memory saving a
    K-client streaming round realizes."""
    acc = sum(_F32_BYTES * s.n for s in zspecs.specs.values())
    return upload_slab_bytes(zspecs, aggregate, chunk, mode) + acc


def serve_tile_pool_bytes(zspecs, cache_budget: int,
                          bm: Optional[int] = None) -> int:
    """Allocated bytes of the hot-block tile pool at ``cache_budget``.

    The pool holds ``min(budget // (4·bm), total_tiles)`` rows of
    4·bm bytes, where total_tiles counts the canonical contraction
    blocks of every zampled matmul leaf ('embed' streams through the
    row-gather path and owns no tiles) — the same geometry
    ``serve.cache.HotBlockCache`` allocates, so this is exact, not an
    estimate.
    """
    from ..kernels import ops  # kernels sit above comm

    bm = bm or ops.SERVE_BM
    tiles = 0
    for path, spec in zspecs.specs.items():
        if path == "embed":
            continue
        groups, d_in, d_out = ops.serve_group_dims(spec)
        _, nblk, _ = ops.serve_block_grid(spec, bm, 0, d_in * d_out)
        tiles += groups * nblk
    return min(int(cache_budget) // (4 * bm), tiles) * 4 * bm


def serve_resident_bytes(sstate, cache_budget: int = 0, *,
                         mode: str = "streaming",
                         kv_cache=None) -> Dict[str, float]:
    """Exact resident bytes of one serving node — the full picture
    (words + cache pool + KV), not the words-only figure.

    ``sstate``: a ``serve.state.ServeState`` (duck-typed — needs
    ``zspecs`` and the byte methods).  ``mode`` picks what the node
    holds: 'streaming' the encoded words (+ draw word), 'load' the
    materialized f32 leaves, 'cached' the words PLUS the tile pool at
    ``cache_budget`` (``serve_tile_pool_bytes``).  ``kv_cache``: the
    live lane KV cache pytree, metered at its array bytes.  Dense
    leaves (norms/biases) are resident in every mode.  Cross-check:
    on backends with memory stats the benchmark's device-peak probe
    should dominate ``total`` (the analytic figure excludes
    activations/XLA workspace); on CPU the analytic figure is the
    only meter.
    """
    if mode not in ("load", "streaming", "cached"):
        raise ValueError(f"unknown serve mode {mode!r}")
    if mode == "load":
        zampled = sstate.loaded_zampled_bytes()
        pool = 0
    else:
        zampled = sstate.resident_zampled_bytes()
        pool = (serve_tile_pool_bytes(sstate.zspecs, cache_budget)
                if mode == "cached" else 0)
    kv = 0
    if kv_cache is not None:
        import jax
        import jax.numpy as jnp

        kv = sum(int(jnp.asarray(leaf).nbytes)
                 for leaf in jax.tree_util.tree_leaves(kv_cache))
    dense = sstate.dense_bytes()
    return {
        "mode": mode,
        "zampled_bytes": float(zampled),
        "cache_bytes": float(pool),
        "kv_bytes": float(kv),
        "dense_bytes": float(dense),
        "total_bytes": float(zampled + pool + kv + dense),
    }


def realized_wire_metrics(report: Dict[str, float], uplink_units,
                          cohort_size: int) -> Dict:
    """Scale a round's exact per-client byte counts by the REALIZED
    traffic of a partial-participation round (the fault-tolerant
    drivers in ``core.federated``).

    ``uplink_units``: how many client uploads actually crossed the
    uplink — arrivals (including corrupt uploads, whose bytes are spent
    before validation rejects them) plus one extra copy per duplicate;
    may be a traced scalar, in which case the round totals are traced
    too.  Dropped and straggler clients never hit the wire (a missed
    cutoff means the server stopped listening), so their bytes are NOT
    counted.  ``cohort_size``: every sampled client receives the
    broadcast at round start, downloads included, whatever happens to
    its upload.  Per-client figures stay the static protocol constants.
    """
    return {
        "uplink_bytes_per_client": report["uplink_bytes_per_client"],
        "uplink_bytes_round":
            report["uplink_bytes_per_client"] * uplink_units,
        "downlink_bytes_per_client": report["downlink_bytes_per_client"],
        "downlink_bytes_round":
            report["downlink_bytes_per_client"] * float(cohort_size),
        "naive_uplink_bytes_per_client":
            report["naive_uplink_bytes_per_client"],
    }


def wire_table(zspecs, num_clients: int, downlink: str = "f32") -> List[Dict]:
    """One row per registered uplink strategy (at the given downlink
    codec) — the measured-bytes table for ``experiments.paper`` and the
    wire benchmark."""
    baseline = round_wire_report(zspecs, "mean_f32", num_clients)
    rows = []
    for name in transport_names(include_aliases=False):
        rep = round_wire_report(zspecs, name, num_clients,
                                downlink=downlink)
        rows.append({
            "bench": "wire_format",
            "strategy": name,
            "K": num_clients,
            "n_total": zspecs.n_total,
            "m_total": zspecs.m_total,
            **rep,
            "uplink_vs_f32": rep["uplink_bytes_per_client"]
            / baseline["uplink_bytes_per_client"],
            "uplink_vs_naive": rep["uplink_bytes_per_client"]
            / rep["naive_uplink_bytes_per_client"],
        })
    return rows


def downlink_table(zspecs, num_clients: int,
                   aggregate: str = "psum_u32") -> List[Dict]:
    """One row per registered downlink codec (at the given uplink
    transport) — the downlink mirror of ``wire_table``."""
    rows = []
    for name in codec_names(include_aliases=False):
        rep = round_wire_report(zspecs, aggregate, num_clients,
                                downlink=name)
        rows.append({
            "bench": "downlink_format",
            "codec": name,
            "K": num_clients,
            "n_total": zspecs.n_total,
            "m_total": zspecs.m_total,
            **rep,
        })
    return rows


__all__ = [
    "mask_uplink_bytes", "score_downlink_bytes", "delta_wire_bytes",
    "scheduled_downlink_bits", "scheduled_wire_metrics",
    "round_wire_report",
    "realized_wire_metrics", "upload_slab_bytes", "streaming_peak_bytes",
    "serve_resident_bytes", "serve_tile_pool_bytes",
    "wire_table", "downlink_table",
    "get_transport", "get_codec",
]
