"""Exact wire accounting: bytes on the network per federated round.

Analytic, not sampled — the byte counts are a pure function of the
spec set and the transport, and they meter the PROTOCOL: what one
client uploads to the aggregator (uint32 lane padding included, unlike
the idealized ``n bits`` of the paper's Table 1).  One caveat for
``psum_u32``: XLA has no sub-word all-reduce, so in the shard_map
SIMULATION its psum operand is the unpacked uint32 vector — the
metered packed bytes describe the client upload a bandwidth-optimal
reduction would move, not that simulated operand's width.
``allgather_packed`` moves exactly the metered lanes end to end, in
simulation too.

Per round, per client:

  uplink    = sum over reparametrized tensors of the transport's mask
              wire bytes  +  f32 bytes for the dense leaves (norms /
              biases are trained locally and averaged too);
  downlink  = f32 score vector (the server's p(t) broadcast)  +  the
              same dense leaves.

``round_wire_report`` feeds the round metrics in ``core.federated``;
``wire_table`` feeds the experiment tables and ``benchmarks/run.py``.
"""

from __future__ import annotations

from typing import Dict, List

from .protocol import Transport, get_transport, resolve_transport, transport_names

_F32_BYTES = 4


def mask_uplink_bytes(transport: Transport, n: int) -> int:
    """Exact wire bytes for one client's n-coordinate mask upload."""
    return -(-transport.uplink_bits_per_client(n) // 8)


def round_wire_report(zspecs, aggregate: str, num_clients: int,
                      mode: str = "sample") -> Dict[str, float]:
    """Exact per-round byte counts for one strategy.

    ``zspecs``: anything with ``.specs`` ({path: spec with .n}),
    ``.n_total``, ``.m_total`` and ``.dense_total`` (ZamplingSpecs).
    Values are python floats (exact for any realistic byte count) —
    int32 would overflow past 2 GiB.  Note that a JITTED function
    returning them (round metrics) casts to f32: exact below 16 MiB,
    ≤ 2^-24 relative rounding above; compare against this function's
    output with a tolerance at that scale.
    """
    t = resolve_transport(aggregate, mode)
    mask_up = sum(mask_uplink_bytes(t, s.n) for s in zspecs.specs.values())
    dense = _F32_BYTES * zspecs.dense_total
    up_client = mask_up + dense
    down_client = _F32_BYTES * zspecs.n_total + dense
    return {
        "transport": t.name,
        "uplink_bytes_per_client": float(up_client),
        "uplink_bytes_round": float(up_client * num_clients),
        "downlink_bytes_per_client": float(down_client),
        "naive_uplink_bytes_per_client": float(
            _F32_BYTES * zspecs.m_total + dense
        ),
    }


def wire_table(zspecs, num_clients: int) -> List[Dict]:
    """One row per registered strategy — the measured-bytes table for
    ``experiments.paper`` and the wire benchmark."""
    baseline = round_wire_report(zspecs, "mean_f32", num_clients)
    rows = []
    for name in transport_names(include_aliases=False):
        rep = round_wire_report(zspecs, name, num_clients)
        rows.append({
            "bench": "wire_format",
            "strategy": name,
            "K": num_clients,
            "n_total": zspecs.n_total,
            "m_total": zspecs.m_total,
            **rep,
            "uplink_vs_f32": rep["uplink_bytes_per_client"]
            / baseline["uplink_bytes_per_client"],
            "uplink_vs_naive": rep["uplink_bytes_per_client"]
            / rep["naive_uplink_bytes_per_client"],
        })
    return rows


__all__ = [
    "mask_uplink_bytes", "round_wire_report", "wire_table", "get_transport",
]
