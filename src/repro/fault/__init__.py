"""Fault-tolerant partial participation for the federated round.

Three pieces, all keyed by the counter-based hash RNG so every
scenario is a pure function of integers (deterministic, replayable,
bit-identical across the vmap and shard_map drivers):

 - ``population``  ``ClientPopulation`` — N virtual clients with
   sample-count weights and the K-of-N cohort draw at COHORT_CTR;
 - ``plan``        ``FaultPlan`` — drop / straggler / corrupt /
   duplicate faults drawn per (round, client) at FAULT_CTR, with
   guaranteed-detectable lane corruption injected at CORRUPT_CTR;
 - ``validate``    server-side upload validation — per-tensor popcount
   checksums that exclude damaged uploads from the weighted aggregate.

The aggregation itself (participation bits and weights as exact uint32
multiplies inside the popcount sum, realized-weight normalization,
skip-round below ``FederatedConfig.min_clients``) lives in
``core.federated`` + ``comm.protocol``.
"""

from .plan import (
    CORRUPT,
    CORRUPT_CTR,
    DROP,
    DUPLICATE,
    FAULT_CTR,
    FAULT_NAMES,
    OK,
    STRAGGLER,
    FaultPlan,
    corrupt_uploads,
    draw_faults,
)
from .population import COHORT_CTR, ClientPopulation
from .validate import upload_counts, validate_uploads

__all__ = [
    "ClientPopulation", "COHORT_CTR",
    "FaultPlan", "FAULT_CTR", "CORRUPT_CTR", "FAULT_NAMES",
    "OK", "DROP", "STRAGGLER", "CORRUPT", "DUPLICATE",
    "draw_faults", "corrupt_uploads",
    "upload_counts", "validate_uploads",
]
