"""Client population + cohort sampling (ROADMAP "million-client round
engine", the sampling face).

Production FL trains K ≈ tens of clients per round drawn from a
population of N ≫ K virtual clients (Konečný et al., PAPERS.md).  A
``ClientPopulation`` is the static description of that population —
its size and the per-client SAMPLE COUNTS (dataset sizes, e.g. the
label-histogram row sums of ``data.federated_split
.dirichlet_client_split``) that become the aggregation weights of the
partial-participation round (``core.federated.federated_round``'s
``weights``: exact uint32 multiplies inside the popcount psum).

Cohort draw: every client gets a priority word from the counter-based
hash RNG at the cohort counter space,

    priority_i = hash_u32(seed, COHORT_CTR, round_index, i),

and the round's cohort is the K smallest priorities (a deterministic
uniform K-of-N draw; ties are broken by index by the stable argsort).
Three properties the round engine needs fall out of keying on
``(seed, round_index, client_id)`` alone:

 - **deterministic + replayable**: the HOST data stager (which must
   know the cohort before it can build the round's batch slab — see
   ``data.federated_split.cohort_batch_stream``) and the traced round
   body regenerate the identical cohort from the same integers, with
   no PRNG key threading;
 - **scan-compatible**: ``round_index`` may be a traced scan counter —
   the draw is a pure jnp function of it;
 - **path-independent**: the cohort does not depend on the training
   key or on vmap-vs-shard_map execution, so fault/participation
   scenarios replay bit-identically across both drivers.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Tuple

import jax.numpy as jnp
import numpy as np

from ..core.hashrng import hash_u32

# Counter-space role of the cohort stream: hash words are
# (seed, COHORT_CTR, round_index, client_id) — disjoint from the mask
# (MASK_CTR), dither (QUANT_DITHER_CTR) and fault (FAULT_CTR /
# CORRUPT_CTR) spaces, so sampling a cohort can never alias a draw.
COHORT_CTR = 0x0020_0000


@dataclass(frozen=True)
class ClientPopulation:
    """N virtual clients with per-client sample counts.

    ``sample_counts``: optional (N,) integer array of per-client
    dataset sizes — the weights the weighted aggregation multiplies
    into the popcount sum (uint32-exact).  ``None`` means the uniform
    population (every client weight 1), whose weighted round is
    bit-identical to the unweighted protocol.
    """

    num_clients: int
    sample_counts: Optional[tuple] = None  # (N,) ints; None = all ones
    seed: int = 0

    def __post_init__(self):
        if self.num_clients < 1:
            raise ValueError(
                f"population needs >= 1 client, got {self.num_clients}"
            )
        if self.sample_counts is not None:
            counts = np.asarray(self.sample_counts)
            if counts.shape != (self.num_clients,):
                raise ValueError(
                    f"sample_counts shape {counts.shape} != "
                    f"({self.num_clients},)"
                )
            if (counts < 1).any():
                raise ValueError(
                    "per-client sample counts must be >= 1 (a weight-0 "
                    "client can never contribute; drop it from the "
                    "population instead)"
                )
            # frozen dataclass: normalize to a hashable static tuple
            object.__setattr__(
                self, "sample_counts", tuple(int(c) for c in counts)
            )

    def counts(self) -> jnp.ndarray:
        """(N,) uint32 per-client sample counts (ones if unset)."""
        if self.sample_counts is None:
            return jnp.ones((self.num_clients,), jnp.uint32)
        return jnp.asarray(self.sample_counts, jnp.uint32)

    def priorities(self, round_index) -> jnp.ndarray:
        """(N,) uint32 cohort priority words for one round."""
        rid = jnp.asarray(round_index).astype(jnp.uint32)
        ids = jnp.arange(self.num_clients, dtype=jnp.uint32)
        return hash_u32(self.seed, COHORT_CTR, rid, ids)

    def sample_cohort(self, round_index, cohort_size: int
                      ) -> Tuple[jnp.ndarray, jnp.ndarray]:
        """The round's cohort: (client_ids, weights), both
        (cohort_size,) uint32, ids sorted ascending.

        Pure in ``(seed, round_index)`` — call it host-side to stage
        data and inside jit to derive draw words; both see the same
        clients.  ``cohort_size == num_clients`` degenerates to full
        participation (ids = arange(N)).
        """
        if not 1 <= cohort_size <= self.num_clients:
            raise ValueError(
                f"cohort_size {cohort_size} not in [1, {self.num_clients}]"
            )
        order = jnp.argsort(self.priorities(round_index))
        ids = jnp.sort(order[:cohort_size]).astype(jnp.uint32)
        return ids, self.counts()[ids]

    def cohort_np(self, round_index: int, cohort_size: int
                  ) -> Tuple[np.ndarray, np.ndarray]:
        """Host-side (numpy) view of ``sample_cohort`` for data
        staging loops — the same bits, materialized."""
        ids, weights = self.sample_cohort(int(round_index), cohort_size)
        return np.asarray(ids), np.asarray(weights)
