"""Server-side upload validation: popcount checksums on the wire.

The uplink is binary — a client's upload of tensor ``path`` is either
uint32 mask lanes (packed transports) or an f32 {0,1} mask — so its
TOTAL popcount is an exact small integer in every representation
(f32 holds any count below 2^24 exactly; continuous-mode probability
uploads use the same f32 sum, computed identically on both ends).
The client declares that count in a tiny per-tensor header (4 bytes —
unmetered protocol overhead, < 1e-4 of any upload) and the server
recomputes it from the received payload.  A corrupted upload fails the
compare; a count above the tensor's coordinate total ``spec.n`` fails
the sanity bound even if the header itself was damaged.  Validation
failures EXCLUDE the upload from the weighted aggregate (its
participation bit drops to 0) and are counted in the round metrics
(``num_corrupt``).

Both drivers run the same checks: the vmap path on (K, ...) stacked
uploads (returning a (K,) verdict), the shard_map path on one shard's
upload (returning a scalar verdict) — the checksum math is shape-
polymorphic over leading batch axes.
"""

from __future__ import annotations

import jax.numpy as jnp

from ..comm.bitpack import packed_total_popcount


def upload_counts(z_all, zspecs, packed: bool):
    """Per-tensor upload checksums, computed on the CLIENT side before
    the wire: {path: count} with the uploads' leading batch axes.
    uint32 total popcounts for packed lanes, exact f32 sums otherwise.
    """
    out = {}
    for path in zspecs.specs:
        z = z_all[path]
        if packed:
            out[path] = packed_total_popcount(z)
        else:
            out[path] = jnp.sum(z, axis=-1)
    return out


def validate_uploads(z_all, declared, zspecs, packed: bool):
    """Recompute every tensor's checksum from the RECEIVED payload and
    compare against the declared counts; bound-check against ``spec.n``.

    Returns a boolean verdict per client (batch-shaped like the
    uploads' leading axes; scalar on the per-shard path): True iff
    every tensor of that client's upload is intact and in-bounds.
    """
    received = upload_counts(z_all, zspecs, packed)
    valid = None
    for path, spec in zspecs.specs.items():
        c = received[path]
        ok = (c == declared[path]) & (c <= spec.n)
        valid = ok if valid is None else (valid & ok)
    return valid
