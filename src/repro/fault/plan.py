"""Deterministic fault injection on the integer wire.

A ``FaultPlan`` is a static description of an unreliable deployment:
per (round, client), one of four faults may strike the client's
participation in the round —

 - **drop**       the client vanishes before uploading (no bytes
   reach the server);
 - **straggler**  the client finishes but misses the server's round
   cutoff — the upload arrives too late and is excluded (the classic
   "don't wait for stragglers" policy: the cost is a smaller realized
   cohort, never a stalled round);
 - **corrupt**    the upload's mask lanes are corrupted in flight;
   the server's upload validation (``fault.validate``) detects the
   damaged payload by its popcount mismatch and excludes it;
 - **duplicate**  the upload arrives twice (a retry bug); the server
   deduplicates — the client is aggregated ONCE at its normal weight,
   the extra copy only costs (and is metered as) wasted uplink bytes.

Fault draws come from the counter-based hash RNG at the FAULT counter
space, keyed ``(plan.seed, FAULT_CTR, round_index, client_id)`` — NOT
by the training key and NOT by vmap slot, so a fault scenario is a
pure function of (seed, round, client): bit-reproducible across the
vmap and shard_map drivers, across reruns, and under ``lax.scan``
(``round_index`` may be traced).  One uniform word decides the fault
via exact integer threshold compares (cumulative rates scaled to
2^32), so the drawn scenario is identical everywhere the same
integers are hashed.

Corruption injection draws its garbage from a second, disjoint
counter space (``CORRUPT_CTR``) and then guarantees detectability: if
XOR-ing the garbage happened to preserve the upload's total popcount
(the validation checksum), the injector flips one more bit.  Real
line noise would evade the popcount check with some probability;
deterministic injection exists to produce REPLAYABLE detected-fault
scenarios, so it guarantees the mismatch by construction.
"""

from __future__ import annotations

from dataclasses import dataclass

import jax.numpy as jnp
import numpy as np

from ..comm.bitpack import packed_total_popcount
from ..core.hashrng import hash_u32

# Counter-space roles (disjoint from core.sampling's MASK_CTR /
# QUANT_DITHER_CTR and fault.population's COHORT_CTR): fault draws are
# (seed, FAULT_CTR, round, client); corruption garbage words are
# (seed, tensor_id, CORRUPT_CTR, round, client, lane/coord).
FAULT_CTR = 0x0028_0000
CORRUPT_CTR = 0x0030_0000

# Fault codes (the value of one (round, client) draw).
OK, DROP, STRAGGLER, CORRUPT, DUPLICATE = 0, 1, 2, 3, 4

FAULT_NAMES = ("ok", "drop", "straggler", "corrupt", "duplicate")


@dataclass(frozen=True)
class FaultPlan:
    """Static fault-scenario description: independent per-(round,
    client) rates, one fault at most per draw (rates must sum <= 1).
    ``FaultPlan()`` (all zero) exercises the full participation
    machinery with no faults — the zero-fault path the benchmarks
    hold bit-identical to (and within 5% of) the plain protocol.
    """

    dropout: float = 0.0
    straggler: float = 0.0
    corrupt: float = 0.0
    duplicate: float = 0.0
    seed: int = 0

    def __post_init__(self):
        rates = (self.dropout, self.straggler, self.corrupt,
                 self.duplicate)
        if any(r < 0 for r in rates):
            raise ValueError(f"fault rates must be >= 0, got {rates}")
        if sum(rates) > 1.0:
            raise ValueError(
                f"fault rates sum to {sum(rates)} > 1 (one fault at "
                f"most per (round, client) draw)"
            )

    def thresholds(self):
        """Cumulative uint32 compare thresholds (static, exact)."""
        edges = np.cumsum([self.dropout, self.straggler, self.corrupt,
                           self.duplicate])
        return [np.uint32(min(int(round(float(e) * 4294967296.0)),
                              0xFFFFFFFF))
                for e in edges]


def draw_faults(plan: FaultPlan, round_index, client_ids):
    """Fault codes for (round, clients): uint32 in {OK..DUPLICATE}.

    ``client_ids`` may be a (K,) array (vmap driver) or a scalar (one
    shard of the shard_map driver) — the same (round, client) pair
    hashes to the same code on both.
    """
    rid = jnp.asarray(round_index).astype(jnp.uint32)
    ids = jnp.asarray(client_ids).astype(jnp.uint32)
    u = hash_u32(plan.seed, FAULT_CTR, rid, ids)
    t_drop, t_strag, t_corr, t_dup = plan.thresholds()
    code = jnp.where(
        u < t_drop, DROP,
        jnp.where(u < t_strag, STRAGGLER,
                  jnp.where(u < t_corr, CORRUPT,
                            jnp.where(u < t_dup, DUPLICATE, OK))))
    return code.astype(jnp.uint32)


def _garbage_u32(plan, spec, round_index, client_ids, length: int):
    """(..., length) garbage words at the corruption counter space."""
    rid = jnp.asarray(round_index).astype(jnp.uint32)
    ids = jnp.asarray(client_ids).astype(jnp.uint32)
    coords = jnp.arange(length, dtype=jnp.uint32)
    return hash_u32(plan.seed, spec.tensor_id, CORRUPT_CTR, rid,
                    ids[..., None], coords)


def corrupt_uploads(plan: FaultPlan, z_all, declared, corrupt_mask,
                    round_index, client_ids, zspecs, packed: bool):
    """Apply lane corruption to the uploads of flagged clients.

    ``z_all``: {path: upload} with an optional leading client axis —
    uint32 lanes when ``packed``, f32 masks/probabilities otherwise.
    ``declared``: the per-tensor upload checksums computed BEFORE the
    wire (``fault.validate.upload_counts``) — the header is assumed to
    travel intact; only the payload is damaged.  ``corrupt_mask``:
    boolean, client-shaped.  Returns the corrupted pytree; the
    popcount/sum of every corrupted tensor is guaranteed != declared,
    so ``validate_uploads`` detects every injected fault.
    """
    out = {}
    for path, spec in zspecs.specs.items():
        z = z_all[path]
        g = _garbage_u32(plan, spec, round_index, client_ids,
                         z.shape[-1])
        if packed:
            bad = (z ^ g).astype(jnp.uint32)
            clash = packed_total_popcount(bad) == declared[path]
            bad = bad.at[..., 0].set(
                jnp.where(clash, bad[..., 0] ^ jnp.uint32(1),
                          bad[..., 0])
            )
        else:
            # replace the payload with garbage bits; same guarantee on
            # the f32 sum checksum (exact: binary values, n < 2^24)
            bad = (g >> np.uint32(31)).astype(z.dtype)
            clash = jnp.sum(bad, axis=-1) == declared[path]
            bad = bad.at[..., 0].set(
                jnp.where(clash, 1.0 - bad[..., 0], bad[..., 0])
            )
        mask = corrupt_mask[..., None] if z.ndim > 1 else corrupt_mask
        out[path] = jnp.where(mask, bad, z)
    return out
