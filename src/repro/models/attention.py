"""Grouped-query attention with the variants the assigned archs need.

 - GQA (n_kv < n_heads), MHA (n_kv == n_heads)
 - optional QKV bias (Qwen1.5 / Qwen2), optional qk-norm (Qwen3)
 - optional sliding window (Mixtral; the long_500k dense variant) with a
   ring-buffer KV cache of size min(seq, window) for decode
 - self-attention with KV cache for autoregressive decode, and
   cross-attention (Seamless enc-dec) with a precomputed encoder cache
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from .common import rms_norm, rope

NEG_INF = -1e30


@dataclass(frozen=True)
class AttnDims:
    n_heads: int
    n_kv: int
    head_dim: int
    qkv_bias: bool = False
    qk_norm: bool = False
    window: Optional[int] = None
    rope_theta: float = 10_000.0
    causal: bool = True


class KVCache(NamedTuple):
    k: jnp.ndarray  # (B, C, n_kv, hd)  C = min(seq, window or seq)
    v: jnp.ndarray
    pos: jnp.ndarray  # () int32 — next write position (absolute)


def init_attn_params(key, d_model: int, dims: AttnDims, dtype,
                     stack: int = 0):
    from .common import dense_init

    ks = jax.random.split(key, 4)
    h, kv, hd = dims.n_heads, dims.n_kv, dims.head_dim
    p = {
        "wq": dense_init(ks[0], d_model, h * hd, dtype, stack=stack),
        "wk": dense_init(ks[1], d_model, kv * hd, dtype, stack=stack),
        "wv": dense_init(ks[2], d_model, kv * hd, dtype, stack=stack),
        "wo": dense_init(ks[3], h * hd, d_model, dtype, stack=stack),
    }
    if dims.qkv_bias:
        zeros = lambda n: jnp.zeros((stack, n) if stack else (n,), dtype)
        p["bq"], p["bk"], p["bv"] = zeros(h * hd), zeros(kv * hd), zeros(kv * hd)
    if dims.qk_norm:
        ones = lambda: jnp.ones((stack, hd) if stack else (hd,), dtype)
        p["q_norm"], p["k_norm"] = ones(), ones()
    return p


def finish_qkv(params, q, k, v, dims: AttnDims, positions):
    """Bias / head-reshape / qk-norm / rope tail of the QKV projection.

    Takes the three raw (B, S, K) projections; split out so the
    serving engine (serve.decode) can run the projections through
    streamed linears and still share this exact head plumbing with the
    dense path.
    """
    B, S = q.shape[:2]
    h, kv, hd = dims.n_heads, dims.n_kv, dims.head_dim
    if dims.qkv_bias:
        q, k, v = q + params["bq"], k + params["bk"], v + params["bv"]
    q = q.reshape(B, S, h, hd)
    k = k.reshape(B, S, kv, hd)
    v = v.reshape(B, S, kv, hd)
    if dims.qk_norm:
        q = rms_norm(q, params["q_norm"])
        k = rms_norm(k, params["k_norm"])
    if positions is not None:
        q = rope(q, positions, dims.rope_theta)
        k = rope(k, positions, dims.rope_theta)
    return q, k, v


def _qkv(params, x, dims: AttnDims, positions):
    q = jnp.einsum("bsd,dk->bsk", x, params["wq"])
    k = jnp.einsum("bsd,dk->bsk", x, params["wk"])
    v = jnp.einsum("bsd,dk->bsk", x, params["wv"])
    return finish_qkv(params, q, k, v, dims, positions)


def _sdpa(q, k, v, mask, n_rep: int):
    """q (B,Sq,H,hd); k,v (B,Sk,KV,hd); mask (B,1,Sq,Sk) or None."""
    B, Sq, H, hd = q.shape
    kv = k.shape[2]
    qg = q.reshape(B, Sq, kv, n_rep, hd)
    logits = jnp.einsum("bqgrh,bkgh->bgrqk", qg, k).astype(jnp.float32)
    logits = logits / (hd**0.5)
    if mask is not None:
        logits = logits + mask[:, :, None]  # broadcast over rep dim
    probs = jax.nn.softmax(logits, axis=-1).astype(v.dtype)
    out = jnp.einsum("bgrqk,bkgh->bqgrh", probs, v)
    return out.reshape(B, Sq, H, hd)


FLASH_THRESHOLD = 4096  # use blockwise attention at/above this seq len


def self_attention(params, x, dims: AttnDims, positions,
                   segment_ids=None):
    """Full-sequence (train / prefill) self-attention.

    Sequences >= FLASH_THRESHOLD take the blockwise online-softmax path
    (memory-bounded); it assumes positions == arange (true for all our
    train/prefill entry points) and no segment packing.
    """
    B, S, _ = x.shape
    q, k, v = _qkv(params, x, dims, positions)
    if S >= FLASH_THRESHOLD and segment_ids is None:
        from .flash import blockwise_attention

        out = blockwise_attention(
            q, k, v, causal=dims.causal, window=dims.window
        )
        return jnp.einsum(
            "bqk,kd->bqd", out.reshape(B, S, -1),
            params["wo"].reshape(-1, x.shape[-1]),
        )
    idx = positions if positions is not None else (
        jnp.broadcast_to(jnp.arange(S), (B, S))
    )
    qi = idx[:, None, :, None]
    ki = idx[:, None, None, :]
    mask = jnp.zeros((B, 1, S, S), jnp.float32)
    if dims.causal:
        mask = jnp.where(ki > qi, NEG_INF, mask)
    if dims.window is not None:
        mask = jnp.where(ki <= qi - dims.window, NEG_INF, mask)
    if segment_ids is not None:
        same = segment_ids[:, None, :, None] == segment_ids[:, None, None, :]
        mask = jnp.where(~same, NEG_INF, mask)
    out = _sdpa(q, k, v, mask, dims.n_heads // dims.n_kv)
    return jnp.einsum(
        "bqk,kd->bqd", out.reshape(B, S, -1), params["wo"].reshape(-1, x.shape[-1])
    )


def init_cache(batch: int, seq_len: int, dims: AttnDims, dtype) -> KVCache:
    c = min(seq_len, dims.window) if dims.window else seq_len
    shape = (batch, c, dims.n_kv, dims.head_dim)
    return KVCache(
        k=jnp.zeros(shape, dtype), v=jnp.zeros(shape, dtype),
        pos=jnp.zeros((), jnp.int32),
    )


def decode_attend(q, k, v, cache: KVCache, dims: AttnDims):
    """Post-QKV single-token attention: cache write + masked SDPA.

    q/k/v (B, 1, heads, hd) already rope'd.  Returns (out (B, 1, H·hd)
    pre-``wo``, new KVCache) — split from ``decode_self_attention`` so
    the serving engine can stream the projections and share this exact
    cache/mask/softmax plumbing.
    """
    B = q.shape[0]
    C = cache.k.shape[1]
    pos = cache.pos  # absolute position of the new token
    slot = pos % C if dims.window is not None else jnp.minimum(pos, C - 1)
    # one-hot write (not dynamic_update_slice): elementwise over the
    # cache, so GSPMD keeps a seq-sharded cache local instead of
    # rematerializing it around a traced-index DUS
    oh = (jnp.arange(C) == slot).astype(cache.k.dtype)[None, :, None, None]
    new_k = cache.k * (1 - oh) + oh * k
    new_v = cache.v * (1 - oh) + oh * v
    # absolute position held by each cache slot (ring-buffer aware)
    slots = jnp.arange(C)
    if dims.window is not None:
        cycle = (pos // C) * C
        abs_pos = jnp.where(slots <= slot, cycle + slots, cycle - C + slots)
    else:
        abs_pos = slots
    valid = (abs_pos <= pos) & (abs_pos >= 0)
    if dims.window is not None:
        valid = valid & (abs_pos > pos - dims.window)
    mask = jnp.where(valid, 0.0, NEG_INF).astype(jnp.float32)
    mask = jnp.broadcast_to(mask[None, None, None, :], (B, 1, 1, C))
    out = _sdpa(q, new_k, new_v, mask, dims.n_heads // dims.n_kv)
    return out.reshape(B, 1, -1), KVCache(new_k, new_v, pos + 1)


def decode_attend_lanes(q, k, v, cache: KVCache, dims: AttnDims, live):
    """Per-lane decode attention for the continuous-batching engine.

    Same cache write / ring-buffer mask / SDPA plumbing as
    ``decode_attend`` but with ``cache.pos`` carrying a PER-LANE (B,)
    position and ``live`` a (B,) bool admission mask: dead lanes write
    nothing and hold position (their outputs are ignored by the
    scheduler), live lanes behave exactly as lane 0 of the scalar path
    — elementwise ops are lane-independent and the SDPA einsums batch
    over lanes without cross-lane reduction, so a lane's bits equal the
    single-request (B=1) decode at the same position and KV capacity
    (pinned in tests/test_serve_batch.py).  Stale KV from a lane's
    previous occupant sits beyond the validity mask (abs_pos > pos) and
    contributes exact zeros through the softmax, so lane recycling
    needs no cache zeroing and never recompiles.
    """
    B = q.shape[0]
    C = cache.k.shape[1]
    pos = cache.pos  # (B,) absolute position of each lane's new token
    live = jnp.asarray(live, bool)
    slot = pos % C if dims.window is not None else jnp.minimum(pos, C - 1)
    oh = ((jnp.arange(C)[None, :] == slot[:, None]) & live[:, None])
    ohf = oh.astype(cache.k.dtype)[:, :, None, None]
    new_k = cache.k * (1 - ohf) + ohf * k
    new_v = cache.v * (1 - ohf) + ohf * v
    slots = jnp.arange(C)[None, :]
    if dims.window is not None:
        cycle = ((pos // C) * C)[:, None]
        abs_pos = jnp.where(slots <= slot[:, None], cycle + slots,
                            cycle - C + slots)
    else:
        abs_pos = jnp.broadcast_to(slots, (B, C))
    valid = (abs_pos <= pos[:, None]) & (abs_pos >= 0)
    if dims.window is not None:
        valid = valid & (abs_pos > pos[:, None] - dims.window)
    mask = jnp.where(valid, 0.0, NEG_INF).astype(jnp.float32)
    out = _sdpa(q, new_k, new_v, mask[:, None, None, :],
                dims.n_heads // dims.n_kv)
    new_pos = jnp.where(live, pos + 1, pos)
    return out.reshape(B, 1, -1), KVCache(new_k, new_v, new_pos)


def decode_self_attention(params, x, cache: KVCache, dims: AttnDims):
    """One-token decode: x (B, 1, d). Ring-buffer write under SWA."""
    B = x.shape[0]
    positions = jnp.broadcast_to(cache.pos[None, None], (B, 1))
    q, k, v = _qkv(params, x, dims, positions)
    out, new_cache = decode_attend(q, k, v, cache, dims)
    y = jnp.einsum("bqk,kd->bqd", out, params["wo"].reshape(-1, x.shape[-1]))
    return y, new_cache


def cross_attention(params, x, enc_k, enc_v, dims: AttnDims,
                    enc_mask=None):
    """Decoder->encoder attention; enc_k/v precomputed (B, Se, KV, hd)."""
    B, S, _ = x.shape
    q = jnp.einsum("bsd,dk->bsk", x, params["wq"]).reshape(
        B, S, dims.n_heads, dims.head_dim
    )
    if (max(S, enc_k.shape[1]) >= FLASH_THRESHOLD and enc_mask is None
            and S % 1024 == 0 and enc_k.shape[1] % 1024 == 0):
        from .flash import blockwise_attention

        out = blockwise_attention(q, enc_k, enc_v, causal=False)
        return jnp.einsum(
            "bqk,kd->bqd", out.reshape(B, S, -1),
            params["wo"].reshape(-1, x.shape[-1]),
        )
    mask = None
    if enc_mask is not None:
        mask = jnp.where(enc_mask[:, None, None, :], 0.0, NEG_INF).astype(
            jnp.float32
        )
    out = _sdpa(q, enc_k, enc_v, mask, dims.n_heads // dims.n_kv)
    return jnp.einsum(
        "bqk,kd->bqd", out.reshape(B, S, -1), params["wo"].reshape(-1, x.shape[-1])
    )


def encode_kv(params, enc_out, dims: AttnDims):
    B, Se, _ = enc_out.shape
    k = jnp.einsum("bsd,dk->bsk", enc_out, params["wk"]).reshape(
        B, Se, dims.n_kv, dims.head_dim
    )
    v = jnp.einsum("bsd,dk->bsk", enc_out, params["wv"]).reshape(
        B, Se, dims.n_kv, dims.head_dim
    )
    return k, v
