"""Model assembly: ArchConfig -> functional Model (init/forward/decode).

All stacks scan over layers (stacked (L, ...) params) so the HLO stays
one-block-sized regardless of depth — essential for the 40-combo
dry-run compile budget and for remat policies.

Families:
  dense   — GQA + SwiGLU decoder (Yi, Qwen1.5/2/3)
  moe     — GQA + MoE decoder (OLMoE, Mixtral w/ SWA)
  ssm     — Mamba2 SSD stack (attention-free)
  hybrid  — Mamba2 backbone + one SHARED attention block every
            ``attn_every`` layers (Zamba2)
  vlm     — dense decoder consuming stubbed patch/text embeddings (Pixtral)
  encdec  — encoder + cross-attending decoder (Seamless; stubbed
            audio-frame embeddings feed the encoder)
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from . import attention as attn
from .attention import AttnDims, KVCache
from .common import cross_entropy, dense_init, embed_init, grouped_scan, rms_norm, swiglu
from .moe import init_moe_params, moe_block
from .ssm import (
    SSMCache,
    init_ssm_cache,
    init_ssm_params,
    ssm_block,
    ssm_decode_step,
    ssm_dims,
)


@dataclass(frozen=True)
class Model:
    cfg: ArchConfig
    init_params: Callable[[Any], Any]
    forward: Callable[..., Any]  # (params, batch) -> (logits, aux)
    init_cache: Callable[..., Any]  # (params, batch_size, seq_len) -> cache
    prefill: Optional[Callable[..., Any]]  # (params, batch) -> (logits, cache)
    decode_step: Optional[Callable[..., Any]]  # (params, cache, tok) -> (logits, cache)


def _dtype(cfg: ArchConfig):
    return jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32


def _attn_dims(cfg: ArchConfig, window_override=None) -> AttnDims:
    return AttnDims(
        n_heads=cfg.n_heads,
        n_kv=cfg.n_kv,
        head_dim=cfg.resolved_head_dim,
        qkv_bias=cfg.qkv_bias,
        qk_norm=cfg.qk_norm,
        window=window_override if window_override is not None else cfg.window,
        rope_theta=cfg.rope_theta,
        causal=True,
    )


# ---------------------------------------------------------------------------
# transformer decoder (dense / moe / vlm)
# ---------------------------------------------------------------------------

def _init_decoder_block(key, cfg: ArchConfig, dt, stack: int):
    ks = jax.random.split(key, 3)
    dims = _attn_dims(cfg)
    p = {
        "attn": attn.init_attn_params(ks[0], cfg.d_model, dims, dt,
                                      stack=stack),
        "ln1": jnp.ones((stack, cfg.d_model) if stack else (cfg.d_model,), dt),
        "ln2": jnp.ones((stack, cfg.d_model) if stack else (cfg.d_model,), dt),
    }
    if cfg.moe is not None:
        p["moe"] = init_moe_params(ks[1], cfg.d_model, cfg.moe, dt,
                                   stack=stack)
    else:
        km = jax.random.split(ks[1], 3)
        p["mlp"] = {
            "gate": dense_init(km[0], cfg.d_model, cfg.d_ff, dt, stack=stack),
            "up": dense_init(km[1], cfg.d_model, cfg.d_ff, dt, stack=stack),
            "down": dense_init(km[2], cfg.d_ff, cfg.d_model, dt, stack=stack),
        }
    return p


def _decoder_block(bp, x, cfg: ArchConfig, dims: AttnDims, positions):
    h = attn.self_attention(bp["attn"], rms_norm(x, bp["ln1"]), dims,
                            positions)
    x = x + h
    if cfg.moe is not None:
        mo, aux = moe_block(bp["moe"], rms_norm(x, bp["ln2"]), cfg.moe)
        return x + mo, aux
    return x + swiglu(rms_norm(x, bp["ln2"]), bp["mlp"]["gate"],
                      bp["mlp"]["up"], bp["mlp"]["down"]), jnp.zeros((), jnp.float32)


def _decoder_block_decode(bp, x, cache: KVCache, cfg: ArchConfig,
                          dims: AttnDims):
    h, cache = attn.decode_self_attention(bp["attn"], rms_norm(x, bp["ln1"]),
                                          cache, dims)
    x = x + h
    if cfg.moe is not None:
        mo, _ = moe_block(bp["moe"], rms_norm(x, bp["ln2"]), cfg.moe)
        return x + mo, cache
    return x + swiglu(rms_norm(x, bp["ln2"]), bp["mlp"]["gate"],
                      bp["mlp"]["up"], bp["mlp"]["down"]), cache


def build_decoder_model(cfg: ArchConfig,
                        window_override=None) -> Model:
    dt = _dtype(cfg)
    dims = _attn_dims(cfg, window_override)
    L = cfg.n_layers

    def init_params(key):
        ks = jax.random.split(key, 4)
        return {
            "embed": embed_init(ks[0], cfg.padded_vocab, cfg.d_model, dt),
            "blocks": _init_decoder_block(ks[1], cfg, dt, stack=L),
            "final_norm": jnp.ones((cfg.d_model,), dt),
            "lm_head": dense_init(ks[2], cfg.d_model, cfg.padded_vocab, dt),
        }

    def _embed(params, batch):
        # embed_stub archs (VLM) feed precomputed patch/text embeddings at
        # prefill/train; decode always goes through the token table.
        if "embeds" in batch:
            return batch["embeds"].astype(dt)
        return jnp.take(params["embed"], batch["tokens"], axis=0)

    def forward(params, batch):
        x = _embed(params, batch)
        B, S, _ = x.shape
        positions = jnp.broadcast_to(jnp.arange(S), (B, S))

        def body(carry, bp):
            x, aux = carry
            x2, a = _decoder_block(bp, x, cfg, dims, positions)
            return (x2, aux + a), None

        x, aux = grouped_scan(body, (x, jnp.zeros((), jnp.float32)),
                              params["blocks"])
        x = rms_norm(x, params["final_norm"])
        logits = jnp.einsum("bsd,dv->bsv", x, params["lm_head"])
        return logits, {"aux_loss": aux}

    def init_cache(params, batch_size: int, seq_len: int):
        del params
        one = attn.init_cache(batch_size, seq_len, dims, dt)
        return KVCache(
            k=jnp.broadcast_to(one.k, (L, *one.k.shape)),
            v=jnp.broadcast_to(one.v, (L, *one.v.shape)),
            pos=jnp.zeros((), jnp.int32),
        )

    def decode_step(params, cache, batch):
        x = _embed(params, batch)  # (B, 1, D)

        def body(x, xs):
            bp, k, v = xs
            lc = KVCache(k=k, v=v, pos=cache.pos)
            x, nc = _decoder_block_decode(bp, x, lc, cfg, dims)
            return x, (nc.k, nc.v)

        x, (nk, nv) = jax.lax.scan(body, x,
                                   (params["blocks"], cache.k, cache.v))
        x = rms_norm(x, params["final_norm"])
        logits = jnp.einsum("bsd,dv->bsv", x, params["lm_head"])
        return logits, KVCache(k=nk, v=nv, pos=cache.pos + 1)

    def prefill(params, batch):
        # cache-building prefill: run forward, then bulk-write k/v.
        # For the dry-run we lower prefill as forward (logits only) +
        # cache init; the bulk write path is exercised by serve tests.
        logits, _ = forward(params, batch)
        return logits, None

    return Model(cfg, init_params, forward, init_cache, prefill, decode_step)


# ---------------------------------------------------------------------------
# SSM (mamba2) and hybrid (zamba2)
# ---------------------------------------------------------------------------

def build_ssm_model(cfg: ArchConfig) -> Model:
    dt = _dtype(cfg)
    sdims = ssm_dims(cfg.d_model, cfg.ssm)
    L = cfg.n_layers

    def init_params(key):
        ks = jax.random.split(key, 4)
        return {
            "embed": embed_init(ks[0], cfg.padded_vocab, cfg.d_model, dt),
            "blocks": {
                "ssm": init_ssm_params(ks[1], sdims, dt, stack=L),
                "ln": jnp.ones((L, cfg.d_model), dt),
            },
            "final_norm": jnp.ones((cfg.d_model,), dt),
            "lm_head": dense_init(ks[2], cfg.d_model, cfg.padded_vocab, dt),
        }

    def forward(params, batch):
        x = jnp.take(params["embed"], batch["tokens"], axis=0)

        def body(x, bp):
            return x + ssm_block(bp["ssm"], rms_norm(x, bp["ln"]), sdims), None

        x = grouped_scan(body, x, params["blocks"])
        x = rms_norm(x, params["final_norm"])
        return jnp.einsum("bsd,dv->bsv", x, params["lm_head"]), {
            "aux_loss": jnp.zeros((), jnp.float32)
        }

    def init_cache(params, batch_size: int, seq_len: int):
        del params, seq_len
        one = init_ssm_cache(batch_size, sdims, dt)
        return SSMCache(
            conv=jnp.broadcast_to(one.conv, (L, *one.conv.shape)),
            state=jnp.broadcast_to(one.state, (L, *one.state.shape)),
        )

    def decode_step(params, cache, batch):
        x = jnp.take(params["embed"], batch["tokens"], axis=0)

        def body(x, xs):
            bp, conv, state = xs
            h, nc = ssm_decode_step(bp["ssm"], rms_norm(x, bp["ln"]),
                                    SSMCache(conv, state), sdims)
            return x + h, (nc.conv, nc.state)

        x, (nconv, nstate) = jax.lax.scan(
            body, x, (params["blocks"], cache.conv, cache.state)
        )
        x = rms_norm(x, params["final_norm"])
        return jnp.einsum("bsd,dv->bsv", x, params["lm_head"]), SSMCache(
            nconv, nstate
        )

    return Model(cfg, init_params, forward, init_cache,
                 lambda p, b: (forward(p, b)[0], None), decode_step)


class HybridCache(NamedTuple):
    ssm: SSMCache  # stacked (L_mamba, ...)
    kv: KVCache  # stacked (n_attn_applications, ...)


def build_hybrid_model(cfg: ArchConfig, window_override=None) -> Model:
    """Zamba2: L mamba blocks; one SHARED attn+mlp block applied every
    ``attn_every`` mamba layers (weights reused across applications)."""
    dt = _dtype(cfg)
    sdims = ssm_dims(cfg.d_model, cfg.ssm)
    dims = _attn_dims(cfg, window_override)
    L = cfg.n_layers
    k = cfg.attn_every
    n_groups, rem = divmod(L, k)
    n_attn = n_groups + (1 if rem else 0)

    def init_params(key):
        ks = jax.random.split(key, 5)
        return {
            "embed": embed_init(ks[0], cfg.padded_vocab, cfg.d_model, dt),
            "mamba": {
                "ssm": init_ssm_params(ks[1], sdims, dt, stack=L),
                "ln": jnp.ones((L, cfg.d_model), dt),
            },
            "shared_attn": _init_decoder_block(ks[2], cfg, dt, stack=0),
            "final_norm": jnp.ones((cfg.d_model,), dt),
            "lm_head": dense_init(ks[3], cfg.d_model, cfg.padded_vocab, dt),
        }

    def _grouped(tree):
        """(L, ...) -> main (n_groups, k, ...) + remainder (rem, ...)."""
        main = jax.tree.map(
            lambda a: a[: n_groups * k].reshape(n_groups, k, *a.shape[1:]),
            tree,
        )
        tail = jax.tree.map(lambda a: a[n_groups * k :], tree)
        return main, tail

    def forward(params, batch):
        x = jnp.take(params["embed"], batch["tokens"], axis=0)
        B, S, _ = x.shape
        positions = jnp.broadcast_to(jnp.arange(S), (B, S))
        main, tail = _grouped(params["mamba"])

        def mamba_body(x, bp):
            return x + ssm_block(bp["ssm"], rms_norm(x, bp["ln"]), sdims), None

        mamba_body = jax.checkpoint(mamba_body)

        def group_body(x, gp):
            x, a = _decoder_block(params["shared_attn"], x, cfg, dims,
                                  positions)
            x, _ = jax.lax.scan(mamba_body, x, gp)
            return x, None

        x, _ = jax.lax.scan(jax.checkpoint(group_body), x, main)
        if rem:
            x, _ = _decoder_block(params["shared_attn"], x, cfg, dims,
                                  positions)
            x, _ = jax.lax.scan(mamba_body, x, tail)
        x = rms_norm(x, params["final_norm"])
        return jnp.einsum("bsd,dv->bsv", x, params["lm_head"]), {
            "aux_loss": jnp.zeros((), jnp.float32)
        }

    def init_cache(params, batch_size: int, seq_len: int):
        del params
        ssm_one = init_ssm_cache(batch_size, sdims, dt)
        kv_one = attn.init_cache(batch_size, seq_len, dims, dt)
        return HybridCache(
            ssm=SSMCache(
                conv=jnp.broadcast_to(ssm_one.conv, (L, *ssm_one.conv.shape)),
                state=jnp.broadcast_to(ssm_one.state,
                                       (L, *ssm_one.state.shape)),
            ),
            kv=KVCache(
                k=jnp.broadcast_to(kv_one.k, (n_attn, *kv_one.k.shape)),
                v=jnp.broadcast_to(kv_one.v, (n_attn, *kv_one.v.shape)),
                pos=jnp.zeros((), jnp.int32),
            ),
        )

    def decode_step(params, cache: HybridCache, batch):
        x = jnp.take(params["embed"], batch["tokens"], axis=0)
        pos = cache.kv.pos
        main, tail = _grouped(params["mamba"])
        ssm_main, ssm_tail = _grouped(
            {"conv": cache.ssm.conv, "state": cache.ssm.state}
        )

        def mamba_body(x, xs):
            bp, conv, state = xs
            h, nc = ssm_decode_step(bp["ssm"], rms_norm(x, bp["ln"]),
                                    SSMCache(conv, state), sdims)
            return x + h, (nc.conv, nc.state)

        def group_body(x, xs):
            gp, sc, kvk, kvv = xs
            lc = KVCache(k=kvk, v=kvv, pos=pos)
            x, nkv = _decoder_block_decode(params["shared_attn"], x, lc, cfg,
                                           dims)
            x, (nconv, nstate) = jax.lax.scan(
                mamba_body, x, (gp, sc["conv"], sc["state"])
            )
            return x, (nconv, nstate, nkv.k, nkv.v)

        x, (mc, ms, ak, av) = jax.lax.scan(
            group_body, x,
            (main, ssm_main, cache.kv.k[:n_groups], cache.kv.v[:n_groups]),
        )
        new_conv = mc.reshape(-1, *mc.shape[2:])
        new_state = ms.reshape(-1, *ms.shape[2:])
        new_k, new_v = ak, av
        if rem:
            lc = KVCache(k=cache.kv.k[n_groups], v=cache.kv.v[n_groups],
                         pos=pos)
            x, nkv = _decoder_block_decode(params["shared_attn"], x, lc, cfg,
                                           dims)
            x, (tconv, tstate) = jax.lax.scan(
                mamba_body, x, (tail, ssm_tail["conv"], ssm_tail["state"])
            )
            new_conv = jnp.concatenate([new_conv, tconv], 0)
            new_state = jnp.concatenate([new_state, tstate], 0)
            new_k = jnp.concatenate([new_k, nkv.k[None]], 0)
            new_v = jnp.concatenate([new_v, nkv.v[None]], 0)
        x = rms_norm(x, params["final_norm"])
        logits = jnp.einsum("bsd,dv->bsv", x, params["lm_head"])
        return logits, HybridCache(
            ssm=SSMCache(conv=new_conv, state=new_state),
            kv=KVCache(k=new_k, v=new_v, pos=pos + 1),
        )

    return Model(cfg, init_params, forward, init_cache,
                 lambda p, b: (forward(p, b)[0], None), decode_step)


# ---------------------------------------------------------------------------
# encoder-decoder (seamless)
# ---------------------------------------------------------------------------

class EncDecCache(NamedTuple):
    self_kv: KVCache  # stacked (L_dec, ...)
    cross_k: jnp.ndarray  # (L_dec, B, Se, KV, hd)
    cross_v: jnp.ndarray


def build_encdec_model(cfg: ArchConfig) -> Model:
    dt = _dtype(cfg)
    ec = cfg.encoder
    enc_dims = AttnDims(n_heads=ec.n_heads, n_kv=ec.n_kv,
                        head_dim=cfg.d_model // ec.n_heads, causal=False,
                        rope_theta=cfg.rope_theta)
    dec_dims = _attn_dims(cfg)
    Ld, Le = cfg.n_layers, ec.n_layers

    def init_params(key):
        ks = jax.random.split(key, 8)
        enc_block = {
            "attn": attn.init_attn_params(ks[0], cfg.d_model, enc_dims, dt,
                                          stack=Le),
            "ln1": jnp.ones((Le, cfg.d_model), dt),
            "ln2": jnp.ones((Le, cfg.d_model), dt),
            "mlp": {
                "gate": dense_init(ks[1], cfg.d_model, ec.d_ff, dt, stack=Le),
                "up": dense_init(ks[2], cfg.d_model, ec.d_ff, dt, stack=Le),
                "down": dense_init(ks[3], ec.d_ff, cfg.d_model, dt, stack=Le),
            },
        }
        dec_block = _init_decoder_block(ks[4], cfg, dt, stack=Ld)
        dec_block["cross"] = attn.init_attn_params(
            ks[5], cfg.d_model, dec_dims, dt, stack=Ld
        )
        dec_block["ln3"] = jnp.ones((Ld, cfg.d_model), dt)
        return {
            "enc_blocks": enc_block,
            "enc_norm": jnp.ones((cfg.d_model,), dt),
            "dec_embed": embed_init(ks[6], cfg.padded_vocab, cfg.d_model, dt),
            "dec_blocks": dec_block,
            "final_norm": jnp.ones((cfg.d_model,), dt),
            "lm_head": dense_init(ks[7], cfg.d_model, cfg.padded_vocab, dt),
        }

    def encode(params, enc_embeds):
        x = enc_embeds.astype(dt)
        B, Se, _ = x.shape
        positions = jnp.broadcast_to(jnp.arange(Se), (B, Se))

        def body(x, bp):
            h = attn.self_attention(bp["attn"], rms_norm(x, bp["ln1"]),
                                    enc_dims, positions)
            x = x + h
            x = x + swiglu(rms_norm(x, bp["ln2"]), bp["mlp"]["gate"],
                           bp["mlp"]["up"], bp["mlp"]["down"])
            return x, None

        x = grouped_scan(body, x, params["enc_blocks"], group=4)
        return rms_norm(x, params["enc_norm"])

    def forward(params, batch):
        enc_out = encode(params, batch["enc_embeds"])
        x = jnp.take(params["dec_embed"], batch["tokens"], axis=0)
        B, S, _ = x.shape
        positions = jnp.broadcast_to(jnp.arange(S), (B, S))

        def body(x, bp):
            h = attn.self_attention(bp["attn"], rms_norm(x, bp["ln1"]),
                                    dec_dims, positions)
            x = x + h
            ek, ev = attn.encode_kv(bp["cross"], enc_out, dec_dims)
            x = x + attn.cross_attention(bp["cross"], rms_norm(x, bp["ln3"]),
                                         ek, ev, dec_dims)
            x = x + swiglu(rms_norm(x, bp["ln2"]), bp["mlp"]["gate"],
                           bp["mlp"]["up"], bp["mlp"]["down"])
            return x, None

        x = grouped_scan(body, x, params["dec_blocks"], group=4)
        x = rms_norm(x, params["final_norm"])
        return jnp.einsum("bsd,dv->bsv", x, params["lm_head"]), {
            "aux_loss": jnp.zeros((), jnp.float32)
        }

    def init_cache(params, batch_size: int, seq_len: int):
        del params
        enc_len = max(seq_len // 4, 1)
        one = attn.init_cache(batch_size, seq_len, dec_dims, dt)
        hd = dec_dims.head_dim
        return EncDecCache(
            self_kv=KVCache(
                k=jnp.broadcast_to(one.k, (Ld, *one.k.shape)),
                v=jnp.broadcast_to(one.v, (Ld, *one.v.shape)),
                pos=jnp.zeros((), jnp.int32),
            ),
            cross_k=jnp.zeros((Ld, batch_size, enc_len, dec_dims.n_kv, hd),
                              dt),
            cross_v=jnp.zeros((Ld, batch_size, enc_len, dec_dims.n_kv, hd),
                              dt),
        )

    def decode_step(params, cache: EncDecCache, batch):
        x = jnp.take(params["dec_embed"], batch["tokens"], axis=0)

        def body(x, xs):
            bp, k, v, ck, cv = xs
            lc = KVCache(k=k, v=v, pos=cache.self_kv.pos)
            h, nc = attn.decode_self_attention(
                bp["attn"], rms_norm(x, bp["ln1"]), lc, dec_dims
            )
            x = x + h
            x = x + attn.cross_attention(bp["cross"],
                                         rms_norm(x, bp["ln3"]), ck, cv,
                                         dec_dims)
            x = x + swiglu(rms_norm(x, bp["ln2"]), bp["mlp"]["gate"],
                           bp["mlp"]["up"], bp["mlp"]["down"])
            return x, (nc.k, nc.v)

        x, (nk, nv) = jax.lax.scan(
            body, x,
            (params["dec_blocks"], cache.self_kv.k, cache.self_kv.v,
             cache.cross_k, cache.cross_v),
        )
        x = rms_norm(x, params["final_norm"])
        logits = jnp.einsum("bsd,dv->bsv", x, params["lm_head"])
        return logits, EncDecCache(
            self_kv=KVCache(nk, nv, cache.self_kv.pos + 1),
            cross_k=cache.cross_k, cross_v=cache.cross_v,
        )

    return Model(cfg, init_params, forward, init_cache,
                 lambda p, b: (forward(p, b)[0], None), decode_step)


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------

def build_model(cfg: ArchConfig, *, window_override=None) -> Model:
    if cfg.family in ("dense", "moe", "vlm"):
        return build_decoder_model(cfg, window_override=window_override)
    if cfg.family == "ssm":
        return build_ssm_model(cfg)
    if cfg.family == "hybrid":
        return build_hybrid_model(cfg, window_override=window_override)
    if cfg.family in ("encdec", "audio"):
        return build_encdec_model(cfg)
    raise ValueError(f"unknown family {cfg.family!r}")


def loss_fn(model: Model, params, batch):
    """Next-token CE (+ MoE aux)."""
    logits, aux = model.forward(params, batch)
    labels = batch["labels"]
    return cross_entropy(
        logits[:, :-1], labels[:, 1:], num_classes=model.cfg.vocab
    ) + aux["aux_loss"]
