"""Shared layer primitives (functional, pytree params, scan-friendly).

Conventions:
 - weight kernels are stored ``(..., in, out)`` — fan-in = shape[-2]
   (this is what ``core.zampling.default_fan_in`` assumes);
 - layer stacks are scanned: every block leaf carries a leading
   ``(n_layers, ...)`` axis;
 - activations/weights in ``cfg.dtype`` (bf16 at scale), norms/softmax
   accumulate in f32.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


def dense_init(key, in_dim: int, out_dim: int, dtype, *, stack: int = 0):
    shape = (stack, in_dim, out_dim) if stack else (in_dim, out_dim)
    scale = (2.0 / in_dim) ** 0.5  # He, matching Lemma 2.1's target
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


def embed_init(key, vocab: int, d_model: int, dtype):
    return (jax.random.normal(key, (vocab, d_model), jnp.float32)
            * (1.0 / d_model**0.5)).astype(dtype)


def rms_norm(x, scale, eps: float = 1e-6):
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(jnp.square(x), axis=-1, keepdims=True) + eps)
    return (x * scale.astype(jnp.float32)).astype(dt)


def rope(x, positions, theta: float):
    """Rotary embedding. x: (..., S, H, hd); positions: (..., S)."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = jnp.exp(
        -jnp.log(theta) * jnp.arange(half, dtype=jnp.float32) / half
    )
    ang = positions[..., None].astype(jnp.float32) * freqs  # (..., S, half)
    cos = jnp.cos(ang)[..., None, :]  # broadcast over heads
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    xf1, xf2 = x1.astype(jnp.float32), x2.astype(jnp.float32)
    return jnp.concatenate(
        [xf1 * cos - xf2 * sin, xf2 * cos + xf1 * sin], axis=-1
    ).astype(x.dtype)


def swiglu(x, gate_w, up_w, down_w):
    g = jnp.einsum("...d,df->...f", x, gate_w)
    u = jnp.einsum("...d,df->...f", x, up_w)
    h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
    return jnp.einsum("...f,fd->...d", h, down_w)


def gelu_mlp(x, up_w, up_b, down_w, down_b):
    h = jnp.einsum("...d,df->...f", x, up_w) + up_b
    h = jax.nn.gelu(h.astype(jnp.float32)).astype(x.dtype)
    return jnp.einsum("...f,fd->...d", h, down_w) + down_b


def grouped_scan(body, carry, xs, group: int = 8):
    """scan-over-layers with NESTED remat.

    Plain per-layer checkpointing saves the carry (activations) for all
    L layers: ~27 GB/device for a 40L x 5k d_model at 4k seq.  Grouping
    saves L/group outer carries; each group is replayed in backward with
    per-layer checkpoints inside — peak ~ (L/group + group) activations.
    """
    L = jax.tree.leaves(xs)[0].shape[0]
    body_ck = jax.checkpoint(body)
    if group <= 1 or L <= group or L % group:
        carry, _ = jax.lax.scan(body_ck, carry, xs)
        return carry

    xs_g = jax.tree.map(
        lambda a: a.reshape(L // group, group, *a.shape[1:]), xs
    )

    def gbody(c, xg):
        c, _ = jax.lax.scan(body_ck, c, xg)
        return c, None

    carry, _ = jax.lax.scan(jax.checkpoint(gbody), carry, xs_g)
    return carry


CE_CHUNK = 8192  # tokens per CE chunk (bounds live f32 logit copies)


def cross_entropy(logits, labels, *, ignore: int = -100,
                  num_classes: int = 0):
    """Mean token CE; chunks the token dim when large (see _ce_body)."""
    T = 1
    for s in labels.shape:
        T *= int(s)
    V = logits.shape[-1]
    if T <= CE_CHUNK:
        return _ce_body(logits, labels, ignore=ignore,
                        num_classes=num_classes)
    nc = -(-T // CE_CHUNK)
    pad = nc * CE_CHUNK - T
    lf = jnp.pad(logits.reshape(T, V), ((0, pad), (0, 0))).reshape(
        nc, CE_CHUNK, V
    )
    ll = jnp.pad(labels.reshape(T), (0, pad), constant_values=ignore).reshape(
        nc, CE_CHUNK
    )

    def one(args):
        lg, lb = args
        s = _ce_body(lg, lb, ignore=ignore, num_classes=num_classes,
                     reduce="sum")
        c = jnp.sum((lb != ignore).astype(jnp.float32))
        return s, c

    sums, counts = jax.lax.map(jax.checkpoint(one), (lf, ll))
    return jnp.sum(sums) / jnp.maximum(jnp.sum(counts), 1.0)


def _ce_body(logits, labels, *, ignore: int = -100,
             num_classes: int = 0, reduce: str = "mean"):
    """Mean token CE in f32. logits (..., V), labels (...) int32.

    Vocab-parallel formulation: the target log-prob is extracted with a
    masked reduction over V (not take_along_axis), so a vocab-sharded
    logits tensor reduces in place under GSPMD instead of being
    all-gathered (which costs ~40 GB/device at 152k vocab, 4k seq).

    ``num_classes``: when logits carry vocab padding (padded_vocab),
    columns >= num_classes are excluded from the partition function.
    """
    logits = logits.astype(jnp.float32)
    vid = jax.lax.broadcasted_iota(jnp.int32, logits.shape, logits.ndim - 1)
    if num_classes and num_classes < logits.shape[-1]:
        logits = jnp.where(vid < num_classes, logits, -1e30)
    m = jnp.max(logits, axis=-1, keepdims=True)
    lse = m[..., 0] + jnp.log(jnp.sum(jnp.exp(logits - m), axis=-1))
    ll = jnp.sum(
        jnp.where(vid == labels[..., None], logits, 0.0), axis=-1
    )
    valid = (labels != ignore).astype(jnp.float32)
    total = jnp.sum((lse - ll) * valid)
    if reduce == "sum":
        return total
    return total / jnp.maximum(jnp.sum(valid), 1.0)
