"""Mamba2 block — SSD (state-space duality) chunked form [arXiv:2405.21060].

Train/prefill runs the block-decomposed dual form: intra-chunk terms are
batched matmuls (MXU-friendly), inter-chunk state is a short
``lax.scan`` recurrence over chunk summaries.  Decode is the O(1)
recurrent update on a constant-size ``(H, P, N)`` state — which is why
SSM/hybrid archs run the long_500k shape natively.

Layout per layer (all leaves scan-stacked on a leading L axis):
  in_proj  (D, 2·d_inner + 2·G·N + H)   -> [z | xBC | dt]
  conv_w   (conv_width, conv_dim)        depthwise causal, conv_dim = d_inner + 2·G·N
  conv_b   (conv_dim,)
  A_log, D, dt_bias   (H,)
  norm     (d_inner,)                    gated RMSNorm
  out_proj (d_inner, D)
"""

from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import SSMConfig
from .common import dense_init, rms_norm


class SSMDims(NamedTuple):
    d_model: int
    d_inner: int
    n_heads: int
    headdim: int
    d_state: int
    n_groups: int
    conv_width: int
    chunk: int


def ssm_dims(d_model: int, cfg: SSMConfig) -> SSMDims:
    d_inner = cfg.expand * d_model
    return SSMDims(
        d_model=d_model,
        d_inner=d_inner,
        n_heads=d_inner // cfg.headdim,
        headdim=cfg.headdim,
        d_state=cfg.d_state,
        n_groups=cfg.n_groups,
        conv_width=cfg.conv_width,
        chunk=cfg.chunk,
    )


def conv_dim(dims: SSMDims) -> int:
    return dims.d_inner + 2 * dims.n_groups * dims.d_state


def init_ssm_params(key, dims: SSMDims, dtype, stack: int = 0):
    ks = jax.random.split(key, 5)
    H = dims.n_heads
    cd = conv_dim(dims)
    d_in_proj = 2 * dims.d_inner + 2 * dims.n_groups * dims.d_state + H

    def shp(*s):
        return (stack, *s) if stack else s

    return {
        "in_proj": dense_init(ks[0], dims.d_model, d_in_proj, dtype,
                              stack=stack),
        "conv_w": (jax.random.normal(ks[1], shp(dims.conv_width, cd),
                                     jnp.float32)
                   * (1.0 / dims.conv_width) ** 0.5).astype(dtype),
        "conv_b": jnp.zeros(shp(cd), dtype),
        "A_log": jnp.log(
            jax.random.uniform(ks[2], shp(H), jnp.float32, 1.0, 16.0)
        ),
        "D": jnp.ones(shp(H), jnp.float32),
        "dt_bias": jnp.log(
            jnp.expm1(
                jnp.exp(
                    jax.random.uniform(ks[3], shp(H), jnp.float32,
                                       jnp.log(1e-3), jnp.log(1e-1))
                )
            )
        ),
        "norm": jnp.ones(shp(dims.d_inner), dtype),
        "out_proj": dense_init(ks[4], dims.d_inner, dims.d_model, dtype,
                               stack=stack),
    }


class SSMCache(NamedTuple):
    conv: jnp.ndarray  # (B, conv_width-1, conv_dim)
    state: jnp.ndarray  # (B, H, P, N) f32


def init_ssm_cache(batch: int, dims: SSMDims, dtype) -> SSMCache:
    return SSMCache(
        conv=jnp.zeros((batch, dims.conv_width - 1, conv_dim(dims)), dtype),
        state=jnp.zeros(
            (batch, dims.n_heads, dims.headdim, dims.d_state), jnp.float32
        ),
    )


def _causal_conv(x, w, b):
    """Depthwise causal conv. x (B,S,C); w (K,C); b (C,)."""
    K = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    S = x.shape[1]
    acc = sum(xp[:, k : k + S, :] * w[k] for k in range(K))
    return acc + b


def _split_proj(zxbcdt, dims: SSMDims):
    di, gn = dims.d_inner, dims.n_groups * dims.d_state
    z = zxbcdt[..., :di]
    xBC = zxbcdt[..., di : di + di + 2 * gn]
    dt = zxbcdt[..., di + di + 2 * gn :]
    return z, xBC, dt


def _split_xbc(xBC, dims: SSMDims):
    di, gn = dims.d_inner, dims.n_groups * dims.d_state
    x = xBC[..., :di]
    Bmat = xBC[..., di : di + gn]
    Cmat = xBC[..., di + gn :]
    return x, Bmat, Cmat


def _group_to_heads(mat, dims: SSMDims):
    """(B,S,G*N) -> (B,S,H,N) broadcasting each group to its heads."""
    B, S, _ = mat.shape
    g = mat.reshape(B, S, dims.n_groups, dims.d_state)
    rep = dims.n_heads // dims.n_groups
    return jnp.repeat(g, rep, axis=2)


def ssm_block(params, u, dims: SSMDims) -> jnp.ndarray:
    """Full-sequence SSD. u (B,S,D) -> (B,S,D)."""
    B, S, D = u.shape
    Lc = min(dims.chunk, S)
    assert S % Lc == 0, f"seq {S} must tile into chunks of {Lc}"
    nc = S // Lc
    H, P, N = dims.n_heads, dims.headdim, dims.d_state

    zxbcdt = jnp.einsum("bsd,dk->bsk", u, params["in_proj"])
    z, xBC, dt = _split_proj(zxbcdt, dims)
    xBC = jax.nn.silu(
        _causal_conv(xBC, params["conv_w"], params["conv_b"]).astype(
            jnp.float32
        )
    ).astype(u.dtype)
    x, Bm, Cm = _split_xbc(xBC, dims)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])  # (B,S,H)
    A = -jnp.exp(params["A_log"])  # (H,)

    xh = x.reshape(B, S, H, P)
    Bh = _group_to_heads(Bm, dims)  # (B,S,H,N)
    Ch = _group_to_heads(Cm, dims)

    # chunked SSD
    a = (dt * A).reshape(B, nc, Lc, H)  # log-decay per step
    dtc = dt.reshape(B, nc, Lc, H)
    xc = xh.reshape(B, nc, Lc, H, P)
    Bc = Bh.reshape(B, nc, Lc, H, N)
    Cc = Ch.reshape(B, nc, Lc, H, N)

    cum = jnp.cumsum(a, axis=2)  # (B,nc,Lc,H)
    # intra-chunk: L[i,j] = exp(cum_i - cum_j) for i >= j
    seg = cum[:, :, :, None, :] - cum[:, :, None, :, :]  # (B,nc,Lc,Lc,H)
    tri = jnp.tril(jnp.ones((Lc, Lc), bool))
    Lmat = jnp.where(tri[None, None, :, :, None], jnp.exp(seg), 0.0)
    scores = jnp.einsum(
        "bclhn,bcshn->bclsh", Cc.astype(jnp.float32), Bc.astype(jnp.float32)
    )
    y_diag = jnp.einsum(
        "bclsh,bclsh,bcsh,bcshp->bclhp",
        scores,
        jnp.transpose(Lmat, (0, 1, 2, 3, 4)),
        dtc,
        xc.astype(jnp.float32),
    )

    # chunk state summaries: S_c = sum_s exp(cum_end - cum_s) dt_s B_s x_s^T
    decay_to_end = jnp.exp(cum[:, :, -1:, :] - cum)  # (B,nc,Lc,H)
    states = jnp.einsum(
        "bcsh,bcsh,bcshn,bcshp->bchpn",
        decay_to_end,
        dtc,
        Bc.astype(jnp.float32),
        xc.astype(jnp.float32),
    )  # (B,nc,H,P,N)
    chunk_decay = jnp.exp(cum[:, :, -1, :])  # (B,nc,H)

    def scan_fn(h, inp):
        s_c, dec = inp  # (B,H,P,N), (B,H)
        h_new = h * dec[..., None, None] + s_c
        return h_new, h  # emit state BEFORE this chunk

    h0 = jnp.zeros((B, H, P, N), jnp.float32)
    _, h_prev = jax.lax.scan(
        scan_fn, h0,
        (jnp.moveaxis(states, 1, 0), jnp.moveaxis(chunk_decay, 1, 0)),
    )
    h_prev = jnp.moveaxis(h_prev, 0, 1)  # (B,nc,H,P,N)

    y_off = jnp.einsum(
        "bclhn,bclh,bchpn->bclhp",
        Cc.astype(jnp.float32),
        jnp.exp(cum),
        h_prev,
    )

    y = (y_diag + y_off).reshape(B, S, H, P)
    y = y + params["D"][None, None, :, None] * xh.astype(jnp.float32)
    y = y.reshape(B, S, dims.d_inner).astype(u.dtype)
    y = rms_norm(
        y * jax.nn.silu(z.astype(jnp.float32)).astype(u.dtype), params["norm"]
    )
    return jnp.einsum("bsd,dk->bsk", y, params["out_proj"])


def ssm_decode_step(params, u, cache: SSMCache, dims: SSMDims
                    ) -> Tuple[jnp.ndarray, SSMCache]:
    """One-token recurrent update. u (B,1,D)."""
    B = u.shape[0]
    H, P, N = dims.n_heads, dims.headdim, dims.d_state

    zxbcdt = jnp.einsum("bsd,dk->bsk", u, params["in_proj"])[:, 0]
    z, xBC, dt = _split_proj(zxbcdt, dims)
    conv_hist = jnp.concatenate([cache.conv, xBC[:, None, :]], axis=1)
    conv_out = (
        jnp.einsum("bkc,kc->bc", conv_hist.astype(jnp.float32),
                   params["conv_w"].astype(jnp.float32))
        + params["conv_b"].astype(jnp.float32)
    )
    xBC = jax.nn.silu(conv_out).astype(u.dtype)
    x, Bm, Cm = _split_xbc(xBC, dims)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])  # (B,H)
    A = -jnp.exp(params["A_log"])

    xh = x.reshape(B, H, P).astype(jnp.float32)
    rep = H // dims.n_groups
    Bh = jnp.repeat(Bm.reshape(B, dims.n_groups, N), rep, 1).astype(jnp.float32)
    Ch = jnp.repeat(Cm.reshape(B, dims.n_groups, N), rep, 1).astype(jnp.float32)

    dA = jnp.exp(dt * A)  # (B,H)
    state = cache.state * dA[..., None, None] + jnp.einsum(
        "bh,bhn,bhp->bhpn", dt, Bh, xh
    )
    y = jnp.einsum("bhpn,bhn->bhp", state, Ch) + params["D"][None, :, None] * xh
    y = y.reshape(B, dims.d_inner).astype(u.dtype)
    y = rms_norm(
        y * jax.nn.silu(z.astype(jnp.float32)).astype(u.dtype), params["norm"]
    )
    out = jnp.einsum("bd,dk->bk", y, params["out_proj"])[:, None, :]
    new_cache = SSMCache(conv=conv_hist[:, 1:, :].astype(cache.conv.dtype),
                         state=state)
    return out, new_cache
