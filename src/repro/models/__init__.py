from .model import Model, build_model, loss_fn

__all__ = ["Model", "build_model", "loss_fn"]
