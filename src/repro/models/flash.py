"""Blockwise attention with online softmax (flash-style), pure JAX.

Full (S, S) score materialization at 32k+ context is a memory
non-starter (B·H·S² f32).  This computes attention in (q_chunk ×
k_chunk) tiles with the running (max, sum, acc) reduction, bounding
live memory to O(S·d + q_chunk·k_chunk) — the standard memory-roofline
fix that every production system applies; XLA:TPU lowers the inner
einsums onto the MXU directly, so a hand-written Pallas flash kernel is
not the bottleneck here (the Zampling reconstruct is — see kernels/).

Supports causal masking, sliding windows, and GQA.  Fully-masked
(q-block, k-block) tiles are skipped with ``lax.cond`` so causal/SWA
FLOPs match the ideal count within one tile of slack.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def blockwise_attention(
    q, k, v, *,
    causal: bool = True,
    window: Optional[int] = None,
    q_chunk: int = 1024,
    k_chunk: int = 1024,
):
    """q (B,Sq,H,hd); k,v (B,Sk,KV,hd) -> (B,Sq,H,hd).

    Sq may differ from Sk (cross-attention; use causal=False there).
    Positions are arange within each side.
    """
    B, S, H, hd = q.shape
    Sk = k.shape[1]
    KV = k.shape[2]
    rep = H // KV
    q_chunk = min(q_chunk, S)
    k_chunk = min(k_chunk, Sk)
    nq, nk = S // q_chunk, Sk // k_chunk
    assert nq * q_chunk == S and nk * k_chunk == Sk, "S must tile evenly"

    qr = q.reshape(B, nq, q_chunk, KV, rep, hd)
    kr = k.reshape(B, nk, k_chunk, KV, hd)
    vr = v.reshape(B, nk, k_chunk, KV, hd)
    scale = hd**-0.5

    def q_block(qi, qb):  # qb (B, q_chunk, KV, rep, hd)
        q_pos = qi * q_chunk + jnp.arange(q_chunk)

        def k_step(carry, ki):
            m, l, acc = carry
            kb = jax.lax.dynamic_index_in_dim(kr, ki, axis=1, keepdims=False)
            vb = jax.lax.dynamic_index_in_dim(vr, ki, axis=1, keepdims=False)
            k_pos = ki * k_chunk + jnp.arange(k_chunk)

            def compute(_):
                s = jnp.einsum(
                    "bqgrh,bkgh->bgrqk", qb, kb,
                    preferred_element_type=jnp.float32,
                ) * scale
                msk = jnp.zeros((q_chunk, k_chunk), jnp.float32)
                if causal:
                    msk = jnp.where(
                        k_pos[None, :] > q_pos[:, None], NEG_INF, msk
                    )
                if window is not None:
                    msk = jnp.where(
                        k_pos[None, :] <= q_pos[:, None] - window, NEG_INF, msk
                    )
                s = s + msk
                new_m = jnp.maximum(m, jnp.max(s, axis=-1))
                p = jnp.exp(s - new_m[..., None])
                corr = jnp.exp(m - new_m)
                new_l = l * corr + jnp.sum(p, axis=-1)
                new_acc = acc * corr[..., None] + jnp.einsum(
                    "bgrqk,bkgh->bgrqh", p.astype(vb.dtype), vb
                ).astype(jnp.float32)
                return new_m, new_l, new_acc

            needed = True
            if causal:
                # any k_pos <= max q_pos in this pair of blocks?
                needed = (ki * k_chunk) <= (qi * q_chunk + q_chunk - 1)
            if window is not None:
                needed = jnp.logical_and(
                    needed,
                    (ki * k_chunk + k_chunk - 1) > (qi * q_chunk - window),
                )
            carry = jax.lax.cond(
                jnp.asarray(needed), compute, lambda _: (m, l, acc), None
            )
            return carry, None

        # remat: recompute score tiles in backward instead of saving
        # every (q_chunk, k_chunk) f32 tile (O(S^2) memory otherwise)
        k_step = jax.checkpoint(k_step)

        m0 = jnp.full((B, KV, rep, q_chunk), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, KV, rep, q_chunk), jnp.float32)
        a0 = jnp.zeros((B, KV, rep, q_chunk, hd), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(
            k_step, (m0, l0, a0), jnp.arange(nk)
        )
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        # (B, KV, rep, q_chunk, hd) -> (B, q_chunk, KV, rep, hd)
        return jnp.transpose(out, (0, 3, 1, 2, 4))

    q_block = jax.checkpoint(q_block)
    out = jax.lax.map(
        lambda qi: q_block(qi, jax.lax.dynamic_index_in_dim(qr, qi, 1, False)),
        jnp.arange(nq),
    )  # (nq, B, q_chunk, KV, rep, hd)
    out = jnp.transpose(out, (1, 0, 2, 3, 4, 5)).reshape(B, S, H, hd)
    return out.astype(q.dtype)
