"""Mixture-of-Experts block (OLMoE 64e/top-8, Mixtral 8e/top-2).

GShard-style *group-local* capacity dispatch (the TPU-native MoE
formulation): tokens are processed in groups of ``group_size``; within
each group, tokens pick top-k experts and a (G, E, C) one-hot dispatch
tensor routes them, with C = capacity_factor·G·k/E.  Expert FFNs run as
one batched einsum over the expert axis — which shards over the
``model`` mesh axis as expert parallelism, turning dispatch/combine
into all-to-alls under GSPMD.

Group-locality matters at scale: a single global dispatch tensor is
(T, E, 1.25·T·k/E) — QUADRATIC in tokens (measured 2 TB/device at 32k
prefill).  Grouped, total dispatch is 1.25·T·G·k — linear, and the
group dim shards over the data axes.
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from ..configs.base import MoEConfig
from .common import dense_init

GROUP_SIZE = 2048


def init_moe_params(key, d_model: int, cfg: MoEConfig, dtype, stack: int = 0):
    ks = jax.random.split(key, 4)
    e, f = cfg.num_experts, cfg.d_ff_expert

    def expert_w(k, a, b):
        shape = (stack, e, a, b) if stack else (e, a, b)
        scale = (2.0 / a) ** 0.5
        return (jax.random.normal(k, shape, jnp.float32) * scale).astype(dtype)

    return {
        "router": dense_init(ks[0], d_model, e, jnp.float32, stack=stack),
        "gate": expert_w(ks[1], d_model, f),
        "up": expert_w(ks[2], d_model, f),
        "down": expert_w(ks[3], f, d_model),
    }


def _group_dispatch(xt, router, cfg: MoEConfig):
    """xt (G, D) -> (dispatch (G,E,C), combine (G,E,C) f32, probs, sel)."""
    G = xt.shape[0]
    E, K = cfg.num_experts, cfg.top_k
    C = max(1, int(cfg.capacity_factor * G * K / E))
    logits = jnp.einsum("td,de->te", xt.astype(jnp.float32),
                        router.astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)  # (G, E)
    gate_vals, gate_idx = jax.lax.top_k(probs, K)  # (G, K)
    gate_vals = gate_vals / jnp.sum(gate_vals, axis=-1, keepdims=True)

    sel = jax.nn.one_hot(gate_idx, E, dtype=jnp.float32)  # (G, K, E)
    pos_in_e = (jnp.cumsum(sel.reshape(G * K, E), axis=0) - 1.0).reshape(
        G, K, E
    )
    pos = jnp.sum(pos_in_e * sel, axis=-1)  # (G, K) buffer slot per pick
    keep = pos < C
    gate_vals = gate_vals * keep.astype(gate_vals.dtype)
    pos_oh = jax.nn.one_hot(
        jnp.where(keep, pos, C).astype(jnp.int32), C + 1, dtype=jnp.float32
    )[..., :C]  # (G, K, C)
    dispatch = jnp.einsum("tke,tkc->tec", sel, pos_oh)
    combine = jnp.einsum("tke,tkc,tk->tec", sel, pos_oh, gate_vals)
    return dispatch.astype(xt.dtype), combine, probs, sel


def moe_block(params, x, cfg: MoEConfig,
              group_size: int = GROUP_SIZE) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x (B, S, D) -> (out (B, S, D), aux_loss scalar)."""
    B, S, D = x.shape
    T = B * S
    G = min(group_size, T)
    if T % G:
        G = T  # single group for awkward (tiny) shapes
    ng = T // G
    xt = x.reshape(ng, G, D)

    dispatch, combine, probs, sel = jax.vmap(
        lambda g: _group_dispatch(g, params["router"], cfg)
    )(xt)

    ein = jnp.einsum("gtec,gtd->gecd", dispatch, xt)  # (ng, E, C, D)
    h = jax.nn.silu(
        jnp.einsum("gecd,edf->gecf", ein, params["gate"]).astype(jnp.float32)
    ).astype(x.dtype) * jnp.einsum("gecd,edf->gecf", ein, params["up"])
    eout = jnp.einsum("gecf,efd->gecd", h, params["down"])  # (ng, E, C, D)
    out = jnp.einsum(
        "gtec,gecd->gtd", combine.astype(x.dtype), eout
    ).reshape(B, S, D)

    # load-balance auxiliary loss (Switch-style), averaged over groups
    E, K = cfg.num_experts, cfg.top_k
    me = jnp.mean(probs, axis=(0, 1))  # (E,)
    frac = jnp.sum(sel, axis=(0, 1, 2)) / (T * K)
    aux = cfg.router_aux_coef * E * jnp.sum(frac * me)
    return out, aux
