"""Feedforward classifiers for the paper's own experiments.

SMALL ARCHITECTURE: 784-20-20-10 (compression & sensitivity, §3.1/§3.3)
MNISTFC:            784-300-100-10 (federated + Zhou comparison, §3.2),
                    266,610 params — matches the paper's count.
"""

from __future__ import annotations

from typing import Sequence

import jax
import jax.numpy as jnp

from .common import cross_entropy, dense_init


def init_mlp_params(key, dims: Sequence[int], dtype=jnp.float32):
    params = {}
    ks = jax.random.split(key, len(dims) - 1)
    for i, (a, b) in enumerate(zip(dims[:-1], dims[1:])):
        params[f"layer{i}"] = {
            "kernel": dense_init(ks[i], a, b, dtype),
            "bias": jnp.zeros((b,), dtype),
        }
    return params


def mlp_forward(params, x):
    n = len(params)
    for i in range(n):
        lp = params[f"layer{i}"]
        x = x @ lp["kernel"] + lp["bias"]
        if i < n - 1:
            x = jax.nn.relu(x)
    return x


def mlp_loss(params, batch):
    logits = mlp_forward(params, batch["x"])
    labels = jax.nn.one_hot(batch["y"], logits.shape[-1])
    logp = jax.nn.log_softmax(logits.astype(jnp.float32))
    return -jnp.mean(jnp.sum(labels * logp, axis=-1))


def mlp_accuracy(params, batch):
    logits = mlp_forward(params, batch["x"])
    return jnp.mean((jnp.argmax(logits, -1) == batch["y"]).astype(jnp.float32))


SMALL_DIMS = (784, 20, 20, 10)
MNISTFC_DIMS = (784, 300, 100, 10)


def param_count(dims: Sequence[int]) -> int:
    return sum(a * b + b for a, b in zip(dims[:-1], dims[1:]))
