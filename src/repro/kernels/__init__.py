"""Pallas TPU kernels for the Zampling hot spots.

``qz_reconstruct`` — materialization-free ``w = Q z`` (fwd + bwd),
validated in interpret mode against ``ref.py``.  ``ops`` holds the jit'd
public wrappers with the custom VJP and impl dispatch.
"""

from . import ops, qz_reconstruct, ref

__all__ = ["ops", "qz_reconstruct", "ref"]
