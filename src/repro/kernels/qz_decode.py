"""Pallas decode kernels: fused ``y = x @ (Q Bern(f(s)))`` — serving
without weights.

``qz_reconstruct`` turned the mask lifecycle into in-kernel draws but
still EMITS the (m,) weight tensor; a serving fleet then holds full
f32 weights resident per model, which is exactly the memory the
paper's (seed, z) story promised back.  These kernels go one step
further: the decode-path contraction consumes the weight values the
moment they are regenerated, so the only resident zampled state is the
encoded score broadcast (u8/u16 words, or f32 scores) and the only
weight values that ever exist live in VMEM for one block.

Per (window, bm) grid block, for the submatrix ``W_g = rows
[row_offset, row_offset + d_in*d_out)`` of the spec's flat moved row
space (``group`` selects a stacked layer; 2-D leaves have one group):

 - regenerate the block's Q edges from the counter-hash RNG
   (``core.qspec.row_indices`` / ``row_values`` — identical streams to
   every other kernel);
 - draw the z-window in-block from the encoded score words: f32 scores
   via ``bernoulli_u32``, quantized words via the widened-threshold
   integer compare ``(u >> 8) < quant_threshold_u24(q)`` (the PR-5
   downlink codec contract, ``comm.downlink``) — the decoded f32 score
   vector never exists anywhere;
 - scatter the block's ``bm`` weight values into the canonical
   i-aligned tile: flat row ``r`` maps to cell ``(i - i_lo, o)`` of a
   (NI, d_out) tile with ``i = (r - row_offset) // d_out``,
   ``o = (r - row_offset) % d_out``, ``i_lo`` the block's first input
   row and ``NI = bm // d_out + 2`` static (each cell is one term, so
   the scatter is exact);
 - accumulate ``y += x[i_lo : i_lo + NI] @ tile`` into the revisited
   (d_out,) / (B, d_out) output that stays in VMEM across the grid
   (zero-initialized at grid step (0, 0)).

Exactness contract: the kernels replay ``kernels.ops``'s CANONICAL
CONTRACTION TREE (see the serve section comment there) — identical
tile shapes, operand values, and ascending (window, block) add order
as the ref/chunked impls — so the result is bit-identical to
``reconstruct``-then-(canonically tiled)-matmul by construction, up
to IEEE signed zeros in all-dead tile cells (XLA's own dot reduction
tree is context-dependent, which is why the tree is pinned explicitly
rather than inherited from one big ``jnp.dot``).  Verified in
tests/test_serve.py: exact equality, all three codecs, single and
batched, interpret-mode Pallas vs both jnp fallbacks.

VMEM note: the scatter one-hots are (bm, NI), (bm, d_out), and
(NI, d_in) f32 — at bm=256 and LLM vocab widths the (bm, d_out)
one-hot dominates.  Interpret mode is the validation target here; on
hardware the out one-hot wants a blocked d_out grid axis (carried in
ROADMAP with the other TPU items).

Grid: only the windows overlapping the group's row range run —
``w0 = row_offset // rows_per_window`` is folded into the p-window
BlockSpec, so a stacked leaf costs one layer's blocks per call, not L.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from ..core.hashrng import bernoulli_u32
from ..core.qspec import QSpec, row_indices, row_values
from ..core.sampling import mask_u32, quant_threshold_u24
from .ops import SERVE_BM, serve_block_grid, serve_tile_rows
from .qz_reconstruct import _lanes_per_window, _onehot, _unpack_window


def _decode_window_mask(spec: QSpec, step, p_win, w0: int, qbits,
                        qpacked=False):
    """Draw grid window ``w0 + program_id(0)``'s z-bits in-block.

    Same draw as ``qz_reconstruct._window_mask`` but with the window
    base offset: the decode grid only spans the windows overlapping
    one group's rows, so the global window id is ``w0 + i``.  With
    ``qpacked`` the operand window is the packed uint32 lanes of the
    sub-byte codecs, unpacked in-block.
    """
    if qpacked:
        p_win = _unpack_window(spec, p_win, qbits)
    i = pl.program_id(0)
    coords = (w0 + i) * spec.window + jax.lax.iota(jnp.int32, spec.window)
    u = mask_u32(spec.seed, spec.tensor_id, step, coords)
    if qbits is None:
        return bernoulli_u32(u, p_win.astype(jnp.float32))
    thr = quant_threshold_u24(p_win, qbits)
    return ((u >> np.uint32(8)) < thr).astype(jnp.float32)


def _decode_block(p_ref, step_ref, *, spec: QSpec, bm: int, w0: int,
                  row_offset: int, d_in: int, d_out: int, qbits,
                  qpacked=False):
    """Shared front half of both decode kernels.

    Regenerates this block's weight values and scatters them into the
    canonical (NI, d_out) tile.  Returns (tile, oh_x) with ``oh_x``
    the (NI, d_in) one-hot selecting ``x[i_lo : i_lo + NI]`` (zero
    rows past d_in), matching ``ops._serve_contract_blocks``'s padded
    dynamic slice value-for-value.
    """
    i = pl.program_id(0)
    j = pl.program_id(1)
    lane = jax.lax.iota(jnp.int32, bm)
    bstart = (w0 + i) * spec.rows_per_window + j * bm
    rows = bstart + lane
    sub = d_in * d_out
    live = (
        (rows >= row_offset)
        & (rows < row_offset + sub)
        & (j * bm + lane < spec.rows_per_window)
        & (rows < spec.m)
    )
    idx = row_indices(spec, rows)  # (bm, d) in-window
    vals = row_values(spec, rows, dtype=jnp.float32)
    zwin = _decode_window_mask(spec, step_ref[0], p_ref[...], w0, qbits,
                               qpacked=qpacked)
    zsel = jnp.dot(_onehot(idx, spec.window), zwin,
                   preferred_element_type=jnp.float32)
    w_blk = jnp.where(live,
                      jnp.sum(vals * zsel.reshape(bm, spec.d), axis=-1),
                      0.0)
    ni = serve_tile_rows(bm, d_out)
    i_lo = jnp.clip(bstart - row_offset, 0, sub - 1) // d_out
    flat = rows - row_offset
    a_rows = jnp.where(live, flat // d_out - i_lo, ni)
    o_cols = jnp.where(live, flat % d_out, 0)
    oh_a = (a_rows[:, None] == jax.lax.iota(jnp.int32, ni)[None, :]
            ).astype(jnp.float32)  # (bm, ni)
    oh_o = (o_cols[:, None] == jax.lax.iota(jnp.int32, d_out)[None, :]
            ).astype(jnp.float32)  # (bm, d_out)
    tile = jnp.dot(oh_a.T, w_blk[:, None] * oh_o,
                   preferred_element_type=jnp.float32)  # (ni, d_out)
    oh_x = ((i_lo + jax.lax.iota(jnp.int32, ni))[:, None]
            == jax.lax.iota(jnp.int32, d_in)[None, :]
            ).astype(jnp.float32)  # (ni, d_in)
    return tile, oh_x


def _mv_kernel(p_ref, step_ref, x_ref, y_ref, *, spec: QSpec, bm: int,
               w0: int, row_offset: int, d_in: int, d_out: int, qbits,
               qpacked=False):
    @pl.when((pl.program_id(0) == 0) & (pl.program_id(1) == 0))
    def _init():
        y_ref[...] = jnp.zeros_like(y_ref)

    tile, oh_x = _decode_block(
        p_ref, step_ref, spec=spec, bm=bm, w0=w0, row_offset=row_offset,
        d_in=d_in, d_out=d_out, qbits=qbits, qpacked=qpacked,
    )
    xseg = jnp.dot(oh_x, x_ref[...].astype(jnp.float32),
                   preferred_element_type=jnp.float32)  # (ni,)
    y_ref[...] += jnp.dot(xseg, tile,
                          preferred_element_type=jnp.float32)


def _mm_kernel(p_ref, step_ref, x_ref, y_ref, *, spec: QSpec, bm: int,
               w0: int, row_offset: int, d_in: int, d_out: int, qbits,
               qpacked=False):
    @pl.when((pl.program_id(0) == 0) & (pl.program_id(1) == 0))
    def _init():
        y_ref[...] = jnp.zeros_like(y_ref)

    tile, oh_x = _decode_block(
        p_ref, step_ref, spec=spec, bm=bm, w0=w0, row_offset=row_offset,
        d_in=d_in, d_out=d_out, qbits=qbits, qpacked=qpacked,
    )
    xseg = jnp.dot(x_ref[...].astype(jnp.float32), oh_x.T,
                   preferred_element_type=jnp.float32)  # (B, ni)
    y_ref[...] += jnp.dot(xseg, tile,
                          preferred_element_type=jnp.float32)


def _check_layout(spec: QSpec, row_offset: int, d_in: int, d_out: int):
    if spec.shard_count != 1:
        raise ValueError(
            "decode kernels address the single-block row layout; "
            f"spec has shard_count={spec.shard_count}"
        )
    if row_offset + d_in * d_out > spec.m:
        raise ValueError(
            f"group rows [{row_offset}, {row_offset + d_in * d_out}) "
            f"exceed spec.m={spec.m}"
        )


def qz_sample_matvec(spec: QSpec, p, step, x, *, row_offset: int = 0,
                     d_in: int, d_out: int, qbits=None, qpacked=False,
                     bm: int = SERVE_BM, interpret: bool = True):
    """Fused serve matvec: encoded scores + x (d_in,) -> y (d_out,) f32.

    ``p``: the (n,) score operand — CLIPPED f32 probabilities
    (``qbits=None``), the codec's uint words (``qbits=b``), or with
    ``qpacked`` the (n/wpl,) packed uint32 lane carry.  ``step``
    is the uint32 draw word pinning the mask draw.  Bit-identical to
    ``ops.serve_matvec`` on every impl (the canonical tree) for rows
    [row_offset, row_offset + d_in*d_out).
    """
    _check_layout(spec, row_offset, d_in, d_out)
    w0, nblk, bpw = serve_block_grid(spec, bm, row_offset, d_in * d_out)
    op_len = _lanes_per_window(spec, qbits) if qpacked else spec.window
    operand = (p.astype(jnp.float32) if qbits is None
               else jnp.asarray(p).astype(jnp.uint32))
    return pl.pallas_call(
        functools.partial(_mv_kernel, spec=spec, bm=bm, w0=w0,
                          row_offset=row_offset, d_in=d_in, d_out=d_out,
                          qbits=qbits, qpacked=qpacked),
        grid=(nblk // bpw, bpw),
        in_specs=[
            pl.BlockSpec((op_len,), lambda i, j: (w0 + i,)),
            pl.BlockSpec((1,), lambda i, j: (0,)),
            pl.BlockSpec((d_in,), lambda i, j: (0,)),
        ],
        out_specs=pl.BlockSpec((d_out,), lambda i, j: (0,)),
        out_shape=jax.ShapeDtypeStruct((d_out,), jnp.float32),
        interpret=interpret,
    )(operand, jnp.asarray(step, jnp.uint32).reshape(1),
      x.astype(jnp.float32))


def qz_sample_matmul(spec: QSpec, p, step, X, *, row_offset: int = 0,
                     d_in: int, d_out: int, qbits=None, qpacked=False,
                     bm: int = SERVE_BM, interpret: bool = True):
    """Fused serve matmul: encoded scores + X (B, d_in) -> (B, d_out).

    The batch rides in-block as extra rows of the x-segment selection
    (the same K-columns-for-free trade as the batched reconstruct
    kernels); grid, draws, and tile tree are identical to the matvec.
    """
    _check_layout(spec, row_offset, d_in, d_out)
    w0, nblk, bpw = serve_block_grid(spec, bm, row_offset, d_in * d_out)
    B = X.shape[0]
    op_len = _lanes_per_window(spec, qbits) if qpacked else spec.window
    operand = (p.astype(jnp.float32) if qbits is None
               else jnp.asarray(p).astype(jnp.uint32))
    return pl.pallas_call(
        functools.partial(_mm_kernel, spec=spec, bm=bm, w0=w0,
                          row_offset=row_offset, d_in=d_in, d_out=d_out,
                          qbits=qbits, qpacked=qpacked),
        grid=(nblk // bpw, bpw),
        in_specs=[
            pl.BlockSpec((op_len,), lambda i, j: (w0 + i,)),
            pl.BlockSpec((1,), lambda i, j: (0,)),
            pl.BlockSpec((B, d_in), lambda i, j: (0, 0)),
        ],
        out_specs=pl.BlockSpec((B, d_out), lambda i, j: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, d_out), jnp.float32),
        interpret=interpret,
    )(operand, jnp.asarray(step, jnp.uint32).reshape(1),
      X.astype(jnp.float32))
