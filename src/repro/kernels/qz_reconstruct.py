"""Pallas TPU kernel: materialization-free ``w = Q z`` reconstruction.

TPU-native design (DESIGN.md §3):

 - grid = (num_windows, blocks_per_window); block (i, j) produces ``bm``
   weights whose Q-rows all read from z-window ``i`` — the (window,)
   slice of ``z`` is the only HBM->VMEM traffic besides the output tile.
 - indices/values are *regenerated* inside the kernel from the hash RNG
   (no Q operand at all), so HBM traffic is O(n + m) instead of
   O(m·d) for a materialized sparse Q.
 - the in-window gather ``z[idx]`` is expressed as a one-hot matmul
   ``onehot(idx) @ z_win`` — a (bm·d, window) × (window,) contraction
   that maps onto the MXU instead of relying on VPU dynamic gather
   support.  bm=256, window=512, d=8 ⇒ 4 MiB of one-hot bf16 in VMEM.

The backward ``grad_z = Q^T grad_w`` has two kernels, gated like the
ref path by ``core.transpose_plan.resolve_bwd_path()`` (env
``REPRO_BWD_PLAN``; ``kernels.ops`` dispatches):

 - PLAN (default, ``qz_reconstruct_bwd_plan``): the cached per-spec
   transpose plan re-binned to this grid (``build_block_plan``): cell
   (window i, row-block j, coordinate c) carries the degree-padded
   incoming edges whose source row lies in rows [j·bm, (j+1)·bm) of
   window i, rows stored BLOCK-relative.  The (window·deg) gather of
   grad_w maps onto the same one-hot MXU contraction as the forward —
   ``onehot(src_rows) (window·deg, bm) @ g (bm,)`` — followed by a
   vals-multiply and deg-axis reduction; the plan slab (rows + vals,
   the only extra operands) rides in with its own BlockSpec.  Edge
   order inside a cell follows the plan's ordering contract
   ('canonical' = by source row), but blocks still accumulate over the
   ``j`` grid dimension, so the Pallas plan path is its OWN ordering
   mode: deterministic and exactly reproducible per (spec, bm), and
   ``allclose`` vs the ref plan / scatter paths.
 - SCATTER (oracle, ``qz_reconstruct_bwd``): the transposed one-hot
   contraction ``contrib (bm·d,) @ onehot (bm·d, window)``.

Both accumulate over the ``j`` (inner) grid dimension into the same
z-window output block (revisited-output pattern).

Batched multi-client kernels (``qz_reconstruct_batched_fwd/bwd``):
the federated round simulates K clients per host, each reconstructing
from its own mask ``z^(k)``.  The batched grid is IDENTICAL to the
single-client grid ``(num_windows, blocks_per_window)`` — the client
axis is carried inside the block, never in the grid, so the hash-RNG
indices/values of Q are regenerated once per block instead of K times:

 - input is the transposed z-slab ``Zt (n, K)``; block (i, j) reads the
   ``(window, K)`` slab of window ``i`` — K client columns ride along
   for free in the same DMA;
 - the gather-as-matmul becomes ``onehot (bm·d, window) @ slab
   (window, K)`` so the MXU produces K output columns per pass (the
   single-client kernel wastes 127/128 MXU lanes on a (window,) vector;
   with K clients the same one-hot feeds K lanes);
 - output tile is ``(bm, K)``; the wrapper transposes back to (K, m).

VMEM budget per block at bm=256, window=512, d=8, K=32 (f32):
slab 512·32·4 = 64 KiB, one-hot 256·8·512·4 = 4 MiB, zsel
256·8·32·4 = 256 KiB, out 256·32·4 = 32 KiB — ~4.4 MiB total, well
under the ~16 MiB/core VMEM budget; K up to ~128 fits (one-hot
dominates and is K-independent).  The backward accumulates the
transposed contraction into a ``(window, K)`` grad-z-slab with the
same revisited-output pattern as the single-client kernel.

Fused mask lifecycle (``qz_sample_reconstruct_*`` /
``qz_sample_pack_*``): the paper's mask ``z ~ Bern(f(s))`` is n BITS,
yet the composed pipeline materializes it as an f32 array in HBM three
times per round — the sampling output, the reconstruction input, and
the pre-bitpack upload draw.  The fused kernels take the *probability*
vector ``p = f(s)`` (or the transposed ``(n, K)`` p-slab) and draw
``z`` in-block from the counter-based hash RNG
(``core.sampling.mask_u32``: words ``(seed, tensor_id, MASK_CTR, step,
coord)``), so the mask only ever exists as a ``(window,)`` /
``(window, K)`` VMEM value between the p-window DMA and the one-hot
contraction:

 - ``qz_sample_reconstruct_fwd`` (+``_batched``): p in, ``w = Q
   Bern(p)`` out.  Identical grid/one-hot layout to the composed
   kernels; the only extra operand is the (1,) / (K,) uint32 ``step``
   draw-counter word, and the only extra in-block work is
   window-sized hashing (VPU) overlapping the MXU contraction.  The
   straight-through backward is UNCHANGED (``grad_p = Q^T grad_w``):
   ``ops.sample_reconstruct`` reuses the composed backward kernels, so
   fused and composed gradients are bit-identical by construction.
 - ``qz_sample_pack_fwd`` (+``_batched``): the end-of-round upload
   draw.  p in, ``uint32`` wire lanes out (bit j of lane i is
   coordinate 32i+j, exactly ``comm.bitpack.pack_mask``); one grid
   step per z-window emits ``window/32`` lanes (requires
   ``window % 32 == 0``; smaller windows fall back to the jnp oracle
   in ``ops``).
 - QUANTIZED operand (``qbits``, the downlink codec subsystem): the
   fused forward also accepts the server's b-bit broadcast words
   (``comm.downlink`` ``u8``/``u16``) instead of f32 probabilities —
   the in-block draw becomes the widened-threshold integer compare
   ``(hash >> 8) < q<<(24-b) + (q<<(24-b))//(2^b-1)`` (uint32 shifts +
   one constant divide on the VPU), so the dequantized f32 score
   vector never exists in HBM or VMEM.  Bit-identical to the f32 draw
   on the codec's decoded probabilities (tests/test_downlink.py).

VMEM budget for the fused batched forward at bm=256, window=512, d=8,
K=32 (f32): p-slab 512·32·4 = 64 KiB, in-block z-slab (same shape)
64 KiB, one-hot 256·8·512·4 = 4 MiB, zsel 256·8·32·4 = 256 KiB, out
256·32·4 = 32 KiB — ~4.5 MiB, the one-hot still dominating and
K-independent; K up to ~128 fits in the ~16 MiB/core budget.  Note the
composed pipeline pays the SAME VMEM for the z-slab but also a
``(K, n)`` f32 mask round-trip through HBM (4 bytes/coordinate where
the wire format is 1 bit) plus the straight-through ``p + sg(z - p)``
elementwise pass; fused, the HBM mask traffic is zero.

Bit-exactness contract (tests/test_fused.py): fused ≡ composed
(sample → reconstruct → pack) to EXACT equality, forward and gradient,
on ref and interpret-mode Pallas, single-client, vmap-batched, and the
shard_map federated path — both sides regenerate the identical mask
bits from ``(seed, tensor_id, step, coord)``.

Validated in interpret mode against ``ref.reconstruct_ref`` /
``ref.grad_z_ref`` over shape/dtype sweeps (tests/test_kernels.py) and
against the batched ref path (tests/test_batched.py).
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from ..core.hashrng import bernoulli_u32
from ..core.qspec import QSpec, row_indices, row_values
from ..core.sampling import mask_u32, quant_threshold_u24
from ..core.transpose_plan import build_block_plan

DEFAULT_BM = 256


def _grid_dims(spec: QSpec, bm: int):
    bpw = max(1, math.ceil(spec.rows_per_window / bm))
    return spec.num_windows, bpw, spec.num_windows * bpw * bm  # m_grid


def _block_rows(spec: QSpec, bm: int, *, masked: bool):
    """Regenerate this grid block's Q rows from the hash RNG.

    Returns (idx (bm, d) in-window, vals (bm, d) f32).  With
    ``masked`` (backward kernels), padding rows get zeroed vals so they
    never scatter garbage into grad_z; forward kernels leave them live
    (their garbage weights are sliced off by the wrapper) but they
    still index safely in-window.
    """
    i = pl.program_id(0)  # window id
    j = pl.program_id(1)  # block within window
    rows = i * spec.rows_per_window + j * bm + jax.lax.iota(jnp.int32, bm)
    idx = row_indices(spec, rows)  # (bm, d) in [0, window)
    vals = row_values(spec, rows, dtype=jnp.float32)  # (bm, d)
    if masked:
        live = (rows < spec.m) & (
            jax.lax.iota(jnp.int32, bm) + j * bm < spec.rows_per_window
        )
        vals = vals * live[:, None].astype(jnp.float32)
    return idx, vals


def _onehot(idx, window: int):
    """(bm, d) in-window indices -> (bm*d, window) f32 one-hot — the
    gather-as-matmul encoding shared by all four kernels."""
    flat = idx.reshape(-1, 1)
    return (flat == jax.lax.iota(jnp.int32, window)[None, :]).astype(
        jnp.float32
    )


def _fwd_kernel(z_ref, w_ref, *, spec: QSpec, bm: int, bpw: int):
    idx, vals = _block_rows(spec, bm, masked=False)
    zwin = z_ref[...].astype(jnp.float32)  # (window,)
    # onehot (bm*d, window) @ zwin (window,)
    zsel = jnp.dot(_onehot(idx, spec.window), zwin,
                   preferred_element_type=jnp.float32)
    w_ref[...] = jnp.sum(vals * zsel.reshape(bm, spec.d), axis=-1)


def _bwd_kernel(g_ref, gz_ref, *, spec: QSpec, bm: int, bpw: int):
    @pl.when(pl.program_id(1) == 0)
    def _init():
        gz_ref[...] = jnp.zeros_like(gz_ref)

    idx, vals = _block_rows(spec, bm, masked=True)
    g = g_ref[...].astype(jnp.float32)  # (bm,)
    contrib = (vals * g[:, None]).reshape(bm * spec.d)  # (bm*d,)
    gz_ref[...] += jnp.dot(contrib, _onehot(idx, spec.window),
                           preferred_element_type=jnp.float32)


def qz_reconstruct_fwd(spec: QSpec, z, *, bm: int = DEFAULT_BM,
                       interpret: bool = True):
    """Pallas forward: z (n,) f32 -> w (m,) f32 (flat; caller reshapes)."""
    nw, bpw, m_grid = _grid_dims(spec, bm)
    out = pl.pallas_call(
        functools.partial(_fwd_kernel, spec=spec, bm=bm, bpw=bpw),
        grid=(nw, bpw),
        in_specs=[pl.BlockSpec((spec.window,), lambda i, j: (i,))],
        out_specs=pl.BlockSpec((bm,), lambda i, j: (i * bpw + j,)),
        out_shape=jax.ShapeDtypeStruct((m_grid,), jnp.float32),
        interpret=interpret,
    )(z.astype(jnp.float32))
    # un-pad: rows were laid out per-window with bpw*bm >= rows_per_window
    if bpw * bm != spec.rows_per_window:
        out = out.reshape(nw, bpw * bm)[:, : spec.rows_per_window].reshape(-1)
    return out[: spec.m]


def qz_reconstruct_bwd(spec: QSpec, grad_w, *, bm: int = DEFAULT_BM,
                       interpret: bool = True):
    """Pallas backward: grad_w (m,) -> grad_z (n,) f32."""
    nw, bpw, m_grid = _grid_dims(spec, bm)
    g = grad_w.reshape(-1).astype(jnp.float32)
    g = jnp.pad(g, (0, spec.m_pad - spec.m))
    # re-pad per window to the grid layout
    if bpw * bm != spec.rows_per_window:
        g = g.reshape(nw, spec.rows_per_window)
        g = jnp.pad(g, ((0, 0), (0, bpw * bm - spec.rows_per_window)))
        g = g.reshape(-1)
    return pl.pallas_call(
        functools.partial(_bwd_kernel, spec=spec, bm=bm, bpw=bpw),
        grid=(nw, bpw),
        in_specs=[pl.BlockSpec((bm,), lambda i, j: (i * bpw + j,))],
        out_specs=pl.BlockSpec((spec.window,), lambda i, j: (i,)),
        out_shape=jax.ShapeDtypeStruct((spec.n,), jnp.float32),
        interpret=interpret,
    )(g)


# ---------------------------------------------------------------------------
# Batched multi-client kernels (client axis carried in the block)
# ---------------------------------------------------------------------------

def _bfwd_kernel(zt_ref, w_ref, *, spec: QSpec, bm: int, nclients: int):
    idx, vals = _block_rows(spec, bm, masked=False)
    slab = zt_ref[...].astype(jnp.float32)  # (window, K)
    # one one-hot, K clients: (bm*d, window) @ (window, K) -> (bm*d, K)
    zsel = jnp.dot(_onehot(idx, spec.window), slab,
                   preferred_element_type=jnp.float32)
    w_ref[...] = jnp.sum(
        vals[..., None] * zsel.reshape(bm, spec.d, nclients), axis=1
    )


def _bbwd_kernel(g_ref, gz_ref, *, spec: QSpec, bm: int, nclients: int):
    @pl.when(pl.program_id(1) == 0)
    def _init():
        gz_ref[...] = jnp.zeros_like(gz_ref)

    idx, vals = _block_rows(spec, bm, masked=True)
    g = g_ref[...].astype(jnp.float32)  # (bm, K)
    contrib = (vals[:, :, None] * g[:, None, :]).reshape(
        bm * spec.d, nclients
    )
    gz_ref[...] += jnp.dot(_onehot(idx, spec.window).T, contrib,
                           preferred_element_type=jnp.float32)


def qz_reconstruct_batched_fwd(spec: QSpec, Z, *, bm: int = DEFAULT_BM,
                               interpret: bool = True):
    """Batched Pallas forward: Z (K, n) f32 -> W (K, m) f32 (flat)."""
    nclients = Z.shape[0]
    nw, bpw, m_grid = _grid_dims(spec, bm)
    zt = Z.astype(jnp.float32).T  # (n, K) — window-major slabs
    out = pl.pallas_call(
        functools.partial(_bfwd_kernel, spec=spec, bm=bm, nclients=nclients),
        grid=(nw, bpw),
        in_specs=[pl.BlockSpec((spec.window, nclients), lambda i, j: (i, 0))],
        out_specs=pl.BlockSpec((bm, nclients), lambda i, j: (i * bpw + j, 0)),
        out_shape=jax.ShapeDtypeStruct((m_grid, nclients), jnp.float32),
        interpret=interpret,
    )(zt)
    if bpw * bm != spec.rows_per_window:
        out = out.reshape(nw, bpw * bm, nclients)[
            :, : spec.rows_per_window
        ].reshape(-1, nclients)
    return out[: spec.m].T


def qz_reconstruct_batched_bwd(spec: QSpec, grad_W, *, bm: int = DEFAULT_BM,
                               interpret: bool = True):
    """Batched Pallas backward: grad_W (K, m) -> grad_Z (K, n) f32."""
    nclients = grad_W.shape[0]
    nw, bpw, m_grid = _grid_dims(spec, bm)
    g = grad_W.reshape(nclients, -1).astype(jnp.float32)
    g = jnp.pad(g, ((0, 0), (0, spec.m_pad - spec.m)))
    if bpw * bm != spec.rows_per_window:
        g = g.reshape(nclients, nw, spec.rows_per_window)
        g = jnp.pad(g, ((0, 0), (0, 0),
                        (0, bpw * bm - spec.rows_per_window)))
    gt = g.reshape(nclients, m_grid).T  # (m_grid, K)
    out = pl.pallas_call(
        functools.partial(_bbwd_kernel, spec=spec, bm=bm, nclients=nclients),
        grid=(nw, bpw),
        in_specs=[pl.BlockSpec((bm, nclients), lambda i, j: (i * bpw + j, 0))],
        out_specs=pl.BlockSpec((spec.window, nclients), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((spec.n, nclients), jnp.float32),
        interpret=interpret,
    )(gt)
    return out.T


# ---------------------------------------------------------------------------
# Plan-driven backward: the transpose as an in-block GATHER over the
# cached block plan (see module docstring and core.transpose_plan).
# ---------------------------------------------------------------------------

def _plan_operands(spec: QSpec, bm: int, order: str):
    """Block-plan slabs as jnp constants + their shared BlockSpec."""
    plan = build_block_plan(spec, bm, order)
    bspec = pl.BlockSpec((1, 1, spec.window, plan.deg),
                         lambda i, j: (i, j, 0, 0))
    return jnp.asarray(plan.rows), jnp.asarray(plan.vals), plan.deg, bspec


def _bwd_plan_kernel(g_ref, rows_ref, vals_ref, gz_ref, *, spec: QSpec,
                     bm: int, deg: int):
    @pl.when(pl.program_id(1) == 0)
    def _init():
        gz_ref[...] = jnp.zeros_like(gz_ref)

    rows = rows_ref[...].reshape(spec.window * deg, 1)  # block-relative
    onehot = (rows == jax.lax.iota(jnp.int32, bm)[None, :]).astype(
        jnp.float32
    )
    g = g_ref[...].astype(jnp.float32)  # (bm,)
    # the (window·deg) gather as the one-hot MXU contraction
    gsel = jnp.dot(onehot, g, preferred_element_type=jnp.float32)
    vals = vals_ref[...].reshape(spec.window, deg)
    gz_ref[...] += jnp.sum(vals * gsel.reshape(spec.window, deg), axis=-1)


def qz_reconstruct_bwd_plan(spec: QSpec, grad_w, *, bm: int = DEFAULT_BM,
                            interpret: bool = True,
                            order: str = "canonical"):
    """Plan-driven Pallas backward: grad_w (m,) -> grad_z (n,) f32."""
    nw, bpw, m_grid = _grid_dims(spec, bm)
    rows, vals, deg, bspec = _plan_operands(spec, bm, order)
    g = grad_w.reshape(-1).astype(jnp.float32)
    g = jnp.pad(g, (0, spec.m_pad - spec.m))
    if bpw * bm != spec.rows_per_window:
        g = g.reshape(nw, spec.rows_per_window)
        g = jnp.pad(g, ((0, 0), (0, bpw * bm - spec.rows_per_window)))
        g = g.reshape(-1)
    return pl.pallas_call(
        functools.partial(_bwd_plan_kernel, spec=spec, bm=bm, deg=deg),
        grid=(nw, bpw),
        in_specs=[
            pl.BlockSpec((bm,), lambda i, j: (i * bpw + j,)),
            bspec, bspec,
        ],
        out_specs=pl.BlockSpec((spec.window,), lambda i, j: (i,)),
        out_shape=jax.ShapeDtypeStruct((spec.n,), jnp.float32),
        interpret=interpret,
    )(g, rows, vals)


def _bbwd_plan_kernel(g_ref, rows_ref, vals_ref, gz_ref, *, spec: QSpec,
                      bm: int, deg: int, nclients: int):
    @pl.when(pl.program_id(1) == 0)
    def _init():
        gz_ref[...] = jnp.zeros_like(gz_ref)

    rows = rows_ref[...].reshape(spec.window * deg, 1)
    onehot = (rows == jax.lax.iota(jnp.int32, bm)[None, :]).astype(
        jnp.float32
    )
    g = g_ref[...].astype(jnp.float32)  # (bm, K)
    # one one-hot, K clients: (window·deg, bm) @ (bm, K)
    gsel = jnp.dot(onehot, g, preferred_element_type=jnp.float32)
    vals = vals_ref[...].reshape(spec.window, deg)
    gz_ref[...] += jnp.sum(
        vals[:, :, None] * gsel.reshape(spec.window, deg, nclients), axis=1
    )


def qz_reconstruct_batched_bwd_plan(spec: QSpec, grad_W, *,
                                    bm: int = DEFAULT_BM,
                                    interpret: bool = True,
                                    order: str = "canonical"):
    """Plan-driven batched backward: grad_W (K, m) -> grad_Z (K, n)."""
    nclients = grad_W.shape[0]
    nw, bpw, m_grid = _grid_dims(spec, bm)
    rows, vals, deg, bspec = _plan_operands(spec, bm, order)
    g = grad_W.reshape(nclients, -1).astype(jnp.float32)
    g = jnp.pad(g, ((0, 0), (0, spec.m_pad - spec.m)))
    if bpw * bm != spec.rows_per_window:
        g = g.reshape(nclients, nw, spec.rows_per_window)
        g = jnp.pad(g, ((0, 0), (0, 0),
                        (0, bpw * bm - spec.rows_per_window)))
    gt = g.reshape(nclients, m_grid).T  # (m_grid, K)
    out = pl.pallas_call(
        functools.partial(_bbwd_plan_kernel, spec=spec, bm=bm, deg=deg,
                          nclients=nclients),
        grid=(nw, bpw),
        in_specs=[
            pl.BlockSpec((bm, nclients), lambda i, j: (i * bpw + j, 0)),
            bspec, bspec,
        ],
        out_specs=pl.BlockSpec((spec.window, nclients), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((spec.n, nclients), jnp.float32),
        interpret=interpret,
    )(gt, rows, vals)
    return out.T


# ---------------------------------------------------------------------------
# Fused mask lifecycle: probabilities in, weights / wire lanes out.
# The mask z is a transient in-block value, never an HBM array.
# ---------------------------------------------------------------------------

def _lanes_per_window(spec: QSpec, qbits: int) -> int:
    """Packed-operand block length: uint32 lanes per z-window.  The
    packed fused path needs whole lanes per window (lane i covers
    coordinates [i·wpl, (i+1)·wpl)), i.e. ``window % floor(32/b) == 0``
    — true for every power-of-two b at the standard window sizes;
    ``ops`` falls back to the unpack oracle otherwise."""
    wpl = 32 // qbits
    if spec.window % wpl != 0:
        raise ValueError(
            f"packed fused kernel needs window % (32//qbits) == 0; got "
            f"window={spec.window}, qbits={qbits} (wpl={wpl})"
        )
    return spec.window // wpl


def _unpack_window(spec: QSpec, lanes, qbits: int):
    """In-block lane unpack: (window/wpl,) [or (window/wpl, K)] uint32
    lanes -> (window,) [or (window, K)] b-bit words — a VMEM-local
    shift/mask, so the per-coordinate word array only ever exists as
    this window-sized transient, never as an (n,) slab in HBM
    (jaxpr-asserted in tests/test_packed_downlink.py)."""
    wpl = 32 // qbits
    mask = np.uint32((1 << qbits) - 1)
    sh = np.uint32(qbits) * jax.lax.iota(jnp.uint32, wpl)
    if lanes.ndim == 2:  # (window/wpl, K) lane slab
        words = (lanes[:, None, :] >> sh[None, :, None]) & mask
        return words.reshape(spec.window, lanes.shape[-1])
    words = (lanes[:, None] >> sh[None, :]) & mask
    return words.reshape(spec.window)


def _window_mask(spec: QSpec, step, p_win, qbits=None, qpacked=False):
    """Draw this grid step's z-window in-block from the hash RNG.

    ``step`` is the traced uint32 draw-counter word; coordinates are
    the window's global z indices, so the bits are identical to the
    oracle's ``sample_mask_hash`` over the full (n,) vector.

    With ``qbits`` the operand is the QUANTIZED probability window
    (uint32 b-bit words from the downlink codec, ``comm.downlink``)
    and the draw is the widened-threshold integer compare
    ``(u >> 8) < quant_threshold_u24(q)`` — pure uint32 shifts and a
    constant divide, no dequantized f32 probabilities even in-block —
    bit-identical to the oracle's ``sample_mask_qhash``.  With
    ``qpacked`` the operand window is the packed uint32 LANES of the
    sub-byte codecs (``comm.bitpack.pack_words`` layout) and the words
    are unpacked in-block first (``_unpack_window``).
    """
    if qpacked:
        p_win = _unpack_window(spec, p_win, qbits)
    i = pl.program_id(0)
    coords = i * spec.window + jax.lax.iota(jnp.int32, spec.window)
    if p_win.ndim == 2:  # (window, K) p-slab: one stream per client
        u = mask_u32(spec.seed, spec.tensor_id, step[None, :],
                     coords[:, None])
    else:
        u = mask_u32(spec.seed, spec.tensor_id, step, coords)
    if qbits is None:
        return bernoulli_u32(u, p_win.astype(jnp.float32))
    thr = quant_threshold_u24(p_win, qbits)
    return ((u >> np.uint32(8)) < thr).astype(jnp.float32)


def _sfwd_kernel(p_ref, step_ref, w_ref, *, spec: QSpec, bm: int, bpw: int,
                 qbits=None, qpacked=False):
    idx, vals = _block_rows(spec, bm, masked=False)
    zwin = _window_mask(spec, step_ref[0], p_ref[...], qbits=qbits,
                        qpacked=qpacked)
    zsel = jnp.dot(_onehot(idx, spec.window), zwin,
                   preferred_element_type=jnp.float32)
    w_ref[...] = jnp.sum(vals * zsel.reshape(bm, spec.d), axis=-1)


def qz_sample_reconstruct_fwd(spec: QSpec, p, step, *, bm: int = DEFAULT_BM,
                              interpret: bool = True, qbits=None,
                              qpacked=False):
    """Fused Pallas forward: p (n,) f32 + step word -> w (m,) f32 (flat).

    With ``qbits`` the operand is the quantized broadcast (b-bit
    probability words, shipped into the kernel as uint32) and the
    in-block draw is the widened-threshold integer compare — the
    dequantized f32 score vector never exists, in HBM or VMEM.  With
    ``qpacked`` the operand is the (n/wpl,) packed uint32 LANE carry of
    the sub-byte codecs and each grid step streams ``window/wpl`` whole
    lanes, unpacking in-block — the per-coordinate word array never
    materializes outside a window-sized VMEM transient.
    """
    nw, bpw, m_grid = _grid_dims(spec, bm)
    op_len = _lanes_per_window(spec, qbits) if qpacked else spec.window
    operand = (p.astype(jnp.float32) if qbits is None
               else jnp.asarray(p).astype(jnp.uint32))
    out = pl.pallas_call(
        functools.partial(_sfwd_kernel, spec=spec, bm=bm, bpw=bpw,
                          qbits=qbits, qpacked=qpacked),
        grid=(nw, bpw),
        in_specs=[
            pl.BlockSpec((op_len,), lambda i, j: (i,)),
            pl.BlockSpec((1,), lambda i, j: (0,)),
        ],
        out_specs=pl.BlockSpec((bm,), lambda i, j: (i * bpw + j,)),
        out_shape=jax.ShapeDtypeStruct((m_grid,), jnp.float32),
        interpret=interpret,
    )(operand, jnp.asarray(step, jnp.uint32).reshape(1))
    if bpw * bm != spec.rows_per_window:
        out = out.reshape(nw, bpw * bm)[:, : spec.rows_per_window].reshape(-1)
    return out[: spec.m]


def _sbfwd_kernel(pt_ref, steps_ref, w_ref, *, spec: QSpec, bm: int,
                  nclients: int, qbits=None, qpacked=False):
    idx, vals = _block_rows(spec, bm, masked=False)
    slab = _window_mask(spec, steps_ref[...], pt_ref[...],
                        qbits=qbits, qpacked=qpacked)  # (window, K)
    zsel = jnp.dot(_onehot(idx, spec.window), slab,
                   preferred_element_type=jnp.float32)
    w_ref[...] = jnp.sum(
        vals[..., None] * zsel.reshape(bm, spec.d, nclients), axis=1
    )


def qz_sample_reconstruct_batched_fwd(spec: QSpec, P, steps, *,
                                      bm: int = DEFAULT_BM,
                                      interpret: bool = True, qbits=None,
                                      qpacked=False):
    """Fused batched forward: P (K, n) probs + steps (K,) -> W (K, m).

    ``qbits``/``qpacked``: as ``qz_sample_reconstruct_fwd`` — P is the
    (K, n) quantized word slab (or the (K, n/wpl) packed lane slab) and
    the draw stays integer in-block.
    """
    nclients = P.shape[0]
    nw, bpw, m_grid = _grid_dims(spec, bm)
    op_len = _lanes_per_window(spec, qbits) if qpacked else spec.window
    if qbits is None:
        pt = P.astype(jnp.float32).T  # (n, K) — window-major p-slabs
    else:
        pt = jnp.asarray(P).astype(jnp.uint32).T
    out = pl.pallas_call(
        functools.partial(_sbfwd_kernel, spec=spec, bm=bm,
                          nclients=nclients, qbits=qbits, qpacked=qpacked),
        grid=(nw, bpw),
        in_specs=[
            pl.BlockSpec((op_len, nclients), lambda i, j: (i, 0)),
            pl.BlockSpec((nclients,), lambda i, j: (0,)),
        ],
        out_specs=pl.BlockSpec((bm, nclients), lambda i, j: (i * bpw + j, 0)),
        out_shape=jax.ShapeDtypeStruct((m_grid, nclients), jnp.float32),
        interpret=interpret,
    )(pt, jnp.asarray(steps, jnp.uint32))
    if bpw * bm != spec.rows_per_window:
        out = out.reshape(nw, bpw * bm, nclients)[
            :, : spec.rows_per_window
        ].reshape(-1, nclients)
    return out[: spec.m].T


def _pack_shifts():
    return jax.lax.iota(jnp.uint32, 32)


def _spack_kernel(p_ref, step_ref, lanes_ref, *, spec: QSpec):
    zwin = _window_mask(spec, step_ref[0], p_ref[...].astype(jnp.float32))
    bits = zwin.astype(jnp.uint32).reshape(spec.window // 32, 32)
    lanes_ref[...] = jnp.sum(bits << _pack_shifts(), axis=-1,
                             dtype=jnp.uint32)


def qz_sample_pack_fwd(spec: QSpec, p, step, *, interpret: bool = True):
    """Fused upload draw: p (n,) -> (n/32,) uint32 wire lanes.

    Lane layout is exactly ``comm.bitpack.pack_mask`` (bit j of lane i
    = coordinate 32i+j).  Requires ``spec.window % 32 == 0`` so each
    grid step emits whole lanes (``ops.sample_pack`` falls back to the
    jnp oracle otherwise).
    """
    assert spec.window % 32 == 0, "pallas sample_pack needs window % 32 == 0"
    out = pl.pallas_call(
        functools.partial(_spack_kernel, spec=spec),
        grid=(spec.num_windows,),
        in_specs=[
            pl.BlockSpec((spec.window,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((spec.window // 32,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((spec.n // 32,), jnp.uint32),
        interpret=interpret,
    )(p.astype(jnp.float32), jnp.asarray(step, jnp.uint32).reshape(1))
    return out


def _sbpack_kernel(pt_ref, steps_ref, lanes_ref, *, spec: QSpec,
                   nclients: int):
    slab = _window_mask(spec, steps_ref[...],
                        pt_ref[...].astype(jnp.float32))  # (window, K)
    bits = slab.astype(jnp.uint32).reshape(spec.window // 32, 32, nclients)
    lanes_ref[...] = jnp.sum(bits << _pack_shifts()[None, :, None], axis=1,
                             dtype=jnp.uint32)


def qz_sample_pack_batched_fwd(spec: QSpec, P, steps, *,
                               interpret: bool = True):
    """Fused batched upload draw: P (K, n) -> (K, n/32) uint32 lanes."""
    assert spec.window % 32 == 0, "pallas sample_pack needs window % 32 == 0"
    nclients = P.shape[0]
    pt = P.astype(jnp.float32).T  # (n, K)
    out = pl.pallas_call(
        functools.partial(_sbpack_kernel, spec=spec, nclients=nclients),
        grid=(spec.num_windows,),
        in_specs=[
            pl.BlockSpec((spec.window, nclients), lambda i: (i, 0)),
            pl.BlockSpec((nclients,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((spec.window // 32, nclients),
                               lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((spec.n // 32, nclients), jnp.uint32),
        interpret=interpret,
    )(pt, jnp.asarray(steps, jnp.uint32))
    return out.T
