"""Pallas TPU kernel: materialization-free ``w = Q z`` reconstruction.

TPU-native design (DESIGN.md §3):

 - grid = (num_windows, blocks_per_window); block (i, j) produces ``bm``
   weights whose Q-rows all read from z-window ``i`` — the (window,)
   slice of ``z`` is the only HBM->VMEM traffic besides the output tile.
 - indices/values are *regenerated* inside the kernel from the hash RNG
   (no Q operand at all), so HBM traffic is O(n + m) instead of
   O(m·d) for a materialized sparse Q.
 - the in-window gather ``z[idx]`` is expressed as a one-hot matmul
   ``onehot(idx) @ z_win`` — a (bm·d, window) × (window,) contraction
   that maps onto the MXU instead of relying on VPU dynamic gather
   support.  bm=256, window=512, d=8 ⇒ 4 MiB of one-hot bf16 in VMEM.

The backward kernel computes ``grad_z = Q^T grad_w`` with the transposed
one-hot contraction, accumulating over the ``j`` (inner) grid dimension
into the same z-window output block (revisited-output pattern).

Validated in interpret mode against ``ref.reconstruct_ref`` /
``ref.grad_z_ref`` over shape/dtype sweeps (tests/test_kernels.py).
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..core.qspec import QSpec, row_indices, row_values

DEFAULT_BM = 256


def _grid_dims(spec: QSpec, bm: int):
    bpw = max(1, math.ceil(spec.rows_per_window / bm))
    return spec.num_windows, bpw, spec.num_windows * bpw * bm  # m_grid


def _fwd_kernel(z_ref, w_ref, *, spec: QSpec, bm: int, bpw: int):
    i = pl.program_id(0)  # window id
    j = pl.program_id(1)  # block within window
    row0 = i * spec.rows_per_window + j * bm
    rows = row0 + jax.lax.iota(jnp.int32, bm)
    # Rows past this window's span (padding) contribute garbage weights
    # that the wrapper slices off; they still index safely in-window.
    idx = row_indices(spec, rows)  # (bm, d) in [0, window)
    vals = row_values(spec, rows, dtype=jnp.float32)  # (bm, d)
    zwin = z_ref[...].astype(jnp.float32)  # (window,)
    # gather-as-matmul: onehot (bm*d, window) @ zwin (window,)
    onehot = (
        idx.reshape(bm * spec.d, 1)
        == jax.lax.iota(jnp.int32, spec.window)[None, :]
    ).astype(jnp.float32)
    zsel = jnp.dot(onehot, zwin, preferred_element_type=jnp.float32)
    w_ref[...] = jnp.sum(vals * zsel.reshape(bm, spec.d), axis=-1)


def _bwd_kernel(g_ref, gz_ref, *, spec: QSpec, bm: int, bpw: int):
    i = pl.program_id(0)
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        gz_ref[...] = jnp.zeros_like(gz_ref)

    row0 = i * spec.rows_per_window + j * bm
    rows = row0 + jax.lax.iota(jnp.int32, bm)
    # padding rows must not scatter garbage into grad_z: zero their vals
    live = (rows < spec.m) & (
        jax.lax.iota(jnp.int32, bm) + j * bm < spec.rows_per_window
    )
    idx = row_indices(spec, rows)
    vals = row_values(spec, rows, dtype=jnp.float32)
    vals = vals * live[:, None].astype(jnp.float32)
    g = g_ref[...].astype(jnp.float32)  # (bm,)
    contrib = (vals * g[:, None]).reshape(bm * spec.d)  # (bm*d,)
    onehot = (
        idx.reshape(bm * spec.d, 1)
        == jax.lax.iota(jnp.int32, spec.window)[None, :]
    ).astype(jnp.float32)
    gz_ref[...] += jnp.dot(contrib, onehot, preferred_element_type=jnp.float32)


def qz_reconstruct_fwd(spec: QSpec, z, *, bm: int = DEFAULT_BM,
                       interpret: bool = True):
    """Pallas forward: z (n,) f32 -> w (m,) f32 (flat; caller reshapes)."""
    nw, bpw, m_grid = _grid_dims(spec, bm)
    out = pl.pallas_call(
        functools.partial(_fwd_kernel, spec=spec, bm=bm, bpw=bpw),
        grid=(nw, bpw),
        in_specs=[pl.BlockSpec((spec.window,), lambda i, j: (i,))],
        out_specs=pl.BlockSpec((bm,), lambda i, j: (i * bpw + j,)),
        out_shape=jax.ShapeDtypeStruct((m_grid,), jnp.float32),
        interpret=interpret,
    )(z.astype(jnp.float32))
    # un-pad: rows were laid out per-window with bpw*bm >= rows_per_window
    if bpw * bm != spec.rows_per_window:
        out = out.reshape(nw, bpw * bm)[:, : spec.rows_per_window].reshape(-1)
    return out[: spec.m]


def qz_reconstruct_bwd(spec: QSpec, grad_w, *, bm: int = DEFAULT_BM,
                       interpret: bool = True):
    """Pallas backward: grad_w (m,) -> grad_z (n,) f32."""
    nw, bpw, m_grid = _grid_dims(spec, bm)
    g = grad_w.reshape(-1).astype(jnp.float32)
    g = jnp.pad(g, (0, spec.m_pad - spec.m))
    # re-pad per window to the grid layout
    if bpw * bm != spec.rows_per_window:
        g = g.reshape(nw, spec.rows_per_window)
        g = jnp.pad(g, ((0, 0), (0, bpw * bm - spec.rows_per_window)))
        g = g.reshape(-1)
    return pl.pallas_call(
        functools.partial(_bwd_kernel, spec=spec, bm=bm, bpw=bpw),
        grid=(nw, bpw),
        in_specs=[pl.BlockSpec((bm,), lambda i, j: (i * bpw + j,))],
        out_specs=pl.BlockSpec((spec.window,), lambda i, j: (i,)),
        out_shape=jax.ShapeDtypeStruct((spec.n,), jnp.float32),
        interpret=interpret,
    )(g)
