"""Pallas TPU kernel: materialization-free ``w = Q z`` reconstruction.

TPU-native design (DESIGN.md §3):

 - grid = (num_windows, blocks_per_window); block (i, j) produces ``bm``
   weights whose Q-rows all read from z-window ``i`` — the (window,)
   slice of ``z`` is the only HBM->VMEM traffic besides the output tile.
 - indices/values are *regenerated* inside the kernel from the hash RNG
   (no Q operand at all), so HBM traffic is O(n + m) instead of
   O(m·d) for a materialized sparse Q.
 - the in-window gather ``z[idx]`` is expressed as a one-hot matmul
   ``onehot(idx) @ z_win`` — a (bm·d, window) × (window,) contraction
   that maps onto the MXU instead of relying on VPU dynamic gather
   support.  bm=256, window=512, d=8 ⇒ 4 MiB of one-hot bf16 in VMEM.

The backward kernel computes ``grad_z = Q^T grad_w`` with the transposed
one-hot contraction, accumulating over the ``j`` (inner) grid dimension
into the same z-window output block (revisited-output pattern).

Batched multi-client kernels (``qz_reconstruct_batched_fwd/bwd``):
the federated round simulates K clients per host, each reconstructing
from its own mask ``z^(k)``.  The batched grid is IDENTICAL to the
single-client grid ``(num_windows, blocks_per_window)`` — the client
axis is carried inside the block, never in the grid, so the hash-RNG
indices/values of Q are regenerated once per block instead of K times:

 - input is the transposed z-slab ``Zt (n, K)``; block (i, j) reads the
   ``(window, K)`` slab of window ``i`` — K client columns ride along
   for free in the same DMA;
 - the gather-as-matmul becomes ``onehot (bm·d, window) @ slab
   (window, K)`` so the MXU produces K output columns per pass (the
   single-client kernel wastes 127/128 MXU lanes on a (window,) vector;
   with K clients the same one-hot feeds K lanes);
 - output tile is ``(bm, K)``; the wrapper transposes back to (K, m).

VMEM budget per block at bm=256, window=512, d=8, K=32 (f32):
slab 512·32·4 = 64 KiB, one-hot 256·8·512·4 = 4 MiB, zsel
256·8·32·4 = 256 KiB, out 256·32·4 = 32 KiB — ~4.4 MiB total, well
under the ~16 MiB/core VMEM budget; K up to ~128 fits (one-hot
dominates and is K-independent).  The backward accumulates the
transposed contraction into a ``(window, K)`` grad-z-slab with the
same revisited-output pattern as the single-client kernel.

Validated in interpret mode against ``ref.reconstruct_ref`` /
``ref.grad_z_ref`` over shape/dtype sweeps (tests/test_kernels.py) and
against the batched ref path (tests/test_batched.py).
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..core.qspec import QSpec, row_indices, row_values

DEFAULT_BM = 256


def _grid_dims(spec: QSpec, bm: int):
    bpw = max(1, math.ceil(spec.rows_per_window / bm))
    return spec.num_windows, bpw, spec.num_windows * bpw * bm  # m_grid


def _block_rows(spec: QSpec, bm: int, *, masked: bool):
    """Regenerate this grid block's Q rows from the hash RNG.

    Returns (idx (bm, d) in-window, vals (bm, d) f32).  With
    ``masked`` (backward kernels), padding rows get zeroed vals so they
    never scatter garbage into grad_z; forward kernels leave them live
    (their garbage weights are sliced off by the wrapper) but they
    still index safely in-window.
    """
    i = pl.program_id(0)  # window id
    j = pl.program_id(1)  # block within window
    rows = i * spec.rows_per_window + j * bm + jax.lax.iota(jnp.int32, bm)
    idx = row_indices(spec, rows)  # (bm, d) in [0, window)
    vals = row_values(spec, rows, dtype=jnp.float32)  # (bm, d)
    if masked:
        live = (rows < spec.m) & (
            jax.lax.iota(jnp.int32, bm) + j * bm < spec.rows_per_window
        )
        vals = vals * live[:, None].astype(jnp.float32)
    return idx, vals


def _onehot(idx, window: int):
    """(bm, d) in-window indices -> (bm*d, window) f32 one-hot — the
    gather-as-matmul encoding shared by all four kernels."""
    flat = idx.reshape(-1, 1)
    return (flat == jax.lax.iota(jnp.int32, window)[None, :]).astype(
        jnp.float32
    )


def _fwd_kernel(z_ref, w_ref, *, spec: QSpec, bm: int, bpw: int):
    idx, vals = _block_rows(spec, bm, masked=False)
    zwin = z_ref[...].astype(jnp.float32)  # (window,)
    # onehot (bm*d, window) @ zwin (window,)
    zsel = jnp.dot(_onehot(idx, spec.window), zwin,
                   preferred_element_type=jnp.float32)
    w_ref[...] = jnp.sum(vals * zsel.reshape(bm, spec.d), axis=-1)


def _bwd_kernel(g_ref, gz_ref, *, spec: QSpec, bm: int, bpw: int):
    @pl.when(pl.program_id(1) == 0)
    def _init():
        gz_ref[...] = jnp.zeros_like(gz_ref)

    idx, vals = _block_rows(spec, bm, masked=True)
    g = g_ref[...].astype(jnp.float32)  # (bm,)
    contrib = (vals * g[:, None]).reshape(bm * spec.d)  # (bm*d,)
    gz_ref[...] += jnp.dot(contrib, _onehot(idx, spec.window),
                           preferred_element_type=jnp.float32)


def qz_reconstruct_fwd(spec: QSpec, z, *, bm: int = DEFAULT_BM,
                       interpret: bool = True):
    """Pallas forward: z (n,) f32 -> w (m,) f32 (flat; caller reshapes)."""
    nw, bpw, m_grid = _grid_dims(spec, bm)
    out = pl.pallas_call(
        functools.partial(_fwd_kernel, spec=spec, bm=bm, bpw=bpw),
        grid=(nw, bpw),
        in_specs=[pl.BlockSpec((spec.window,), lambda i, j: (i,))],
        out_specs=pl.BlockSpec((bm,), lambda i, j: (i * bpw + j,)),
        out_shape=jax.ShapeDtypeStruct((m_grid,), jnp.float32),
        interpret=interpret,
    )(z.astype(jnp.float32))
    # un-pad: rows were laid out per-window with bpw*bm >= rows_per_window
    if bpw * bm != spec.rows_per_window:
        out = out.reshape(nw, bpw * bm)[:, : spec.rows_per_window].reshape(-1)
    return out[: spec.m]


def qz_reconstruct_bwd(spec: QSpec, grad_w, *, bm: int = DEFAULT_BM,
                       interpret: bool = True):
    """Pallas backward: grad_w (m,) -> grad_z (n,) f32."""
    nw, bpw, m_grid = _grid_dims(spec, bm)
    g = grad_w.reshape(-1).astype(jnp.float32)
    g = jnp.pad(g, (0, spec.m_pad - spec.m))
    # re-pad per window to the grid layout
    if bpw * bm != spec.rows_per_window:
        g = g.reshape(nw, spec.rows_per_window)
        g = jnp.pad(g, ((0, 0), (0, bpw * bm - spec.rows_per_window)))
        g = g.reshape(-1)
    return pl.pallas_call(
        functools.partial(_bwd_kernel, spec=spec, bm=bm, bpw=bpw),
        grid=(nw, bpw),
        in_specs=[pl.BlockSpec((bm,), lambda i, j: (i * bpw + j,))],
        out_specs=pl.BlockSpec((spec.window,), lambda i, j: (i,)),
        out_shape=jax.ShapeDtypeStruct((spec.n,), jnp.float32),
        interpret=interpret,
    )(g)


# ---------------------------------------------------------------------------
# Batched multi-client kernels (client axis carried in the block)
# ---------------------------------------------------------------------------

def _bfwd_kernel(zt_ref, w_ref, *, spec: QSpec, bm: int, nclients: int):
    idx, vals = _block_rows(spec, bm, masked=False)
    slab = zt_ref[...].astype(jnp.float32)  # (window, K)
    # one one-hot, K clients: (bm*d, window) @ (window, K) -> (bm*d, K)
    zsel = jnp.dot(_onehot(idx, spec.window), slab,
                   preferred_element_type=jnp.float32)
    w_ref[...] = jnp.sum(
        vals[..., None] * zsel.reshape(bm, spec.d, nclients), axis=1
    )


def _bbwd_kernel(g_ref, gz_ref, *, spec: QSpec, bm: int, nclients: int):
    @pl.when(pl.program_id(1) == 0)
    def _init():
        gz_ref[...] = jnp.zeros_like(gz_ref)

    idx, vals = _block_rows(spec, bm, masked=True)
    g = g_ref[...].astype(jnp.float32)  # (bm, K)
    contrib = (vals[:, :, None] * g[:, None, :]).reshape(
        bm * spec.d, nclients
    )
    gz_ref[...] += jnp.dot(_onehot(idx, spec.window).T, contrib,
                           preferred_element_type=jnp.float32)


def qz_reconstruct_batched_fwd(spec: QSpec, Z, *, bm: int = DEFAULT_BM,
                               interpret: bool = True):
    """Batched Pallas forward: Z (K, n) f32 -> W (K, m) f32 (flat)."""
    nclients = Z.shape[0]
    nw, bpw, m_grid = _grid_dims(spec, bm)
    zt = Z.astype(jnp.float32).T  # (n, K) — window-major slabs
    out = pl.pallas_call(
        functools.partial(_bfwd_kernel, spec=spec, bm=bm, nclients=nclients),
        grid=(nw, bpw),
        in_specs=[pl.BlockSpec((spec.window, nclients), lambda i, j: (i, 0))],
        out_specs=pl.BlockSpec((bm, nclients), lambda i, j: (i * bpw + j, 0)),
        out_shape=jax.ShapeDtypeStruct((m_grid, nclients), jnp.float32),
        interpret=interpret,
    )(zt)
    if bpw * bm != spec.rows_per_window:
        out = out.reshape(nw, bpw * bm, nclients)[
            :, : spec.rows_per_window
        ].reshape(-1, nclients)
    return out[: spec.m].T


def qz_reconstruct_batched_bwd(spec: QSpec, grad_W, *, bm: int = DEFAULT_BM,
                               interpret: bool = True):
    """Batched Pallas backward: grad_W (K, m) -> grad_Z (K, n) f32."""
    nclients = grad_W.shape[0]
    nw, bpw, m_grid = _grid_dims(spec, bm)
    g = grad_W.reshape(nclients, -1).astype(jnp.float32)
    g = jnp.pad(g, ((0, 0), (0, spec.m_pad - spec.m)))
    if bpw * bm != spec.rows_per_window:
        g = g.reshape(nclients, nw, spec.rows_per_window)
        g = jnp.pad(g, ((0, 0), (0, 0),
                        (0, bpw * bm - spec.rows_per_window)))
    gt = g.reshape(nclients, m_grid).T  # (m_grid, K)
    out = pl.pallas_call(
        functools.partial(_bbwd_kernel, spec=spec, bm=bm, nclients=nclients),
        grid=(nw, bpw),
        in_specs=[pl.BlockSpec((bm, nclients), lambda i, j: (i * bpw + j, 0))],
        out_specs=pl.BlockSpec((spec.window, nclients), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((spec.n, nclients), jnp.float32),
        interpret=interpret,
    )(gt)
    return out.T
