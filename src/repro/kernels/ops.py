"""jit'd public wrappers around the reconstruction kernels.

``reconstruct(spec, z)`` is THE hot op of the paper's technique: every
training/serving step turns the sampled mask ``z`` back into weights.
Dispatch:

 - impl='ref'     pure-jnp oracle (default on CPU)
 - impl='pallas'  the Pallas TPU kernel (interpret=True on CPU;
                  single-block layout, shard_count == 1)
 - distributed    when the spec carries shard_count > 1 and a mesh is
                  active, the manually-partitioned shard_map op emits
                  the tensor directly in consumer sharding
                  (kernels.qz_sharded — zero collectives)
 - chunks>1       lax.map over row-chunks of the ref path (bounds the
                  O(m·d) temporaries on a single host)

A ``jax.custom_vjp`` ties forward and backward together so both
directions use the same impl and the straight-through chain
``grad_s = Q^T grad_w ⊙ 1_{0<p<1}`` (paper §1.3) falls out of autodiff.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from ..core.qspec import QSpec, padded_row_window, row_indices, row_values
from ..core.reconstruct import _select_valid, _unmove, grad_z_ref, reconstruct_ref
from . import qz_reconstruct as _pk

_DEFAULT_IMPL = "ref"


def set_default_impl(impl: str) -> None:
    global _DEFAULT_IMPL
    assert impl in ("ref", "pallas")
    _DEFAULT_IMPL = impl


def _ref_chunked(spec: QSpec, z, chunks: int):
    """Row-chunked padded rows: temporaries bounded to m_pad/chunks."""
    rpc = -(-spec.m_pad // chunks) // 8 * 8 or spec.m_pad  # multiple of 8
    chunks = -(-spec.m_pad // rpc)
    zf = z.astype(jnp.float32)

    def one(c):
        rp = c * rpc + jnp.arange(rpc, dtype=jnp.int32)
        rp = jnp.minimum(rp, spec.m_pad - 1)
        win = padded_row_window(spec, rp)
        idx = row_indices(spec, rp.astype(jnp.uint32))
        vals = row_values(spec, rp.astype(jnp.uint32), dtype=jnp.float32)
        gidx = win[:, None] * spec.window + idx
        return jnp.sum(vals * jnp.take(zf, gidx, axis=0), axis=-1)

    w_pad = jax.lax.map(one, jnp.arange(chunks)).reshape(-1)[: spec.m_pad]
    return _unmove(spec, _select_valid(spec, w_pad))


@functools.partial(jax.custom_vjp, nondiff_argnums=(0, 2, 3, 4))
def _reconstruct(spec: QSpec, z, impl: str, chunks: int, model_size):
    if model_size is not None and spec.shard_count > 1:
        from .qz_sharded import sharded_reconstruct

        return sharded_reconstruct(spec, z, model_size)
    if impl == "pallas":
        assert spec.shard_count == 1, "pallas path is single-block layout"
        return _pk.qz_reconstruct_fwd(spec, z).reshape(spec.shape)
    if chunks > 1:
        return _ref_chunked(spec, z, chunks)
    return reconstruct_ref(spec, z, dtype=jnp.float32)


def _fwd(spec, z, impl, chunks, model_size):
    return _reconstruct(spec, z, impl, chunks, model_size), None


def _bwd(spec, impl, chunks, model_size, _res, g):
    if model_size is not None and spec.shard_count > 1:
        from .qz_sharded import sharded_grad_z

        return (sharded_grad_z(spec, g.astype(jnp.float32), model_size),)
    if impl == "pallas":
        return (_pk.qz_reconstruct_bwd(spec, g.reshape(-1)),)
    return (grad_z_ref(spec, g),)


_reconstruct.defvjp(_fwd, _bwd)


def reconstruct(spec: QSpec, z, *, dtype=jnp.float32, chunks: int = 1,
                impl: Optional[str] = None, model_size: Optional[int] = None,
                row_sharding=None):
    """w = Q z, returned with ``spec.shape`` and ``dtype``.

    ``model_size``: size of the 'model' mesh axis — activates the
    distributed op when the spec was built with shard_count > 1.
    (``row_sharding`` kept for API compat; its mesh provides model_size.)
    """
    if model_size is None and row_sharding is not None:
        shape = dict(zip(row_sharding.mesh.axis_names,
                         row_sharding.mesh.devices.shape))
        model_size = shape.get("model")
    impl = impl or _DEFAULT_IMPL
    w = _reconstruct(spec, z.astype(jnp.float32), impl, int(chunks),
                     model_size)
    return w.astype(dtype)
