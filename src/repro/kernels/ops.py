"""jit'd public wrappers around the reconstruction kernels.

``reconstruct(spec, z)`` is THE hot op of the paper's technique: every
training/serving step turns the sampled mask ``z`` back into weights.
Dispatch:

 - impl='ref'     pure-jnp oracle (default on CPU)
 - impl='pallas'  the Pallas TPU kernel (interpret=True on CPU;
                  single-block layout, shard_count == 1)
 - distributed    when the spec carries shard_count > 1 and a mesh is
                  active, the manually-partitioned shard_map op emits
                  the tensor directly in consumer sharding
                  (kernels.qz_sharded — zero collectives)
 - chunks>1       lax.map over row-chunks of the ref path (bounds the
                  O(m·d) temporaries on a single host)

A ``jax.custom_vjp`` ties forward and backward together so both
directions use the same impl and the straight-through chain
``grad_s = Q^T grad_w ⊙ 1_{0<p<1}`` (paper §1.3) falls out of autodiff.

Transpose path: every backward branch (ref, chunked, pallas, sharded)
additionally dispatches plan-vs-scatter via
``core.transpose_plan.resolve_bwd_path()`` (env ``REPRO_BWD_PLAN``,
read at trace time; the custom_vjp/custom_vmap signatures are
unchanged).  'plan' (default) computes ``grad_z = Q^T grad_w`` as a
gather + reduction over the cached per-spec transpose plan — measured
>2x over the scatter oracle at K∈{10,32} on the CPU ref path
(``bwd_transpose_plan`` rows in BENCH_reconstruct.json); 'scatter' is
the bit-exactness oracle.  The chunked plan path chunks over WINDOWS
(each chunk owns a contiguous ``g_pad`` slice) instead of rows,
bounding temporaries at O(n·deg/chunks).

Batching-aware dispatch: every impl above also has a natively-batched
variant that takes ``Z (K, n)`` (K stacked clients) and regenerates
Q's hash-RNG indices/values ONCE instead of per client —
``reconstruct_batched`` is the explicit entry point.  On top of that,
the single-client op's custom_vjp internals are wrapped in
``jax.custom_batching.custom_vmap`` rules (one for the forward, one
for the cotangent), so ``jax.vmap(local_update)`` in
``core.federated`` lowers onto the batched kernels automatically —
including under ``vmap(grad(...))``, where JAX batches the stored fwd
and bwd jaxprs separately and hits one rule in each.  The backward
rule accumulates ``grad_Z = Q^T grad_W`` per client.  Benchmarks
(benchmarks/run.py bench_federated_round; BENCH_reconstruct.json at
the repo root) track the batched-vs-vmap win: ~4x at K=10 and ~5x
at K=32 on the CPU ref path (forward; the backward scatter batches
well under plain vmap and stays at parity), where the hash+Box-Muller regeneration
dominates a single-client reconstruct.

Fused mask lifecycle: ``sample_reconstruct`` (+``_batched``) computes
``w = Q·Bern(p)`` with the Bernoulli draw INSIDE the op — probs in,
weights out, the mask a transient value keyed by the uint32 ``step``
draw word (``core.sampling.mask_u32``).  Its custom_vjp backward is
the straight-through ``grad_p = Q^T grad_w`` — literally the composed
op's backward cores — so fused ≡ composed to exact equality, forward
and gradient, per impl (tests/test_fused.py).  ``sample_pack``
(+``_batched``) is the end-of-round upload draw: probs in, uint32
wire lanes out (``comm.bitpack.pack_mask`` layout), fed natively to
the packed transports.  Both carry the same custom_vmap rules as the
composed ops.  ``sample_reconstruct(..., qbits=b)`` additionally
accepts the QUANTIZED downlink broadcast (the ``comm.downlink``
codec's b-bit probability words): the in-op draw is the
widened-threshold integer compare (``core.sampling
.sample_mask_qhash``), bit-identical to the f32 draw on the decoded
probabilities, and gradient-free (training decodes first — see
``core.zampling.MaskProgram``).  The default impl honors the
``REPRO_RECONSTRUCT_IMPL`` env override (mirroring
``REPRO_BATCH_MAP_THRESHOLD``); benchmarks (bench_fused ->
BENCH_reconstruct.json ``fused_mask_lifecycle`` rows) track
fused-vs-composed at the Zhou-retrieval spec point.
"""

from __future__ import annotations

import functools
import os
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..core.qspec import QSpec, padded_row_window, row_indices, row_values
from ..core.hashrng import bernoulli_u32
from ..core.sampling import (
    mask_u32,
    quant_threshold_u24,
    sample_mask_hash,
    sample_mask_qhash,
)
from ..core.transpose_plan import (
    build_transpose_plan,
    plan_window_apply,
    resolve_bwd_path,
)
from ..core.reconstruct import (
    _insert_padding,
    _insert_padding_batched,
    _move,
    _move_batched,
    _select_valid,
    _select_valid_batched,
    _unmove,
    _unmove_batched,
    grad_z_batched_ref,
    grad_z_ref,
    reconstruct_batched_ref,
    reconstruct_ref,
)
from . import qz_reconstruct as _pk

_DEFAULT_IMPL = "ref"
_VALID_IMPLS = ("ref", "pallas")


def set_default_impl(impl: str) -> None:
    """Set the process-wide default reconstruction impl."""
    global _DEFAULT_IMPL
    if impl not in _VALID_IMPLS:
        raise ValueError(
            f"unknown reconstruction impl {impl!r}; valid impls: "
            f"{', '.join(_VALID_IMPLS)}"
        )
    _DEFAULT_IMPL = impl


def _default_impl() -> str:
    """Effective default impl: the ``REPRO_RECONSTRUCT_IMPL`` env var
    overrides ``set_default_impl`` (mirroring
    ``REPRO_BATCH_MAP_THRESHOLD``) — read at trace time, so flipping it
    between jit calls of different shapes needs no code edit."""
    env = os.environ.get("REPRO_RECONSTRUCT_IMPL")
    if env is None:
        return _DEFAULT_IMPL
    if env not in _VALID_IMPLS:
        raise ValueError(
            f"REPRO_RECONSTRUCT_IMPL={env!r} is not a valid impl; "
            f"valid impls: {', '.join(_VALID_IMPLS)}"
        )
    return env


def _chunk_plan(spec: QSpec, chunks: int):
    """(rows_per_chunk, num_chunks) with rpc a multiple of 8."""
    rpc = -(-spec.m_pad // chunks) // 8 * 8 or spec.m_pad
    return rpc, -(-spec.m_pad // rpc)


def _chunk_rows_global(spec: QSpec, c, rpc):
    """Hash-RNG z-indices/values for padded rows [c*rpc, (c+1)*rpc)."""
    rp = c * rpc + jnp.arange(rpc, dtype=jnp.int32)
    rp = jnp.minimum(rp, spec.m_pad - 1)
    win = padded_row_window(spec, rp)
    idx = row_indices(spec, rp.astype(jnp.uint32))
    vals = row_values(spec, rp.astype(jnp.uint32), dtype=jnp.float32)
    return win[:, None] * spec.window + idx, vals


def _ref_chunked(spec: QSpec, z, chunks: int):
    """Row-chunked padded rows: temporaries bounded to m_pad/chunks."""
    rpc, chunks = _chunk_plan(spec, chunks)
    zf = z.astype(jnp.float32)

    def one(c):
        gidx, vals = _chunk_rows_global(spec, c, rpc)
        return jnp.sum(vals * jnp.take(zf, gidx, axis=0), axis=-1)

    w_pad = jax.lax.map(one, jnp.arange(chunks)).reshape(-1)[: spec.m_pad]
    return _unmove(spec, _select_valid(spec, w_pad))


def _ref_chunked_batched(spec: QSpec, Z, chunks: int):
    """Batched row-chunking: the chunk's indices/values are generated
    once and contracted against all K clients, so temporaries stay at
    O(rpc·d + K·rpc) per chunk (never O(K·m·d))."""
    rpc, chunks = _chunk_plan(spec, chunks)
    zf = Z.astype(jnp.float32)

    def one(c):
        gidx, vals = _chunk_rows_global(spec, c, rpc)
        return jax.lax.map(
            lambda z: jnp.sum(vals * jnp.take(z, gidx, axis=0), axis=-1), zf
        )  # (K, rpc)

    w_pad = jax.lax.map(one, jnp.arange(chunks))  # (chunks, K, rpc)
    w_pad = jnp.moveaxis(w_pad, 1, 0).reshape(
        Z.shape[0], -1
    )[:, : spec.m_pad]
    return _unmove_batched(spec, _select_valid_batched(spec, w_pad))


def _chunk_live_rows(spec: QSpec, c, rpc):
    """Clamped padded-row ids for chunk ``c`` + their live mask (the
    tail chunk repeats row m_pad-1; its updates must be zeroed)."""
    loc = c * rpc + jnp.arange(rpc)
    rows = jnp.minimum(loc, spec.m_pad - 1)
    return rows, (loc < spec.m_pad).astype(jnp.float32)


def _grad_chunked(spec: QSpec, g, chunks: int):
    """Row-chunked Q^T g: bounds the (rpc, d) temporaries exactly like
    the forward ``_ref_chunked`` (the transpose scatter accumulates
    over chunks via scan)."""
    rpc, chunks = _chunk_plan(spec, chunks)
    g_pad = _insert_padding(spec, _move(spec, g.astype(jnp.float32)))

    def step(gz, c):
        gidx, vals = _chunk_rows_global(spec, c, rpc)
        rows, live = _chunk_live_rows(spec, c, rpc)
        gc = g_pad[rows] * live
        return gz.at[gidx.reshape(-1)].add(
            (vals * gc[:, None]).reshape(-1)
        ), None

    gz, _ = jax.lax.scan(step, jnp.zeros((spec.n,), jnp.float32),
                         jnp.arange(chunks))
    return gz


def _plan_chunk_tables(spec: QSpec, chunks: int, order: str):
    """The transpose plan split into window-chunks (trace constants).

    Returns (rows (nc, wpc, window·deg), vals (nc, wpc, window, deg),
    deg, wpc, pad_windows) with the window axis zero-padded to a
    multiple of wpc so a ``lax.map`` can scan it.
    """
    plan = build_transpose_plan(spec, order)
    nw = spec.num_windows
    wpc = -(-nw // chunks)
    nc = -(-nw // wpc)
    rows = plan.rows.reshape(nw, spec.window * plan.deg)
    vals = plan.vals
    pad = nc * wpc - nw
    if pad:
        rows = np.pad(rows, ((0, pad), (0, 0)))
        vals = np.pad(vals, ((0, pad), (0, 0), (0, 0)))
    return (
        jnp.asarray(rows.reshape(nc, wpc, spec.window * plan.deg)),
        jnp.asarray(vals.reshape(nc, wpc, spec.window, plan.deg)),
        plan.deg, wpc, pad,
    )


def _grad_chunked_plan(spec: QSpec, g, chunks: int, order: str):
    """Window-chunked plan gather: per-chunk GATHER TEMPORARIES are
    bounded to O(n·deg/chunks) — each window-chunk owns a contiguous
    g_pad slice, so no cross-chunk accumulation is needed.  Note the
    plan slab itself stays resident as one static constant (see the
    memory-profile note on ``_bwd_one``)."""
    rows_c, vals_c, deg, wpc, pad = _plan_chunk_tables(spec, chunks, order)
    g_pad = _insert_padding(spec, _move(spec, g.astype(jnp.float32)))
    g_pad = jnp.pad(g_pad, (0, pad * spec.rows_per_window))
    g_c = g_pad.reshape(rows_c.shape[0], wpc * spec.rows_per_window)

    def one(xs):
        r, v, gc = xs
        return plan_window_apply(spec, r, v, deg, gc, wpc)

    return jax.lax.map(one, (rows_c, vals_c, g_c)).reshape(-1)[: spec.n]


def _grad_chunked_batched_plan(spec: QSpec, G, chunks: int, order: str):
    """Batched window-chunked plan gather: one chunk's tables feed all
    K clients; per-chunk temporaries stay at O((n·deg + K·n)/chunks)."""
    rows_c, vals_c, deg, wpc, pad = _plan_chunk_tables(spec, chunks, order)
    k = G.shape[0]
    g_pad = _insert_padding_batched(
        spec, _move_batched(spec, G.astype(jnp.float32))
    )
    g_pad = jnp.pad(g_pad, ((0, 0), (0, pad * spec.rows_per_window)))
    g_c = jnp.moveaxis(
        g_pad.reshape(k, rows_c.shape[0], wpc * spec.rows_per_window), 1, 0
    )

    def one(xs):
        r, v, gc = xs  # gc (K, wpc·rpw)
        return jax.lax.map(
            lambda gk: plan_window_apply(spec, r, v, deg, gk, wpc), gc
        )

    out = jax.lax.map(one, (rows_c, vals_c, g_c))  # (nc, K, wpc·window)
    return jnp.moveaxis(out, 1, 0).reshape(k, -1)[:, : spec.n]


def _grad_chunked_batched(spec: QSpec, G, chunks: int):
    """Batched row-chunked Q^T G: one chunk-plan generation feeds all K
    per-client scatter-adds; temporaries stay at O(rpc·d + K·rpc)."""
    rpc, chunks = _chunk_plan(spec, chunks)
    g_pad = _insert_padding_batched(
        spec, _move_batched(spec, G.astype(jnp.float32))
    )

    def step(gz, c):
        gidx, vals = _chunk_rows_global(spec, c, rpc)
        rows, live = _chunk_live_rows(spec, c, rpc)
        flat = gidx.reshape(-1)

        def one(gz_k, g_k):
            gc = g_k[rows] * live
            return gz_k.at[flat].add((vals * gc[:, None]).reshape(-1))

        return jax.vmap(one)(gz, g_pad), None

    gz, _ = jax.lax.scan(
        step, jnp.zeros((G.shape[0], spec.n), jnp.float32),
        jnp.arange(chunks),
    )
    return gz


# ---------------------------------------------------------------------------
# Primal implementations (single-client and K-stacked), shared by the
# custom_vjp entry points below.
# ---------------------------------------------------------------------------

def _fwd_one(spec: QSpec, z, impl, chunks, model_size):
    if model_size is not None and spec.shard_count > 1:
        from .qz_sharded import sharded_reconstruct

        return sharded_reconstruct(spec, z, model_size)
    if impl == "pallas":
        assert spec.shard_count == 1, "pallas path is single-block layout"
        # kernel emits rows in moved (sharding-major) flat order
        return _unmove(spec, _pk.qz_reconstruct_fwd(spec, z))
    if chunks > 1:
        return _ref_chunked(spec, z, chunks)
    return reconstruct_ref(spec, z, dtype=jnp.float32)


def _bwd_one(spec: QSpec, g, impl, chunks, model_size):
    # Memory profile of the plan backward: the cached plan slab
    # (O(n·deg) rows+vals) is static read-only data, resident once per
    # (spec, order) — chunking bounds the per-chunk GATHER temporaries
    # only.  A caller that needs the scatter path's strict O(rpc·d)
    # footprint (no resident slab) gates REPRO_BWD_PLAN=scatter.
    if model_size is not None and spec.shard_count > 1:
        from .qz_sharded import sharded_grad_z

        return sharded_grad_z(spec, g.astype(jnp.float32), model_size)
    kind, order = resolve_bwd_path()
    if impl == "pallas":
        if kind == "plan":
            return _pk.qz_reconstruct_bwd_plan(spec, _move(spec, g),
                                               order=order)
        return _pk.qz_reconstruct_bwd(spec, _move(spec, g))
    if chunks > 1:
        if kind == "plan":
            return _grad_chunked_plan(spec, g, chunks, order)
        return _grad_chunked(spec, g, chunks)
    return grad_z_ref(spec, g)


def _fwd_many(spec: QSpec, Z, impl, chunks, model_size):
    if model_size is not None and spec.shard_count > 1:
        from .qz_sharded import sharded_reconstruct_batched

        return sharded_reconstruct_batched(spec, Z, model_size)
    if impl == "pallas":
        assert spec.shard_count == 1, "pallas path is single-block layout"
        # kernel emits rows in moved (sharding-major) flat order
        return _unmove_batched(spec, _pk.qz_reconstruct_batched_fwd(spec, Z))
    if chunks > 1:
        return _ref_chunked_batched(spec, Z, chunks)
    return reconstruct_batched_ref(spec, Z, dtype=jnp.float32)


def _bwd_many(spec: QSpec, G, impl, chunks, model_size):
    if model_size is not None and spec.shard_count > 1:
        from .qz_sharded import sharded_grad_z_batched

        return sharded_grad_z_batched(spec, G.astype(jnp.float32),
                                      model_size)
    kind, order = resolve_bwd_path()
    if impl == "pallas":
        if kind == "plan":
            return _pk.qz_reconstruct_batched_bwd_plan(
                spec, _move_batched(spec, G), order=order
            )
        return _pk.qz_reconstruct_batched_bwd(spec, _move_batched(spec, G))
    if chunks > 1:
        if kind == "plan":
            return _grad_chunked_batched_plan(spec, G, chunks, order)
        return _grad_chunked_batched(spec, G, chunks)
    return grad_z_batched_ref(spec, G)


# ---------------------------------------------------------------------------
# vmap-aware cores: custom_vmap rules route a batched z onto the
# natively-batched impls.  Cached so the wrapped-function identity is
# stable across traces (jit cache friendliness).
# ---------------------------------------------------------------------------

# Bounded: eviction only costs a retrace of the custom_vmap wrappers,
# never correctness, and 256 (spec, impl, chunks, model_size) combos is
# far beyond any real model's tensor count; unbounded would pin every
# spec a long-lived process ever builds.
@functools.lru_cache(maxsize=256)
def _vmap_cores(spec: QSpec, impl: str, chunks: int, model_size):
    @jax.custom_batching.custom_vmap
    def fwd_core(z):
        return _fwd_one(spec, z, impl, chunks, model_size)

    @fwd_core.def_vmap
    def _fwd_rule(axis_size, in_batched, Z):  # noqa: ARG001
        if not in_batched[0]:
            return _fwd_one(spec, Z, impl, chunks, model_size), False
        return _fwd_many(spec, Z, impl, chunks, model_size), True

    @jax.custom_batching.custom_vmap
    def bwd_core(g):
        return _bwd_one(spec, g, impl, chunks, model_size)

    @bwd_core.def_vmap
    def _bwd_rule(axis_size, in_batched, G):  # noqa: ARG001
        if not in_batched[0]:
            return _bwd_one(spec, G, impl, chunks, model_size), False
        return _bwd_many(spec, G, impl, chunks, model_size), True

    return fwd_core, bwd_core


def _make_reconstruct_op(fwd_impl, bwd_impl):
    """custom_vjp wrapper shared by the three entry points: no
    residuals, nondiff static (spec, impl, chunks, model_size)."""

    @functools.partial(jax.custom_vjp, nondiff_argnums=(0, 2, 3, 4))
    def op(spec: QSpec, z, impl: str, chunks: int, model_size):
        return fwd_impl(spec, z, impl, chunks, model_size)

    def fwd(spec, z, impl, chunks, model_size):
        return op(spec, z, impl, chunks, model_size), None

    def bwd(spec, impl, chunks, model_size, _res, g):
        return (bwd_impl(spec, g, impl, chunks, model_size),)

    op.defvjp(fwd, bwd)
    return op


# vmap-aware single-client op: fwd/bwd route through the custom_vmap
# cores so a batched z lowers onto the natively-batched impls.
_reconstruct = _make_reconstruct_op(
    lambda spec, z, impl, chunks, ms: _vmap_cores(spec, impl, chunks,
                                                  ms)[0](z),
    lambda spec, g, impl, chunks, ms: _vmap_cores(spec, impl, chunks,
                                                  ms)[1](g),
)

# Naive variant WITHOUT the custom_vmap hook: under jax.vmap this
# regenerates Q per client.  Benchmark baseline + equivalence oracle.
_reconstruct_naive = _make_reconstruct_op(_fwd_one, _bwd_one)

# Explicit K-stacked entry: Z (K, n) -> W (K, *shape).
_reconstruct_b = _make_reconstruct_op(_fwd_many, _bwd_many)


def _resolve_model_size(model_size, row_sharding):
    if model_size is None and row_sharding is not None:
        shape = dict(zip(row_sharding.mesh.axis_names,
                         row_sharding.mesh.devices.shape))
        model_size = shape.get("model")
    return model_size


def reconstruct(spec: QSpec, z, *, dtype=jnp.float32, chunks: int = 1,
                impl: Optional[str] = None, model_size: Optional[int] = None,
                row_sharding=None, auto_batch: bool = True):
    """w = Q z, returned with ``spec.shape`` and ``dtype``.

    ``model_size``: size of the 'model' mesh axis — activates the
    distributed op when the spec was built with shard_count > 1.
    (``row_sharding`` kept for API compat; its mesh provides model_size.)
    ``auto_batch``: keep the custom_vmap hook that lowers
    ``jax.vmap(reconstruct)`` onto the natively-batched kernels; pass
    False to force the per-client path (benchmark baseline).
    """
    model_size = _resolve_model_size(model_size, row_sharding)
    impl = impl or _default_impl()
    fn = _reconstruct if auto_batch else _reconstruct_naive
    w = fn(spec, z.astype(jnp.float32), impl, int(chunks), model_size)
    return w.astype(dtype)


def reconstruct_batched(spec: QSpec, Z, *, dtype=jnp.float32,
                        chunks: int = 1, impl: Optional[str] = None,
                        model_size: Optional[int] = None, row_sharding=None):
    """W = Q z^(k) for K stacked clients: Z (K, n) -> (K, *spec.shape).

    Semantically identical to ``jax.vmap(reconstruct)(Z)`` (fwd and
    grad) but regenerates Q's indices/values once per row block instead
    of once per client.  Same impl dispatch as ``reconstruct``.
    """
    if Z.ndim != 2 or Z.shape[-1] != spec.n:
        raise ValueError(f"Z has shape {Z.shape}, spec expects (K, {spec.n})")
    model_size = _resolve_model_size(model_size, row_sharding)
    impl = impl or _default_impl()
    W = _reconstruct_b(spec, Z.astype(jnp.float32), impl, int(chunks),
                       model_size)
    return W.astype(dtype)


# ---------------------------------------------------------------------------
# Fused mask lifecycle: w = Q·Bern(p) and lanes = pack(Bern(p)) as one
# op each — the mask z never exists as an f32 array between ops.  The
# draw is the counter-based hash stream (core.sampling.mask_u32), so
# fused and composed (sample -> reconstruct -> pack) regenerate
# IDENTICAL bits from (spec.seed, spec.tensor_id, step, coord): the
# bit-exactness contract is exact equality, forward and gradient.
# ---------------------------------------------------------------------------

def _packed_fusable(spec: QSpec, qbits) -> bool:
    """Whole lanes per window — the packed in-block unpack needs
    ``window % (32 // qbits) == 0`` (true for every power-of-two width
    at the standard windows); other widths fall back to the unpack
    oracle below."""
    return spec.window % (32 // qbits) == 0


def _sample_one(spec: QSpec, p, step, qbits=None, qpacked=False):
    """The oracle draw for one client: z (n,) f32 in {0,1}.  With
    ``qbits`` the operand is the quantized broadcast words and the draw
    is the widened-threshold integer compare (``sample_mask_qhash``).
    With ``qpacked`` the operand is the packed uint32 lane carry
    (``comm.bitpack``); the oracle unpacks it to per-coordinate words
    first — this REF path is the one packed impl that materializes the
    (n,) word slab (it is the exactness anchor, not the fast path)."""
    if qpacked:
        from ..comm.bitpack import unpack_words

        p = unpack_words(jnp.asarray(p), spec.n, qbits)
    if qbits is not None:
        return sample_mask_qhash(p, qbits, spec.seed, spec.tensor_id, step)
    return sample_mask_hash(p, spec.seed, spec.tensor_id, step)


def _fwd_one_fused(spec: QSpec, p, step, impl, chunks, model_size,
                   qbits=None, qpacked=False):
    if model_size is not None and spec.shard_count > 1:
        # shard-local draw: each shard hashes only its own nw_loc
        # windows at GLOBAL coordinates — bit-identical to drawing the
        # replicated (n,) mask and slicing, without materializing it
        from .qz_sharded import sharded_sample_reconstruct

        if not qpacked or _packed_fusable(spec, qbits):
            return sharded_sample_reconstruct(spec, p, step, model_size,
                                              qbits=qbits, qpacked=qpacked)
    elif impl == "pallas" and (not qpacked or _packed_fusable(spec, qbits)):
        assert spec.shard_count == 1, "pallas path is single-block layout"
        return _unmove(spec, _pk.qz_sample_reconstruct_fwd(
            spec, p, step, qbits=qbits, qpacked=qpacked))
    z = _sample_one(spec, p, step, qbits, qpacked)
    if chunks > 1:
        return _ref_chunked(spec, z, chunks)
    return reconstruct_ref(spec, z, dtype=jnp.float32)


def _fwd_many_fused(spec: QSpec, P, steps, impl, chunks, model_size,
                    qbits=None, qpacked=False):
    if model_size is not None and spec.shard_count > 1:
        # shard-local batched draw (see _fwd_one_fused)
        from .qz_sharded import sharded_sample_reconstruct_batched

        if not qpacked or _packed_fusable(spec, qbits):
            return sharded_sample_reconstruct_batched(
                spec, P, steps, model_size, qbits=qbits, qpacked=qpacked)
    elif impl == "pallas" and (not qpacked or _packed_fusable(spec, qbits)):
        assert spec.shard_count == 1, "pallas path is single-block layout"
        return _unmove_batched(
            spec, _pk.qz_sample_reconstruct_batched_fwd(
                spec, P, steps, qbits=qbits, qpacked=qpacked)
        )
    Z = _sample_one(spec, P, steps, qbits, qpacked)
    if chunks > 1:
        return _ref_chunked_batched(spec, Z, chunks)
    return reconstruct_batched_ref(spec, Z, dtype=jnp.float32)


@functools.lru_cache(maxsize=256)
def _fused_cores(spec: QSpec, impl: str, chunks: int, model_size):
    """vmap-aware fused forward: a batched (p, step) lowers onto the
    natively-batched fused impls (same pattern as ``_vmap_cores``; the
    backward IS ``_vmap_cores``'s bwd core — the straight-through
    cotangent does not depend on the draw)."""

    @jax.custom_batching.custom_vmap
    def fwd_core(p, step):
        return _fwd_one_fused(spec, p, step, impl, chunks, model_size)

    @fwd_core.def_vmap
    def _fwd_rule(axis_size, in_batched, P, steps):
        pb, sb = in_batched
        if not pb and not sb:
            return _fwd_one_fused(spec, P, steps, impl, chunks,
                                  model_size), False
        if not pb:
            P = jnp.broadcast_to(P, (axis_size, *P.shape))
        if not sb:
            steps = jnp.broadcast_to(steps, (axis_size,))
        return _fwd_many_fused(spec, P, steps, impl, chunks,
                               model_size), True

    return fwd_core


def _float0_like(step):
    """Cotangent for the integer step word (jax float0 convention)."""
    return np.zeros(np.shape(step), jax.dtypes.float0)


def _make_sample_reconstruct_op(fwd_impl, bwd_impl):
    """custom_vjp for the fused op: primal draws in-op; backward is the
    straight-through ``grad_p = Q^T grad_w`` — the SAME code path as
    the composed reconstruction backward, so gradients are bit-exact
    across fused/composed by construction."""

    @functools.partial(jax.custom_vjp, nondiff_argnums=(0, 3, 4, 5))
    def op(spec: QSpec, p, step, impl: str, chunks: int, model_size):
        return fwd_impl(spec, p, step, impl, chunks, model_size)

    def fwd(spec, p, step, impl, chunks, model_size):
        return op(spec, p, step, impl, chunks, model_size), step

    def bwd(spec, impl, chunks, model_size, step, g):
        return (bwd_impl(spec, g, impl, chunks, model_size),
                _float0_like(step))

    op.defvjp(fwd, bwd)
    return op


# vmap-aware fused op (the custom_vmap hook lowers vmap(local_update)
# onto the batched fused kernel) and the explicit K-stacked entry.
_sample_reconstruct = _make_sample_reconstruct_op(
    lambda spec, p, step, impl, chunks, ms: _fused_cores(
        spec, impl, chunks, ms)(p, step),
    lambda spec, g, impl, chunks, ms: _vmap_cores(spec, impl, chunks,
                                                  ms)[1](g),
)
_sample_reconstruct_b = _make_sample_reconstruct_op(_fwd_many_fused,
                                                    _bwd_many)


@functools.lru_cache(maxsize=256)
def _fused_q_cores(spec: QSpec, qbits: int, impl: str, chunks: int,
                   model_size, qpacked: bool = False):
    """vmap-aware QUANTIZED fused forward: the operand is the downlink
    codec's b-bit probability words — or, with ``qpacked``, its packed
    uint32 lane carry (``comm.bitpack``) — and the in-op draw is the
    widened-threshold integer compare.  No custom_vjp — integer wire
    words carry no cotangent (the trainable path decodes first; see
    ``core.zampling.MaskProgram``)."""

    @jax.custom_batching.custom_vmap
    def core(q, step):
        return _fwd_one_fused(spec, q, step, impl, chunks, model_size,
                              qbits, qpacked)

    @core.def_vmap
    def _rule(axis_size, in_batched, Q, steps):
        qb, sb = in_batched
        if not qb and not sb:
            return _fwd_one_fused(spec, Q, steps, impl, chunks, model_size,
                                  qbits, qpacked), False
        if not qb:
            Q = jnp.broadcast_to(Q, (axis_size, *Q.shape))
        if not sb:
            steps = jnp.broadcast_to(steps, (axis_size,))
        return _fwd_many_fused(spec, Q, steps, impl, chunks, model_size,
                               qbits, qpacked), True

    return core


def sample_reconstruct(spec: QSpec, p, step, *, dtype=jnp.float32,
                       chunks: int = 1, impl: Optional[str] = None,
                       model_size: Optional[int] = None, row_sharding=None,
                       qbits: Optional[int] = None, qpacked: bool = False):
    """w = Q·Bern(p) fused: probabilities in, weights out.

    ``step`` is the uint32 draw-counter word (``core.sampling``); the
    mask is drawn inside the op (in-block on the Pallas path) and is
    bit-identical to ``reconstruct(spec, sample_mask_hash(p, ...))``.
    Differentiable in ``p`` with the straight-through
    ``grad_p = Q^T grad_w``; chain through ``clip_probs`` for the
    paper's ``⊙ 1_{0<s<1}`` gate.  Same impl dispatch as
    ``reconstruct``.

    ``qbits``: the operand is a QUANTIZED downlink broadcast — b-bit
    probability words from the ``comm.downlink`` codec — and the in-op
    draw is the widened-threshold integer compare, bit-identical to
    the f32 path on the codec's decoded probabilities
    (``sample_mask_qhash``).  That path is gradient-free (wire words
    carry no cotangent); training decodes first.

    ``qpacked``: the operand is the packed uint32 LANE carry of the
    sub-byte codecs (``comm.downlink.PackedDown`` / ``comm.bitpack``
    layout, length ``packed_word_len(n, qbits)``): the fused impls
    stream whole lanes and unpack in-block, so the per-coordinate word
    slab never materializes (only the ref oracle unpacks up front).
    """
    model_size = _resolve_model_size(model_size, row_sharding)
    impl = impl or _default_impl()
    if qpacked and qbits is None:
        raise ValueError("qpacked requires qbits (a packed codec width)")
    if qbits is not None:
        w = _fused_q_cores(spec, int(qbits), impl, int(chunks), model_size,
                           bool(qpacked))(
            jnp.asarray(p).astype(jnp.uint32),
            jnp.asarray(step, jnp.uint32))
        return w.astype(dtype)
    w = _sample_reconstruct(spec, p.astype(jnp.float32),
                            jnp.asarray(step, jnp.uint32), impl,
                            int(chunks), model_size)
    return w.astype(dtype)


def sample_reconstruct_batched(spec: QSpec, P, steps, *, dtype=jnp.float32,
                               chunks: int = 1, impl: Optional[str] = None,
                               model_size: Optional[int] = None,
                               row_sharding=None,
                               qbits: Optional[int] = None,
                               qpacked: bool = False):
    """Fused W = Q·Bern(p^(k)) for K stacked clients: P (K, n) probs +
    steps (K,) draw words -> (K, *spec.shape).  ``qbits``/``qpacked``
    as ``sample_reconstruct``: P is the (K, n) quantized word slab, or
    the (K, n/wpl) packed lane slab."""
    exp_len = spec.n
    if qpacked:
        if qbits is None:
            raise ValueError("qpacked requires qbits (a packed codec width)")
        from ..comm.bitpack import packed_word_len

        exp_len = packed_word_len(spec.n, int(qbits))
    if P.ndim != 2 or P.shape[-1] != exp_len:
        raise ValueError(f"P has shape {P.shape}, spec expects "
                         f"(K, {exp_len})")
    model_size = _resolve_model_size(model_size, row_sharding)
    impl = impl or _default_impl()
    if qbits is not None:
        W = _fwd_many_fused(spec, jnp.asarray(P).astype(jnp.uint32),
                            jnp.asarray(steps, jnp.uint32), impl,
                            int(chunks), model_size, int(qbits),
                            bool(qpacked))
        return W.astype(dtype)
    W = _sample_reconstruct_b(spec, P.astype(jnp.float32),
                              jnp.asarray(steps, jnp.uint32), impl,
                              int(chunks), model_size)
    return W.astype(dtype)


# ---------------------------------------------------------------------------
# Fused upload draw: probabilities in, uint32 wire lanes out.
# ---------------------------------------------------------------------------

def _pack_one(spec: QSpec, p, step, impl):
    if impl == "pallas" and spec.window % 32 == 0:
        return _pk.qz_sample_pack_fwd(spec, p, step)
    from ..comm.bitpack import pack_mask

    return pack_mask(_sample_one(spec, p, step))


def _pack_many(spec: QSpec, P, steps, impl):
    if impl == "pallas" and spec.window % 32 == 0:
        return _pk.qz_sample_pack_batched_fwd(spec, P, steps)
    from ..comm.bitpack import pack_mask

    return pack_mask(_sample_one(spec, P, steps))


@functools.lru_cache(maxsize=256)
def _pack_cores(spec: QSpec, impl: str):
    @jax.custom_batching.custom_vmap
    def core(p, step):
        return _pack_one(spec, p, step, impl)

    @core.def_vmap
    def _rule(axis_size, in_batched, P, steps):
        pb, sb = in_batched
        if not pb and not sb:
            return _pack_one(spec, P, steps, impl), False
        if not pb:
            P = jnp.broadcast_to(P, (axis_size, *P.shape))
        if not sb:
            steps = jnp.broadcast_to(steps, (axis_size,))
        return _pack_many(spec, P, steps, impl), True

    return core


def sample_pack(spec: QSpec, p, step, *, impl: Optional[str] = None):
    """Fused end-of-round upload: lanes = pack(Bern(p)), uint32
    (ceil(n/32),).  Bit-identical to
    ``pack_mask(sample_mask_hash(p, ...))``; not differentiable (the
    upload draw carries no gradient).  The pallas impl emits whole
    lanes per z-window and needs ``spec.window % 32 == 0`` — smaller
    windows fall back to the jnp oracle (same lanes either way)."""
    impl = impl or _default_impl()
    return _pack_cores(spec, impl)(p.astype(jnp.float32),
                                   jnp.asarray(step, jnp.uint32))


def sample_pack_batched(spec: QSpec, P, steps, *,
                        impl: Optional[str] = None):
    """Fused batched upload: P (K, n) probs -> (K, ceil(n/32)) lanes."""
    if P.ndim != 2 or P.shape[-1] != spec.n:
        raise ValueError(f"P has shape {P.shape}, spec expects (K, {spec.n})")
    impl = impl or _default_impl()
    return _pack_many(spec, P.astype(jnp.float32),
                      jnp.asarray(steps, jnp.uint32), impl)


# ---------------------------------------------------------------------------
# Streaming serve ops: y = x @ W_g with W_g never materialized.  The
# decode-path contraction regenerates Q edges + mask bits per tile and
# consumes the weight values in place, so a serving node's resident
# zampled state is the ENCODED score broadcast alone (kernels.qz_decode
# has the kernel story; serve.decode drives these per leaf).  Gradient-
# free by design — serving never backprops.  Impl dispatch mirrors
# reconstruct: 'chunked' (default; lax.scan over the canonical blocks,
# bounds temporaries at O(bm·d)), 'pallas' (qz_decode kernels,
# interpret on CPU), 'ref' (reconstruct-then-matmul oracle — the ONE
# serve impl that does materialize W_g).  The REPRO_SERVE_IMPL env
# override is read at trace time.
#
# CANONICAL CONTRACTION TREE.  Floating-point summation order is part
# of the serve contract: XLA's ``jnp.dot`` reduction tree is
# context-dependent (measured on CPU: mat-mat does not bitwise equal
# its own ascending row-blocked partial sums, and at B=1 a vmapped
# row dot differs from the stacked per-row dots), so "bit-identical
# across impls" cannot lean on dot internals.  Instead every impl —
# ref, chunked, and the Pallas kernels — contracts through ONE defined
# tree: per (window, bm)-block in ascending grid order, the block's
# rows scatter into an i-aligned (NI, d_out) weight tile (each cell a
# single term, NI = bm//d_out + 2 static), and the accumulator takes
# ``y += dot(x[i_lo:i_lo+NI], tile)``.  Identical dot shapes, operand
# values, and add order at every step ⇒ identical bits by
# construction (up to IEEE signed zeros in all-dead tile cells),
# whatever the backend's dot does inside one tile.
# ---------------------------------------------------------------------------

_DEFAULT_SERVE_IMPL = "chunked"
_VALID_SERVE_IMPLS = ("ref", "chunked", "pallas")

# row-block size of the canonical serve tree; part of the bit-exactness
# contract (a different bm is a different summation tree)
SERVE_BM = 256


def set_default_serve_impl(impl: str) -> None:
    """Set the process-wide default serve impl."""
    global _DEFAULT_SERVE_IMPL
    if impl not in _VALID_SERVE_IMPLS:
        raise ValueError(
            f"unknown serve impl {impl!r}; valid impls: "
            f"{', '.join(_VALID_SERVE_IMPLS)}"
        )
    _DEFAULT_SERVE_IMPL = impl


def _default_serve_impl() -> str:
    """Effective serve impl: ``REPRO_SERVE_IMPL`` env override (read at
    trace time, mirroring ``REPRO_RECONSTRUCT_IMPL``), else the
    process default."""
    env = os.environ.get("REPRO_SERVE_IMPL")
    if env is None:
        return _DEFAULT_SERVE_IMPL
    if env not in _VALID_SERVE_IMPLS:
        raise ValueError(
            f"REPRO_SERVE_IMPL={env!r} is not a valid impl; "
            f"valid impls: {', '.join(_VALID_SERVE_IMPLS)}"
        )
    return env


def serve_group_dims(spec: QSpec):
    """(groups, d_in, d_out) of a spec's flat moved row space.

    The serve ops address one GROUP (stacked layer) at a time: a
    (L, d_in, d_out) leaf has L groups of contiguous rows, a 2-D leaf
    one.  Requires the single-block identity row layout (shard_count
    == 1, major_axis == 0) — the serving case; ``build_specs`` without
    a shard plan always produces it.
    """
    if spec.shard_count != 1 or spec.major_axis != 0:
        raise ValueError(
            "serve ops address the single-block identity row layout "
            f"(shard_count=1, major_axis=0); spec has shard_count="
            f"{spec.shard_count}, major_axis={spec.major_axis}"
        )
    if len(spec.shape) < 2:
        raise ValueError(f"serve ops need a >=2-D spec, got {spec.shape}")
    if len(spec.shape) == 2:
        return 1, spec.shape[0], spec.shape[1]
    groups = spec.shape[0]
    d_out = spec.shape[-1]
    d_in = 1
    for s in spec.shape[1:-1]:
        d_in *= s
    return groups, d_in, d_out


def _serve_operand(spec: QSpec, words, qbits):
    """Clip f32 scores to probabilities; pass wire words through."""
    if qbits is None:
        return jnp.clip(jnp.asarray(words).astype(jnp.float32), 0.0, 1.0)
    return jnp.asarray(words).astype(jnp.uint32)


def _serve_edge_weights(spec: QSpec, p, step, rows, qbits, qpacked=False):
    """Per-edge streamed weight values at flat rows ``rows`` (..., ).

    Regenerates the rows' Q edges, draws each edge's mask bit straight
    from the encoded score words at its global z coordinate, and
    reduces over the degree axis — the same per-row expression as the
    reconstruct kernels, so values are bit-identical to gathering the
    materialized tensor.  With ``qpacked``, ``p`` is the packed uint32
    lane carry and each edge gathers its LANE (``coords // wpl``) then
    shift/masks its word out — no per-coordinate word slab, the
    gathered temporaries stay at the edge count.
    """
    rows = jnp.asarray(rows)
    idx = row_indices(spec, rows)  # (..., d) in-window
    vals = row_values(spec, rows, dtype=jnp.float32)
    win = (rows // spec.rows_per_window).astype(jnp.int32)
    coords = win[..., None] * spec.window + idx  # global z coords
    u = mask_u32(spec.seed, spec.tensor_id, jnp.asarray(step, jnp.uint32),
                 coords)
    if qpacked:
        wpl = 32 // qbits
        lanes = jnp.take(p, (coords // wpl).reshape(-1)).reshape(
            coords.shape)
        off = (coords % wpl).astype(jnp.uint32) * jnp.uint32(qbits)
        pw = (lanes >> off) & np.uint32((1 << qbits) - 1)
    else:
        pw = jnp.take(p, coords.reshape(-1)).reshape(coords.shape)
    if qbits is None:
        bits = bernoulli_u32(u, pw)
    else:
        thr = quant_threshold_u24(pw, qbits)
        bits = ((u >> np.uint32(8)) < thr).astype(jnp.float32)
    return jnp.sum(vals * bits, axis=-1)


def serve_tile_rows(bm: int, d_out: int) -> int:
    """NI: i-rows a bm-row flat block can straddle (static tile height).

    A contiguous run of ``bm`` flat rows starting mid-i-row touches at
    most ``ceil((bm + d_out - 1) / d_out) <= bm // d_out + 2`` distinct
    input rows of the (d_in, d_out) group.
    """
    return bm // d_out + 2


def serve_block_grid(spec: QSpec, bm: int, row_offset: int, sub: int):
    """(w0, nblocks, bpw): the canonical block enumeration for a group.

    Only the windows overlapping rows [row_offset, row_offset + sub)
    are visited — a stacked leaf costs one layer's blocks per call.
    Blocks run in ascending (window, block) order; this order is part
    of the bit-exactness contract.
    """
    bpw = max(1, -(-spec.rows_per_window // bm))
    w0 = row_offset // spec.rows_per_window
    w1 = (row_offset + sub - 1) // spec.rows_per_window
    return w0, (w1 - w0 + 1) * bpw, bpw


def _serve_contract_blocks(spec: QSpec, x, row_offset, d_in, d_out, bm,
                           w_blk_fn):
    """The canonical window-blocked contraction (see section comment).

    ``w_blk_fn(rows (bm,) int32, live (bm,) bool, t () int32) -> (bm,)
    f32`` yields block ``t``'s weight values with exact +0.0 at dead
    rows (``t`` is the canonical grid index — the hot-block cache keys
    its tiles by it).  Every serve impl and the qz_decode kernels
    replay THIS tree — identical tile shapes, operand values, and
    accumulation order — so their float sums agree bit-for-bit.
    """
    sub = d_in * d_out
    ni = serve_tile_rows(bm, d_out)
    w0, nblk, bpw = serve_block_grid(spec, bm, row_offset, sub)
    rpw = spec.rows_per_window
    xf = x.astype(jnp.float32)
    pad = ((0, 0), (0, ni)) if xf.ndim == 2 else ((0, ni),)
    xpad = jnp.pad(xf, pad)
    lane = jnp.arange(bm, dtype=jnp.int32)

    def body(y, t):
        j = t % bpw
        bstart = (w0 + t // bpw) * rpw + j * bm
        rows = bstart + lane
        live = ((rows >= row_offset) & (rows < row_offset + sub)
                & (j * bm + lane < rpw) & (rows < spec.m))
        w_blk = w_blk_fn(rows, live, t)
        i_lo = jnp.clip(bstart - row_offset, 0, sub - 1) // d_out
        pos = jnp.where(live, rows - row_offset - i_lo * d_out,
                        ni * d_out)
        tile = jnp.zeros((ni * d_out,), jnp.float32)
        tile = tile.at[pos].add(w_blk, mode="drop").reshape(ni, d_out)
        if xf.ndim == 2:
            xseg = jax.lax.dynamic_slice(xpad, (0, i_lo),
                                         (xpad.shape[0], ni))
        else:
            xseg = jax.lax.dynamic_slice(xpad, (i_lo,), (ni,))
        return (y + jnp.dot(xseg, tile,
                            preferred_element_type=jnp.float32), None)

    y0 = jnp.zeros(xf.shape[:-1] + (d_out,), jnp.float32)
    y, _ = jax.lax.scan(body, y0, jnp.arange(nblk, dtype=jnp.int32))
    return y


def _serve_contract_chunked(spec: QSpec, p, step, x, row_offset, d_in,
                            d_out, qbits, bm, qpacked=False):
    """Streaming jnp path: each canonical block regenerates its own
    (bm,) weight values from the encoded words and is consumed by the
    tile dot in place — peak temporaries O(bm·d), no W_g anywhere."""

    def w_blk_fn(rows, live, t):
        del t
        w = _serve_edge_weights(spec, p, step, rows, qbits, qpacked)
        return jnp.where(live, w, 0.0)

    return _serve_contract_blocks(spec, x, row_offset, d_in, d_out, bm,
                                  w_blk_fn)


def _serve_contract_resident(spec: QSpec, W, x, row_offset, d_in, d_out,
                             bm):
    """Canonical blocked contraction against a MATERIALIZED leaf: the
    reconstruct-on-load serving mode's linear (a tiled dense matmul —
    the tiling pins the summation order the streaming impls replay)."""
    Wf = jnp.pad(jnp.asarray(W).reshape(-1).astype(jnp.float32),
                 (0, spec.rows_per_window + bm))

    def w_blk_fn(rows, live, t):
        del t
        return jnp.where(live, jnp.take(Wf, rows), 0.0)

    return _serve_contract_blocks(spec, x, row_offset, d_in, d_out, bm,
                                  w_blk_fn)


def _serve_contract_cached(spec: QSpec, p, step, x, row_offset, d_in,
                           d_out, qbits, bm, pool, slots, qpacked=False):
    """Hot-block-cache path: per canonical block, a ``lax.cond`` on the
    block's cache slot — a resident tile gather on a hit, the streaming
    regeneration on a miss.  Both branches produce the identical (bm,)
    values (the pool is filled by ``serve_fill_tiles``, which computes
    the miss branch's exact expression), so any slot assignment —
    empty, partial, or full — yields bit-identical output; the cache
    budget moves only the latency point.

    ``pool``: (S, bm) f32 global tile pool (S >= 1); ``slots``: (nblk,)
    int32 slot per canonical block of THIS group, -1 = uncached.  Both
    are jit arguments, so fills/evictions/invalidations never
    recompile.
    """

    def w_blk_fn(rows, live, t):
        slot = slots[t]

        def hit(_):
            return jax.lax.dynamic_index_in_dim(pool, slot, keepdims=False)

        def miss(_):
            w = _serve_edge_weights(spec, p, step, rows, qbits, qpacked)
            return jnp.where(live, w, 0.0)

        return jax.lax.cond(slot >= 0, hit, miss, None)

    return _serve_contract_blocks(spec, x, row_offset, d_in, d_out, bm,
                                  w_blk_fn)


def _serve_contract_ref(spec: QSpec, words, step, x, row_offset, d_in,
                        d_out, qbits, bm, qpacked=False):
    """Reconstruct-then-matmul oracle: materializes the full leaf, then
    contracts it through the resident (load-mode) path."""
    W = sample_reconstruct(spec, words, step, qbits=qbits, qpacked=qpacked,
                           impl="ref")
    return _serve_contract_resident(spec, W, x, row_offset, d_in, d_out,
                                    bm)


def _serve_contract(spec, words, step, x, group, qbits, impl, bm,
                    qpacked=False):
    groups, d_in, d_out = serve_group_dims(spec)
    if not 0 <= group < groups:
        raise ValueError(f"group {group} out of range [0, {groups})")
    if x.shape[-1] != d_in:
        raise ValueError(
            f"activation has trailing dim {x.shape[-1]}, spec group "
            f"expects d_in={d_in}"
        )
    row_offset = group * d_in * d_out
    if impl == "ref":
        return _serve_contract_ref(spec, words, step, x, row_offset,
                                   d_in, d_out, qbits, bm, qpacked)
    p = _serve_operand(spec, words, qbits)
    if impl == "pallas" and (not qpacked or _packed_fusable(spec, qbits)):
        from .qz_decode import qz_sample_matmul, qz_sample_matvec

        fn = qz_sample_matvec if x.ndim == 1 else qz_sample_matmul
        return fn(spec, p, step, x, row_offset=row_offset, d_in=d_in,
                  d_out=d_out, qbits=qbits, qpacked=qpacked, bm=bm)
    return _serve_contract_chunked(spec, p, step, x, row_offset, d_in,
                                   d_out, qbits, bm, qpacked)


def serve_matvec(spec: QSpec, words, step, x, *, group: int = 0,
                 qbits: Optional[int] = None, qpacked: bool = False,
                 impl: Optional[str] = None, bm: int = SERVE_BM):
    """Streamed y = x @ W_g: encoded scores + x (d_in,) -> (d_out,).

    ``words``: the serve-resident score state — f32 scores (clipped to
    probabilities in-op), the downlink codec's uint words with
    ``qbits`` set, or the packed uint32 lane carry with ``qpacked``
    (sub-byte codecs; the streamed impls gather lanes and shift/mask
    in place).  ``step`` pins the mask draw; ``group`` selects the
    stacked layer.  All impls contract through the canonical blocked
    tree (section comment), so ref/chunked/pallas agree bit-for-bit;
    'ref' IS reconstruct-then-matmul and anchors the exactness tests.
    """
    impl = impl or _default_serve_impl()
    if impl not in _VALID_SERVE_IMPLS:
        raise ValueError(
            f"unknown serve impl {impl!r}; valid impls: "
            f"{', '.join(_VALID_SERVE_IMPLS)}"
        )
    if x.ndim != 1:
        raise ValueError(f"serve_matvec takes x (d_in,), got {x.shape}")
    return _serve_contract(spec, words, step, x, int(group), qbits, impl,
                           int(bm), bool(qpacked))


def serve_matmul(spec: QSpec, words, step, X, *, group: int = 0,
                 qbits: Optional[int] = None, qpacked: bool = False,
                 impl: Optional[str] = None, bm: int = SERVE_BM):
    """Streamed Y = X @ W_g for a (B, d_in) activation batch."""
    impl = impl or _default_serve_impl()
    if impl not in _VALID_SERVE_IMPLS:
        raise ValueError(
            f"unknown serve impl {impl!r}; valid impls: "
            f"{', '.join(_VALID_SERVE_IMPLS)}"
        )
    if X.ndim != 2:
        raise ValueError(f"serve_matmul takes X (B, d_in), got {X.shape}")
    return _serve_contract(spec, words, step, X, int(group), qbits, impl,
                           int(bm), bool(qpacked))


def serve_cached_matmul(spec: QSpec, words, step, X, pool, slots, *,
                        group: int = 0, qbits: Optional[int] = None,
                        qpacked: bool = False, bm: int = SERVE_BM):
    """Streamed Y = X @ W_g with the hot-block cache in the loop.

    ``pool`` (S, bm) f32 and ``slots`` (nblk,) int32 come from
    ``serve.cache.HotBlockCache`` (slice its per-leaf slot map at
    ``group``).  Bit-identical to ``serve_matmul`` at every cache
    occupancy — a hit swaps WHERE a block's values come from, never
    what they are or how they are summed.
    """
    if X.ndim != 2:
        raise ValueError(
            f"serve_cached_matmul takes X (B, d_in), got {X.shape}"
        )
    groups, d_in, d_out = serve_group_dims(spec)
    group = int(group)
    if not 0 <= group < groups:
        raise ValueError(f"group {group} out of range [0, {groups})")
    if X.shape[-1] != d_in:
        raise ValueError(
            f"activation has trailing dim {X.shape[-1]}, spec group "
            f"expects d_in={d_in}"
        )
    p = _serve_operand(spec, words, qbits)
    return _serve_contract_cached(spec, p, step, X, group * d_in * d_out,
                                  d_in, d_out, qbits, int(bm), pool,
                                  slots, bool(qpacked))


def serve_fill_tiles(spec: QSpec, words, step, groups_idx, blocks, *,
                     qbits: Optional[int] = None, qpacked: bool = False,
                     bm: int = SERVE_BM):
    """Batched tile fill: materialize T canonical blocks' weight values.

    ``groups_idx`` / ``blocks`` are (T,) int32 (group, canonical block
    index) pairs; returns (T, bm) f32 tiles with exact +0.0 at dead
    lanes — the same values ``serve_matmul``'s miss path regenerates
    for those blocks, computed in ONE vectorized ``_serve_edge_weights``
    call (no full-leaf materialization, peak temporaries O(T·bm·d)).
    The hot-block cache's fill path: pool rows written from here are
    bit-identical to the streaming regeneration they replace.
    """
    groups, d_in, d_out = serve_group_dims(spec)
    sub = d_in * d_out
    rpw = spec.rows_per_window
    bpw = max(1, -(-rpw // bm))
    g = jnp.asarray(groups_idx, jnp.int32)
    t = jnp.asarray(blocks, jnp.int32)
    if g.shape != t.shape or g.ndim != 1:
        raise ValueError(
            f"groups_idx/blocks must be matching (T,) arrays, got "
            f"{g.shape} vs {t.shape}"
        )
    row_offset = g * sub
    w0 = row_offset // rpw
    j = t % bpw
    bstart = (w0 + t // bpw) * rpw + j * bm
    lane = jnp.arange(bm, dtype=jnp.int32)
    rows = bstart[:, None] + lane[None, :]
    live = ((rows >= row_offset[:, None])
            & (rows < row_offset[:, None] + sub)
            & ((j * bm)[:, None] + lane[None, :] < rpw)
            & (rows < spec.m))
    p = _serve_operand(spec, words, qbits)
    w = _serve_edge_weights(spec, p, step, rows, qbits, bool(qpacked))
    return jnp.where(live, w, 0.0)


def _serve_resident_dims(spec: QSpec, group: int, x):
    groups, d_in, d_out = serve_group_dims(spec)
    if not 0 <= group < groups:
        raise ValueError(f"group {group} out of range [0, {groups})")
    if x.shape[-1] != d_in:
        raise ValueError(
            f"activation has trailing dim {x.shape[-1]}, spec group "
            f"expects d_in={d_in}"
        )
    return group * d_in * d_out, d_in, d_out


def serve_resident_matvec(spec: QSpec, W, x, *, group: int = 0,
                          bm: int = SERVE_BM):
    """y = x @ W_g against a materialized leaf, canonical tree.

    The reconstruct-on-load serving mode's linear: ``W`` is the full
    reconstructed leaf (spec.shape).  Contracting through the same
    blocked tree as the streamed impls is what makes load-mode serving
    bit-identical to streaming-mode serving — the modes differ only in
    WHERE the block's weight values come from (a resident tensor vs an
    in-block regeneration), never in how they are summed.
    """
    if x.ndim != 1:
        raise ValueError(
            f"serve_resident_matvec takes x (d_in,), got {x.shape}"
        )
    row_offset, d_in, d_out = _serve_resident_dims(spec, int(group), x)
    return _serve_contract_resident(spec, W, x, row_offset, d_in, d_out,
                                    int(bm))


def serve_resident_matmul(spec: QSpec, W, X, *, group: int = 0,
                          bm: int = SERVE_BM):
    """Y = X @ W_g against a materialized leaf for (B, d_in) batches."""
    if X.ndim != 2:
        raise ValueError(
            f"serve_resident_matmul takes X (B, d_in), got {X.shape}"
        )
    row_offset, d_in, d_out = _serve_resident_dims(spec, int(group), X)
    return _serve_contract_resident(spec, W, X, row_offset, d_in, d_out,
                                    int(bm))


def serve_embed_rows(spec: QSpec, words, step, tokens, *,
                     qbits: Optional[int] = None, qpacked: bool = False):
    """Streamed embedding-row gather: tokens (...) int -> (..., d_out).

    Row t of a 2-D (vocab, d_model) leaf is the contiguous flat-row
    run [t*d_model, (t+1)*d_model); the per-edge draw regenerates just
    those rows — bit-identical to ``jnp.take`` on the materialized
    table, at O(B·d_model·d) hashes per token batch.  Pure jnp on
    every impl (a gather has no contraction to fuse into).
    """
    groups, d_in, d_out = serve_group_dims(spec)
    if groups != 1:
        raise ValueError(
            f"serve_embed_rows addresses 2-D table leaves; spec shape "
            f"{spec.shape} has {groups} stacked groups"
        )
    p = _serve_operand(spec, words, qbits)
    tokens = jnp.asarray(tokens, jnp.int32)
    rows = tokens[..., None] * d_out + jnp.arange(d_out, dtype=jnp.int32)
    return _serve_edge_weights(spec, p, step, rows, qbits, bool(qpacked))
