"""Distribution-aware reconstruction: shard_map over the 'model' axis.

GSPMD cannot partition the scatter in ``grad_z = Q^T grad_w``, and a
flat-row-sharded weight must be RESHARDED to its consumer layout — an
all-gather of the full tensor through a replicated f32 intermediate
(measured 14 GB/device/tensor on qwen3-14b).  Both problems disappear
with the sharding-major layout (QSpec.major_axis/shard_count):

 - shard k owns rows [k·m_pad_loc, (k+1)·m_pad_loc) which read ONLY its
   own ``nw_loc`` z windows — the gather/scatter is purely local;
 - those rows ARE the k-th block of the tensor's sharded axis, so the
   local reshape+moveaxis emits the weight block in consumer layout and
   ``out_specs`` reassembles the global tensor with ZERO collectives.

The shard_map is entered without an explicit mesh so it composes with
the (partially-manual) context mesh of the federated round.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ..core.qspec import QSpec, row_indices, row_values

AXIS = "model"


TARGET_CHUNK_BYTES = 128 << 20  # bound the (rows, d) temporaries


def _num_chunks(spec: QSpec) -> int:
    per_row = spec.d * 4 * 3  # idx + vals + gathered z, f32/i32
    return max(1, min(spec.m_pad_loc,
                      (spec.m_pad_loc * per_row) // TARGET_CHUNK_BYTES))


def _chunk_rows(spec: QSpec, c, rpc):
    """Gather indices + values for rows [c*rpc, (c+1)*rpc) of this shard."""
    sid = jax.lax.axis_index(AXIS)
    loc = c * rpc + jnp.arange(rpc, dtype=jnp.int32)
    loc = jnp.minimum(loc, spec.m_pad_loc - 1)  # clamp tail overrun
    rp = (sid * spec.m_pad_loc + loc).astype(jnp.uint32)
    idx = row_indices(spec, rp)  # (rpc, d) in-window
    vals = row_values(spec, rp, dtype=jnp.float32)
    win_loc = jnp.minimum(loc // spec.rows_per_window, spec.nw_loc - 1)
    gidx = win_loc[:, None] * spec.window + idx  # local z-slice index
    return gidx, vals


def _check(spec: QSpec, ms: int):
    if spec.shard_count != ms:
        raise ValueError(
            f"spec.shard_count={spec.shard_count} != model axis size {ms}; "
            "build specs with shard_count=model_size"
        )


def _out_spec(spec: QSpec) -> P:
    dims = [None] * len(spec.shape)
    dims[spec.major_axis] = AXIS
    return P(*dims)


def sharded_reconstruct(spec: QSpec, z, ms: int):
    """w = Q z with z sharded P('model'); returns the weight tensor
    with ``spec.shape``, sharded on its major axis. Zero collectives."""
    _check(spec, ms)
    a = spec.major_axis
    loc_moved = (spec.shape[a] // ms,
                 *spec.shape[:a], *spec.shape[a + 1:])

    def local(zl):
        zf = zl.astype(jnp.float32)
        nc = _num_chunks(spec)
        rpc = -(-spec.m_pad_loc // nc)

        def one(c):
            gidx, vals = _chunk_rows(spec, c, rpc)
            return jnp.sum(vals * zf[gidx], axis=-1)

        w = jax.lax.map(one, jnp.arange(nc)).reshape(-1)[: spec.m_blk]
        return jnp.moveaxis(w.reshape(loc_moved), 0, a)

    return jax.shard_map(
        local, in_specs=P(AXIS), out_specs=_out_spec(spec),
        axis_names={AXIS}, check_vma=False,
    )(z.astype(jnp.float32))


def sharded_grad_z(spec: QSpec, grad_w, ms: int):
    """Q^T g; g has spec.shape (any sharding — in_specs reshards to the
    major axis); returns (n,) f32 sharded P('model'). Zero collectives
    beyond the input reshard (none when g is already major-sharded)."""
    _check(spec, ms)

    def local(gl):
        gm = jnp.moveaxis(gl, spec.major_axis, 0).reshape(-1)  # (m_blk,)
        g_pad = jnp.pad(gm.astype(jnp.float32),
                        (0, spec.m_pad_loc - spec.m_blk))
        nc = _num_chunks(spec)
        rpc = -(-spec.m_pad_loc // nc)
        nloc = spec.nw_loc * spec.window

        def step(gz, c):
            gidx, vals = _chunk_rows(spec, c, rpc)
            rows = jnp.minimum(c * rpc + jnp.arange(rpc), spec.m_pad_loc - 1)
            gc = g_pad[rows]
            # clamped tail rows repeat row m_pad_loc-1: zero their updates
            live = (c * rpc + jnp.arange(rpc)) < spec.m_pad_loc
            upd = (vals * (gc * live.astype(jnp.float32))[:, None]
                   ).reshape(-1)
            return gz.at[gidx.reshape(-1)].add(upd), None

        gz, _ = jax.lax.scan(step, jnp.zeros((nloc,), jnp.float32),
                             jnp.arange(nc))
        return gz

    return jax.shard_map(
        local, in_specs=_out_spec(spec), out_specs=P(AXIS),
        axis_names={AXIS}, check_vma=False,
    )(grad_w)
