"""Distribution-aware reconstruction: shard_map over the 'model' axis.

GSPMD cannot partition the scatter in ``grad_z = Q^T grad_w``, and a
flat-row-sharded weight must be RESHARDED to its consumer layout — an
all-gather of the full tensor through a replicated f32 intermediate
(measured 14 GB/device/tensor on qwen3-14b).  Both problems disappear
with the sharding-major layout (QSpec.major_axis/shard_count):

 - shard k owns rows [k·m_pad_loc, (k+1)·m_pad_loc) which read ONLY its
   own ``nw_loc`` z windows — the gather/scatter is purely local;
 - those rows ARE the k-th block of the tensor's sharded axis, so the
   local reshape+moveaxis emits the weight block in consumer layout and
   ``out_specs`` reassembles the global tensor with ZERO collectives.

The shard_map is entered without an explicit mesh so it composes with
the (partially-manual) context mesh of the federated round.  The
jax-version compat (top-level ``jax.shard_map`` vs the experimental API
bound to the ambient ``with mesh:`` context) is shared with the
transport collectives — ``repro.comm.shardmap.shard_map_compat`` — so
the op is exercisable on forced-multi-device CPU too.

Batched variants (``sharded_reconstruct_batched`` /
``sharded_grad_z_batched``): K stacked clients share one generation of
the chunk's hash-RNG indices/values; z rides as a (K, n_loc) slab per
shard and the per-chunk temporaries stay bounded at
O(rpc·d + K·rpc) — the chunk count scales with K so the budget in
TARGET_CHUNK_BYTES holds for any K.

Transpose path: ``sharded_grad_z`` / ``sharded_grad_z_batched``
dispatch plan-vs-scatter like the global ref path
(``core.transpose_plan.resolve_bwd_path``, env ``REPRO_BWD_PLAN``).
The cached transpose plan is shard-local BY CONSTRUCTION: all edges
into window ``w``'s coordinates come from window ``w``'s rows, and the
sharding-major layout gives each shard a contiguous block of windows —
so the (num_windows, window, deg) plan slabs enter the shard_map as
operands sharded ``P('model')`` on the window axis and each shard
gathers purely locally (zero collectives, same as the forward).
Window-chunking (``lax.map``) keeps per-chunk temporaries inside
TARGET_CHUNK_BYTES; the scatter chunks stay as the bit-exactness
oracle.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ..comm.shardmap import shard_map_compat
from ..core.qspec import QSpec, row_indices, row_values
from ..core.transpose_plan import (
    build_transpose_plan,
    plan_window_apply,
    resolve_bwd_path,
)

AXIS = "model"


TARGET_CHUNK_BYTES = 128 << 20  # bound the (rows, d) temporaries


def _shard_map(f, in_specs, out_specs):
    """The shared compat shim bound to this module's 'model' axis."""
    return shard_map_compat(f, (AXIS,), in_specs, out_specs)


def _num_chunks(spec: QSpec, nclients: int = 1) -> int:
    per_row = spec.d * 4 * 3 + nclients * 4  # idx/vals/gather + K outputs
    return max(1, min(spec.m_pad_loc,
                      (spec.m_pad_loc * per_row) // TARGET_CHUNK_BYTES))


def _chunk_live_rows(spec: QSpec, c, rpc):
    """Clamped shard-local row ids for chunk ``c`` + their live mask
    (the tail chunk repeats row m_pad_loc-1; its updates are zeroed)."""
    loc = c * rpc + jnp.arange(rpc, dtype=jnp.int32)
    rows = jnp.minimum(loc, spec.m_pad_loc - 1)
    return rows, (loc < spec.m_pad_loc).astype(jnp.float32)


def _chunk_rows(spec: QSpec, c, rpc):
    """Gather indices + values for rows [c*rpc, (c+1)*rpc) of this shard."""
    sid = jax.lax.axis_index(AXIS)
    loc, _ = _chunk_live_rows(spec, c, rpc)
    rp = (sid * spec.m_pad_loc + loc).astype(jnp.uint32)
    idx = row_indices(spec, rp)  # (rpc, d) in-window
    vals = row_values(spec, rp, dtype=jnp.float32)
    win_loc = jnp.minimum(loc // spec.rows_per_window, spec.nw_loc - 1)
    gidx = win_loc[:, None] * spec.window + idx  # local z-slice index
    return gidx, vals


def _check(spec: QSpec, ms: int):
    if spec.shard_count != ms:
        raise ValueError(
            f"spec.shard_count={spec.shard_count} != model axis size {ms}; "
            "build specs with shard_count=model_size"
        )


def _out_spec(spec: QSpec) -> P:
    dims = [None] * len(spec.shape)
    dims[spec.major_axis] = AXIS
    return P(*dims)


def _out_spec_b(spec: QSpec) -> P:
    """Weight PartitionSpec with a leading (replicated) client axis."""
    dims = [None] * (len(spec.shape) + 1)
    dims[spec.major_axis + 1] = AXIS
    return P(*dims)


def sharded_reconstruct(spec: QSpec, z, ms: int):
    """w = Q z with z sharded P('model'); returns the weight tensor
    with ``spec.shape``, sharded on its major axis. Zero collectives."""
    _check(spec, ms)
    a = spec.major_axis
    loc_moved = (spec.shape[a] // ms,
                 *spec.shape[:a], *spec.shape[a + 1:])

    def local(zl):
        zf = zl.astype(jnp.float32)
        nc = _num_chunks(spec)
        rpc = -(-spec.m_pad_loc // nc)

        def one(c):
            gidx, vals = _chunk_rows(spec, c, rpc)
            return jnp.sum(vals * zf[gidx], axis=-1)

        w = jax.lax.map(one, jnp.arange(nc)).reshape(-1)[: spec.m_blk]
        return jnp.moveaxis(w.reshape(loc_moved), 0, a)

    return _shard_map(local, P(AXIS), _out_spec(spec))(
        z.astype(jnp.float32)
    )


def sharded_reconstruct_batched(spec: QSpec, Z, ms: int):
    """W = Q z^(k), K clients at once.  ``Z``: (K, n) with the z axis
    sharded P(None, 'model'); returns (K, *spec.shape) sharded on the
    tensor's major axis.  The chunk indices/values are generated once
    per chunk and contracted against all K local z slabs — zero
    collectives, same as the single-client op."""
    _check(spec, ms)
    a = spec.major_axis
    loc_moved = (spec.shape[a] // ms,
                 *spec.shape[:a], *spec.shape[a + 1:])

    def local(zl):  # (K, n_loc)
        k = zl.shape[0]
        zf = zl.astype(jnp.float32)
        nc = _num_chunks(spec, k)
        rpc = -(-spec.m_pad_loc // nc)

        def one(c):
            gidx, vals = _chunk_rows(spec, c, rpc)
            return jax.lax.map(
                lambda z: jnp.sum(vals * z[gidx], axis=-1), zf
            )  # (K, rpc)

        w = jax.lax.map(one, jnp.arange(nc))  # (nc, K, rpc)
        w = jnp.moveaxis(w, 1, 0).reshape(k, -1)[:, : spec.m_blk]
        return jnp.moveaxis(w.reshape(k, *loc_moved), 1, a + 1)

    return _shard_map(local, P(None, AXIS), _out_spec_b(spec))(
        Z.astype(jnp.float32)
    )


# ---------------------------------------------------------------------------
# Fused shard-local draw: each shard hashes ONLY its own nw_loc windows.
# ---------------------------------------------------------------------------

def _local_draw(spec: QSpec, pl, step, qbits, qpacked=False):
    """This shard's mask bits, drawn from the hash stream at GLOBAL
    coordinates.

    The counter-hash RNG keys every bit on ``(seed, tensor_id, step,
    coord)`` with ``coord`` the global z index, so shard ``sid`` can
    draw its own contiguous slice ``[sid·n_loc, (sid+1)·n_loc)``
    (n_loc = nw_loc·window) without the replicated (n,) mask ever
    existing: the bits equal the global draw's slice EXACTLY.  ``pl``
    is the shard's probability slice — f32, b-bit wire words with
    ``qbits`` (widened-threshold integer compare, as
    ``core.sampling.sample_mask_qhash``), or with ``qpacked`` the
    shard's (n_loc/wpl,) slice of the packed uint32 lane carry
    (``comm.bitpack`` layout — lanes shard cleanly because
    ``wpl | window``), unpacked to shard-local words here.  ``step``
    broadcasts against ``pl``'s leading axes (scalar, or (K,) for the
    batched op).
    """
    from ..comm.bitpack import unpack_words
    from ..core.sampling import bernoulli_u32, mask_u32, quant_threshold_u24

    n_loc = spec.nw_loc * spec.window
    if qpacked:
        pl = unpack_words(pl, n_loc, qbits)
    sid = jax.lax.axis_index(AXIS).astype(jnp.uint32)
    coords = sid * jnp.uint32(n_loc) + jnp.arange(n_loc, dtype=jnp.uint32)
    step = jnp.asarray(step, jnp.uint32)
    u = mask_u32(spec.seed, spec.tensor_id, step[..., None], coords)
    if qbits is not None:
        thr = quant_threshold_u24(pl, qbits)
        return ((u >> jnp.uint32(8)) < thr).astype(jnp.float32)
    return bernoulli_u32(u, pl)


def sharded_sample_reconstruct(spec: QSpec, p, step, ms: int, qbits=None,
                               qpacked=False):
    """Fused w = Q·Bern(p) with the DRAW inside the shard_map body.

    ``p``: (n,) probabilities (or quantized words with ``qbits``; or
    the (n/wpl,) packed lane carry with ``qpacked``),
    sharded/shardable P('model'); ``step``: replicated uint32 draw
    word.  Each shard draws only its own ``nw_loc`` windows from the
    hash stream at global coordinates (``_local_draw``) and contracts
    them locally — no replicated (n,) mask is ever materialized, and
    the result is bit-identical to
    ``sharded_reconstruct(spec, sample_mask_hash(p, ...), ms)``.
    """
    _check(spec, ms)
    if qpacked and spec.window % (32 // qbits) != 0:
        raise ValueError(
            f"packed sharded draw needs window % (32//qbits) == 0; got "
            f"window={spec.window}, qbits={qbits}"
        )
    a = spec.major_axis
    loc_moved = (spec.shape[a] // ms,
                 *spec.shape[:a], *spec.shape[a + 1:])

    def local(pl, st):
        zf = _local_draw(spec, pl, st, qbits, qpacked=qpacked)
        nc = _num_chunks(spec)
        rpc = -(-spec.m_pad_loc // nc)

        def one(c):
            gidx, vals = _chunk_rows(spec, c, rpc)
            return jnp.sum(vals * zf[gidx], axis=-1)

        w = jax.lax.map(one, jnp.arange(nc)).reshape(-1)[: spec.m_blk]
        return jnp.moveaxis(w.reshape(loc_moved), 0, a)

    return _shard_map(local, (P(AXIS), P()), _out_spec(spec))(
        p, jnp.asarray(step, jnp.uint32)
    )


def sharded_sample_reconstruct_batched(spec: QSpec, Pr, steps, ms: int,
                                       qbits=None, qpacked=False):
    """Fused batched W = Q·Bern(p^(k)): ``Pr`` (K, n) sharded
    P(None, 'model') — or (K, n/wpl) packed lanes with ``qpacked`` —
    ``steps`` (K,) replicated draw words.  One
    in-body draw of the (K, n_loc) local mask slab (global-coordinate
    hash — bit-identical to the replicated draw's slice), one chunk
    index/value generation shared by all K clients, zero collectives.
    """
    _check(spec, ms)
    if qpacked and spec.window % (32 // qbits) != 0:
        raise ValueError(
            f"packed sharded draw needs window % (32//qbits) == 0; got "
            f"window={spec.window}, qbits={qbits}"
        )
    a = spec.major_axis
    loc_moved = (spec.shape[a] // ms,
                 *spec.shape[:a], *spec.shape[a + 1:])

    def local(pl, st):  # (K, n_loc), (K,)
        k = pl.shape[0]
        zf = _local_draw(spec, pl, st, qbits, qpacked=qpacked)
        nc = _num_chunks(spec, k)
        rpc = -(-spec.m_pad_loc // nc)

        def one(c):
            gidx, vals = _chunk_rows(spec, c, rpc)
            return jax.lax.map(
                lambda z: jnp.sum(vals * z[gidx], axis=-1), zf
            )  # (K, rpc)

        w = jax.lax.map(one, jnp.arange(nc))  # (nc, K, rpc)
        w = jnp.moveaxis(w, 1, 0).reshape(k, -1)[:, : spec.m_blk]
        return jnp.moveaxis(w.reshape(k, *loc_moved), 1, a + 1)

    return _shard_map(local, (P(None, AXIS), P()), _out_spec_b(spec))(
        Pr, jnp.asarray(steps, jnp.uint32)
    )


# ---------------------------------------------------------------------------
# Plan-path transpose: shard-local gather over the cached plan slabs.
# ---------------------------------------------------------------------------

def _plan_num_chunks(spec: QSpec, deg: int) -> int:
    """Window-chunk count bounding the (wpc·window·deg) gather temps."""
    per_win = spec.window * deg * 12  # rows + vals + gathered f32
    return max(1, min(spec.nw_loc,
                      (spec.nw_loc * per_win) // TARGET_CHUNK_BYTES))


def _plan_local(spec: QSpec, rows_l, vals_l, deg: int, g_pad):
    """One shard's grad_z: gather + deg-reduce over its local windows.

    ``rows_l`` (nw_loc, window·deg) block-local source rows, ``vals_l``
    (nw_loc, window, deg), ``g_pad`` (m_pad_loc,).  Window-chunked via
    ``lax.map`` when the gather temporaries exceed TARGET_CHUNK_BYTES.
    """
    nw_loc, rpw = spec.nw_loc, spec.rows_per_window
    nc = _plan_num_chunks(spec, deg)
    if nc == 1:
        return plan_window_apply(spec, rows_l, vals_l, deg, g_pad, nw_loc)
    wpc = -(-nw_loc // nc)
    nc = -(-nw_loc // wpc)
    pad = nc * wpc - nw_loc
    rows_c = jnp.pad(rows_l, ((0, pad), (0, 0))).reshape(nc, wpc, -1)
    vals_c = jnp.pad(vals_l, ((0, pad), (0, 0), (0, 0))).reshape(
        nc, wpc, spec.window, deg
    )
    g_c = jnp.pad(g_pad, (0, pad * rpw)).reshape(nc, wpc * rpw)
    out = jax.lax.map(
        lambda xs: plan_window_apply(spec, xs[0], xs[1], deg, xs[2], wpc),
        (rows_c, vals_c, g_c),
    )
    return out.reshape(-1)[: nw_loc * spec.window]


def _plan_operands(spec: QSpec, order: str):
    """Global plan slabs (jnp) + deg; shard_map slices the window axis."""
    plan = build_transpose_plan(spec, order)
    rows = jnp.asarray(plan.rows.reshape(spec.num_windows, -1))
    return rows, jnp.asarray(plan.vals), plan.deg


def _sharded_grad_z_plan(spec: QSpec, grad_w, order: str):
    rows, vals, deg = _plan_operands(spec, order)

    def local(gl, rows_l, vals_l):
        gm = jnp.moveaxis(gl, spec.major_axis, 0).reshape(-1)
        g_pad = jnp.pad(gm.astype(jnp.float32),
                        (0, spec.m_pad_loc - spec.m_blk))
        return _plan_local(spec, rows_l, vals_l, deg, g_pad)

    return _shard_map(
        local,
        (_out_spec(spec), P(AXIS, None), P(AXIS, None, None)),
        P(AXIS),
    )(grad_w, rows, vals)


def _sharded_grad_z_batched_plan(spec: QSpec, grad_W, order: str):
    rows, vals, deg = _plan_operands(spec, order)

    def local(gl, rows_l, vals_l):  # gl (K, local tensor block)
        k = gl.shape[0]
        gm = jnp.moveaxis(gl, spec.major_axis + 1, 1).reshape(k, -1)
        g_pad = jnp.pad(gm.astype(jnp.float32),
                        ((0, 0), (0, spec.m_pad_loc - spec.m_blk)))
        return jax.lax.map(
            lambda g: _plan_local(spec, rows_l, vals_l, deg, g), g_pad
        )

    return _shard_map(
        local,
        (_out_spec_b(spec), P(AXIS, None), P(AXIS, None, None)),
        P(None, AXIS),
    )(grad_W, rows, vals)


def sharded_grad_z(spec: QSpec, grad_w, ms: int):
    """Q^T g; g has spec.shape (any sharding — in_specs reshards to the
    major axis); returns (n,) f32 sharded P('model'). Zero collectives
    beyond the input reshard (none when g is already major-sharded).

    Dispatches plan (shard-local gather) vs scatter (oracle) via
    ``resolve_bwd_path()``.
    """
    _check(spec, ms)
    kind, order = resolve_bwd_path()
    if kind == "plan":
        return _sharded_grad_z_plan(spec, grad_w, order)

    def local(gl):
        gm = jnp.moveaxis(gl, spec.major_axis, 0).reshape(-1)  # (m_blk,)
        g_pad = jnp.pad(gm.astype(jnp.float32),
                        (0, spec.m_pad_loc - spec.m_blk))
        nc = _num_chunks(spec)
        rpc = -(-spec.m_pad_loc // nc)
        nloc = spec.nw_loc * spec.window

        def step(gz, c):
            gidx, vals = _chunk_rows(spec, c, rpc)
            rows, live = _chunk_live_rows(spec, c, rpc)
            upd = (vals * (g_pad[rows] * live)[:, None]).reshape(-1)
            return gz.at[gidx.reshape(-1)].add(upd), None

        gz, _ = jax.lax.scan(step, jnp.zeros((nloc,), jnp.float32),
                             jnp.arange(nc))
        return gz

    return _shard_map(local, _out_spec(spec), P(AXIS))(grad_w)


def sharded_grad_z_batched(spec: QSpec, grad_W, ms: int):
    """Q^T g per client; ``grad_W``: (K, *spec.shape); returns (K, n)
    f32 sharded P(None, 'model').  One generation of the chunk
    indices/values (scatter) or one shared plan slab (plan, default)
    feeds all K clients; dispatch via ``resolve_bwd_path()``."""
    _check(spec, ms)
    kind, order = resolve_bwd_path()
    if kind == "plan":
        return _sharded_grad_z_batched_plan(spec, grad_W, order)

    def local(gl):  # (K, local tensor block)
        k = gl.shape[0]
        gm = jnp.moveaxis(gl, spec.major_axis + 1, 1).reshape(k, -1)
        g_pad = jnp.pad(gm.astype(jnp.float32),
                        ((0, 0), (0, spec.m_pad_loc - spec.m_blk)))
        nc = _num_chunks(spec, k)
        rpc = -(-spec.m_pad_loc // nc)
        nloc = spec.nw_loc * spec.window

        def step(gz, c):
            gidx, vals = _chunk_rows(spec, c, rpc)
            rows, live = _chunk_live_rows(spec, c, rpc)
            flat = gidx.reshape(-1)

            def one(args):
                gz_k, g_k = args
                upd = (vals * (g_k[rows] * live)[:, None]).reshape(-1)
                return gz_k.at[flat].add(upd)

            return jax.lax.map(one, (gz, g_pad)), None

        gz, _ = jax.lax.scan(step, jnp.zeros((k, nloc), jnp.float32),
                             jnp.arange(nc))
        return gz

    return _shard_map(local, _out_spec_b(spec), P(None, AXIS))(grad_W)
