"""Pure-jnp oracles for the kernels package.

``reconstruct_ref`` / ``grad_z_ref`` are the ground truth for the
Pallas ``qz_reconstruct`` kernels — every kernel test sweeps
shapes/dtypes and ``assert_allclose``s against these.
"""

from ..core.reconstruct import grad_z_ref, materialize_q, reconstruct_ref

__all__ = ["reconstruct_ref", "materialize_q", "grad_z_ref"]
