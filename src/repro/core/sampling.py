"""Training-by-sampling primitives (paper §1.3).

Scores ``s`` live in R^n; probabilities ``p = f(s)`` with the clipped
ReLU ``f(x) = min(max(x, 0), 1)``; masks ``z ~ Bern(p)`` are resampled
every forward pass.  Gradients use the straight-through estimator: the
backward pass treats ``z`` as ``p``, and the clip zeroes coordinates
outside (0, 1) — exactly the paper's
``∇_s L = (∇_w L ⊙ Q) ⊙ 1_{0<p<1}``.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def clip_probs(s):
    """p = f(s), the ReLU clipped at 1. Gradient is 1_{0<=s<=1}."""
    return jnp.clip(s, 0.0, 1.0)


def sample_mask(p, key):
    """z ~ Bern(p), float32 in {0,1}. Not differentiable."""
    u = jax.random.uniform(key, p.shape, dtype=jnp.float32)
    return (u <= p).astype(jnp.float32)


def sample_mask_st(p, key):
    """Straight-through Bernoulli: forward z, backward identity in p."""
    z = sample_mask(p, key)
    return p + jax.lax.stop_gradient(z - p)


def expected_mask(p, key=None):
    """ContinuousModel variant: use p itself (no sampling)."""
    del key
    return p


def discretize_mask(p):
    """Round-to-nearest mask (paper App. A 'discretized network')."""
    return (p >= 0.5).astype(jnp.float32)


def init_scores(key, n, *, dist: str = "uniform", beta_a: float = 1.0,
                beta_b: float = 1.0):
    """p(0) ~ U(0,1)^n by default (paper); beta(a,b) for App. A sweeps."""
    if dist == "uniform":
        return jax.random.uniform(key, (n,), dtype=jnp.float32)
    if dist == "beta":
        return jax.random.beta(key, beta_a, beta_b, (n,), dtype=jnp.float32)
    raise ValueError(f"unknown init dist {dist!r}")
