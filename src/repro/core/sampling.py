"""Training-by-sampling primitives (paper §1.3).

Scores ``s`` live in R^n; probabilities ``p = f(s)`` with the clipped
ReLU ``f(x) = min(max(x, 0), 1)``; masks ``z ~ Bern(p)`` are resampled
every forward pass.  Gradients use the straight-through estimator: the
backward pass treats ``z`` as ``p``, and the clip zeroes coordinates
outside (0, 1) — exactly the paper's
``∇_s L = (∇_w L ⊙ Q) ⊙ 1_{0<p<1}``.

RNG: every Bernoulli draw comes from the counter-based hash RNG
(``core.hashrng``), NOT ``jax.random``.  The bit at coordinate ``j`` of
tensor ``tensor_id`` at draw counter ``step`` is

    z_j = 1[ uniform(hash_u32(seed, tensor_id, MASK_CTR, step, j)) <= p_j ]

so the pure-jnp oracle and the Pallas kernels regenerate *identical*
bits from ``(seed, tensor_id, step)`` alone — a window block only needs
its coordinate range and the traced ``step`` word, never a (n,) mask
operand.  ``step`` is a single uint32 draw counter; callers build it
from their PRNG key (``key_word``) plus round/client/local-step
counters threaded through their scans (``core.federated.local_update``,
``train.fit``).  ``MASK_CTR`` keeps the mask stream disjoint from the
Q-generation counter space (``core.qspec``).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .hashrng import bernoulli_u32, hash_u32

# Counter-space role of the mask stream: hash words are
# (seed, tensor_id, MASK_CTR, step, coord) — a 5-word combine, disjoint
# from qspec's 4-word (seed, tensor_id, row, ctr) Q streams.
MASK_CTR = 0x0008_0000


def clip_probs(s):
    """p = f(s), the ReLU clipped at 1. Gradient is 1_{0<=s<=1}."""
    return jnp.clip(s, 0.0, 1.0)


# ---------------------------------------------------------------------------
# Draw words (uint32 counters)
# ---------------------------------------------------------------------------

def key_word(key):
    """Collapse a jax PRNG key (typed or raw uint32 data) to one u32."""
    arr = jnp.asarray(key)
    if jnp.issubdtype(arr.dtype, jax.dtypes.prng_key):
        arr = jax.random.key_data(key)
    arr = arr.astype(jnp.uint32).reshape(-1)
    return hash_u32(*(arr[i] for i in range(arr.shape[0])))


def as_word(key_or_word):
    """Accept a PRNG key, an integer, or an existing u32 word."""
    arr = jnp.asarray(key_or_word)
    if jnp.issubdtype(arr.dtype, jax.dtypes.prng_key) or arr.ndim > 0:
        return key_word(key_or_word)
    return arr.astype(jnp.uint32)


def fold_word(word, *counters):
    """Derive a sub-word: hash-combine counters into a draw word."""
    return hash_u32(word, *counters)


# ---------------------------------------------------------------------------
# The mask stream
# ---------------------------------------------------------------------------

def mask_u32(seed, tensor_id, step, coords):
    """The u32 mask stream at the given coordinates.

    ``seed``/``tensor_id`` are static ints (folded at trace time),
    ``step`` is the traced draw counter, ``coords`` the (traced or
    static) coordinate array — the same function body runs in the jnp
    oracle and inside Pallas kernel blocks.
    """
    return hash_u32(seed, tensor_id, MASK_CTR, step, coords)


def sample_mask_hash(p, seed, tensor_id, step):
    """z ~ Bern(p) from the hash stream, float32 in {0,1}. Not
    differentiable; ``p`` has shape (..., n) with coordinates on the
    last axis and ``step`` broadcasting against the leading axes."""
    n = p.shape[-1]
    coords = jnp.arange(n, dtype=jnp.uint32)
    step = jnp.asarray(step, jnp.uint32)
    u = mask_u32(seed, tensor_id, step[..., None], coords)
    return bernoulli_u32(u, p)


def sample_mask_st_hash(p, seed, tensor_id, step):
    """Straight-through hash Bernoulli: forward z, backward identity."""
    z = sample_mask_hash(p, seed, tensor_id, step)
    return p + jax.lax.stop_gradient(z - p)


def sample_mask(p, key):
    """z ~ Bern(p), float32 in {0,1}. Not differentiable.

    Key-based convenience wrapper over the hash stream (seed/tensor 0);
    prefer ``sample_mask_hash`` where a QSpec identifies the tensor.
    """
    return sample_mask_hash(p, 0, 0, as_word(key))


def sample_mask_st(p, key):
    """Straight-through Bernoulli: forward z, backward identity in p."""
    z = sample_mask(p, key)
    return p + jax.lax.stop_gradient(z - p)


def expected_mask(p, key=None):
    """ContinuousModel variant: use p itself (no sampling)."""
    del key
    return p


def discretize_mask(p):
    """Round-to-nearest mask (paper App. A 'discretized network')."""
    return (p >= 0.5).astype(jnp.float32)


def init_scores(key, n, *, dist: str = "uniform", beta_a: float = 1.0,
                beta_b: float = 1.0):
    """p(0) ~ U(0,1)^n by default (paper); beta(a,b) for App. A sweeps."""
    if dist == "uniform":
        return jax.random.uniform(key, (n,), dtype=jnp.float32)
    if dist == "beta":
        return jax.random.beta(key, beta_a, beta_b, (n,), dtype=jnp.float32)
    raise ValueError(f"unknown init dist {dist!r}")
