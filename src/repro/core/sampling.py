"""Training-by-sampling primitives (paper §1.3).

Scores ``s`` live in R^n; probabilities ``p = f(s)`` with the clipped
ReLU ``f(x) = min(max(x, 0), 1)``; masks ``z ~ Bern(p)`` are resampled
every forward pass.  Gradients use the straight-through estimator: the
backward pass treats ``z`` as ``p``, and the clip zeroes coordinates
outside (0, 1) — exactly the paper's
``∇_s L = (∇_w L ⊙ Q) ⊙ 1_{0<p<1}``.

RNG: every Bernoulli draw comes from the counter-based hash RNG
(``core.hashrng``), NOT ``jax.random``.  The bit at coordinate ``j`` of
tensor ``tensor_id`` at draw counter ``step`` is

    z_j = 1[ uniform(hash_u32(seed, tensor_id, MASK_CTR, step, j)) <= p_j ]

so the pure-jnp oracle and the Pallas kernels regenerate *identical*
bits from ``(seed, tensor_id, step)`` alone — a window block only needs
its coordinate range and the traced ``step`` word, never a (n,) mask
operand.  When the server broadcast is quantized (``comm.downlink``),
the SAME draw word decides the bit by an integer compare against the
widened threshold (``sample_mask_qhash``) — bit-identical to
``bernoulli_u32`` on the codec's decoded probability, with no f32
score slab on the client draw path.  ``step`` is a single uint32 draw counter; callers build it
from their PRNG key (``key_word``) plus round/client/local-step
counters threaded through their scans (``core.federated.local_update``,
``train.fit``).  ``MASK_CTR`` keeps the mask stream disjoint from the
Q-generation counter space (``core.qspec``).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from .hashrng import bernoulli_u32, hash_u32

# Counter-space role of the mask stream: hash words are
# (seed, tensor_id, MASK_CTR, step, coord) — a 5-word combine, disjoint
# from qspec's 4-word (seed, tensor_id, row, ctr) Q streams.
MASK_CTR = 0x0008_0000

# Counter space of the downlink-quantization dither stream
# (comm.downlink): words are (seed, tensor_id, QUANT_DITHER_CTR, word,
# coord), disjoint from MASK_CTR so the server's encode dither can
# never alias a mask draw.  Dither/determinism contract: the dither is
# PSEUDORANDOM BUT SHARED — every party (the vmap server, each shard_map
# shard re-encoding the replicated aggregate, the test oracle)
# regenerates the identical dither from (spec.seed, tensor_id, round
# word, coord), so the encoded broadcast is bit-identical everywhere
# with ZERO extra wire bits, while the rounding error still decorrelates
# across coordinates and rounds (no systematic drift of the mean, which
# deterministic round-to-nearest would reintroduce).
QUANT_DITHER_CTR = 0x0010_0000

# Further counter spaces of the hash stream live with their consumers
# but share this registry discipline (each CTR word keeps its stream
# disjoint from all others):
#   COHORT_CTR  = 0x0020_0000  fault.population — K-of-N cohort draws
#   FAULT_CTR   = 0x0028_0000  fault.plan — per-(round, client) faults
#   CORRUPT_CTR = 0x0030_0000  fault.plan — lane-corruption garbage


def clip_probs(s):
    """p = f(s), the ReLU clipped at 1. Gradient is 1_{0<=s<=1}."""
    return jnp.clip(s, 0.0, 1.0)


# ---------------------------------------------------------------------------
# Draw words (uint32 counters)
# ---------------------------------------------------------------------------

def key_word(key):
    """Collapse a jax PRNG key (typed or raw uint32 data) to one u32."""
    arr = jnp.asarray(key)
    if jnp.issubdtype(arr.dtype, jax.dtypes.prng_key):
        arr = jax.random.key_data(key)
    arr = arr.astype(jnp.uint32).reshape(-1)
    return hash_u32(*(arr[i] for i in range(arr.shape[0])))


def as_word(key_or_word):
    """Accept a PRNG key, an integer, or an existing u32 word."""
    arr = jnp.asarray(key_or_word)
    if jnp.issubdtype(arr.dtype, jax.dtypes.prng_key) or arr.ndim > 0:
        return key_word(key_or_word)
    return arr.astype(jnp.uint32)


def fold_word(word, *counters):
    """Derive a sub-word: hash-combine counters into a draw word."""
    return hash_u32(word, *counters)


# ---------------------------------------------------------------------------
# The mask stream
# ---------------------------------------------------------------------------

def mask_u32(seed, tensor_id, step, coords):
    """The u32 mask stream at the given coordinates.

    ``seed``/``tensor_id`` are static ints (folded at trace time),
    ``step`` is the traced draw counter, ``coords`` the (traced or
    static) coordinate array — the same function body runs in the jnp
    oracle and inside Pallas kernel blocks.
    """
    return hash_u32(seed, tensor_id, MASK_CTR, step, coords)


def sample_mask_hash(p, seed, tensor_id, step):
    """z ~ Bern(p) from the hash stream, float32 in {0,1}. Not
    differentiable; ``p`` has shape (..., n) with coordinates on the
    last axis and ``step`` broadcasting against the leading axes."""
    n = p.shape[-1]
    coords = jnp.arange(n, dtype=jnp.uint32)
    step = jnp.asarray(step, jnp.uint32)
    u = mask_u32(seed, tensor_id, step[..., None], coords)
    return bernoulli_u32(u, p)


def sample_mask_st_hash(p, seed, tensor_id, step):
    """Straight-through hash Bernoulli: forward z, backward identity."""
    z = sample_mask_hash(p, seed, tensor_id, step)
    return p + jax.lax.stop_gradient(z - p)


def quant_threshold_u24(q, bits: int):
    """Widen a b-bit probability word to the 24-bit draw threshold.

    ``T(q) = floor(q * 2^24 / (2^bits - 1))``, exact in uint32
    arithmetic via ``a + a // S`` with ``a = q << (24 - bits)`` and
    ``S = 2^bits - 1`` (since ``a * 2^bits / S = a + a/S``) — no 64-bit
    intermediate, so the same expression runs inside Pallas kernel
    blocks.  ``T(0) = 0`` and ``T(S) = 2^24``, so the endpoints stay
    exact (never/always fire).  The decoded probability ``T * 2^-24``
    is exactly representable in f32, which is what makes the integer
    compare below bit-identical to ``bernoulli_u32`` on the decoded
    value: ``u32_to_uniform(u) <= T*2^-24  <=>  (u >> 8) < T``.
    """
    if not 1 <= bits <= 24:
        raise ValueError(f"quantized probability words need 1..24 bits, "
                         f"got {bits}")
    a = jnp.asarray(q).astype(jnp.uint32) << np.uint32(24 - bits)
    return a + a // np.uint32((1 << bits) - 1)


def quant_threshold_u24_dyn(q, bits):
    """``quant_threshold_u24`` with a TRACED bit width.

    Same exact integer identity ``T(q) = a + a // S`` with
    ``a = q << (24 - b)`` and ``S = 2^b - 1``, but ``bits`` may be a
    traced uint32 array (a per-round scheduled width, broadcasting
    against ``q``) — uint32 shifts by traced counts and divisions by
    traced divisors are exact, so for any concrete b in [1, 16] the
    result is bit-identical to the static ``quant_threshold_u24(q, b)``.
    This is what lets the downlink schedules (``core.federated``,
    ``FederatedConfig.downlink_schedule``) re-quantize every round at a
    per-tensor width while the R-round scan still compiles once.
    """
    b = jnp.asarray(bits).astype(jnp.uint32)
    a = jnp.asarray(q).astype(jnp.uint32) << (jnp.uint32(24) - b)
    return a + a // ((jnp.uint32(1) << b) - jnp.uint32(1))


def sample_mask_qhash(q, bits: int, seed, tensor_id, step):
    """z ~ Bern(T(q)/2^24) drawn straight from QUANTIZED probability
    words — the integer compare of the draw word against the widened
    threshold.  No dequantized f32 probability array exists: ``q`` is
    the b-bit wire word per coordinate (any uint dtype), and the draw
    is ``(hash_word >> 8) < quant_threshold_u24(q)``.  Bit-identical to
    ``sample_mask_hash(decode(q), ...)`` where ``decode(q) =
    quant_threshold_u24(q, bits) * 2^-24`` (see ``comm.downlink``).
    Not differentiable; shapes/broadcasting as ``sample_mask_hash``.
    """
    n = jnp.shape(q)[-1]
    coords = jnp.arange(n, dtype=jnp.uint32)
    step = jnp.asarray(step, jnp.uint32)
    u = mask_u32(seed, tensor_id, step[..., None], coords)
    thr = quant_threshold_u24(q, bits)
    return ((u >> np.uint32(8)) < thr).astype(jnp.float32)


def sample_mask(p, key):
    """z ~ Bern(p), float32 in {0,1}. Not differentiable.

    Key-based convenience wrapper over the hash stream (seed/tensor 0);
    prefer ``sample_mask_hash`` where a QSpec identifies the tensor.
    """
    return sample_mask_hash(p, 0, 0, as_word(key))


def sample_mask_st(p, key):
    """Straight-through Bernoulli: forward z, backward identity in p."""
    z = sample_mask(p, key)
    return p + jax.lax.stop_gradient(z - p)


def expected_mask(p, key=None):
    """ContinuousModel variant: use p itself (no sampling)."""
    del key
    return p


def discretize_mask(p):
    """Round-to-nearest mask (paper App. A 'discretized network')."""
    return (p >= 0.5).astype(jnp.float32)


def init_scores(key, n, *, dist: str = "uniform", beta_a: float = 1.0,
                beta_b: float = 1.0):
    """p(0) ~ U(0,1)^n by default (paper); beta(a,b) for App. A sweeps."""
    if dist == "uniform":
        return jax.random.uniform(key, (n,), dtype=jnp.float32)
    if dist == "beta":
        return jax.random.beta(key, beta_a, beta_b, (n,), dtype=jnp.float32)
    raise ValueError(f"unknown init dist {dist!r}")
