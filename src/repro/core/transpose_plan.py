"""Per-spec caches for Q's generation plans: rows, and the transpose.

Two spec-static artifacts are built here ONCE per ``QSpec`` (numpy, at
first use) and reused by every trace that touches the spec:

``row_plan(spec)`` — the forward row plan ``(gidx (m_pad, d) global
z-indices, vals (m_pad, d) f32)``.  ``core.reconstruct`` previously
recomputed this (hash + Box–Muller over all m_pad rows) inside every
traced call, so a fwd+bwd pair in one jit generated Q twice and every
retrace paid it again; cached as numpy it becomes a trace-time
constant shared by forward and backward.

``build_transpose_plan(spec)`` — the TENTPOLE of the gather backward:
the inversion of the row plan into per-coordinate incoming-edge lists.
Every nonzero of padded row ``rp`` lands in window ``w = rp //
rows_per_window`` (rows tile windows contiguously in the padded row
space, across shard blocks too), so Q^T factors into ``num_windows``
independent ``(window, rows_per_window)`` blocks.  A one-time counting
sort over the ``m_pad·d`` edges produces, for every z coordinate, the
degree-padded list of (window-local source row, coefficient) pairs:

    rows (num_windows, window, deg) int32   in [0, rows_per_window)
    vals (num_windows, window, deg) f32     0.0 on padding entries

with ``deg = max_in_degree`` over all coordinates (exact, computed by
the counting sort; expected value ``rows_per_window·d/window =
compression·d``).  Padding entries point at row 0 with value 0, so a
consumer may gather them unconditionally.  Edges of rows beyond the
valid range (``padded_row_valid`` false) are EXCLUDED at build time —
they carry hash-generated values but always multiply a zero cotangent.

The backward then becomes a batch-friendly gather + reduction,

    grad_z[w·window + c] = sum_e vals[w, c, e] · g_pad[w·rpw + rows[w, c, e]]

instead of a scatter-add of m_pad·d updates (see
``core.reconstruct.grad_z_plan_ref``).

Ordering contract: floating-point addition is not associative, so the
EDGE ORDER inside each coordinate's list is part of the numerics.

 - ``order='canonical'`` (default): edges sorted by (source row, slot).
   Deterministic and layout-independent — the same spec always sums in
   the same order, giving bit-reproducible runs across plan consumers
   that reduce the deg axis sequentially.
 - ``order='slot'``: edges sorted by (slot k, source row) — a second
   deterministic ordering used to test the cross-order ``allclose``
   contract.

Exact equality holds per ordering mode (same plan -> same bits);
across modes, and against the scatter oracle, the contract is
``allclose`` (see tests/test_transpose_plan.py).

``build_block_plan(spec, bm)`` re-bins the same edges by the Pallas
backward's row-block grid (``kernels.qz_reconstruct``): cell (window
i, block j, coordinate c) holds the edges whose source row falls in
rows [j·bm, (j+1)·bm) of window i, rows stored block-relative so the
kernel's gather is an in-block one-hot contraction.

Path gating: ``resolve_bwd_path()`` decides scatter vs plan at TRACE
time.  The ``REPRO_BWD_PLAN`` env var overrides the process default
(``set_default_bwd_path``), mirroring ``REPRO_RECONSTRUCT_IMPL`` — an
already-compiled shape keeps its path.  The scatter path is kept as
the bit-exactness oracle.
"""

from __future__ import annotations

import functools
import os
from dataclasses import dataclass

import numpy as np
import jax
import jax.numpy as jnp

from .qspec import QSpec, padded_row_valid, padded_row_window, row_indices, row_values

# ---------------------------------------------------------------------------
# Backward-path gate (trace-time, env-overridable)
# ---------------------------------------------------------------------------

_ORDERS = ("canonical", "slot")
# accepted spellings of the gate; "plan" is canonical-order
_VALID_BWD_PATHS = ("plan", "plan:canonical", "plan:slot", "scatter")
_DEFAULT_BWD_PATH = "plan"


def set_default_bwd_path(path: str) -> None:
    """Set the process-wide default transpose path (plan | scatter)."""
    global _DEFAULT_BWD_PATH
    if path not in _VALID_BWD_PATHS:
        raise ValueError(
            f"unknown bwd path {path!r}; valid paths: "
            f"{', '.join(_VALID_BWD_PATHS)}"
        )
    _DEFAULT_BWD_PATH = path


def default_bwd_path() -> str:
    """Effective transpose path: ``REPRO_BWD_PLAN`` env overrides the
    ``set_default_bwd_path`` process default — read at trace time, so
    flipping it between jit calls of different closures needs no code
    edit (an already-compiled function keeps its path)."""
    env = os.environ.get("REPRO_BWD_PLAN")
    if env is None:
        return _DEFAULT_BWD_PATH
    if env not in _VALID_BWD_PATHS:
        raise ValueError(
            f"REPRO_BWD_PLAN={env!r} is not a valid bwd path; valid: "
            f"{', '.join(_VALID_BWD_PATHS)}"
        )
    return env


def resolve_bwd_path(path: str | None = None):
    """``(kind, order)`` for a path string (default: the gated one).

    kind is 'plan' or 'scatter'; order is the plan edge ordering
    ('canonical' | 'slot', None for scatter).
    """
    path = path or default_bwd_path()
    if path not in _VALID_BWD_PATHS:
        raise ValueError(
            f"unknown bwd path {path!r}; valid paths: "
            f"{', '.join(_VALID_BWD_PATHS)}"
        )
    if path == "scatter":
        return "scatter", None
    _, _, order = path.partition(":")
    return "plan", order or "canonical"


# ---------------------------------------------------------------------------
# Cached forward row plan (spec-static)
# ---------------------------------------------------------------------------

# Bounded like ops._vmap_cores: eviction costs a one-time rebuild,
# never correctness.  Entries are O(m_pad·d) numpy, so keep it small.
@functools.lru_cache(maxsize=32)
def row_plan(spec: QSpec):
    """Hash-RNG indices/values for ALL padded rows, built once (numpy).

    Returns ``(gidx (m_pad, d) int32 global z-indices, vals (m_pad, d)
    f32)`` — byte-identical to the traced generation (same jnp hash
    ops, evaluated eagerly and frozen).
    """
    rp = np.arange(spec.m_pad, dtype=np.uint32)
    # the first build may happen inside a trace (jit/vmap/grad of a
    # consumer): force eager evaluation so the result is concrete numpy
    with jax.ensure_compile_time_eval():
        win = np.asarray(padded_row_window(spec, rp.astype(np.int32)))
        idx = np.asarray(row_indices(spec, rp))
        vals = np.asarray(row_values(spec, rp, dtype=jnp.float32))
    gidx = win[:, None].astype(np.int64) * spec.window + idx
    return gidx.astype(np.int32), vals


# ---------------------------------------------------------------------------
# Transpose plans
# ---------------------------------------------------------------------------

@dataclass(frozen=True, eq=False)
class TransposePlan:
    """Inverted row plan: per-coordinate padded incoming-edge lists.

    ``rows[w, c, e]`` is the window-local source row (in
    [0, rows_per_window)) of edge ``e`` into coordinate ``w·window+c``;
    ``vals[w, c, e]`` its Q coefficient (0.0 on padding entries, which
    point at row 0).  ``counts`` is the exact per-coordinate in-degree
    (n,), ``deg`` its max (>= 1).
    """

    order: str
    deg: int
    rows: np.ndarray  # (num_windows, window, deg) int32
    vals: np.ndarray  # (num_windows, window, deg) f32
    counts: np.ndarray  # (n,) int32

    @property
    def n_edges(self) -> int:
        return int(self.counts.sum())


@dataclass(frozen=True, eq=False)
class BlockPlan:
    """Transpose plan re-binned to the Pallas (window, row-block) grid.

    ``rows[i, j, c, e]`` is BLOCK-relative (in [0, bm)): the source row
    of edge ``e`` into in-window coordinate ``c``, among the rows
    [j·bm, (j+1)·bm) of window i.  ``deg`` is the max in-degree over
    all (window, block, coordinate) cells.
    """

    order: str
    bm: int
    bpw: int
    deg: int
    rows: np.ndarray  # (num_windows, bpw, window, deg) int32
    vals: np.ndarray  # (num_windows, bpw, window, deg) f32


def _edges(spec: QSpec, order: str):
    """Flat valid-edge arrays (key basis, src row local, vals) in the
    requested enumeration order; counting-sort key is added by callers."""
    if order not in _ORDERS:
        raise ValueError(f"unknown plan order {order!r}; valid: {_ORDERS}")
    gidx, vals = row_plan(spec)
    rp = np.arange(spec.m_pad, dtype=np.int64)
    with jax.ensure_compile_time_eval():
        valid = np.asarray(padded_row_valid(spec, rp))
    r_local = (rp % spec.rows_per_window).astype(np.int64)
    coord = gidx.astype(np.int64)  # (m_pad, d) global z coordinate
    rows2 = np.broadcast_to(r_local[:, None], coord.shape)
    mask2 = np.broadcast_to(valid[:, None], coord.shape)
    if order == "canonical":  # row-major: per coord sorted by (row, k)
        c, r, v, mk = (coord.reshape(-1), rows2.reshape(-1),
                       vals.reshape(-1), mask2.reshape(-1))
    else:  # 'slot': k-major enumeration -> per coord sorted by (k, row)
        c, r, v, mk = (coord.T.reshape(-1), rows2.T.reshape(-1),
                       vals.T.reshape(-1), mask2.T.reshape(-1))
    return c[mk], r[mk], v[mk]


def _pack(keys, rows, vals, num_cells: int):
    """Counting-sort edges by cell key into degree-padded (num_cells,
    deg) slabs.  Returns (rows_pad, vals_pad, counts, deg)."""
    perm = np.argsort(keys, kind="stable")  # stable: keeps edge order
    ks, rs, vs = keys[perm], rows[perm], vals[perm]
    counts = np.bincount(ks, minlength=num_cells).astype(np.int64)
    deg = int(max(1, counts.max() if counts.size else 1))
    starts = np.concatenate(([0], np.cumsum(counts)[:-1]))
    pos = np.arange(ks.size, dtype=np.int64) - starts[ks]
    rows_pad = np.zeros((num_cells, deg), np.int32)
    vals_pad = np.zeros((num_cells, deg), np.float32)
    rows_pad[ks, pos] = rs
    vals_pad[ks, pos] = vs
    return rows_pad, vals_pad, counts.astype(np.int32), deg


def plan_window_apply(spec: QSpec, rows, vals, deg: int, g, nwin: int):
    """The ONE window-blocked plan-apply expression: gather + deg-sum.

    ``rows`` (nwin, window·deg) window-LOCAL source rows, ``vals``
    (nwin, window, deg), ``g`` (nwin·rows_per_window,) the cotangent
    slice those windows own; returns (nwin·window,) grad-z.

    Every window-blocked consumer (the chunked backward in
    ``kernels.ops``, the shard-local backward in
    ``kernels.qz_sharded``) MUST route through this helper: the
    deg-axis summation order is the ordering contract, and a drifting
    copy would silently break the cross-path bit-reproducibility the
    tests pin.  (The global ref path uses a flat gather over global
    row ids instead — ``core.reconstruct._plan_apply`` — which is a
    genuinely different, also-pinned form.)
    """
    g_win = g.reshape(nwin, spec.rows_per_window)
    gath = jnp.take_along_axis(
        g_win, rows, axis=1,
        mode=jax.lax.GatherScatterMode.PROMISE_IN_BOUNDS,
    )
    return (vals * gath.reshape(nwin, spec.window, deg)).sum(-1).reshape(-1)


@functools.lru_cache(maxsize=32)
def build_transpose_plan(spec: QSpec,
                         order: str = "canonical") -> TransposePlan:
    """Invert the row plan into per-coordinate incoming-edge lists."""
    c, r, v = _edges(spec, order)
    rows_pad, vals_pad, counts, deg = _pack(c, r, v, spec.n)
    nw = spec.num_windows
    return TransposePlan(
        order=order, deg=deg,
        rows=rows_pad.reshape(nw, spec.window, deg),
        vals=vals_pad.reshape(nw, spec.window, deg),
        counts=counts,
    )


@functools.lru_cache(maxsize=32)
def build_block_plan(spec: QSpec, bm: int,
                     order: str = "canonical") -> BlockPlan:
    """Transpose plan binned per (window, bm-row-block, coordinate)."""
    c, r, v = _edges(spec, order)
    bpw = max(1, -(-spec.rows_per_window // bm))
    blk, rblk = r // bm, (r % bm).astype(np.int64)
    w, cw = c // spec.window, c % spec.window
    key = ((w * bpw + blk) * spec.window + cw).astype(np.int64)
    rows_pad, vals_pad, _, deg = _pack(
        key, rblk, v, spec.num_windows * bpw * spec.window
    )
    return BlockPlan(
        order=order, bm=bm, bpw=bpw, deg=deg,
        rows=rows_pad.reshape(spec.num_windows, bpw, spec.window, deg),
        vals=vals_pad.reshape(spec.num_windows, bpw, spec.window, deg),
    )
