"""QSpec — static description of one tensor's influence matrix Q.

Paper (§1.3): ``Q ∈ R^{m×n}`` has exactly ``d`` non-zeros per row, drawn
``N(0, 6/(d·fan_in))``; ``w = Q z`` with ``z ~ Bern(p)``.

TPU adaptation (DESIGN.md §3): indices for row ``i`` are drawn from a
contiguous *window* of ``z`` of size ``window`` (a power of two) assigned
by ``i // rows_per_window``, so a Pallas block keeps its window resident
in VMEM.  Distinctness of the ``d`` indices is guaranteed structurally:

    idx_k = (base + k * stride) mod window,   stride odd, window = 2^t

an odd stride is a unit of Z/2^t, so the d < window points are distinct —
this replaces the paper's "sample d indices without replacement" with an
equivalent-marginal, two-hashes-per-row scheme.

Nothing here allocates: QSpec is a hashable static pytree-leaf-free
dataclass, usable as a closure constant under ``jit``.
"""

from __future__ import annotations

import math
from dataclasses import dataclass
from functools import partial

import numpy as np
import jax.numpy as jnp

from .hashrng import gaussian_from_u32, hash_u32

# Counter-space roles for hash_u32(seed, tensor_id, row, ctr).
_CTR_BASE = 0x0001_0000
_CTR_STRIDE = 0x0002_0000
_CTR_VAL = 0x0004_0000  # value k uses counters _CTR_VAL + 2k, +2k+1


@dataclass(frozen=True)
class QSpec:
    """Static (hashable) spec of one tensor's sparse influence matrix.

    Distribution-aware layout (DESIGN.md §3, "sharding-major rows"):
    the tensor is flattened with ``major_axis`` moved to the front, and
    rows/windows are grouped into ``shard_count`` contiguous blocks so
    that block k's rows read ONLY block k's z windows.  With
    shape[major_axis] % shard_count == 0, the reconstruction emits the
    tensor already sharded on its consumer axis — no reshard, no
    replicated intermediates.  shard_count=1 (default) is the plain
    single-host layout used by the paper-scale experiments and tests.
    """

    tensor_id: int
    shape: tuple  # original weight tensor shape
    m: int  # number of weights = prod(shape)
    n: int  # trainable-parameter count (padded to num_windows*window)
    n_raw: int  # ceil(m / compression) before window padding
    d: int  # non-zeros per row
    window: int  # z-window size (power of two)
    num_windows: int
    rows_per_window: int
    m_pad: int  # shard_count * m_pad_loc >= m
    fan_in: int  # fan-in of the target neuron (sets sigma)
    seed: int
    major_axis: int = 0  # tensor axis that shards (moved to front)
    shard_count: int = 1  # contiguous row/window blocks (mesh model size)

    @property
    def sigma(self) -> float:
        return math.sqrt(6.0 / (self.d * max(self.fan_in, 1)))

    @property
    def compression(self) -> float:
        """Achieved compression factor m/n."""
        return self.m / self.n

    # --- layout helpers -------------------------------------------------
    @property
    def m_blk(self) -> int:
        return self.m // self.shard_count

    @property
    def nw_loc(self) -> int:
        return self.num_windows // self.shard_count

    @property
    def m_pad_loc(self) -> int:
        return self.nw_loc * self.rows_per_window

    @property
    def moved_shape(self) -> tuple:
        a = self.major_axis
        return (self.shape[a], *self.shape[:a], *self.shape[a + 1:])


def make_qspec(
    tensor_id: int,
    shape,
    fan_in: int,
    *,
    compression: float = 32.0,
    d: int = 8,
    window: int = 512,
    seed: int = 0,
    align: int = 1,
    major_axis: int = 0,
    shard_count: int = 1,
) -> QSpec:
    """Build a QSpec for a weight tensor.

    ``n`` is rounded up so the z vector tiles exactly into power-of-two
    windows; the achieved compression (``spec.compression``) is reported
    rather than silently pretending the requested one.

    ``align``: round num_windows up to a multiple of this (the mesh
    'model' axis size), so z and the (num_windows, rows_per_window) row
    space shard contiguously with window-local gathers (DESIGN.md §3.2).
    """
    shape = tuple(int(s) for s in shape)
    m = int(math.prod(shape))
    major_axis = int(major_axis)
    shard_count = int(shard_count)
    if shard_count > 1 and (shape[major_axis] % shard_count
                            or m % shard_count):
        # axis not block-shardable: fall back to the single-block layout
        major_axis, shard_count = 0, 1
    n_raw = max(1, math.ceil(m / compression))
    window = int(min(window, 1 << max(1, math.ceil(math.log2(max(n_raw, 2))))))
    if window & (window - 1):
        raise ValueError(f"window must be a power of two, got {window}")
    if d >= window:
        d = max(1, window // 2)
    align = max(align, shard_count)
    num_windows = max(1, math.ceil(n_raw / window))
    num_windows = math.ceil(num_windows / align) * align
    n = num_windows * window
    nw_loc = num_windows // shard_count
    m_blk = m // shard_count
    rows_per_window = math.ceil(m_blk / nw_loc)
    m_pad = rows_per_window * nw_loc * shard_count
    return QSpec(
        tensor_id=int(tensor_id),
        shape=shape,
        m=m,
        n=n,
        n_raw=n_raw,
        d=int(d),
        window=window,
        num_windows=num_windows,
        rows_per_window=rows_per_window,
        m_pad=m_pad,
        fan_in=int(fan_in),
        seed=int(seed),
        major_axis=major_axis,
        shard_count=shard_count,
    )


def padded_row_window(spec: QSpec, rp):
    """Padded row id -> global window id (shard-block aware)."""
    blk = rp // spec.m_pad_loc
    loc = rp % spec.m_pad_loc
    return (blk * spec.nw_loc
            + jnp.minimum(loc // spec.rows_per_window, spec.nw_loc - 1)
            ).astype(jnp.int32)


def padded_row_valid(spec: QSpec, rp):
    """True where a padded row id maps to a real weight."""
    return (rp % spec.m_pad_loc) < spec.m_blk


def row_indices(spec: QSpec, rows):
    """In-window column indices for the given (global) row ids.

    Returns int32 ``(..., d)`` in ``[0, window)``; the global z index is
    ``(rows // rows_per_window) * window + idx``.
    """
    rows = jnp.asarray(rows).astype(jnp.uint32)
    base = hash_u32(spec.seed, spec.tensor_id, rows, _CTR_BASE) & np.uint32(
        spec.window - 1
    )
    # stride odd in [1, window): unit mod 2^t => the d points are distinct
    stride = (
        hash_u32(spec.seed, spec.tensor_id, rows, _CTR_STRIDE)
        % np.uint32(spec.window // 2)
    ) * np.uint32(2) + np.uint32(1)
    k = jnp.arange(spec.d, dtype=jnp.uint32)
    idx = (base[..., None] + stride[..., None] * k) & np.uint32(spec.window - 1)
    return idx.astype(jnp.int32)


def row_values(spec: QSpec, rows, dtype=jnp.float32):
    """Gaussian coefficients ``q_{i,k} ~ N(0, 6/(d·fan_in))``, shape (..., d)."""
    rows = jnp.asarray(rows).astype(jnp.uint32)
    k = jnp.arange(spec.d, dtype=jnp.uint32)
    ua = hash_u32(
        spec.seed, spec.tensor_id, rows[..., None], _CTR_VAL + 2 * k
    )
    ub = hash_u32(
        spec.seed, spec.tensor_id, rows[..., None], _CTR_VAL + 2 * k + 1
    )
    g = gaussian_from_u32(ua, ub) * np.float32(spec.sigma)
    return g.astype(dtype)
