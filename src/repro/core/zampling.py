"""Zampling as a first-class reparametrization over model param trees.

Given any model's parameter template (a pytree of arrays or
ShapeDtypeStructs), Zampling replaces each large leaf with a QSpec and a
trainable score vector ``s`` (n floats, n = m/compression).  The
trainable state of the whole model is the collection of score vectors
plus the small dense leaves (norm scales, biases, ...) that are not
worth reparametrizing — the paper applies Q to the weight matrices.

Pipeline per step (training-by-sampling):
    p = clip(s)                         # f(x), §1.3
    z ~ Bern(p)  (straight-through)     # fresh every step
    w = Q z      (materialization-free) # kernels/ops.py dispatch
    loss = model.apply(w, batch); grad flows w -> z -> s
"""

from __future__ import annotations

from dataclasses import dataclass, field, replace
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .qspec import QSpec, make_qspec
from .sampling import clip_probs, discretize_mask, init_scores, sample_mask, sample_mask_st

PathLeaf = Tuple[str, Any]


def _path_str(path) -> str:
    return "/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in path)


@dataclass(frozen=True)
class ZamplingConfig:
    """Reparametrization hyper-parameters (paper notation in brackets)."""

    compression: float = 32.0  # m/n
    d: int = 8  # non-zeros per row of Q
    window: int = 512  # TPU adaptation: z-window size
    seed: int = 0  # shared server/client seed for Q
    min_size: int = 1024  # leaves smaller than this stay dense
    mode: str = "sample"  # sample | continuous | discretize
    chunks: int = 1  # reconstruction row-chunking (perf knob)
    shard_align: int = 1  # round num_windows to this (mesh model size)


@dataclass(frozen=True)
class ZamplingSpecs:
    """Static spec set for one model. Not a pytree — closure constant."""

    specs: Dict[str, QSpec]
    dense_paths: Tuple[str, ...]
    template: Any  # pytree of ShapeDtypeStruct (full model params)
    config: ZamplingConfig

    @property
    def m_total(self) -> int:
        return sum(s.m for s in self.specs.values())

    @property
    def n_total(self) -> int:
        return sum(s.n for s in self.specs.values())

    @property
    def dense_total(self) -> int:
        leaves = {p: l for p, l in _flatten(self.template)}
        return sum(int(jnp.size(leaves[p])) if hasattr(leaves[p], "size") else 0
                   for p in self.dense_paths)

    @property
    def compression(self) -> float:
        return self.m_total / max(self.n_total, 1)

    def comm_bits_per_round(self, packed: bool = True) -> Dict[str, int]:
        """Analytic communication accounting (paper Table 1)."""
        n, m = self.n_total, self.m_total
        return {
            "naive_client_up": 32 * m,
            "client_up": n if packed else 8 * n,
            "server_down": 32 * n,
            "naive_server_down": 32 * m,
        }


def _flatten(tree):
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    return [(_path_str(p), l) for p, l in flat]


def default_fan_in(path: str, shape) -> int:
    """Fan-in of the target neuron for He-style sigma (Lemma 2.1).

    Convention: weights are stored (..., in, out) — fan-in is the
    product of all-but-last dims.  Embedding tables ('embed' in path)
    use the model dim instead (their rows are looked up, not summed).
    """
    if len(shape) < 2:
        return max(int(shape[0]) if shape else 1, 1)
    if "embed" in path.lower():
        return int(shape[-1])
    fan = 1
    for s in shape[:-1]:
        fan *= int(s)
    return max(fan, 1)


def build_specs(
    template,
    config: ZamplingConfig,
    fan_in_fn: Callable[[str, tuple], int] = default_fan_in,
    shard_plan_fn: Optional[Callable[[str, tuple], Optional[int]]] = None,
) -> ZamplingSpecs:
    """Assign a QSpec to every large leaf of the param template.

    ``shard_plan_fn(path, shape) -> axis | None``: which tensor axis the
    runtime shards over 'model' — reconstruction then uses the
    sharding-major layout (shard_count = config.shard_align) so weights
    come out pre-sharded (see QSpec docstring).
    """
    template = jax.tree.map(
        lambda l: jax.ShapeDtypeStruct(jnp.shape(l), jnp.result_type(l)), template
    )
    specs: Dict[str, QSpec] = {}
    dense = []
    for tid, (path, leaf) in enumerate(_flatten(template)):
        m = 1
        for s in leaf.shape:
            m *= int(s)
        if len(leaf.shape) >= 2 and m >= config.min_size:
            axis = shard_plan_fn(path, leaf.shape) if shard_plan_fn else None
            specs[path] = make_qspec(
                tid,
                leaf.shape,
                fan_in_fn(path, leaf.shape),
                compression=config.compression,
                d=config.d,
                window=config.window,
                seed=config.seed,
                align=config.shard_align,
                major_axis=0 if axis is None else axis,
                shard_count=1 if axis is None else config.shard_align,
            )
        else:
            dense.append(path)
    return ZamplingSpecs(
        specs=specs, dense_paths=tuple(dense), template=template, config=config
    )


# ---------------------------------------------------------------------------
# Trainable state
# ---------------------------------------------------------------------------

def init_state(key, zspecs: ZamplingSpecs, dense_init=None) -> Dict[str, Any]:
    """{'scores': {path: f32[n]}, 'dense': {path: array}}.

    ``dense_init``: optional pytree of actual params to take dense leaves
    from (e.g. a real model init); falls back to ones/zeros heuristics.
    """
    scores = {}
    for path, spec in zspecs.specs.items():
        key, sub = jax.random.split(key)
        scores[path] = init_scores(sub, spec.n)
    dense = {}
    dense_leaves = dict(_flatten(dense_init)) if dense_init is not None else {}
    tmpl = dict(_flatten(zspecs.template))
    for path in zspecs.dense_paths:
        if path in dense_leaves:
            dense[path] = dense_leaves[path]
        else:
            leaf = tmpl[path]
            init = jnp.ones if ("scale" in path or "norm" in path.lower()) else jnp.zeros
            dense[path] = init(leaf.shape, leaf.dtype)
    return {"scores": scores, "dense": dense}


def state_spec(zspecs: ZamplingSpecs):
    """ShapeDtypeStructs of the trainable state (for dry-run lowering)."""
    scores = {
        p: jax.ShapeDtypeStruct((s.n,), jnp.float32)
        for p, s in zspecs.specs.items()
    }
    tmpl = dict(_flatten(zspecs.template))
    dense = {
        p: jax.ShapeDtypeStruct(tmpl[p].shape, tmpl[p].dtype)
        for p in zspecs.dense_paths
    }
    return {"scores": scores, "dense": dense}


# ---------------------------------------------------------------------------
# Weights
# ---------------------------------------------------------------------------

def _mask(p, key, mode: str):
    if mode == "sample":
        return sample_mask_st(p, key)
    if mode == "continuous":
        return p
    if mode == "discretize":
        return discretize_mask(p)
    raise ValueError(f"unknown mode {mode!r}")


def sample_masks(zspecs: ZamplingSpecs, state, key, mode: Optional[str] = None):
    """{path: z} straight-through masks, one fresh draw per tensor."""
    mode = mode or zspecs.config.mode
    masks = {}
    for path, spec in zspecs.specs.items():
        p = clip_probs(state["scores"][path])
        masks[path] = _mask(p, jax.random.fold_in(key, spec.tensor_id), mode)
    return masks


def weights_from_masks(zspecs: ZamplingSpecs, masks, state,
                       constraints: Optional[Dict[str, Any]] = None,
                       row_sharding=None):
    """Reconstruct the full model param tree from masks + dense leaves.

    ``constraints``: optional {path: NamedSharding} applied to each
    reconstructed tensor (GSPMD anchor for the distributed runtime).
    ``row_sharding``: optional NamedSharding for the (num_windows,
    rows_per_window) reconstruction row space (shards the O(m d)
    temporaries over 'model').
    """
    from ..kernels import ops  # late import: kernels layer sits above core

    tmpl = dict(_flatten(zspecs.template))
    leaves = {}
    for path, spec in zspecs.specs.items():
        w = ops.reconstruct(
            spec, masks[path], dtype=tmpl[path].dtype,
            chunks=zspecs.config.chunks, row_sharding=row_sharding,
        )
        if constraints is not None and path in constraints:
            w = jax.lax.with_sharding_constraint(w, constraints[path])
        leaves[path] = w
    for path in zspecs.dense_paths:
        leaves[path] = state["dense"][path]
    return unflatten_like(zspecs.template, leaves)


def sample_weights(zspecs: ZamplingSpecs, state, key,
                   mode: Optional[str] = None,
                   constraints: Optional[Dict[str, Any]] = None,
                   row_sharding=None):
    """One fresh sampled network: params pytree matching the template."""
    masks = sample_masks(zspecs, state, key, mode)
    return weights_from_masks(zspecs, masks, state, constraints=constraints,
                              row_sharding=row_sharding)


def unflatten_like(template, leaves: Dict[str, Any]):
    flat, treedef = jax.tree_util.tree_flatten_with_path(template)
    ordered = [leaves[_path_str(p)] for p, _ in flat]
    return jax.tree_util.tree_unflatten(treedef, ordered)
