"""Zampling as a first-class reparametrization over model param trees.

Given any model's parameter template (a pytree of arrays or
ShapeDtypeStructs), Zampling replaces each large leaf with a QSpec and a
trainable score vector ``s`` (n floats, n = m/compression).  The
trainable state of the whole model is the collection of score vectors
plus the small dense leaves (norm scales, biases, ...) that are not
worth reparametrizing — the paper applies Q to the weight matrices.

Pipeline per step (training-by-sampling):
    p = clip(s)                         # f(x), §1.3
    z ~ Bern(p)  (straight-through)     # fresh every step
    w = Q z      (materialization-free) # kernels/ops.py dispatch
    loss = model.apply(w, batch); grad flows w -> z -> s

The mask lifecycle (which mode, whether the draw is fused into the
reconstruction/pack kernels, and whether the upload leaves as uint32
wire lanes) is configured ONCE per use as a ``MaskProgram`` — the
single implementation behind ``sample_masks``/``sample_weights`` here
and ``local_update`` in ``core.federated``.  Draws are keyed by the
counter-based hash RNG (``core.sampling.mask_u32``), never
``jax.random``, so the jnp oracle and the Pallas kernels regenerate
identical bits from ``(seed, tensor_id, step, coord)``.
"""

from __future__ import annotations

from dataclasses import dataclass, field, replace
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .qspec import QSpec, make_qspec
from .sampling import (
    as_word,
    clip_probs,
    discretize_mask,
    init_scores,
    sample_mask_hash,
    sample_mask_qhash,
    sample_mask_st_hash,
)

PathLeaf = Tuple[str, Any]

# Valid mask lifecycles; shared by MaskProgram and FederatedConfig.
MASK_MODES = ("sample", "continuous", "discretize")


def _path_str(path) -> str:
    return "/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in path)


@dataclass(frozen=True)
class ZamplingConfig:
    """Reparametrization hyper-parameters (paper notation in brackets)."""

    compression: float = 32.0  # m/n
    d: int = 8  # non-zeros per row of Q
    window: int = 512  # TPU adaptation: z-window size
    seed: int = 0  # shared server/client seed for Q
    min_size: int = 1024  # leaves smaller than this stay dense
    mode: str = "sample"  # sample | continuous | discretize
    chunks: int = 1  # reconstruction row-chunking (perf knob)
    shard_align: int = 1  # round num_windows to this (mesh model size)


@dataclass(frozen=True)
class ZamplingSpecs:
    """Static spec set for one model. Not a pytree — closure constant."""

    specs: Dict[str, QSpec]
    dense_paths: Tuple[str, ...]
    template: Any  # pytree of ShapeDtypeStruct (full model params)
    config: ZamplingConfig

    @property
    def m_total(self) -> int:
        return sum(s.m for s in self.specs.values())

    @property
    def n_total(self) -> int:
        return sum(s.n for s in self.specs.values())

    @property
    def dense_total(self) -> int:
        leaves = {p: l for p, l in _flatten(self.template)}
        return sum(int(jnp.size(leaves[p])) if hasattr(leaves[p], "size") else 0
                   for p in self.dense_paths)

    @property
    def compression(self) -> float:
        return self.m_total / max(self.n_total, 1)

    def comm_bits_per_round(self, packed: bool = True,
                            downlink: str = "f32") -> Dict[str, int]:
        """Analytic communication accounting (paper Table 1).

        ``client_up``/``server_down`` are the paper's IDEALIZED figures
        (n mask bits up, n score coordinates down at the configured
        downlink codec's b bits each) and deliberately ignore two
        real-wire costs: (a) masks travel as uint32 lanes, so each
        tensor pays up to 31 bits of lane padding, and (b) the dense
        (non-reparametrized) leaves are trained and averaged too, f32
        both ways.  The ``*_wire`` keys are the EXACT protocol figures
        including both — they match ``comm.metering.round_wire_report``
        bit-for-byte (pinned in tests/test_fused.py and
        tests/test_downlink.py): ``client_up_wire`` == 8x the metered
        ``uplink_bytes_per_client`` for the packed
        (``psum_u32``/``allgather_packed``) resp. ``mean_f32``
        transports, and ``server_down_wire`` == 8x the metered
        ``downlink_bytes_per_client`` for the configured codec.
        """
        from ..comm.bitpack import packed_len  # comm sits above core
        from ..comm.downlink import get_codec
        from ..comm.metering import score_downlink_bytes

        codec = get_codec(downlink)
        n, m = self.n_total, self.m_total
        dense_bits = 32 * self.dense_total
        lane_bits = sum(32 * packed_len(s.n) for s in self.specs.values())
        mask_up_wire = lane_bits if packed else 32 * n
        # the SAME per-tensor byte ceiling the metering applies, so the
        # pinned server_down_wire == 8 x metered-bytes equality cannot
        # drift between the two implementations
        down_wire = sum(
            8 * score_downlink_bytes(codec, s.n)
            for s in self.specs.values()
        )
        return {
            "naive_client_up": 32 * m,
            "client_up": n if packed else 8 * n,
            "server_down": codec.bits * n,
            "naive_server_down": 32 * m,
            "client_up_wire": mask_up_wire + dense_bits,
            "server_down_wire": down_wire + dense_bits,
        }


def _flatten(tree):
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    return [(_path_str(p), l) for p, l in flat]


def default_fan_in(path: str, shape) -> int:
    """Fan-in of the target neuron for He-style sigma (Lemma 2.1).

    Convention: weights are stored (..., in, out) — fan-in is the
    product of all-but-last dims.  Embedding tables ('embed' in path)
    use the model dim instead (their rows are looked up, not summed).
    """
    if len(shape) < 2:
        return max(int(shape[0]) if shape else 1, 1)
    if "embed" in path.lower():
        return int(shape[-1])
    fan = 1
    for s in shape[:-1]:
        fan *= int(s)
    return max(fan, 1)


def build_specs(
    template,
    config: ZamplingConfig,
    fan_in_fn: Callable[[str, tuple], int] = default_fan_in,
    shard_plan_fn: Optional[Callable[[str, tuple], Optional[int]]] = None,
) -> ZamplingSpecs:
    """Assign a QSpec to every large leaf of the param template.

    ``shard_plan_fn(path, shape) -> axis | None``: which tensor axis the
    runtime shards over 'model' — reconstruction then uses the
    sharding-major layout (shard_count = config.shard_align) so weights
    come out pre-sharded (see QSpec docstring).
    """
    template = jax.tree.map(
        lambda l: jax.ShapeDtypeStruct(jnp.shape(l), jnp.result_type(l)), template
    )
    specs: Dict[str, QSpec] = {}
    dense = []
    for tid, (path, leaf) in enumerate(_flatten(template)):
        m = 1
        for s in leaf.shape:
            m *= int(s)
        if len(leaf.shape) >= 2 and m >= config.min_size:
            axis = shard_plan_fn(path, leaf.shape) if shard_plan_fn else None
            specs[path] = make_qspec(
                tid,
                leaf.shape,
                fan_in_fn(path, leaf.shape),
                compression=config.compression,
                d=config.d,
                window=config.window,
                seed=config.seed,
                align=config.shard_align,
                major_axis=0 if axis is None else axis,
                shard_count=1 if axis is None else config.shard_align,
            )
        else:
            dense.append(path)
    return ZamplingSpecs(
        specs=specs, dense_paths=tuple(dense), template=template, config=config
    )


# ---------------------------------------------------------------------------
# Trainable state
# ---------------------------------------------------------------------------

def init_state(key, zspecs: ZamplingSpecs, dense_init=None) -> Dict[str, Any]:
    """{'scores': {path: f32[n]}, 'dense': {path: array}}.

    ``dense_init``: optional pytree of actual params to take dense leaves
    from (e.g. a real model init); falls back to ones/zeros heuristics.
    """
    scores = {}
    for path, spec in zspecs.specs.items():
        key, sub = jax.random.split(key)
        scores[path] = init_scores(sub, spec.n)
    dense = {}
    dense_leaves = dict(_flatten(dense_init)) if dense_init is not None else {}
    tmpl = dict(_flatten(zspecs.template))
    for path in zspecs.dense_paths:
        if path in dense_leaves:
            dense[path] = dense_leaves[path]
        else:
            leaf = tmpl[path]
            init = jnp.ones if ("scale" in path or "norm" in path.lower()) else jnp.zeros
            dense[path] = init(leaf.shape, leaf.dtype)
    return {"scores": scores, "dense": dense}


def state_spec(zspecs: ZamplingSpecs):
    """ShapeDtypeStructs of the trainable state (for dry-run lowering)."""
    scores = {
        p: jax.ShapeDtypeStruct((s.n,), jnp.float32)
        for p, s in zspecs.specs.items()
    }
    tmpl = dict(_flatten(zspecs.template))
    dense = {
        p: jax.ShapeDtypeStruct(tmpl[p].shape, tmpl[p].dtype)
        for p in zspecs.dense_paths
    }
    return {"scores": scores, "dense": dense}


# ---------------------------------------------------------------------------
# The mask program: one abstraction for the whole mask lifecycle
# (mode x fused/composed x packed-ness).  core.federated and the public
# sample_masks/sample_weights below all route through it — there is ONE
# implementation of the mode dispatch and ONE draw keying scheme
# (core.sampling.mask_u32: (spec.seed, spec.tensor_id, step, coord)).
# ---------------------------------------------------------------------------

def validate_mask_mode(mode: str) -> str:
    if mode not in MASK_MODES:
        raise ValueError(
            f"unknown mask mode {mode!r}; valid modes: "
            f"{', '.join(MASK_MODES)}"
        )
    return mode


@dataclass(frozen=True)
class MaskProgram:
    """One configured mask lifecycle over a spec set.

    ``fused=True`` routes mode='sample' through the fused kernels
    (``kernels.ops.sample_reconstruct`` / ``sample_pack``): scores in,
    weights / wire lanes out, the mask a transient in-kernel value.
    ``fused=False`` is the composed oracle — explicit straight-through
    draw, then reconstruct/pack — bit-identical to fused (exact
    equality, forward and gradient) by the shared hash-RNG keying.
    ``packed`` selects the upload representation: uint32 wire lanes
    (what the packed transports move) vs the f32 {0,1} mask.
    ``downlink`` names the registered ``comm.downlink`` codec of the
    server broadcast: the ``*_from_wire`` methods below consume the
    ENCODED score pytree directly — for the quantized codecs the
    sample-mode draw is the widened-threshold integer compare
    (``core.sampling.sample_mask_qhash``; in the fused kernels via
    ``ops.sample_reconstruct(..., qbits=b)``), so no dequantized f32
    score slab exists on the draw path.  ``step`` everywhere below is
    the uint32 draw-counter word; callers derive it from their PRNG
    key + round/client/local-step counters
    (``core.sampling.key_word``/``fold_word``).
    """

    zspecs: ZamplingSpecs
    mode: str = "sample"
    fused: bool = True
    packed: bool = False
    downlink: str = "f32"  # registered comm.downlink codec name
    impl: Optional[str] = None  # kernels impl override (None = default)

    def __post_init__(self):
        validate_mask_mode(self.mode)

    @property
    def codec(self):
        """The resolved downlink codec (raises on unknown names)."""
        from ..comm.downlink import get_codec  # comm sits above core

        return get_codec(self.downlink)

    def _wire_words(self, wire_scores, path: str):
        """Validate + fetch one tensor's encoded broadcast leaf (b-bit
        words, or uint32 LANES for the packed codecs — lane count
        validated against the spec, since every packed codec shares the
        uint32 carrier and dtype alone cannot tell them apart)."""
        codec = self.codec
        q = wire_scores[path]
        if jnp.asarray(q).dtype != jnp.dtype(codec.wire_dtype):
            raise ValueError(
                f"score leaf {path!r} has dtype {jnp.asarray(q).dtype}, "
                f"but downlink codec {codec.name!r} carries "
                f"{jnp.dtype(codec.wire_dtype).name}; encode the state "
                f"first (core.federated.encode_state)"
            )
        if codec.packed:
            spec = self.zspecs.specs[path]
            want = codec.wire_len(spec.n)
            got = jnp.shape(q)[-1]
            if got != want:
                raise ValueError(
                    f"score leaf {path!r} has {got} uint32 lanes but "
                    f"codec {codec.name!r} packs n={spec.n} words into "
                    f"{want} lanes — wrong packed codec for this carry?"
                )
        return q

    def decode_scores(self, wire_scores) -> Dict[str, Any]:
        """Encoded broadcast -> the client's f32 trainable score copy
        (identity for the ``f32`` oracle codec — same arrays, so the
        f32 path stays bit-identical to the pre-codec protocol)."""
        codec = self.codec
        if not codec.quantized:
            return dict(wire_scores)
        return {
            path: codec.decode(spec, self._wire_words(wire_scores, path))
            for path, spec in self.zspecs.specs.items()
        }

    # -- composed masks ------------------------------------------------
    def mask(self, p, spec: QSpec, step):
        """One tensor's mask from CLIPPED probabilities ``p`` (the mode
        dispatch formerly duplicated across zampling._mask and
        federated._client_masks)."""
        if self.mode == "sample":
            return sample_mask_st_hash(p, spec.seed, spec.tensor_id, step)
        if self.mode == "continuous":
            return p
        return discretize_mask(p)

    def masks(self, scores, step) -> Dict[str, Any]:
        """{path: mask}, one fresh draw per tensor at draw word ``step``."""
        return {
            path: self.mask(clip_probs(scores[path]), spec, step)
            for path, spec in self.zspecs.specs.items()
        }

    # -- weights -------------------------------------------------------
    def weights(self, scores, dense, step,
                constraints: Optional[Dict[str, Any]] = None,
                row_sharding=None):
        """Full param pytree for one forward pass at draw word ``step``."""
        if not (self.fused and self.mode == "sample"):
            return weights_from_masks(
                self.zspecs, self.masks(scores, step), {"dense": dense},
                constraints=constraints, row_sharding=row_sharding,
                impl=self.impl,
            )
        from ..kernels import ops  # late import: kernels sit above core

        tmpl = dict(_flatten(self.zspecs.template))
        leaves = {}
        for path, spec in self.zspecs.specs.items():
            w = ops.sample_reconstruct(
                spec, clip_probs(scores[path]), step,
                dtype=tmpl[path].dtype, chunks=self.zspecs.config.chunks,
                impl=self.impl, row_sharding=row_sharding,
            )
            if constraints is not None and path in constraints:
                w = jax.lax.with_sharding_constraint(w, constraints[path])
            leaves[path] = w
        for path in self.zspecs.dense_paths:
            leaves[path] = dense[path]
        return unflatten_like(self.zspecs.template, leaves)

    # -- the wire draw -------------------------------------------------
    def upload(self, scores, step) -> Dict[str, Any]:
        """The end-of-round upload per tensor: fresh (gradient-free)
        Bernoulli bits at draw word ``step`` — as uint32 wire lanes
        when ``packed`` (what the packed transports move natively),
        else as the f32 {0,1} mask.  Discretize mode uploads rounded
        bits (binary, so packable too); continuous mode uploads
        probabilities (f32 only — ``mean_f32`` wire)."""
        from ..kernels import ops

        out = {}
        for path, spec in self.zspecs.specs.items():
            p = clip_probs(scores[path])
            if self.mode == "continuous":
                out[path] = p
            elif self.mode == "discretize":
                if self.packed:
                    from ..comm.bitpack import pack_mask

                    out[path] = pack_mask(discretize_mask(p))
                else:
                    out[path] = discretize_mask(p)
            elif self.packed and self.fused:
                out[path] = ops.sample_pack(spec, p, step, impl=self.impl)
            elif self.packed:
                from ..comm.bitpack import pack_mask

                out[path] = pack_mask(
                    sample_mask_hash(p, spec.seed, spec.tensor_id, step)
                )
            else:
                out[path] = sample_mask_hash(p, spec.seed, spec.tensor_id,
                                             step)
        return out

    # -- drawing straight from the encoded broadcast -------------------
    def mask_from_wire(self, q, spec: QSpec, step):
        """One tensor's mask from its ENCODED broadcast words.  Sample
        mode is the widened-threshold integer compare — bit-identical
        to ``self.mask(codec.decode(q), ...)`` without materializing
        the decoded f32 probabilities (discretize compares the
        threshold against 2^23, i.e. p_hat >= 0.5)."""
        codec = self.codec
        if not codec.quantized:
            return self.mask(clip_probs(q), spec, step)
        if self.mode == "sample":
            return sample_mask_qhash(codec.wire_words(spec, q),
                                     codec.bits, spec.seed,
                                     spec.tensor_id, step)
        if self.mode == "continuous":
            return codec.decode(spec, q)
        thr = codec.threshold_u24(codec.wire_words(spec, q))
        return (thr >= jnp.uint32(1 << 23)).astype(jnp.float32)

    def masks_from_wire(self, wire_scores, step) -> Dict[str, Any]:
        """{path: mask} drawn directly from the encoded broadcast."""
        return {
            path: self.mask_from_wire(self._wire_words(wire_scores, path),
                                      spec, step)
            for path, spec in self.zspecs.specs.items()
        }

    def weights_from_wire(self, wire_scores, dense, step,
                          constraints: Optional[Dict[str, Any]] = None,
                          row_sharding=None):
        """Full param pytree sampled straight from the encoded
        broadcast — the serving/eval path for a quantized downlink
        state.  Gradient-free (the broadcast carries no cotangent; the
        trainable path decodes first via ``decode_scores``).  Fused
        sample mode hands the quantized words to the kernels
        (``ops.sample_reconstruct(..., qbits=b)``: threshold compare
        in-block), bit-identical to the composed
        ``masks_from_wire`` -> ``weights_from_masks`` oracle."""
        codec = self.codec
        if not codec.quantized:
            return self.weights(wire_scores, dense, step,
                                constraints=constraints,
                                row_sharding=row_sharding)
        if not (self.fused and self.mode == "sample"):
            return weights_from_masks(
                self.zspecs, self.masks_from_wire(wire_scores, step),
                {"dense": dense}, constraints=constraints,
                row_sharding=row_sharding, impl=self.impl,
            )
        from ..kernels import ops  # late import: kernels sit above core

        tmpl = dict(_flatten(self.zspecs.template))
        leaves = {}
        for path, spec in self.zspecs.specs.items():
            w = ops.sample_reconstruct(
                spec, self._wire_words(wire_scores, path), step,
                qbits=codec.bits, qpacked=codec.packed,
                dtype=tmpl[path].dtype,
                chunks=self.zspecs.config.chunks, impl=self.impl,
                row_sharding=row_sharding,
            )
            if constraints is not None and path in constraints:
                w = jax.lax.with_sharding_constraint(w, constraints[path])
            leaves[path] = w
        for path in self.zspecs.dense_paths:
            leaves[path] = dense[path]
        return unflatten_like(self.zspecs.template, leaves)


def infer_downlink(scores) -> str:
    """Infer the broadcast codec of a score pytree from its leaf dtypes
    — floating leaves are plain/``f32`` scores, uint leaves name the
    quantized codec that carries them.  VALIDATED FALLBACK only: every
    packed codec's wire dtype is uint32, so dtype sniffing RAISES on a
    packed carry (``comm.downlink.codec_for_dtype``) — route those by
    explicit tag (``carried=`` on ``sample_weights``/``sample_masks``/
    ``evaluate``/``make_serve_state``, or the checkpoint's
    ``meta['downlink']``)."""
    from ..comm.downlink import codec_for_dtype  # comm sits above core

    dtypes = {jnp.asarray(v).dtype for v in scores.values()}
    names = {codec_for_dtype(dt).name for dt in dtypes}
    if len(names) > 1:
        raise ValueError(
            f"score leaves mix downlink representations {sorted(names)}"
        )
    return names.pop() if names else "f32"


def validate_carried(zspecs: ZamplingSpecs, scores, carried: str) -> str:
    """Validate an EXPLICIT codec tag against the score leaves and
    return the canonical codec name — the tag-routing counterpart of
    ``infer_downlink`` (which cannot distinguish the uint32-laned
    packed codecs).  Checks dtype for every codec and the per-tensor
    lane count for the packed family, so a wrong tag fails loudly
    instead of mis-decoding the carry."""
    from ..comm.downlink import get_codec  # comm sits above core

    codec = get_codec(carried)
    for path, spec in zspecs.specs.items():
        leaf = jnp.asarray(scores[path])
        if codec.quantized:
            ok = (leaf.dtype == jnp.dtype(codec.wire_dtype)
                  and leaf.shape[-1] == codec.wire_len(spec.n))
        else:
            ok = jnp.issubdtype(leaf.dtype, jnp.floating)
        if not ok:
            raise ValueError(
                f"score leaf {path!r} (dtype {leaf.dtype}, trailing dim "
                f"{leaf.shape[-1]}) cannot carry the tagged codec "
                f"{codec.name!r} (wire dtype "
                f"{jnp.dtype(codec.wire_dtype).name}, wire length "
                f"{codec.wire_len(spec.n)} for n={spec.n})"
            )
    return codec.name


def resolve_carried(zspecs: ZamplingSpecs, scores,
                    carried: Optional[str] = None) -> str:
    """The ONE carried-representation resolver: an explicit tag is
    validated (``validate_carried``); without one, dtype sniffing
    (``infer_downlink``) is the fallback and raises on ambiguity."""
    if carried is not None:
        return validate_carried(zspecs, scores, carried)
    return infer_downlink(scores)


def sample_masks(zspecs: ZamplingSpecs, state, key,
                 mode: Optional[str] = None,
                 carried: Optional[str] = None):
    """{path: z} straight-through masks, one fresh draw per tensor.

    ``key``: a PRNG key or uint32 draw word (``core.sampling.as_word``).
    ``carried`` names the codec of an encoded score state explicitly
    (required for the packed uint32-lane codecs); without it the
    representation is inferred from leaf dtypes, which raises on
    ambiguity.  Quantized carries draw through the widened-threshold
    integer compare.
    """
    downlink = resolve_carried(zspecs, state["scores"], carried)
    program = MaskProgram(zspecs, mode=mode or zspecs.config.mode,
                          fused=False, downlink=downlink)
    if program.codec.quantized:
        return program.masks_from_wire(state["scores"], as_word(key))
    return program.masks(state["scores"], as_word(key))


def weights_from_masks(zspecs: ZamplingSpecs, masks, state,
                       constraints: Optional[Dict[str, Any]] = None,
                       row_sharding=None, impl: Optional[str] = None):
    """Reconstruct the full model param tree from masks + dense leaves.

    ``constraints``: optional {path: NamedSharding} applied to each
    reconstructed tensor (GSPMD anchor for the distributed runtime).
    ``row_sharding``: optional NamedSharding for the (num_windows,
    rows_per_window) reconstruction row space (shards the O(m d)
    temporaries over 'model').
    """
    from ..kernels import ops  # late import: kernels layer sits above core

    tmpl = dict(_flatten(zspecs.template))
    leaves = {}
    for path, spec in zspecs.specs.items():
        w = ops.reconstruct(
            spec, masks[path], dtype=tmpl[path].dtype,
            chunks=zspecs.config.chunks, row_sharding=row_sharding,
            impl=impl,
        )
        if constraints is not None and path in constraints:
            w = jax.lax.with_sharding_constraint(w, constraints[path])
        leaves[path] = w
    for path in zspecs.dense_paths:
        leaves[path] = state["dense"][path]
    return unflatten_like(zspecs.template, leaves)


def sample_weights(zspecs: ZamplingSpecs, state, key,
                   mode: Optional[str] = None,
                   constraints: Optional[Dict[str, Any]] = None,
                   row_sharding=None, fused: bool = True,
                   downlink: Optional[str] = None,
                   carried: Optional[str] = None):
    """One fresh sampled network: params pytree matching the template.

    Routes through ``MaskProgram``: with ``fused`` (default) the
    sample-mode draw happens inside the fused reconstruction kernel;
    ``fused=False`` is the composed bit-exact oracle.  ``carried``
    names the codec of an encoded score state EXPLICITLY (validated
    against the leaves; required for the packed uint32-lane codecs,
    whose dtype is ambiguous); without it the representation is
    inferred from leaf dtypes, which raises on ambiguity —
    ``train.local.evaluate(..., carried=tag)`` threads the tag through.
    An explicit ``downlink`` must agree with the carried representation
    (treating wire words as f32 scores would silently clip them all to
    p=1).
    """
    from ..comm.downlink import get_codec  # comm sits above core

    resolved = resolve_carried(zspecs, state["scores"], carried)
    if downlink is not None and get_codec(downlink).name != resolved:
        raise ValueError(
            f"downlink={downlink!r} does not match the state's score "
            f"representation ({resolved!r})"
        )
    program = MaskProgram(zspecs, mode=mode or zspecs.config.mode,
                          fused=fused, downlink=resolved)
    if program.codec.quantized:
        return program.weights_from_wire(
            state["scores"], state["dense"], as_word(key),
            constraints=constraints, row_sharding=row_sharding)
    return program.weights(state["scores"], state["dense"], as_word(key),
                           constraints=constraints,
                           row_sharding=row_sharding)


def unflatten_like(template, leaves: Dict[str, Any]):
    flat, treedef = jax.tree_util.tree_flatten_with_path(template)
    ordered = [leaves[_path_str(p)] for p, _ in flat]
    return jax.tree_util.tree_unflatten(treedef, ordered)
