"""Convex-random-geometry utilities (paper §2).

Small, exact implementations of the paper's theory quantities, used by
the property tests and the theory benchmark:

 - Lemma 2.2: E[#nonzero entries of w = Q z] = m (1 - 2^{-d})
 - Lemma 2.3: empty-column probability / ~ e^{-d} proportion
 - Prop. 2.4: max_p E|Q_i p| = Theta(sqrt(d / fan_in))
 - Prop. 2.5: E[vol_n(Z_Q)] (computed in log space — it under/overflows
   wildly in linear space even for n ~ 50)
 - Def. 2.2 / Prop 2.6: tau-hypercube dimension and the Jensen bound.
"""

from __future__ import annotations

import math

import jax.numpy as jnp


def expected_nonzero_weights(m: int, d: int) -> float:
    """Lemma 2.2 (at p ~ U(0,1): P(all d mask bits zero) = 2^-d)."""
    return m * (1.0 - 0.5 ** d)


def empty_column_fraction(d: int) -> float:
    """Lemma 2.3 limit: fraction of all-zero columns for large m = n."""
    return math.exp(-d)


def expected_empty_columns(m: int, n: int, d: int) -> float:
    """E[#empty cols] = n (1 - d/n)^m (App. C)."""
    return n * (1.0 - d / n) ** m


def max_row_magnitude(d: int, fan_in: int) -> float:
    """Prop. 2.4 upper bound d * sigma * sqrt(2/pi) with sigma=sqrt(6/(d f))."""
    sigma = math.sqrt(6.0 / (d * fan_in))
    return d * sigma * math.sqrt(2.0 / math.pi)


def log_expected_zonotope_volume(fan_ins, d: int) -> float:
    """Prop. 2.5 in log space.

    log E[vol_n(Z_Q)] = log n! + (n/2) log(3/d) - log Gamma(1 + n/2)
                        - (1/2) sum_i log fan_in_i
    """
    n = len(fan_ins)
    return (
        math.lgamma(n + 1)
        + 0.5 * n * math.log(3.0 / d)
        - math.lgamma(1.0 + n / 2.0)
        - 0.5 * float(sum(math.log(f) for f in fan_ins))
    )


def tau_hypercube_dim(p, tau: float):
    """dim(C_tau) = #{j : tau <= p_j <= 1 - tau} (Def. 2.2)."""
    p = jnp.asarray(p)
    return int(jnp.sum((p >= tau) & (p <= 1.0 - tau)))


def perturb_nontrivial(p, key, tau: float, scale: float = 1.0):
    """Gaussian impulse on the non-trivial coordinates (paper §3.3).

    tau = 0.5 perturbs ALL coordinates — the paper's Table 4 reads
    "even when tau = 0.5 (and therefore all values p_j are perturbed)",
    i.e. the degenerate single-point C_0.5 is interpreted as the
    everything-perturbed stress test.
    """
    import jax

    if tau >= 0.5:
        mask = jnp.ones_like(p)
    else:
        mask = ((p >= tau) & (p <= 1.0 - tau)).astype(jnp.float32)
    eps = jax.random.normal(key, p.shape, dtype=jnp.float32) * scale
    return p + eps * mask, eps * mask
