"""Zampling core — the paper's contribution as composable JAX modules."""

from .federated import FederatedConfig, federated_round, local_update, sharded_client_update
from .qspec import QSpec, make_qspec, row_indices, row_values
from .reconstruct import materialize_q, reconstruct_ref
from .sampling import (
    clip_probs,
    discretize_mask,
    expected_mask,
    init_scores,
    sample_mask,
    sample_mask_st,
)
from .zampling import (
    ZamplingConfig,
    ZamplingSpecs,
    build_specs,
    init_state,
    sample_masks,
    sample_weights,
    state_spec,
    weights_from_masks,
)

__all__ = [
    "FederatedConfig", "federated_round", "local_update",
    "sharded_client_update", "QSpec", "make_qspec", "row_indices",
    "row_values", "materialize_q", "reconstruct_ref", "clip_probs",
    "discretize_mask", "expected_mask", "init_scores", "sample_mask",
    "sample_mask_st", "ZamplingConfig", "ZamplingSpecs", "build_specs",
    "init_state", "sample_masks", "sample_weights", "state_spec",
    "weights_from_masks",
]
