"""Zampling core — the paper's contribution as composable JAX modules."""

from .federated import (
    FederatedConfig,
    decode_state,
    encode_state,
    federated_round,
    local_update,
    mask_program,
    sharded_client_update,
)
from .qspec import QSpec, make_qspec, row_indices, row_values
from .reconstruct import materialize_q, reconstruct_ref
from .transpose_plan import (
    TransposePlan,
    build_block_plan,
    build_transpose_plan,
    default_bwd_path,
    resolve_bwd_path,
    row_plan,
    set_default_bwd_path,
)
from .sampling import (
    as_word,
    clip_probs,
    discretize_mask,
    expected_mask,
    fold_word,
    init_scores,
    key_word,
    mask_u32,
    quant_threshold_u24,
    sample_mask,
    sample_mask_hash,
    sample_mask_qhash,
    sample_mask_st,
    sample_mask_st_hash,
)
from .zampling import (
    MASK_MODES,
    MaskProgram,
    ZamplingConfig,
    ZamplingSpecs,
    build_specs,
    infer_downlink,
    init_state,
    sample_masks,
    sample_weights,
    state_spec,
    validate_mask_mode,
    weights_from_masks,
)

__all__ = [
    "FederatedConfig", "decode_state", "encode_state", "federated_round",
    "local_update", "mask_program",
    "sharded_client_update", "QSpec", "make_qspec", "row_indices",
    "row_values", "materialize_q", "reconstruct_ref", "TransposePlan",
    "build_block_plan", "build_transpose_plan", "default_bwd_path",
    "resolve_bwd_path", "row_plan", "set_default_bwd_path", "as_word",
    "clip_probs", "discretize_mask", "expected_mask", "fold_word",
    "init_scores", "key_word", "mask_u32", "quant_threshold_u24",
    "sample_mask", "sample_mask_hash", "sample_mask_qhash",
    "sample_mask_st", "sample_mask_st_hash",
    "MASK_MODES", "MaskProgram", "ZamplingConfig", "ZamplingSpecs",
    "build_specs", "infer_downlink", "init_state", "sample_masks",
    "sample_weights",
    "state_spec", "validate_mask_mode", "weights_from_masks",
]
