"""Counter-based deterministic hash RNG.

The influence matrix Q is pseudorandom and frozen for the whole training
run (paper §1.3).  We never materialize it: every consumer (the pure-jnp
reference oracle and the Pallas TPU kernel) regenerates indices/values
from the same counter-based hash.

Implementation notes:
 - all constants are numpy scalars / Python ints so they trace as jaxpr
   *literals*, never captured consts — a hard requirement inside
   ``pl.pallas_call`` kernel bodies;
 - static (Python/numpy int) words are folded in pure Python at trace
   time, so e.g. ``hash_u32(seed, tensor_id, rows, ctr)`` costs exactly
   one traced mix over ``rows``;
 - the mixer is the murmur3 finalizer (fmix32) over a xxhash-style
   running combine — not cryptographic, but distinct
   (seed, tensor, row, counter) tuples decorrelate (tested).
"""

from __future__ import annotations

import numpy as np

import jax.numpy as jnp

_M32 = 0xFFFFFFFF
_C1 = 0x85EBCA6B
_C2 = 0xC2B2AE35
_K1 = 0x9E3779B9  # golden-ratio increment
_K2 = 0x165667B1
_H0 = 0x2545F491

_INV_2_24 = np.float32(1.0 / (1 << 24))
_TWO_PI = np.float32(6.283185307179586)


def _is_static(x) -> bool:
    return isinstance(x, (int, np.integer))


def fmix32(h):
    """murmur3 32-bit finalizer (full avalanche). Static or traced."""
    if _is_static(h):
        h = int(h) & _M32
        h ^= h >> 16
        h = (h * _C1) & _M32
        h ^= h >> 13
        h = (h * _C2) & _M32
        h ^= h >> 16
        return h
    h = h ^ (h >> 16)
    h = h * np.uint32(_C1)
    h = h ^ (h >> 13)
    h = h * np.uint32(_C2)
    return h ^ (h >> 16)


def _combine(h, w):
    """h' = (h ^ fmix32(w + K1)) * K2 + K1 — identical static/traced."""
    if _is_static(h) and _is_static(w):
        return ((int(h) ^ fmix32((int(w) + _K1) & _M32)) * _K2 + _K1) & _M32
    if _is_static(w):
        w = np.uint32(int(w) & _M32)
        mixed = np.uint32(fmix32(int(w + np.uint32(_K1)) & _M32))
    else:
        w = jnp.asarray(w).astype(jnp.uint32)
        mixed = fmix32(w + np.uint32(_K1))
    if _is_static(h):
        h = np.uint32(h)
    return (h ^ mixed) * np.uint32(_K2) + np.uint32(_K1)


def hash_u32(*words):
    """Combine integer words (static ints or traced arrays) into one u32.

    ``hash_u32(seed, tensor_id, row, counter)`` is the canonical call of
    the Q generator.  Static prefix words fold at trace time.
    """
    h = _H0
    for w in words:
        h = _combine(h, w)
    out = fmix32(h)
    if _is_static(out):
        return np.uint32(out)
    return out


def u32_to_uniform(u):
    """u32 -> float32 uniform in (0, 1] (never 0: safe for log)."""
    return (u >> np.uint32(8)).astype(jnp.float32) * _INV_2_24 + _INV_2_24


def gaussian_from_u32(u_a, u_b):
    """Two u32 streams -> standard normal via Box-Muller (cos branch)."""
    u1 = u32_to_uniform(u_a)
    u2 = u32_to_uniform(u_b)
    r = jnp.sqrt(-2.0 * jnp.log(u1))
    return r * jnp.cos(_TWO_PI * u2)


def bernoulli_u32(u, p):
    """u32 stream + probabilities -> {0,1} float32 Bernoulli draws."""
    return (u32_to_uniform(u) <= p).astype(jnp.float32)
