"""Deprecated location — bitpacking moved to ``repro.comm.bitpack``.

This shim keeps old imports working; the real implementation (now
batched over leading client axes, plus the packed-popcount reduction)
lives in the wire-format transport layer.
"""

from ..comm.bitpack import (  # noqa: F401
    pack_mask,
    packed_len,
    packed_popcount_sum,
    unpack_mask,
)

__all__ = ["pack_mask", "packed_len", "packed_popcount_sum", "unpack_mask"]
