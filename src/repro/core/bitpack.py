"""Bit-packing of binary masks for communication.

The federated protocol uploads ``z ∈ {0,1}^n`` — n *bits* on the wire.
JAX has no 1-bit dtype, so we pack 32 mask bits per ``uint32`` lane;
the packed representation is what crosses the network (all-gather over
the client axis), giving the paper's full 32x-over-uint8 saving.
"""

from __future__ import annotations

import jax.numpy as jnp


def packed_len(n: int) -> int:
    return (n + 31) // 32


def pack_mask(z):
    """float/bool {0,1} mask (n,) -> uint32 (ceil(n/32),)."""
    n = z.shape[0]
    pad = packed_len(n) * 32 - n
    bits = jnp.pad(z.astype(jnp.uint32), (0, pad)).reshape(-1, 32)
    shifts = jnp.arange(32, dtype=jnp.uint32)
    return jnp.sum(bits << shifts, axis=-1, dtype=jnp.uint32)


def unpack_mask(packed, n: int):
    """uint32 (ceil(n/32),) -> float32 mask (n,)."""
    shifts = jnp.arange(32, dtype=jnp.uint32)
    bits = (packed[:, None] >> shifts) & jnp.uint32(1)
    return bits.reshape(-1)[:n].astype(jnp.float32)
