"""FEDERATED ZAMPLING (paper §1.3, federated version).

One round:
  1. server "broadcasts" p(t)           -> replication across clients
  2. client k: s = p(t); E local steps of SGD/Adam on the scores with a
     FRESH mask sample every forward pass (training-by-sampling)
  3. client k: p_new = f(s); z_new ~ Bern(p_new)  (n BITS on the wire)
  4. server: p(t+1) = mean_k z_new^(k)

Step 3/4 — what actually crosses the network — is delegated to the
wire-format transport layer (``repro.comm``): ``FederatedConfig
.aggregate`` names a registered ``comm.protocol.Transport`` strategy
(``mean_f32`` f32 baseline, ``psum_u32`` integer popcount psum of
bitpacked lanes, ``allgather_packed`` raw-lane all-gather; ``mean`` is
a backwards-compatible alias of ``mean_f32``).  All strategies are
bit-exact against each other; they differ only in wire bytes, which
``comm.metering`` reports exactly in every round's metrics
(``uplink_bytes_per_client`` etc.).  Continuous-mode rounds upload
probabilities, not bits, and always use ``mean_f32``.

Two execution paths with identical math:
  * ``federated_round``        — vmap over a stacked client axis
    (CPU simulation; the paper's 10-client experiments).  The
    ``w = Q z`` inside each client's forward/backward does NOT pay
    K-times Q regeneration: ``kernels.ops`` installs custom_vmap rules
    on the reconstruction custom_vjp, so this vmap lowers onto the
    natively-batched kernels — see ``kernels.ops.reconstruct_batched``.
    Aggregation uses ``Transport.aggregate_stacked`` on the (K, n)
    mask slab.
  * ``sharded_client_update``  — the piece that runs inside
    ``shard_map`` on the production mesh, where the client axis IS the
    ``data`` mesh axis and aggregation is
    ``Transport.aggregate_collective``: the psum / all-gather of
    (bit-packed) masks replaces the f32 gradient all-reduce of
    standard data parallelism.

Multi-round driving (one compile per (K, E) shape, rounds carried
through ``lax.scan``) lives in ``train.fit.federated_fit``.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp

from ..comm.metering import round_wire_report
from ..comm.protocol import resolve_transport, transport_names
from ..optim import Optimizer, sgd
from .sampling import clip_probs, sample_mask, sample_mask_st
from .zampling import ZamplingSpecs, weights_from_masks

LossFn = Callable[[Any, Any], jnp.ndarray]  # (params, batch) -> scalar


@dataclass(frozen=True)
class FederatedConfig:
    num_clients: int = 10
    local_steps: int = 1  # "epochs" per round in the paper (up to 100)
    local_lr: float = 0.1
    mode: str = "sample"  # sample | continuous (ContinuousModel baseline)
    aggregate: str = "mean"  # a registered comm.protocol transport name

    def __post_init__(self):
        if self.aggregate not in transport_names():
            raise ValueError(
                f"unknown aggregate strategy {self.aggregate!r}; "
                f"registered transports: {', '.join(transport_names())}"
            )


def _client_masks(zspecs: ZamplingSpecs, scores, key, mode):
    masks = {}
    for path, spec in zspecs.specs.items():
        p = clip_probs(scores[path])
        k = jax.random.fold_in(key, spec.tensor_id)
        if mode == "sample":
            masks[path] = sample_mask_st(p, k)
        else:  # continuous
            masks[path] = p
    return masks


def local_update(
    zspecs: ZamplingSpecs,
    state: Dict[str, Any],
    loss_fn: LossFn,
    batches,  # (local_steps, ...) stacked client batches
    key,
    cfg: FederatedConfig,
    opt: Optional[Optimizer] = None,
    constraints=None,
    row_sharding=None,
):
    """One client's round: E local score-steps -> final Bernoulli masks.

    Returns (z_new {path: f32[n] in {0,1}}, dense_new, mean_loss).
    Dense (non-reparametrized) leaves are trained locally too and
    aggregated by plain averaging (they are tiny: norms/biases).
    """
    opt = opt or sgd(cfg.local_lr)
    scores0 = dict(state["scores"])
    dense0 = dict(state["dense"])

    def loss_of(trainable, batch, sub):
        masks = _client_masks(zspecs, trainable["scores"], sub, cfg.mode)
        params = weights_from_masks(
            zspecs, masks, {"dense": trainable["dense"]},
            constraints=constraints, row_sharding=row_sharding,
        )
        return loss_fn(params, batch)

    def step(carry, xs):
        trainable, opt_state = carry
        batch, sub = xs
        loss, grads = jax.value_and_grad(loss_of)(trainable, batch, sub)
        updates, opt_state = opt.update(grads, opt_state, trainable)
        trainable = jax.tree.map(lambda p, u: p + u, trainable, updates)
        return (trainable, opt_state), loss

    trainable0 = {"scores": scores0, "dense": dense0}
    keys = jax.random.split(key, cfg.local_steps)
    (trainable, _), losses = jax.lax.scan(
        step, (trainable0, opt.init(trainable0)), (batches, keys)
    )

    # p_new = f(s_new); z_new ~ Bern(p_new)  — the n bits sent upstream
    final_key = jax.random.fold_in(key, 0x5EED)
    z_new = {}
    for path, spec in zspecs.specs.items():
        p_new = clip_probs(trainable["scores"][path])
        if cfg.mode == "sample":
            z_new[path] = sample_mask(
                p_new, jax.random.fold_in(final_key, spec.tensor_id)
            )
        else:
            z_new[path] = p_new
    return z_new, trainable["dense"], jnp.mean(losses)


# byte-count keys every round's metrics dict carries (comm.metering);
# launch code sizing shard_map out_specs keys off the metrics tree uses
# this instead of hardcoding {"loss"}
WIRE_METRIC_KEYS = (
    "uplink_bytes_per_client",
    "uplink_bytes_round",
    "downlink_bytes_per_client",
    "naive_uplink_bytes_per_client",
)


def _wire_metrics(zspecs: ZamplingSpecs, cfg: FederatedConfig,
                  num_clients: Optional[int] = None):
    """Exact byte counts for this round's traffic (static per config).

    ``num_clients`` overrides ``cfg.num_clients`` on the sharded path,
    where the true client count is the mesh axis size.
    """
    rep = round_wire_report(
        zspecs, cfg.aggregate,
        cfg.num_clients if num_clients is None else num_clients,
        mode=cfg.mode,
    )
    return {k: rep[k] for k in WIRE_METRIC_KEYS}


def federated_round(
    zspecs: ZamplingSpecs,
    state: Dict[str, Any],
    loss_fn: LossFn,
    client_batches,  # pytree with leading axes (K, local_steps, ...)
    key,
    cfg: FederatedConfig,
    opt: Optional[Optimizer] = None,
):
    """Full round over K stacked clients (vmap). Returns (state', metrics)."""
    transport = resolve_transport(cfg.aggregate, cfg.mode)
    keys = jax.random.split(key, cfg.num_clients)

    def one(batches, k):
        return local_update(zspecs, state, loss_fn, batches, k, cfg, opt)

    z_all, dense_all, losses = jax.vmap(one)(client_batches, keys)
    # server aggregation: p(t+1) = mean_k z^(k), via the wire transport
    new_scores = {p: transport.aggregate_stacked(z) for p, z in z_all.items()}
    new_dense = jax.tree.map(lambda d: jnp.mean(d, axis=0), dense_all)
    new_state = {"scores": new_scores, "dense": new_dense}
    metrics = {"loss": jnp.mean(losses), **_wire_metrics(zspecs, cfg)}
    return new_state, metrics


def sharded_client_update(
    zspecs: ZamplingSpecs,
    state: Dict[str, Any],
    loss_fn: LossFn,
    batches,
    key,
    cfg: FederatedConfig,
    *,
    axis_names=("data",),
    opt: Optional[Optimizer] = None,
    constraints=None,
    row_sharding=None,
):
    """Body to run under ``shard_map``: client id = mesh position.

    The mask aggregation is the ONLY cross-client communication; the
    configured transport decides its wire format — an f32 psum
    (``mean_f32``), a uint32 popcount psum of bitpacked lanes
    (``psum_u32``), or an all-gather of the raw packed lanes
    (``allgather_packed``) over the client axes.
    """
    from ..comm.shardmap import axis_size

    transport = resolve_transport(cfg.aggregate, cfg.mode)
    idx = sum(
        jax.lax.axis_index(a) * 1_000_003 ** i for i, a in enumerate(axis_names)
    )
    ckey = jax.random.fold_in(key, idx)
    z_new, dense_new, loss = local_update(
        zspecs, state, loss_fn, batches, ckey, cfg, opt,
        constraints=constraints, row_sharding=row_sharding,
    )
    nclients = axis_size(axis_names)
    new_scores = {
        p: transport.aggregate_collective(z, axis_names)
        for p, z in z_new.items()
    }
    # dense leaves stay on the f32 psum path: XLA:CPU's
    # AllReducePromotion pass aborts on bf16 all-reduces (and f32 is
    # the numerically right accumulator anyway)
    new_dense = jax.tree.map(
        lambda d: (jax.lax.psum(d.astype(jnp.float32), axis_names)
                   / nclients).astype(d.dtype),
        dense_new,
    )
    loss = jax.lax.pmean(loss, axis_names)
    # the mesh axis size, not cfg.num_clients, is the real K here
    metrics = {"loss": loss, **_wire_metrics(zspecs, cfg, nclients)}
    return {"scores": new_scores, "dense": new_dense}, metrics
