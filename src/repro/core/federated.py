"""FEDERATED ZAMPLING (paper §1.3, federated version).

One round:
  1. server "broadcasts" p(t)           -> replication across clients
  2. client k: s = p(t); E local steps of SGD/Adam on the scores with a
     FRESH mask sample every forward pass (training-by-sampling)
  3. client k: p_new = f(s); z_new ~ Bern(p_new)  (n BITS on the wire)
  4. server: p(t+1) = mean_k z_new^(k)

Fused mask lifecycle (this module's hot path): the mask ``z`` is n
bits, and with ``FederatedConfig.mask_path='fused'`` (default) it
NEVER exists as an f32 array between ops.  Every draw is keyed by the
counter-based hash RNG (``core.sampling.mask_u32``: words
``(spec.seed, spec.tensor_id, step, coord)``), where ``step`` is a
uint32 draw word derived from (round key, round_index, client index,
local step) — integer counters threaded through the scans, NOT
pre-split PRNG keys.  Step 2's per-forward draw happens inside the
fused reconstruction kernel (``kernels.ops.sample_reconstruct``:
scores in, weights out, straight-through ``grad_s = Q^T grad_w ⊙
1_{0<s<1}`` via its custom_vjp); step 3's upload draw happens inside
the fused pack kernel (``kernels.ops.sample_pack``: scores in, uint32
wire lanes out).  ``mask_path='composed'`` is the bit-exact oracle —
explicit draw, then reconstruct/pack — equal to fused to EXACT
equality, forward and gradient (tests/test_fused.py).  All mode
dispatch lives in ONE place, ``core.zampling.MaskProgram`` (mode x
fused x packed-ness).

Step 3/4 — what actually crosses the network upstream — is delegated
to the wire-format transport layer (``repro.comm``): ``FederatedConfig
.aggregate`` names a registered ``comm.protocol.Transport`` strategy
(``mean_f32`` f32 baseline, ``psum_u32`` integer popcount psum of
bitpacked lanes, ``allgather_packed`` raw-lane all-gather; ``mean`` is
a backwards-compatible alias of ``mean_f32``).  Packed transports
receive the clients' uint32 lanes NATIVELY (``aggregate_*_packed``) —
there is no post-hoc jnp pack of an f32 mask slab.  All strategies are
bit-exact against each other; they differ only in wire bytes, which
``comm.metering`` reports exactly in every round's metrics
(``uplink_bytes_per_client`` etc.).  Continuous-mode rounds upload
probabilities, not bits, and always use ``mean_f32``.

Step 1 — the DOWNLINK — is symmetric since the codec subsystem
(``comm.downlink``): ``FederatedConfig.downlink`` names a registered
``DownlinkCodec`` and the ENCODED scores ARE the round's carried
state.  ``federated_round`` / ``sharded_client_update`` take
``state['scores']`` in the codec's wire representation, the client
decodes only its own trainable copy (``MaskProgram.decode_scores``),
and after aggregation the server re-encodes ``p(t+1)`` with the
shared dither word ``fold_word(key_word(key), round_index)`` — every
shard regenerates the identical dither from the replicated key, so
the encoded broadcast is bit-identical across the vmap and shard_map
paths with zero extra bits.  ``downlink='f32'`` (default) is the
identity oracle: those rounds are bit-identical to the pre-codec
protocol.  Quantized codecs (``u16``/``u8``) cut the dominant
``server_down_wire`` term 2x/4x; mask draws made straight from the
broadcast (eval/serving, ``MaskProgram.*_from_wire``) use the
widened-threshold integer compare and never materialize a dequantized
f32 score slab.  ``encode_state`` converts an f32 init state into the
configured wire representation before the first round.

PARTIAL PARTICIPATION (the fault-tolerant round, ``repro.fault``):
the full-participation round above is the special case every client
shows up.  Passing ``client_ids`` / ``weights`` / ``faults`` to either
driver switches the server update to the weighted partial form

    p(t+1) = sum_k w_k·b_k·z^(k) / sum_k w_k·b_k,

where ``w_k`` is client k's sample-count weight (``fault.population
.ClientPopulation``, e.g. Dirichlet split sizes) and ``b_k ∈ {0,1}``
is its REALIZED participation bit: 0 if the client dropped, straggled
past the round cutoff, or failed the server's upload validation
(``fault.validate`` popcount checksums detect the lane corruption
``FaultPlan`` injects).  Both factors enter the popcount reduction as
exact uint32 multiplies (``comm.protocol`` ``*_weighted``), so the
mean over the survivors is EXACT — the same integers in every wire
representation — and the realized denominator replaces the configured
K (the divide-by-K mean is silently wrong the moment anyone drops).
A round whose surviving cohort falls below ``FederatedConfig
.min_clients`` (or whose realized weight is zero) is SKIPPED: the
carried state — scores in the downlink codec's wire words, dense
leaves — passes through unchanged and the metrics flag
``round_skipped=1``; averaging two survivors of a hundred would move
p(t) by sampling noise, not signal.  With all clients participating
at weight 1 every multiply is an identity and the weighted round is
bit-identical to the plain protocol (tests/test_faults.py); with no
participation arguments at all the plain code path runs, untouched.
Metrics gain the realized-cohort counters (``PARTICIPATION_METRIC_
KEYS``) and ``comm.metering.realized_wire_metrics`` replaces the
configured byte totals with realized ones (corrupt uploads still
spend uplink bytes; duplicates spend them twice; drops spend none).

STREAMING AGGREGATION (unbounded K, ``FederatedConfig.stream_chunk``):
the vmap driver above still materializes the cohort's uploads as a
(K, lanes) slab before reducing, so device memory — not the wire —
caps K.  With ``stream_chunk=C > 0`` the round becomes a ``lax.scan``
over ceil(K/C) upload chunks whose carry IS the server state: the
unnormalized uint32 weighted vote counts (plus f32 dense sums, the
uint32 weight sum, and the realized-cohort counters).  Each scan step
trains one chunk of C clients, runs the SAME per-upload fault pipeline
(draws key on the global client id, so scenarios replay bit-
identically), and folds the chunk's lanes into the accumulator
(``comm.protocol`` ``fold_stacked_*`` -> ``comm.bitpack.packed_
weighted_fold``).  Integer addition is associative, so after the one
reciprocal normalization at the end the scores are BIT-IDENTICAL to
the slab path at any K and chunk size (tests/test_streaming.py);
peak upload memory is O(C·n) whatever K is, and a straggler past the
cutoff is simply an upload never folded in.  A non-dividing last chunk
is padded with weight-0, live-masked replays of leading clients —
excluded from every count.  Host-side, ``train.fit.streamed_
federated_fit`` double-buffers the NEXT cohort's batches onto the
device (``jax.device_put``) under the current round's dispatched
compute.

Two execution paths with identical math AND identical draws (the
per-client draw words coincide, so the two paths produce bit-identical
scores for the same key/round_index):
  * ``federated_round``        — vmap over a stacked client axis
    (CPU simulation; the paper's 10-client experiments).  The
    fused ``w = Q·Bern(f(s))`` inside each client's forward/backward
    does NOT pay K-times Q regeneration: ``kernels.ops`` installs
    custom_vmap rules on the fused custom_vjp, so this vmap lowers
    onto the natively-batched fused kernels (p-slab in-block, one
    hash-RNG generation per row block).
  * ``sharded_client_update``  — the piece that runs inside
    ``shard_map`` on the production mesh, where the client axis IS the
    ``data`` mesh axis and aggregation is the transport's collective:
    the psum / all-gather of packed mask lanes replaces the f32
    gradient all-reduce of standard data parallelism.

Multi-round driving (one compile per (K, E) shape, rounds + the round
counter carried through ``lax.scan``) lives in ``train.fit``.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp

from ..comm.downlink import codec_names, get_codec
from ..comm.metering import (
    realized_wire_metrics,
    round_wire_report,
    scheduled_wire_metrics,
)
from ..comm.protocol import resolve_transport, transport_names
from ..optim import Optimizer, sgd
from .sampling import (as_word, clip_probs, fold_word,
                       quant_threshold_u24_dyn)
from .zampling import (
    MaskProgram,
    ZamplingSpecs,
    infer_downlink,
    validate_carried,
    validate_mask_mode,
)

LossFn = Callable[[Any, Any], jnp.ndarray]  # (params, batch) -> scalar

_MASK_PATHS = ("fused", "composed")

# downlink rate-control schedules (FederatedConfig.downlink_schedule):
#   constant — every round broadcasts at the codec's full width; the
#              plain fixed-codec path runs untouched (b_vec is None)
#   cosine   — anneal the width from schedule_b_min up to codec.bits
#              over schedule_rounds rounds (coarse early rounds, full
#              precision at convergence)
#   frontier — per-tensor widths adapted from MEASURED score dynamics:
#              the fraction of draw words that would flip between b and
#              b+2 bits, computed on the already-encoded carry
DOWNLINK_SCHEDULES = ("constant", "cosine", "frontier")


@dataclass(frozen=True)
class FederatedConfig:
    num_clients: int = 10
    local_steps: int = 1  # "epochs" per round in the paper (up to 100)
    local_lr: float = 0.1
    mode: str = "sample"  # sample | continuous | discretize
    aggregate: str = "mean"  # a registered comm.protocol transport name
    mask_path: str = "fused"  # fused | composed (the bit-exact oracle)
    downlink: str = "f32"  # a registered comm.downlink codec name
    # partial participation: a round whose SURVIVING cohort (arrived
    # AND validated) is smaller than this is skipped — state carried
    # forward unchanged, metrics flag round_skipped
    min_clients: int = 1
    # streaming aggregation: fold uploads into the (n,) vote-count
    # accumulator in chunks of this many clients (lax.scan carry), so
    # the (K, n) upload slab never materializes and peak upload memory
    # is O(stream_chunk * n) whatever K is.  0 (default) = the one-shot
    # slab path; a chunk >= K also falls through to it (one chunk IS
    # the slab).  Scores are bit-identical either way.
    stream_chunk: int = 0
    # adaptive downlink rate control (DOWNLINK_SCHEDULES): the round's
    # broadcast is re-quantized at a per-round (frontier: per-tensor)
    # width b <= codec.bits.  The CARRY stays the codec's fixed-width
    # wire representation (the scheduled word is widened by the exact
    # divisor embedding, comm.downlink.QuantizedDown.encode_at), so the
    # width vector is a TRACED per-round value — R rounds compile once
    # and every carry consumer (fused kernels, serve, checkpoint) stays
    # on the static fast path.  Only b bits/coord are metered as
    # crossing the wire (the widening is a shared deterministic map).
    downlink_schedule: str = "constant"
    schedule_b_min: int = 2  # the schedules' floor width
    schedule_rounds: int = 0  # cosine anneal horizon (rounds)
    # frontier controller: raise b by 2 when the measured draw-word
    # flip fraction between b and b+2 exceeds this; lower b by 2 when
    # it falls under a quarter of it
    frontier_threshold: float = 0.02

    def __post_init__(self):
        if self.min_clients < 1:
            raise ValueError(
                f"min_clients must be >= 1, got {self.min_clients}"
            )
        if self.stream_chunk < 0:
            raise ValueError(
                f"stream_chunk must be >= 0 (0 = slab path), got "
                f"{self.stream_chunk}"
            )
        if self.aggregate not in transport_names():
            raise ValueError(
                f"unknown aggregate strategy {self.aggregate!r}; "
                f"registered transports: {', '.join(transport_names())}"
            )
        if self.downlink not in codec_names():
            raise ValueError(
                f"unknown downlink codec {self.downlink!r}; "
                f"registered codecs: {', '.join(codec_names())}"
            )
        validate_mask_mode(self.mode)
        if self.mask_path not in _MASK_PATHS:
            raise ValueError(
                f"unknown mask_path {self.mask_path!r}; valid paths: "
                f"{', '.join(_MASK_PATHS)}"
            )
        if self.downlink_schedule not in DOWNLINK_SCHEDULES:
            raise ValueError(
                f"unknown downlink_schedule {self.downlink_schedule!r}; "
                f"valid schedules: {', '.join(DOWNLINK_SCHEDULES)}"
            )
        if self.downlink_schedule != "constant":
            codec = get_codec(self.downlink)
            if not codec.quantized:
                raise ValueError(
                    f"downlink_schedule={self.downlink_schedule!r} needs "
                    f"a quantized downlink codec to rate-control; "
                    f"{self.downlink!r} is not quantized"
                )
            if not 1 <= self.schedule_b_min <= codec.bits:
                raise ValueError(
                    f"schedule_b_min must be in [1, {codec.bits}] for "
                    f"downlink codec {self.downlink!r}, got "
                    f"{self.schedule_b_min}"
                )
            if (self.downlink_schedule == "cosine"
                    and self.schedule_rounds < 1):
                raise ValueError(
                    "downlink_schedule='cosine' needs schedule_rounds "
                    f">= 1 (the anneal horizon), got "
                    f"{self.schedule_rounds}"
                )
            if (self.downlink_schedule == "frontier"
                    and self.frontier_threshold <= 0):
                raise ValueError(
                    "frontier_threshold must be > 0, got "
                    f"{self.frontier_threshold}"
                )


def mask_program(zspecs: ZamplingSpecs, cfg: FederatedConfig) -> MaskProgram:
    """The round's configured mask lifecycle: mode x fused x packed.

    THE single definition of the packed-wire predicate: the resolved
    transport's ``packed_wire`` (``resolve_transport`` already
    downgrades continuous — the only non-binary upload — to
    ``mean_f32``).  ``local_update`` emits what this program's
    ``packed`` says, and the aggregators in ``federated_round`` /
    ``sharded_client_update`` branch on the SAME field — never
    recompute the predicate elsewhere.
    """
    transport = resolve_transport(cfg.aggregate, cfg.mode)
    return MaskProgram(
        zspecs,
        mode=cfg.mode,
        fused=cfg.mask_path == "fused",
        packed=transport.packed_wire,
        downlink=cfg.downlink,
    )


def _with_schedule_state(zspecs: ZamplingSpecs, cfg: FederatedConfig,
                         state):
    """Attach the frontier schedule's carried per-tensor width vector
    to an encoded state (identity for the other schedules, and for a
    state that already carries one).  Widths start at the floor
    ``schedule_b_min`` — the controller raises them as the measured
    score dynamics demand."""
    if cfg.downlink_schedule != "frontier" or "downlink_b" in state:
        return state
    b0 = jnp.full((len(zspecs.specs),), cfg.schedule_b_min, jnp.uint32)
    return {**state, "downlink_b": b0}


def encode_state(zspecs: ZamplingSpecs, cfg: FederatedConfig, state,
                 word=0):
    """Encode an f32 score state into ``cfg.downlink``'s wire
    representation — what the round drivers carry.  ``word`` keys the
    dither stream (use the same derivation as the round that WOULD
    have produced this broadcast; 0 for an init state).  Identity for
    ``downlink='f32'``.  Idempotent: a state already carrying
    ``cfg.downlink``'s wire words passes through unchanged (encoding
    wire words as if they were f32 scores would saturate them all to
    the top code); a state encoded with a DIFFERENT codec raises.  The
    match is a full SIGNATURE check (dtype + packed lane count, the
    explicit-tag validation of ``core.zampling.validate_carried``) —
    the packed sub-byte codecs all share the uint32 carrier, so dtype
    sniffing alone cannot tell them apart.  With the frontier schedule
    the returned state additionally carries the per-tensor width
    vector ``state['downlink_b']``."""
    codec = get_codec(cfg.downlink)
    try:
        validate_carried(zspecs, state["scores"], codec.name)
        return _with_schedule_state(zspecs, cfg, state)
    except ValueError:
        pass
    carried = infer_downlink(state["scores"])
    if carried != "f32":
        raise ValueError(
            f"state is already encoded with downlink codec {carried!r}; "
            f"decode_state it first before re-encoding as "
            f"{codec.name!r}"
        )
    if not codec.quantized:
        return _with_schedule_state(zspecs, cfg, state)
    w = as_word(word)
    scores = {
        path: codec.encode(spec, state["scores"][path], w)
        for path, spec in zspecs.specs.items()
    }
    return _with_schedule_state(zspecs, cfg, {**state, "scores": scores})


def decode_state(zspecs: ZamplingSpecs, cfg: FederatedConfig, state):
    """Wire-encoded round carry -> f32 score state (server-side
    analysis helper; the lossy inverse of ``encode_state``)."""
    program = mask_program(zspecs, cfg)
    return {**state, "scores": program.decode_scores(state["scores"])}


def local_update(
    zspecs: ZamplingSpecs,
    state: Dict[str, Any],
    loss_fn: LossFn,
    batches,  # (local_steps, ...) stacked client batches
    key,  # PRNG key or uint32 draw word identifying (round, client)
    cfg: FederatedConfig,
    opt: Optional[Optimizer] = None,
    constraints=None,
    row_sharding=None,
):
    """One client's round: E local score-steps -> the upload draw.

    Returns (z_new, dense_new, mean_loss); ``z_new`` is {path: uint32
    wire lanes} when the configured transport is packed (sample mode),
    else {path: f32 masks/probs}.  Dense (non-reparametrized) leaves
    are trained locally too and aggregated by plain averaging (they are
    tiny: norms/biases).

    Draw keying: local step ``e`` draws at word ``fold_word(kw, e)``
    and the upload at ``fold_word(kw, E)``, where ``kw = as_word(key)``
    — the integer step counter is the scanned xs, so the in-kernel
    draw of the fused path and this oracle generate identical bits.

    ``state['scores']`` arrives in ``cfg.downlink``'s wire
    representation (the encoded broadcast); the client decodes its own
    TRAINABLE copy here — identity for the ``f32`` oracle codec, the
    exact widened-threshold probabilities for the quantized codecs.
    """
    opt = opt or sgd(cfg.local_lr)
    program = mask_program(zspecs, cfg)
    kw = as_word(key)
    scores0 = program.decode_scores(state["scores"])
    dense0 = dict(state["dense"])

    def loss_of(trainable, batch, step_word):
        params = program.weights(
            trainable["scores"], trainable["dense"], step_word,
            constraints=constraints, row_sharding=row_sharding,
        )
        return loss_fn(params, batch)

    def step(carry, xs):
        trainable, opt_state = carry
        batch, e = xs
        loss, grads = jax.value_and_grad(loss_of)(
            trainable, batch, fold_word(kw, e)
        )
        updates, opt_state = opt.update(grads, opt_state, trainable)
        trainable = jax.tree.map(lambda p, u: p + u, trainable, updates)
        return (trainable, opt_state), loss

    trainable0 = {"scores": scores0, "dense": dense0}
    steps = jnp.arange(cfg.local_steps, dtype=jnp.uint32)
    (trainable, _), losses = jax.lax.scan(
        step, (trainable0, opt.init(trainable0)), (batches, steps)
    )

    # p_new = f(s_new); z_new ~ Bern(p_new) — the n bits sent upstream,
    # drawn at the next counter value (E) and emitted as wire lanes on
    # the packed transports (fused: in-kernel, no f32 mask slab).
    z_new = program.upload(trainable["scores"],
                           fold_word(kw, cfg.local_steps))
    return z_new, trainable["dense"], jnp.mean(losses)


# byte-count keys every round's metrics dict carries (comm.metering);
# launch code sizing shard_map out_specs keys off the metrics tree uses
# this instead of hardcoding {"loss"}
WIRE_METRIC_KEYS = (
    "uplink_bytes_per_client",
    "uplink_bytes_round",
    "downlink_bytes_per_client",
    "downlink_bytes_round",
    "naive_uplink_bytes_per_client",
)

# realized-cohort counters (partial participation; repro.fault) — the
# plain full-participation round reports them too (all clients
# participating, nothing skipped), so EVERY round's metrics dict has
# the identical key set and shard_map out_specs never depend on the
# participation arguments
PARTICIPATION_METRIC_KEYS = (
    "cohort_size",
    "num_participating",
    "num_dropped",
    "num_stragglers",
    "num_corrupt",
    "num_duplicates",
    "weight_sum",
    "round_skipped",
)

# THE key set of a round's metrics dict: size shard_map out_specs from
# this (tests/_helpers.round_metric_specs, launch.dryrun), never from
# a hardcoded subset
ROUND_METRIC_KEYS = ("loss",) + WIRE_METRIC_KEYS + PARTICIPATION_METRIC_KEYS


def _wire_metrics(zspecs: ZamplingSpecs, cfg: FederatedConfig,
                  num_clients: int, b_vec=None):
    """Exact byte counts for this round's traffic (static per config).

    ``num_clients`` is the round's REALIZED cohort size — the stacked
    batch's leading axis on the vmap path, the mesh axis size on the
    sharded path — never ``cfg.num_clients``, which only names the
    default population size.

    ``b_vec``: a scheduled round's traced per-tensor width vector —
    the downlink counts are overridden with the REALIZED bits at those
    widths (``comm.metering.scheduled_wire_metrics``: lane packing and
    padding included), so the metrics report what actually crossed the
    wire, not the carry's configured width.  Key set unchanged (values
    become traced f32).
    """
    rep = round_wire_report(
        zspecs, cfg.aggregate, num_clients,
        mode=cfg.mode, downlink=cfg.downlink,
    )
    out = {k: rep[k] for k in WIRE_METRIC_KEYS}
    if b_vec is not None:
        sched = scheduled_wire_metrics(out, zspecs, b_vec, num_clients)
        out = {k: sched[k] for k in WIRE_METRIC_KEYS}
    return out


def _full_participation_metrics(k: int):
    """The participation counters of a plain full-participation round:
    everyone sampled, everyone weight 1, nothing faulted or skipped."""
    return {
        "cohort_size": float(k),
        "num_participating": float(k),
        "num_dropped": 0.0,
        "num_stragglers": 0.0,
        "num_corrupt": 0.0,
        "num_duplicates": 0.0,
        "weight_sum": float(k),
        "round_skipped": 0.0,
    }


def _encode_scores(zspecs: ZamplingSpecs, cfg: FederatedConfig,
                   scores, key, round_index, b_vec=None):
    """Re-encode the aggregated p(t+1) as the next round's broadcast.

    The dither word ``fold_word(key_word(key), round_index)`` is
    derived from REPLICATED values only, so the vmap server and every
    shard_map shard produce bit-identical encodings (the dither stream
    has its own counter space — it can never alias a client draw
    word).  Identity for ``downlink='f32'``.

    ``b_vec``: the scheduled round's traced per-tensor widths — tensor
    i quantizes at ``b_vec[i]`` bits and the scheduled word is widened
    into the codec's fixed carry width by the exact divisor embedding
    (``encode_at``); only b bits/coord cross the wire.  ``None`` (the
    constant schedule) is the plain fixed-width path, bitwise
    untouched.
    """
    codec = get_codec(cfg.downlink)
    if not codec.quantized:
        return scores
    w = fold_word(as_word(key), jnp.asarray(round_index).astype(jnp.uint32))
    if b_vec is None:
        return {
            path: codec.encode(spec, scores[path], w)
            for path, spec in zspecs.specs.items()
        }
    return {
        path: codec.encode_at(spec, scores[path], w, b_vec[i])
        for i, (path, spec) in enumerate(zspecs.specs.items())
    }


def _round_b_vec(zspecs: ZamplingSpecs, cfg: FederatedConfig, state,
                 round_index):
    """This round's per-tensor downlink width vector (traced uint32),
    or ``None`` on the constant schedule (the plain fixed-codec path).

    cosine: one width for every tensor, annealed from
    ``schedule_b_min`` up to the codec's full width over
    ``schedule_rounds`` rounds (half-cosine, clamped at the horizon) —
    coarse broadcasts while the scores are still moving fast, full
    precision at convergence.  frontier: the carried measured widths
    ``state['downlink_b']`` (updated per round by
    ``_frontier_next_b``).  Both are functions of traced per-round
    values only, so an R-round scan compiles ONCE.
    """
    if cfg.downlink_schedule == "constant":
        return None
    if cfg.downlink_schedule == "frontier":
        b = state.get("downlink_b")
        if b is None:  # direct round call without encode_state
            b = jnp.full((len(zspecs.specs),), cfg.schedule_b_min,
                         jnp.uint32)
        return jnp.asarray(b).astype(jnp.uint32)
    codec = get_codec(cfg.downlink)
    horizon = jnp.float32(cfg.schedule_rounds)
    t = jnp.minimum(jnp.asarray(round_index).astype(jnp.float32), horizon)
    span = jnp.float32(codec.bits - cfg.schedule_b_min)
    b = (jnp.float32(cfg.schedule_b_min)
         + span * (1.0 - jnp.cos(jnp.pi * t / horizon)) * 0.5)
    b = jnp.clip(jnp.round(b), cfg.schedule_b_min, codec.bits)
    return jnp.full((len(zspecs.specs),), 1, jnp.uint32) * b.astype(
        jnp.uint32)


def _flip_fraction(p, b, b_hi):
    """Expected fraction of draw words that flip between widths ``b``
    and ``b_hi`` for probabilities ``p``: the draw at width b fires
    iff ``(u >> 8) < T_b``, so for a uniform word the flip probability
    at one coordinate is ``|T_b - T_hi| * 2^-24`` — no dither, no
    draws: a deterministic probe of how much probability mass the
    coarser lattice is displacing."""
    def thr(bits):
        bf = ((jnp.uint32(1) << bits) - jnp.uint32(1)).astype(jnp.float32)
        q = jnp.clip(jnp.floor(p * bf + 0.5), 0.0, bf).astype(jnp.uint32)
        return quant_threshold_u24_dyn(q, bits)

    t_lo, t_hi = thr(b), thr(b_hi)
    diff = jnp.where(t_lo > t_hi, t_lo - t_hi, t_hi - t_lo)
    return jnp.mean(diff.astype(jnp.float32)) * jnp.float32(2.0 ** -24)


def _frontier_next_b(zspecs: ZamplingSpecs, cfg: FederatedConfig,
                     agg, b_vec):
    """The frontier controller: next round's per-tensor widths from
    the round's f32 aggregate — the scores ABOUT to be encoded, probed
    BEFORE the lattice coarsens them (the decoded b-bit carry sits
    exactly on the b-bit lattice, so a post-encode probe would read a
    flip fraction of zero forever).  Tensor i probes the draw-word
    flip fraction between its current width b and b+2
    (``_flip_fraction``; the aggregate is replicated post-collective,
    so every shard computes the identical widths); flips above
    ``frontier_threshold`` mean the coarse lattice is audibly
    displacing mass -> widen by 2, flips under a quarter of it mean
    precision is being wasted -> narrow by 2.  Clamped to
    [schedule_b_min, codec.bits]."""
    codec = get_codec(cfg.downlink)
    b_max = jnp.uint32(codec.bits)
    nxt = []
    for i, (path, spec) in enumerate(zspecs.specs.items()):
        b = b_vec[i]
        p = clip_probs(jnp.asarray(agg[path], jnp.float32))
        flip = _flip_fraction(p, b, jnp.minimum(b + jnp.uint32(2), b_max))
        up = flip > jnp.float32(cfg.frontier_threshold)
        down = flip < jnp.float32(cfg.frontier_threshold / 4.0)
        nb = jnp.where(up, b + jnp.uint32(2),
                       jnp.where(down & (b > jnp.uint32(2)),
                                 b - jnp.uint32(2), b))
        nxt.append(jnp.clip(nb, jnp.uint32(cfg.schedule_b_min), b_max))
    return jnp.stack(nxt)


def _schedule_state_out(zspecs: ZamplingSpecs, cfg: FederatedConfig,
                        agg, state, b_vec, skip=None):
    """The extra carried leaves of a scheduled round's output state
    (frontier's width vector, measured on the round's f32 aggregate;
    empty otherwise).  On a skipped round the widths pass through
    unchanged with the rest of the carry."""
    if cfg.downlink_schedule != "frontier":
        return {}
    nb = _frontier_next_b(zspecs, cfg, agg, b_vec)
    if skip is not None:
        nb = jnp.where(skip, jnp.asarray(state["downlink_b"],
                                         jnp.uint32), nb)
    return {"downlink_b": nb}


def _aggregate_stacked(zspecs, transport, packed, z_all):
    """Server reduction over the stacked client axis, packed or f32."""
    if packed:
        return {
            p: transport.aggregate_stacked_packed(z_all[p],
                                                  zspecs.specs[p].n)
            for p in z_all
        }
    return {p: transport.aggregate_stacked(z) for p, z in z_all.items()}


def _resolve_faults(zspecs, packed, z_all, faults, round_index, ids):
    """Shared per-upload fault pipeline of both drivers.

    ``z_all``/``ids`` carry a (K,) client axis on the vmap path and are
    per-shard (no client axis) under shard_map — the draws key on the
    CLIENT ID either way, so the scenarios coincide bit-for-bit.
    Returns (z_wire, codes, arrived, participating): the uploads as
    the server RECEIVES them (corruption applied), the per-client
    fault codes, the arrival bits (bytes on the wire), and
    ``arrived & validated`` (counted in the aggregate).
    """
    # late import: core.federated is imported by repro.core's __init__,
    # while repro.fault imports core.hashrng — binding at trace time
    # keeps the package import order acyclic in both directions
    from ..fault.plan import CORRUPT, DROP, STRAGGLER, corrupt_uploads, draw_faults
    from ..fault.validate import upload_counts, validate_uploads

    declared = upload_counts(z_all, zspecs, packed)
    if faults is not None:
        codes = draw_faults(faults, round_index, ids)
        z_wire = corrupt_uploads(faults, z_all, declared, codes == CORRUPT,
                                 round_index, ids, zspecs, packed)
    else:
        codes = jnp.zeros(jnp.shape(ids), jnp.uint32)
        z_wire = z_all
    arrived = (codes != DROP) & (codes != STRAGGLER)
    # server-side validation runs on the RECEIVED payload — the genuine
    # check, not a read-back of the injector's corrupt flag
    valid = validate_uploads(z_wire, declared, zspecs, packed)
    return z_wire, codes, arrived, arrived & valid


def _fault_counts(codes, arrived, participating, live=None):
    """Realized-cohort counters from per-client fault state (f32).

    ``live`` masks out the padding lanes of a streaming chunk (the last
    chunk is padded up to ``stream_chunk`` with replayed clients at
    weight 0) — a padded lane must not count anywhere."""
    from ..fault.plan import DROP, DUPLICATE, STRAGGLER

    def cnt(mask):
        if live is not None:
            mask = mask & live
        return jnp.sum(mask.astype(jnp.float32))

    dup = cnt(codes == DUPLICATE)
    return {
        "num_participating": cnt(participating),
        "num_dropped": cnt(codes == DROP),
        "num_stragglers": cnt(codes == STRAGGLER),
        "num_corrupt": cnt(arrived & ~participating),
        "num_duplicates": dup,
        # arrivals spend uplink bytes even when validation rejects
        # them; each duplicate upload arrives twice
        "uplink_units": cnt(arrived) + dup,
    }


# streaming-carry counter keys: the f32 scalars accumulated across
# chunks alongside the vote counts (uplink_units is popped into the
# realized byte metrics, the rest are PARTICIPATION_METRIC_KEYS)
_STREAM_COUNTER_KEYS = ("num_participating", "num_dropped",
                        "num_stragglers", "num_corrupt",
                        "num_duplicates", "uplink_units")


def _streaming_round(zspecs, state, loss_fn, client_batches, key, cfg,
                     opt, transport, packed, *, round_index, ids, w,
                     faults, k):
    """The unbounded-K round: a ``lax.scan`` over upload CHUNKS with
    the unnormalized weighted vote counts as carry.

    The slab round materializes every client's upload as a (K, lanes)
    stack before reducing, so device memory — not the wire — caps K.
    Here the K clients are processed ``stream_chunk`` at a time: each
    scan step runs the chunk's local updates, applies the per-upload
    fault pipeline (``_resolve_faults`` is shape-polymorphic over the
    leading axis, so draws still key on the GLOBAL client id and any
    fault scenario replays bit-identically), and FOLDS the chunk's
    uploads into the carry via the transport's ``fold_stacked_*``
    hooks.  Peak upload memory is O(chunk·n), independent of K.

    Carry = {uint32 (or exact-integer f32) vote counts per tensor, f32
    weighted dense sums, uint32 weight sum, f32 loss sum, f32 fault
    counters}.  Integer sums are associative, so after the final
    reciprocal normalization the scores are BIT-IDENTICAL to the slab
    path at any K and chunk size; dense leaves and loss are f32 sums
    re-associated across chunks (allclose, not bitwise — same contract
    as the cross-driver comparison).

    ``k % stream_chunk != 0`` pads the last chunk by replaying leading
    clients at weight 0 under a ``live=False`` mask: a padded lane
    replays a real client's fault draw and upload but is excluded from
    the vote counts, the weight sum, every counter, and the loss.
    """
    chunk = cfg.stream_chunk
    nchunks = -(-k // chunk)
    pad = nchunks * chunk - k

    def chunked(x):
        if pad:
            x = jnp.concatenate([x, x[:pad]], axis=0)
        return x.reshape((nchunks, chunk) + x.shape[1:])

    live = jnp.arange(nchunks * chunk, dtype=jnp.uint32) < jnp.uint32(k)
    xs = {
        "batches": jax.tree.map(chunked, client_batches),
        "ids": chunked(ids),
        "w": chunked(w),
        "live": live.reshape(nchunks, chunk),
    }
    rword = jnp.asarray(round_index).astype(jnp.uint32)

    def one(batches, word):
        return local_update(zspecs, state, loss_fn, batches, word, cfg,
                            opt)

    carry0 = {
        "votes": {p: transport.stream_init(spec.n)
                  for p, spec in zspecs.specs.items()},
        "dense": jax.tree.map(
            lambda d: jnp.zeros(jnp.shape(d), jnp.float32),
            dict(state["dense"]),
        ),
        "wsum": jnp.uint32(0),
        "loss": jnp.float32(0),
        **{c: jnp.float32(0) for c in _STREAM_COUNTER_KEYS},
    }

    def body(carry, x):
        words = fold_word(as_word(key), rword, x["ids"])
        z_all, dense_all, losses = jax.vmap(one)(x["batches"], words)
        z_wire, codes, arrived, participating = _resolve_faults(
            zspecs, packed, z_all, faults, round_index, x["ids"])
        chunk_live = x["live"]
        participating = participating & chunk_live
        w_eff = x["w"] * participating.astype(jnp.uint32)
        if packed:
            votes = {
                p: transport.fold_stacked_packed_weighted(
                    carry["votes"][p], z_wire[p], zspecs.specs[p].n,
                    w_eff)
                for p in z_wire
            }
        else:
            votes = {
                p: transport.fold_stacked_weighted(carry["votes"][p], z,
                                                   w_eff)
                for p, z in z_wire.items()
            }
        w_f = w_eff.astype(jnp.float32)

        def dense_fold(acc, d):
            wcol = w_f.reshape((chunk,) + (1,) * (d.ndim - 1))
            return acc + jnp.sum(d * wcol, axis=0)

        counts = _fault_counts(codes, arrived, participating,
                               live=chunk_live)
        new = {
            "votes": votes,
            "dense": jax.tree.map(dense_fold, carry["dense"], dense_all),
            "wsum": carry["wsum"] + jnp.sum(w_eff, dtype=jnp.uint32),
            "loss": carry["loss"] + jnp.sum(
                losses * participating.astype(jnp.float32)),
            **{c: carry[c] + counts[c] for c in _STREAM_COUNTER_KEYS},
        }
        return new, None

    acc, _ = jax.lax.scan(body, carry0, xs)

    wsum = acc["wsum"].astype(jnp.float32)
    safe_wsum = jnp.where(wsum > 0, wsum, jnp.float32(1))
    # reciprocal form, matching the slab participation branch — see
    # federated_round
    recip = jnp.float32(1.0) / safe_wsum
    agg = {
        p: (v.astype(jnp.float32) if packed else v) * recip
        for p, v in acc["votes"].items()
    }
    b_vec = _round_b_vec(zspecs, cfg, state, round_index)
    new_enc = _encode_scores(zspecs, cfg, agg, key, round_index, b_vec)
    new_dense_agg = jax.tree.map(lambda a: a * recip, acc["dense"])
    skip = acc["num_participating"] < cfg.min_clients
    new_scores = {
        p: jnp.where(skip, state["scores"][p], new_enc[p])
        for p in new_enc
    }
    new_dense = jax.tree.map(
        lambda old, new: jnp.where(skip, old, new),
        dict(state["dense"]), new_dense_agg,
    )
    cnt = acc["num_participating"]
    safe_cnt = jnp.where(cnt > 0, cnt, jnp.float32(1))
    loss = acc["loss"] * (jnp.float32(1.0) / safe_cnt)
    metrics = {
        "loss": loss,
        **realized_wire_metrics(_wire_metrics(zspecs, cfg, k, b_vec),
                                acc["uplink_units"], k),
        "cohort_size": float(k),
        **{c: acc[c] for c in _STREAM_COUNTER_KEYS
           if c != "uplink_units"},
        "weight_sum": wsum,
        "round_skipped": skip.astype(jnp.float32),
    }
    return {"scores": new_scores, "dense": new_dense,
            **_schedule_state_out(zspecs, cfg, agg, state, b_vec,
                                  skip)}, metrics


def federated_round(
    zspecs: ZamplingSpecs,
    state: Dict[str, Any],
    loss_fn: LossFn,
    client_batches,  # pytree with leading axes (K, local_steps, ...)
    key,
    cfg: FederatedConfig,
    opt: Optional[Optimizer] = None,
    *,
    round_index=0,
    client_ids=None,  # (K,) uint32 cohort ids; None = arange(K)
    weights=None,  # (K,) uint32 sample-count weights; None = all ones
    faults: Optional["FaultPlan"] = None,  # noqa: F821 — repro.fault
):
    """Full round over K stacked clients (vmap). Returns (state', metrics).

    ``round_index``: the round counter folded into every draw word
    (threaded by ``train.fit.federated_fit``'s scan); client k draws
    from word ``hash(key_word(key), round_index, client_id_k)``.

    ``client_ids`` / ``weights`` / ``faults`` switch on the
    partial-participation path (weighted aggregation over the realized
    cohort, skip below ``cfg.min_clients``; see the module docstring).
    With all three None the plain full-participation protocol runs —
    the exact PR-5 code path, bit for bit.  K is the stacked batch's
    leading axis; ``cfg.num_clients`` only names the default
    population.

    ``cfg.stream_chunk > 0`` (and < K) reroutes to the streaming
    accumulator (``_streaming_round``): same signature, same metrics
    key set, bit-identical scores, O(stream_chunk·n) peak upload
    memory instead of O(K·n).
    """
    transport = resolve_transport(cfg.aggregate, cfg.mode)
    packed = mask_program(zspecs, cfg).packed
    k = jax.tree.leaves(client_batches)[0].shape[0]
    participation = (client_ids is not None or weights is not None
                     or faults is not None)
    ids = (jnp.arange(k, dtype=jnp.uint32) if client_ids is None
           else jnp.asarray(client_ids).astype(jnp.uint32))
    if cfg.stream_chunk and cfg.stream_chunk < k:
        # streaming aggregation: fold uploads chunk-by-chunk into the
        # vote-count carry; the (K, lanes) slab never materializes and
        # the scores are bit-identical to the slab path below
        w = (jnp.ones((k,), jnp.uint32) if weights is None
             else jnp.asarray(weights).astype(jnp.uint32))
        return _streaming_round(
            zspecs, state, loss_fn, client_batches, key, cfg, opt,
            transport, packed, round_index=round_index, ids=ids, w=w,
            faults=faults, k=k,
        )
    words = fold_word(
        as_word(key), jnp.asarray(round_index).astype(jnp.uint32), ids,
    )

    def one(batches, w):
        return local_update(zspecs, state, loss_fn, batches, w, cfg, opt)

    z_all, dense_all, losses = jax.vmap(one)(client_batches, words)

    if not participation:
        # server aggregation: p(t+1) = mean_k z^(k), via the wire
        # transport, re-encoded as the next broadcast (cfg.downlink's
        # wire words)
        b_vec = _round_b_vec(zspecs, cfg, state, round_index)
        agg = _aggregate_stacked(zspecs, transport, packed, z_all)
        new_scores = _encode_scores(zspecs, cfg, agg, key, round_index,
                                    b_vec)
        new_dense = jax.tree.map(lambda d: jnp.mean(d, axis=0), dense_all)
        metrics = {"loss": jnp.mean(losses),
                   **_wire_metrics(zspecs, cfg, k, b_vec),
                   **_full_participation_metrics(k)}
        return {"scores": new_scores, "dense": new_dense,
                **_schedule_state_out(zspecs, cfg, agg, state,
                                      b_vec)}, metrics

    # ---- partial participation: faults -> validation -> weighted mean
    z_wire, codes, arrived, participating = _resolve_faults(
        zspecs, packed, z_all, faults, round_index, ids)
    w = (jnp.ones((k,), jnp.uint32) if weights is None
         else jnp.asarray(weights).astype(jnp.uint32))
    w_eff = w * participating.astype(jnp.uint32)
    wsum = jnp.sum(w_eff, dtype=jnp.uint32).astype(jnp.float32)
    safe_wsum = jnp.where(wsum > 0, wsum, jnp.float32(1))
    # RECIPROCAL form everywhere below, never `x / safe_wsum`: XLA
    # strength-reduces the legacy path's divisions by a CONSTANT count
    # (aggregate_stacked's `/ K`, jnp.mean, psum / axis_size) into a
    # reciprocal multiply, and a runtime `x * (1/w)` reproduces that
    # bit for bit at any K while a true division drifts by an ulp
    # whenever the weight sum is not a power of two
    recip = jnp.float32(1.0) / safe_wsum
    if packed:
        agg = {
            p: transport.aggregate_stacked_packed_weighted(
                z_wire[p], zspecs.specs[p].n, w_eff
            ).astype(jnp.float32) * recip
            for p in z_wire
        }
    else:
        agg = {
            p: transport.aggregate_stacked_weighted(z, w_eff) * recip
            for p, z in z_wire.items()
        }
    counters = _fault_counts(codes, arrived, participating)
    b_vec = _round_b_vec(zspecs, cfg, state, round_index)
    new_enc = _encode_scores(zspecs, cfg, agg, key, round_index, b_vec)
    w_f = w_eff.astype(jnp.float32)

    def dense_mean(d):
        wcol = w_f.reshape((k,) + (1,) * (d.ndim - 1))
        return jnp.sum(d * wcol, axis=0) * recip

    new_dense_agg = jax.tree.map(dense_mean, dense_all)
    # skip-round: below min_clients the carried state passes through
    # unchanged (averaging a near-empty cohort is sampling noise)
    skip = counters["num_participating"] < cfg.min_clients
    new_scores = {
        p: jnp.where(skip, state["scores"][p], new_enc[p])
        for p in new_enc
    }
    new_dense = jax.tree.map(
        lambda old, new: jnp.where(skip, old, new),
        dict(state["dense"]), new_dense_agg,
    )
    part_f = participating.astype(jnp.float32)
    cnt = counters["num_participating"]
    safe_cnt = jnp.where(cnt > 0, cnt, jnp.float32(1))
    loss = jnp.sum(losses * part_f) * (jnp.float32(1.0) / safe_cnt)
    uplink_units = counters.pop("uplink_units")
    metrics = {
        "loss": loss,
        **realized_wire_metrics(_wire_metrics(zspecs, cfg, k, b_vec),
                                uplink_units, k),
        "cohort_size": float(k),
        **counters,
        "weight_sum": wsum,
        "round_skipped": skip.astype(jnp.float32),
    }
    return {"scores": new_scores, "dense": new_dense,
            **_schedule_state_out(zspecs, cfg, agg, state, b_vec,
                                  skip)}, metrics


def sharded_client_update(
    zspecs: ZamplingSpecs,
    state: Dict[str, Any],
    loss_fn: LossFn,
    batches,
    key,
    cfg: FederatedConfig,
    *,
    axis_names=("data",),
    opt: Optional[Optimizer] = None,
    constraints=None,
    row_sharding=None,
    round_index=0,
    client_id=None,  # this shard's global client id; None = axis index
    weight=None,  # this shard's uint32 sample-count weight; None = 1
    faults: Optional["FaultPlan"] = None,  # noqa: F821 — repro.fault
):
    """Body to run under ``shard_map``: client id = mesh position.

    The mask aggregation is the ONLY cross-client communication; the
    configured transport decides its wire format — an f32 psum
    (``mean_f32``), a uint32 popcount psum of the packed lanes
    (``psum_u32``), or an all-gather of the raw packed lanes
    (``allgather_packed``) over the client axes.  On the packed
    transports the collective operand IS the lanes the fused kernel
    emitted — no f32 mask slab exists on this path at all.  The draw
    words match ``federated_round``'s (client id = axis index), so the
    two paths are bit-identical for the same key/round_index.

    ``client_id`` / ``weight`` / ``faults`` switch on the
    partial-participation path — fault draws, upload validation, and
    the weighted psum key on the GLOBAL client id (per-shard scalars
    here), so a scenario replays bit-identically against the vmap
    driver run over the same cohort.
    """
    from ..comm.shardmap import axis_size

    transport = resolve_transport(cfg.aggregate, cfg.mode)
    packed = mask_program(zspecs, cfg).packed
    participation = (client_id is not None or weight is not None
                     or faults is not None)
    idx = sum(
        jax.lax.axis_index(a) * 1_000_003 ** i for i, a in enumerate(axis_names)
    )
    my_id = (jnp.asarray(idx) if client_id is None
             else jnp.asarray(client_id)).astype(jnp.uint32)
    word = fold_word(
        as_word(key), jnp.asarray(round_index).astype(jnp.uint32), my_id,
    )
    z_new, dense_new, loss = local_update(
        zspecs, state, loss_fn, batches, word, cfg, opt,
        constraints=constraints, row_sharding=row_sharding,
    )
    nclients = axis_size(axis_names)

    if not participation:
        if packed:
            new_scores = {
                p: transport.aggregate_collective_packed(
                    z, zspecs.specs[p].n, axis_names
                )
                for p, z in z_new.items()
            }
        else:
            new_scores = {
                p: transport.aggregate_collective(z, axis_names)
                for p, z in z_new.items()
            }
        # re-encode the replicated aggregate as the next broadcast: the
        # dither word comes from the replicated (key, round_index), so
        # all shards produce the identical encoding — bit-equal to the
        # vmap path (the schedule's b_vec is likewise a function of
        # replicated values only)
        b_vec = _round_b_vec(zspecs, cfg, state, round_index)
        agg = new_scores
        new_scores = _encode_scores(zspecs, cfg, agg, key,
                                    round_index, b_vec)
        # dense leaves stay on the f32 psum path: XLA:CPU's
        # AllReducePromotion pass aborts on bf16 all-reduces (and f32
        # is the numerically right accumulator anyway)
        new_dense = jax.tree.map(
            lambda d: (jax.lax.psum(d.astype(jnp.float32), axis_names)
                       / nclients).astype(d.dtype),
            dense_new,
        )
        loss = jax.lax.pmean(loss, axis_names)
        # the mesh axis size, not cfg.num_clients, is the real K here
        metrics = {"loss": loss,
                   **_wire_metrics(zspecs, cfg, nclients, b_vec),
                   **_full_participation_metrics(nclients)}
        return {"scores": new_scores, "dense": new_dense,
                **_schedule_state_out(zspecs, cfg, agg, state,
                                      b_vec)}, metrics

    # ---- partial participation: every per-client quantity is a
    # per-shard scalar; the psums realize the weighted server sum
    z_wire, code, arrived, participating = _resolve_faults(
        zspecs, packed, z_new, faults, round_index, my_id)
    w = (jnp.uint32(1) if weight is None
         else jnp.asarray(weight).astype(jnp.uint32))
    w_eff = w * participating.astype(jnp.uint32)
    wsum = jax.lax.psum(w_eff, tuple(axis_names)).astype(jnp.float32)
    safe_wsum = jnp.where(wsum > 0, wsum, jnp.float32(1))
    # reciprocal form, matching the vmap driver and the legacy path's
    # constant divisions after XLA's strength reduction — see
    # federated_round's participation branch
    recip = jnp.float32(1.0) / safe_wsum
    if packed:
        agg = {
            p: transport.aggregate_collective_packed_weighted(
                z, zspecs.specs[p].n, w_eff, axis_names
            ).astype(jnp.float32) * recip
            for p, z in z_wire.items()
        }
    else:
        agg = {
            p: transport.aggregate_collective_weighted(
                z, w_eff, axis_names
            ) * recip
            for p, z in z_wire.items()
        }
    b_vec = _round_b_vec(zspecs, cfg, state, round_index)
    new_enc = _encode_scores(zspecs, cfg, agg, key, round_index, b_vec)
    counters = {
        k: jax.lax.psum(v, tuple(axis_names))
        for k, v in _fault_counts(code, arrived, participating).items()
    }
    w_f = w_eff.astype(jnp.float32)
    new_dense_agg = jax.tree.map(
        lambda d: (jax.lax.psum(d.astype(jnp.float32) * w_f, axis_names)
                   * recip).astype(d.dtype),
        dense_new,
    )
    skip = counters["num_participating"] < cfg.min_clients
    new_scores = {
        p: jnp.where(skip, state["scores"][p], new_enc[p])
        for p in new_enc
    }
    new_dense = jax.tree.map(
        lambda old, new: jnp.where(skip, old, new),
        dict(state["dense"]), new_dense_agg,
    )
    cnt = counters["num_participating"]
    safe_cnt = jnp.where(cnt > 0, cnt, jnp.float32(1))
    loss = jax.lax.psum(
        loss * participating.astype(jnp.float32), tuple(axis_names)
    ) * (jnp.float32(1.0) / safe_cnt)
    uplink_units = counters.pop("uplink_units")
    metrics = {
        "loss": loss,
        **realized_wire_metrics(
            _wire_metrics(zspecs, cfg, nclients, b_vec),
            uplink_units, nclients),
        "cohort_size": float(nclients),
        **counters,
        "weight_sum": wsum,
        "round_skipped": skip.astype(jnp.float32),
    }
    return {"scores": new_scores, "dense": new_dense,
            **_schedule_state_out(zspecs, cfg, agg, state, b_vec,
                                  skip)}, metrics
