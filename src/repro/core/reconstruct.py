"""Reference (pure-jnp) reconstruction ``w = Q z``.

This is the oracle the Pallas kernel and the distributed shard_map op
are validated against, and the default path on CPU.  Differentiable in
``z`` (the transpose is a scatter-add, i.e. ``grad_z = Q^T grad_w``,
exactly the paper's ``∇_s L = (∇_w L ⊙ Q)`` chain).

Layout (QSpec docstring): rows live in a padded per-block space of
``shard_count`` x ``m_pad_loc``; valid rows map to the tensor flattened
with ``major_axis`` moved to the front (sharding-major order).  All
functions here compute globally — the distributed equivalent is
``kernels.qz_sharded``.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .qspec import QSpec, padded_row_valid, padded_row_window, row_indices, row_values


def _w_padded(spec: QSpec, z):
    """All padded rows: w_pad (m_pad,) f32."""
    rp = jnp.arange(spec.m_pad, dtype=jnp.uint32)
    win = padded_row_window(spec, rp.astype(jnp.int32))
    idx = row_indices(spec, rp)  # (m_pad, d) in-window
    vals = row_values(spec, rp, dtype=jnp.float32)
    gidx = win[:, None] * spec.window + idx
    zg = jnp.take(z.astype(jnp.float32), gidx, axis=0)
    return jnp.sum(vals * zg, axis=-1)


def _select_valid(spec: QSpec, w_pad):
    """(m_pad,) -> (m,) in moved (sharding-major) flat order."""
    return w_pad.reshape(spec.shard_count, spec.m_pad_loc)[
        :, : spec.m_blk
    ].reshape(-1)


def _insert_padding(spec: QSpec, flat_moved):
    """(m,) moved order -> (m_pad,) with per-block padding zeros."""
    blocks = flat_moved.reshape(spec.shard_count, spec.m_blk)
    return jnp.pad(
        blocks, ((0, 0), (0, spec.m_pad_loc - spec.m_blk))
    ).reshape(-1)


def _unmove(spec: QSpec, flat_moved):
    w = flat_moved.reshape(spec.moved_shape)
    return jnp.moveaxis(w, 0, spec.major_axis)


def _move(spec: QSpec, w):
    return jnp.moveaxis(w, spec.major_axis, 0).reshape(-1)


def reconstruct_ref(spec: QSpec, z, dtype=None, row_sharding=None):
    """w = Q z for one tensor. ``z``: (n,) -> weights with spec.shape."""
    del row_sharding  # the ref path computes globally
    if z.shape != (spec.n,):
        raise ValueError(f"z has shape {z.shape}, spec expects ({spec.n},)")
    dtype = dtype or z.dtype
    w = _select_valid(spec, _w_padded(spec, z))
    return _unmove(spec, w).astype(dtype)


def grad_z_ref(spec: QSpec, grad_w, row_sharding=None):
    """Q^T grad_w — the reconstruction transpose. Returns (n,) f32."""
    del row_sharding
    g = _insert_padding(spec, _move(spec, grad_w.astype(jnp.float32)))
    rp = jnp.arange(spec.m_pad, dtype=jnp.uint32)
    win = padded_row_window(spec, rp.astype(jnp.int32))
    idx = row_indices(spec, rp)
    vals = row_values(spec, rp)
    gidx = (win[:, None] * spec.window + idx).reshape(-1)
    out = jnp.zeros((spec.n,), jnp.float32)
    return out.at[gidx].add((vals * g[:, None]).reshape(-1))


def materialize_q(spec: QSpec):
    """Dense (m, n) Q in NATURAL (spec.shape row-major) order —
    tests/small-scale theory checks ONLY."""
    rp = jnp.arange(spec.m_pad, dtype=jnp.uint32)
    win = padded_row_window(spec, rp.astype(jnp.int32))
    idx = row_indices(spec, rp)
    vals = row_values(spec, rp)
    gidx = win[:, None] * spec.window + idx
    q_pad = jnp.zeros((spec.m_pad, spec.n), jnp.float32)
    q_pad = q_pad.at[jnp.arange(spec.m_pad)[:, None], gidx].add(vals)
    q_moved = q_pad.reshape(spec.shard_count, spec.m_pad_loc, spec.n)[
        :, : spec.m_blk
    ].reshape(spec.m, spec.n)
    # moved flat order -> natural order rows
    q = q_moved.reshape(*spec.moved_shape, spec.n)
    q = jnp.moveaxis(q, 0, spec.major_axis)
    return q.reshape(spec.m, spec.n)
