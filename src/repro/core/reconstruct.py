"""Reference (pure-jnp) reconstruction ``w = Q z`` and its transpose.

This is the oracle the Pallas kernel and the distributed shard_map op
are validated against, and the default path on CPU.  Differentiable in
``z`` (``grad_z = Q^T grad_w``, exactly the paper's ``∇_s L =
(∇_w L ⊙ Q)`` chain).

Layout (QSpec docstring): rows live in a padded per-block space of
``shard_count`` x ``m_pad_loc``; valid rows map to the tensor flattened
with ``major_axis`` moved to the front (sharding-major order).  All
functions here compute globally — the distributed equivalent is
``kernels.qz_sharded``.

Row plan caching: Q's hash-RNG indices/values are spec-static, so
``_row_plan`` routes through the per-spec numpy cache
(``core.transpose_plan.row_plan``) and enters every trace as a
CONSTANT — a fwd+bwd pair in one jit shares one generation, and no
trace ever re-pays the hash + Box–Muller sweep over m_pad rows.  (The
chunked and sharded FORWARD paths still regenerate per chunk by
design: they exist to bound temporaries, which a baked O(m_pad·d)
constant would defeat; the scatter oracle also keeps traced
generation — XLA:CPU pessimizes scatters whose index operand is a
large constant.  The plan BACKWARD is different: its O(n·deg) slab is
static read-only data resident once per (spec, order) — chunking
bounds the gather TEMPORARIES, not the slab; callers needing the
scatter path's strict O(rpc·d) footprint set
``REPRO_BWD_PLAN=scatter``.)  All
constant-index gathers go through raw PROMISE_IN_BOUNDS ``lax.gather``
(``_gather_rows``): ``jnp.take``'s bounds masks and negative-index
normalization would be constant-folded over the O(m_pad·d) slab for
tens of seconds per trace at bench scale.

The transpose ``grad_z = Q^T grad_w`` has two implementations, gated
at trace time by ``core.transpose_plan.resolve_bwd_path()`` (env
``REPRO_BWD_PLAN``; default 'plan'):

 - PLAN (default): a gather + reduction over each coordinate's
   incoming edges.  Every nonzero of window ``i``'s rows lands in
   window ``i``'s coordinates, so Q^T factors into ``num_windows``
   independent (window × rows_per_window·d) blocks; the cached
   ``TransposePlan`` inverts the row plan once (counting sort, numpy)
   into degree-padded per-coordinate edge lists ``(src_row, val)`` and
   the backward becomes

       grad_z[w, c] = sum_e vals[w, c, e] · g_pad[w·rpw + rows[w, c, e]]

   — a contiguous ``take_along_axis`` + multiply + deg-axis sum that
   vectorizes (and batches over K clients) where the scatter
   serializes.  Ordering contract: the deg-axis sum runs in the plan's
   edge order, so runs are bit-reproducible per ordering mode
   ('canonical' = sorted by source row; 'slot' for cross-order tests)
   and ``allclose`` across modes and vs the scatter oracle.
 - SCATTER (oracle): the original ``.at[gidx].add`` scatter-add,
   kept as the bit-exactness baseline (``grad_z_scatter_ref``).

Batched (multi-client) variants: ``reconstruct_batched_ref`` /
``grad_z_batched_ref`` take a stacked ``Z (K, n)`` and use the cached
plan ONCE, contracting it against all K client vectors.
``jax.vmap(reconstruct_ref)`` shares the constant too, but the batched
entry also picks a size-dependent contraction strategy
(``_BATCH_MAP_THRESHOLD``):

 - LARGE specs (``m_pad·d`` above the threshold): a ``lax.map`` over
   clients of 1-D gathers.  XLA:CPU lowers the (K, m_pad, d)
   mega-gather to a strided column gather that is slower than K
   contiguous row gathers, and the map keeps temporaries at
   O(m_pad·d) instead of O(K·m_pad·d).
 - SMALL specs: one fused batched gather + einsum, exactly what vmap
   would emit.  Inside ``vmap(grad(lax.scan))`` (the federated round)
   a ``lax.map`` body costs ~ms per iteration in XLA:CPU while-loop
   form, which at test scale swamps any savings.

The crossover point is tuned for XLA:CPU (re-measured with the plan
backward by ``benchmarks.run bench_threshold`` — see the committed
``batch_map_threshold`` rows in BENCH_reconstruct.json); set the env
var ``REPRO_BATCH_MAP_THRESHOLD`` (elements of hash work
``m_pad * d``) to retune on other backends without code edits — it is
read at trace time, so changing it between jit calls of different
shapes takes effect immediately (an already-compiled shape keeps its
strategy).
"""

from __future__ import annotations

import functools
import os

import numpy as np
import jax
import jax.numpy as jnp

from .qspec import QSpec, padded_row_valid, padded_row_window, row_indices, row_values
from .transpose_plan import build_transpose_plan, resolve_bwd_path, row_plan


def _row_plan(spec: QSpec):
    """Cached hash-RNG indices/values for ALL padded rows (constants).

    Returns (gidx (m_pad, d) global z-indices, vals (m_pad, d) f32) —
    numpy from the per-spec cache, so they enter the trace as
    constants and fwd+bwd in one jit share one generation.
    """
    gidx, vals = row_plan(spec)
    return jnp.asarray(gidx), jnp.asarray(vals)


def _row_plan_traced(spec: QSpec):
    """Hash-RNG indices/values generated IN-GRAPH (traced ops).

    The scatter oracle keeps this: XLA:CPU pessimizes scatters whose
    index operand is a large constant (measured 5-10x slower than the
    same scatter with computed indices), so baking the cached plan into
    the scatter path would corrupt the very baseline the plan path is
    measured against.
    """
    rp = jnp.arange(spec.m_pad, dtype=jnp.uint32)
    win = padded_row_window(spec, rp.astype(jnp.int32))
    idx = row_indices(spec, rp)  # (m_pad, d) in-window
    vals = row_values(spec, rp, dtype=jnp.float32)
    return win[:, None] * spec.window + idx, vals


def _gather_rows(x, idx2d):
    """1-D gather ``x[idx2d[:, 0]]`` with no index arithmetic in-graph.

    ``jnp.take``/``take_along_axis`` emit bounds masks and negative-
    index normalization; over the O(m_pad·d) CONSTANT index slabs of
    the cached plans XLA constant-folds those elementwise ops for tens
    of seconds per trace at bench scale.  Indices here are in-bounds by
    construction, so a raw ``lax.gather`` with PROMISE_IN_BOUNDS skips
    all of it.
    """
    dn = jax.lax.GatherDimensionNumbers(
        offset_dims=(), collapsed_slice_dims=(0,), start_index_map=(0,)
    )
    return jax.lax.gather(
        x, idx2d, dn, slice_sizes=(1,),
        mode=jax.lax.GatherScatterMode.PROMISE_IN_BOUNDS,
    )


def _gather_cols(x2d, idx2d):
    """Batched column gather ``x2d[:, idx2d[:, 0]]`` -> (K, N), same
    PROMISE_IN_BOUNDS / no-index-arithmetic rationale as
    ``_gather_rows`` (one shared constant index slab, K rows ride
    along in the slice)."""
    dn = jax.lax.GatherDimensionNumbers(
        offset_dims=(0,), collapsed_slice_dims=(1,), start_index_map=(1,)
    )
    return jax.lax.gather(
        x2d, idx2d, dn, slice_sizes=(x2d.shape[0], 1),
        mode=jax.lax.GatherScatterMode.PROMISE_IN_BOUNDS,
    )


def _w_padded(spec: QSpec, z):
    """All padded rows: w_pad (m_pad,) f32."""
    gidx, vals = _row_plan(spec)
    zg = _gather_rows(z.astype(jnp.float32), gidx.reshape(-1, 1))
    return jnp.sum(vals * zg.reshape(spec.m_pad, spec.d), axis=-1)


def _select_valid(spec: QSpec, w_pad):
    """(m_pad,) -> (m,) in moved (sharding-major) flat order."""
    return w_pad.reshape(spec.shard_count, spec.m_pad_loc)[
        :, : spec.m_blk
    ].reshape(-1)


def _insert_padding(spec: QSpec, flat_moved):
    """(m,) moved order -> (m_pad,) with per-block padding zeros."""
    blocks = flat_moved.reshape(spec.shard_count, spec.m_blk)
    return jnp.pad(
        blocks, ((0, 0), (0, spec.m_pad_loc - spec.m_blk))
    ).reshape(-1)


def _unmove(spec: QSpec, flat_moved):
    w = flat_moved.reshape(spec.moved_shape)
    return jnp.moveaxis(w, 0, spec.major_axis)


def _move(spec: QSpec, w):
    return jnp.moveaxis(w, spec.major_axis, 0).reshape(-1)


def _select_valid_batched(spec: QSpec, w_pad):
    """(K, m_pad) -> (K, m) in moved (sharding-major) flat order."""
    k = w_pad.shape[0]
    return w_pad.reshape(k, spec.shard_count, spec.m_pad_loc)[
        :, :, : spec.m_blk
    ].reshape(k, spec.m)


def _insert_padding_batched(spec: QSpec, flat_moved):
    """(K, m) moved order -> (K, m_pad) with per-block padding zeros."""
    k = flat_moved.shape[0]
    blocks = flat_moved.reshape(k, spec.shard_count, spec.m_blk)
    return jnp.pad(
        blocks, ((0, 0), (0, 0), (0, spec.m_pad_loc - spec.m_blk))
    ).reshape(k, spec.m_pad)


def _unmove_batched(spec: QSpec, flat_moved):
    """(K, m) moved flat order -> (K, *spec.shape)."""
    k = flat_moved.shape[0]
    w = flat_moved.reshape(k, *spec.moved_shape)
    return jnp.moveaxis(w, 1, spec.major_axis + 1)


def _move_batched(spec: QSpec, w):
    """(K, *spec.shape) -> (K, m) moved flat order."""
    return jnp.moveaxis(w, spec.major_axis + 1, 1).reshape(w.shape[0], -1)


# Above this much hash work (m_pad * d elements) the once-per-round
# regeneration saving beats XLA:CPU's per-iteration lax.map overhead.
# Default for XLA:CPU; override via REPRO_BATCH_MAP_THRESHOLD (see
# module docstring) when retuning for TPU/GPU.
_BATCH_MAP_THRESHOLD = 2_000_000


def _batch_map_threshold() -> int:
    """Effective crossover, env-overridable (read at trace time)."""
    return int(os.environ.get("REPRO_BATCH_MAP_THRESHOLD",
                              _BATCH_MAP_THRESHOLD))


def reconstruct_batched_ref(spec: QSpec, Z, dtype=None, row_sharding=None):
    """W = Q z^(k) for K stacked clients. ``Z``: (K, n) -> (K, *shape)."""
    del row_sharding
    if Z.ndim != 2 or Z.shape[-1] != spec.n:
        raise ValueError(f"Z has shape {Z.shape}, spec expects (K, {spec.n})")
    dtype = dtype or Z.dtype
    gidx, vals = _row_plan(spec)
    zf = Z.astype(jnp.float32)
    if spec.m_pad * spec.d >= _batch_map_threshold():
        flat = gidx.reshape(-1, 1)
        w_pad = jax.lax.map(
            lambda z: jnp.sum(
                vals * _gather_rows(z, flat).reshape(spec.m_pad, spec.d),
                axis=-1,
            ),
            zf,
        )
    else:
        zg = _gather_cols(zf, gidx.reshape(-1, 1)).reshape(
            Z.shape[0], spec.m_pad, spec.d
        )
        w_pad = jnp.einsum("md,kmd->km", vals, zg)
    w = _select_valid_batched(spec, w_pad)
    return _unmove_batched(spec, w).astype(dtype)


# ---------------------------------------------------------------------------
# The transpose Q^T g: plan (gather) path and scatter oracle.
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=32)
def _plan_tables_np(spec: QSpec, order: str):
    """Plan slabs for the global gather: rows flattened to GLOBAL
    padded-row ids (n·deg, 1) (windows tile the padded row space
    contiguously: global row = w·rpw + local row), vals (nw, window,
    deg)."""
    plan = build_transpose_plan(spec, order)
    off = np.arange(spec.num_windows, dtype=np.int64)[:, None, None]
    rows = (plan.rows.astype(np.int64)
            + off * spec.rows_per_window).reshape(-1, 1)
    return rows.astype(np.int32), plan.vals, plan.deg


def _plan_tables(spec: QSpec, order: str):
    rows, vals, deg = _plan_tables_np(spec, order)
    return jnp.asarray(rows), jnp.asarray(vals), deg


def _plan_apply(spec: QSpec, rows, vals, deg: int, g_pad):
    """grad_z for one client: one flat gather + deg-axis reduction.

    ``g_pad`` (m_pad,) in padded row space; ``rows`` (n·deg, 1) global
    padded-row ids (``_plan_tables``).  The raw PROMISE_IN_BOUNDS
    gather keeps the constant index slab free of in-graph index
    arithmetic (see ``_gather_rows``).
    """
    gath = _gather_rows(g_pad, rows)
    prod = vals * gath.reshape(spec.num_windows, spec.window, deg)
    return prod.sum(axis=-1).reshape(spec.n)


def grad_z_plan_ref(spec: QSpec, grad_w, order: str = "canonical"):
    """Q^T grad_w as a GATHER over the cached transpose plan."""
    g = _insert_padding(spec, _move(spec, grad_w.astype(jnp.float32)))
    rows, vals, deg = _plan_tables(spec, order)
    return _plan_apply(spec, rows, vals, deg, g)


def grad_z_plan_batched_ref(spec: QSpec, grad_W,
                            order: str = "canonical"):
    """Per-client Q^T grad_w over the plan: (K, *shape) -> (K, n).

    One plan constant feeds all K clients.  Strategy mirrors the
    forward (``_batch_map_threshold``): large specs run a ``lax.map``
    over clients (temporaries O(n·deg), not O(K·n·deg)); small specs
    do one broadcast take_along_axis — identical elementwise expression
    either way, so the deg-axis summation order (the ordering
    contract) is strategy-independent.
    """
    g_pad = _insert_padding_batched(
        spec, _move_batched(spec, grad_W.astype(jnp.float32))
    )
    rows, vals, deg = _plan_tables(spec, order)
    if spec.m_pad * spec.d >= _batch_map_threshold():
        return jax.lax.map(
            lambda g: _plan_apply(spec, rows, vals, deg, g), g_pad
        )
    k = g_pad.shape[0]
    gath = _gather_cols(g_pad, rows)
    prod = vals[None] * gath.reshape(k, spec.num_windows, spec.window, deg)
    return prod.sum(axis=-1).reshape(k, spec.n)


def grad_z_scatter_ref(spec: QSpec, grad_w):
    """Q^T grad_w as the original scatter-add — the bit-exactness
    oracle for the plan path (traced index generation; see
    ``_row_plan_traced``)."""
    g = _insert_padding(spec, _move(spec, grad_w.astype(jnp.float32)))
    gidx, vals = _row_plan_traced(spec)
    out = jnp.zeros((spec.n,), jnp.float32)
    return out.at[gidx.reshape(-1)].add((vals * g[:, None]).reshape(-1))


def grad_z_scatter_batched_ref(spec: QSpec, grad_W):
    """Per-client scatter-add transpose (oracle for the batched plan)."""
    g_pad = _insert_padding_batched(
        spec, _move_batched(spec, grad_W.astype(jnp.float32))
    )
    gidx, vals = _row_plan_traced(spec)
    gidx = gidx.reshape(-1)
    if spec.m_pad * spec.d >= _batch_map_threshold():
        # the scatter-add batches WELL under vmap on XLA:CPU (lax.map
        # of scatters measured 2x slower, the (K, m_pad*d) one-shot
        # batched scatter 1.5x slower)
        def one(gk):
            out = jnp.zeros((spec.n,), jnp.float32)
            return out.at[gidx].add((vals * gk[:, None]).reshape(-1))

        return jax.vmap(one)(g_pad)
    contrib = (vals[None] * g_pad[:, :, None]).reshape(g_pad.shape[0], -1)
    out = jnp.zeros((g_pad.shape[0], spec.n), jnp.float32)
    return out.at[:, gidx].add(contrib)


def grad_z_batched_ref(spec: QSpec, grad_W, row_sharding=None):
    """Q^T grad_w per client: (K, *shape) -> (K, n) f32.

    Dispatches plan vs scatter via ``resolve_bwd_path()`` (env
    ``REPRO_BWD_PLAN``, read at trace time).
    """
    del row_sharding
    kind, order = resolve_bwd_path()
    if kind == "plan":
        return grad_z_plan_batched_ref(spec, grad_W, order)
    return grad_z_scatter_batched_ref(spec, grad_W)


def reconstruct_ref(spec: QSpec, z, dtype=None, row_sharding=None):
    """w = Q z for one tensor. ``z``: (n,) -> weights with spec.shape."""
    del row_sharding  # the ref path computes globally
    if z.shape != (spec.n,):
        raise ValueError(f"z has shape {z.shape}, spec expects ({spec.n},)")
    dtype = dtype or z.dtype
    w = _select_valid(spec, _w_padded(spec, z))
    return _unmove(spec, w).astype(dtype)


def grad_z_ref(spec: QSpec, grad_w, row_sharding=None):
    """Q^T grad_w — the reconstruction transpose. Returns (n,) f32.

    Dispatches plan vs scatter via ``resolve_bwd_path()`` (env
    ``REPRO_BWD_PLAN``, read at trace time).
    """
    del row_sharding
    kind, order = resolve_bwd_path()
    if kind == "plan":
        return grad_z_plan_ref(spec, grad_w, order)
    return grad_z_scatter_ref(spec, grad_w)


def materialize_q(spec: QSpec):
    """Dense (m, n) Q in NATURAL (spec.shape row-major) order —
    tests/small-scale theory checks ONLY."""
    gidx, vals = _row_plan(spec)
    q_pad = jnp.zeros((spec.m_pad, spec.n), jnp.float32)
    q_pad = q_pad.at[jnp.arange(spec.m_pad)[:, None], gidx].add(vals)
    q_moved = q_pad.reshape(spec.shard_count, spec.m_pad_loc, spec.n)[
        :, : spec.m_blk
    ].reshape(spec.m, spec.n)
    # moved flat order -> natural order rows
    q = q_moved.reshape(*spec.moved_shape, spec.n)
    q = jnp.moveaxis(q, 0, spec.major_axis)
    return q.reshape(spec.m, spec.n)
