"""Reference (pure-jnp) reconstruction ``w = Q z``.

This is the oracle the Pallas kernel and the distributed shard_map op
are validated against, and the default path on CPU.  Differentiable in
``z`` (the transpose is a scatter-add, i.e. ``grad_z = Q^T grad_w``,
exactly the paper's ``∇_s L = (∇_w L ⊙ Q)`` chain).

Layout (QSpec docstring): rows live in a padded per-block space of
``shard_count`` x ``m_pad_loc``; valid rows map to the tensor flattened
with ``major_axis`` moved to the front (sharding-major order).  All
functions here compute globally — the distributed equivalent is
``kernels.qz_sharded``.

Batched (multi-client) variants: ``reconstruct_batched_ref`` /
``grad_z_batched_ref`` take a stacked ``Z (K, n)`` and regenerate the
hash-RNG indices/values of Q ONCE, contracting them against all K
client z-vectors.  ``jax.vmap(reconstruct_ref)`` regenerates Q per
client, so at K simulated clients per host the batched path removes
(K-1)/K of the hash+Box-Muller work — the dominant cost of the ref
path (measured ~90% of a single-client reconstruct at paper scale).
The contraction strategy is size-dependent (``_BATCH_MAP_THRESHOLD``):

 - LARGE specs (hash work ``m_pad·d`` above the threshold): a
   ``lax.map`` of 1-D gathers over clients.  XLA:CPU lowers the
   (K, m_pad, d) mega-gather to a strided column gather that is ~2x
   slower than K contiguous row gathers, and the map keeps temporaries
   at O(m_pad·d) instead of O(K·m_pad·d).  Measured ~4x over vmap at
   K=10 on the benchmark spec (m=1M, d=8).
 - SMALL specs: one fused batched gather + einsum.  Inside
   ``vmap(grad(lax.scan))`` (the federated round) a ``lax.map`` body
   costs ~ms per iteration in XLA:CPU while-loop form, which at test
   scale (m~16k) swamps the hash savings; the fused form is exactly
   what vmap would emit, minus the K-times hash regeneration.

The crossover point is tuned for XLA:CPU; set the env var
``REPRO_BATCH_MAP_THRESHOLD`` (elements of hash work ``m_pad * d``) to
retune on other backends without code edits — it is read at trace
time, so changing it between jit calls of different shapes takes
effect immediately (an already-compiled shape keeps its strategy).
"""

from __future__ import annotations

import os

import jax
import jax.numpy as jnp

from .qspec import QSpec, padded_row_valid, padded_row_window, row_indices, row_values


def _row_plan(spec: QSpec):
    """Hash-RNG indices/values for ALL padded rows, generated once.

    Returns (gidx (m_pad, d) global z-indices, vals (m_pad, d) f32).
    """
    rp = jnp.arange(spec.m_pad, dtype=jnp.uint32)
    win = padded_row_window(spec, rp.astype(jnp.int32))
    idx = row_indices(spec, rp)  # (m_pad, d) in-window
    vals = row_values(spec, rp, dtype=jnp.float32)
    return win[:, None] * spec.window + idx, vals


def _w_padded(spec: QSpec, z):
    """All padded rows: w_pad (m_pad,) f32."""
    gidx, vals = _row_plan(spec)
    zg = jnp.take(z.astype(jnp.float32), gidx, axis=0)
    return jnp.sum(vals * zg, axis=-1)


def _select_valid(spec: QSpec, w_pad):
    """(m_pad,) -> (m,) in moved (sharding-major) flat order."""
    return w_pad.reshape(spec.shard_count, spec.m_pad_loc)[
        :, : spec.m_blk
    ].reshape(-1)


def _insert_padding(spec: QSpec, flat_moved):
    """(m,) moved order -> (m_pad,) with per-block padding zeros."""
    blocks = flat_moved.reshape(spec.shard_count, spec.m_blk)
    return jnp.pad(
        blocks, ((0, 0), (0, spec.m_pad_loc - spec.m_blk))
    ).reshape(-1)


def _unmove(spec: QSpec, flat_moved):
    w = flat_moved.reshape(spec.moved_shape)
    return jnp.moveaxis(w, 0, spec.major_axis)


def _move(spec: QSpec, w):
    return jnp.moveaxis(w, spec.major_axis, 0).reshape(-1)


def _select_valid_batched(spec: QSpec, w_pad):
    """(K, m_pad) -> (K, m) in moved (sharding-major) flat order."""
    k = w_pad.shape[0]
    return w_pad.reshape(k, spec.shard_count, spec.m_pad_loc)[
        :, :, : spec.m_blk
    ].reshape(k, spec.m)


def _insert_padding_batched(spec: QSpec, flat_moved):
    """(K, m) moved order -> (K, m_pad) with per-block padding zeros."""
    k = flat_moved.shape[0]
    blocks = flat_moved.reshape(k, spec.shard_count, spec.m_blk)
    return jnp.pad(
        blocks, ((0, 0), (0, 0), (0, spec.m_pad_loc - spec.m_blk))
    ).reshape(k, spec.m_pad)


def _unmove_batched(spec: QSpec, flat_moved):
    """(K, m) moved flat order -> (K, *spec.shape)."""
    k = flat_moved.shape[0]
    w = flat_moved.reshape(k, *spec.moved_shape)
    return jnp.moveaxis(w, 1, spec.major_axis + 1)


def _move_batched(spec: QSpec, w):
    """(K, *spec.shape) -> (K, m) moved flat order."""
    return jnp.moveaxis(w, spec.major_axis + 1, 1).reshape(w.shape[0], -1)


# Above this much hash work (m_pad * d elements) the once-per-round
# regeneration saving beats XLA:CPU's per-iteration lax.map overhead.
# Default for XLA:CPU; override via REPRO_BATCH_MAP_THRESHOLD (see
# module docstring) when retuning for TPU/GPU.
_BATCH_MAP_THRESHOLD = 2_000_000


def _batch_map_threshold() -> int:
    """Effective crossover, env-overridable (read at trace time)."""
    return int(os.environ.get("REPRO_BATCH_MAP_THRESHOLD",
                              _BATCH_MAP_THRESHOLD))


def reconstruct_batched_ref(spec: QSpec, Z, dtype=None, row_sharding=None):
    """W = Q z^(k) for K stacked clients. ``Z``: (K, n) -> (K, *shape)."""
    del row_sharding
    if Z.ndim != 2 or Z.shape[-1] != spec.n:
        raise ValueError(f"Z has shape {Z.shape}, spec expects (K, {spec.n})")
    dtype = dtype or Z.dtype
    gidx, vals = _row_plan(spec)
    zf = Z.astype(jnp.float32)
    if spec.m_pad * spec.d >= _batch_map_threshold():
        w_pad = jax.lax.map(
            lambda z: jnp.sum(vals * jnp.take(z, gidx, axis=0), axis=-1), zf
        )
    else:
        zg = jnp.take(zf, gidx, axis=1)  # (K, m_pad, d)
        w_pad = jnp.einsum("md,kmd->km", vals, zg)
    w = _select_valid_batched(spec, w_pad)
    return _unmove_batched(spec, w).astype(dtype)


def grad_z_batched_ref(spec: QSpec, grad_W, row_sharding=None):
    """Q^T grad_w per client: (K, *shape) -> (K, n) f32."""
    del row_sharding
    g_pad = _insert_padding_batched(
        spec, _move_batched(spec, grad_W.astype(jnp.float32))
    )
    gidx, vals = _row_plan(spec)
    gidx = gidx.reshape(-1)
    if spec.m_pad * spec.d >= _batch_map_threshold():
        # unlike the forward gather, the scatter-add batches WELL under
        # vmap on XLA:CPU (lax.map of scatters measured 2x slower, the
        # (K, m_pad*d) one-shot batched scatter 1.5x slower); vmap-of-
        # scatter with the hash hoisted is the fastest of the three
        def one(gk):
            out = jnp.zeros((spec.n,), jnp.float32)
            return out.at[gidx].add((vals * gk[:, None]).reshape(-1))

        return jax.vmap(one)(g_pad)
    contrib = (vals[None] * g_pad[:, :, None]).reshape(g_pad.shape[0], -1)
    out = jnp.zeros((g_pad.shape[0], spec.n), jnp.float32)
    return out.at[:, gidx].add(contrib)


def reconstruct_ref(spec: QSpec, z, dtype=None, row_sharding=None):
    """w = Q z for one tensor. ``z``: (n,) -> weights with spec.shape."""
    del row_sharding  # the ref path computes globally
    if z.shape != (spec.n,):
        raise ValueError(f"z has shape {z.shape}, spec expects ({spec.n},)")
    dtype = dtype or z.dtype
    w = _select_valid(spec, _w_padded(spec, z))
    return _unmove(spec, w).astype(dtype)


def grad_z_ref(spec: QSpec, grad_w, row_sharding=None):
    """Q^T grad_w — the reconstruction transpose. Returns (n,) f32."""
    del row_sharding
    g = _insert_padding(spec, _move(spec, grad_w.astype(jnp.float32)))
    gidx, vals = _row_plan(spec)
    out = jnp.zeros((spec.n,), jnp.float32)
    return out.at[gidx.reshape(-1)].add((vals * g[:, None]).reshape(-1))


def materialize_q(spec: QSpec):
    """Dense (m, n) Q in NATURAL (spec.shape row-major) order —
    tests/small-scale theory checks ONLY."""
    gidx, vals = _row_plan(spec)
    q_pad = jnp.zeros((spec.m_pad, spec.n), jnp.float32)
    q_pad = q_pad.at[jnp.arange(spec.m_pad)[:, None], gidx].add(vals)
    q_moved = q_pad.reshape(spec.shard_count, spec.m_pad_loc, spec.n)[
        :, : spec.m_blk
    ].reshape(spec.m, spec.n)
    # moved flat order -> natural order rows
    q = q_moved.reshape(*spec.moved_shape, spec.n)
    q = jnp.moveaxis(q, 0, spec.major_axis)
    return q.reshape(spec.m, spec.n)
