"""LOCAL ZAMPLING trainer (paper §1.3, centralized version).

Drives the paper's own experiments: train the score vector with a fresh
mask sample per forward pass, Adam optimizer, early stopping with
patience/delta as in §3 ("100 epochs with early stopping, 10 epochs of
patience, delta 1e-4").
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Dict, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..core.sampling import clip_probs, discretize_mask
from ..core.zampling import ZamplingSpecs, sample_weights, weights_from_masks
from ..optim import Optimizer, adam
from ..optim.optimizers import apply_updates


@dataclass(frozen=True)
class LocalTrainConfig:
    steps: int = 500
    lr: float = 1e-3
    mode: str = "sample"  # sample | continuous
    eval_every: int = 50
    patience: int = 10  # evaluations without improvement
    min_delta: float = 1e-4
    seed: int = 0


def train_local_zampling(
    zspecs: ZamplingSpecs,
    state: Dict[str, Any],
    loss_fn: Callable,  # (params, batch) -> scalar
    batch_iter: Iterator,
    cfg: LocalTrainConfig,
    eval_fn: Optional[Callable] = None,  # (params) -> metric (higher=better)
    optimizer: Optional[Optimizer] = None,
):
    opt = optimizer or adam(cfg.lr)
    key = jax.random.PRNGKey(cfg.seed)

    @jax.jit
    def train_step(state, opt_state, batch, key):
        def loss(tr):
            params = sample_weights(zspecs, tr, key, mode=cfg.mode)
            return loss_fn(params, batch)

        l, grads = jax.value_and_grad(loss)(state)
        updates, opt_state = opt.update(grads, opt_state, state)
        return apply_updates(state, updates), opt_state, l

    opt_state = opt.init(state)
    history = {"loss": [], "eval": []}
    best, stale = -np.inf, 0
    for t in range(cfg.steps):
        key, sub = jax.random.split(key)
        batch = next(batch_iter)
        state, opt_state, l = train_step(state, opt_state, batch, sub)
        history["loss"].append(float(l))
        if eval_fn is not None and (t + 1) % cfg.eval_every == 0:
            params = sample_weights(
                zspecs, state, jax.random.fold_in(key, 1), mode="continuous"
            )
            m = float(eval_fn(params))
            history["eval"].append(m)
            if m > best + cfg.min_delta:
                best, stale = m, 0
            else:
                stale += 1
                if stale >= cfg.patience:
                    break
    return state, history


def evaluate(
    zspecs: ZamplingSpecs,
    state: Dict[str, Any],
    metric_fn: Callable,  # (params) -> scalar
    key,
    *,
    mode: str = "sample",
    n_samples: int = 100,
    carried: Optional[str] = None,
):
    """Mean/std metric over sampled networks (paper's 'sampled accuracy'),
    or the expected (mode='continuous') / discretized network.

    ``carried`` names the downlink codec of an ENCODED score state
    (explicit-tag routing, validated against the leaves; the packed
    sub-byte codecs share a uint32 carrier, so dtype sniffing alone is
    ambiguous there).  None sniffs the dtype, raising on ambiguity."""
    if mode in ("continuous", "discretize"):
        params = sample_weights(zspecs, state, key, mode=mode,
                                carried=carried)
        v = float(metric_fn(params))
        return v, 0.0
    vals = []
    for i in range(n_samples):
        params = sample_weights(zspecs, state, jax.random.fold_in(key, i),
                                mode="sample", carried=carried)
        vals.append(float(metric_fn(params)))
    return float(np.mean(vals)), float(np.std(vals))
