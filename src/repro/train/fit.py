"""Scan-over-rounds federated drivers (ROADMAP "Multi-round pipelining").

``federated_round`` recompiles per (K, E) batch shape AND pays one
dispatch per round when driven from Python.  ``federated_fit`` carries
R rounds through a single ``lax.scan``: one compilation per
(R, K, E, batch) shape, one dispatch for the whole block, with the
stacked client batches prefetched as a (R, K, E, ...) slab.  Round r
uses key ``jax.random.split(key, R)[r]`` AND round counter ``r`` —
the scan threads the integer round index into every mask-draw word
(the counter-based hash RNG's ``step``; see ``core.sampling``) — so a
fit over R rounds is numerically the same computation as R sequential
``federated_round(..., round_index=r)`` calls with those keys.

``sharded_client_fit`` is the same scan wrapped around
``sharded_client_update`` — the body to run inside ``shard_map`` on the
production mesh, where each shard sees its own (R, E, ...) batch slab
and the per-round mask aggregation stays a single collective
(``FederatedConfig.aggregate`` selects the wire transport).

Downlink codec (``FederatedConfig.downlink``, ``comm.downlink``): the
scan CARRY is the codec-encoded score pytree — each round decodes the
broadcast client-side, trains, aggregates, and re-encodes, so with a
quantized codec (``u8``/``u16``) the carried state between rounds IS
the metered wire representation (uint8/uint16 words), never an f32
score slab.  Callers encode an f32 init state once with
``core.federated.encode_state`` before the first round; ``f32``
(default) carries plain scores, bit-identical to the pre-codec
drivers.  The encode dither word is derived from (round key,
round_index) only, so the fit ≡ R-sequential-rounds equivalence holds
per codec.
"""

from __future__ import annotations

from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from ..core.federated import (
    FederatedConfig,
    LossFn,
    federated_round,
    sharded_client_update,
)
from ..core.zampling import ZamplingSpecs
from ..optim import Optimizer


def _rounds_and_keys(round_batches, key, rounds):
    """Slice the batch slab to ``rounds`` (when given) and derive one
    subkey + round counter per round — round r always uses
    ``split(key, R)[r]`` and ``round_index=r``."""
    r = rounds if rounds is not None else jax.tree.leaves(
        round_batches)[0].shape[0]
    if rounds is not None:
        round_batches = jax.tree.map(lambda x: x[:r], round_batches)
    return (round_batches, jax.random.split(key, r),
            jnp.arange(r, dtype=jnp.uint32))


def federated_fit(
    zspecs: ZamplingSpecs,
    state: Dict[str, Any],
    loss_fn: LossFn,
    round_batches,  # pytree with leading axes (R, K, local_steps, ...)
    key,
    cfg: FederatedConfig,
    opt: Optional[Optimizer] = None,
    rounds: Optional[int] = None,
):
    """R federated rounds under one ``lax.scan``.

    Returns (state', metrics) with every metric stacked to shape (R,).
    Wrap in ``jax.jit`` (or call from jitted code): the whole block
    compiles once and re-runs for any same-shape batch slab.
    ``rounds`` runs only the first ``rounds`` entries of the slab.
    """
    round_batches, keys, rids = _rounds_and_keys(round_batches, key, rounds)

    def body(state, xs):
        batches, sub, rid = xs
        state, metrics = federated_round(
            zspecs, state, loss_fn, batches, sub, cfg, opt, round_index=rid
        )
        return state, metrics

    return jax.lax.scan(body, state, (round_batches, keys, rids))


def sharded_client_fit(
    zspecs: ZamplingSpecs,
    state: Dict[str, Any],
    loss_fn: LossFn,
    round_batches,  # per-shard pytree with leading axes (R, local_steps, ...)
    key,
    cfg: FederatedConfig,
    *,
    axis_names=("data",),
    opt: Optional[Optimizer] = None,
    constraints=None,
    row_sharding=None,
    rounds: Optional[int] = None,
):
    """R rounds of ``sharded_client_update`` under one ``lax.scan`` —
    run this INSIDE ``shard_map`` (client id = mesh position).  The key
    is replicated; every shard derives the same per-round subkeys and
    ``sharded_client_update`` folds in the axis index per client."""
    round_batches, keys, rids = _rounds_and_keys(round_batches, key, rounds)

    def body(state, xs):
        batches, sub, rid = xs
        state, metrics = sharded_client_update(
            zspecs, state, loss_fn, batches, sub, cfg,
            axis_names=axis_names, opt=opt, constraints=constraints,
            row_sharding=row_sharding, round_index=rid,
        )
        return state, metrics

    return jax.lax.scan(body, state, (round_batches, keys, rids))
