"""Scan-over-rounds federated drivers (ROADMAP "Multi-round pipelining").

``federated_round`` recompiles per (K, E) batch shape AND pays one
dispatch per round when driven from Python.  ``federated_fit`` carries
R rounds through a single ``lax.scan``: one compilation per
(R, K, E, batch) shape, one dispatch for the whole block, with the
stacked client batches prefetched as a (R, K, E, ...) slab.  Round r
uses key ``jax.random.split(key, R)[r]`` AND round counter ``r`` —
the scan threads the integer round index into every mask-draw word
(the counter-based hash RNG's ``step``; see ``core.sampling``) — so a
fit over R rounds is numerically the same computation as R sequential
``federated_round(..., round_index=r)`` calls with those keys.

``sharded_client_fit`` is the same scan wrapped around
``sharded_client_update`` — the body to run inside ``shard_map`` on the
production mesh, where each shard sees its own (R, E, ...) batch slab
and the per-round mask aggregation stays a single collective
(``FederatedConfig.aggregate`` selects the wire transport).

Partial participation (``repro.fault``) threads through both scans:
``client_ids`` / ``weights`` are per-round xs — (R, K) stacked slabs
on the vmap driver, per-shard (R,) slices under shard_map (stage them
host-side from ``ClientPopulation.cohort_np``, the same draw the
traced round replays) — and ``faults`` is a static ``FaultPlan``
whose per-(round, client) draws key on the scanned round counter, so
one compiled block covers every fault scenario the plan can produce.
The scan carry is unchanged: a skipped round (cohort below
``min_clients``) passes the state through and flags
``round_skipped`` in that round's metrics row.

Downlink codec (``FederatedConfig.downlink``, ``comm.downlink``): the
scan CARRY is the codec-encoded score pytree — each round decodes the
broadcast client-side, trains, aggregates, and re-encodes, so with a
quantized codec (``u8``/``u16``) the carried state between rounds IS
the metered wire representation (uint8/uint16 words), never an f32
score slab.  Callers encode an f32 init state once with
``core.federated.encode_state`` before the first round; ``f32``
(default) carries plain scores, bit-identical to the pre-codec
drivers.  The encode dither word is derived from (round key,
round_index) only, so the fit ≡ R-sequential-rounds equivalence holds
per codec.

Downlink rate schedules (``FederatedConfig.downlink_schedule``): the
per-round, per-tensor width vector is a TRACED function of the scanned
round counter (``cosine``) or a carried ``state["downlink_b"]`` leaf
(``frontier`` — seeded by ``encode_state``, updated by the round body
from the measured draw-word flip fraction), so an R-round scheduled
fit still compiles ONCE — no per-width recompilation.  ``constant``
(default) is the plain fixed-codec path, bit for bit.  Start a
frontier fit from ``encode_state(zspecs, cfg, state)`` so the width
vector is in the scan carry from round 0.

Streaming + host staging (``FederatedConfig.stream_chunk``, the
unbounded-K mode): ``federated_fit``'s scanned round body reroutes to
the chunk-fold accumulator automatically when the config streams — the
(R, K, E, ...) batch slab is still prefetched whole, but no round ever
materializes a (K, lanes) upload slab.  ``streamed_federated_fit`` is
the production-shaped driver on top: it consumes a host-side
``data.cohort_batch_stream`` round by round and DOUBLE-BUFFERS the
upload pipeline — round t+1's cohort slab is ``jax.device_put`` while
round t's dispatched computation still runs (JAX dispatch is async;
the loop never blocks between rounds), so host→device staging
overlaps device compute and peak device residency is two cohort slabs
+ one chunk of uploads, independent of R and K.  Round r uses key
``split(key, R)[r]`` and ``round_index=r`` — the SAME derivation as
``federated_fit``'s scan — so the two drivers are numerically
identical rounds-for-rounds (bit-identical scores; see
tests/test_streaming.py).
"""

from __future__ import annotations

from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from ..core.federated import (
    FederatedConfig,
    LossFn,
    federated_round,
    sharded_client_update,
)
from ..core.zampling import ZamplingSpecs
from ..optim import Optimizer


def _rounds_and_keys(round_batches, key, rounds):
    """Slice the batch slab to ``rounds`` (when given) and derive one
    subkey + round counter per round — round r always uses
    ``split(key, R)[r]`` and ``round_index=r``."""
    r = rounds if rounds is not None else jax.tree.leaves(
        round_batches)[0].shape[0]
    if rounds is not None:
        round_batches = jax.tree.map(lambda x: x[:r], round_batches)
    return (round_batches, jax.random.split(key, r),
            jnp.arange(r, dtype=jnp.uint32))


def _scan_xs(round_batches, keys, rids, client_ids, weights):
    """The scanned xs dict: batches/keys/round-ids always, the
    participation slabs only when given (leading axis R on each)."""
    r = rids.shape[0]
    xs = {"batches": round_batches, "key": keys, "rid": rids}
    for name, val in (("client_ids", client_ids), ("weights", weights)):
        if val is not None:
            val = jnp.asarray(val)[:r]
            if val.shape[0] != r:
                raise ValueError(
                    f"{name} leading axis {val.shape[0]} != rounds {r}"
                )
            xs[name] = val.astype(jnp.uint32)
    return xs


def federated_fit(
    zspecs: ZamplingSpecs,
    state: Dict[str, Any],
    loss_fn: LossFn,
    round_batches,  # pytree with leading axes (R, K, local_steps, ...)
    key,
    cfg: FederatedConfig,
    opt: Optional[Optimizer] = None,
    rounds: Optional[int] = None,
    client_ids=None,  # (R, K) uint32 per-round cohort ids
    weights=None,  # (R, K) uint32 per-round sample-count weights
    faults=None,  # static FaultPlan (repro.fault)
):
    """R federated rounds under one ``lax.scan``.

    Returns (state', metrics) with every metric stacked to shape (R,).
    Wrap in ``jax.jit`` (or call from jitted code): the whole block
    compiles once and re-runs for any same-shape batch slab.
    ``rounds`` runs only the first ``rounds`` entries of the slab.
    """
    round_batches, keys, rids = _rounds_and_keys(round_batches, key, rounds)
    xs = _scan_xs(round_batches, keys, rids, client_ids, weights)

    def body(state, xs):
        state, metrics = federated_round(
            zspecs, state, loss_fn, xs["batches"], xs["key"], cfg, opt,
            round_index=xs["rid"], client_ids=xs.get("client_ids"),
            weights=xs.get("weights"), faults=faults,
        )
        return state, metrics

    return jax.lax.scan(body, state, xs)


def streamed_federated_fit(
    zspecs: ZamplingSpecs,
    state: Dict[str, Any],
    loss_fn: LossFn,
    stream,  # data.cohort_batch_stream iterator: (ids, weights, x, y)
    key,
    cfg: FederatedConfig,
    rounds: int,
    opt: Optional[Optimizer] = None,
    faults=None,  # static FaultPlan (repro.fault)
):
    """R rounds driven from a host-side cohort stream with
    double-buffered device staging.

    Each round is one jitted ``federated_round`` call (compiled once
    for the cohort shape).  While round t's computation is dispatched
    and running on the device, round t+1's cohort — ids, weights, and
    the (K, E, B, ...) batch slab — is already being ``jax.device_put``
    from the host: the loop issues the transfer immediately after the
    dispatch and never calls ``block_until_ready`` in between, so
    staging rides under compute.  Combine with ``cfg.stream_chunk`` to
    bound upload memory too: then no (K, lanes) slab exists anywhere
    in the pipeline.

    Returns (state', metrics) with metrics stacked to (R,) — the same
    contract, key derivation (``split(key, R)[r]``, ``round_index=r``),
    and therefore bit-identical scores as ``federated_fit`` over the
    stacked slabs of the same stream.
    """
    keys = jax.random.split(key, rounds)
    rids = jnp.arange(rounds, dtype=jnp.uint32)

    @jax.jit
    def one_round(state, batch, key, rid, ids, weights):
        return federated_round(
            zspecs, state, loss_fn, batch, key, cfg, opt,
            round_index=rid, client_ids=ids, weights=weights,
            faults=faults,
        )

    def stage(item):
        ids, weights, x, y = item
        return jax.device_put((
            jnp.asarray(ids).astype(jnp.uint32),
            jnp.asarray(weights).astype(jnp.uint32),
            {"x": jnp.asarray(x), "y": jnp.asarray(y)},
        ))

    nxt = stage(next(stream))
    metrics = []
    for r in range(rounds):
        ids, weights, batch = nxt
        state, m = one_round(state, batch, keys[r], rids[r], ids,
                             weights)
        # stage round r+1 while round r computes (async dispatch)
        if r + 1 < rounds:
            nxt = stage(next(stream))
        metrics.append(m)
    return state, jax.tree.map(lambda *xs: jnp.stack(xs), *metrics)


def sharded_client_fit(
    zspecs: ZamplingSpecs,
    state: Dict[str, Any],
    loss_fn: LossFn,
    round_batches,  # per-shard pytree with leading axes (R, local_steps, ...)
    key,
    cfg: FederatedConfig,
    *,
    axis_names=("data",),
    opt: Optional[Optimizer] = None,
    constraints=None,
    row_sharding=None,
    rounds: Optional[int] = None,
    client_ids=None,  # per-shard (R,) uint32 global client ids
    weights=None,  # per-shard (R,) uint32 sample-count weights
    faults=None,  # static FaultPlan (repro.fault)
):
    """R rounds of ``sharded_client_update`` under one ``lax.scan`` —
    run this INSIDE ``shard_map`` (client id = mesh position).  The key
    is replicated; every shard derives the same per-round subkeys and
    ``sharded_client_update`` folds in the axis index per client."""
    round_batches, keys, rids = _rounds_and_keys(round_batches, key, rounds)
    xs = _scan_xs(round_batches, keys, rids, client_ids, weights)

    def body(state, xs):
        state, metrics = sharded_client_update(
            zspecs, state, loss_fn, xs["batches"], xs["key"], cfg,
            axis_names=axis_names, opt=opt, constraints=constraints,
            row_sharding=row_sharding, round_index=xs["rid"],
            client_id=xs.get("client_ids"), weight=xs.get("weights"),
            faults=faults,
        )
        return state, metrics

    return jax.lax.scan(body, state, xs)
