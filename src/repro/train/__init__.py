from .fit import federated_fit, sharded_client_fit, streamed_federated_fit
from .local import LocalTrainConfig, evaluate, train_local_zampling
from .steps import TrainState, make_train_step, make_zampling_train_step

__all__ = [
    "LocalTrainConfig", "evaluate", "train_local_zampling",
    "TrainState", "make_train_step", "make_zampling_train_step",
    "federated_fit", "sharded_client_fit", "streamed_federated_fit",
]
