"""Train steps for the big-model path (pjit-able; used by the dry-run).

Two step builders:
 - ``make_train_step``            standard training (the naive-FL /
                                  dense-DP baseline the paper compares
                                  against);
 - ``make_zampling_train_step``   training-by-sampling on scores: the
                                  paper's system. Per step: p=clip(s),
                                  z~Bern(p) (straight-through), w=Qz,
                                  CE loss, Adam/SGD on s.

Both close over static specs/model and take (state, batch, key).
"""

from __future__ import annotations

from typing import Any, Dict, NamedTuple, Optional

import jax
import jax.numpy as jnp

from ..core.zampling import ZamplingSpecs, sample_weights
from ..models.model import Model, loss_fn
from ..optim import Optimizer
from ..optim.optimizers import apply_updates


class TrainState(NamedTuple):
    trainable: Any  # params (standard) or {'scores','dense'} (zampling)
    opt: Any
    step: jnp.ndarray


def init_train_state(trainable, optimizer: Optimizer) -> TrainState:
    return TrainState(trainable, optimizer.init(trainable),
                      jnp.zeros((), jnp.int32))


def make_train_step(model: Model, optimizer: Optimizer):
    def step(state: TrainState, batch):
        def loss(params):
            return loss_fn(model, params, batch)

        l, grads = jax.value_and_grad(loss)(state.trainable)
        updates, opt = optimizer.update(grads, state.opt, state.trainable)
        params = apply_updates(state.trainable, updates)
        return TrainState(params, opt, state.step + 1), {"loss": l}

    return step


def make_zampling_train_step(model: Model, zspecs: ZamplingSpecs,
                             optimizer: Optimizer):
    def step(state: TrainState, batch, key):
        key = jax.random.fold_in(key, state.step)

        def loss(trainable):
            params = sample_weights(zspecs, trainable, key)
            return loss_fn(model, params, batch)

        l, grads = jax.value_and_grad(loss)(state.trainable)
        updates, opt = optimizer.update(grads, state.opt, state.trainable)
        trainable = jax.tree.map(
            lambda p, u: (p + u).astype(p.dtype), state.trainable, updates
        )
        return TrainState(trainable, opt, state.step + 1), {"loss": l}

    return step
