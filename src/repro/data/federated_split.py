"""IID client partitioning (the paper assumes IID splits, §1.3)."""

from __future__ import annotations

from typing import Iterator, List, Tuple

import numpy as np

from .synthetic import SyntheticClassification


def iid_client_split(ds: SyntheticClassification, num_clients: int,
                     seed: int = 0) -> List[SyntheticClassification]:
    rng = np.random.RandomState(seed)
    n = len(ds.x_train)
    perm = rng.permutation(n)
    shards = np.array_split(perm, num_clients)
    return [
        SyntheticClassification(
            ds.x_train[s], ds.y_train[s], ds.x_test, ds.y_test
        )
        for s in shards
    ]


def client_batch_stream(
    clients: List[SyntheticClassification],
    batch_size: int,
    local_steps: int,
    seed: int = 0,
) -> Iterator[Tuple[np.ndarray, np.ndarray]]:
    """Yields stacked (K, local_steps, B, ...) batches per round."""
    rng = np.random.RandomState(seed)
    while True:
        xs, ys = [], []
        for c in clients:
            n = len(c.x_train)
            idx = rng.randint(0, n, (local_steps, batch_size))
            xs.append(c.x_train[idx])
            ys.append(c.y_train[idx])
        yield np.stack(xs), np.stack(ys)
