"""Client partitioning: IID (the paper's §1.3 assumption) and
Dirichlet-β non-IID (the production regime the fault-tolerant round
engine targets — fedPrune-style ``--total-clients N`` populations with
heterogeneous label mixes and UNEQUAL per-client dataset sizes, the
sample-count weights of the weighted aggregation in
``core.federated``)."""

from __future__ import annotations

from typing import Iterator, List, Tuple

import numpy as np

from .synthetic import SyntheticClassification


def iid_client_split(ds: SyntheticClassification, num_clients: int,
                     seed: int = 0) -> List[SyntheticClassification]:
    rng = np.random.RandomState(seed)
    n = len(ds.x_train)
    perm = rng.permutation(n)
    shards = np.array_split(perm, num_clients)
    return [
        SyntheticClassification(
            ds.x_train[s], ds.y_train[s], ds.x_test, ds.y_test
        )
        for s in shards
    ]


def dirichlet_client_split(
    ds: SyntheticClassification,
    num_clients: int,
    beta: float = 0.5,
    seed: int = 0,
) -> Tuple[List[SyntheticClassification], np.ndarray]:
    """Dirichlet-β non-IID split with per-client label histograms.

    For every class c, a draw ``q ~ Dir(beta 1_K)`` apportions that
    class's examples across the K clients — small β concentrates each
    class on few clients (pathological non-IID), large β approaches
    IID.  Returns ``(clients, hist)`` where ``hist`` is the (K, C)
    label-count matrix; ``hist.sum(axis=1)`` are the per-client sample
    counts that ``fault.population.ClientPopulation`` takes as the
    aggregation weights of the partial-participation round.  Every
    client is guaranteed at least one example (a weight-0 client could
    never contribute): empty clients steal one example from the
    largest.
    """
    if beta <= 0:
        raise ValueError(f"beta must be > 0, got {beta}")
    rng = np.random.RandomState(seed)
    y = np.asarray(ds.y_train)
    classes = np.unique(y)
    shards: List[List[np.ndarray]] = [[] for _ in range(num_clients)]
    for c in classes:
        idx = rng.permutation(np.flatnonzero(y == c))
        q = rng.dirichlet(np.full(num_clients, beta))
        # proportions -> contiguous slices of the shuffled class pool
        cuts = (np.cumsum(q)[:-1] * len(idx)).astype(np.int64)
        for k, part in enumerate(np.split(idx, cuts)):
            shards[k].append(part)
    owned = [np.concatenate(s) if s else np.empty(0, np.int64)
             for s in shards]
    for k in range(num_clients):
        while len(owned[k]) == 0:
            donor = int(np.argmax([len(o) for o in owned]))
            owned[k] = owned[donor][-1:]
            owned[donor] = owned[donor][:-1]
    hist = np.zeros((num_clients, len(classes)), np.int64)
    clients = []
    for k, s in enumerate(owned):
        s = rng.permutation(s)
        for j, c in enumerate(classes):
            hist[k, j] = int(np.sum(y[s] == c))
        clients.append(SyntheticClassification(
            ds.x_train[s], ds.y_train[s], ds.x_test, ds.y_test
        ))
    return clients, hist


def client_batch_stream(
    clients: List[SyntheticClassification],
    batch_size: int,
    local_steps: int,
    seed: int = 0,
) -> Iterator[Tuple[np.ndarray, np.ndarray]]:
    """Yields stacked (K, local_steps, B, ...) batches per round."""
    rng = np.random.RandomState(seed)
    while True:
        xs, ys = [], []
        for c in clients:
            n = len(c.x_train)
            idx = rng.randint(0, n, (local_steps, batch_size))
            xs.append(c.x_train[idx])
            ys.append(c.y_train[idx])
        yield np.stack(xs), np.stack(ys)


def cohort_batch_stream(
    clients: List[SyntheticClassification],
    population,  # fault.population.ClientPopulation over these clients
    cohort_size: int,
    batch_size: int,
    local_steps: int,
    seed: int = 0,
) -> Iterator[Tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray]]:
    """Host-side data staging for partial-participation rounds.

    Round r replays the SAME K-of-N cohort draw the traced round
    derives from the hash stream (``ClientPopulation.cohort_np`` —
    pure in (population.seed, r)) and stages batches for exactly those
    K clients.  Yields ``(client_ids, weights, x, y)`` per round with
    x/y stacked (cohort_size, local_steps, batch_size, ...) — feed ids
    and weights straight into ``federated_round`` / ``federated_fit``
    so the draw words key on the GLOBAL client ids.
    """
    if len(clients) != population.num_clients:
        raise ValueError(
            f"{len(clients)} client datasets for a population of "
            f"{population.num_clients}"
        )
    rng = np.random.RandomState(seed)
    r = 0
    while True:
        ids, weights = population.cohort_np(r, cohort_size)
        xs, ys = [], []
        for cid in ids:
            c = clients[int(cid)]
            idx = rng.randint(0, len(c.x_train), (local_steps, batch_size))
            xs.append(c.x_train[idx])
            ys.append(c.y_train[idx])
        yield ids, weights, np.stack(xs), np.stack(ys)
        r += 1
