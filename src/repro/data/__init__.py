from .synthetic import SyntheticClassification, lm_token_batches, make_teacher_dataset
from .federated_split import iid_client_split, client_batch_stream

__all__ = [
    "SyntheticClassification", "lm_token_batches", "make_teacher_dataset",
    "iid_client_split", "client_batch_stream",
]
