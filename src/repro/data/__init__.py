from .synthetic import SyntheticClassification, lm_token_batches, make_teacher_dataset
from .federated_split import (
    client_batch_stream,
    cohort_batch_stream,
    dirichlet_client_split,
    iid_client_split,
)

__all__ = [
    "SyntheticClassification", "lm_token_batches", "make_teacher_dataset",
    "iid_client_split", "dirichlet_client_split", "client_batch_stream",
    "cohort_batch_stream",
]
