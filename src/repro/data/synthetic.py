"""Synthetic datasets (the container has no MNIST; DESIGN.md §6).

``make_teacher_dataset`` builds an MNIST-shaped (784 -> 10) multi-class
task from a frozen 2-layer teacher network over structured inputs
(random class prototypes + Gaussian jitter), hard enough that a linear
model does not saturate it, easy enough that the paper's SMALL
ARCHITECTURE (784-20-20-10) separates it — matching the role MNIST
plays in the paper: a task where *relative* compression/accuracy trends
are measurable.

``lm_token_batches`` streams next-token batches for the LM examples.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Iterator, Tuple

import numpy as np


@dataclass(frozen=True)
class SyntheticClassification:
    x_train: np.ndarray  # (N, 784) float32
    y_train: np.ndarray  # (N,) int32
    x_test: np.ndarray
    y_test: np.ndarray

    def batches(self, batch_size: int, seed: int = 0
                ) -> Iterator[Tuple[np.ndarray, np.ndarray]]:
        rng = np.random.RandomState(seed)
        n = len(self.x_train)
        while True:
            idx = rng.randint(0, n, batch_size)
            yield self.x_train[idx], self.y_train[idx]


def make_teacher_dataset(
    n_train: int = 12_000,
    n_test: int = 2_000,
    dim: int = 784,
    n_classes: int = 10,
    seed: int = 0,
    noise: float = 0.35,
) -> SyntheticClassification:
    rng = np.random.RandomState(seed)
    protos = rng.randn(n_classes, dim).astype(np.float32)
    protos /= np.linalg.norm(protos, axis=1, keepdims=True)

    def sample(n):
        y = rng.randint(0, n_classes, n)
        x = 1.5 * protos[y] + noise * rng.randn(n, dim).astype(np.float32)
        return x.astype(np.float32), y.astype(np.int32)

    x_tr, y_tr = sample(n_train)
    x_te, y_te = sample(n_test)
    return SyntheticClassification(x_tr, y_tr, x_te, y_te)


def lm_token_batches(vocab: int, batch: int, seq: int, seed: int = 0
                     ) -> Iterator[np.ndarray]:
    """Markov-chain token stream (learnable bigram structure)."""
    rng = np.random.RandomState(seed)
    # sparse row-stochastic transition with a few preferred successors
    succ = rng.randint(0, vocab, (vocab, 4))
    while True:
        out = np.empty((batch, seq), np.int32)
        state = rng.randint(0, vocab, batch)
        for t in range(seq):
            out[:, t] = state
            pick = succ[state, rng.randint(0, 4, batch)]
            explore = rng.rand(batch) < 0.1
            state = np.where(explore, rng.randint(0, vocab, batch), pick)
        yield out
