from .paper import (
    comm_savings_table,
    run_downlink_tradeoff,
    run_federated,
    run_heterogeneity,
    run_integrality,
    run_local_compression,
    run_sensitivity,
    run_wire_formats,
    run_zhou_comparison,
)

__all__ = [
    "comm_savings_table", "run_downlink_tradeoff", "run_federated",
    "run_heterogeneity", "run_integrality", "run_local_compression",
    "run_sensitivity", "run_wire_formats", "run_zhou_comparison",
]
